"""Chaos hammer: the concurrency hammer of test_serving_concurrency
run *under fault injection* (src/repro/faults.py) — scan faults with
retry, every maintenance pass crashing mid-recluster, the cache failing
closed, the ticker thread dying — while 8 threads mix submits (some
with tight latency budgets), writes, and maintenance triggers.

Contracts (ISSUE acceptance):
  * every admitted query reaches exactly one terminal status
    (``sum(status_counts.values()) == queries_submitted``) — no query
    is lost to an injected fault;
  * PARTIAL results carry finite recall estimates;
  * no deadlocks: thread joins are watchdog-bounded, and the lock
    sanitizer sees zero order/guarded violations even on fault paths;
  * self-healing leaves the index byte-identical to a fault-free
    replay of the surviving write operations (maintenance crashes roll
    back completely; ``index_state_fingerprint`` compares).
"""
import threading

import numpy as np
import pytest

from repro import sanitize
from repro.core import QuakeConfig, QuakeIndex, ServingConfig, ServingRuntime
from repro.core.serving import TERMINAL_STATUSES, STATUS_PARTIAL
from repro.data import datasets
from repro.faults import FaultInjector, index_state_fingerprint


@pytest.fixture(scope="module")
def ds():
    return datasets.clustered(2000, 16, n_clusters=12, seed=0)


def build(ds):
    return QuakeIndex.build(ds.vectors, num_partitions=16, kmeans_iters=3,
                            config=QuakeConfig())


N_THREADS, OPS_PER_THREAD = 8, 25
JOIN_TIMEOUT_S = 120.0           # deadlock watchdog, not an expectation


def test_chaos_hammer_terminal_statuses_and_replay(ds):
    idx = build(ds)
    fi = FaultInjector(seed=11, rates={
        "scan": 0.05,            # transient: retries absorb these
        "maintenance": 1.0,      # every pass crashes mid-recluster
        "cache": 1.0,            # first probe fails -> cache-off
        "ticker": 0.2,           # ticker dies, restarts on admission
    })
    cfg = ServingConfig(k=10, flush_size=4, scan_backend="host",
                        cache_entries=64, flush_deadline_ms=5.0,
                        ticker=True, maint_min_ops=32,
                        queue_cap=32, queue_policy="shed-newest",
                        govern=True, govern_patience=2,
                        scan_retries=6, scan_backoff_s=0.0005,
                        scan_backoff_max_s=0.002,
                        record_admissions=True)
    qs = datasets.queries_near(ds, 64, seed=5).astype(np.float32)
    qids, qids_lock = [], threading.Lock()
    errors = []

    def worker(tid, rt):
        rng = np.random.default_rng(100 + tid)
        my_ids = []
        try:
            for i in range(OPS_PER_THREAD):
                r = rng.random()
                if r < 0.60:
                    qid = rt.submit_query(qs[rng.integers(len(qs))])
                    with qids_lock:
                        qids.append(qid)
                elif r < 0.70:
                    # tight budget: may retire PARTIAL mid-search
                    qid = rt.submit_query(qs[rng.integers(len(qs))],
                                          deadline_s=0.002)
                    with qids_lock:
                        qids.append(qid)
                elif r < 0.80:
                    eid = 500_000 + tid * 1000 + i
                    rt.submit_insert(qs[None, rng.integers(len(qs))] + 0.01,
                                     np.array([eid]))
                    my_ids.append(eid)
                elif r < 0.90 and my_ids:
                    rt.submit_delete(np.array([my_ids.pop()]))
                else:
                    rt.maybe_maintain()      # crashes + rolls back (rate 1.0)
                if i % 7 == 0:
                    rt.stats()
        except BaseException as e:   # noqa: BLE001 - surfaced below
            errors.append((tid, e))

    with ServingRuntime(idx, cfg, faults=fi) as rt:
        with sanitize.sanitized(transfers=False, nans=False,
                                compiles=False, locks=True), \
                sanitize.LockOrderWatchdog() as wd:
            threads = [threading.Thread(target=worker, args=(t, rt))
                       for t in range(N_THREADS)]
            for t in threads:
                t.start()
            for t in threads:
                t.join(timeout=JOIN_TIMEOUT_S)
            stuck = [t.name for t in threads if t.is_alive()]
            assert not stuck, f"deadlocked worker threads: {stuck}"
            rt.drain()
            assert not errors, errors
            # lock discipline holds on the fault paths too
            assert wd.events.order_violations == 0
            assert wd.events.guarded_violations == 0
            assert wd.events.acquisitions > 0
        st = rt.stats()
        log = rt.admission_log()

        # -- every query reached exactly one terminal status ------------
        assert sum(st["status_counts"].values()) == st["queries_submitted"]
        assert st["queue_depth"] == 0
        assert st["in_flight"] == 0
        for qid in qids:
            res = rt.result(qid)
            assert res is not None, f"query {qid} lost"
            assert res.status in TERMINAL_STATUSES, (qid, res.status)
            if res.status == STATUS_PARTIAL:
                assert np.isfinite(res.recall_estimate)
                assert 0.0 <= res.recall_estimate <= 1.0

        # -- the injected faults actually fired and were survived -------
        trips = fi.counters()["trips"]
        assert trips.get("cache", 0) >= 1 and st["cache_disabled"] is True
        if trips.get("maintenance", 0):
            assert st["maintenance_failures"] >= 1
            assert st["maintenance_runs"] == 0    # nothing ever committed
        if st["scan_faults"]:                     # retries absorbed them
            assert st["scan_retries_used"] >= 1

        faulted_fp = index_state_fingerprint(idx)
        idx.check_invariants()

    # -- self-healing: fault-free replay of surviving writes ------------
    # Maintenance always crashed and rolled back, so the post-chaos index
    # must equal a fresh identical build plus the admission-log writes
    # applied in engine-lock order, byte for byte.
    twin = build(ds)
    replay_cfg = ServingConfig(k=10, flush_size=10 ** 9,
                               scan_backend="host", cache_entries=0,
                               ticker=False, maint_min_ops=10 ** 9)
    with ServingRuntime(twin, replay_cfg) as rt2:
        for entry in log:
            if entry[0] == "insert":
                rt2.submit_insert(entry[1], entry[2])
            elif entry[0] == "delete":
                rt2.submit_delete(entry[1])
        rt2.drain()
    assert index_state_fingerprint(twin) == faulted_fp
    twin.check_invariants()
