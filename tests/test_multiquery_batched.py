"""Batched multi-query executor vs the dynamic index (paper §7.4).

The batched path must return the *same* results as per-query
``QuakeIndex.search`` for a fixed ``nprobe`` (identical probe sets, exact
scans — only float-accumulation order differs), while scanning each probed
partition once per batch instead of once per query.
"""
import numpy as np
import pytest

from repro.core import QuakeConfig, QuakeIndex
from repro.core.multiquery import (batch_search, get_executor, plan_batch,
                                   per_query_search)
from repro.data import datasets


@pytest.fixture(scope="module")
def built():
    ds = datasets.clustered(4000, 16, n_clusters=16, seed=0)
    idx = QuakeIndex.build(ds.vectors, num_partitions=32, kmeans_iters=4)
    return ds, idx


@pytest.mark.parametrize("impl", ["jnp", "pallas"])
@pytest.mark.parametrize("b", [1, 16, 64])
def test_batched_matches_single_query_fixed_nprobe(built, impl, b):
    ds, idx = built
    q = datasets.queries_near(ds, b, seed=2)
    rb = batch_search(idx, q, 10, nprobe=6, impl=impl)
    assert rb.ids.shape == (b, 10)
    for i in range(b):
        r = idx.search(q[i], 10, nprobe=6, record_stats=False)
        got = rb.ids[i][rb.ids[i] >= 0]
        assert set(got.tolist()) == set(r.ids.tolist()), i
        np.testing.assert_allclose(
            np.sort(rb.dists[i][np.isfinite(rb.dists[i])]),
            np.sort(r.dists), rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("metric", ["l2", "ip"])
def test_batched_matches_single_query_metrics(built, metric):
    ds, _ = built
    idx = QuakeIndex.build(ds.vectors, num_partitions=24, kmeans_iters=3,
                           config=QuakeConfig(metric=metric))
    q = datasets.queries_near(ds, 12, seed=3)
    rb = batch_search(idx, q, 10, nprobe=5, impl="jnp")
    for i in range(12):
        r = idx.search(q[i], 10, nprobe=5, record_stats=False)
        got = rb.ids[i][rb.ids[i] >= 0]
        assert set(got.tolist()) == set(r.ids.tolist()), i


def test_partition_scan_amortization(built):
    """On an overlapping batch the union is strictly smaller than B*nprobe
    and the streamed vector count beats the per-query re-scan total."""
    ds, idx = built
    b, nprobe = 64, 8
    q = datasets.queries_near(ds, b, seed=4)
    rb = batch_search(idx, q, 10, nprobe=nprobe, impl="jnp")
    rp = per_query_search(idx, q, 10, nprobe=nprobe, impl="jnp")
    assert rb.partitions_scanned < b * nprobe
    assert rb.partitions_scanned <= idx.num_partitions
    assert rb.vectors_scanned < rp.vectors_scanned
    # the comparison count (per-query work) equals the baseline's streaming
    # count — only the memory traffic is amortized, never the math
    assert rb.comparisons == rp.vectors_scanned
    # identical results from both paths
    assert (np.sort(rb.ids, 1) == np.sort(rp.ids, 1)).all()


def test_aps_driven_plan_is_per_query(built):
    ds, idx = built
    q = datasets.queries_near(ds, 24, seed=5)
    rb = batch_search(idx, q, 10, recall_target=0.9)
    assert rb.nprobe is not None and len(rb.nprobe) == 24
    assert (rb.nprobe >= 1).all()
    assert len(np.unique(rb.nprobe)) > 1  # adaptive, not one global count
    gt = ds.ground_truth(q, 10)
    rec = np.mean([len(set(rb.ids[i].tolist()) & set(gt[i].tolist())) / 10
                   for i in range(24)])
    assert rec >= 0.8, rec


def test_snapshot_invalidated_on_mutation(built):
    ds, _ = built
    idx = QuakeIndex.build(ds.vectors[:2000], num_partitions=16,
                           kmeans_iters=3)
    q = datasets.queries_near(ds, 4, seed=6)
    batch_search(idx, q, 5, nprobe=4)
    ex = get_executor(idx)
    key0 = ex._key
    new_ids = np.arange(5000, 5004)
    idx.insert(q[:4] * 0.999, new_ids)
    rb = batch_search(idx, q, 5, nprobe=4)
    assert ex._key != key0  # snapshot refreshed
    # a small insert refreshes through the dirty-partition delta path,
    # not a full O(N*d) rebuild
    assert ex.delta_refreshes == 1 and ex.full_rebuilds == 1
    hits = set(rb.ids.ravel().tolist()) & set(new_ids.tolist())
    assert hits  # fresh inserts are visible to the batched path


def test_plan_union_padding_is_inert(built):
    """Union padding duplicates a real partition with an all-False mask —
    result columns never reference it on behalf of a non-probing query."""
    ds, idx = built
    q = datasets.queries_near(ds, 3, seed=7)
    plan = plan_batch(idx, np.asarray(q, np.float32), 10, nprobe=3,
                      u_bucket=16)
    assert len(plan.sel) % 16 == 0
    assert plan.n_real <= len(plan.sel)
    assert not plan.qmask[:, plan.n_real:].any()
    assert (plan.nprobe == 3).all()


def test_per_query_search_forwards_recall_target(built):
    """per_query_search must exercise the APS planner one query at a time
    — the B=1 case of batch_search with the same recall_target."""
    ds, idx = built
    q = datasets.queries_near(ds, 8, seed=8)
    rp = per_query_search(idx, q, 10, recall_target=0.9)
    assert rp.nprobe is not None and len(np.unique(rp.nprobe)) > 1
    for i in range(8):
        rb = batch_search(idx, q[i], 10, recall_target=0.9)
        assert set(rp.ids[i].tolist()) == set(rb.ids[0].tolist()), i
        assert rp.nprobe[i] == rb.nprobe[0], i


@pytest.mark.parametrize("dtype,min_overlap", [("bf16", 0.9),
                                               ("int8", 0.85)])
def test_storage_dtype_recall_vs_f32_oracle(built, dtype, min_overlap):
    """Quantized batched paths: recall within quantization tolerance of the
    f32 oracle, and the masked-slot contract (ids -1 <=> dists inf) holds."""
    ds, idx = built
    q = datasets.queries_near(ds, 32, seed=9)
    gt = ds.ground_truth(q, 10)
    r32 = batch_search(idx, q, 10, nprobe=6)
    rq = batch_search(idx, q, 10, nprobe=6, storage_dtype=dtype)
    assert rq.ids.shape == r32.ids.shape
    # same probe plan -> identical scan footprint, smaller bytes
    assert rq.partitions_scanned == r32.partitions_scanned
    assert rq.vectors_scanned == r32.vectors_scanned
    overlap = np.mean([len(set(rq.ids[i].tolist())
                           & set(r32.ids[i].tolist())) / 10
                       for i in range(32)])
    assert overlap >= min_overlap, overlap
    rec32 = np.mean([len(set(r32.ids[i].tolist()) & set(gt[i].tolist()))
                     / 10 for i in range(32)])
    recq = np.mean([len(set(rq.ids[i].tolist()) & set(gt[i].tolist()))
                    / 10 for i in range(32)])
    assert rec32 - recq <= 0.05, (rec32, recq)
    # masked-slot contract
    miss = ~np.isfinite(rq.dists)
    assert (rq.ids[miss] == -1).all()
    assert (rq.ids[~miss] >= 0).all()
    assert np.isfinite(rq.dists[~miss]).all()


def test_storage_dtype_refresh_policy(built):
    """bf16 snapshots take the journal delta path (patches cast on
    device); int8 snapshots force a full rebuild on any content delta
    (residual codes would need requantizing) — the sharded engine's
    policy, mirrored."""
    ds, _ = built
    for dtype, want_delta in (("bf16", True), ("int8", False)):
        idx = QuakeIndex.build(ds.vectors[:2000], num_partitions=16,
                               kmeans_iters=3)
        ex = get_executor(idx, dtype)
        q = datasets.queries_near(ds, 4, seed=10)
        ex.search(q, 5, nprobe=4)
        assert ex.full_rebuilds == 1
        new_ids = np.arange(8000, 8004)
        idx.insert(q * 0.999, new_ids)
        r = ex.search(q, 5, nprobe=4)
        if want_delta:
            assert ex.delta_refreshes == 1 and ex.full_rebuilds == 1
        else:
            assert ex.delta_refreshes == 0 and ex.full_rebuilds == 2
        # fresh inserts visible through either refresh path
        assert set(r.ids.ravel().tolist()) & set(new_ids.tolist())


def test_executors_cached_per_storage_dtype(built):
    ds, _ = built
    idx = QuakeIndex.build(ds.vectors[:2000], num_partitions=16,
                           kmeans_iters=3)
    assert get_executor(idx) is get_executor(idx, "f32")
    assert get_executor(idx, "int8") is get_executor(idx, "int8")
    assert get_executor(idx, "int8") is not get_executor(idx)
    assert get_executor(idx, "int8").storage_dtype == "int8"
