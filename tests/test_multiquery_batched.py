"""Batched multi-query executor vs the dynamic index (paper §7.4).

The batched path must return the *same* results as per-query
``QuakeIndex.search`` for a fixed ``nprobe`` (identical probe sets, exact
scans — only float-accumulation order differs), while scanning each probed
partition once per batch instead of once per query.
"""
import numpy as np
import pytest

from repro.core import QuakeConfig, QuakeIndex
from repro.core.multiquery import (batch_search, get_executor, plan_batch,
                                   per_query_search)
from repro.data import datasets


@pytest.fixture(scope="module")
def built():
    ds = datasets.clustered(4000, 16, n_clusters=16, seed=0)
    idx = QuakeIndex.build(ds.vectors, num_partitions=32, kmeans_iters=4)
    return ds, idx


@pytest.mark.parametrize("impl", ["jnp", "pallas"])
@pytest.mark.parametrize("b", [1, 16, 64])
def test_batched_matches_single_query_fixed_nprobe(built, impl, b):
    ds, idx = built
    q = datasets.queries_near(ds, b, seed=2)
    rb = batch_search(idx, q, 10, nprobe=6, impl=impl)
    assert rb.ids.shape == (b, 10)
    for i in range(b):
        r = idx.search(q[i], 10, nprobe=6, record_stats=False)
        got = rb.ids[i][rb.ids[i] >= 0]
        assert set(got.tolist()) == set(r.ids.tolist()), i
        np.testing.assert_allclose(
            np.sort(rb.dists[i][np.isfinite(rb.dists[i])]),
            np.sort(r.dists), rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("metric", ["l2", "ip"])
def test_batched_matches_single_query_metrics(built, metric):
    ds, _ = built
    idx = QuakeIndex.build(ds.vectors, num_partitions=24, kmeans_iters=3,
                           config=QuakeConfig(metric=metric))
    q = datasets.queries_near(ds, 12, seed=3)
    rb = batch_search(idx, q, 10, nprobe=5, impl="jnp")
    for i in range(12):
        r = idx.search(q[i], 10, nprobe=5, record_stats=False)
        got = rb.ids[i][rb.ids[i] >= 0]
        assert set(got.tolist()) == set(r.ids.tolist()), i


def test_partition_scan_amortization(built):
    """On an overlapping batch the union is strictly smaller than B*nprobe
    and the streamed vector count beats the per-query re-scan total."""
    ds, idx = built
    b, nprobe = 64, 8
    q = datasets.queries_near(ds, b, seed=4)
    rb = batch_search(idx, q, 10, nprobe=nprobe, impl="jnp")
    rp = per_query_search(idx, q, 10, nprobe=nprobe, impl="jnp")
    assert rb.partitions_scanned < b * nprobe
    assert rb.partitions_scanned <= idx.num_partitions
    assert rb.vectors_scanned < rp.vectors_scanned
    # the comparison count (per-query work) equals the baseline's streaming
    # count — only the memory traffic is amortized, never the math
    assert rb.comparisons == rp.vectors_scanned
    # identical results from both paths
    assert (np.sort(rb.ids, 1) == np.sort(rp.ids, 1)).all()


def test_aps_driven_plan_is_per_query(built):
    ds, idx = built
    q = datasets.queries_near(ds, 24, seed=5)
    rb = batch_search(idx, q, 10, recall_target=0.9)
    assert rb.nprobe is not None and len(rb.nprobe) == 24
    assert (rb.nprobe >= 1).all()
    assert len(np.unique(rb.nprobe)) > 1  # adaptive, not one global count
    gt = ds.ground_truth(q, 10)
    rec = np.mean([len(set(rb.ids[i].tolist()) & set(gt[i].tolist())) / 10
                   for i in range(24)])
    assert rec >= 0.8, rec


def test_snapshot_invalidated_on_mutation(built):
    ds, _ = built
    idx = QuakeIndex.build(ds.vectors[:2000], num_partitions=16,
                           kmeans_iters=3)
    q = datasets.queries_near(ds, 4, seed=6)
    batch_search(idx, q, 5, nprobe=4)
    ex = get_executor(idx)
    key0 = ex._key
    new_ids = np.arange(5000, 5004)
    idx.insert(q[:4] * 0.999, new_ids)
    rb = batch_search(idx, q, 5, nprobe=4)
    assert ex._key != key0  # snapshot refreshed
    # a small insert refreshes through the dirty-partition delta path,
    # not a full O(N*d) rebuild
    assert ex.delta_refreshes == 1 and ex.full_rebuilds == 1
    hits = set(rb.ids.ravel().tolist()) & set(new_ids.tolist())
    assert hits  # fresh inserts are visible to the batched path


def test_plan_union_padding_is_inert(built):
    """Union padding duplicates a real partition with an all-False mask —
    result columns never reference it on behalf of a non-probing query."""
    ds, idx = built
    q = datasets.queries_near(ds, 3, seed=7)
    plan = plan_batch(idx, np.asarray(q, np.float32), 10, nprobe=3,
                      u_bucket=16)
    assert len(plan.sel) % 16 == 0
    assert plan.n_real <= len(plan.sel)
    assert not plan.qmask[:, plan.n_real:].any()
    assert (plan.nprobe == 3).all()


def test_per_query_search_forwards_recall_target(built):
    """per_query_search must exercise the APS planner one query at a time
    — the B=1 case of batch_search with the same recall_target."""
    ds, idx = built
    q = datasets.queries_near(ds, 8, seed=8)
    rp = per_query_search(idx, q, 10, recall_target=0.9)
    assert rp.nprobe is not None and len(np.unique(rp.nprobe)) > 1
    for i in range(8):
        rb = batch_search(idx, q[i], 10, recall_target=0.9)
        assert set(rp.ids[i].tolist()) == set(rb.ids[0].tolist()), i
        assert rp.nprobe[i] == rb.nprobe[0], i


@pytest.mark.parametrize("dtype,min_overlap", [("bf16", 0.9),
                                               ("int8", 0.85)])
def test_storage_dtype_recall_vs_f32_oracle(built, dtype, min_overlap):
    """Quantized batched paths: recall within quantization tolerance of the
    f32 oracle, and the masked-slot contract (ids -1 <=> dists inf) holds."""
    ds, idx = built
    q = datasets.queries_near(ds, 32, seed=9)
    gt = ds.ground_truth(q, 10)
    r32 = batch_search(idx, q, 10, nprobe=6)
    rq = batch_search(idx, q, 10, nprobe=6, storage_dtype=dtype)
    assert rq.ids.shape == r32.ids.shape
    # same probe plan -> identical scan footprint, smaller bytes
    assert rq.partitions_scanned == r32.partitions_scanned
    assert rq.vectors_scanned == r32.vectors_scanned
    overlap = np.mean([len(set(rq.ids[i].tolist())
                           & set(r32.ids[i].tolist())) / 10
                       for i in range(32)])
    assert overlap >= min_overlap, overlap
    rec32 = np.mean([len(set(r32.ids[i].tolist()) & set(gt[i].tolist()))
                     / 10 for i in range(32)])
    recq = np.mean([len(set(rq.ids[i].tolist()) & set(gt[i].tolist()))
                    / 10 for i in range(32)])
    assert rec32 - recq <= 0.05, (rec32, recq)
    # masked-slot contract
    miss = ~np.isfinite(rq.dists)
    assert (rq.ids[miss] == -1).all()
    assert (rq.ids[~miss] >= 0).all()
    assert np.isfinite(rq.dists[~miss]).all()


def test_storage_dtype_refresh_policy(built):
    """bf16 snapshots take the journal delta path (patches cast on
    device); int8 snapshots force a full rebuild on any content delta
    (residual codes would need requantizing) — the sharded engine's
    policy, mirrored."""
    ds, _ = built
    for dtype, want_delta in (("bf16", True), ("int8", False)):
        idx = QuakeIndex.build(ds.vectors[:2000], num_partitions=16,
                               kmeans_iters=3)
        ex = get_executor(idx, dtype)
        q = datasets.queries_near(ds, 4, seed=10)
        ex.search(q, 5, nprobe=4)
        assert ex.full_rebuilds == 1
        new_ids = np.arange(8000, 8004)
        idx.insert(q * 0.999, new_ids)
        r = ex.search(q, 5, nprobe=4)
        if want_delta:
            assert ex.delta_refreshes == 1 and ex.full_rebuilds == 1
        else:
            assert ex.delta_refreshes == 0 and ex.full_rebuilds == 2
        # fresh inserts visible through either refresh path
        assert set(r.ids.ravel().tolist()) & set(new_ids.tolist())


def test_executors_cached_per_storage_dtype(built):
    ds, _ = built
    idx = QuakeIndex.build(ds.vectors[:2000], num_partitions=16,
                           kmeans_iters=3)
    assert get_executor(idx) is get_executor(idx, "f32")
    assert get_executor(idx, "int8") is get_executor(idx, "int8")
    assert get_executor(idx, "int8") is not get_executor(idx)
    assert get_executor(idx, "int8").storage_dtype == "int8"


# ---------------------------------------------------------------------------
# Multi-round early-exit executor (Algorithm 2)
# ---------------------------------------------------------------------------

def _recall_of(ids, gt):
    k = gt.shape[1]
    return np.mean([len(set(ids[i].tolist()) & set(gt[i].tolist())) / k
                    for i in range(len(gt))])


def test_rounds1_is_fixed_plan(built):
    """rounds=1 forces the monolithic fixed-plan scan: one round, no
    trace, stats identical to the packed plan, and byte-identical results
    across repeated calls (the pre-round-executor behaviour)."""
    ds, idx = built
    q = datasets.queries_near(ds, 16, seed=21)
    ex = get_executor(idx)
    r1 = ex.search(q, 10, recall_target=0.9, rounds=1)
    r2 = ex.search(q, 10, recall_target=0.9, rounds=1)
    assert r1.rounds == 1 and r1.round_trace is None
    np.testing.assert_array_equal(r1.ids, r2.ids)
    np.testing.assert_array_equal(r1.dists, r2.dists)
    plan = plan_batch(idx, np.asarray(q, np.float32), 10,
                      recall_target=0.9, cache=ex.planner_cache,
                      cent_norms=ex._cent_norms)
    assert r1.partitions_scanned == plan.n_real
    np.testing.assert_array_equal(r1.nprobe, plan.nprobe)


def test_earlyexit_subset_of_fixed_plan(built):
    """The round path scans a per-query *prefix* of the fixed plan under
    union riding, so: never more streamed vectors, per-rank distances
    dominate the fixed path's, and queries that never exited early get
    exactly the fixed-plan result."""
    ds, idx = built
    q = datasets.queries_near(ds, 24, seed=22)
    ex = get_executor(idx)
    r_fix = ex.search(q, 10, recall_target=0.9, rounds=1)
    r_ee = ex.search(q, 10, recall_target=0.9)
    assert r_ee.vectors_scanned <= r_fix.vectors_scanned
    assert r_ee.comparisons <= r_fix.comparisons
    assert (r_ee.nprobe <= r_fix.nprobe).all()
    d_fix = np.where(np.isfinite(r_fix.dists), r_fix.dists, np.inf)
    d_ee = np.where(np.isfinite(r_ee.dists), r_ee.dists, np.inf)
    assert (d_ee >= d_fix - 1e-6).all()
    full = r_ee.nprobe >= r_fix.nprobe       # scanned the whole plan
    assert full.any()
    for i in np.nonzero(full)[0]:
        assert set(r_ee.ids[i].tolist()) == set(r_fix.ids[i].tolist()), i


def test_earlyexit_monotone_round_budget(built):
    """More rounds = more exit opportunities: scanned vectors and
    comparisons are non-increasing in the round budget, and recall stays
    within a narrow band of the fixed plan's."""
    ds, idx = built
    q = datasets.queries_near(ds, 24, seed=23)
    gt = ds.ground_truth(q, 10)
    ex = get_executor(idx)
    vecs, comps, recs = [], [], []
    for rounds in (1, 2, 3, None):
        r = ex.search(q, 10, recall_target=0.9, rounds=rounds)
        vecs.append(r.vectors_scanned)
        comps.append(r.comparisons)
        recs.append(_recall_of(r.ids, gt))
    assert all(a >= b for a, b in zip(vecs, vecs[1:])), vecs
    assert all(a >= b for a, b in zip(comps, comps[1:])), comps
    assert min(recs) >= 0.8
    assert recs[0] - recs[-1] <= 0.05, recs


def test_earlyexit_trace_and_recall_estimate(built):
    """APS-planned batched results must carry the per-query recall
    estimate (the satellite contract for QuakeIndex.search_batch) and the
    per-round trace; exited queries report estimates above the target."""
    ds, idx = built
    q = datasets.queries_near(ds, 24, seed=24)
    r = idx.search_batch(q, 10, recall_target=0.9)
    assert r.recall_estimate is not None and len(r.recall_estimate) == 24
    tr = r.round_trace
    assert tr is not None and len(tr["round_live"]) == r.rounds
    assert tr["round_live"][0] == 24
    assert all(a >= b for a, b in zip(tr["round_live"], tr["round_live"][1:]))
    exited = r.nprobe < np.asarray(
        plan_batch(idx, np.asarray(q, np.float32), 10, recall_target=0.9,
                   ).planned)
    assert (r.recall_estimate[exited] >= 0.9 - 1e-9).all()
    # nprobe-pinned searches have no estimator: no estimate, one round
    rp = idx.search_batch(q, 10, nprobe=4)
    assert rp.recall_estimate is None and rp.rounds == 1


@pytest.mark.parametrize("dtype", ["bf16", "int8"])
def test_earlyexit_storage_dtypes(built, dtype):
    """The round path runs all storage dtypes: recall within quantization
    tolerance of the f32 round path, footprint never above the fixed
    plan, and the masked-slot contract holds."""
    ds, idx = built
    q = datasets.queries_near(ds, 24, seed=25)
    gt = ds.ground_truth(q, 10)
    r32 = batch_search(idx, q, 10, recall_target=0.9)
    rq = batch_search(idx, q, 10, recall_target=0.9, storage_dtype=dtype)
    rq_fix = batch_search(idx, q, 10, recall_target=0.9,
                          storage_dtype=dtype, rounds=1)
    assert rq.vectors_scanned <= rq_fix.vectors_scanned
    assert _recall_of(r32.ids, gt) - _recall_of(rq.ids, gt) <= 0.06
    miss = ~np.isfinite(rq.dists)
    assert (rq.ids[miss] == -1).all() and (rq.ids[~miss] >= 0).all()


def test_earlyexit_snapshot_refresh_interaction(built):
    """Early-exit searches ride the same journal-driven snapshot
    coherence: bf16 refreshes through the delta path, int8 full-rebuilds
    on any content delta, and fresh inserts are visible to the round
    path either way."""
    ds, _ = built
    for dtype, want_delta in (("bf16", True), ("int8", False)):
        idx = QuakeIndex.build(ds.vectors[:2000], num_partitions=16,
                               kmeans_iters=3)
        ex = get_executor(idx, dtype)
        q = datasets.queries_near(ds, 6, seed=26)
        r0 = ex.search(q, 5, recall_target=0.9)
        assert r0.rounds >= 1 and ex.full_rebuilds == 1
        new_ids = np.arange(9000, 9006)
        idx.insert(q * 0.999, new_ids)
        r = ex.search(q, 5, recall_target=0.9)
        if want_delta:
            assert ex.delta_refreshes == 1 and ex.full_rebuilds == 1
        else:
            assert ex.delta_refreshes == 0 and ex.full_rebuilds == 2
        assert set(r.ids.ravel().tolist()) & set(new_ids.tolist())


def test_earlyexit_union_cap_falls_back_to_fixed_plan(built):
    """union_cap's footprint bound is plan-level truncation, so capped
    searches keep the one-shot capped plan (a per-round cap would let
    the batch total exceed the cap): one round, total partitions within
    the anchor-floored cap, every query keeps a hit, and truncated
    queries report no (NaN) planner recall estimate."""
    ds, idx = built
    q = datasets.queries_near(ds, 32, seed=27)
    ex = get_executor(idx)
    r = ex.search(q, 10, recall_target=0.9, union_cap=6)
    assert r.rounds == 1 and r.round_trace is None
    plan = plan_batch(idx, np.asarray(q, np.float32), 10,
                      recall_target=0.9, union_cap=6,
                      cache=ex.planner_cache, cent_norms=ex._cent_norms)
    anchors = len(np.unique(plan.anchor))
    assert r.partitions_scanned <= max(6, anchors)
    assert (r.ids[:, 0] >= 0).all()
    assert np.isfinite(r.dists[:, 0]).all()
    truncated = plan.nprobe < plan.planned
    assert truncated.any(), "cap did not truncate; tighten the setup"
    assert np.isnan(plan.recall_est[truncated]).all()
    assert np.isfinite(plan.recall_est[~truncated]).all()


def test_rounds_budget_validation(built):
    ds, idx = built
    q = datasets.queries_near(ds, 4, seed=29)
    with pytest.raises(ValueError):
        get_executor(idx).search(q, 10, recall_target=0.9, rounds=0)


def test_earlyexit_b1_matches_per_query(built):
    """B=1 round search is per_query_search's unit of work: identical
    results and probe counts, and the recall estimate survives the
    per-query aggregation."""
    ds, idx = built
    q = datasets.queries_near(ds, 6, seed=28)
    rp = per_query_search(idx, q, 10, recall_target=0.9)
    assert rp.recall_estimate is not None
    for i in range(6):
        rb = batch_search(idx, q[i], 10, recall_target=0.9)
        assert set(rp.ids[i].tolist()) == set(rb.ids[0].tolist()), i
        assert rp.nprobe[i] == rb.nprobe[0], i


def test_round_windows_cover_and_budget():
    from repro.core.multiquery import _round_windows
    for n_max in (1, 2, 5, 17, 32):
        for rounds in (None, 1, 2, 3, 10):
            wins = _round_windows(n_max, rounds)
            # contiguous, non-overlapping, full coverage
            assert wins[0][0] == 0 and wins[-1][1] == n_max
            for (a0, a1), (b0, b1) in zip(wins, wins[1:]):
                assert a1 == b0 and a0 < a1
            if rounds is not None:
                assert len(wins) <= rounds or len(wins) == 1
    assert _round_windows(32, 1) == [(0, 32)]
