"""Vectorized batch planner vs the per-query loop oracle.

The planner rewrite (``multiquery._aps_probe_counts_batched``) must produce
*byte-identical* probe sets and counts to the pre-vectorization per-query
loop (``_aps_probe_counts_loop``) when both see the same calibrated radius:
the batched estimator (``aps.estimate_probs_batch``) mirrors
``estimate_probs_np`` summation-tree-for-summation-tree, so parity is exact,
not approximate.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import QuakeConfig, QuakeIndex
from repro.core import aps as aps_mod
from repro.core import geometry
from repro.core import multiquery as mq
from repro.data import datasets


@pytest.fixture(scope="module")
def built():
    ds = datasets.clustered(4000, 16, n_clusters=16, seed=0)
    idx = QuakeIndex.build(ds.vectors, num_partitions=32, kmeans_iters=4)
    return ds, idx


# ---------------------------------------------------------------------------
# estimator
# ---------------------------------------------------------------------------

def _rand_estimator_inputs(b=16, m=24, seed=0):
    rng = np.random.default_rng(seed)
    di = np.sort(rng.uniform(0.5, 8.0, size=(b, m)), axis=1)
    d0 = di[:, 0].copy()
    cc = rng.uniform(0.1, 4.0, size=(b, m))
    rho_sq = rng.uniform(0.2, 6.0, size=b)
    valid = np.ones((b, m), dtype=bool)
    valid[:, 0] = False
    table = np.asarray(geometry.betainc_table(17), dtype=np.float32)
    return d0, di, cc, rho_sq, table, valid


def test_estimate_probs_batch_bitwise_matches_np():
    d0, di, cc, rho_sq, table, valid = _rand_estimator_inputs()
    p0_b, p_b = aps_mod.estimate_probs_batch(d0, di, cc, rho_sq, table,
                                             valid)
    for i in range(len(d0)):
        p0_i, p_i = aps_mod.estimate_probs_np(
            float(d0[i]), di[i], cc[i], float(rho_sq[i]), table, valid[i])
        # byte-identical, not allclose: same summation trees per row
        assert p0_b[i] == p0_i, i
        np.testing.assert_array_equal(p_b[i], p_i)


def test_estimate_probs_batch_degenerate_rows():
    d0, di, cc, rho_sq, table, valid = _rand_estimator_inputs(b=4)
    rho_sq = np.array([np.inf, 1e-40, 2.0, 0.5])  # inf + ~zero radii
    p0_b, p_b = aps_mod.estimate_probs_batch(d0, di, cc, rho_sq, table,
                                             valid)
    for i in range(4):
        p0_i, p_i = aps_mod.estimate_probs_np(
            float(d0[i]), di[i], cc[i], float(rho_sq[i]), table, valid[i])
        assert p0_b[i] == p0_i
        np.testing.assert_array_equal(p_b[i], p_i)
    assert np.isfinite(p_b).all()


def test_estimate_probs_batch_general_masks():
    """Outside the planner convention (extra invalid columns, or a valid
    column 0) every valid column must still contribute to p0 — agreement
    with the scalar mirror to float rounding."""
    d0, di, cc, rho_sq, table, valid = _rand_estimator_inputs(b=6)
    rng = np.random.default_rng(3)
    valid[:, 0] = rng.random(6) < 0.5          # some rows include col 0
    valid &= rng.random(valid.shape) < 0.8     # random extra invalids
    p0_b, p_b = aps_mod.estimate_probs_batch(d0, di, cc, rho_sq, table,
                                             valid)
    for i in range(6):
        p0_i, p_i = aps_mod.estimate_probs_np(
            float(d0[i]), di[i], cc[i], float(rho_sq[i]), table, valid[i])
        np.testing.assert_allclose(p0_b[i], p0_i, rtol=1e-12)
        np.testing.assert_allclose(p_b[i], p_i, rtol=1e-12)


def test_estimate_probs_batch_jnp_jittable():
    import jax
    d0, di, cc, rho_sq, table, valid = _rand_estimator_inputs()
    f = jax.jit(aps_mod.estimate_probs_batch)
    p0_j, p_j = f(jnp.asarray(d0), jnp.asarray(di), jnp.asarray(cc),
                  jnp.asarray(rho_sq), jnp.asarray(table),
                  jnp.asarray(valid))
    p0_n, p_n = aps_mod.estimate_probs_batch(d0, di, cc, rho_sq, table,
                                             valid)
    np.testing.assert_allclose(np.asarray(p0_j), p0_n, rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(p_j), p_n, rtol=2e-3, atol=2e-5)


# ---------------------------------------------------------------------------
# planner parity (the acceptance bar: byte-identical probe sets)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("metric", ["l2", "ip"])
@pytest.mark.parametrize("b", [1, 7, 32])
def test_vectorized_planner_parity_with_loop(built, metric, b):
    ds, _ = built
    idx = QuakeIndex.build(ds.vectors, num_partitions=32, kmeans_iters=3,
                           config=QuakeConfig(metric=metric))
    q = datasets.queries_near(ds, b, seed=11).astype(np.float32)
    kth = mq._calibrate_kth_loop(idx, q, 10, 0.9)
    geo = mq._centroid_geo_batch(idx, q)   # shared centroid pass: parity
    # tests the vectorization transform itself (per-query GEMV and batched
    # GEMM round differently, so each impl gets the same matrix)
    s_l, v_l, c_l = mq._aps_probe_counts_loop(idx, q, 10, 0.9, kth_med=kth,
                                              geo=geo)
    s_b, v_b, c_b, _ = mq._aps_probe_counts_batched(idx, q, 10, 0.9,
                                                 kth_med=kth, geo=geo)
    np.testing.assert_array_equal(c_l, c_b)
    np.testing.assert_array_equal(v_l, v_b)
    np.testing.assert_array_equal(s_l, s_b)


def test_vectorized_planner_parity_infinite_radius(built):
    """No calibrated radius -> both planners fall back to the conservative
    full candidate scan, identically."""
    ds, idx = built
    q = datasets.queries_near(ds, 5, seed=12).astype(np.float32)
    geo = mq._centroid_geo_batch(idx, q)
    s_l, v_l, c_l = mq._aps_probe_counts_loop(idx, q, 10, 0.9,
                                              kth_med=np.inf, geo=geo)
    s_b, v_b, c_b, _ = mq._aps_probe_counts_batched(idx, q, 10, 0.9,
                                                 kth_med=np.inf, geo=geo)
    np.testing.assert_array_equal(c_l, c_b)
    np.testing.assert_array_equal(s_l, s_b)
    assert (c_l == mq._aps_candidate_budget(idx)).all()


def test_device_centroid_pass_close_to_host(built):
    """The jitted scan_topk centroid pass plans (near-)identical probe sets
    — it may differ from the host GEMM only through matmul rounding."""
    ds, idx = built
    q = datasets.queries_near(ds, 16, seed=13).astype(np.float32)
    kth = mq._calibrate_kth_loop(idx, q, 10, 0.9)
    s_h, v_h, c_h, _ = mq._aps_probe_counts_batched(idx, q, 10, 0.9,
                                                 kth_med=kth)
    # and the loop oracle on its own per-query GEMV pass stays equivalent
    s_g, v_g, c_g = mq._aps_probe_counts_loop(idx, q, 10, 0.9, kth_med=kth)
    assert np.mean(c_g == c_h) >= 0.9
    s_d, v_d, c_d, _ = mq._aps_probe_counts_batched(idx, q, 10, 0.9,
                                                 kth_med=kth,
                                                 pass_impl="scan_topk")
    jac = []
    for i in range(16):
        a = set(s_h[i][v_h[i]].tolist())
        d = set(s_d[i][v_d[i]].tolist())
        jac.append(len(a & d) / max(len(a | d), 1))
    assert np.mean(jac) >= 0.9, jac
    assert np.mean(np.abs(c_h - c_d)) <= 1.0


def test_end_to_end_default_planner_matches_loop_planner(built):
    """plan_batch(planner=...) end-to-end: both planners calibrate
    differently (batched sample search vs per-sample APS), so probe sets
    may differ — but executor recall must be equivalent."""
    ds, idx = built
    q = datasets.queries_near(ds, 24, seed=14)
    gt = ds.ground_truth(q, 10)
    recs = {}
    for planner in ("vectorized", "loop"):
        ex = mq.BatchedSearchExecutor(idx, planner=planner)
        r = ex.search(q, 10, recall_target=0.9)
        recs[planner] = np.mean(
            [len(set(r.ids[i].tolist()) & set(gt[i].tolist())) / 10
             for i in range(24)])
    assert recs["vectorized"] >= 0.8
    assert abs(recs["vectorized"] - recs["loop"]) <= 0.1, recs


# ---------------------------------------------------------------------------
# union cap (read-skew truncation)
# ---------------------------------------------------------------------------

def _skewed_batch(ds, b, seed=0):
    """Queries drawn from 2 hot clusters + a uniform tail."""
    rng = np.random.default_rng(seed)
    hot = ds.vectors[ds.cluster_of <= 1]
    base = hot[rng.integers(0, len(hot), b)]
    return (base + rng.normal(size=base.shape).astype(np.float32) * 0.05
            ).astype(np.float32)


def test_union_cap_truncates_by_frequency(built):
    ds, idx = built
    q = _skewed_batch(ds, 48, seed=3)
    full = mq.plan_batch(idx, q, 10, nprobe=8)
    cap = max(full.n_real // 2, 1)
    capped = mq.plan_batch(idx, q, 10, nprobe=8, union_cap=cap)
    anchors = set(np.unique(capped.anchor).tolist())
    # cap honored up to the anchor floor (no query loses every probe)
    assert capped.n_real <= max(cap, len(anchors))
    assert capped.n_real < full.n_real
    assert not capped.qmask[:, capped.n_real:].any()
    kept_set = set(capped.sel[:capped.n_real].tolist())
    assert anchors <= kept_set     # every query keeps its nearest
    # frequency ranking among non-anchors: kept >= dropped
    freq = {}
    for u in range(full.n_real):
        freq[int(full.sel[u])] = int(full.qmask[:, u].sum())
    kept = [freq[j] for j in kept_set - anchors]
    dropped = [freq[j] for j in set(freq) - kept_set]
    assert dropped, "cap did not truncate; tighten the test setup"
    assert not kept or min(kept) >= max(dropped), (kept, dropped)
    # effective probes never exceed planned, never hit zero
    assert (capped.nprobe <= capped.planned).all()
    assert (capped.nprobe >= 1).all()
    assert (full.nprobe == full.planned).all()


def test_union_cap_recall_under_skew(built):
    """Under Zipfian read skew (the paper's Fig. 1a regime) a cap at half
    the batch union sheds scan work while recall stays near the uncapped
    level — hot partitions are shared across the batch and the
    frequency-ranked truncation drops only the rarely-probed tail."""
    from repro.data import workload
    ds, idx = built
    wl = workload.readonly_workload(ds, n_ops=1, queries_per_op=64,
                                    skew=1.0, seed=7)
    q = wl.operations[0].queries
    gt = ds.ground_truth(q, 10)
    r_full = mq.batch_search(idx, q, 10, nprobe=8)
    cap = max(r_full.partitions_scanned // 2, 1)
    r_cap = mq.batch_search(idx, q, 10, nprobe=8, union_cap=cap)
    def rec(r):
        return np.mean([len(set(r.ids[i].tolist()) & set(gt[i].tolist()))
                        / 10 for i in range(len(q))])
    plan = mq.plan_batch(idx, np.asarray(q, np.float32), 10, nprobe=8,
                         union_cap=cap)
    assert r_cap.partitions_scanned <= max(cap,
                                           len(np.unique(plan.anchor)))
    assert r_cap.partitions_scanned < r_full.partitions_scanned
    assert r_cap.vectors_scanned < r_full.vectors_scanned
    assert rec(r_full) - rec(r_cap) <= 0.1, (rec(r_full), rec(r_cap))


def test_union_cap_from_config(built):
    ds, _ = built
    idx = QuakeIndex.build(ds.vectors[:2000], num_partitions=16,
                           kmeans_iters=3,
                           config=QuakeConfig(union_cap=4))
    q = datasets.queries_near(ds, 16, seed=5)
    plan = mq.plan_batch(idx, np.asarray(q, np.float32), 10, nprobe=8,
                         union_cap=idx.config.union_cap)
    r = mq.batch_search(idx, q, 10, nprobe=8)
    # cap honored up to the anchor floor (distinct nearest partitions)
    n_anchor = len(np.unique(plan.anchor))
    assert r.partitions_scanned <= max(4, n_anchor)
    assert (r.nprobe >= 1).all()


def test_union_cap_floor_never_empties_a_query(built):
    """A cap below the distinct-anchor count must not return silent
    all-miss rows: every query keeps at least its nearest partition."""
    ds, idx = built
    # spread-out batch: anchors cover many distinct partitions
    q = datasets.queries_near(ds, 32, seed=15)
    r = mq.batch_search(idx, q, 10, nprobe=4, union_cap=4)
    assert (r.nprobe >= 1).all()
    assert (r.ids[:, 0] >= 0).all()          # no empty result rows
    assert np.isfinite(r.dists[:, 0]).all()
    plan = mq.plan_batch(idx, np.asarray(q, np.float32), 10, nprobe=4,
                         union_cap=4)
    assert plan.n_real <= max(4, len(np.unique(plan.anchor)))


# ---------------------------------------------------------------------------
# cached centroid norms (fixed-nprobe path satellite)
# ---------------------------------------------------------------------------

def test_fixed_path_cached_centroid_norms_bitwise(built):
    ds, idx = built
    q = datasets.queries_near(ds, 8, seed=6).astype(np.float32)
    cents = idx.levels[0].centroids
    cached = np.sum(cents * cents, axis=1)
    np.testing.assert_array_equal(
        mq._centroid_dists(idx, q),
        mq._centroid_dists(idx, q, cent_norms=cached))
    np.testing.assert_array_equal(
        mq._centroid_geo_batch(idx, q),
        mq._centroid_geo_batch(idx, q, cent_norms=cached))


def test_executor_norm_cache_invalidated_with_snapshot(built):
    """The cached ||c||^2 follows the journal fingerprint: a refresh (full
    or delta) re-mirrors it, so post-mutation plans match a fresh
    executor's."""
    ds, _ = built
    idx = QuakeIndex.build(ds.vectors[:2000], num_partitions=16,
                           kmeans_iters=3)
    ex = mq.get_executor(idx)
    q = datasets.queries_near(ds, 6, seed=7)
    ex.search(q, 5, nprobe=4)
    assert ex._cent_norms is not None
    idx.insert(q[:2] * 0.999, np.arange(7000, 7002))
    r1 = ex.search(q, 5, nprobe=4)
    fresh = mq.BatchedSearchExecutor(idx)
    r2 = fresh.search(q, 5, nprobe=4)
    np.testing.assert_array_equal(r1.ids, r2.ids)
    np.testing.assert_array_equal(ex._cent_norms, fresh._cent_norms)


# ---------------------------------------------------------------------------
# fused single-jit device planner
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("metric", ["l2", "ip"])
def test_fused_planner_matches_host_selection_oracle(built, metric):
    """The fused single-jit planner must select exactly the probe sets
    the host (numpy) estimator+selection picks when both consume the same
    device centroid pass (``pass_impl="scan_topk"``) at a shared
    calibrated radius — the selection stage itself adds no divergence."""
    ds, _ = built
    idx = QuakeIndex.build(ds.vectors, num_partitions=32, kmeans_iters=3,
                           config=QuakeConfig(metric=metric))
    q = datasets.queries_near(ds, 16, seed=31).astype(np.float32)
    kth = mq._calibrate_kth_loop(idx, q, 10, 0.9)
    s_h, v_h, c_h, r_h = mq._aps_probe_counts_batched(
        idx, q, 10, 0.9, kth_med=kth, pass_impl="scan_topk")
    s_f, v_f, c_f, r_f = mq._aps_probe_counts_fused(
        idx, q, 10, 0.9, kth_med=kth)
    np.testing.assert_array_equal(c_h, c_f)
    for i in range(16):
        assert set(s_h[i][v_h[i]].tolist()) == \
            set(s_f[i][v_f[i]].tolist()), i
    np.testing.assert_allclose(r_f, r_h, rtol=5e-3, atol=1e-3)


def test_fused_planner_infinite_radius_fallback(built):
    """No radius -> conservative full candidate scan, like the host."""
    ds, idx = built
    q = datasets.queries_near(ds, 5, seed=32).astype(np.float32)
    s_f, v_f, c_f, r_f = mq._aps_probe_counts_fused(
        idx, q, 10, 0.9, kth_med=np.inf)
    assert (c_f == mq._aps_candidate_budget(idx)).all()
    assert np.isnan(r_f).all()


def test_fused_planner_no_host_transfer(built):
    """The acceptance bar: between the centroid pass and probe selection
    there is no host round-trip.  With all operands device-resident the
    whole fused planner runs under a transfer guard that forbids any
    implicit host<->device transfer."""
    import jax
    ds, idx = built
    q = datasets.queries_near(ds, 8, seed=33).astype(np.float32)
    m = mq._aps_candidate_budget(idx)
    cfg = idx.config
    q_d = jax.device_put(q)
    cents_d = jax.device_put(idx.levels[0].centroids)
    aug_d = jax.device_put(np.zeros(idx.num_partitions, np.float32))
    table_d = jax.device_put(np.asarray(idx._beta_table))
    mns_d = jax.device_put(np.float32(idx._max_norm_sq))
    kth_d = jax.device_put(np.float32(3.0))
    tgt_d = jax.device_put(np.float32(0.9))
    args = (q_d, cents_d, aug_d, mns_d, kth_d, table_d, tgt_d)
    mq._fused_plan_probes(*args, m=m, metric=cfg.metric)   # compile
    with jax.transfer_guard("disallow"):
        out = mq._fused_plan_probes(*args, m=m, metric=cfg.metric)
        jax.block_until_ready(out)
    seq, counts = np.asarray(out[0]), np.asarray(out[1])
    assert seq.shape == (8, m) and (counts >= 1).all()


def test_fused_plan_rounds_close_to_host(built):
    """plan_rounds(planner="fused") returns the same round plan as the
    host planner up to float rounding (same calibrated radius)."""
    ds, idx = built
    q = datasets.queries_near(ds, 12, seed=34).astype(np.float32)
    kth = mq._calibrate_kth_loop(idx, q, 10, 0.9)
    rp_h = mq._aps_probe_counts_batched(idx, q, 10, 0.9, kth_med=kth,
                                        pass_impl="scan_topk", full=True)
    rp_f = mq._aps_probe_counts_fused(idx, q, 10, 0.9, kth_med=kth,
                                      full=True)
    np.testing.assert_array_equal(rp_h.counts, rp_f.counts)
    np.testing.assert_array_equal(rp_h.seq[:, 0], rp_f.seq[:, 0])
    np.testing.assert_allclose(rp_f.geo, rp_h.geo, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(rp_f.cc, rp_h.cc, rtol=1e-4, atol=1e-4)


def test_fused_executor_end_to_end(built):
    """BatchedSearchExecutor(planner="fused"): the device planner drives
    the round executor end to end at equivalent recall."""
    ds, idx = built
    q = datasets.queries_near(ds, 16, seed=35)
    gt = ds.ground_truth(q, 10)
    def rec(r):
        return np.mean([len(set(r.ids[i].tolist()) & set(gt[i].tolist()))
                        / 10 for i in range(16)])
    ex_f = mq.BatchedSearchExecutor(idx, planner="fused")
    ex_v = mq.BatchedSearchExecutor(idx)
    r_f = ex_f.search(q, 10, recall_target=0.9)
    r_v = ex_v.search(q, 10, recall_target=0.9)
    assert r_f.rounds >= 1 and r_f.recall_estimate is not None
    assert rec(r_f) >= 0.8
    assert abs(rec(r_f) - rec(r_v)) <= 0.1


# ---------------------------------------------------------------------------
# PlannerCache radius TTL through QuakeConfig
# ---------------------------------------------------------------------------

def test_planner_radius_ttl_from_config(built):
    ds, _ = built
    idx = QuakeIndex.build(ds.vectors[:2000], num_partitions=16,
                           kmeans_iters=3,
                           config=QuakeConfig(planner_radius_ttl=1))
    cache = mq.PlannerCache(idx).ensure_fresh()
    assert cache.radius_ttl == 1
    cache.put_radius(10, 0.9, 2.5)
    assert cache.get_radius(10, 0.9) == 2.5     # first reuse
    assert cache.get_radius(10, 0.9) is None    # TTL expired
    # executor and sharded-engine caches inherit the config value
    ex = mq.get_executor(idx)
    assert ex.planner_cache.radius_ttl == 1
    # explicit argument still overrides
    assert mq.PlannerCache(idx, radius_ttl=7).radius_ttl == 7
    # default stays the class default when the config is untouched
    idx2 = QuakeIndex.build(ds.vectors[:2000], num_partitions=16,
                            kmeans_iters=3)
    assert mq.PlannerCache(idx2).radius_ttl == mq.PlannerCache.RADIUS_TTL
