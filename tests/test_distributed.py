"""Mesh-sharded engine + distributed model steps.

The main pytest process keeps the single real device; multi-device checks
run in a subprocess with 8 virtual host devices (the dry-run pattern), per
the instruction that tests must not set the device-count flag globally.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.core import EngineConfig, IndexSnapshot, QuakeIndex, \
    ShardedQuakeEngine
from repro.data import datasets


@pytest.fixture(scope="module")
def snap_and_data():
    ds = datasets.clustered(4000, 16, n_clusters=16, seed=0)
    idx = QuakeIndex.build(ds.vectors, num_partitions=32, kmeans_iters=4)
    snap = IndexSnapshot.from_index(idx)
    return snap, ds


def _mesh111():
    return Mesh(np.array(jax.devices()[:1]).reshape(1, 1, 1),
                ("pod", "data", "model"))


def test_engine_bruteforce_exact(snap_and_data):
    snap, ds = snap_and_data
    eng = ShardedQuakeEngine(_mesh111(), EngineConfig(
        k=10, part_axes=("pod", "data")))
    q = jnp.asarray(ds.vectors[:8])
    d, i = eng.search_bruteforce(q, eng.shard_snapshot(snap))
    gt = ds.ground_truth(np.asarray(q), 10)
    rec = np.mean([len(set(np.asarray(i[r]).tolist())
                       & set(gt[r].tolist())) / 10 for r in range(8)])
    assert rec == 1.0


def test_engine_fixed_and_adaptive(snap_and_data):
    snap, ds = snap_and_data
    eng = ShardedQuakeEngine(_mesh111(), EngineConfig(
        k=10, nprobe=8, recall_target=0.9, part_axes=("pod", "data")))
    ss = eng.shard_snapshot(snap)
    q = jnp.asarray(datasets.queries_near(ds, 8, seed=2))
    gt = ds.ground_truth(np.asarray(q), 10)
    d_f, i_f = eng.search_fixed(q, ss)
    d_a, i_a, r_est, nprobe = eng.search_adaptive(q, ss)
    rec_f = np.mean([len(set(np.asarray(i_f[r]).tolist())
                         & set(gt[r].tolist())) / 10 for r in range(8)])
    rec_a = np.mean([len(set(np.asarray(i_a[r]).tolist())
                         & set(gt[r].tolist())) / 10 for r in range(8)])
    assert rec_f >= 0.85 and rec_a >= 0.85
    assert (np.asarray(nprobe) >= 1).all()
    assert (np.asarray(nprobe) <= snap.num_partitions).all()


def test_engine_matches_dynamic_index(snap_and_data):
    """Compiled engine and dynamic index must agree on fixed-nprobe scans."""
    snap, ds = snap_and_data
    idx = QuakeIndex.build(ds.vectors, num_partitions=32, kmeans_iters=4)
    eng = ShardedQuakeEngine(_mesh111(), EngineConfig(
        k=10, nprobe=6, part_axes=("pod", "data")))
    ss = eng.shard_snapshot(IndexSnapshot.from_index(idx))
    q = datasets.queries_near(ds, 6, seed=3)
    d_e, i_e = eng.search_fixed(jnp.asarray(q), ss)
    for r in range(6):
        host = idx.search(q[r], 10, nprobe=6, record_stats=False)
        overlap = len(set(np.asarray(i_e[r]).tolist())
                      & set(host.ids.tolist())) / 10
        assert overlap >= 0.9, (r, overlap)


def test_engine_search_batch_shares_planner(snap_and_data):
    """The sharded multi-query entry runs through core.multiquery's
    plan_batch: identical results to the host batched executor on a fixed
    plan, and APS-driven per-query probe counts."""
    snap, ds = snap_and_data
    idx = QuakeIndex.build(ds.vectors, num_partitions=32, kmeans_iters=4)
    eng = ShardedQuakeEngine(_mesh111(), EngineConfig(
        k=10, part_axes=("pod", "data")))
    q = datasets.queries_near(ds, 12, seed=4)
    from repro.core.multiquery import batch_search
    r_host = batch_search(idx, q, 10, nprobe=6)
    r_eng = eng.search_batch(idx, q, 10, nprobe=6)
    assert (np.sort(r_host.ids, 1) == np.sort(r_eng.ids, 1)).all()
    assert r_eng.partitions_scanned == r_host.partitions_scanned
    # APS mode: adaptive per-query probe counts through the same planner
    r_aps = eng.search_batch(idx, q, 10, recall_target=0.9)
    assert len(np.unique(r_aps.nprobe)) > 1
    gt = ds.ground_truth(q, 10)
    rec = np.mean([len(set(r_aps.ids[i].tolist()) & set(gt[i].tolist()))
                   / 10 for i in range(12)])
    assert rec >= 0.8, rec


def test_engine_search_batch_union_cap_stats_consistent(snap_and_data):
    """EngineConfig.union_cap caps the plan itself, so the reported stats
    (partitions_scanned, effective nprobe) reflect what was scanned."""
    snap, ds = snap_and_data
    idx = QuakeIndex.build(ds.vectors, num_partitions=32, kmeans_iters=4)
    eng_full = ShardedQuakeEngine(_mesh111(), EngineConfig(
        k=10, part_axes=("pod", "data")))
    r_full = eng_full.search_batch(idx, datasets.queries_near(ds, 16,
                                                              seed=6),
                                   10, nprobe=8)
    cap = max(r_full.partitions_scanned // 2, 1)
    eng = ShardedQuakeEngine(_mesh111(), EngineConfig(
        k=10, part_axes=("pod", "data"), union_cap=cap))
    q = datasets.queries_near(ds, 16, seed=6)
    r = eng.search_batch(idx, q, 10, nprobe=8)
    from repro.core.multiquery import plan_batch
    plan = plan_batch(idx, np.asarray(q, np.float32), 10, nprobe=8,
                      union_cap=cap)
    assert r.partitions_scanned == plan.n_real
    assert r.partitions_scanned <= max(cap, len(np.unique(plan.anchor)))
    assert (r.nprobe == plan.nprobe).all()
    assert (r.nprobe >= 1).all() and (r.ids[:, 0] >= 0).all()


@pytest.mark.parametrize("dtype", ["bf16", "int8"])
def test_engine_search_batch_storage_dtypes(snap_and_data, dtype):
    snap, ds = snap_and_data
    idx = QuakeIndex.build(ds.vectors, num_partitions=32, kmeans_iters=4)
    eng = ShardedQuakeEngine(_mesh111(), EngineConfig(
        k=10, part_axes=("pod", "data"), storage_dtype=dtype))
    q = datasets.queries_near(ds, 8, seed=5)
    gt = ds.ground_truth(q, 10)
    r = eng.search_batch(idx, q, 10, nprobe=8)
    rec = np.mean([len(set(r.ids[i].tolist()) & set(gt[i].tolist())) / 10
                   for i in range(8)])
    assert rec >= 0.8, rec


def test_engine_journal_refresh_patches_sharded_snapshot(snap_and_data):
    """The engine's cached snapshot consumes the mutation journal: an
    insert patches only the dirty rows (no re-shard), and the patched
    snapshot serves the fresh vectors."""
    _, ds = snap_and_data
    idx = QuakeIndex.build(ds.vectors, num_partitions=32, kmeans_iters=4)
    eng = ShardedQuakeEngine(_mesh111(), EngineConfig(
        k=10, nprobe=32, part_axes=("pod", "data")))
    ss = eng.refresh_snapshot(idx)
    assert eng.full_rebuilds == 1
    q = datasets.queries_near(ds, 4, seed=9)
    new_ids = np.arange(60_000, 60_004)
    idx.insert(q * 0.999, new_ids)
    ss2 = eng.refresh_snapshot(idx)
    assert eng.delta_refreshes == 1 and eng.full_rebuilds == 1
    assert ss2.capacity == ss.capacity
    _, i = eng.search_fixed(jnp.asarray(q), ss2)
    assert set(np.asarray(i).ravel().tolist()) & set(new_ids.tolist())
    # structural mutation -> full re-shard
    idx.journal.record(structural=True, reason="test")
    eng.refresh_snapshot(idx)
    assert eng.full_rebuilds == 2


MULTIDEV_SCRIPT = textwrap.dedent("""
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    from repro.core import (EngineConfig, IndexSnapshot, QuakeIndex,
                            ShardedQuakeEngine)
    from repro.data import datasets
    from repro.train import checkpoint as ck, optimizer as opt, steps
    import tempfile

    assert len(jax.devices()) == 8
    ds = datasets.clustered(3000, 16, n_clusters=16, seed=0)
    idx = QuakeIndex.build(ds.vectors, num_partitions=30, kmeans_iters=3)
    mesh = Mesh(np.array(jax.devices()).reshape(2, 2, 2),
                ("pod", "data", "model"))
    eng = ShardedQuakeEngine(mesh, EngineConfig(
        k=10, nprobe=8, part_axes=("pod", "data")))
    snap = IndexSnapshot.from_index(idx, pad_partitions_to=eng.n_part_shards)
    ss = eng.shard_snapshot(snap)
    q = jnp.asarray(datasets.queries_near(ds, 8, seed=1))
    gt = ds.ground_truth(np.asarray(q), 10)
    d_b, i_b = eng.search_bruteforce(q, ss)
    rec = np.mean([len(set(np.asarray(i_b[r]).tolist())
                       & set(gt[r].tolist())) / 10 for r in range(8)])
    assert rec == 1.0, rec
    d_a, i_a, r_est, nprobe = eng.search_adaptive(q, ss)
    rec_a = np.mean([len(set(np.asarray(i_a[r]).tolist())
                         & set(gt[r].tolist())) / 10 for r in range(8)])
    assert rec_a >= 0.8, rec_a

    # planner-driven multi-query entry on a real 2x2x2 mesh: the (B, P)
    # probe matrix shards over batch x partition axes
    r_b = eng.search_batch(idx, np.asarray(q), 10, nprobe=8)
    rec_b = np.mean([len(set(r_b.ids[r].tolist())
                         & set(gt[r].tolist())) / 10 for r in range(8)])
    assert rec_b >= 0.8, rec_b

    # elastic checkpoint: save replicated, restore sharded on a new mesh
    params = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    with tempfile.TemporaryDirectory() as d:
        mgr = ck.CheckpointManager(d, async_write=False)
        mgr.save(1, params, block=True)
        mesh2 = Mesh(np.array(jax.devices()).reshape(4, 2),
                     ("data", "model"))
        sh = {"w": NamedSharding(mesh2, P("data", "model"))}
        restored, man = mgr.restore(params, shardings=sh)
        assert man["step"] == 1
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.asarray(params["w"]))
        assert restored["w"].sharding.spec == P("data", "model")

    # compressed-DP step on a real 2x2x2 mesh
    def loss(p, b):
        return jnp.mean((b["x"] @ p["w"] - b["y"]) ** 2)
    p0 = {"w": jnp.zeros((8, 1))}
    st = opt.init_state(p0)
    res = opt.init_residual(p0)
    step = steps.make_compressed_dp_step(
        loss, opt.AdamWConfig(lr=5e-2, warmup_steps=1, total_steps=100),
        mesh, dp_axes=("pod", "data"))
    rng = np.random.default_rng(0)
    w_true = rng.normal(size=(8, 1))
    losses = []
    for s in range(60):
        x = rng.normal(size=(16, 8)).astype(np.float32)
        y = (x @ w_true).astype(np.float32)
        p0, st, res, m = step(p0, st, res, {"x": jnp.asarray(x),
                                            "y": jnp.asarray(y)})
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.1, losses[::10]
    print("MULTIDEV_OK")
""")


def test_multidevice_subprocess():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", MULTIDEV_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "MULTIDEV_OK" in out.stdout


def test_engine_search_batch_earlyexit_rounds(snap_and_data):
    """APS-driven engine search_batch runs the same multi-round
    early-exit loop as the host executor: footprint never above the
    rounds=1 fixed plan, per-query recall estimates populated, live
    counts non-increasing, and recall equivalent to the host round
    path."""
    snap, ds = snap_and_data
    idx = QuakeIndex.build(ds.vectors, num_partitions=32, kmeans_iters=4)
    eng = ShardedQuakeEngine(_mesh111(), EngineConfig(
        k=10, part_axes=("pod", "data")))
    q = datasets.queries_near(ds, 16, seed=8)
    r_fix = eng.search_batch(idx, q, 10, recall_target=0.9, rounds=1)
    assert r_fix.rounds == 1 and r_fix.recall_estimate is not None
    r_ee = eng.search_batch(idx, q, 10, recall_target=0.9)
    assert r_ee.vectors_scanned <= r_fix.vectors_scanned
    assert r_ee.comparisons <= r_fix.comparisons
    assert r_ee.recall_estimate is not None
    tr = r_ee.round_trace
    assert tr is not None and len(tr["round_live"]) == r_ee.rounds
    assert all(a >= b for a, b in zip(tr["round_live"],
                                      tr["round_live"][1:]))
    gt = ds.ground_truth(q, 10)
    def rec(r):
        return np.mean([len(set(r.ids[i].tolist()) & set(gt[i].tolist()))
                        / 10 for i in range(16)])
    assert rec(r_ee) >= 0.8
    from repro.core.multiquery import batch_search
    r_host = batch_search(idx, q, 10, recall_target=0.9)
    assert abs(rec(r_ee) - rec(r_host)) <= 0.1
    # a union cap (plan-level truncation) falls back to the one-shot path
    r_cap = eng.search_batch(idx, q, 10, recall_target=0.9, union_cap=8)
    assert r_cap.rounds == 1
