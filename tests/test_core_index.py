"""Dynamic index: build/search/update correctness and APS behaviour."""
import numpy as np
import pytest

from repro.core import QuakeConfig, QuakeIndex
from repro.data import datasets


@pytest.fixture(scope="module")
def clustered():
    return datasets.clustered(6000, 24, n_clusters=32, seed=0)


def _recall_of(index, ds, k=10, n=40, target=0.9, seed=1, **kw):
    rng = np.random.default_rng(seed)
    gt_all, got = [], []
    q = datasets.queries_near(ds, n, seed=seed)
    gt = ds.ground_truth(q, k)
    rs = []
    for i in range(n):
        r = index.search(q[i], k, recall_target=target, **kw)
        rs.append(len(set(r.ids.tolist()) & set(gt[i].tolist())) / k)
    return float(np.mean(rs))


@pytest.mark.parametrize("metric", ["l2", "ip"])
def test_build_search_recall(clustered, metric):
    ds = datasets.clustered(6000, 24, n_clusters=32, seed=0, metric=metric)
    idx = QuakeIndex.build(ds.vectors, config=QuakeConfig(metric=metric),
                           kmeans_iters=4)
    idx.check_invariants()
    assert _recall_of(idx, ds) >= 0.85


def test_multilevel_matches_flat(clustered):
    ds = clustered
    flat = QuakeIndex.build(ds.vectors, num_partitions=96, kmeans_iters=4)
    two = QuakeIndex.build(ds.vectors, level_sizes=(96, 12), kmeans_iters=4)
    two.check_invariants()
    r_flat = _recall_of(flat, ds)
    r_two = _recall_of(two, ds)
    assert r_two >= r_flat - 0.1   # hierarchy must not wreck recall


def test_insert_then_search(clustered):
    ds = clustered
    idx = QuakeIndex.build(ds.vectors[:4000], ids=np.arange(4000),
                           kmeans_iters=4)
    idx.insert(ds.vectors[4000:], np.arange(4000, 6000))
    idx.check_invariants()
    assert idx.num_vectors == 6000
    # new vectors must be findable
    q = ds.vectors[5000]
    r = idx.search(q, 5, recall_target=0.95)
    assert 5000 in r.ids.tolist()


def test_delete_removes(clustered):
    ds = clustered
    idx = QuakeIndex.build(ds.vectors, ids=np.arange(ds.n), kmeans_iters=4)
    victims = np.arange(0, 3000)
    removed = idx.delete(victims)
    idx.check_invariants()
    assert removed == 3000
    assert idx.num_vectors == ds.n - 3000
    r = idx.search(ds.vectors[100], 10)
    assert not np.isin(r.ids, victims).any()


def test_aps_adapts_nprobe_to_target(clustered):
    """Higher recall targets must scan at least as many partitions."""
    ds = clustered
    idx = QuakeIndex.build(ds.vectors, kmeans_iters=4)
    q = datasets.queries_near(ds, 20, seed=3)
    n_low = [idx.search(qi, 10, recall_target=0.5).nprobe[0] for qi in q]
    n_high = [idx.search(qi, 10, recall_target=0.99).nprobe[0] for qi in q]
    assert np.mean(n_high) >= np.mean(n_low)


def test_fixed_nprobe_baseline(clustered):
    ds = clustered
    idx = QuakeIndex.build(ds.vectors, kmeans_iters=4)
    r1 = idx.search(ds.vectors[0], 10, nprobe=1)
    r8 = idx.search(ds.vectors[0], 10, nprobe=8)
    assert r8.nprobe[0] == 8 and r1.nprobe[0] == 1
    assert r8.dists[-1] <= r1.dists[-1] + 1e-6  # more probes only improve


def test_recall_estimate_tracks_true_recall(clustered):
    """APS estimate should be well-calibrated on average (paper Table 5:
    estimate-driven termination lands near the target)."""
    ds = clustered
    idx = QuakeIndex.build(ds.vectors, kmeans_iters=4)
    q = datasets.queries_near(ds, 50, seed=5)
    gt = ds.ground_truth(q, 10)
    true_r, est_r = [], []
    for i in range(len(q)):
        r = idx.search(q[i], 10, recall_target=0.9)
        true_r.append(len(set(r.ids.tolist()) & set(gt[i].tolist())) / 10)
        est_r.append(r.recall_estimate)
    assert np.mean(true_r) >= 0.85
    assert abs(np.mean(est_r) - np.mean(true_r)) < 0.12
