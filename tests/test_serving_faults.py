"""Failure semantics of the serving runtime (docs/serving.md):

  * per-query latency budgets — budget-expired queries retire at the
    end of the current round with their running top-k, status PARTIAL,
    carrying a *finite* recall estimate (the round loop's refined APS
    number over what was actually scanned);
  * admission control — bounded queue with block / shed-oldest /
    shed-newest policies; shed queries complete immediately with SHED;
  * degradation governor — sustained queue pressure steps the effective
    recall target down and caps probe budgets; calm restores them;
  * fault injection + self-healing (src/repro/faults.py) — scan faults
    retry with backoff then fail only the affected batch (FAILED);
    maintenance crashes roll back (index version unchanged, retried on
    the next trigger); cache failures degrade to cache-off; a dead
    ticker restarts on the next admission; a wedged ticker is counted.

Every admitted query reaches exactly one terminal status:
``sum(status_counts.values()) == queries_submitted`` is asserted
throughout.
"""
import threading
import time

import numpy as np
import pytest

from repro.core import (QuakeConfig, QuakeIndex, ServingConfig,
                        ServingRuntime)
from repro.core import multiquery as mq
from repro.core.maintenance import (Maintainer, checkpoint_index,
                                    restore_index)
from repro.core.serving import (STATUS_FAILED, STATUS_OK, STATUS_PARTIAL,
                                STATUS_SHED, TERMINAL_STATUSES)
from repro.data import datasets
from repro.faults import FaultInjector, InjectedFault, index_state_fingerprint


@pytest.fixture(scope="module")
def ds():
    return datasets.clustered(3000, 16, n_clusters=12, seed=0)


def build(ds):
    return QuakeIndex.build(ds.vectors, num_partitions=16, kmeans_iters=3,
                            config=QuakeConfig(recall_target=0.9))


def _terminal_invariant(rt):
    st = rt.stats()
    assert sum(st["status_counts"].values()) == st["queries_submitted"], st
    return st


# ---------------------------------------------------------------------------
# config validation (satellite: reject zero/negative deadlines, _ms wins)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kw", [
    {"flush_deadline": 0.0}, {"flush_deadline": -1.0},
    {"flush_deadline_ms": 0.0}, {"flush_deadline_ms": -5.0},
    {"deadline_s": 0.0}, {"deadline_s": -0.1},
    {"queue_cap": 0}, {"queue_policy": "drop-all"},
    {"govern_low": 0.8, "govern_high": 0.2}, {"govern_low": 0.0},
    {"govern_patience": 0}, {"govern_max_steps": 0},
    {"govern_probe_frac": 0.0}, {"govern_probe_frac": 1.5},
    {"scan_retries": -1}, {"scan_backoff_s": -0.1},
])
def test_config_validation_rejects(kw):
    with pytest.raises(ValueError):
        ServingConfig(**kw)


def test_config_ms_wins_over_seconds():
    cfg = ServingConfig(flush_deadline=9.0, flush_deadline_ms=5.0)
    assert cfg.flush_deadline == pytest.approx(0.005)
    # seconds-only form still folds through untouched
    assert ServingConfig(flush_deadline=0.25).flush_deadline == 0.25


def test_submit_rejects_nonpositive_deadline(ds):
    with ServingRuntime(build(ds), ServingConfig(k=5)) as rt:
        with pytest.raises(ValueError):
            rt.submit_query(np.zeros(16, np.float32), deadline_s=0.0)


# ---------------------------------------------------------------------------
# fault injector determinism
# ---------------------------------------------------------------------------

def test_injector_deterministic_per_site():
    a = FaultInjector(seed=7, rates={"scan": 0.3, "cache": 0.3})
    b = FaultInjector(seed=7, rates={"scan": 0.3, "cache": 0.3})
    # interleave differently: site streams must not influence each other
    seq_a = [a.fire("scan") for _ in range(50)]
    [a.fire("cache") for _ in range(17)]
    seq_a += [a.fire("scan") for _ in range(50)]
    [b.fire("cache") for _ in range(3)]
    seq_b = [b.fire("scan") for _ in range(100)]
    assert seq_a == seq_b
    assert a.counters()["draws"]["scan"] == 100
    with pytest.raises(ValueError):
        FaultInjector(rates={"not-a-site": 1.0})
    with pytest.raises(InjectedFault):
        FaultInjector(rates={"ticker": 1.0}).check("ticker")


# ---------------------------------------------------------------------------
# per-query latency budgets -> PARTIAL
# ---------------------------------------------------------------------------

def test_round_loop_deadline_budget(ds):
    """The Algorithm-2 primitive: the loop stops at the end of the
    current round once the budget is spent — at least one round always
    runs — and reports it in the trace."""
    import jax.numpy as jnp
    idx = build(ds)
    ex = mq.BatchedSearchExecutor(idx, storage_dtype="f32")
    q = datasets.queries_near(ds, 6, seed=3).astype(np.float32)
    snap = ex.snapshot()
    rplan = mq.plan_rounds(idx, q, 10, 0.99, planner=ex.planner,
                           cache=ex.planner_cache,
                           cent_norms=ex._cent_norms)
    q_dev = jnp.asarray(q)
    seq_dev = (rplan.seq_dev if rplan.seq_dev is not None
               else jnp.asarray(rplan.seq.astype(np.int32)))

    def scan_round(take, kept):
        return ex.scan_probe_round(q_dev, seq_dev, take, kept, 10,
                                   snap=snap, seq_host=rplan.seq)

    def run(deadline_s, clock):
        return mq.run_round_loop(
            rplan, 10, 0.99, idx._beta_table, mq._batch_rho_fn(idx, q),
            scan_round, rounds=4, k_keep=10,
            deadline_s=deadline_s, clock=clock)

    t = {"now": 0.0}

    def fast_clock():              # every read advances a full second
        t["now"] += 1.0
        return t["now"]

    *_, n_full, trace_full, _ = run(None, None)
    *_, n_cut, trace_cut, _ = run(0.5, fast_clock)
    assert not trace_full["budget_expired"]
    assert trace_cut["budget_expired"]
    assert n_cut == 1              # budget spent after the first round
    assert n_cut <= n_full


def test_partial_results_on_expired_budget(ds):
    """A fake clock that leaps past every per-query deadline: queries
    retire PARTIAL at the end of the first round, with running top-k
    and a finite recall estimate."""
    t = {"now": 0.0}

    def clock():
        t["now"] += 1.0
        return t["now"]

    idx = build(ds)
    cfg = ServingConfig(k=10, flush_size=4, scan_backend="host",
                        recall_target=0.99, rounds=4, ticker=False,
                        interleave_rounds=1, maint_min_ops=10 ** 9)
    qs = datasets.queries_near(ds, 4, seed=5).astype(np.float32)
    with ServingRuntime(idx, cfg, clock=clock) as rt:
        qids = [rt.submit_query(q, deadline_s=0.5) for q in qs]
        rt.drain()
        st = _terminal_invariant(rt)
        assert st["partials"] >= 1
        saw_partial = False
        for qid in qids:
            res = rt.result(qid)
            assert res is not None and res.status in TERMINAL_STATUSES
            if res.status == STATUS_PARTIAL:
                saw_partial = True
                assert np.isfinite(res.recall_estimate)
                assert 0.0 <= res.recall_estimate <= 1.0
                assert res.rounds >= 1           # ran at least one round
        assert saw_partial

    # same queries, no budget: everything completes OK
    t["now"] = 0.0
    with ServingRuntime(build(ds), cfg, clock=clock) as rt2:
        for q in qs:
            rt2.submit_query(q)
        rt2.drain()
        st2 = _terminal_invariant(rt2)
        assert st2["partials"] == 0
        assert st2["status_counts"][STATUS_OK] == len(qs)


# ---------------------------------------------------------------------------
# admission control / load shedding
# ---------------------------------------------------------------------------

def test_shed_newest_policy(ds):
    cfg = ServingConfig(k=5, flush_size=10 ** 6, queue_cap=2,
                        queue_policy="shed-newest", ticker=False)
    qs = datasets.queries_near(ds, 5, seed=1).astype(np.float32)
    with ServingRuntime(build(ds), cfg) as rt:
        qids = [rt.submit_query(q) for q in qs]
        # first two queued, the rest shed immediately
        for qid in qids[2:]:
            res = rt.result(qid)
            assert res is not None and res.status == STATUS_SHED
            assert res.recall_estimate == 0.0 and np.all(res.ids == -1)
        rt.drain()
        st = _terminal_invariant(rt)
        assert st["queries_shed"] == 3
        assert st["status_counts"][STATUS_SHED] == 3
        assert st["status_counts"][STATUS_OK] == 2


def test_shed_oldest_policy(ds):
    cfg = ServingConfig(k=5, flush_size=10 ** 6, queue_cap=2,
                        queue_policy="shed-oldest", ticker=False)
    qs = datasets.queries_near(ds, 5, seed=2).astype(np.float32)
    with ServingRuntime(build(ds), cfg) as rt:
        qids = [rt.submit_query(q) for q in qs]
        # the three oldest were evicted; the two newest survive
        for qid in qids[:3]:
            assert rt.result(qid).status == STATUS_SHED
        rt.drain()
        st = _terminal_invariant(rt)
        assert st["queries_shed"] == 3
        for qid in qids[3:]:
            assert rt.result(qid).status == STATUS_OK


def test_block_policy_applies_backpressure(ds):
    """block: the submitter pays for a flush and retries — nothing is
    shed, every query completes, and the queue never exceeds the cap."""
    cfg = ServingConfig(k=5, flush_size=10 ** 6, queue_cap=2,
                        queue_policy="block", ticker=False)
    qs = datasets.queries_near(ds, 7, seed=3).astype(np.float32)
    with ServingRuntime(build(ds), cfg) as rt:
        qids = [rt.submit_query(q) for q in qs]
        rt.drain()
        st = _terminal_invariant(rt)
        assert st["queries_shed"] == 0
        assert st["status_counts"][STATUS_OK] == len(qs)
        assert all(rt.result(q).status == STATUS_OK for q in qids)


def test_governor_degrades_and_restores(ds):
    cfg = ServingConfig(k=5, flush_size=4, queue_cap=4, govern=True,
                        govern_high=0.75, govern_low=0.25,
                        govern_patience=1, govern_step=0.05,
                        govern_max_steps=2, govern_probe_frac=0.5,
                        recall_target=0.9, ticker=False,
                        maint_min_ops=10 ** 9)
    qs = datasets.queries_near(ds, 32, seed=4).astype(np.float32)
    with ServingRuntime(build(ds), cfg) as rt:
        base = rt.target
        # full-cap flushes: sustained pressure -> degrade
        for q in qs[:8]:
            rt.submit_query(q)        # flush_size=4 == queue_cap fill
        st = rt.stats()
        assert st["governor"]["degrades"] >= 1
        assert st["effective_target"] < base
        assert st["probe_frac"] is not None and st["probe_frac"] < 1.0
        steps_after_pressure = st["governor"]["steps"]
        # empty flushes: sustained calm -> restore to baseline
        for _ in range(2 * steps_after_pressure):
            rt.flush()
        rt.drain()
        st = _terminal_invariant(rt)
        assert st["governor"]["restores"] >= steps_after_pressure
        assert st["governor"]["steps"] == 0
        assert st["effective_target"] == pytest.approx(base)
        assert st["probe_frac"] is None


# ---------------------------------------------------------------------------
# scan faults: retry with backoff, then fail only the affected batch
# ---------------------------------------------------------------------------

def test_scan_fault_recovers_with_retry(ds):
    """Rate-1.0 scan faults with enough retries: every round scan fails
    then succeeds on retry — results identical to the fault-free run."""
    sleeps = []
    fi = FaultInjector(seed=3, rates={"scan": 0.5},
                       sleep_fn=sleeps.append)
    cfg = ServingConfig(k=10, flush_size=4, scan_backend="host",
                        ticker=False, scan_retries=8,
                        scan_backoff_s=0.001, scan_backoff_max_s=0.004,
                        maint_min_ops=10 ** 9)
    qs = datasets.queries_near(ds, 8, seed=6).astype(np.float32)
    with ServingRuntime(build(ds), cfg, faults=fi) as rt:
        qids = [rt.submit_query(q) for q in qs]
        rt.drain()
        st = _terminal_invariant(rt)
        assert st["status_counts"][STATUS_OK] == len(qs)
        assert st["scan_faults"] >= 1
        assert st["scan_retries_used"] >= 1
        assert st["failed_batches"] == 0
    with ServingRuntime(build(ds), cfg) as clean:
        ref = [clean.submit_query(q) for q in qs]
        clean.drain()
        for qid, rid in zip(qids, ref):
            np.testing.assert_array_equal(rt.result(qid).ids,
                                          clean.result(rid).ids)
    # backoff doubled then capped
    if len(sleeps) >= 3:
        assert sleeps[0] <= sleeps[1] <= max(sleeps) <= 0.004 + 1e-12


def test_scan_fault_exhausts_retries_fails_batch_only(ds):
    fi = FaultInjector(seed=1, rates={"scan": 1.0}, sleep_fn=lambda s: None)
    cfg = ServingConfig(k=10, flush_size=4, scan_backend="host",
                        ticker=False, scan_retries=2,
                        maint_min_ops=10 ** 9)
    qs = datasets.queries_near(ds, 8, seed=7).astype(np.float32)
    with ServingRuntime(build(ds), cfg, faults=fi) as rt:
        first = [rt.submit_query(q) for q in qs[:4]]
        rt.drain()
        for qid in first:
            res = rt.result(qid)
            assert res.status == STATUS_FAILED
            assert "InjectedFault" in res.error
            assert np.all(res.ids == -1) and np.all(np.isinf(res.dists))
        # the runtime survives: stop injecting, later batches succeed
        fi.rates["scan"] = 0.0
        second = [rt.submit_query(q) for q in qs[4:]]
        rt.drain()
        assert all(rt.result(q).status == STATUS_OK for q in second)
        st = _terminal_invariant(rt)
        assert st["failed_batches"] == 1
        assert st["status_counts"][STATUS_FAILED] == 4
        assert st["status_counts"][STATUS_OK] == 4


def test_slow_round_stall_is_absorbed(ds):
    """A straggler round (stall injection) delays but never corrupts:
    queries complete OK, and the injected sleeps actually happened."""
    sleeps = []
    fi = FaultInjector(seed=4, rates={"slow_round": 1.0}, delay_s=0.001,
                       sleep_fn=sleeps.append)
    cfg = ServingConfig(k=10, flush_size=4, scan_backend="host",
                        ticker=False, maint_min_ops=10 ** 9)
    qs = datasets.queries_near(ds, 4, seed=10).astype(np.float32)
    with ServingRuntime(build(ds), cfg, faults=fi) as rt:
        qids = [rt.submit_query(q) for q in qs]
        rt.drain()
        st = _terminal_invariant(rt)
        assert st["status_counts"][STATUS_OK] == len(qs)
        assert all(rt.result(q).status == STATUS_OK for q in qids)
    assert len(sleeps) >= 1 and all(s == 0.001 for s in sleeps)


# ---------------------------------------------------------------------------
# cache faults degrade to cache-off
# ---------------------------------------------------------------------------

def test_cache_fault_degrades_to_cache_off(ds):
    fi = FaultInjector(seed=2, rates={"cache": 1.0})
    cfg = ServingConfig(k=10, flush_size=2, scan_backend="host",
                        cache_entries=64, ticker=False,
                        maint_min_ops=10 ** 9)
    qs = datasets.queries_near(ds, 6, seed=8).astype(np.float32)
    with ServingRuntime(build(ds), cfg, faults=fi) as rt:
        qids = [rt.submit_query(q) for q in qs]
        rt.drain()
        st = _terminal_invariant(rt)
        # every query still answered, none errored
        assert all(rt.result(q).status == STATUS_OK for q in qids)
        assert st["cache_errors"] >= 1
        assert st["cache_disabled"] is True
        # degraded mode: no further probes, identical repeat is re-run
        rpt = rt.submit_query(qs[0])
        rt.drain()
        assert rt.result(rpt).from_cache is False
        _terminal_invariant(rt)


# ---------------------------------------------------------------------------
# maintenance crash mid-recluster: rollback, version unchanged, retried
# ---------------------------------------------------------------------------

def _skewed_index(seed=1, hot=2, cold=10, hot_size=2500, cold_size=250,
                  dim=16):
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(hot + cold, dim)) * 6
    parts = [centers[i] + rng.normal(size=(hot_size, dim))
             for i in range(hot)]
    parts += [centers[hot + i] + rng.normal(size=(cold_size, dim))
              for i in range(cold)]
    x = np.concatenate(parts).astype(np.float32)
    idx = QuakeIndex.build(x, num_partitions=hot + cold, kmeans_iters=4)
    for q in np.concatenate(
            [centers[i] + rng.normal(size=(60, dim)) for i in range(hot)]
    ).astype(np.float32):
        idx.search(q, 10)
    return idx


def test_checkpoint_restore_roundtrip():
    idx = _skewed_index()
    before_fp = index_state_fingerprint(idx)
    before_v = idx.version
    ckpt = checkpoint_index(idx)
    rep = Maintainer(idx).run()
    assert rep.splits + rep.merges >= 1       # something actually moved
    assert index_state_fingerprint(idx) != before_fp
    restore_index(idx, ckpt)
    assert index_state_fingerprint(idx) == before_fp
    assert idx.version == before_v
    idx.check_invariants()


def test_maintenance_crash_rolls_back_and_retries():
    idx = _skewed_index()
    fi = FaultInjector(seed=0, rates={"maintenance": 1.0})
    cfg = ServingConfig(k=10, flush_size=4, scan_backend="host",
                        ticker=False, maint_min_ops=10 ** 9)
    with ServingRuntime(idx, cfg, faults=fi) as rt:
        before_fp = index_state_fingerprint(idx)
        before_v = idx.version
        rep = rt.maybe_maintain(force=True)
        assert rep is None                    # the pass crashed
        st = rt.stats()
        assert st["maintenance_failures"] == 1
        assert st["maintenance_runs"] == 0    # nothing was committed
        # rollback: index state and version byte-identical
        assert index_state_fingerprint(idx) == before_fp
        assert idx.version == before_v
        idx.check_invariants()
        # self-healing: stop injecting, the retry commits
        fi.rates["maintenance"] = 0.0
        rep = rt.maybe_maintain(force=True)
        assert rep is not None and rep.splits + rep.merges >= 1
        assert rt.stats()["maintenance_runs"] == 1
        idx.check_invariants()


# ---------------------------------------------------------------------------
# ticker: death -> restart on next admission; wedge -> counted in close()
# ---------------------------------------------------------------------------

def test_ticker_death_restarts_on_admission(ds):
    fi = FaultInjector(seed=0, rates={"ticker": 1.0})
    cfg = ServingConfig(k=5, flush_size=10 ** 6, flush_deadline_ms=4.0,
                        ticker=True, maint_min_ops=10 ** 9)
    with ServingRuntime(build(ds), cfg, faults=fi) as rt:
        deadline = time.perf_counter() + 5.0
        while (rt.stats()["ticker_errors"] == 0
               and time.perf_counter() < deadline):
            time.sleep(0.005)
        st = rt.stats()
        assert st["ticker_errors"] >= 1       # the injected tick killed it
        # next admission revives the ticker (which dies again at rate
        # 1.0 — restarts keep pace with deaths, flushes keep happening)
        rt.submit_query(datasets.queries_near(ds, 1, seed=9)
                        .astype(np.float32)[0])
        assert rt.stats()["ticker_restarts"] >= 1
        rt.drain()
        _terminal_invariant(rt)


def test_close_detects_wedged_ticker(ds):
    class WedgedThread:
        name = "serving-ticker"

        def join(self, timeout=None):
            pass                              # never actually joins

        def is_alive(self):
            return True

    cfg = ServingConfig(k=5, flush_deadline_ms=50.0, ticker=True)
    rt = ServingRuntime(build(ds), cfg)
    real = rt._ticker_thread
    rt._ticker_thread = WedgedThread()
    rt.close()
    st = rt.stats()
    assert st["ticker_wedged"] is True
    assert rt._ticker_thread is not None      # kept observable
    # the real thread exits via _closed; tidy up
    if real is not None:
        real.join(timeout=5.0)
        assert not real.is_alive()


def test_close_clean_ticker_not_wedged(ds):
    cfg = ServingConfig(k=5, flush_deadline_ms=50.0, ticker=True)
    rt = ServingRuntime(build(ds), cfg)
    rt.close()
    assert rt.stats()["ticker_wedged"] is False
    assert rt._ticker_thread is None
