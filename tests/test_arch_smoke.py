"""Per-arch smoke tests (deliverable f): reduced configs of the same family
run one real forward/train step on CPU — shapes + no NaNs.  The FULL configs
are exercised only via the dry-run (ShapeDtypeStruct, no allocation).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY, get_arch
from repro.data import graphs, pipelines
from repro.models import gnn, recsys, transformer as tr

LM_ARCHS = [n for n, s in REGISTRY.items() if s.family == "lm"]
RECSYS_ARCHS = [n for n, s in REGISTRY.items() if s.family == "recsys"]


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_train_step(arch):
    cfg = get_arch(arch).smoke_config()
    params = tr.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                              cfg.vocab_size)
    loss, grads = jax.value_and_grad(tr.lm_loss)(params, toks, cfg)
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_prefill_decode(arch):
    cfg = get_arch(arch).smoke_config()
    params = tr.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg.vocab_size)
    logits, (ck, cv) = tr.prefill(params, toks, cfg)
    assert logits.shape == (2, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    # decode one token against the cache
    pad = 24
    l, b, s, k, dh = ck.shape[0], 2, pad, cfg.n_kv_heads, cfg.head_dim
    ckp = jnp.zeros((l, b, s, k, dh), ck.dtype).at[:, :, :16].set(ck)
    cvp = jnp.zeros((l, b, s, k, dh), cv.dtype).at[:, :, :16].set(cv)
    lg, _ = tr.decode_step(params, toks[:, -1], ckp, cvp,
                           jnp.array([16, 16]), cfg)
    assert lg.shape == (2, cfg.vocab_size)
    assert not bool(jnp.isnan(lg).any())


def test_gat_smoke_train_step():
    cfg = get_arch("gat-cora").smoke_config()
    g, feats, labels = graphs.community_graph(
        300, 4.0, d_feat=cfg.d_in, n_classes=cfg.n_classes, seed=0)
    src, dst = graphs.to_edges(g)
    params = gnn.init_params(jax.random.PRNGKey(0), cfg)
    loss, grads = jax.value_and_grad(gnn.loss_fn)(
        params, jnp.asarray(feats), jnp.asarray(src), jnp.asarray(dst),
        jnp.asarray(labels), cfg)
    assert np.isfinite(float(loss))
    logits = gnn.forward(params, jnp.asarray(feats), jnp.asarray(src),
                         jnp.asarray(dst), cfg)
    assert logits.shape == (300, cfg.n_classes)
    assert not bool(jnp.isnan(logits).any())


def test_gat_smoke_minibatch_sampled():
    """minibatch_lg path: the real neighbor sampler feeds the train step."""
    cfg = get_arch("gat-cora").smoke_config()
    g, feats, labels = graphs.community_graph(
        2000, 6.0, d_feat=cfg.d_in, n_classes=cfg.n_classes, seed=1)
    pipe = pipelines.GraphMinibatchPipeline(g, feats, labels, 64,
                                            fanouts=(5, 3))
    b = pipe.batch_at(0)
    params = gnn.init_params(jax.random.PRNGKey(0), cfg)
    logits = gnn.forward(params, jnp.asarray(b["feats"]),
                         jnp.asarray(b["src"]), jnp.asarray(b["dst"]), cfg)
    assert logits.shape[0] == b["feats"].shape[0]
    assert not bool(jnp.isnan(logits).any())


def test_gat_smoke_molecule_pooled():
    cfg = get_arch("gat-cora").smoke_config()
    src, dst, feats, graph_of = graphs.molecule_batch(8, d_feat=cfg.d_in)
    params = gnn.init_params(jax.random.PRNGKey(0), cfg)
    gl = gnn.graph_pool_logits(params, jnp.asarray(feats), jnp.asarray(src),
                               jnp.asarray(dst), jnp.asarray(graph_of), 8,
                               cfg)
    assert gl.shape == (8, cfg.n_classes)
    assert not bool(jnp.isnan(gl).any())


_RECSYS_LOSS = {"din": (recsys.din_init, recsys.din_loss),
                "sasrec": (recsys.sasrec_init, recsys.sasrec_loss),
                "two-tower-retrieval": (recsys.twotower_init,
                                        recsys.twotower_loss),
                "dlrm-rm2": (recsys.dlrm_init, recsys.dlrm_loss)}


@pytest.mark.parametrize("arch", RECSYS_ARCHS)
def test_recsys_smoke_train_step(arch):
    cfg = get_arch(arch).smoke_config()
    init, loss_fn = _RECSYS_LOSS[arch]
    hist = getattr(cfg, "seq_len", getattr(cfg, "hist_len", 50))
    pipe = pipelines.RecsysPipeline(batch=16, vocab=1000, hist_len=hist)
    batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(0).items()}
    params = init(jax.random.PRNGKey(0), cfg)
    loss, grads = jax.value_and_grad(loss_fn)(params, batch, cfg)
    assert np.isfinite(float(loss))


def test_recsys_training_learns():
    """Two-tower on the synthetic stream: loss must fall (end-to-end)."""
    from repro.train import AdamWConfig, init_state, steps
    cfg = get_arch("two-tower-retrieval").smoke_config()
    pipe = pipelines.RecsysPipeline(batch=32, vocab=1000, hist_len=50)
    params = recsys.twotower_init(jax.random.PRNGKey(0), cfg)
    ost = init_state(params)
    step = jax.jit(steps.make_train_step(
        lambda p, b: recsys.twotower_loss(p, b, cfg),
        AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=40)))
    losses = []
    for s in range(25):
        b = {k: jnp.asarray(v) for k, v in pipe.batch_at(s).items()}
        params, ost, m = step(params, ost, b)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]


def test_registry_complete():
    """All 10 assigned archs + quake-ann registered, with full shape sets."""
    assert len(REGISTRY) == 11
    for name, spec in REGISTRY.items():
        expected = {"lm": 4, "gnn": 4, "recsys": 4, "ann": 4}[spec.family]
        assert len(spec.shapes) == expected, name
        assert callable(spec.model_config) and callable(spec.build)
