"""Crash-consistent durability (docs/durability.md):

  * WAL framing — CRC-checked, length-prefixed, strictly-increasing
    LSNs; torn / bit-flipped / regressive tails stop the reader at the
    last valid prefix, never raise;
  * atomic checkpoints — temp + fsync + rename, incremental via the
    journal dirty set with hard-link reuse, damaged generations
    rejected in favour of older ones;
  * recovery — newest valid checkpoint + WAL-suffix replay, verified
    against the stored ``index_state_fingerprint``;
  * the randomized kill-point harness — ≥50 seeded crash samples across
    all four durability fault sites; every recovery must land on a
    *prefix* of the admitted write stream and match a fault-free twin
    replay of that prefix byte-for-byte.
"""
import copy
import json
import os

import numpy as np
import pytest

from repro.core import (QuakeConfig, QuakeIndex, ServingConfig,
                        ServingRuntime)
from repro.core import multiquery as mq
from repro.core.durability import (DurabilityManager, REC_FP, REC_INSERT,
                                   REC_MAINT, RecoveryError, WAL_NAME,
                                   WriteAheadLog, list_checkpoints,
                                   read_wal, recover_index, save_index,
                                   select_checkpoint, validate_checkpoint,
                                   write_checkpoint)
from repro.core.maintenance import checkpoint_index, restore_index
from repro.data import datasets
from repro.faults import FaultInjector, InjectedFault, index_state_fingerprint


@pytest.fixture(scope="module")
def ds():
    return datasets.clustered(3000, 16, n_clusters=12, seed=0)


@pytest.fixture(scope="module")
def base(ds):
    return QuakeIndex.build(ds.vectors[:2000], num_partitions=16,
                            kmeans_iters=3,
                            config=QuakeConfig(recall_target=0.9))


def fresh(base):
    return copy.deepcopy(base)


# ---------------------------------------------------------------------------
# the shared write stream: inserts with fresh ids + deletes of disjoint
# base-id slices, so *every prefix* of the stream is a valid replay
# ---------------------------------------------------------------------------

def make_ops(ds, n_ops=24, seed=123):
    rng = np.random.default_rng(seed)
    ops, nxt, del_base = [], 50_000, 1900
    for i in range(n_ops):
        if i % 6 == 5 and del_base + 5 <= 2000:
            ops.append(("delete", np.arange(del_base, del_base + 5)))
            del_base += 5
        else:
            x = (ds.vectors[rng.integers(2000, size=8)]
                 + rng.normal(0, 0.01, (8, ds.vectors.shape[1]))
                 ).astype(np.float32)
            ops.append(("insert", x, np.arange(nxt, nxt + 8)))
            nxt += 8
    return ops


def apply_op(idx, op):
    if op[0] == "insert":
        idx.insert(op[1], op[2])
    else:
        idx.delete(op[1])


@pytest.fixture(scope="module")
def ops(ds):
    return make_ops(ds)


# ---------------------------------------------------------------------------
# WAL unit tests
# ---------------------------------------------------------------------------

def test_wal_round_trip(tmp_path):
    path = str(tmp_path / WAL_NAME)
    wal = WriteAheadLog(path, fsync="always")
    payloads = [(REC_INSERT, b"ins-payload"), (REC_MAINT, b"splits=1"),
                (REC_FP, b"\x00" * 32)]
    lsns = [wal.append(rt, p) for rt, p in payloads]
    wal.close()
    records, valid, reason = read_wal(path)
    assert reason == "clean" and valid == os.path.getsize(path)
    assert [r.lsn for r in records] == lsns == [1, 2, 3]
    assert [(r.rtype, r.payload) for r in records] == payloads


def test_wal_torn_tail_truncated_on_open(tmp_path):
    path = str(tmp_path / WAL_NAME)
    wal = WriteAheadLog(path, fsync="always")
    wal.append(REC_MAINT, b"a")
    wal.append(REC_MAINT, b"b")
    wal.close()
    good = os.path.getsize(path)
    with open(path, "ab") as f:           # torn frame: header cut short
        f.write(b"\x01\x02\x03")
    records, valid, reason = read_wal(path)
    assert reason == "torn_header" and valid == good and len(records) == 2
    # reopening truncates the damage and continues LSNs past the prefix
    wal2 = WriteAheadLog(path, fsync="always")
    assert wal2.truncated_on_open == 3
    assert os.path.getsize(path) == good
    assert wal2.append(REC_MAINT, b"c") == 3
    wal2.close()
    assert read_wal(path)[2] == "clean"


def test_wal_corrupt_mid_record_recovers_prefix(tmp_path):
    path = str(tmp_path / WAL_NAME)
    wal = WriteAheadLog(path, fsync="always")
    offs = []
    for i in range(3):
        wal.append(REC_MAINT, b"x%d" % i)
        offs.append(os.path.getsize(path))
    wal.close()
    with open(path, "r+b") as f:          # flip a payload byte of record 2
        pos = offs[0] + 4 + 13            # past frame crc + body header
        f.seek(pos)
        b = f.read(1)
        f.seek(pos)
        f.write(bytes([b[0] ^ 0x01]))
    records, valid, reason = read_wal(path)
    # stops at the damaged record: the prefix before it survives
    assert reason == "crc_mismatch"
    assert [r.lsn for r in records] == [1] and valid == offs[0]


def test_wal_lsn_regression_detected(tmp_path):
    path = str(tmp_path / WAL_NAME)
    wal = WriteAheadLog(path, fsync="always")
    wal.append(REC_MAINT, b"a")
    first_end = os.path.getsize(path)
    wal.append(REC_MAINT, b"b")
    wal.close()
    with open(path, "rb") as f:           # replay frame 1 after frame 2
        data = f.read()
    frame1 = data[8:first_end]            # magic is 8 bytes
    with open(path, "ab") as f:
        f.write(frame1)
    records, _valid, reason = read_wal(path)
    assert reason == "lsn_regression" and [r.lsn for r in records] == [1, 2]


def test_wal_fsync_policies(tmp_path):
    always = WriteAheadLog(str(tmp_path / "a.log"), fsync="always")
    batch = WriteAheadLog(str(tmp_path / "b.log"), fsync="batch",
                          batch_ops=4)
    off = WriteAheadLog(str(tmp_path / "c.log"), fsync="off")
    for i in range(8):
        for w in (always, batch, off):
            w.append(REC_MAINT, b"p%d" % i)
    assert always.unsynced_bytes == 0
    assert always.fsyncs >= 8 + 1          # one per append (+ open)
    assert 1 <= batch.fsyncs - 1 <= 2      # every 4th append
    assert off.fsyncs == 1 and off.unsynced_bytes > 0   # open only
    assert off.sync() and off.unsynced_bytes == 0
    for w in (always, batch, off):
        w.close()


def test_wal_poisoned_after_injected_crash(tmp_path):
    fi = FaultInjector(seed=1, rates={"wal_torn_write": 1.0})
    wal = WriteAheadLog(str(tmp_path / WAL_NAME), fsync="always", faults=fi)
    with pytest.raises(InjectedFault):
        wal.append(REC_MAINT, b"doomed")
    # the process is dead: further appends refuse instead of writing
    # unreachable frames past the damaged tail
    with pytest.raises(RuntimeError, match="recover"):
        wal.append(REC_MAINT, b"after")
    # keep the whole flushed-but-unsynced tail: the torn frame survives
    size = wal.simulate_crash(keep_unsynced=10 ** 9)
    records, valid, reason = read_wal(wal.path)
    assert valid < size and records == [] and reason != "clean"


# ---------------------------------------------------------------------------
# checkpoint tests
# ---------------------------------------------------------------------------

def test_checkpoint_atomic_write_and_validate(tmp_path, base):
    idx = fresh(base)
    root = str(tmp_path)
    # tmp debris from a previous aborted attempt is swept, not fatal
    os.makedirs(os.path.join(root, ".tmp-ckpt-00000001/x"))
    manifest, stats = write_checkpoint(idx, root, 1, wal_lsn=0,
                                       write_op_count=0)
    assert not os.path.exists(os.path.join(root, ".tmp-ckpt-00000001"))
    assert stats["partitions_written"] == idx.levels[0].num_partitions
    gendir = os.path.join(root, "ckpt-00000001")
    assert validate_checkpoint(gendir) == manifest
    with pytest.raises(ValueError, match="already exists"):
        write_checkpoint(idx, root, 1, wal_lsn=0, write_op_count=0)


def test_damaged_generation_rejected_falls_back(tmp_path, base):
    idx = fresh(base)
    root = str(tmp_path)
    save_index(idx, root)
    apply_op(idx, ("insert", np.ones((1, idx.dim), np.float32),
                   np.array([77_000])))
    m2 = save_index(idx, root)
    gendir2 = os.path.join(root, "ckpt-00000002")
    # bit-flip one partition blob of the newest generation
    blob = os.path.join(gendir2, m2["partitions"][0])
    with open(blob, "r+b") as f:
        f.seek(10)
        c = f.read(1)
        f.seek(10)
        f.write(bytes([c[0] ^ 0xFF]))
    assert validate_checkpoint(gendir2) is None
    path, manifest = select_checkpoint(root)
    assert manifest["generation"] == 1      # falls back, does not raise
    rec, rep = recover_index(root)
    assert rep.generation == 1


def test_incremental_checkpoint_hardlinks_clean_partitions(tmp_path, base):
    idx = fresh(base)
    dm = DurabilityManager(idx, str(tmp_path), fsync="always",
                           ckpt_every_ops=None, keep_checkpoints=4)
    x = np.asarray(idx.levels[0].vectors[0][:2]) + 0.01
    dm.log_insert(x, np.array([60_000, 60_001]))
    idx.insert(x, np.array([60_000, 60_001]))
    assert dm.checkpoint(force=True)
    st = dm.stats()
    assert st["partitions_linked"] > 0
    assert st["partitions_written"] >= idx.levels[0].num_partitions + 1
    # linked blobs share the inode with the previous generation
    m1 = validate_checkpoint(os.path.join(str(tmp_path), "ckpt-00000001"))
    m2 = dm._prev_manifest
    shared = [n for n in m2["partitions"] if n in m1["files"]]
    assert shared
    a = os.stat(os.path.join(str(tmp_path), "ckpt-00000001", shared[0]))
    b = os.stat(os.path.join(str(tmp_path), "ckpt-00000002", shared[0]))
    assert a.st_ino == b.st_ino
    dm.close()


def test_pruning_keeps_newest_and_linked_blobs_survive(tmp_path, base):
    idx = fresh(base)
    dm = DurabilityManager(idx, str(tmp_path), fsync="always",
                           ckpt_every_ops=None, keep_checkpoints=2)
    for g in range(4):
        x = np.asarray(idx.levels[0].vectors[0][:1]) + 0.01 * (g + 1)
        dm.log_insert(x, np.array([61_000 + g]))
        idx.insert(x, np.array([61_000 + g]))
        dm.checkpoint(force=True)
    gens = [g for g, _p in list_checkpoints(str(tmp_path))]
    assert gens == [4, 5]                   # attach=1, then 2..5, keep 2
    rec, rep = recover_index(str(tmp_path))
    assert rep.generation == 5
    assert index_state_fingerprint(rec) == index_state_fingerprint(idx)
    dm.close()


def test_ckpt_crash_before_rename_loses_nothing_logged(tmp_path, base):
    idx = fresh(base)
    fi = FaultInjector(seed=2, rates={"ckpt_crash_before_rename": 1.0})
    dm = DurabilityManager(idx, str(tmp_path), fsync="always",
                           ckpt_every_ops=None, faults=fi)
    x = np.asarray(idx.levels[0].vectors[0][:2]) + 0.01
    dm.log_insert(x, np.array([62_000, 62_001]))
    idx.insert(x, np.array([62_000, 62_001]))
    with pytest.raises(InjectedFault):
        dm.checkpoint(force=True)
    assert dm.checkpoint_failures == 1
    dm.simulate_crash()
    # the aborted generation never appeared; the WAL suffix replays the
    # logged op on top of the attach baseline
    rec, rep = recover_index(str(tmp_path))
    assert rep.generation == 1 and rep.inserts_replayed == 1
    assert index_state_fingerprint(rec) == index_state_fingerprint(idx)


# ---------------------------------------------------------------------------
# recovery tests
# ---------------------------------------------------------------------------

def test_recover_requires_a_checkpoint(tmp_path):
    with pytest.raises(RecoveryError, match="no valid checkpoint"):
        recover_index(str(tmp_path))


def test_recover_rejects_fingerprint_mismatch(tmp_path, base):
    idx = fresh(base)
    root = str(tmp_path)
    save_index(idx, root)
    mpath = os.path.join(root, "ckpt-00000001", "MANIFEST.json")
    with open(mpath) as f:
        manifest = json.load(f)
    manifest["fingerprint"] = "00" * 32     # blobs still CRC-valid
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    with pytest.raises(RecoveryError, match="fingerprint"):
        recover_index(root)
    rec, _rep = recover_index(root, verify=False)
    assert rec.num_vectors == idx.num_vectors


def test_recover_truncates_torn_tail_persistently(tmp_path, base, ops):
    idx = fresh(base)
    dm = DurabilityManager(idx, str(tmp_path), fsync="always",
                           ckpt_every_ops=None)
    for op in ops[:3]:
        (dm.log_insert(op[1], op[2]) if op[0] == "insert"
         else dm.log_delete(op[1]))
        apply_op(idx, op)
    dm.simulate_crash()
    wal_path = os.path.join(str(tmp_path), WAL_NAME)
    good = os.path.getsize(wal_path)
    with open(wal_path, "ab") as f:
        f.write(b"\xde\xad\xbe\xef")
    rec, rep = recover_index(str(tmp_path))
    assert rep.wal_reason == "torn_header"
    assert rep.wal_truncated_bytes == 4
    assert os.path.getsize(wal_path) == good     # truncation is durable
    assert read_wal(wal_path)[2] == "clean"
    assert index_state_fingerprint(rec) == index_state_fingerprint(idx)


def test_save_load_round_trip(tmp_path, base, ops):
    idx = fresh(base)
    for op in ops[:6]:
        apply_op(idx, op)
    root = str(tmp_path)
    idx.save(root)
    loaded = QuakeIndex.load(root)
    assert index_state_fingerprint(loaded) == index_state_fingerprint(idx)
    loaded.check_invariants()
    # saving again bumps the generation; load picks the newest
    apply_op(idx, ops[6])
    idx.save(root)
    assert index_state_fingerprint(QuakeIndex.load(root)) == \
        index_state_fingerprint(idx)


# ---------------------------------------------------------------------------
# runtime integration
# ---------------------------------------------------------------------------

def _runtime_cfg(**kw):
    cfg = dict(k=5, cache_entries=0, ticker=False, flush_size=4,
               maint_min_ops=10 ** 9, fsync="always", ckpt_every_ops=6)
    cfg.update(kw)
    return ServingConfig(**cfg)


def test_runtime_recover_matches_live(tmp_path, base, ds, ops):
    idx = fresh(base)
    rt = ServingRuntime(idx, _runtime_cfg(wal_dir=str(tmp_path)))
    q = datasets.queries_near(ds, 8, seed=5).astype(np.float32)
    for op in ops[:10]:
        if op[0] == "insert":
            rt.submit_insert(op[1], op[2])
        else:
            rt.submit_delete(op[1])
    rt.submit_batch(q)
    rt.drain()
    st = rt.stats()
    assert st["durability"] is not None
    assert st["durability"]["wal_appends"] >= 10
    assert st["durability"]["checkpoints_written"] >= 2   # attach + cadence
    live_fp = index_state_fingerprint(idx)
    rt.close()

    rt2 = ServingRuntime.recover(str(tmp_path), _runtime_cfg())
    assert rt2.recovery_report is not None
    assert rt2.recovery_report.fingerprint == live_fp.hex()
    assert index_state_fingerprint(rt2.index) == live_fp
    qid = rt2.submit_query(q[0])
    rt2.drain()
    r = rt2.result(qid)
    assert r.status == "OK" and len(r.ids) == 5
    rt2.close()


def test_runtime_maintenance_checkpoint_protocol(tmp_path, base, ds):
    """A committed maintenance pass is made durable by the forced
    checkpoint that follows it (its effects are not WAL-replayable), so
    recovery after maintenance must still match the live index."""
    idx = fresh(base)
    rt = ServingRuntime(idx, _runtime_cfg(
        wal_dir=str(tmp_path), maint_min_ops=2, ckpt_every_ops=None))
    rng = np.random.default_rng(9)
    hot = np.asarray(idx.levels[0].vectors[0][:1])
    for i in range(12):                      # pile into one partition
        x = (hot + rng.normal(0, 0.005, (24, idx.dim))).astype(np.float32)
        rt.submit_insert(x, np.arange(70_000 + i * 24, 70_000 + (i+1) * 24))
        rt.maybe_maintain()
    rt.drain()
    st = rt.stats()
    ver_changed = st["maintenance_runs"] > 0
    records, _v, _r = read_wal(os.path.join(str(tmp_path), WAL_NAME))
    if ver_changed and st["durability"]["checkpoints_written"] > 1:
        assert any(r.rtype == REC_MAINT for r in records)
    live_fp = index_state_fingerprint(idx)
    rt.close()
    rec, rep = recover_index(str(tmp_path))
    assert index_state_fingerprint(rec) == live_fp
    rec.check_invariants()


# ---------------------------------------------------------------------------
# satellite: journal overflow is loud, and consumers fall back
# ---------------------------------------------------------------------------

def test_journal_overflow_flag_and_stats(base, ds):
    idx = fresh(base)
    assert idx.journal.overflowed is False
    rt = ServingRuntime(idx, _runtime_cfg())
    idx.journal.max_entries = 4
    for i in range(8):
        rt.submit_insert(np.ones((1, idx.dim), np.float32) * 0.01 * i,
                         np.array([80_000 + i]))
    st = rt.stats()
    assert st["journal_overflowed"] is True
    assert st["journal_overflow_count"] >= 4
    rt.close()


def test_journal_overflow_forces_executor_full_rebuild(base):
    idx = fresh(base)
    ex = mq.BatchedSearchExecutor(idx, storage_dtype="bf16")
    q = np.asarray(idx.levels[0].vectors[0][:2], dtype=np.float32)
    ex.search(q, 5, nprobe=4)
    assert ex.full_rebuilds == 1
    idx.insert(q + 0.01, np.array([81_000, 81_001]))
    ex.search(q, 5, nprobe=4)
    assert ex.delta_refreshes == 1 and ex.full_rebuilds == 1
    idx.journal.max_entries = 1              # force the loss window
    for i in range(4):
        idx.insert(q + 0.02 * (i + 1), np.array([81_010 + 2 * i,
                                                 81_011 + 2 * i]))
    assert idx.journal.overflowed is True
    ex.search(q, 5, nprobe=4)
    # the delta window is gone: the snapshot must full-rebuild, not
    # silently serve a stale view
    assert ex.full_rebuilds == 2


# ---------------------------------------------------------------------------
# satellite: checkpoint/restore round trip across storage dtypes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", ["f32", "bf16", "int8"])
def test_checkpoint_restore_round_trip_dtypes(base, ds, dtype, tmp_path):
    idx = fresh(base)
    q = datasets.queries_near(ds, 8, seed=7).astype(np.float32)
    ex = mq.BatchedSearchExecutor(idx, storage_dtype=dtype)
    before = ex.search(q, 10, nprobe=6)
    scales_before = (np.asarray(ex._snap.scales).copy()
                     if dtype == "int8" else None)
    ckpt = checkpoint_index(idx)
    ver = idx.version
    idx.insert(q[:2] + 0.01, np.array([90_000, 90_001]))
    idx.delete(np.arange(1800, 1805))
    restore_index(idx, ckpt)
    assert idx.version == ver               # snapshot consumers coherent
    after = mq.BatchedSearchExecutor(idx, storage_dtype=dtype)\
        .search(q, 10, nprobe=6)
    np.testing.assert_array_equal(before.ids, after.ids)
    np.testing.assert_array_equal(before.dists, after.dists)
    # durable round trip preserves it too (int8 scales exactly: the
    # quantization is deterministic in the stored f32 vectors)
    idx.save(str(tmp_path))
    loaded = QuakeIndex.load(str(tmp_path))
    ex3 = mq.BatchedSearchExecutor(loaded, storage_dtype=dtype)
    r3 = ex3.search(q, 10, nprobe=6)
    np.testing.assert_array_equal(before.ids, r3.ids)
    if dtype == "int8":
        np.testing.assert_array_equal(scales_before,
                                      np.asarray(ex3._snap.scales))


# ---------------------------------------------------------------------------
# satellite: fingerprint stability (canonical-ordering contract)
# ---------------------------------------------------------------------------

def test_fingerprint_invariant_under_commuting_interleavings(base):
    a = fresh(base)
    b = fresh(base)
    x1 = np.asarray(a.levels[0].vectors[0][:3]) + 0.01
    x2 = np.asarray(a.levels[0].vectors[1][:3]) + 0.01
    dele = np.arange(1850, 1855)
    # disjoint write batches commute: arrival order is not logical state
    a.insert(x1, np.array([95_000, 95_001, 95_002]))
    a.insert(x2, np.array([95_010, 95_011, 95_012]))
    a.delete(dele)
    b.delete(dele)
    b.insert(x2, np.array([95_010, 95_011, 95_012]))
    b.insert(x1, np.array([95_000, 95_001, 95_002]))
    assert index_state_fingerprint(a) == index_state_fingerprint(b)


def test_fingerprint_stable_across_save_load(base, ops, tmp_path):
    idx = fresh(base)
    for op in ops[:8]:
        apply_op(idx, op)
    fp = index_state_fingerprint(idx)
    idx.save(str(tmp_path))
    assert index_state_fingerprint(QuakeIndex.load(str(tmp_path))) == fp
    # serving-session state (journal, stats) is excluded by contract
    idx.journal.record(dirty=np.array([0]), reason="noise")
    assert index_state_fingerprint(idx) == fp


# ---------------------------------------------------------------------------
# the randomized kill-point harness (acceptance criterion)
# ---------------------------------------------------------------------------

SITES = ("wal_torn_write", "wal_corrupt_record",
         "ckpt_crash_before_rename", "fsync_dropped")
KILL_SAMPLES = 56                            # 14 per fault site


@pytest.mark.parametrize("sample", range(KILL_SAMPLES))
def test_kill_point_recovery_is_prefix_consistent(tmp_path, base, ops,
                                                 sample):
    """Crash at a seeded random point under one of the four durability
    fault sites; recovery must land on a *prefix* of the admitted write
    stream whose fingerprint is byte-identical to a fault-free twin
    replay of that prefix."""
    site = SITES[sample % len(SITES)]
    rng = np.random.default_rng([202608, sample])
    rate = float(rng.uniform(0.05, 0.5))
    policy = ("always", "batch", "off")[sample % 3]
    ckpt_every = int(rng.choice([4, 7, 10]))
    fi = FaultInjector(seed=1000 + sample, rates={site: rate})

    idx = fresh(base)
    dm = DurabilityManager(idx, str(tmp_path), fsync=policy,
                           wal_batch_ops=3, ckpt_every_ops=ckpt_every,
                           faults=fi)
    admitted = 0
    for op in ops:
        try:
            if op[0] == "insert":
                dm.log_insert(op[1], op[2])
            else:
                dm.log_delete(op[1])
        except InjectedFault:
            break                            # crashed mid-append: the op
        apply_op(idx, op)                    # was never applied
        admitted += 1
        if dm.checkpoint_due():
            try:
                dm.checkpoint()
            except InjectedFault:
                break                        # crashed before the rename
    dm.simulate_crash(keep_unsynced=int(rng.integers(0, 4096)))

    rec, rep = recover_index(str(tmp_path))
    m = rep.write_ops_recovered
    assert 0 <= m <= admitted, (site, policy, m, admitted)
    twin = fresh(base)
    for op in ops[:m]:
        apply_op(twin, op)
    assert index_state_fingerprint(rec) == index_state_fingerprint(twin), \
        (site, policy, rate, m, admitted, rep)
    rec.check_invariants()
