"""Pallas kernels vs. pure-jnp oracles: shape/dtype sweeps.

Top-k is a discrete boundary (taxonomy Part E): ties make elementwise index
comparison ill-posed, so indices are checked by set overlap (recall@k) and
distances by sorted allclose.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.scan_topk import bitonic_sort, merge_sorted_topk


def _recall(a: np.ndarray, b: np.ndarray) -> float:
    hits = [len(set(x[x >= 0].tolist()) & set(y[y >= 0].tolist()))
            / max((y >= 0).sum(), 1) for x, y in zip(a, b)]
    return float(np.mean(hits))


@pytest.mark.parametrize("metric", ["l2", "ip"])
@pytest.mark.parametrize("q,n,d,k", [
    (1, 100, 8, 5),        # tiny, unaligned
    (3, 1000, 48, 10),     # typical partition
    (5, 333, 17, 7),       # awkward shapes
    (8, 2048, 64, 100),    # paper's k=100
    (2, 57, 32, 64),       # k > n
])
def test_scan_topk_vs_oracle(metric, q, n, d, k):
    rng = np.random.default_rng(q * 1000 + n + d)
    qs = jnp.asarray(rng.normal(size=(q, d)), jnp.float32)
    xs = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    dr, ir = ref.scan_topk_ref(qs, xs, min(k, n), metric)
    dp, ip_ = ops.scan_topk(qs, xs, k, metric=metric, impl="pallas")
    kk = min(k, n)
    assert _recall(np.asarray(ip_[:, :kk]), np.asarray(ir)) >= 0.999
    np.testing.assert_allclose(np.sort(np.asarray(dp[:, :kk]), 1),
                               np.sort(np.asarray(dr), 1),
                               rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_scan_topk_dtypes(dtype):
    rng = np.random.default_rng(0)
    qs = jnp.asarray(rng.normal(size=(4, 32)), dtype)
    xs = jnp.asarray(rng.normal(size=(512, 32)), dtype)
    dp, ip_ = ops.scan_topk(qs, xs, 10, metric="l2", impl="pallas")
    dr, ir = ref.scan_topk_ref(qs.astype(jnp.float32),
                               xs.astype(jnp.float32), 10, "l2")
    # bf16 rounding shifts near-ties: require high-but-not-perfect overlap
    thresh = 0.999 if dtype == jnp.float32 else 0.8
    assert _recall(np.asarray(ip_), np.asarray(ir)) >= thresh


def test_scan_topk_masked():
    rng = np.random.default_rng(1)
    qs = jnp.asarray(rng.normal(size=(2, 16)), jnp.float32)
    xs = jnp.asarray(rng.normal(size=(64, 16)), jnp.float32)
    valid = jnp.asarray(np.arange(64) % 3 != 0)
    dp, ip_ = ops.scan_topk(qs, xs, 8, valid=valid, impl="pallas")
    assert not np.isin(np.asarray(ip_), np.where(~np.asarray(valid))[0]).any()


@pytest.mark.parametrize("n,c,d", [(100, 7, 8), (513, 37, 24),
                                   (1024, 128, 64), (65, 200, 16)])
def test_kmeans_assign_vs_oracle(n, c, d):
    rng = np.random.default_rng(n + c)
    xs = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    cs = jnp.asarray(rng.normal(size=(c, d)), jnp.float32)
    a_r, d_r = ref.kmeans_assign_ref(xs, cs)
    a_p, d_p = ops.kmeans_assign(xs, cs, impl="pallas")
    # ties can differ; distances must match
    np.testing.assert_allclose(np.asarray(d_p), np.asarray(d_r),
                               rtol=1e-4, atol=1e-3)
    assert np.mean(np.asarray(a_p) == np.asarray(a_r)) > 0.99


def test_bitonic_sort_sorts():
    rng = np.random.default_rng(2)
    d = jnp.asarray(rng.normal(size=(4, 128)), jnp.float32)
    i = jnp.broadcast_to(jnp.arange(128, dtype=jnp.int32), (4, 128))
    ds, is_ = jax.jit(bitonic_sort)(d, i)
    np.testing.assert_allclose(np.asarray(ds), np.sort(np.asarray(d), 1),
                               rtol=1e-6)
    # payload permuted consistently
    np.testing.assert_allclose(
        np.take_along_axis(np.asarray(d), np.asarray(is_), 1),
        np.asarray(ds), rtol=1e-6)


def test_merge_sorted_topk():
    rng = np.random.default_rng(3)
    a = np.sort(rng.normal(size=(2, 16)), 1).astype(np.float32)
    b = np.sort(rng.normal(size=(2, 16)), 1).astype(np.float32)
    ia = np.arange(16, dtype=np.int32)[None].repeat(2, 0)
    ib = (np.arange(16, dtype=np.int32) + 100)[None].repeat(2, 0)
    md, mi = jax.jit(merge_sorted_topk)(jnp.asarray(a), jnp.asarray(ia),
                                        jnp.asarray(b), jnp.asarray(ib))
    expect = np.sort(np.concatenate([a, b], 1), 1)[:, :16]
    np.testing.assert_allclose(np.asarray(md), expect, rtol=1e-6)


# ---------------------------------------------------------------------------
# Indexed selected-block scan (scan_topk_indexed)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("metric", ["l2", "ip"])
@pytest.mark.parametrize("p,s,d,b,u,k", [
    (12, 64, 32, 16, 5, 8),      # typical
    (8, 16, 8, 4, 8, 4),         # union = all partitions
    (32, 128, 48, 8, 3, 100),    # k > u*s? no: k clipped inside
])
def test_scan_selected_vs_oracle(metric, p, s, d, b, u, k):
    rng = np.random.default_rng(p + s + b)
    data = jnp.asarray(rng.normal(size=(p, s, d)), jnp.float32)
    valid = jnp.asarray(rng.random((p, s)) < 0.9)
    sel = jnp.asarray(rng.choice(p, u, replace=False).astype(np.int32))
    qmask = jnp.asarray(rng.random((b, u)) < 0.7)
    qs = jnp.asarray(rng.normal(size=(b, d)), jnp.float32)
    d_ref, i_ref = ref.scan_selected_ref(qs, data, valid, sel, qmask,
                                         min(k, u * s), metric)
    d_pal, i_pal = ops.scan_selected_topk(qs, data, valid, sel, qmask, k,
                                          metric=metric, impl="pallas")
    kk = min(k, u * s)
    assert _recall(np.asarray(i_pal[:, :kk]), np.asarray(i_ref)) >= 0.999
    fin = np.asarray(d_ref) < 1e37
    np.testing.assert_allclose(np.asarray(d_pal[:, :kk])[fin],
                               np.asarray(d_ref)[fin], rtol=1e-4, atol=1e-3)


def test_scan_selected_bf16_storage():
    rng = np.random.default_rng(7)
    data32 = rng.normal(size=(8, 64, 16)).astype(np.float32)
    data = jnp.asarray(data32, jnp.bfloat16)
    valid = jnp.ones((8, 64), bool)
    sel = jnp.arange(8, dtype=jnp.int32)
    qs = jnp.asarray(rng.normal(size=(4, 16)), jnp.float32)
    qmask = jnp.ones((4, 8), bool)
    d_ref, i_ref = ref.scan_selected_ref(
        qs, jnp.asarray(data32), valid, sel, qmask, 10, "l2")
    d_pal, i_pal = ops.scan_selected_topk(qs, data, valid, sel, qmask, 10,
                                          metric="l2", impl="pallas")
    assert _recall(np.asarray(i_pal), np.asarray(i_ref)) >= 0.8


# ---------------------------------------------------------------------------
# Fused flash-attention forward kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,h,kh,sq,sk,d,causal", [
    (2, 8, 1, 96, 96, 32, True),     # MQA causal
    (1, 8, 2, 128, 128, 64, True),   # GQA
    (2, 4, 4, 100, 120, 32, False),  # MHA cross, unaligned lengths
    (1, 6, 2, 64, 256, 16, True),    # long kv
])
def test_flash_attention_kernel_vs_oracle(b, h, kh, sq, sk, d, causal):
    from repro.kernels.flash_attention import flash_attention_pallas
    from repro.models.layers import flash_attention as flash_ref
    rng = np.random.default_rng(b * 100 + h + sq)
    q = jnp.asarray(rng.normal(size=(b, sq, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, sk, kh, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, sk, kh, d)), jnp.float32)
    ref_o = flash_ref(q, k, v, causal=causal, q_block=32, k_block=32,
                      grouped=True)
    out = flash_attention_pallas(q, k, v, causal=causal, q_block=32,
                                 k_block=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref_o),
                               rtol=2e-5, atol=2e-5)


def test_grouped_flash_matches_repeat():
    from repro.models.layers import flash_attention
    rng = np.random.default_rng(11)
    q = jnp.asarray(rng.normal(size=(2, 64, 12, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 64, 4, 16)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 64, 4, 16)), jnp.float32)
    a = flash_attention(q, k, v, causal=True, q_block=32, k_block=32,
                        grouped=False)
    b_ = flash_attention(q, k, v, causal=True, q_block=32, k_block=32,
                         grouped=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                               rtol=1e-5, atol=1e-5)


def test_prefill_pallas_attention_matches_jnp():
    import dataclasses
    from repro.models import transformer as tr
    cfg = tr.TransformerConfig(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, vocab_size=256, remat=False,
        compute_dtype=jnp.float32, q_block=32, k_block=32)
    params = tr.init_params(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray(np.random.default_rng(0).integers(0, 256, (2, 64)),
                       jnp.int32)
    lg_ref, _ = tr.prefill(params, toks, cfg)
    lg_pal, _ = tr.prefill(params, toks,
                           dataclasses.replace(cfg, attn_impl="pallas"))
    np.testing.assert_allclose(np.asarray(lg_pal), np.asarray(lg_ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("metric", ["l2", "ip"])
def test_scan_selected_q8_residual(metric):
    """IVF residual SQ8: near-f32 ranking because the query-centroid term
    is exact; only the (small) residual carries quantization error."""
    rng = np.random.default_rng(5)
    P, S, d, B, U, k = 16, 64, 24, 8, 10, 10
    cents = rng.normal(size=(P, d)).astype(np.float32) * 4.0
    data = cents[:, None, :] + rng.normal(
        size=(P, S, d)).astype(np.float32)          # tight clusters
    from repro.kernels.scan_topk_indexed import quantize_int8_residual
    codes, scales = quantize_int8_residual(jnp.asarray(data),
                                           jnp.asarray(cents))
    valid = jnp.ones((P, S), bool)
    sel = jnp.asarray(rng.choice(P, U, replace=False).astype(np.int32))
    qmask = jnp.ones((B, U), bool)
    qs = jnp.asarray(cents[np.asarray(sel)[:B] % P]
                     + rng.normal(size=(B, d)).astype(np.float32))
    d_ref, i_ref = ref.scan_selected_ref(qs, jnp.asarray(data), valid,
                                         sel, qmask, k, metric)
    d_q8, i_q8 = ops.scan_selected_topk_q8(
        qs, codes, scales, valid, sel, qmask, k, metric=metric,
        centroids=jnp.asarray(cents))
    assert _recall(np.asarray(i_q8), np.asarray(i_ref)) >= 0.9
    fin = np.asarray(d_ref) < 1e37
    np.testing.assert_allclose(np.asarray(d_q8)[fin],
                               np.asarray(d_ref)[fin], rtol=0.05, atol=0.5)


def test_engine_int8_recall():
    from jax.sharding import Mesh
    from repro.core import (EngineConfig, IndexSnapshot, QuakeIndex,
                            ShardedQuakeEngine)
    from repro.data import datasets
    ds = datasets.clustered(3000, 16, n_clusters=16, seed=0)
    idx = QuakeIndex.build(ds.vectors, num_partitions=24, kmeans_iters=4)
    snap0 = IndexSnapshot.from_index(idx)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1, 1),
                ("pod", "data", "model"))
    q = jnp.asarray(datasets.queries_near(ds, 24, seed=2))
    gt = ds.ground_truth(np.asarray(q), 10)
    eng = ShardedQuakeEngine(mesh, EngineConfig(
        k=10, nprobe=8, part_axes=("pod", "data"),
        scan_impl="union_pallas", storage_dtype="int8"))
    ss = eng.shard_snapshot(snap0)
    d_f, i_f = eng.search_fixed(q, ss)
    rec = np.mean([len(set(np.asarray(i_f[r]).tolist())
                       & set(gt[r].tolist())) / 10 for r in range(24)])
    assert rec >= 0.9, rec
