"""quakecheck rule suite: every rule family must flag its seeded-bad
fixture and pass its known-good twin, pragmas must suppress and
register, and the repo itself must lint clean (the acceptance bar)."""
import pathlib
import subprocess
import sys

import pytest

from tools.quakecheck import lint_paths, lint_source

FIXTURES = pathlib.Path(__file__).parent / "quakecheck_fixtures"
REPO = pathlib.Path(__file__).resolve().parents[1]


def rules_in(path):
    return sorted({f.rule for f in lint_paths([str(path)])})


@pytest.mark.parametrize("rule,bad,good", [
    ("QK101", "qk101_bad.py", "qk101_good.py"),
    ("QK102", "qk102_bad.py", "qk102_good.py"),
    ("QK103", "kernels/qk103_bad.py", "kernels/qk103_good.py"),
    ("QK104", "qk104_bad.py", "qk104_good.py"),
    ("QK105", "qk105_bad.py", "qk105_good.py"),
    ("QK201", "qk201_bad.py", "qk201_good.py"),
    ("QK202", "qk202_bad.py", "qk202_good.py"),
    ("QK203", "qk203_bad.py", "qk203_good.py"),
    ("QK204", "qk204_bad.py", "qk204_good.py"),
    ("QK301", "repro/qk301_bad.py", "repro/qk301_good.py"),
    ("QK302", "durability/qk302_bad.py", "durability/qk302_good.py"),
    ("QK401", "repro/core/qk401_bad.py", "repro/core/qk401_good.py"),
])
def test_rule_flags_bad_passes_good(rule, bad, good):
    assert rules_in(FIXTURES / bad) == [rule]
    assert rules_in(FIXTURES / good) == []


def test_bad_fixtures_have_expected_counts():
    # each seeded violation is individually detected, not just the file
    assert len(lint_paths([str(FIXTURES / "qk101_bad.py")])) == 3
    assert len(lint_paths([str(FIXTURES / "qk102_bad.py")])) >= 2
    assert len(lint_paths([str(FIXTURES / "kernels/qk103_bad.py")])) == 4
    assert len(lint_paths([str(FIXTURES / "qk104_bad.py")])) == 1
    assert len(lint_paths([str(FIXTURES / "qk105_bad.py")])) == 2
    assert len(lint_paths([str(FIXTURES / "qk201_bad.py")])) == 2
    assert len(lint_paths([str(FIXTURES / "qk202_bad.py")])) == 1
    assert len(lint_paths([str(FIXTURES / "qk203_bad.py")])) == 1
    assert len(lint_paths([str(FIXTURES / "qk204_bad.py")])) == 1
    assert len(lint_paths([str(FIXTURES / "repro/qk301_bad.py")])) == 3
    # qk302_bad: unsynced append + manifest open that is both unsynced
    # and written in place
    assert len(lint_paths([str(FIXTURES / "durability/qk302_bad.py")])) == 3
    # qk401_bad: two time.time() reads + one print()
    assert len(lint_paths([str(FIXTURES / "repro/core/qk401_bad.py")])) == 3


def test_qk100_reasonless_allow_sync():
    rules = rules_in(FIXTURES / "qk100_bad.py")
    # the empty-reason pragma is flagged AND does not suppress the sync
    assert rules == ["QK100", "QK101"]


def test_qk100_reasonless_allow_swallow():
    # an allow-swallow with no reason is itself a finding, and it does
    # not suppress the swallow it sits on (mirrors allow-sync)
    src = ("def f(c):\n"
           "    try:\n"
           "        c.tick()\n"
           "    except Exception:  # quakecheck: allow-swallow()\n"
           "        pass\n")
    rules = sorted({f.rule for f in lint_source(src, "src/repro/t.py")})
    assert rules == ["QK100", "QK301"]
    # outside a repro runtime path the swallow rule stays silent
    assert all(f.rule != "QK301" for f in lint_source(src, "bench/t.py"))


def test_qk100_reasonless_allow_nosync():
    # an allow-nosync with no reason is itself a finding, and it does
    # not suppress the unsynced write it sits on (mirrors allow-sync)
    src = ("def tear(path, size):\n"
           "    with open(path, 'r+b') as f:"
           "  # quakecheck: allow-nosync()\n"
           "        f.truncate(size)\n")
    rules = sorted({f.rule for f in
                    lint_source(src, "src/repro/core/durability.py")})
    assert rules == ["QK100", "QK302"]
    # outside a durability path the rule stays silent (pragma still bad)
    assert sorted({f.rule for f in lint_source(src, "bench/t.py")}) \
        == ["QK100"]


def test_qk100_reasonless_allow_wallclock():
    # an allow-wallclock with no reason is itself a finding, and it does
    # not suppress the wall-clock read it sits on (mirrors allow-sync)
    src = ("import time\n"
           "def stamp():\n"
           "    return time.time()  # quakecheck: allow-wallclock()\n")
    rules = sorted({f.rule for f in
                    lint_source(src, "src/repro/core/serving.py")})
    assert rules == ["QK100", "QK401"]
    # outside a core runtime path the rule stays silent (pragma still bad)
    assert sorted({f.rule for f in lint_source(src, "bench/t.py")}) \
        == ["QK100"]


def test_fixture_dir_as_a_whole():
    findings = lint_paths([str(FIXTURES)])
    assert {f.rule for f in findings} == \
        {"QK100", "QK101", "QK102", "QK103", "QK104", "QK105",
         "QK201", "QK202", "QK203", "QK204", "QK301", "QK302", "QK401"}
    assert all("good" not in f.path for f in findings)


def test_inline_disable_pragma():
    src = (
        "import jax\n"
        "def run(xs):\n"
        "    for _ in range(2):\n"
        "        xs = jax.jit(lambda a: a + 1)(xs)"
        "  # quakecheck: disable=QK102(bench harness, built twice)\n"
        "    return xs\n")
    assert lint_source(src, "t.py") == []
    assert any(f.rule == "QK102"
               for f in lint_source(src.replace(
                   "  # quakecheck: disable=QK102(bench harness, "
                   "built twice)", ""), "t.py"))


def test_device_path_pragma_registers():
    src = ("import numpy as np, jax.numpy as jnp\n"
           "def f(q):  # quakecheck: device-path\n"
           "    d = jnp.sum(q)\n"
           "    return np.asarray(d)\n")
    assert [f.rule for f in lint_source(src, "t.py")] == ["QK101"]
    # without the marker the same body is host code
    assert lint_source(src.replace(
        "  # quakecheck: device-path", ""), "t.py") == []


def test_repo_lints_clean():
    """Acceptance criterion: the stack carries no undocumented findings."""
    findings = lint_paths([str(REPO / "src"), str(REPO / "tools")])
    assert findings == [], "\n".join(f.render() for f in findings)


def test_select_prefix_matches_family():
    # --select QK2 picks up the whole concurrency family and nothing else
    findings = lint_paths([str(FIXTURES)], select=["QK2"])
    rules = {f.rule for f in findings}
    assert rules == {"QK201", "QK202", "QK203", "QK204"}


def test_holds_pragma_seeds_lock_set():
    src = (
        "class ResultCache:\n"
        "    def on_collect(self, eid, e):"
        "  # quakecheck: holds(ResultCache._lock)\n"
        "        self._store[eid] = e\n")
    assert lint_source(src, "t.py") == []
    stripped = src.replace("  # quakecheck: holds(ResultCache._lock)", "")
    assert [f.rule for f in lint_source(stripped, "t.py")] == ["QK201"]


def test_empty_holds_pragma_is_qk100():
    src = (
        "class ResultCache:\n"
        "    def on_collect(self, eid, e):  # quakecheck: holds()\n"
        "        self._store[eid] = e\n")
    rules = [f.rule for f in lint_source(src, "t.py")]
    # the empty pragma is flagged AND seeds nothing, so QK201 still fires
    assert sorted(rules) == ["QK100", "QK201"]


def test_cli_exit_codes():
    ok = subprocess.run(
        [sys.executable, "-m", "tools.quakecheck", "src"],
        cwd=REPO, capture_output=True, text=True)
    assert ok.returncode == 0, ok.stdout + ok.stderr
    bad = subprocess.run(
        [sys.executable, "-m", "tools.quakecheck",
         str(FIXTURES / "qk101_bad.py")],
        cwd=REPO, capture_output=True, text=True)
    assert bad.returncode == 1
    assert "QK101" in bad.stdout
