"""Online serving runtime (core/serving.py).

Pins the subsystem's four contracts:

  * coalescing determinism — the same operation stream yields identical
    results under any flush timing (queue size / interleave choices only
    change *when* work runs, never what a query scans);
  * the riding-footprint invariant — partitions streamed across queued
    batches are a subset of the union of the per-batch fixed plans, and
    a co-admitted group streams each partition at most once;
  * result-cache correctness under interleaved insert/delete — journal-
    driven per-partition invalidation keeps every served hit consistent
    with brute force over the entry's footprint, and structural changes
    clear the cache;
  * drift-triggered maintenance — triggers fire on journal dirty mass /
    cost drift / access-histogram shift and nothing else, with served-
    batch access frequencies feeding the statistics.
"""
import numpy as np
import pytest

from repro.core import (QuakeConfig, QuakeIndex, ServingConfig,
                        ServingRuntime)
from repro.core.serving import (MaintenanceScheduler, MaintenanceTriggers,
                                ResultCache)
from repro.core.maintenance import Maintainer
from repro.core.cost_model import LatencyModel
from repro.data import datasets
from repro.data.workload import IncrementalGroundTruth


@pytest.fixture(scope="module")
def ds():
    return datasets.clustered(4000, 16, n_clusters=16, seed=0)


def build(ds, **cfg):
    return QuakeIndex.build(ds.vectors, num_partitions=32, kmeans_iters=4,
                            config=QuakeConfig(**cfg))


def _result_rows(rt, qids):
    return [rt.result(i) for i in qids]


# ---------------------------------------------------------------------------
# Coalescing determinism
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["host", "device"])
def test_coalescing_determinism(ds, backend):
    """Same ops, any flush timing -> same results (ids and distances),
    including across a write barrier."""
    q1 = datasets.queries_near(ds, 24, seed=1)
    q2 = datasets.queries_near(ds, 17, seed=2)
    ins = ds.vectors[:20] + 0.01

    def replay(flush_size, interleave):
        idx = build(ds)
        rt = ServingRuntime(idx, ServingConfig(
            k=10, flush_size=flush_size, interleave_rounds=interleave,
            scan_backend=backend, maint_min_ops=10 ** 9))
        qa = rt.submit_batch(q1)
        rt.submit_insert(ins, np.arange(90_000, 90_020))
        qb = rt.submit_batch(q2)
        rt.drain()
        return _result_rows(rt, qa + qb)

    ref = replay(64, 1)
    for flush_size, interleave in ((5, 0), (8, 3), (1, 1)):
        got = replay(flush_size, interleave)
        for r_ref, r_got in zip(ref, got):
            assert np.array_equal(r_ref.ids, r_got.ids)
            # scan arithmetic is f32 and the BLAS kernel blocks
            # differently with different rider counts: distances agree
            # to f32 rounding, the selected ids exactly
            np.testing.assert_allclose(r_ref.dists, r_got.dists,
                                       rtol=1e-4, atol=1e-3)
            assert r_ref.nprobe == r_got.nprobe


def test_host_and_device_backends_agree(ds):
    idx = build(ds)
    q = datasets.queries_near(ds, 16, seed=3)
    res = {}
    for backend in ("host", "device"):
        rt = ServingRuntime(idx, ServingConfig(
            k=10, scan_backend=backend, maint_min_ops=10 ** 9))
        qids = rt.submit_batch(q)
        rt.drain()
        res[backend] = _result_rows(rt, qids)
    for rh, rd in zip(res["host"], res["device"]):
        assert set(rh.ids.tolist()) == set(rd.ids.tolist())


# ---------------------------------------------------------------------------
# Riding-footprint invariant
# ---------------------------------------------------------------------------

def test_riding_footprint_invariant(ds):
    """Partitions streamed across queued batches ⊆ union of the batches'
    fixed plans; a co-admitted group streams each partition at most once;
    riding amortizes (fewer streams than the per-batch plans sum to)."""
    idx = build(ds)
    rt = ServingRuntime(idx, ServingConfig(
        k=10, flush_size=16, interleave_rounds=0, maint_min_ops=10 ** 9))
    # overlapping batches (same hot region) queued together
    for seed in (4, 5, 6):
        rt.submit_batch(datasets.queries_near(ds, 16, seed=seed))
    rt.drain()
    sch = rt.scheduler
    streamed = np.concatenate(sch.round_streams)
    planned = np.unique(np.concatenate(sch.plan_footprints))
    assert set(streamed.tolist()) <= set(planned.tolist())
    # co-admitted: each partition streams at most once across all three
    # queued batches (run_round_loop's per-batch guarantee, extended)
    assert len(streamed) == len(np.unique(streamed))
    # and strictly fewer streams than the per-batch plans would pay
    per_batch_sum = sum(len(f) for f in sch.plan_footprints)
    assert sch.partitions_streamed < per_batch_sum
    assert rt.stats()["riding_savings"] > 0


def test_late_admission_rides_in_flight_rounds(ds):
    """A batch admitted while another is mid-rounds joins its remaining
    rounds: the footprint invariant holds and total streams stay at or
    under the per-batch sum."""
    idx = build(ds)
    rt = ServingRuntime(idx, ServingConfig(
        k=10, flush_size=16, interleave_rounds=1, rounds=4,
        maint_min_ops=10 ** 9))
    rt.submit_batch(datasets.queries_near(ds, 16, seed=7))   # flushes+steps
    rt.submit_batch(datasets.queries_near(ds, 16, seed=8))   # rides
    rt.drain()
    sch = rt.scheduler
    streamed = np.concatenate(sch.round_streams)
    planned = np.unique(np.concatenate(sch.plan_footprints))
    assert set(streamed.tolist()) <= set(planned.tolist())
    assert sch.partitions_streamed <= sum(len(f)
                                          for f in sch.plan_footprints)


def test_results_exact_over_planned_footprint(ds):
    """Every served result is the exact top-k over the contents of the
    query's planned partitions (rounds decompose the plan, never change
    it)."""
    idx = build(ds)
    rt = ServingRuntime(idx, ServingConfig(
        k=10, flush_size=8, maint_min_ops=10 ** 9))
    q = datasets.queries_near(ds, 12, seed=9)
    qids = rt.submit_batch(q)
    rt.drain()
    lvl0 = idx.levels[0]
    for j, qid in enumerate(qids):
        res = rt.result(qid)
        # recover the plan footprint from the scheduler's telemetry is
        # per-batch; recompute the expected set by brute force over the
        # partitions the query actually consumed is equivalent here:
        # nprobe == planned count (no early exit), so scan every level-0
        # partition the result could have come from
        parts = sorted({idx.id_map[int(i)] for i in res.ids if i >= 0})
        ids = np.concatenate([lvl0.ids[p] for p in parts])
        got = set(int(i) for i in res.ids if i >= 0)
        # served ids must be at least as close as the best of their own
        # partitions (exactness within the scanned footprint)
        assert got <= set(ids.tolist())
        assert res.nprobe >= 1 and res.rounds >= 1


# ---------------------------------------------------------------------------
# Result cache
# ---------------------------------------------------------------------------

def _footprint_topk(idx, q, footprint, k):
    lvl0 = idx.levels[0]
    xs = [lvl0.vectors[int(p)] for p in footprint
          if int(p) < lvl0.num_partitions]
    ids = [lvl0.ids[int(p)] for p in footprint
           if int(p) < lvl0.num_partitions]
    x = np.concatenate(xs)
    ii = np.concatenate(ids)
    d = np.sum((x - q) ** 2, axis=1)
    kk = min(k, len(d))
    return ii[np.argsort(d, kind="stable")[:kk]]


def test_cache_exact_hit_and_dirty_invalidation(ds):
    """Exact-key cache: a repeat hits; an insert into the entry's
    footprint invalidates it (journal-driven), and the re-served result
    matches brute force over the footprint — including the new vector."""
    idx = build(ds)
    rt = ServingRuntime(idx, ServingConfig(
        k=10, cache_entries=128, maint_min_ops=10 ** 9))
    q = datasets.queries_near(ds, 1, seed=10)[0]
    qid1 = rt.submit_query(q)
    rt.drain()
    r1 = rt.result(qid1)
    assert not r1.from_cache

    qid2 = rt.submit_query(q)
    r2 = rt.result(qid2)          # cache hits resolve synchronously
    assert r2 is not None and r2.from_cache
    assert np.array_equal(r1.ids, r2.ids)

    # insert the query itself: routes to its nearest partition, which is
    # in the footprint -> entry must drop, re-serve must see the new id
    new_id = 123_456
    rt.submit_insert(q[None, :], np.asarray([new_id]))
    qid3 = rt.submit_query(q)
    rt.drain()
    r3 = rt.result(qid3)
    assert not r3.from_cache
    assert new_id in set(r3.ids.tolist())

    # delete it again: footprint dirty -> invalidated -> served result
    # must not contain the deleted id
    rt.submit_delete(np.asarray([new_id]))
    qid4 = rt.submit_query(q)
    rt.drain()
    r4 = rt.result(qid4)
    assert not r4.from_cache
    assert new_id not in set(r4.ids.tolist())
    assert set(r4.ids.tolist()) == set(r1.ids.tolist())


def test_cache_survives_unrelated_writes_and_matches_brute_force(ds):
    """Writes confined to partitions outside an entry's footprint leave
    it valid; every hit equals brute force over the footprint's current
    contents (the QVCache consistency contract)."""
    idx = build(ds)
    rt = ServingRuntime(idx, ServingConfig(
        k=10, cache_entries=128, maint_min_ops=10 ** 9))
    q = datasets.queries_near(ds, 1, seed=11)[0]
    qid1 = rt.submit_query(q)
    rt.drain()
    r1 = rt.result(qid1)
    entry = rt.cache.get(q, 10)
    assert entry is not None
    footprint = set(int(p) for p in entry["footprint"])

    # a far-away insert: pick a vector whose routed partition is outside
    # the footprint
    far = None
    for cand in range(ds.n):
        p = idx.id_map.get(cand)
        if p is not None and p not in footprint:
            far = ds.vectors[cand] + 0.01
            break
    assert far is not None
    rt.submit_insert(far[None, :], np.asarray([77_777]))
    assert idx.id_map[77_777] not in footprint

    qid2 = rt.submit_query(q)
    r2 = rt.result(qid2)
    assert r2 is not None and r2.from_cache
    want = set(_footprint_topk(idx, q, sorted(footprint), 10).tolist())
    assert set(int(i) for i in r2.ids if i >= 0) == want


def test_cache_cleared_on_structural_change(ds):
    idx = build(ds)
    rt = ServingRuntime(idx, ServingConfig(
        k=10, cache_entries=128, maint_min_ops=10 ** 9))
    q = datasets.queries_near(ds, 4, seed=12)
    rt.submit_batch(q)
    rt.drain()
    assert len(rt.cache) == 4
    rt.maybe_maintain(force=True)     # splits/merges -> structural entries
    if any(e.structural for e in idx.journal.entries_since(0)):
        assert len(rt.cache) == 0


def test_result_cache_lsh_and_lru():
    rng = np.random.default_rng(0)
    cache = ResultCache(max_entries=4, bits=16, tol=0.5, seed=0)
    q = rng.normal(size=8).astype(np.float32)
    cache.put(q, 10, np.arange(10), np.arange(10.0), np.asarray([1, 2]))
    # a nearby query in the same LSH bucket within tol hits
    hit = cache.get(q + 1e-4, 10)
    assert hit is not None and np.array_equal(hit["ids"], np.arange(10))
    # far query misses (tol check, whatever the bucket)
    assert cache.get(-q, 10) is None
    # k mismatch misses
    assert cache.get(q, 5) is None
    # LRU eviction at capacity
    for i in range(5):
        cache.put(rng.normal(size=8).astype(np.float32) * 10, 10,
                  np.arange(10), np.arange(10.0), np.asarray([3]))
    assert len(cache) == 4
    # partition invalidation removes exactly the touching entries
    cache2 = ResultCache(max_entries=8, bits=0, tol=0.0)
    qa = rng.normal(size=8).astype(np.float32)
    qb = rng.normal(size=8).astype(np.float32)
    cache2.put(qa, 10, np.arange(10), np.arange(10.0), np.asarray([1, 2]))
    cache2.put(qb, 10, np.arange(10), np.arange(10.0), np.asarray([3]))
    assert cache2.invalidate_partitions({2}) == 1
    assert cache2.get(qa, 10) is None
    assert cache2.get(qb, 10) is not None


# ---------------------------------------------------------------------------
# Maintenance scheduling
# ---------------------------------------------------------------------------

def test_maintenance_trigger_dirty_mass(ds):
    idx = build(ds)
    sched = MaintenanceScheduler(
        Maintainer(idx, LatencyModel(dim=ds.dim)),
        MaintenanceTriggers(min_ops=2, dirty_frac=0.25, cost_drift=np.inf,
                            access_shift=np.inf, max_ops=None))
    assert sched.due() is None                 # below min_ops
    sched.note_op(2)
    assert sched.due() is None                 # no drift yet
    # dirty a third of the partitions
    n_dirty = idx.num_partitions // 3 + 1
    idx.journal.record(dirty=range(n_dirty), reason="insert")
    assert sched.due() == "dirty_mass"
    rep = sched.run_if_due()
    assert rep is not None
    assert sched.history[-1]["reason"] == "dirty_mass"
    assert sched.ops_since == 0                # rebaselined
    sched.note_op(2)
    assert sched.due() is None                 # trigger cleared


def test_maintenance_trigger_cost_drift_and_op_budget(ds):
    idx = build(ds)
    m = Maintainer(idx, LatencyModel(dim=ds.dim))
    sched = MaintenanceScheduler(m, MaintenanceTriggers(
        min_ops=1, dirty_frac=np.inf, cost_drift=0.10,
        access_shift=np.inf, max_ops=None))
    sched.note_op()
    assert sched.due() is None
    # grow one partition hard: the access-weighted cost estimate moves
    lvl0 = idx.levels[0]
    j = int(np.argmax(lvl0.sizes()))
    grow = np.repeat(lvl0.vectors[j][:1], 4000, axis=0)
    idx.insert(grow, np.arange(500_000, 504_000))
    assert sched.due() == "cost_drift"
    # op budget forces a pass even with every drift trigger off
    sched2 = MaintenanceScheduler(m, MaintenanceTriggers(
        min_ops=1, dirty_frac=np.inf, cost_drift=np.inf,
        access_shift=np.inf, max_ops=3))
    sched2.note_op(3)
    assert sched2.due() == "op_budget"


def test_maintenance_trigger_access_shift(ds):
    idx = build(ds)
    sched = MaintenanceScheduler(
        Maintainer(idx, LatencyModel(dim=ds.dim)),
        MaintenanceTriggers(min_ops=1, dirty_frac=np.inf,
                            cost_drift=np.inf, access_shift=0.5,
                            max_ops=None))
    lvl0 = idx.levels[0]
    lvl0.stats.ensure(lvl0.num_partitions)
    sched._rebaseline()
    sched.note_op()
    # all traffic concentrates on one partition: total-variation
    # distance from the (uniform-prior) baseline exceeds 0.5
    lvl0.stats.record_batch(np.asarray([0]), np.asarray([100.0]), 100)
    assert sched.due() == "access_shift"


def test_runtime_feeds_access_stats(ds):
    """Served batches must feed PartitionStats (the batched path bypasses
    per-query recording)."""
    idx = build(ds)
    rt = ServingRuntime(idx, ServingConfig(
        k=10, flush_size=16, maint_min_ops=10 ** 9))   # no pass resets
    lvl0 = idx.levels[0]
    rt.submit_batch(datasets.queries_near(ds, 32, seed=13))
    rt.drain()
    assert lvl0.stats.window == 32
    assert lvl0.stats.hits.sum() > 0


def test_runtime_maintains_on_drift(ds):
    """The runtime runs drift-triggered passes on its own — from write
    barriers and from read-only drains alike."""
    idx = build(ds)
    rt = ServingRuntime(idx, ServingConfig(
        k=10, flush_size=16, maint_min_ops=1, maint_dirty_frac=0.2))
    # read-only stream: the served access frequencies move the cost
    # estimate / histogram, and the drain-time check picks it up
    rt.submit_batch(datasets.queries_near(ds, 32, seed=13))
    rt.drain()
    read_only_runs = len(rt.maintenance.history)
    # writes accumulate dirty mass until the trigger fires
    for i in range(4):
        rt.submit_insert(ds.vectors[i * 50:(i + 1) * 50] + 0.01,
                         np.arange(700_000 + i * 50, 700_050 + i * 50))
    assert len(rt.maintenance.history) >= max(read_only_runs, 1)
    assert rt.stats()["maintenance_runs"] == len(rt.maintenance.history)
    assert all(h["reason"] for h in rt.maintenance.history)


# ---------------------------------------------------------------------------
# Incremental ground truth
# ---------------------------------------------------------------------------

def test_incremental_ground_truth_matches_recompute(ds):
    gt = IncrementalGroundTruth(ds, np.arange(1000))
    rng = np.random.default_rng(3)
    q = ds.vectors[rng.integers(0, 1000, 8)] + 0.01

    def brute(resident):
        res = np.asarray(sorted(resident))
        x = ds.vectors[res]
        d = (np.sum(x ** 2, 1)[None, :] - 2.0 * q @ x.T
             + np.sum(q ** 2, 1)[:, None])
        return res[np.argsort(d, axis=1, kind="stable")[:, :5]]

    resident = set(range(1000))
    np.testing.assert_array_equal(gt.topk(q, 5), brute(resident))
    gt.insert(np.arange(1000, 1400))
    resident |= set(range(1000, 1400))
    np.testing.assert_array_equal(gt.topk(q, 5), brute(resident))
    gt.delete(np.arange(0, 500))
    resident -= set(range(0, 500))
    np.testing.assert_array_equal(gt.topk(q, 5), brute(resident))
    assert len(gt.resident_ids) == len(resident)
