"""Optimizer / checkpoint / fault-tolerant loop / workload+pipeline tests."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.core import QuakeIndex
from repro.core.multiquery import batch_search, per_query_search
from repro.data import datasets, pipelines, wikipedia, workload
from repro.roofline import hlo_cost
from repro.train import (AdamWConfig, CheckpointManager, LoopConfig,
                         init_state, train_loop)
from repro.train import optimizer as opt
from repro.train import steps


def test_adamw_converges_quadratic():
    def loss(p, _):
        return jnp.sum((p["w"] - 3.0) ** 2)
    params = {"w": jnp.zeros(4)}
    st = init_state(params)
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1,
                      total_steps=200)
    step = jax.jit(steps.make_train_step(loss, cfg))
    for s in range(150):
        params, st, m = step(params, st, None)
    np.testing.assert_allclose(np.asarray(params["w"]), 3.0, atol=0.05)


def test_grad_clipping():
    g = {"a": jnp.full(100, 10.0)}
    clipped, norm = opt.clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(100.0)
    assert float(opt.global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    s = np.asarray([float(opt.schedule(cfg, jnp.asarray(t)))
                    for t in range(101)])
    assert s[0] == 0.0 and s[10] == pytest.approx(1.0, abs=0.1)
    assert s[100] == pytest.approx(0.1, abs=0.01)
    assert (np.diff(s[:10]) > 0).all()       # warmup rises
    assert (np.diff(s[20:]) <= 1e-9).all()   # decay falls


def test_int8_compression_roundtrip():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(256,)) * 0.01, jnp.float32)
    q, scale = opt.compress_int8(g)
    deq = opt.decompress_int8(q, scale)
    assert float(jnp.max(jnp.abs(deq - g))) <= float(scale) * 0.51


def test_checkpoint_roundtrip_and_gc():
    state = {"w": jnp.arange(6.0), "nested": [jnp.ones((2, 3))],
             "opt": init_state({"w": jnp.arange(6.0)})}
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2, async_write=False)
        for s in (1, 2, 3):
            mgr.save(s, state, block=True)
        assert len(mgr.list()) == 2          # gc keeps last 2
        restored, man = mgr.restore(state)
        assert man["step"] == 3
        for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(state)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_shape_mismatch_rejected():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, async_write=False)
        mgr.save(1, {"w": jnp.zeros((4,))}, block=True)
        with pytest.raises(ValueError):
            mgr.restore({"w": jnp.zeros((5,))})


def test_loop_recovers_and_replays_data():
    """After an injected failure the loop must resume from the checkpoint
    step and consume the same batches (step-indexed pipeline)."""
    seen = []

    def step_fn(state, batch):
        seen.append(int(batch))
        return state + 1, {"loss": float(state)}

    fails = {13}

    def injector(s):
        if s in fails:
            fails.discard(s)
            raise RuntimeError("boom")

    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, async_write=False)
        rep = train_loop(jnp.zeros(()), step_fn, lambda s: s, mgr,
                         LoopConfig(n_steps=20, ckpt_every=5),
                         failure_injector=injector)
    assert rep.restarts == 1
    # steps 10..12 replayed after restore from ckpt@10
    assert seen.count(10) == 2 and seen.count(11) == 2
    assert sorted(set(seen)) == list(range(20))


def test_workload_generator_determinism_and_mix():
    ds = datasets.clustered(3000, 16, seed=0)
    cfg = workload.WorkloadConfig(n_operations=30, read_fraction=0.5,
                                  delete_fraction=0.3, query_skew=1.0,
                                  vectors_per_op=100, seed=7)
    w1 = workload.generate(ds, cfg)
    w2 = workload.generate(ds, cfg)
    assert [o.kind for o in w1.operations] == [o.kind for o in w2.operations]
    kinds = [o.kind for o in w1.operations]
    assert kinds.count("query") > 0 and kinds.count("insert") > 0


def test_wikipedia_workload_grows_and_skews():
    wl = wikipedia.wikipedia_workload(n_total=5000, dim=8, months=5,
                                      queries_per_month=200)
    assert wl.dataset.metric == "ip"
    inserted = sum(len(op.ids) for op in wl.operations
                   if op.kind == "insert")
    assert len(wl.initial_ids) + inserted == 5000
    # skew: query batches should reuse popular targets
    qops = [op for op in wl.operations if op.kind == "query"]
    assert len(qops) == 5


def test_multiquery_matches_perquery():
    ds = datasets.clustered(4000, 16, seed=0)
    idx = QuakeIndex.build(ds.vectors, num_partitions=64, kmeans_iters=3)
    q = datasets.queries_near(ds, 64, seed=2)
    rb = batch_search(idx, q, 10, nprobe=8)
    rp = per_query_search(idx, q, 10, nprobe=8)
    overlap = np.mean([len(set(rb.ids[i]) & set(rp.ids[i])) / 10
                       for i in range(64)])
    assert overlap >= 0.97


def test_hlo_cost_trip_counts():
    """The roofline analyzer must multiply scan bodies by trip count and
    agree with XLA on loop-free programs."""
    def scanned(x, w):
        def step(c, _):
            return c @ w, None
        return jax.lax.scan(step, x, None, length=7)[0]

    def flat(x, w):
        for _ in range(7):
            x = x @ w
        return x

    a = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    cs = jax.jit(scanned).lower(a, a).compile()
    cf = jax.jit(flat).lower(a, a).compile()
    mine_s = hlo_cost.analyze(cs.as_text())
    mine_f = hlo_cost.analyze(cf.as_text())
    xla_f = compat.cost_analysis_dict(cf)["flops"]
    assert mine_f.flops == pytest.approx(xla_f, rel=0.01)
    assert mine_s.flops == pytest.approx(mine_f.flops, rel=0.02)


def test_pipelines_are_step_indexed():
    tp = pipelines.TokenPipeline(100, 2, 8, seed=3)
    assert (tp.batch_at(5)["tokens"] == tp.batch_at(5)["tokens"]).all()
    assert (tp.batch_at(5)["tokens"] != tp.batch_at(6)["tokens"]).any()
    rp = pipelines.RecsysPipeline(batch=4, vocab=100)
    b5, b5b = rp.batch_at(5), rp.batch_at(5)
    for k in b5:
        np.testing.assert_array_equal(b5[k], b5b[k])
