"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="optional dev dependency (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import aps, geometry, kmeans
from repro.core.cost_model import LatencyModel
from repro.models.layers import embedding_bag

SET = settings(max_examples=30, deadline=None)


@given(st.integers(2, 512), st.floats(-2.0, 2.0))
@SET
def test_cap_fraction_bounds(dim, t):
    """Cap volume fraction is in [0,1], 1/2 at the equator, decreasing in
    the (signed) margin."""
    tbl = jnp.asarray(geometry.betainc_table(dim))
    v = float(geometry.cap_fraction(jnp.float32(t), tbl))
    assert 0.0 <= v <= 1.0
    v0 = float(geometry.cap_fraction(jnp.float32(0.0), tbl))
    assert abs(v0 - 0.5) < 1e-3
    v_hi = float(geometry.cap_fraction(jnp.float32(min(t + 0.2, 1.0)), tbl))
    assert v_hi <= v + 1e-4


@given(st.integers(2, 256))
@SET
def test_cap_table_matches_exact(dim):
    tbl = jnp.asarray(geometry.betainc_table(dim))
    ts = jnp.linspace(-1, 1, 33)
    approx = geometry.cap_fraction(ts, tbl)
    exact = geometry.cap_fraction_exact(ts, dim)
    np.testing.assert_allclose(np.asarray(approx), np.asarray(exact),
                               atol=2e-3)


@given(st.integers(1, 30), st.floats(0.1, 10.0), st.integers(0, 10**6))
@SET
def test_probabilities_form_distribution(m, rho, seed):
    rng = np.random.default_rng(seed)
    d0 = float(rng.uniform(0.1, 5.0))
    di = d0 + np.abs(rng.normal(size=m)) + 1e-3
    cc = np.abs(rng.normal(size=m)) + 1e-2
    tbl = geometry.betainc_table(32).astype(np.float64)
    valid = np.ones(m, bool)
    p0, p = aps.estimate_probs_np(d0, di, cc, rho ** 2, tbl, valid)
    assert 0.0 <= p0 <= 1.0 + 1e-9
    assert (p >= -1e-12).all()
    assert p0 + p.sum() <= 1.0 + 1e-6


@given(st.integers(2, 40), st.integers(0, 10**6))
@SET
def test_np_and_jnp_estimators_agree(m, seed):
    rng = np.random.default_rng(seed)
    d0 = float(rng.uniform(0.1, 5.0))
    di = d0 + np.abs(rng.normal(size=m)) + 1e-3
    cc = np.abs(rng.normal(size=m)) + 1e-2
    rho_sq = float(rng.uniform(0.05, 9.0))
    tbl = geometry.betainc_table(16)
    valid = np.ones(m, bool)
    valid[int(rng.integers(m))] = False
    p0n, pn = aps.estimate_probs_np(d0, di, cc, rho_sq,
                                    tbl.astype(np.float64), valid)
    p0j, pj = aps.estimate_probs(jnp.float32(d0), jnp.asarray(di, jnp.float32),
                                 jnp.asarray(cc, jnp.float32),
                                 jnp.float32(rho_sq), jnp.asarray(tbl),
                                 jnp.asarray(valid))
    assert abs(p0n - float(p0j)) < 5e-3
    np.testing.assert_allclose(pn, np.asarray(pj, np.float64), atol=5e-3)


@given(st.integers(20, 200), st.integers(2, 8), st.integers(0, 10**6))
@SET
def test_kmeans_objective_nonincreasing(n, k, seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, 8)).astype(np.float32)

    def objective(c, a):
        return float(np.sum((x - c[a]) ** 2))

    c1, a1 = kmeans.kmeans(x, k, iters=1, seed=0)
    c5, a5 = kmeans.kmeans(x, k, iters=6, seed=0)
    assert objective(c5, a5) <= objective(c1, a1) + 1e-3
    assert len(np.unique(a5)) <= k
    assert (a5 >= 0).all() and (a5 < min(k, n)).all()


@given(st.integers(2, 100))
@SET
def test_split_two_always_splits(n):
    rng = np.random.default_rng(n)
    # adversarial: duplicate points
    x = np.repeat(rng.normal(size=(max(n // 3, 1), 4)), 3, axis=0)[:n]
    x = x.astype(np.float32)
    c, a = kmeans.split_two(x, seed=0)
    assert set(np.unique(a).tolist()) == {0, 1}
    assert c.shape == (2, 4)


@given(st.floats(0, 1e5), st.floats(0, 1e5))
@SET
def test_latency_model_monotone(s1, s2):
    lam = LatencyModel()
    lo, hi = sorted([s1, s2])
    assert lam(lo) <= lam(hi) + 1e-9


@given(st.integers(1, 8), st.integers(1, 16), st.integers(2, 50),
       st.integers(0, 10**6))
@SET
def test_embedding_bag_matches_onehot(b, bag, vocab, seed):
    rng = np.random.default_rng(seed)
    table = jnp.asarray(rng.normal(size=(vocab, 6)), jnp.float32)
    ids = jnp.asarray(rng.integers(0, vocab, size=(b, bag)))
    valid = jnp.asarray(rng.random((b, bag)) < 0.8)
    got = embedding_bag(table, ids, mode="sum", valid=valid)
    onehot = jax.nn.one_hot(ids, vocab) * valid[..., None]
    want = jnp.einsum("bnv,vd->bd", onehot, table)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


@given(st.integers(1, 6), st.integers(0, 10**6))
@SET
def test_topk_accumulator(k, seed):
    rng = np.random.default_rng(seed)
    heap = aps.TopK(k)
    all_d, all_i = [], []
    for _ in range(3):
        d = rng.normal(size=rng.integers(0, 7))
        i = rng.integers(0, 10**6, size=len(d))
        heap.update(d, i)
        all_d.extend(d.tolist())
        all_i.extend(i.tolist())
    want = np.sort(np.asarray(all_d))[:k] if all_d else []
    got = heap.dists[np.isfinite(heap.dists)]
    np.testing.assert_allclose(got, want[:len(got)], rtol=1e-9)


@given(st.integers(2, 10), st.integers(1, 6), st.integers(1, 8),
       st.integers(0, 10**6))
@SET
def test_scan_selected_subset_of_full(p, b, u, seed):
    """Indexed scan over a selection == full scan restricted to the union:
    every returned id belongs to a selected partition the query asked for,
    and distances match the brute-force oracle over that subset."""
    from repro.kernels import ref as kref
    rng = np.random.default_rng(seed)
    s, d, k = 16, 8, 5
    u = min(u, p)
    data = jnp.asarray(rng.normal(size=(p, s, d)), jnp.float32)
    valid = jnp.ones((p, s), bool)
    sel = jnp.asarray(rng.choice(p, u, replace=False).astype(np.int32))
    qmask = jnp.asarray(rng.random((b, u)) < 0.7)
    qs = jnp.asarray(rng.normal(size=(b, d)), jnp.float32)
    dd, ii = kref.scan_selected_ref(qs, data, valid, sel, qmask, k, "l2")
    dd, ii = np.asarray(dd), np.asarray(ii)
    sel_np, qm = np.asarray(sel), np.asarray(qmask)
    for r in range(b):
        allowed = {int(pp) * s + j for ui, pp in enumerate(sel_np)
                   if qm[r, ui] for j in range(s)}
        got = ii[r][ii[r] >= 0]
        assert set(got.tolist()) <= allowed
        # brute-force the allowed subset
        if allowed:
            flat = np.asarray(data).reshape(p * s, d)
            q = np.asarray(qs[r])
            al = np.asarray(sorted(allowed))
            dist = ((flat[al] - q) ** 2).sum(1)
            want = np.sort(dist)[:min(k, len(al))]
            have = dd[r][dd[r] < 1e37]
            np.testing.assert_allclose(np.sort(have), want[:len(have)],
                                       rtol=1e-4, atol=1e-4)
