"""Snapshot coherence under streaming mutations (paper §8.2 COW serving).

The batched executor serves searches from a cached device snapshot while
the index mutates; the mutation journal tells it *which partitions*
changed so it patches only those rows (``IndexSnapshot.apply_delta``)
instead of rebuilding the full ``(P, S_cap, d)`` tensor.  These tests pin
the coherence contract: delta-refreshed results must be exactly the
results a fresh full rebuild would produce, under any interleaving of
``insert`` / ``delete`` / ``Maintainer.run`` with ``search_batch``, for
both metrics — and every fallback edge (structural change, capacity
overflow, trimmed journal, lossy truncation, empty batch) must stay safe.
"""
import numpy as np
import pytest

from repro.core import (IndexSnapshot, Maintainer, MutationJournal,
                        QuakeConfig, QuakeIndex)
from repro.core.multiquery import (BatchedSearchExecutor, batch_search,
                                   get_executor, plan_batch)
from repro.data import datasets


# ---------------------------------------------------------------------------
# journal unit semantics
# ---------------------------------------------------------------------------

def test_journal_records_and_folds():
    j = MutationJournal()
    assert j.delta_since(0).empty
    j.record(dirty=[3, 5], reason="insert")
    j.record(dirty=[5, 7], reason="delete")
    d = j.delta_since(0)
    assert d.dirty == {3, 5, 7} and not d.structural
    assert j.delta_since(1).dirty == {5, 7}
    j.record(structural=True, reason="split")
    assert j.delta_since(0).structural
    assert j.delta_since(j.version).empty


def test_journal_trim_floor_forces_rebuild():
    j = MutationJournal(max_entries=2)
    for i in range(5):
        j.record(dirty=[i])
    assert j.delta_since(0) is None          # history lost -> full rebuild
    assert j.delta_since(j.version - 2).dirty == {3, 4}


def test_index_mutations_feed_journal():
    ds = datasets.clustered(1000, 8, n_clusters=8, seed=0)
    idx = QuakeIndex.build(ds.vectors, num_partitions=8, kmeans_iters=2)
    v0 = idx.version
    idx.insert(ds.vectors[:3] + 0.01, np.arange(10_000, 10_003))
    d = idx.journal.delta_since(v0)
    assert d.dirty and not d.structural
    idx.delete(np.arange(10_000, 10_003))
    d2 = idx.journal.delta_since(v0)
    assert d2.dirty >= d.dirty
    # deleting unknown ids is a no-op: no journal entry, no invalidation
    v = idx.version
    assert idx.delete(np.asarray([999_999])) == 0
    assert idx.version == v


# ---------------------------------------------------------------------------
# delta refresh == full rebuild (the coherence contract)
# ---------------------------------------------------------------------------

def _assert_matches_fresh_rebuild(idx, q, k, nprobe):
    """Cached (possibly delta-patched) executor vs a brand-new executor
    that full-rebuilds from the live index: identical results."""
    r_delta = batch_search(idx, q, k, nprobe=nprobe, impl="jnp")
    fresh = BatchedSearchExecutor(idx, impl="jnp")
    r_full = fresh.search(q, k, nprobe=nprobe)
    assert fresh.full_rebuilds == 1 and fresh.delta_refreshes == 0
    np.testing.assert_array_equal(np.sort(r_delta.ids, 1),
                                  np.sort(r_full.ids, 1))
    np.testing.assert_array_equal(np.sort(r_delta.dists, 1),
                                  np.sort(r_full.dists, 1))
    return r_delta


def _brute_force(idx, q, k):
    """Exact top-k over the live index contents (minimization dists)."""
    lvl0 = idx.levels[0]
    x = np.concatenate(lvl0.vectors)
    ids = np.concatenate(lvl0.ids)
    if idx.config.metric == "l2":
        d = (np.sum(x * x, 1)[None, :] + np.sum(q * q, 1)[:, None]
             - 2.0 * (q @ x.T))
    else:
        d = -(q @ x.T)
    order = np.argsort(d, axis=1, kind="stable")[:, :k]
    return ids[order], np.take_along_axis(d, order, axis=1)


@pytest.mark.parametrize("metric", ["l2", "ip"])
def test_insert_delta_matches_full_rebuild(metric):
    ds = datasets.clustered(3000, 16, n_clusters=12, seed=1)
    idx = QuakeIndex.build(ds.vectors, num_partitions=24, kmeans_iters=3,
                           config=QuakeConfig(metric=metric))
    q = datasets.queries_near(ds, 16, seed=2)
    batch_search(idx, q, 10, nprobe=6, impl="jnp")      # build snapshot
    ex = get_executor(idx)
    assert ex.full_rebuilds == 1
    idx.insert(q * 0.999, np.arange(50_000, 50_000 + len(q)))
    r = _assert_matches_fresh_rebuild(idx, q, 10, nprobe=6)
    assert ex.delta_refreshes == 1 and ex.full_rebuilds == 1
    # fresh inserts are visible through the patched rows
    assert set(r.ids.ravel().tolist()) & set(range(50_000, 50_016))


@pytest.mark.parametrize("metric", ["l2", "ip"])
def test_interleaved_stream_coherence(metric):
    """insert / delete / maintenance interleaved with search_batch: the
    cached executor (delta path) must track a fresh rebuild exactly, and an
    all-partition scan must equal brute force over the live contents."""
    rng = np.random.default_rng(7)
    ds = datasets.clustered(3000, 16, n_clusters=12, seed=3)
    idx = QuakeIndex.build(ds.vectors, num_partitions=24, kmeans_iters=3,
                           config=QuakeConfig(metric=metric))
    maint = Maintainer(idx)
    q = datasets.queries_near(ds, 8, seed=4)
    next_id = 100_000
    live = []
    batch_search(idx, q, 10, nprobe=idx.num_partitions, impl="jnp")
    ex = get_executor(idx)
    for step in range(6):
        op = step % 3
        if op == 0:                       # insert a small batch
            xb = (datasets.queries_near(ds, 12, seed=10 + step)
                  + rng.normal(scale=0.01, size=(12, 16))).astype(np.float32)
            new = np.arange(next_id, next_id + 12)
            idx.insert(xb, new)
            live.extend(new.tolist())
            next_id += 12
        elif op == 1:                     # delete some of them
            drop = live[: len(live) // 2]
            idx.delete(np.asarray(drop, dtype=np.int64))
            live = live[len(live) // 2:]
        else:                             # maintenance (may split/merge)
            for row in q:
                idx.search(row, 10)
            maint.run()
            idx.check_invariants()
        nprobe = idx.num_partitions       # exact scan -> brute-force oracle
        r = _assert_matches_fresh_rebuild(idx, q, 10, nprobe=nprobe)
        gt_ids, gt_d = _brute_force(idx, q, 10)
        np.testing.assert_allclose(np.sort(r.dists, 1), np.sort(gt_d, 1),
                                   rtol=1e-3, atol=1e-3)
        rec = np.mean([len(set(r.ids[i]) & set(gt_ids[i])) / 10
                       for i in range(len(q))])
        assert rec >= 0.99, (step, rec)
    # the stream must have run mostly on the cheap path: every insert /
    # delete step refreshes by patching, never by rebuilding
    assert ex.delta_refreshes >= 2, ex.delta_refreshes
    assert ex.full_rebuilds >= 1, ex.full_rebuilds


def test_structural_change_falls_back_to_rebuild():
    ds = datasets.clustered(2000, 8, n_clusters=8, seed=5)
    idx = QuakeIndex.build(ds.vectors, num_partitions=8, kmeans_iters=2)
    q = datasets.queries_near(ds, 4, seed=6)
    batch_search(idx, q, 5, nprobe=4)
    ex = get_executor(idx)
    idx.journal.record(structural=True, reason="test")
    batch_search(idx, q, 5, nprobe=4)
    assert ex.full_rebuilds == 2 and ex.delta_refreshes == 0


def test_capacity_overflow_falls_back_to_rebuild():
    ds = datasets.clustered(2000, 8, n_clusters=8, seed=8)
    idx = QuakeIndex.build(ds.vectors, num_partitions=8, kmeans_iters=2)
    q = datasets.queries_near(ds, 4, seed=9)
    batch_search(idx, q, 5, nprobe=4)
    ex = get_executor(idx)
    cap = ex._snap.capacity
    # overflow one partition past the slack capacity
    j = int(np.argmax([len(v) for v in idx.levels[0].vectors]))
    c = idx.levels[0].centroids[j]
    n_extra = cap  # certainly exceeds remaining slack
    xb = (c[None, :] + np.zeros((n_extra, idx.dim), np.float32))
    idx.insert(xb, np.arange(200_000, 200_000 + n_extra))
    r = batch_search(idx, q, 5, nprobe=idx.num_partitions, impl="jnp")
    assert ex.full_rebuilds == 2 and ex.delta_refreshes == 0
    assert ex._snap.capacity > cap
    gt_ids, gt_d = _brute_force(idx, np.asarray(q, np.float32), 5)
    np.testing.assert_allclose(np.sort(r.dists, 1), np.sort(gt_d, 1),
                               rtol=1e-3, atol=1e-3)


def test_dirty_fraction_threshold_forces_rebuild():
    ds = datasets.clustered(2000, 8, n_clusters=8, seed=10)
    idx = QuakeIndex.build(ds.vectors, num_partitions=16, kmeans_iters=2)
    q = datasets.queries_near(ds, 4, seed=11)
    ex = BatchedSearchExecutor(idx, impl="jnp", max_dirty_frac=0.1)
    ex.search(q, 5, nprobe=4)
    # touch every partition: way past the 10% delta threshold
    idx.insert(ds.vectors[:500] + 0.01, np.arange(300_000, 300_500))
    ex.search(q, 5, nprobe=4)
    assert ex.full_rebuilds == 2 and ex.delta_refreshes == 0


def test_journal_trim_forces_executor_rebuild():
    ds = datasets.clustered(1500, 8, n_clusters=8, seed=12)
    idx = QuakeIndex.build(ds.vectors, num_partitions=8, kmeans_iters=2)
    idx.journal.max_entries = 2
    q = datasets.queries_near(ds, 4, seed=13)
    batch_search(idx, q, 5, nprobe=4)
    ex = get_executor(idx)
    for i in range(5):                 # > max_entries mutations
        idx.insert(ds.vectors[i:i + 1] + 0.01, np.asarray([400_000 + i]))
    batch_search(idx, q, 5, nprobe=4)
    assert ex.full_rebuilds == 2 and ex.delta_refreshes == 0


# ---------------------------------------------------------------------------
# from_index truncation bugfix
# ---------------------------------------------------------------------------

def test_from_index_lossy_truncation_raises():
    ds = datasets.clustered(1500, 8, n_clusters=8, seed=14)
    idx = QuakeIndex.build(ds.vectors, num_partitions=8, kmeans_iters=2)
    with pytest.raises(ValueError, match="truncate"):
        IndexSnapshot.from_index(idx, capacity=8)


def test_from_index_truncation_clamps_sizes():
    ds = datasets.clustered(1500, 8, n_clusters=8, seed=15)
    idx = QuakeIndex.build(ds.vectors, num_partitions=8, kmeans_iters=2)
    snap = IndexSnapshot.from_index(idx, capacity=8, allow_truncation=True)
    sizes = np.asarray(snap.sizes)
    stored = np.asarray(snap.ids >= 0).sum(axis=1)
    np.testing.assert_array_equal(sizes, stored)   # sizes == valid mask
    assert sizes.max() <= snap.capacity


def test_from_index_headroom_pads_capacity():
    ds = datasets.clustered(1500, 8, n_clusters=8, seed=16)
    idx = QuakeIndex.build(ds.vectors, num_partitions=8, kmeans_iters=2)
    base = IndexSnapshot.from_index(idx)
    padded = IndexSnapshot.from_index(idx, headroom=2.0)
    assert padded.capacity >= base.capacity
    max_size = int(max(len(v) for v in idx.levels[0].vectors))
    assert padded.capacity >= 2 * max_size * 0.99


# ---------------------------------------------------------------------------
# empty batch (plan_batch IndexError bugfix)
# ---------------------------------------------------------------------------

def test_empty_batch_returns_empty_result():
    ds = datasets.clustered(1000, 8, n_clusters=8, seed=17)
    idx = QuakeIndex.build(ds.vectors, num_partitions=8, kmeans_iters=2)
    q0 = np.zeros((0, 8), dtype=np.float32)
    r = batch_search(idx, q0, 5, nprobe=4)
    assert r.ids.shape == (0, 5) and r.dists.shape == (0, 5)
    assert r.partitions_scanned == 0 and r.vectors_scanned == 0
    plan = plan_batch(idx, q0, 5, nprobe=4)
    assert plan.n_real == 0 and plan.qmask.shape[0] == 0
    assert len(plan.nprobe) == 0
