"""Observability stack (repro.obs + the serving hooks).

Pins the subsystem's contracts:

  * registry semantics — counters/gauges/histograms under one innermost
    lock, log-bucketed percentiles within the documented ~4.4% relative
    error, the lazy-fold pending buffer invisible to readers, and the
    batched ``update`` path equivalent to per-sample recording;
  * ``summarize`` — the repo's one shared percentile path matches
    ``numpy.percentile`` (linear interpolation) exactly;
  * the pinned ``round_trace`` schema (docs/observability.md) that the
    serving trace emitter and benchmarks/common rely on;
  * ``metrics_snapshot()`` golden dotted names, and the zero-observer
    guarantee: ``ServingConfig(metrics=False)`` yields byte-identical
    results, including under admission-log replay;
  * trace spans — compact terminal records expand to full
    admit -> flush -> round* -> done event lists; cache hits and shed
    queries get single-instant spans; ring eviction is accounted.
"""
import json
import threading

import numpy as np
import pytest

from repro.core import (QuakeConfig, QuakeIndex, ServingConfig,
                        ServingRuntime)
from repro.core.serving import STATUS_OK, STATUS_SHED
from repro.data import datasets
from repro.obs import (CalibrationTracker, Histogram, MetricsRegistry,
                       QueryTracer, summarize, to_prometheus)
from repro.obs.tracing import DONE_FIELDS


@pytest.fixture(scope="module")
def ds():
    return datasets.clustered(4000, 16, n_clusters=16, seed=0)


def build(ds, **cfg):
    return QuakeIndex.build(ds.vectors, num_partitions=32, kmeans_iters=4,
                            config=QuakeConfig(**cfg))


def serve_cfg(**kw):
    kw.setdefault("k", 10)
    kw.setdefault("flush_size", 8)
    kw.setdefault("scan_backend", "host")
    kw.setdefault("maint_min_ops", 10 ** 9)
    return ServingConfig(**kw)


# ---------------------------------------------------------------------------
# MetricsRegistry
# ---------------------------------------------------------------------------

def test_registry_counters_gauges_histograms():
    reg = MetricsRegistry()
    reg.inc("a.count")
    reg.inc("a.count", 4)
    reg.set_gauge("a.gauge", 2.5)
    reg.set_gauge("a.gauge", 1.5)          # last write wins
    for v in (0.001, 0.002, 0.003):
        reg.observe("a.lat", v)
    assert reg.counter("a.count") == 5
    assert reg.counter("missing") == 0
    assert reg.gauge("a.gauge") == 1.5
    snap = reg.histogram("a.lat")
    assert snap["count"] == 3
    assert snap["min"] == 0.001 and snap["max"] == 0.003
    assert snap["sum"] == pytest.approx(0.006)
    # unknown histogram reads as the empty snapshot, not an error
    assert reg.histogram("missing")["count"] == 0
    flat = reg.snapshot()
    assert flat["a.count"] == 5
    assert flat["a.gauge"] == 1.5
    assert flat["a.lat.count"] == 3


def test_registry_update_batch_equivalent():
    """The batched hot-path entry point records exactly what the
    per-sample calls would."""
    a, b = MetricsRegistry(), MetricsRegistry()
    vals = [0.01, 0.02, 0.05, 0.1]
    a.update(counters={"c": 3}, gauges={"g": 7.0},
             observations={"h": vals})
    b.inc("c", 3)
    b.set_gauge("g", 7.0)
    for v in vals:
        b.observe("h", v)
    assert a.snapshot() == b.snapshot()


def test_histogram_percentile_accuracy():
    """Log buckets at 8/octave: every reported percentile within the
    documented ~4.4% relative error of the exact order statistic, and
    clamped to the exact observed [min, max]."""
    rng = np.random.default_rng(0)
    xs = rng.lognormal(mean=-7.0, sigma=1.0, size=5000)
    h = Histogram()
    h.observe_many(xs)
    for q in (0.5, 0.9, 0.95, 0.99):
        exact = float(np.percentile(xs, q * 100))
        got = h.percentile(q)
        assert abs(got - exact) / exact <= 0.045, (q, got, exact)
    snap = h.snapshot()
    assert snap["min"] == float(xs.min())
    assert snap["max"] == float(xs.max())
    # single observation: envelope clamping makes the snapshot exact
    h1 = Histogram()
    h1.observe(0.0123)
    s1 = h1.snapshot()
    assert s1["p50"] == s1["p99"] == s1["min"] == s1["max"] == 0.0123


def test_histogram_lazy_fold():
    """Recording only appends to the pending buffer; folds happen at the
    _FOLD_AT threshold and on any read — never visible to readers."""
    h = Histogram()
    h.observe(0.5)
    assert h.count == 0 and len(h._pending) == 1     # not folded yet
    assert h.snapshot()["count"] == 1                # read folds
    assert not h._pending
    h.observe_many([0.1] * (Histogram._FOLD_AT - 1))
    assert h._pending                                 # below threshold
    h.observe(0.1)                                    # hits _FOLD_AT
    assert not h._pending and h.count == 1 + Histogram._FOLD_AT
    # non-finite samples are discarded at fold time
    h2 = Histogram()
    h2.observe_many([1.0, float("nan"), float("inf"), 2.0])
    assert h2.snapshot()["count"] == 2


def test_registry_thread_safety_smoke():
    reg = MetricsRegistry()
    n_threads, per = 8, 500

    def worker(t):
        for i in range(per):
            reg.update(counters={"hits": 1},
                       observations={"lat": (float(i + 1) * 1e-6,)})

    threads = [threading.Thread(target=worker, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert reg.counter("hits") == n_threads * per
    assert reg.histogram("lat")["count"] == n_threads * per


# ---------------------------------------------------------------------------
# summarize — the shared percentile path
# ---------------------------------------------------------------------------

def test_summarize_matches_numpy_percentile():
    rng = np.random.default_rng(1)
    xs = rng.random(257)
    s = summarize(xs)
    assert s["count"] == 257
    assert s["min"] == float(xs.min()) and s["max"] == float(xs.max())
    assert s["mean"] == pytest.approx(float(xs.mean()))
    for key, q in (("p50", 50), ("p95", 95), ("p99", 99)):
        assert s[key] == pytest.approx(float(np.percentile(xs, q)))


def test_summarize_edge_cases():
    empty = summarize([])
    assert empty["count"] == 0 and empty["p99"] == 0.0
    one = summarize([0.25])
    assert one["p50"] == one["p99"] == one["min"] == one["max"] == 0.25


def test_to_prometheus_exposition():
    text = to_prometheus({"a.b": 1, "lat.p50": 0.5, "flag": True,
                          "skip_nan": float("nan"), "skip_str": "x"})
    lines = text.strip().split("\n")
    assert "quake_a_b 1" in lines
    assert "quake_lat_p50 0.5" in lines
    assert "quake_flag 1" in lines                  # bool -> 0/1
    assert not any("skip" in ln for ln in lines)    # nan/str dropped
    assert text.endswith("\n")


# ---------------------------------------------------------------------------
# round_trace — the pinned per-round schema
# ---------------------------------------------------------------------------

ROUND_TRACE_KEYS = {"round_live", "round_partitions", "round_vectors",
                    "round_comparisons", "round_kth", "round_wall_s",
                    "budget_expired", "timed_out_rows"}


def test_round_trace_pinned_schema(ds):
    """docs/observability.md pins exactly these keys; the serving trace
    emitter and benchmarks/common.round_trajectory both rely on them."""
    idx = build(ds)
    q = datasets.queries_near(ds, 24, seed=30)
    r = idx.search_batch(q, 10, recall_target=0.9)
    tr = r.round_trace
    assert tr is not None
    assert set(tr.keys()) == ROUND_TRACE_KEYS
    assert r.rounds >= 1
    for key in ("round_live", "round_partitions", "round_vectors",
                "round_comparisons", "round_kth", "round_wall_s"):
        assert len(tr[key]) == r.rounds, key
    assert isinstance(tr["budget_expired"], bool)
    assert isinstance(tr["timed_out_rows"], int)
    assert tr["round_live"][0] == len(q)
    assert all(w >= 0.0 for w in tr["round_wall_s"])
    assert sum(tr["round_vectors"]) == r.vectors_scanned


# ---------------------------------------------------------------------------
# metrics_snapshot — golden dotted names
# ---------------------------------------------------------------------------

GOLDEN_KEYS = (
    # serving front-end
    "serving.queries_submitted", "serving.queries_completed",
    "serving.flushes", "serving.in_flight", "serving.queue_depth",
    "serving.write_ops", "serving.cache_hits", "serving.queries_shed",
    "serving.status.OK", "serving.status.PARTIAL",
    "serving.status.SHED", "serving.status.FAILED",
    "serving.governor.steps",
    # latency histograms (registry-backed)
    "serving.latency_s.count", "serving.latency_s.p50",
    "serving.latency_s.p95", "serving.latency_s.p99",
    "serving.queue_wait_s.count", "serving.queue_wait_s.p50",
    # scheduler
    "scheduler.rounds", "scheduler.partitions_streamed",
    "scheduler.vectors_streamed", "scheduler.round_wall_s.count",
    "scheduler.round_wall_s.p50",
    # calibration (LatencyModel predicted vs observed)
    "calibration.latency.samples", "calibration.latency.rel_err",
    "calibration.latency.predicted_s.p50",
    "calibration.latency.observed_s.p50",
    # tracer
    "trace.emitted", "trace.dropped", "trace.completed",
    "trace.flushes_tracked", "trace.rounds_tracked",
    # maintenance + sanitizer bridge
    "maintenance.runs", "sanitize.acquisitions",
    "sanitize.order_violations", "sanitize.guarded_violations",
)


def test_metrics_snapshot_golden_keys(ds):
    rt = ServingRuntime(build(ds), serve_cfg())
    q = datasets.queries_near(ds, 40, seed=31)
    rt.submit_batch(q)
    rt.submit_insert(ds.vectors[:5] + 0.01, np.arange(90_000, 90_005))
    rt.drain()
    ms = rt.metrics_snapshot()
    missing = [k for k in GOLDEN_KEYS if k not in ms]
    assert not missing, missing
    assert ms["serving.queries_submitted"] == 40
    assert ms["serving.latency_s.count"] == 40
    assert ms["trace.completed"] == 40
    assert ms["scheduler.rounds"] >= 1
    assert ms["calibration.latency.samples"] >= 1
    # numbers only: renderable straight to Prometheus text
    assert all(isinstance(v, (int, float)) for v in ms.values())
    text = to_prometheus(ms)
    assert "quake_serving_latency_s_p50" in text
    # snapshots never lag in-flight rounds: a second drain-free read
    # still balances submitted == completed
    assert ms["serving.queries_completed"] >= ms["serving.queries_submitted"]


def test_metrics_off_byte_identical(ds):
    """metrics=False leaves rt.obs None; every result is byte-identical
    to the metrics-on run of the same operation stream."""
    q = datasets.queries_near(ds, 32, seed=32).astype(np.float32)
    ins = ds.vectors[:8] + 0.01

    def run(metrics):
        rt = ServingRuntime(build(ds), serve_cfg(metrics=metrics))
        qa = rt.submit_batch(q[:20])
        rt.submit_insert(ins, np.arange(91_000, 91_008))
        qb = rt.submit_batch(q[20:])
        rt.drain()
        return rt, [rt.result(i) for i in qa + qb]

    rt_on, res_on = run(True)
    rt_off, res_off = run(False)
    assert rt_on.obs is not None and rt_off.obs is None
    for a, b in zip(res_on, res_off):
        assert a.ids.tobytes() == b.ids.tobytes()
        assert a.dists.tobytes() == b.dists.tobytes()
        assert a.status == b.status and a.nprobe == b.nprobe
    # the snapshot still works without the registry: stats-only keys
    ms_off = rt_off.metrics_snapshot()
    assert "serving.queries_submitted" in ms_off
    assert "trace.emitted" not in ms_off


def test_metrics_off_admission_replay_identical(ds):
    """A metrics-on run's admission log, replayed on a metrics-off twin,
    reproduces every per-query result byte-for-byte — the observability
    layer is a pure observer even of admission ordering."""
    q = datasets.queries_near(ds, 30, seed=33).astype(np.float32)
    rt = ServingRuntime(build(ds), serve_cfg(flush_size=4,
                                             record_admissions=True))
    qvec = {}
    for i, row in enumerate(q):
        qid = rt.submit_query(row)
        qvec[qid] = row
        if i == 10:
            rt.submit_insert(ds.vectors[:3] + 0.02,
                             np.arange(92_000, 92_003))
    rt.drain()
    log = rt.admission_log()
    ref = {qid: rt.result(qid) for qid in qvec}

    rt2 = ServingRuntime(build(ds), serve_cfg(flush_size=10 ** 9,
                                              metrics=False))
    pairs = []
    for entry in log:
        if entry[0] == "q":
            for qid in entry[1]:
                pairs.append((qid, rt2.submit_query(qvec[qid])))
            rt2.flush()
        elif entry[0] == "insert":
            rt2.submit_insert(entry[1], entry[2])
        else:
            rt2.submit_delete(entry[1])
    rt2.drain()
    assert pairs
    for orig, rep in pairs:
        got = rt2.result(rep)
        assert ref[orig].ids.tobytes() == got.ids.tobytes()
        assert ref[orig].dists.tobytes() == got.dists.tobytes()


# ---------------------------------------------------------------------------
# trace spans
# ---------------------------------------------------------------------------

def test_trace_span_synthesis(ds, tmp_path):
    """Compact terminal records expand to ordered
    admit -> flush -> round* -> done event lists with non-decreasing
    timestamps, and dump_jsonl round-trips them as JSON-lines."""
    rt = ServingRuntime(build(ds), serve_cfg(flush_size=8))
    q = datasets.queries_near(ds, 16, seed=34)
    qids = rt.submit_batch(q)
    rt.drain()
    spans = rt.obs.tracer.spans()
    by_qid = {s["qid"]: s for s in spans if "qid" in s}
    assert set(qids) <= set(by_qid)
    saw_round = False
    for qid in qids:
        s = by_qid[qid]
        assert s["status"] == STATUS_OK
        names = [e["e"] for e in s["events"]]
        assert names[0] == "admit" and names[-1] == "done"
        assert "flush" in names
        assert names.index("flush") == 1            # right after admit
        saw_round |= "round" in names
        ts = [e["t"] for e in s["events"]]
        assert ts == sorted(ts)                     # non-decreasing
        done = s["events"][-1]
        assert done["status"] == STATUS_OK
        assert done["latency_s"] >= 0.0
        assert done["rounds"] >= 1
        for e in s["events"]:
            if e["e"] == "round":
                assert e["partitions"] >= 1 and e["wall_s"] >= 0.0
    assert saw_round                               # rounds joined back in
    out = tmp_path / "trace.jsonl"
    n = rt.obs.tracer.dump_jsonl(str(out))
    lines = out.read_text().strip().split("\n")
    assert n == len(lines) == len(spans)
    parsed = [json.loads(ln) for ln in lines]
    assert {p["qid"] for p in parsed if "qid" in p} >= set(qids)


def test_trace_cache_hit_span(ds):
    rt = ServingRuntime(build(ds), serve_cfg(flush_size=1,
                                             cache_entries=64))
    q = datasets.queries_near(ds, 1, seed=35)[0]
    rt.submit_query(q)
    rt.drain()
    hit = rt.submit_query(q)                       # identical repeat
    rt.drain()
    assert rt.stats()["cache_hits"] == 1
    span = {s["qid"]: s for s in rt.obs.tracer.spans()
            if "qid" in s}[hit]
    names = [e["e"] for e in span["events"]]
    assert names == ["admit", "cache_hit", "done"]
    assert span["events"][-1]["cache"] is True
    assert span["status"] == STATUS_OK


def test_trace_shed_span(ds):
    rt = ServingRuntime(build(ds), serve_cfg(
        flush_size=10 ** 9, queue_cap=2, queue_policy="shed-newest"))
    q = datasets.queries_near(ds, 4, seed=36)
    qids = [rt.submit_query(row) for row in q]
    shed = [i for i in qids
            if rt.result(i) is not None
            and rt.result(i).status == STATUS_SHED]
    assert shed                                     # cap 2 -> rows 3,4 shed
    spans = {s["qid"]: s for s in rt.obs.tracer.spans() if "qid" in s}
    for qid in shed:
        names = [e["e"] for e in spans[qid]["events"]]
        assert names == ["admit", "done"]
        assert spans[qid]["status"] == STATUS_SHED
    rt.drain()


def test_tracer_ring_eviction_accounting():
    assert DONE_FIELDS == ("qid", "t", "status", "rounds", "nprobe",
                           "recall_estimate", "latency_s", "t_submit",
                           "batch")
    tr = QueryTracer(capacity=4)
    recs = [(qid, 1.0, STATUS_OK, 1, 4, 0.95, 0.001, 0.0, 0)
            for qid in range(10)]
    tr.close_many(recs)
    c = tr.counters()
    assert c["emitted"] == 10 and c["dropped"] == 6 and c["completed"] == 4
    # survivors are the newest four, expanded on read
    assert [s["qid"] for s in tr.spans()] == [6, 7, 8, 9]
    tr.audit("maintenance", {"action": "split", "partition": 3})
    audits = [s for s in tr.spans() if s.get("audit")]
    assert audits and audits[0]["action"] == "split"


# ---------------------------------------------------------------------------
# calibration tracker
# ---------------------------------------------------------------------------

class _FakeLam:
    def predict_scan_ns(self, sizes):
        return float(sum(sizes)) * 100.0


def test_calibration_latency_and_recall():
    reg = MetricsRegistry()
    cal = CalibrationTracker(reg, lam=_FakeLam(), window=4)
    assert cal.latency_error() is None and cal.recall_error() is None
    # predicted = 3000 * 100 ns = 0.3 ms vs observed 0.6 ms -> rel 0.5
    cal.record_scan([1000, 2000], 0.0006)
    assert cal.latency_error() == pytest.approx(0.5)
    cal.record_scan([1000, 2000], 0.0003)          # exact -> rel 0.0
    assert cal.latency_error() == pytest.approx(0.25)
    cal.record_recall(0.95, 0.90)
    cal.record_recall(0.85, 0.90)
    assert cal.recall_error() == pytest.approx(0.05)
    flat = reg.snapshot()
    assert flat["calibration.latency.samples"] == 2
    assert flat["calibration.latency.rel_err"] == pytest.approx(0.25)
    assert flat["calibration.recall.samples"] == 2
    assert flat["calibration.recall.abs_err"] == pytest.approx(0.05)
    # non-finite and non-positive samples are discarded, not recorded
    cal.record_scan([10], 0.0)
    cal.record_recall(float("nan"), 0.9)
    assert reg.counter("calibration.latency.samples") == 2
    assert reg.counter("calibration.recall.samples") == 2


def test_calibration_without_model_is_inert():
    reg = MetricsRegistry()
    cal = CalibrationTracker(reg, lam=None)
    cal.record_scan([100], 0.001)
    assert cal.latency_error() is None
    assert reg.counter("calibration.latency.samples") == 0
