"""Seeded QK100 violation: allow-sync pragma without a reason (an
undocumented suppression is itself a finding)."""
import numpy as np
import jax.numpy as jnp


def hot_path(q):  # quakecheck: device-path
    d = jnp.sum(q)
    return np.asarray(d)  # quakecheck: allow-sync()
