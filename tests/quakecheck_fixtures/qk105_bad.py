"""Seeded QK105 violations: guarded scheduler state mutated from outside
the owning class (bypasses the write-barrier discipline)."""


class RuntimeBad:
    def __init__(self, scheduler):
        self.scheduler = scheduler

    def collect(self):
        out = list(self.scheduler.done)
        self.scheduler.done.clear()     # QK105: cross-object mutation
        self.scheduler.active = []      # QK105: cross-object write
        return out
