"""Seeded QK102 violations: data-dependent static arg without a bucket,
jit constructed inside a loop, immediately-invoked jit."""
import functools

import jax


@functools.partial(jax.jit, static_argnames=("n",))
def pad_scan_bad(x, *, n):
    return x[:n]


def caller_bad(xs, counts):
    n = int(counts.max())        # data-dependent, never bucketed
    out = pad_scan_bad(xs, n=n)  # QK102: fragments the jit cache
    y = xs
    for _ in range(3):
        y = jax.jit(lambda a: a + 1)(y)   # QK102: jit built per iteration
    return out, y
