"""QK202-clean twin: locks nest in the declared order (outermost
first), and reentrant re-acquisition of a held lock is not an
inversion."""


class ServingRuntime:
    def __init__(self, cache):
        self._lock = object()
        self.cache = cache

    def ordered(self):
        with self._lock:
            with self.cache._lock:      # admission -> cache: declared order
                pass

    def reentrant(self):
        with self._lock:
            with self._lock:            # RLock re-entry, not an inversion
                pass
