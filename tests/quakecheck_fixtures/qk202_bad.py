"""Seeded QK202 violation: lock acquisition inverting the declared
partial order (admission lock taken while holding the cache lock —
a deadlock waiting for the opposite interleaving)."""


class ServingRuntime:
    def __init__(self, cache):
        self._lock = object()
        self.cache = cache

    def inverted(self):
        with self.cache._lock:          # ResultCache._lock (inner rank)
            with self._lock:            # QK202: admission lock after it
                pass
