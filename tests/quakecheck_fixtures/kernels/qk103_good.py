"""QK103-clean (parse-only fixture): guarded launcher, int32-accumulated
int8 dot, f32-only kernel body."""
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


def _scale_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...].astype(jnp.float32) * 2.0


def launch_scale(x, block_q=8):
    b = x.shape[0]
    assert b % block_q == 0      # tile divisibility guard
    return pl.pallas_call(_scale_kernel, out_shape=x)(x)


def dot_q8(codes, cents, dn):
    return lax.dot_general(codes, cents, dn,
                           preferred_element_type=jnp.int32)
