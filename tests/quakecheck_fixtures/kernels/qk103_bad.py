"""Seeded QK103 violations (parse-only fixture; never imported): direct
pltpu compat-only name, launcher without a divisibility guard, int8 dot
without int32 accumulation, f64 inside a kernel body."""
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scale_kernel(x_ref, o_ref):
    acc = x_ref[...].astype(jnp.float64)   # QK103: f64 in kernel body
    o_ref[...] = acc.astype(jnp.float32)


def launch_scale(x):
    params = pltpu.TPUCompilerParams()     # QK103: bypass pallas_compat
    return pl.pallas_call(                 # QK103: no divisibility guard
        _scale_kernel, out_shape=x, compiler_params=params)(x)


def dot_q8(codes, cents):
    # QK103: int8 path accumulating in the operand dtype
    return jnp.einsum("bd,pd->bp", codes, cents)
