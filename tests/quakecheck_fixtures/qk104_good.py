"""QK104-clean: the donated name is rebound by the donating statement
itself, so every later read sees the new buffer."""
import jax

_scatter_good = jax.jit(lambda a, u: a.at[0].set(u), donate_argnums=(0,))


def update_good(buf, val):
    buf = _scatter_good(buf, val)   # same-statement rebind: safe
    return buf.sum()
