"""QK203-clean twin: the admission lock covers only bookkeeping; the
blocking flush runs after it drops, under the engine lock."""


class ServingRuntime:
    def __init__(self, scheduler):
        self._engine_lock = object()
        self._lock = object()
        self.scheduler = scheduler
        self._queue = []

    def submit(self, q):
        with self._lock:
            self._queue.append(q)
            do_flush = len(self._queue) >= 8
        if do_flush:
            with self._engine_lock:
                self.scheduler.drain()  # blocking work: engine scope
