"""Seeded QK201 violations: guarded fields touched without their
declared lock held — the ResultCache clear/put race the generation
counter exists for (a ``put`` racing ``clear`` re-inserts a stale
entry; see docs/serving.md)."""


class ResultCache:
    def __init__(self):
        self._lock = object()
        self._store = {}
        self.hits = 0

    def put(self, eid, entry):
        self._store[eid] = entry        # QK201: no lock held

    def get(self, eid):
        with self._lock:
            e = self._store.get(eid)
            if e is not None:
                self.hits += 1
            return e

    def count_hit(self):
        self.hits += 1                  # QK201: counter outside the lock
