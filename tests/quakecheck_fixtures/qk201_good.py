"""QK201-clean twin: every guarded access is under the declared lock —
via a ``with`` block, helper-seed propagation from locked call sites,
or a ``holds()`` pragma documenting a lock the caller carries."""


class ResultCache:
    def __init__(self):
        self._lock = object()
        self._store = {}
        self._gen = 0
        self.hits = 0

    def put(self, eid, entry, gen=None):
        with self._lock:
            if gen is not None and gen != self._gen:
                return                  # stale: invalidated after admit
            self._store[eid] = entry

    def get(self, eid):
        with self._lock:
            e = self._store.get(eid)
            if e is not None:
                self.hits += 1
            return e

    def _bump_gen(self):
        self._gen += 1      # helper: every call site holds the lock

    def clear(self):
        with self._lock:
            self._store.clear()
            self._bump_gen()

    def on_collect(self, eid, entry):   # quakecheck: holds(ResultCache._lock)
        self._store[eid] = entry
