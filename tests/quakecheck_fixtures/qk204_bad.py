"""Seeded QK204 violation: a guarded mutable field escapes its lock
scope — the returned alias is read (and mutated) after the lock drops,
so the lock protected nothing."""


class RoundScheduler:
    def __init__(self):
        self._lock = object()
        self.done = []

    def peek_done(self):
        with self._lock:
            return self.done            # QK204: alias outlives the lock
