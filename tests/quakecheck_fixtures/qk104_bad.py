"""Seeded QK104 violation: a donated operand is read after the call that
donated its buffer."""
import jax

_scatter_bad = jax.jit(lambda a, u: a.at[0].set(u), donate_argnums=(0,))


def update_bad(buf, val):
    out = _scatter_bad(buf, val)
    total = buf.sum()       # QK104: buf's buffer was donated above
    return out, total
