"""QK204-clean twin: hand out a snapshot, or transfer ownership by
rebinding the field before the reference leaves the lock scope."""


class RoundScheduler:
    def __init__(self):
        self._lock = object()
        self.done = []

    def peek_done(self):
        with self._lock:
            return list(self.done)      # snapshot, not an alias

    def take_done(self):
        with self._lock:
            out = self.done
            self.done = []              # ownership transfer by rebind
            return out
