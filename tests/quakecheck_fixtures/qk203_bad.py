"""Seeded QK203 violation: blocking engine work under the admission
lock — every concurrent submit_* caller stalls behind the scan."""


class ServingRuntime:
    def __init__(self, scheduler):
        self._lock = object()
        self.scheduler = scheduler
        self._queue = []

    def submit(self, q):
        with self._lock:
            self._queue.append(q)
            self.scheduler.drain()      # QK203: blocking under admission
