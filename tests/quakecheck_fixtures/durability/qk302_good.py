"""Known-good twin of qk302_bad.py: every durable write fsyncs before
closing, the manifest goes through temp + rename, a deliberate unsynced
write carries a reasoned allow-nosync pragma, and read-mode opens are
out of scope."""
import os


def append_record(path, frame):
    with open(path, "ab") as f:
        f.write(frame)
        f.flush()
        os.fsync(f.fileno())


def write_manifest(root, payload):
    tmp = os.path.join(root, ".tmp-MANIFEST.json")
    with open(tmp, "w") as f:
        f.write(payload)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, os.path.join(root, "MANIFEST.json"))


def tear_tail(path, size):
    # quakecheck: allow-nosync(test helper models post-crash disk state)
    with open(path, "r+b") as f:
        f.truncate(size)


def read_manifest(root):
    with open(os.path.join(root, "MANIFEST.json"), "r") as f:
        return f.read()
