"""Seeded QK302 violations: durability-path writes that skip the fsync
pairing and a manifest published in place instead of via temp+rename.
Three findings: the unsynced WAL append, and the in-place manifest open
(which is both unsynced and non-atomic)."""
import os


def append_record(path, frame):
    # unsynced append: the OS may still be buffering this when power cuts
    with open(path, "ab") as f:
        f.write(frame)


def write_manifest(root, payload):
    # in-place manifest write: a crash mid-write leaves a torn file that
    # recovery will select as the newest checkpoint (also unsynced)
    with open(os.path.join(root, "MANIFEST.json"), "w") as f:
        f.write(payload)
