"""Seeded QK101 violations: host syncs on device values inside a
device-resident function (registered via the device-path pragma)."""
import numpy as np
import jax.numpy as jnp


def hot_scan(q):  # quakecheck: device-path
    d = jnp.sum(q * q, axis=1)
    pulled = np.asarray(d)          # QK101: implicit device->host pull
    kth = float(d[0])               # QK101: concretizes a device value
    listed = d.tolist()             # QK101: .tolist() on a device value
    return pulled, kth, listed
