"""QK105-clean: the owner mutates its own state; consumers go through
the owner's hand-off API."""


class SchedulerGood:
    def __init__(self):
        self.done = []
        self.active = []

    def take_done(self):
        out = self.done
        self.done = []      # owner's prerogative
        return out


class RuntimeGood:
    def __init__(self, scheduler):
        self.scheduler = scheduler

    def collect(self):
        return self.scheduler.take_done()   # sanctioned API
