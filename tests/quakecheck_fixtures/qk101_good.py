"""QK101-clean: documented boundary pull + host-side helper."""
import numpy as np
import jax.numpy as jnp


def hot_scan(q):  # quakecheck: device-path
    d = jnp.sum(q * q, axis=1)
    # quakecheck: allow-sync(result boundary pull)
    out = np.asarray(d)
    return out


def host_helper(x):
    # not device-resident: plain numpy is fine here
    return np.asarray(x, dtype=np.float64)
