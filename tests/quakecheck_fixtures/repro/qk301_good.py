"""Known-good twin of qk301_bad.py: every handler either narrows the
catch, surfaces the failure (count / log / re-raise), or documents the
intentional drop with an allow-swallow pragma."""
import logging

logger = logging.getLogger("repro.fixture")


def tick_all(components, stats):
    for c in components:
        try:
            c.tick()
        except Exception as e:      # surfaced: counted and logged
            stats["tick_errors"] += 1
            logger.warning("tick failed: %r", e)


def load_snapshot(path):
    try:
        with open(path) as fh:
            return fh.read()
    except OSError:                 # narrow catch is fine
        return None


def cleanup(tmp):
    try:
        tmp.unlink()
    except Exception:  # quakecheck: allow-swallow(best-effort temp cleanup)
        pass


def guard(fn):
    try:
        return fn()
    except:                         # bare, but re-raises — not a swallow
        logger.exception("guarded call failed")
        raise
