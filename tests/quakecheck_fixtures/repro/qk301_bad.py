"""Seeded QK301 violations: runtime-path handlers that silently drop
exceptions — the failure never reaches a terminal status, a counter, or
a log line (docs/serving.md failure semantics)."""


def tick_all(components):
    for c in components:
        try:
            c.tick()
        except Exception:           # QK301: broad catch, body only drops
            pass


def load_snapshot(path):
    try:
        with open(path) as fh:
            return fh.read()
    except:                         # QK301: bare except, nothing re-raised
        return None


def poll(sources):
    out = []
    for s in sources:
        try:
            out.append(s.read())
        except (ValueError, BaseException):  # QK301: BaseException dropped
            continue
    return out
