"""Seeded-bad fixture for QK401: wall-clock reads and stdout writes in
a core runtime path.  Latency measured with ``time.time()`` shears under
NTP adjustment and is untestable under a fake clock, and ``print()``
from the serving hot path bypasses the metrics/trace layer."""
import time


def measure(scan):
    t0 = time.time()                     # QK401: wall clock
    scan()
    return time.time() - t0              # QK401: wall clock


def report(stats):
    print("rounds:", stats["rounds"])    # QK401: stdout from runtime path
