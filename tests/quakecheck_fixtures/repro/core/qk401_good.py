"""Known-good twin of qk401_bad.py: durations come from the injectable
monotonic clock, reporting goes through the metrics registry, and the
one legitimate wall-clock read carries an allow-wallclock pragma."""
import time


def measure(scan, clock=time.perf_counter):
    t0 = clock()
    scan()
    return clock() - t0


def report(stats, registry):
    registry.inc("scheduler.rounds", stats["rounds"])


def manifest_stamp():
    # quakecheck: allow-wallclock(checkpoint manifests carry a real date)
    return time.time()
