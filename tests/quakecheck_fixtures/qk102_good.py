"""QK102-clean: the data-dependent width is rounded through a bucket and
the jitted callable is bound once at module scope."""
import functools

import jax


def _next_pow2(n):
    p = 1
    while p < n:
        p *= 2
    return p


@functools.partial(jax.jit, static_argnames=("n",))
def pad_scan_good(x, *, n):
    return x[:n]


_inc = jax.jit(lambda a: a + 1)


def caller_good(xs, counts):
    n_bucket = _next_pow2(int(counts.max()))   # bucketed: cache-stable
    return pad_scan_good(xs, n=n_bucket), _inc(xs)
