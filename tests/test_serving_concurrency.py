"""Thread-safety contracts of the serving runtime (core/serving.py),
checked under the runtime twin of the QK2xx static rules:

  * hammer — 8 threads mixing submit/insert/delete/maintain against one
    runtime under ``sanitized(locks=True)``: zero lock-order inversions,
    zero eraser guarded-field violations, every query answered;
  * replay determinism — the engine-lock admission log of a concurrent
    run, replayed single-threaded on an identical index, reproduces
    byte-identical ids (coalescing determinism survives concurrency);
  * deadline clock — with a fake clock and the ticker off, a queued
    query flushes exactly when it crosses ``flush_deadline_ms``, and
    the deadline-flushed batch equals the size-triggered flush of the
    same batch byte for byte;
  * ticker — the background ticker thread flushes a lone query in real
    time with no explicit flush/drain call;
  * stats — ``stats()`` returns a self-consistent snapshot the caller
    owns (mutating it cannot corrupt the runtime).
"""
import threading
import time

import numpy as np
import pytest

from repro import sanitize
from repro.core import QuakeConfig, QuakeIndex, ServingConfig, ServingRuntime
from repro.data import datasets


@pytest.fixture(scope="module")
def ds():
    return datasets.clustered(2000, 16, n_clusters=12, seed=0)


def build(ds):
    return QuakeIndex.build(ds.vectors, num_partitions=16, kmeans_iters=3,
                            config=QuakeConfig())


# ---------------------------------------------------------------------------
# hammer under the concurrency sanitizer
# ---------------------------------------------------------------------------

N_THREADS, OPS_PER_THREAD = 8, 25


def test_hammer_sanitized(ds):
    """8 threads x 25 ops against one runtime: the lock discipline the
    QK2xx rules check statically holds dynamically — no inversions, no
    guarded-field races, and every submitted query gets an answer."""
    idx = build(ds)
    cfg = ServingConfig(k=10, flush_size=4, scan_backend="host",
                        cache_entries=64, flush_deadline_ms=5.0,
                        ticker=True, maint_min_ops=32)
    qs = datasets.queries_near(ds, 64, seed=5).astype(np.float32)
    qids, qids_lock = [], threading.Lock()
    errors = []

    def worker(tid):
        rng = np.random.default_rng(100 + tid)
        my_ids = []
        try:
            for i in range(OPS_PER_THREAD):
                r = rng.random()
                if r < 0.70:
                    qid = rt.submit_query(qs[rng.integers(len(qs))])
                    with qids_lock:
                        qids.append(qid)
                elif r < 0.80:
                    eid = 500_000 + tid * 1000 + i
                    rt.submit_insert(qs[None, rng.integers(len(qs))] + 0.01,
                                     np.array([eid]))
                    my_ids.append(eid)
                elif r < 0.90 and my_ids:
                    rt.submit_delete(np.array([my_ids.pop()]))
                else:
                    rt.maybe_maintain()
                if i % 7 == 0:
                    rt.stats()       # concurrent snapshot polling
        except BaseException as e:   # noqa: BLE001 - surfaced below
            errors.append((tid, e))

    with ServingRuntime(idx, cfg) as rt:
        with sanitize.sanitized(transfers=False, nans=False,
                                compiles=False, locks=True), \
                sanitize.LockOrderWatchdog() as wd:
            threads = [threading.Thread(target=worker, args=(t,))
                       for t in range(N_THREADS)]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            rt.drain()
            assert not errors, errors
            assert wd.events.order_violations == 0
            assert wd.events.guarded_violations == 0
            assert wd.events.acquisitions > 0    # the locks were exercised
        assert rt._ticker_error is None
        for qid in qids:
            res = rt.result(qid)
            assert res is not None and res.ids.shape == (10,)
        st = rt.stats()
        assert st["queries_submitted"] == len(qids)
        assert st["queries_completed"] >= len(qids)  # + cache hits
        assert st["queue_depth"] == 0


# ---------------------------------------------------------------------------
# replay determinism: concurrent admission order, single-threaded replay
# ---------------------------------------------------------------------------

def test_concurrent_replay_determinism(ds):
    """The engine lock totally orders admissions; replaying the recorded
    order single-threaded on an identical index reproduces the exact
    per-query results.  This is the coalescing-determinism contract
    (test_serving) extended across threads."""
    qs = datasets.queries_near(ds, 48, seed=9).astype(np.float32)
    cfg = ServingConfig(k=10, flush_size=3, scan_backend="host",
                        cache_entries=0, maint_min_ops=10 ** 9,
                        record_admissions=True)
    qvec, qvec_lock = {}, threading.Lock()
    errors = []

    def worker(tid, rt):
        rng = np.random.default_rng(200 + tid)
        try:
            for i in range(20):
                r = rng.random()
                if r < 0.85 or tid != 0:
                    q = qs[rng.integers(len(qs))]
                    qid = rt.submit_query(q)
                    with qvec_lock:
                        qvec[qid] = q
                elif r < 0.93:
                    rt.submit_insert(qs[None, i] + 0.02,
                                     np.array([700_000 + i]))
                else:
                    rt.submit_delete(np.array([700_000 + i - 1]))
        except BaseException as e:   # noqa: BLE001
            errors.append((tid, e))

    with ServingRuntime(build(ds), cfg) as rt:
        threads = [threading.Thread(target=worker, args=(t, rt))
                   for t in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        rt.drain()
        assert not errors, errors
        log = rt.admission_log()
        concurrent = {qid: rt.result(qid) for qid in qvec}

    assert any(e[0] == "q" for e in log)
    # single-threaded replay of the recorded order on a fresh twin
    replay_cfg = ServingConfig(k=10, flush_size=10 ** 9,
                               scan_backend="host", cache_entries=0,
                               maint_min_ops=10 ** 9)
    with ServingRuntime(build(ds), replay_cfg) as rt2:
        pairs = []                     # (original qid, replay qid)
        for entry in log:
            if entry[0] == "q":
                for qid in entry[1]:
                    pairs.append((qid, rt2.submit_query(qvec[qid])))
                rt2.flush()
            elif entry[0] == "insert":
                rt2.submit_insert(entry[1], entry[2])
            else:
                rt2.submit_delete(entry[1])
        rt2.drain()
        for orig, rep in pairs:
            got = rt2.result(rep)
            ref = concurrent[orig]
            np.testing.assert_array_equal(ref.ids, got.ids)
            np.testing.assert_array_equal(ref.dists, got.dists)


# ---------------------------------------------------------------------------
# deadline clock (fake timer) + background ticker (real timer)
# ---------------------------------------------------------------------------

def test_fake_clock_deadline_flush(ds):
    """A queued query flushes when the oldest entry crosses
    flush_deadline_ms — no size trigger involved — and the
    deadline-flushed batch is byte-identical to a size-triggered flush
    of the same batch."""
    idx = build(ds)
    now = [0.0]
    cfg = ServingConfig(k=10, flush_size=64, scan_backend="host",
                        flush_deadline_ms=50.0, ticker=False,
                        maint_min_ops=10 ** 9)
    batch = datasets.queries_near(ds, 3, seed=13).astype(np.float32)
    with ServingRuntime(idx, cfg, clock=lambda: now[0]) as rt:
        qids = [rt.submit_query(q) for q in batch]
        assert rt.stats()["queue_depth"] == 3      # far below flush_size
        now[0] = 0.049
        assert rt.tick() is False                  # 49ms < deadline
        assert rt.result(qids[0]) is None
        now[0] = 0.051
        assert rt.tick() is True                   # deadline crossed
        deadline_res = [rt.result(q) for q in qids]
        assert all(r is not None for r in deadline_res)

    # size-triggered twin: same index state, same admitted group
    size_cfg = ServingConfig(k=10, flush_size=3, scan_backend="host",
                             maint_min_ops=10 ** 9)
    with ServingRuntime(build(ds), size_cfg) as rt2:
        qids2 = [rt2.submit_query(q) for q in batch]  # 3rd submit flushes
        rt2.drain()                                   # finish in-flight rounds
        size_res = [rt2.result(q) for q in qids2]
    for a, b in zip(deadline_res, size_res):
        assert b is not None
        np.testing.assert_array_equal(a.ids, b.ids)
        np.testing.assert_array_equal(a.dists, b.dists)
        assert a.nprobe == b.nprobe


def test_background_ticker_flushes(ds):
    """With the ticker on, a lone queued query is answered within the
    deadline by the background thread — no explicit flush/drain."""
    cfg = ServingConfig(k=10, flush_size=64, scan_backend="host",
                        flush_deadline_ms=10.0, ticker=True,
                        maint_min_ops=10 ** 9)
    q = datasets.queries_near(ds, 1, seed=17).astype(np.float32)[0]
    with ServingRuntime(build(ds), cfg) as rt:
        ticker = rt._ticker_thread
        assert ticker is not None and ticker.is_alive()
        qid = rt.submit_query(q)
        deadline = time.monotonic() + 5.0
        while rt.result(qid) is None and time.monotonic() < deadline:
            time.sleep(0.005)
        res = rt.result(qid)
        assert res is not None, "ticker never flushed the queued query"
        assert res.latency_s > 0.0
        assert rt._ticker_error is None
    assert not ticker.is_alive()                   # close() joined it


# ---------------------------------------------------------------------------
# stats snapshot ownership
# ---------------------------------------------------------------------------

def test_stats_snapshot_is_owned_by_caller(ds):
    cfg = ServingConfig(k=10, flush_size=4, scan_backend="host",
                        cache_entries=8, maint_min_ops=10 ** 9)
    with ServingRuntime(build(ds), cfg) as rt:
        qs = datasets.queries_near(ds, 8, seed=21).astype(np.float32)
        for q in qs:
            rt.submit_query(q)
        rt.drain()
        s1 = rt.stats()
        # deep-owned: clobbering the snapshot cannot corrupt the runtime
        for k in list(s1):
            s1[k] = None if not isinstance(s1[k], dict) else s1[k].clear()
        s2 = rt.stats()
        assert s2["queries_submitted"] == 8
        assert s2["queries_completed"] >= 8
        assert isinstance(s2["maintenance_reasons"], list)
