"""Runtime sanitizer harness: compile-event counter sanity, the
compile-budget gate, shape-bucket recompile constancy for the packed
round scan, sanitized (transfer-guarded) runs of the fused-planner
and packed-scan device paths, and the concurrency sanitizer
(TrackedLock rank checks, eraser guarded-field checker, watchdog)."""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import sanitize
from repro.core import QuakeIndex
from repro.core import multiquery as mq
from repro.core.multiquery import get_executor, plan_batch
from repro.data import datasets
from repro.kernels import ops


@pytest.fixture(scope="module")
def built():
    ds = datasets.clustered(3000, 16, n_clusters=12, seed=0)
    idx = QuakeIndex.build(ds.vectors, num_partitions=24, kmeans_iters=3)
    ex = get_executor(idx)
    ex.snapshot()
    return ds, idx, ex


# ---------------------------------------------------------------------------
# counter + budget mechanics
# ---------------------------------------------------------------------------

def test_compile_counter_sanity():
    """The monitoring event the counter keys on must fire on a real
    compilation — if a newer JAX renames the event, this fails loudly
    instead of the budget gate silently passing."""
    with sanitize.compile_events() as ev:
        f = jax.jit(lambda x: x * 3 + 1)
        f(jnp.ones((13,))).block_until_ready()   # fresh shape: compiles
        assert ev.new() >= 1
        ev.reset()
        f(jnp.ones((13,))).block_until_ready()   # cache hit: no event
        assert ev.new() == 0


def test_warm_until_stable():
    g = jax.jit(lambda x: x - 2)
    x = jnp.ones((17,))
    calls = sanitize.warm_until_stable(
        lambda: g(x).block_until_ready())
    assert calls >= 1
    with sanitize.compile_events() as ev:
        g(x).block_until_ready()
    assert ev.new() == 0


def test_compile_budget_file():
    budgets = sanitize.load_compile_budget()
    assert "scan_probe_round.steady" in budgets
    assert budgets["scan_probe_round.steady"] == 0
    sanitize.assert_compile_budget("scan_probe_round.steady", 0)
    with pytest.raises(AssertionError):
        sanitize.assert_compile_budget("scan_probe_round.steady", 1)
    with pytest.raises(AssertionError):
        sanitize.assert_compile_budget("no.such.entry_point", 0)


# ---------------------------------------------------------------------------
# recompile constancy: geometric B/U padding vs varying flush sizes
# ---------------------------------------------------------------------------

# (rows flushed, probe-window width) — kept-union sizes land on several
# rungs of the u_pow2 ladder, and rows vary under a fixed B padding
FLUSH_SWEEP = [(1, 1), (2, 1), (5, 2), (8, 2), (8, 3)]
B_PAD, M = 8, 10


def _round_inputs(ds, idx, n_rows, w, seed_q):
    rng = np.random.default_rng(7)   # fixed: same seq matrix every call
    seq = np.stack([rng.permutation(idx.num_partitions)[:M]
                    for _ in range(B_PAD)]).astype(np.int64)
    q = datasets.queries_near(ds, B_PAD, seed=seed_q).astype(np.float32)
    take = np.zeros((B_PAD, M), dtype=bool)
    take[:n_rows, :w] = True
    kept = np.unique(seq[take])
    return q, seq, take, kept


def _run_sweep(ds, idx, ex, snap, seed_q):
    for n_rows, w in FLUSH_SWEEP:
        q, seq, take, kept = _round_inputs(ds, idx, n_rows, w, seed_q)
        d, flat, st = ex.scan_probe_round(
            jnp.asarray(q), jnp.asarray(seq.astype(np.int32)), take,
            kept, 10, snap=snap, u_pow2=True, seq_host=seq)
        jax.block_until_ready((d, flat))
        assert st["partitions"] == len(kept)


def test_scan_probe_round_compile_constant_across_flush_sizes(built):
    """The tentpole invariant the buckets exist for: once the pow2 union
    ladder's rungs are warm, repeated flushes of *varying* sizes (new
    query values, same rungs) trigger zero new XLA compilations."""
    ds, idx, ex = built
    snap = ex.snapshot()
    with sanitize.compile_events() as ev:
        _run_sweep(ds, idx, ex, snap, seed_q=11)      # warm-up sweep
        warm = ev.new()
        sanitize.assert_compile_budget("scan_probe_round.warm", warm)
        ev.reset()
        _run_sweep(ds, idx, ex, snap, seed_q=23)      # steady state
        _run_sweep(ds, idx, ex, snap, seed_q=37)
        sanitize.assert_compile_budget("scan_probe_round.steady",
                                       ev.new())


# ---------------------------------------------------------------------------
# sanitized device paths (transfer guard + NaN debug + counter)
# ---------------------------------------------------------------------------

@pytest.mark.sanitized
def test_fused_planner_sanitized(built, sanitized_run):
    """The fused planner's steady state holds under the full sanitizer
    stack: no implicit transfer, no NaN production, zero recompiles."""
    ds, idx, ex = built
    q = datasets.queries_near(ds, 8, seed=33).astype(np.float32)
    m = mq._aps_candidate_budget(idx)
    args = (jax.device_put(q),
            jax.device_put(idx.levels[0].centroids),
            jax.device_put(np.zeros(idx.num_partitions, np.float32)),
            jax.device_put(np.float32(idx._max_norm_sq)),
            jax.device_put(np.float32(3.0)),
            jax.device_put(np.asarray(idx._beta_table)),
            jax.device_put(np.float32(0.9)))
    kw = dict(m=m, metric=idx.config.metric)
    jax.block_until_ready(mq._fused_plan_probes(*args, **kw))  # warm
    with sanitized_run() as ev:
        out = mq._fused_plan_probes(*args, **kw)
        jax.block_until_ready(out)
        sanitize.assert_compile_budget("fused_plan_probes.steady",
                                       ev.new())
    seq, counts = np.asarray(out[0]), np.asarray(out[1])
    assert seq.shape == (8, m) and (counts >= 1).all()


@pytest.mark.sanitized
def test_packed_scan_sanitized(built, sanitized_run):
    """The packed union scan consumes the planner's device-resident plan
    (BatchPlan.sel_dev/qmask_dev) under the transfer guard — proving the
    plan->scan seam needs no host round trip."""
    ds, idx, ex = built
    q = datasets.queries_near(ds, 6, seed=41).astype(np.float32)
    snap = ex.snapshot()
    plan = plan_batch(idx, q, 10, nprobe=4, u_bucket=ex.u_bucket)
    assert plan.sel_dev is not None and plan.qmask_dev is not None
    q_d = jax.device_put(q)
    kw = dict(metric=idx.config.metric, impl="jnp")
    warm = ops.scan_selected_topk(q_d, snap.data, ex._valid,
                                  plan.sel_dev, plan.qmask_dev, 10, **kw)
    jax.block_until_ready(warm)
    with sanitized_run() as ev:
        d, flat = ops.scan_selected_topk(q_d, snap.data, ex._valid,
                                         plan.sel_dev, plan.qmask_dev,
                                         10, **kw)
        jax.block_until_ready((d, flat))
        sanitize.assert_compile_budget("scan_selected_topk.steady",
                                       ev.new())
    # guarded run returns the exact same top-k as the unguarded warm run
    np.testing.assert_array_equal(np.asarray(flat), np.asarray(warm[1]))


# ---------------------------------------------------------------------------
# concurrency sanitizer: TrackedLock / watchdog / eraser / guarded_by
# ---------------------------------------------------------------------------

def test_lock_order_matches_quakecheck_config():
    """The runtime twin and the static analyzer must agree on the
    hierarchy, or one of them is checking a fiction."""
    from tools.quakecheck import config as qc
    assert tuple(qc.LOCK_ORDER) == sanitize.LOCK_ORDER


def test_tracked_lock_in_order_is_clean():
    outer = sanitize.TrackedLock("ServingRuntime._lock")
    inner = sanitize.TrackedLock("ResultCache._lock")
    with sanitize.LockOrderWatchdog() as wd:
        with outer:
            assert outer.held()
            with inner:
                pass
        assert not outer.held()
        assert wd.events.order_violations == 0
        assert wd.events.acquisitions == 2


def test_tracked_lock_reentrant():
    lk = sanitize.TrackedLock("ServingRuntime._lock")
    with sanitize.LockOrderWatchdog() as wd:
        with lk:
            with lk:                      # re-entry is not an inversion
                assert lk.held()
        assert wd.events.order_violations == 0


def test_lock_order_inversion_raises_under_watchdog():
    outer = sanitize.TrackedLock("ServingRuntime._lock")
    inner = sanitize.TrackedLock("ResultCache._lock")
    with sanitize.LockOrderWatchdog() as wd:
        with pytest.raises(RuntimeError, match="inverts LOCK_ORDER"):
            with inner:
                with outer:
                    pass
        assert wd.events.order_violations == 1
    # outside the watchdog the same inversion only counts
    before = sanitize.concurrency_counters()["order_violations"]
    with inner:
        with outer:
            pass
    assert sanitize.concurrency_counters()["order_violations"] == before + 1


def test_release_from_wrong_thread_raises():
    lk = sanitize.TrackedLock("ResultCache._lock")
    lk.acquire()
    err = []

    def stray():
        try:
            lk.release()
        except RuntimeError as e:
            err.append(e)
    t = threading.Thread(target=stray)
    t.start()
    t.join()
    lk.release()
    assert err, "release from a non-owner thread must raise"


def test_eraser_flags_no_common_lock():
    la = sanitize.TrackedLock("ResultCache._lock")
    lb = sanitize.TrackedLock("MaintenanceScheduler._lock")

    class Obj:
        pass
    o = Obj()
    with sanitize.LockOrderWatchdog() as wd:
        with la:
            sanitize.note_guarded(o, "field")     # thread 1 under la
        raised = []

        def other():
            try:
                with lb:                          # thread 2 under lb only
                    sanitize.note_guarded(o, "field")
            except RuntimeError as e:
                raised.append(e)
        t = threading.Thread(target=other)
        t.start()
        t.join()
        assert raised and "no common lock" in str(raised[0])
        assert wd.events.guarded_violations == 1


def test_eraser_clean_with_common_lock():
    lk = sanitize.TrackedLock("ResultCache._lock")

    class Obj:
        pass
    o = Obj()
    with sanitize.LockOrderWatchdog() as wd:
        def worker():
            with lk:
                sanitize.note_guarded(o, "field")
        ts = [threading.Thread(target=worker) for _ in range(4)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        with lk:
            sanitize.note_guarded(o, "field")
        assert wd.events.guarded_violations == 0


def test_guarded_by_decorator():
    class Box:
        def __init__(self):
            self._lock = sanitize.TrackedLock("ResultCache._lock")
            self.v = 0

        @sanitize.guarded_by("_lock")
        def bump(self):
            self.v += 1
    b = Box()
    with sanitize.LockOrderWatchdog() as wd:
        with b._lock:
            b.bump()                       # lock held: fine
        assert wd.events.guarded_violations == 0
        with pytest.raises(RuntimeError, match="guarded"):
            b.bump()                       # lock not held: flagged
        assert wd.events.guarded_violations == 1
    assert b.bump.__quakecheck_guarded_by__ == "_lock"


def test_concurrency_events_are_deltas():
    lk = sanitize.TrackedLock("ServingRuntime._lock")
    with lk:
        pass
    with sanitize.LockOrderWatchdog() as wd:
        assert wd.events.acquisitions == 0    # pre-watchdog noise excluded
        with lk:
            pass
        assert wd.events.acquisitions == 1
        wd.events.reset()
        assert wd.events.acquisitions == 0


def test_sanitized_locks_arms_watchdog():
    inner = sanitize.TrackedLock("ResultCache._lock")
    outer = sanitize.TrackedLock("ServingRuntime._lock")
    with sanitize.sanitized(locks=True):
        with pytest.raises(RuntimeError, match="inverts LOCK_ORDER"):
            with inner:
                with outer:
                    pass
