"""Maintenance engine: estimate/verify/commit semantics (paper §4)."""
import numpy as np
import pytest

from repro.core import (LatencyModel, Maintainer, MaintenancePolicy,
                        QuakeConfig, QuakeIndex)
from repro.core import cost_model as cm
from repro.data import datasets


def _skewed_index(seed=1, hot=2, cold=20, hot_size=5000, cold_size=300,
                  dim=24, **cfg_kw):
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(hot + cold, dim)) * 6
    parts = [centers[i] + rng.normal(size=(hot_size, dim))
             for i in range(hot)]
    parts += [centers[hot + i] + rng.normal(size=(cold_size, dim))
              for i in range(cold)]
    x = np.concatenate(parts).astype(np.float32)
    idx = QuakeIndex.build(x, num_partitions=hot + cold,
                           config=QuakeConfig(**cfg_kw), kmeans_iters=4)
    queries = np.concatenate(
        [centers[i] + rng.normal(size=(100, dim)) for i in range(hot)]
    ).astype(np.float32)
    for q in queries:
        idx.search(q, 10)
    return idx, x, centers


def test_cost_example_from_paper():
    """Paper §4.2.4 worked example: balanced split committed, imbalanced
    split rejected, with their exact lambda values."""
    lam = cm.fit_latency_model(np.array([50, 250, 450, 500]),
                               np.array([250e3, 550e3, 1050e3, 1200e3]))
    # reproduce the decision arithmetic with the paper's numbers directly
    d_over, tau, alpha, a = 60e3, 4e3, 0.5, 0.10
    lam_500, lam_250 = 1200e3, 550e3
    lam_450, lam_50 = 1050e3, 250e3
    est = d_over - a * lam_500 + 2 * alpha * a * lam_250
    assert est < -tau                       # tentative split accepted
    bal = d_over - a * lam_500 + alpha * a * (lam_250 + lam_250)
    imb = d_over - a * lam_500 + alpha * a * (lam_450 + lam_50)
    assert bal < -tau                       # P1 commit
    assert imb > -tau                       # P2 reject


def test_split_reduces_cost_monotonically():
    idx, x, _ = _skewed_index()
    m = Maintainer(idx)
    costs = [m.total_cost()]
    for _ in range(3):
        rng = np.random.default_rng(0)
        for q in x[rng.integers(0, len(x), 100)]:
            idx.search(q, 10)
        rep = m.run()
        assert rep.cost_after <= rep.cost_before + 1e-6
        costs.append(rep.cost_after)
        idx.check_invariants()
    assert costs[-1] < costs[0]


def test_split_triggers_on_hot_partitions():
    idx, _, _ = _skewed_index()
    rep = Maintainer(idx).run()
    assert rep.splits >= 1
    idx.check_invariants()


def test_merge_triggers_when_overpartitioned():
    rng = np.random.default_rng(2)
    x = rng.normal(size=(4000, 16)).astype(np.float32)
    idx = QuakeIndex.build(x, num_partitions=200,
                           config=QuakeConfig(min_partition_size=64,
                                              tau_ns=1.0), kmeans_iters=3)
    for q in x[rng.integers(0, 4000, 200)]:
        idx.search(q, 10)
    rep = Maintainer(idx).run()
    assert rep.merges >= 1
    assert rep.cost_after <= rep.cost_before + 1e-6
    idx.check_invariants()


def test_rejection_blocks_bad_actions():
    """With a huge tau nothing should ever commit."""
    idx, x, _ = _skewed_index(tau_ns=1e12)
    rep = Maintainer(idx).run()
    assert rep.splits == 0 and rep.merges == 0


def test_noop_maintenance_does_not_invalidate_snapshots():
    """Regression: a maintenance pass where zero actions commit must not
    bump the mutation clock — the batched executor's cached snapshot stays
    valid and no refresh (full or delta) happens on the next search."""
    from repro.core.multiquery import batch_search, get_executor

    idx, x, _ = _skewed_index(tau_ns=1e12)     # tau blocks every commit
    q = x[:4]
    batch_search(idx, q, 5, nprobe=4)
    ex = get_executor(idx)
    v0, key0, rebuilds0 = idx.version, ex._key, ex.full_rebuilds
    rep = Maintainer(idx).run()
    assert rep.splits == 0 and rep.merges == 0
    assert not rep.level_added and not rep.level_removed
    assert idx.version == v0                   # clock untouched
    batch_search(idx, q, 5, nprobe=4)
    assert ex._key == key0
    assert ex.full_rebuilds == rebuilds0 and ex.delta_refreshes == 0
    # the maintenance log still records the pass, with an empty journal
    assert idx.maintenance_log[-1]["journal"] == []


def test_no_rejection_policy_commits_tentatives():
    idx, _, _ = _skewed_index()
    pol = MaintenancePolicy(use_rejection=False)
    rep = Maintainer(idx, policy=pol).run()
    assert rep.rejected_splits == 0 and rep.rejected_merges == 0
    idx.check_invariants()


def test_norefine_policy_skips_refinement():
    idx, _, _ = _skewed_index()
    pol = MaintenancePolicy(use_refinement=False)
    rep = Maintainer(idx, policy=pol).run()
    idx.check_invariants()   # structure stays coherent without refinement


def test_search_correct_after_maintenance():
    idx, x, _ = _skewed_index()
    Maintainer(idx).run()
    rng = np.random.default_rng(3)
    k = 10
    recs = []
    for _ in range(20):
        q = x[rng.integers(len(x))]
        d = np.sum((x - q) ** 2, axis=1)
        gt = set(np.argsort(d)[:k].tolist())
        r = idx.search(q, k, recall_target=0.9)
        recs.append(len(gt & set(r.ids.tolist())) / k)
    assert np.mean(recs) >= 0.85


def test_level_add_and_remove():
    rng = np.random.default_rng(4)
    x = rng.normal(size=(3000, 8)).astype(np.float32)
    idx = QuakeIndex.build(x, num_partitions=64,
                           config=QuakeConfig(level_add_threshold=32))
    rep = Maintainer(idx).run()
    assert rep.level_added and len(idx.levels) == 2
    idx.check_invariants()
    # force removal
    idx.config.level_add_threshold = 10**9
    idx.config.level_remove_threshold = 10**6
    rep2 = Maintainer(idx).run()
    assert rep2.level_removed and len(idx.levels) == 1
    idx.check_invariants()


def test_latency_model_fit_and_profile():
    sizes = np.array([64, 256, 1024, 4096])
    lam0 = LatencyModel(c_fixed=100, c_lin=2.0, c_sel=0.3)
    fit = cm.fit_latency_model(sizes, lam0(sizes))
    np.testing.assert_allclose(fit(sizes), lam0(sizes), rtol=1e-6)
    prof = cm.profile(dim=16, sizes=(64, 256, 1024), repeats=2)
    assert (prof(np.array([10, 100, 1000])) > 0).all()
