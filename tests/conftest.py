"""Shared fixtures: the runtime sanitizer harness (src/repro/sanitize.py).

``sanitized_run`` gives a test the stacked sanitizers (transfer guard +
NaN debugging + compile counter) as a context factory; the ``sanitized``
marker documents which tests exercise device paths under the guard (CI
selects them with ``-m sanitized`` for the sanitized tier-1 subset).
"""
import pytest

from repro import sanitize


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "sanitized: runs device paths under jax.transfer_guard('disallow') "
        "+ debug_nans + the compile-event counter")


@pytest.fixture
def sanitized_run():
    """Factory for sanitizer scopes: ``with sanitized_run() as ev: ...``.
    Stage device operands explicitly (device_put/jnp.asarray) before
    entering — implicit transfers inside the scope raise."""
    return sanitize.sanitized


@pytest.fixture
def compile_events():
    """Compile-event counter scope (no transfer/NaN guards)."""
    return sanitize.compile_events
