"""Pragma parsing: per-line suppression comments.

Two forms, both requiring a finding to exist on the same line (or the
line a standalone pragma comment precedes):

  ``# quakecheck: allow-sync(<reason>)``
      Documents an intentional device->host sync (QK101 only).  The
      reason is mandatory — an allow-sync with no reason is itself a
      finding (QK100): the whole point is that intentional sync points
      are *documented*, not hidden.

  ``# quakecheck: allow-swallow(<reason>)``
      Documents an intentional broad exception swallow (QK301 only) —
      a handler that really should drop everything, e.g. best-effort
      telemetry.  Like allow-sync, the reason is mandatory; a reasonless
      allow-swallow is itself a finding (QK100).

  ``# quakecheck: allow-nosync(<reason>)``
      Documents an intentional unsynced file write in a durability path
      (QK302 only) — e.g. a test helper that deliberately models a
      crash's half-written state.  The reason is mandatory; a reasonless
      allow-nosync is itself a finding (QK100).

  ``# quakecheck: allow-wallclock(<reason>)``
      Documents an intentional wall-clock read or stdout write in a core
      runtime path (QK401 only) — e.g. stamping a checkpoint manifest
      with a real date.  The reason is mandatory; a reasonless
      allow-wallclock is itself a finding (QK100).

  ``# quakecheck: disable=QK102,QK105(<reason>)``
      Suppresses the listed rules on the line.  Reason optional but
      encouraged.

  ``# quakecheck: device-path``
      On a ``def`` line: registers the function as device-resident for
      QK101 (the inline form of ``config.DEVICE_RESIDENT_FUNCS``).

  ``# quakecheck: holds(<lock>[, <lock2>])``
      Asserts the named lock(s) are held on this line (or, on a ``def``
      line, throughout the function) — the inline escape hatch the QK2xx
      lock-set analysis consults when the acquisition happens outside
      the analyzed function (e.g. a callback invoked under the caller's
      lock).  Lock names are bare attributes (``_lock``) qualified
      against the enclosing class, or explicit ``Class._lock``
      qualnames.  An empty ``holds()`` is malformed (QK100).
"""
from __future__ import annotations

import io
import re
import tokenize
from dataclasses import dataclass, field
from typing import Dict, List, Set

_ALLOW_SYNC = re.compile(r"#\s*quakecheck:\s*allow-sync\s*(?:\((?P<reason>[^)]*)\))?")
_ALLOW_SWALLOW = re.compile(
    r"#\s*quakecheck:\s*allow-swallow\s*(?:\((?P<reason>[^)]*)\))?")
_ALLOW_NOSYNC = re.compile(
    r"#\s*quakecheck:\s*allow-nosync\s*(?:\((?P<reason>[^)]*)\))?")
_ALLOW_WALLCLOCK = re.compile(
    r"#\s*quakecheck:\s*allow-wallclock\s*(?:\((?P<reason>[^)]*)\))?")
_DISABLE = re.compile(r"#\s*quakecheck:\s*disable\s*=\s*(?P<rules>[A-Z0-9, ]+)"
                      r"\s*(?:\((?P<reason>[^)]*)\))?")
_DEVICE_PATH = re.compile(r"#\s*quakecheck:\s*device-path\b")
_HOLDS = re.compile(r"#\s*quakecheck:\s*holds\s*\((?P<locks>[^)]*)\)")


@dataclass
class LinePragmas:
    allow_sync: bool = False
    allow_sync_reason: str = ""
    allow_swallow: bool = False
    allow_swallow_reason: str = ""
    allow_nosync: bool = False
    allow_nosync_reason: str = ""
    allow_wallclock: bool = False
    allow_wallclock_reason: str = ""
    disabled: Set[str] = field(default_factory=set)
    device_path: bool = False
    holds: Set[str] = field(default_factory=set)
    bad_holds: bool = False     # holds() with no lock named (QK100)


@dataclass
class FilePragmas:
    by_line: Dict[int, LinePragmas] = field(default_factory=dict)

    def _line(self, lineno: int) -> LinePragmas:
        return self.by_line.get(lineno, _EMPTY)

    def allows_sync(self, lineno: int) -> bool:
        p = self._line(lineno)
        return p.allow_sync and bool(p.allow_sync_reason.strip())

    def bad_allow_sync(self, lineno: int) -> bool:
        p = self._line(lineno)
        return p.allow_sync and not p.allow_sync_reason.strip()

    def allows_swallow(self, lineno: int) -> bool:
        p = self._line(lineno)
        return p.allow_swallow and bool(p.allow_swallow_reason.strip())

    def bad_allow_swallow(self, lineno: int) -> bool:
        p = self._line(lineno)
        return p.allow_swallow and not p.allow_swallow_reason.strip()

    def allows_nosync(self, lineno: int) -> bool:
        p = self._line(lineno)
        return p.allow_nosync and bool(p.allow_nosync_reason.strip())

    def bad_allow_nosync(self, lineno: int) -> bool:
        p = self._line(lineno)
        return p.allow_nosync and not p.allow_nosync_reason.strip()

    def allows_wallclock(self, lineno: int) -> bool:
        p = self._line(lineno)
        return p.allow_wallclock and bool(p.allow_wallclock_reason.strip())

    def bad_allow_wallclock(self, lineno: int) -> bool:
        p = self._line(lineno)
        return p.allow_wallclock and not p.allow_wallclock_reason.strip()

    def disabled(self, lineno: int, rule: str) -> bool:
        return rule in self._line(lineno).disabled

    def device_path(self, lineno: int) -> bool:
        return self._line(lineno).device_path

    def holds(self, lineno: int) -> Set[str]:
        return self._line(lineno).holds

    def bad_holds(self, lineno: int) -> bool:
        return self._line(lineno).bad_holds

    def pragma_lines(self) -> List[int]:
        return sorted(self.by_line)


_EMPTY = LinePragmas()


def parse_pragmas(source: str) -> FilePragmas:
    """Extract quakecheck pragmas, attributing standalone comment lines to
    the next line of code (so a pragma can sit above a long statement)."""
    out = FilePragmas()
    comments: List[tuple] = []   # (lineno, text, is_standalone)
    try:
        toks = list(tokenize.generate_tokens(io.StringIO(source).readline))
    except (tokenize.TokenError, IndentationError):  # linted elsewhere
        return out
    code_lines: Set[int] = set()
    for tok in toks:
        if tok.type == tokenize.COMMENT:
            comments.append((tok.start[0], tok.string))
        elif tok.type not in (tokenize.NL, tokenize.NEWLINE,
                              tokenize.INDENT, tokenize.DEDENT,
                              tokenize.ENCODING, tokenize.ENDMARKER):
            code_lines.add(tok.start[0])
    n_lines = source.count("\n") + 1
    for lineno, text in comments:
        pragma = _parse_comment(text)
        if pragma is None:
            continue
        target = lineno
        if lineno not in code_lines:   # standalone: applies to next code line
            nxt = lineno + 1
            while nxt <= n_lines and nxt not in code_lines:
                nxt += 1
            target = nxt
        cur = out.by_line.setdefault(target, LinePragmas(disabled=set()))
        if pragma.allow_sync:
            cur.allow_sync = True
            cur.allow_sync_reason = pragma.allow_sync_reason
        if pragma.allow_swallow:
            cur.allow_swallow = True
            cur.allow_swallow_reason = pragma.allow_swallow_reason
        if pragma.allow_nosync:
            cur.allow_nosync = True
            cur.allow_nosync_reason = pragma.allow_nosync_reason
        if pragma.allow_wallclock:
            cur.allow_wallclock = True
            cur.allow_wallclock_reason = pragma.allow_wallclock_reason
        cur.disabled |= pragma.disabled
        cur.device_path = cur.device_path or pragma.device_path
        cur.holds |= pragma.holds
        cur.bad_holds = cur.bad_holds or pragma.bad_holds
    return out


def _parse_comment(text: str) -> LinePragmas | None:
    if "quakecheck" not in text:
        return None
    out = LinePragmas(disabled=set())
    hit = False
    m = _ALLOW_SYNC.search(text)
    if m:
        out.allow_sync = True
        out.allow_sync_reason = (m.group("reason") or "").strip()
        hit = True
    m = _ALLOW_SWALLOW.search(text)
    if m:
        out.allow_swallow = True
        out.allow_swallow_reason = (m.group("reason") or "").strip()
        hit = True
    m = _ALLOW_NOSYNC.search(text)
    if m:
        out.allow_nosync = True
        out.allow_nosync_reason = (m.group("reason") or "").strip()
        hit = True
    m = _ALLOW_WALLCLOCK.search(text)
    if m:
        out.allow_wallclock = True
        out.allow_wallclock_reason = (m.group("reason") or "").strip()
        hit = True
    m = _DISABLE.search(text)
    if m:
        out.disabled = {r.strip() for r in m.group("rules").split(",")
                        if r.strip()}
        hit = True
    if _DEVICE_PATH.search(text):
        out.device_path = True
        hit = True
    m = _HOLDS.search(text)
    if m:
        locks = {l.strip() for l in m.group("locks").split(",")
                 if l.strip()}
        if locks:
            out.holds = locks
        else:
            out.bad_holds = True
        hit = True
    return out if hit else None
