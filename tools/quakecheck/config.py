"""Repo-specific registries the quakecheck rules consult.

Everything here is *policy*, not mechanism: which functions are declared
device-resident, which call names produce device values, which serving
classes own write-barrier-guarded state.  New subsystems extend these
tables (or use the inline markers) instead of touching the rule code.
"""
from __future__ import annotations

# --------------------------------------------------------------------------
# QK101 — device-resident functions (host syncs inside these must carry an
# allow-sync pragma).  Entries are bare function names or
# ``ClassName.method`` qualnames.  Functions jitted with ``@jax.jit`` /
# ``functools.partial(jax.jit, ...)`` are registered automatically, as is
# any def whose line carries a ``# quakecheck: device-path`` marker.
# --------------------------------------------------------------------------
DEVICE_RESIDENT_FUNCS = {
    # core/multiquery.py — the batched executor hot path
    "_fused_plan_probes",
    "_aps_probe_counts_batched",
    "_aps_probe_counts_fused",
    "run_round_loop",
    "BatchedSearchExecutor.search",
    "BatchedSearchExecutor.scan_probe_round",
    "BatchedSearchExecutor._search_rounds",
    # core/serving.py — the riding-round scheduler
    "RoundScheduler.step",
}

# Call names (bare or attribute leaf) whose results live on device.  The
# taint pass also treats any ``jnp.*`` / ``jax.*`` call as device-producing
# (except the explicit sync entry points below).
DEVICE_PRODUCING_CALLS = {
    "scan_topk", "scan_selected_topk", "scan_selected_topk_q8",
    "kmeans_assign", "pack_union", "pack_round", "pack_round_masked",
    "topk_merge", "_fused_plan_probes", "scan_probe_round", "_pack_plan",
    "run_round_loop", "device_arrays", "apply_delta", "build_patch",
}

# Explicit sync entry points: calling these on a device value is the
# device->host pull QK101 exists to surface.
HOST_SYNC_CALLS = {
    "np.asarray", "np.array", "np.ascontiguousarray", "numpy.asarray",
    "numpy.array", "jax.device_get", "device_get",
}
HOST_SYNC_BUILTINS = {"float", "int", "bool"}
HOST_SYNC_METHODS = {"item", "tolist", "__array__", "block_until_ready"}

# --------------------------------------------------------------------------
# QK102 — jit cache discipline
# --------------------------------------------------------------------------
# Names that mark a value as bucket-rounded (safe to use as a jit static
# argument / padded shape even though it derives from data).
BUCKET_HINT_NAMES = {"bucket", "pad", "pow2", "align", "tile", "cap"}
BUCKET_CALLS = {"_next_pow2", "_pad_to", "next_pow2", "pad_to"}
# Reducers whose results vary with the *data* (not just operand shapes):
# feeding one of these into a jit static argument fragments the cache.
DATA_DEPENDENT_REDUCERS = {"max", "min", "sum", "argmax", "argmin",
                           "nonzero", "unique", "count_nonzero"}

# --------------------------------------------------------------------------
# QK103 — Pallas kernel contract
# --------------------------------------------------------------------------
# pltpu names that have churned across JAX releases; kernels must reach
# them through kernels/pallas_compat.py, never directly.
PLTPU_COMPAT_ONLY = {
    "TPUCompilerParams", "CompilerParams", "PrefetchScalarGridSpec",
    "GridDimensionSemantics",
}
# The one file allowed to touch them.
PALLAS_COMPAT_FILE = "pallas_compat.py"
# Directory (path fragment) the kernel-contract rules apply to.
KERNELS_DIR_FRAGMENT = "kernels"

# --------------------------------------------------------------------------
# QK105 — serving shared state (write-barrier discipline, docs/serving.md)
# --------------------------------------------------------------------------
# owner class -> guarded fields.  Mutating one of these outside a method of
# the owning class bypasses the write barrier.  Reads are always fine;
# calling the owner's public methods is the sanctioned API.
GUARDED_STATE = {
    "ServingRuntime": {"results", "_queue", "_cache_version",
                       "_maintaining", "_next_qid"},
    "ResultCache": {"_store", "_by_key", "_by_part", "_next_eid",
                    "_proj", "hits", "misses", "invalidated"},
    "RoundScheduler": {"active", "done", "_epoch_key", "_snap",
                       "round_streams", "plan_footprints"},
    "PartitionStats": {"hits", "window"},
    # observability layer (src/repro/obs, docs/observability.md)
    "MetricsRegistry": {"_counters", "_gauges", "_histograms"},
    "QueryTracer": {"_open", "_ring"},
    "CalibrationTracker": {"_lat_err", "_rec_err"},
}
# Attribute names that are guarded under *any* owner (the linter cannot
# infer types, so a guarded-name mutation through a non-self base is
# flagged wherever it appears; the owner's own methods use ``self``).
GUARDED_ATTRS = {a for attrs in GUARDED_STATE.values() for a in attrs}

MUTATING_METHODS = {"append", "extend", "clear", "pop", "popitem", "remove",
                    "insert", "update", "setdefault", "discard", "add",
                    "move_to_end", "sort", "fill"}

# --------------------------------------------------------------------------
# QK2xx — lock discipline (docs/serving.md threading model)
# --------------------------------------------------------------------------
# owner class -> {guarded field -> lock attribute that must be held}.
# Layered on GUARDED_STATE: QK105 checks *who* writes, QK201 checks *under
# what lock*.  Lock attributes are unqualified (``_lock``); the analysis
# qualifies them against the owning class (``ResultCache._lock``).
GUARDED_BY = {
    "ServingRuntime": {
        "results": "_lock", "_queue": "_lock", "_cache_version": "_lock",
        "_maintaining": "_lock", "_next_qid": "_lock",
        "_admission_log": "_lock", "_admit_gen": "_lock",
        "queries_submitted": "_lock", "cache_hits": "_lock",
        "write_ops": "_lock",
        # failure / degradation telemetry (docs/serving.md)
        "shed_queries": "_lock", "_status_counts": "_lock",
        "cache_errors": "_lock", "_cache_disabled": "_lock",
        "ticker_errors": "_lock", "ticker_restarts": "_lock",
        "ticker_wedged": "_lock", "maintenance_failures": "_lock",
        "_overflow_since_flush": "_lock", "_govern_steps": "_lock",
        "_pressure_streak": "_lock", "_calm_streak": "_lock",
        "_govern_degrades": "_lock", "_govern_restores": "_lock",
    },
    "RoundScheduler": {
        "active": "_lock", "done": "_lock", "_epoch_key": "_lock",
        "_snap": "_lock", "round_streams": "_lock",
        "plan_footprints": "_lock", "partitions_streamed": "_lock",
        "vectors_streamed": "_lock", "comparisons": "_lock",
        "rounds_run": "_lock",
        # failure / degradation telemetry
        "partials": "_lock", "failures": "_lock",
        "failed_batches": "_lock", "scan_faults": "_lock",
        "scan_retries_used": "_lock", "_last_scan_error": "_lock",
        "target": "_lock", "probe_frac": "_lock",
    },
    "ResultCache": {
        "_store": "_lock", "_by_key": "_lock", "_by_part": "_lock",
        "_next_eid": "_lock", "_proj": "_lock", "_gen": "_lock",
        "hits": "_lock", "misses": "_lock", "invalidated": "_lock",
        "stale_puts": "_lock",
    },
    "MaintenanceScheduler": {
        "ops_since": "_lock", "history": "_lock", "_last_version": "_lock",
        "_last_cost": "_lock", "_last_freqs": "_lock",
    },
    "MetricsRegistry": {
        "_counters": "_lock", "_gauges": "_lock", "_histograms": "_lock",
    },
    "QueryTracer": {
        "_open": "_lock", "_ring": "_lock",
        "emitted": "_lock", "dropped": "_lock",
    },
    "CalibrationTracker": {
        "_lat_err": "_lock", "_rec_err": "_lock",
    },
}

# Declared global lock partial order (qualified names, outermost first).
# Acquiring a lock while holding one that appears *later* in this list is
# a QK202 lock-order violation — the runtime twin is
# ``repro.sanitize.LOCK_ORDER`` (a test asserts the two lists agree).
LOCK_ORDER = [
    "ServingRuntime._engine_lock",
    "ServingRuntime._lock",
    "RoundScheduler._lock",
    "ResultCache._lock",
    "MaintenanceScheduler._lock",
    # observability locks rank innermost: recording under any runtime
    # lock is legal, the reverse never is (docs/observability.md)
    "QueryTracer._lock",
    "CalibrationTracker._lock",
    "MetricsRegistry._lock",
]

# Locks on the admission fast path: holding one of these across a
# blocking call (QK203) stalls every concurrent submit_* caller.  The
# engine lock is deliberately absent — serializing blocking scan /
# maintenance work is its whole job.
ADMISSION_LOCKS = {"ServingRuntime._lock"}

# Call names (leaf) that block: device syncs, host pulls, scans, and
# maintenance entry points.  QK203 flags any of these inside a region
# holding an admission lock.
BLOCKING_CALLS = {
    "block_until_ready", "device_get", "drain", "flush",
    "maybe_maintain", "run_if_due", "kmeans", "kmeans_assign",
    "scan_probe_round", "host_scan_round", "plan_rounds", "plan_batch",
    "sleep", "join",
}

# Attribute -> owner class, for resolving cross-object lock references
# (``self.cache._lock`` inside ServingRuntime -> ``ResultCache._lock``).
INSTANCE_ATTRS = {
    "scheduler": "RoundScheduler",
    "cache": "ResultCache",
    "maintenance": "MaintenanceScheduler",
    "metrics": "MetricsRegistry",
    "tracer": "QueryTracer",
    "calibration": "CalibrationTracker",
}

# --------------------------------------------------------------------------
# QK301 — swallowed exceptions (docs/serving.md failure semantics)
# --------------------------------------------------------------------------
# Directory (path fragment) the swallow rule applies to: runtime code under
# src/repro/ must never silently drop an exception — every failure is
# counted, degraded-to, retried, or documented with
# ``# quakecheck: allow-swallow(<why>)``.
SWALLOW_DIR_FRAGMENT = "repro"

# --------------------------------------------------------------------------
# QK401 — wall-clock / stdout discipline in core runtime paths
# (docs/observability.md).  Scope: paths with both a "repro" and a "core"
# component (src/repro/core and the fixture twins).  In scope,
# ``time.time()`` and ``print()`` are forbidden: runtime code reads the
# injectable monotonic clock (the ``clock`` parameter on ServingRuntime /
# RoundScheduler / run_round_loop, default ``time.perf_counter``) and
# reports through the metrics registry / trace emitter, so fake-clock
# tests stay deterministic and the serving hot path never writes to
# stdout.  Documented exceptions carry
# ``# quakecheck: allow-wallclock(<why>)``.
RUNTIME_CORE_FRAGMENT = "core"
WALLCLOCK_CALLS = {"time.time"}      # dotted call names (plus bare `time`)
STDOUT_CALLS = {"print"}             # bare call names

# --------------------------------------------------------------------------
# QK302 — durability I/O discipline (docs/durability.md)
# --------------------------------------------------------------------------
# Path fragment the durability rules apply to: a path component equal to
# "durability" (fixture dirs) or starting with "durability." (the module
# itself).  In scope, every write-mode ``open`` must be paired with an
# fsync in the same function (or carry # quakecheck: allow-nosync(<why>)),
# and manifest/checkpoint files must be written via the temp + rename
# idiom, never in place.
DURABILITY_PATH_FRAGMENT = "durability"
# Call leaf names that count as making the write durable.
FSYNC_CALLS = {"fsync", "_fsync", "sync", "fdatasync"}
# Call leaf names that count as the atomic-publish step.
RENAME_CALLS = {"rename", "replace", "renames"}
# Lowercase substrings of a written path literal that mark it as a
# manifest / checkpoint (the files whose partial state must never be
# observable in place).
MANIFEST_HINTS = ("manifest", "ckpt", "checkpoint")

# Guarded fields whose values are immutable scalars: reading them without
# the lock can tear a *snapshot* but can never leak a mutable alias, so
# QK204 (escaping reference) skips them.
SCALAR_GUARDED = {
    "emitted", "dropped",
    "_cache_version", "_maintaining", "_next_qid", "_next_eid",
    "_epoch_key", "hits", "misses", "invalidated", "stale_puts",
    "queries_submitted", "cache_hits", "write_ops", "ops_since",
    "partitions_streamed", "vectors_streamed", "comparisons",
    "rounds_run", "_gen", "_last_version", "_last_cost",
    "shed_queries", "cache_errors", "_cache_disabled", "ticker_errors",
    "ticker_restarts", "ticker_wedged", "maintenance_failures",
    "_overflow_since_flush", "_govern_steps", "_pressure_streak",
    "_calm_streak", "_govern_degrades", "_govern_restores",
    "partials", "failures", "failed_batches", "scan_faults",
    "scan_retries_used", "target", "probe_frac",
}

# Copy-producing wrappers: returning ``list(self._queue)`` (or
# ``.copy()`` / ``deepcopy`` / ``sorted`` / ``dict`` ...) hands the
# caller a private snapshot, not an alias, so QK204 allows it.
COPYING_CALLS = {
    "list", "dict", "tuple", "set", "frozenset", "sorted", "copy",
    "deepcopy", "asarray", "array",
}
