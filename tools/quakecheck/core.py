"""quakecheck engine: registry pre-pass + the five rule families.

The checker is two passes over plain ``ast``:

  1. **Registry pass** over every linted file: collect jitted functions
     (decorated ``@jax.jit`` / ``functools.partial(jax.jit, ...)`` or
     module-level ``name = jax.jit(...)`` aliases) with their static and
     donated arguments — QK101 auto-registers them as device-resident,
     QK102 checks their call sites' static args, QK104 checks their call
     sites' donated operands.
  2. **Rule pass** per file: a lightweight forward taint analysis inside
     device-resident functions (QK101), structural checks for jit-cache
     discipline (QK102), the Pallas kernel contract (QK103),
     donation-after-use (QK104) and serving shared-state mutation
     (QK105).

No third-party dependencies: the linter must run in CI before anything
else is importable.
"""
from __future__ import annotations

import ast
import os
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from . import config
from .pragmas import FilePragmas, parse_pragmas

RULES = {
    "QK100": "malformed pragma (allow-sync/holds require an argument)",
    "QK101": "host sync in device path",
    "QK102": "jit cache fragmentation",
    "QK103": "Pallas kernel contract",
    "QK104": "donation after use",
    "QK105": "serving shared state mutated outside write barrier",
    "QK201": "guarded field accessed without its declared lock held",
    "QK202": "lock acquired against the declared lock order",
    "QK203": "blocking call while holding an admission lock",
    "QK204": "guarded mutable state escapes its lock scope",
    "QK301": "swallowed exception in runtime path",
    "QK302": "durability write without fsync / atomic-rename discipline",
    "QK401": "wall-clock read or print() in core runtime path",
}


@dataclass(frozen=True)
class Finding:
    rule: str
    path: str
    line: int
    col: int
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"


# ---------------------------------------------------------------------------
# small AST helpers
# ---------------------------------------------------------------------------

def dotted(node: ast.AST) -> Optional[str]:
    """'a.b.c' for Name/Attribute chains, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def leaf_name(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return None


def root_name(node: ast.AST) -> Optional[str]:
    while isinstance(node, (ast.Attribute, ast.Subscript)):
        node = node.value
    return node.id if isinstance(node, ast.Name) else None


def const_int_tuple(node: ast.AST) -> Optional[Tuple[int, ...]]:
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for e in node.elts:
            if isinstance(e, ast.Constant) and isinstance(e.value, int):
                out.append(e.value)
            else:
                return None
        return tuple(out)
    return None


def const_str_tuple(node: ast.AST) -> Optional[Tuple[str, ...]]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for e in node.elts:
            if isinstance(e, ast.Constant) and isinstance(e.value, str):
                out.append(e.value)
            else:
                return None
        return tuple(out)
    return None


def _is_jax_jit(node: ast.AST) -> bool:
    d = dotted(node)
    return d in ("jax.jit", "jit", "pjit", "jax.pjit")


# ---------------------------------------------------------------------------
# registry pass
# ---------------------------------------------------------------------------

@dataclass
class JitInfo:
    name: str
    path: str
    line: int
    params: Tuple[str, ...] = ()
    static_names: Set[str] = field(default_factory=set)
    static_nums: Set[int] = field(default_factory=set)
    donate_nums: Set[int] = field(default_factory=set)
    donate_names: Set[str] = field(default_factory=set)
    donate_unknown: bool = False   # dynamic donate expr — skip QK104

    def static_params(self) -> Set[str]:
        out = set(self.static_names)
        for i in self.static_nums:
            if i < len(self.params):
                out.add(self.params[i])
        return out

    def donated_positions(self) -> Set[int]:
        out = set(self.donate_nums)
        for n in self.donate_names:
            if n in self.params:
                out.add(self.params.index(n))
        return out


def _jit_kwargs(call: ast.Call, info: JitInfo) -> None:
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            names = const_str_tuple(kw.value)
            if names:
                info.static_names |= set(names)
        elif kw.arg == "static_argnums":
            nums = const_int_tuple(kw.value)
            if nums:
                info.static_nums |= set(nums)
        elif kw.arg == "donate_argnums":
            nums = const_int_tuple(kw.value)
            if nums is not None:
                info.donate_nums |= set(nums)
            else:
                info.donate_unknown = True
        elif kw.arg == "donate_argnames":
            names = const_str_tuple(kw.value)
            if names is not None:
                info.donate_names |= set(names)
            else:
                info.donate_unknown = True


def _fn_params(fn) -> Tuple[str, ...]:
    a = fn.args
    return tuple(p.arg for p in
                 list(a.posonlyargs) + list(a.args) + list(a.kwonlyargs))


def collect_registry(trees: Dict[str, ast.AST]) -> Dict[str, JitInfo]:
    """name -> JitInfo over all linted files (bare-name matching: the
    stack imports these under their def names)."""
    reg: Dict[str, JitInfo] = {}
    for path, tree in trees.items():
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for dec in node.decorator_list:
                    info = None
                    if _is_jax_jit(dec):
                        info = JitInfo(node.name, path, node.lineno,
                                       _fn_params(node))
                    elif (isinstance(dec, ast.Call)
                          and leaf_name(dec.func) == "partial"
                          and dec.args and _is_jax_jit(dec.args[0])):
                        info = JitInfo(node.name, path, node.lineno,
                                       _fn_params(node))
                        _jit_kwargs(dec, info)
                    elif isinstance(dec, ast.Call) and _is_jax_jit(dec.func):
                        info = JitInfo(node.name, path, node.lineno,
                                       _fn_params(node))
                        _jit_kwargs(dec, info)
                    if info is not None:
                        reg[info.name] = info
            elif isinstance(node, ast.Assign) and len(node.targets) == 1:
                tgt = node.targets[0]
                if (isinstance(tgt, ast.Name)
                        and isinstance(node.value, ast.Call)
                        and _is_jax_jit(node.value.func)):
                    call = node.value
                    params: Tuple[str, ...] = ()
                    if call.args and isinstance(call.args[0], ast.Lambda):
                        params = tuple(
                            p.arg for p in call.args[0].args.args)
                    elif call.args:
                        inner = leaf_name(call.args[0])
                        if inner and inner in reg:
                            params = reg[inner].params
                    info = JitInfo(tgt.id, path, node.lineno, params)
                    _jit_kwargs(call, info)
                    reg[info.name] = info
    return reg


# ---------------------------------------------------------------------------
# QK101 — host sync in device path (forward taint pass)
# ---------------------------------------------------------------------------

class _Taint:
    """Forward may-be-on-device taint over one function body."""

    def __init__(self, fn, path: str, pragmas: FilePragmas,
                 findings: List[Finding], mode: str,
                 initial: Iterable[str] = ()):
        self.fn = fn
        self.path = path
        self.pragmas = pragmas
        self.findings = findings
        self.mode = mode              # "host" (registered) | "jit"
        self.tainted: Set[str] = set(initial)

    # -- expression taint (also emits findings for sync calls) ----------

    def taint_of(self, node: ast.AST) -> bool:
        if isinstance(node, ast.Call):
            return self._call(node)
        if isinstance(node, (ast.Name, ast.Attribute)):
            d = dotted(node)
            if d is not None:
                if d in self.tainted:
                    return True
                head = d.split(".")[0]
                return head in self.tainted
            return isinstance(node, ast.Attribute) and \
                self.taint_of(node.value)
        if isinstance(node, ast.Subscript):
            return self.taint_of(node.value)
        if isinstance(node, ast.BinOp):
            return self.taint_of(node.left) or self.taint_of(node.right)
        if isinstance(node, ast.BoolOp):
            return any(self.taint_of(v) for v in node.values)
        if isinstance(node, ast.Compare):
            return (self.taint_of(node.left)
                    or any(self.taint_of(c) for c in node.comparators))
        if isinstance(node, ast.UnaryOp):
            return self.taint_of(node.operand)
        if isinstance(node, ast.IfExp):
            return self.taint_of(node.body) or self.taint_of(node.orelse)
        if isinstance(node, (ast.Tuple, ast.List)):
            return any(self.taint_of(e) for e in node.elts)
        if isinstance(node, ast.Starred):
            return self.taint_of(node.value)
        return False

    def _args_tainted(self, call: ast.Call) -> bool:
        return (any(self.taint_of(a) for a in call.args)
                or any(self.taint_of(k.value) for k in call.keywords))

    def _flag(self, node: ast.AST, what: str) -> None:
        line = node.lineno
        if self.pragmas.allows_sync(line) \
                or self.pragmas.disabled(line, "QK101"):
            return
        where = self.fn.name
        self.findings.append(Finding(
            "QK101", self.path, line, node.col_offset,
            f"{what} inside device-resident '{where}' — document with "
            f"'# quakecheck: allow-sync(<reason>)' if intentional"))

    def _call(self, call: ast.Call) -> bool:
        fn_dotted = dotted(call.func) or ""
        fn_leaf = leaf_name(call.func) or ""
        fn_root = fn_dotted.split(".")[0] if fn_dotted else ""

        # recurse args first: nested producing calls taint, nested syncs flag
        arg_taint = self._args_tainted(call)

        # explicit sync entry points
        if fn_dotted in config.HOST_SYNC_CALLS or fn_leaf == "device_get":
            if arg_taint or self.mode == "jit":
                self._flag(call, f"host sync ({fn_dotted or fn_leaf}) on a "
                                 f"device value")
            return False
        if isinstance(call.func, ast.Name) \
                and call.func.id in config.HOST_SYNC_BUILTINS:
            if arg_taint:
                self._flag(call, f"host sync ({call.func.id}() "
                                 f"concretizes a device value)")
            return False
        if isinstance(call.func, ast.Attribute) \
                and call.func.attr in config.HOST_SYNC_METHODS:
            if self.taint_of(call.func.value):
                self._flag(call, f".{call.func.attr}() on a device value")
            return False

        # generic numpy call on a device operand = implicit conversion
        if fn_root in ("np", "numpy") and arg_taint:
            self._flag(call, f"implicit device->host conversion "
                             f"({fn_dotted})")
            return False

        # device-producing calls
        if fn_root in ("jnp", "lax"):
            return True
        if fn_root == "jax" and fn_leaf != "device_get":
            return True
        if fn_leaf in config.DEVICE_PRODUCING_CALLS:
            return True
        # unknown call: propagate operand taint conservatively
        return arg_taint

    # -- statements -----------------------------------------------------

    def _bind(self, target: ast.AST, value_taint: bool) -> None:
        if isinstance(target, ast.Name):
            (self.tainted.add if value_taint
             else self.tainted.discard)(target.id)
        elif isinstance(target, ast.Attribute):
            d = dotted(target)
            if d:
                (self.tainted.add if value_taint
                 else self.tainted.discard)(d)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for e in target.elts:
                self._bind(e, value_taint)
        elif isinstance(target, ast.Starred):
            self._bind(target.value, value_taint)
        # subscript stores don't rebind the base

    def run(self, body: Sequence[ast.stmt]) -> None:
        for stmt in body:
            self._stmt(stmt)

    def _stmt(self, stmt: ast.stmt) -> None:
        if isinstance(stmt, ast.Assign):
            t = self.taint_of(stmt.value)
            if (isinstance(stmt.value, ast.Tuple)
                    and len(stmt.targets) == 1
                    and isinstance(stmt.targets[0], (ast.Tuple, ast.List))
                    and len(stmt.targets[0].elts)
                    == len(stmt.value.elts)):
                for tgt, val in zip(stmt.targets[0].elts,
                                    stmt.value.elts):
                    self._bind(tgt, self.taint_of(val))
            else:
                for tgt in stmt.targets:
                    self._bind(tgt, t)
        elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            self._bind(stmt.target, self.taint_of(stmt.value))
        elif isinstance(stmt, ast.AugAssign):
            t = self.taint_of(stmt.value) or self.taint_of(stmt.target)
            self._bind(stmt.target, t)
        elif isinstance(stmt, (ast.Expr, ast.Return)):
            if stmt.value is not None:
                self.taint_of(stmt.value)
        elif isinstance(stmt, (ast.For, ast.AsyncFor)):
            self.taint_of(stmt.iter)
            self._bind(stmt.target, self.taint_of(stmt.iter))
            # two passes: taints introduced late in the body reach uses
            # earlier in the next iteration
            self.run(stmt.body)
            self.run(stmt.body)
            self.run(stmt.orelse)
        elif isinstance(stmt, ast.While):
            self.taint_of(stmt.test)
            self.run(stmt.body)
            self.run(stmt.body)
            self.run(stmt.orelse)
        elif isinstance(stmt, ast.If):
            self.taint_of(stmt.test)
            self.run(stmt.body)
            self.run(stmt.orelse)
        elif isinstance(stmt, (ast.With, ast.AsyncWith)):
            for item in stmt.items:
                self.taint_of(item.context_expr)
            self.run(stmt.body)
        elif isinstance(stmt, ast.Try):
            self.run(stmt.body)
            for h in stmt.handlers:
                self.run(h.body)
            self.run(stmt.orelse)
            self.run(stmt.finalbody)
        elif isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            # nested defs (e.g. scan_round closures) inherit current taint
            inner = _Taint(stmt, self.path, self.pragmas, self.findings,
                           self.mode, initial=set(self.tainted))
            inner.fn = stmt
            inner.run(stmt.body)
        # other statements carry no taint


def _qualname(fn, class_stack: Tuple[str, ...]) -> str:
    return (".".join(class_stack + (fn.name,))
            if class_stack else fn.name)


def check_qk101(tree: ast.AST, path: str, pragmas: FilePragmas,
                registry: Dict[str, JitInfo],
                findings: List[Finding]) -> None:
    def visit(node, class_stack: Tuple[str, ...]):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.ClassDef):
                visit(child, class_stack + (child.name,))
            elif isinstance(child, (ast.FunctionDef,
                                    ast.AsyncFunctionDef)):
                qual = _qualname(child, class_stack)
                short = class_stack[-1] + "." + child.name \
                    if class_stack else child.name
                registered = (
                    child.name in config.DEVICE_RESIDENT_FUNCS
                    or qual in config.DEVICE_RESIDENT_FUNCS
                    or short in config.DEVICE_RESIDENT_FUNCS
                    or pragmas.device_path(child.lineno))
                jit = registry.get(child.name)
                jitted = jit is not None and jit.path == path \
                    and jit.line == child.lineno
                if jitted:
                    statics = jit.static_params()
                    initial = [p for p in _fn_params(child)
                               if p not in statics and p != "self"]
                    t = _Taint(child, path, pragmas, findings, "jit",
                               initial)
                    t.run(child.body)
                elif registered:
                    t = _Taint(child, path, pragmas, findings, "host")
                    t.run(child.body)
                else:
                    visit(child, class_stack)   # look for nested defs
            else:
                visit(child, class_stack)

    visit(tree, ())


# ---------------------------------------------------------------------------
# QK102 — jit cache fragmentation
# ---------------------------------------------------------------------------

def _expr_mentions(node: ast.AST, pred) -> bool:
    return any(pred(n) for n in ast.walk(node))


def _is_bucket_hint(node: ast.AST) -> bool:
    name = None
    if isinstance(node, ast.Name):
        name = node.id
    elif isinstance(node, ast.Attribute):
        name = node.attr
    elif isinstance(node, ast.Call):
        name = leaf_name(node.func)
        if name in config.BUCKET_CALLS:
            return True
        name = None
    if name is None:
        return False
    low = name.lower()
    return any(h in low for h in config.BUCKET_HINT_NAMES)


def _is_data_reducer(node: ast.AST) -> bool:
    # Only method/np-style reducers (counts.max(), np.unique(x)) count:
    # builtin min(k, x.shape[0]) is shape math, not data-dependent.
    return (isinstance(node, ast.Call)
            and isinstance(node.func, ast.Attribute)
            and node.func.attr in config.DATA_DEPENDENT_REDUCERS)


class _AssignIndex(ast.NodeVisitor):
    """name -> last assigned expression, per enclosing function."""

    def __init__(self):
        self.assigns: Dict[str, ast.AST] = {}

    def visit_Assign(self, node: ast.Assign):
        for tgt in node.targets:
            if isinstance(tgt, ast.Name):
                self.assigns[tgt.id] = node.value
        self.generic_visit(node)


def _resolve_props(expr: ast.AST, assigns: Dict[str, ast.AST],
                   depth: int = 0, seen: Optional[Set[str]] = None
                   ) -> Tuple[bool, bool]:
    """(data_dependent, bucketed) for an expression, chasing local
    assignments a few levels deep."""
    seen = seen or set()
    dd = _expr_mentions(expr, _is_data_reducer)
    bk = _expr_mentions(expr, _is_bucket_hint)
    if depth >= 5:
        return dd, bk
    for n in ast.walk(expr):
        if isinstance(n, ast.Name) and n.id in assigns \
                and n.id not in seen:
            seen.add(n.id)
            d2, b2 = _resolve_props(assigns[n.id], assigns,
                                    depth + 1, seen)
            dd = dd or d2
            bk = bk or b2
    return dd, bk


def check_qk102(tree: ast.AST, path: str, pragmas: FilePragmas,
                registry: Dict[str, JitInfo],
                findings: List[Finding]) -> None:
    def flag(node, msg):
        if not pragmas.disabled(node.lineno, "QK102"):
            findings.append(Finding("QK102", path, node.lineno,
                                    node.col_offset, msg))

    # (a) per-call jit construction
    loop_stack: List[ast.AST] = []

    def walk(node, in_loop: bool):
        for child in ast.iter_child_nodes(node):
            child_in_loop = in_loop or isinstance(
                child, (ast.For, ast.While, ast.AsyncFor))
            if isinstance(child, ast.Call):
                if _is_jax_jit(child.func):
                    if in_loop:
                        flag(child, "jax.jit constructed inside a loop — "
                                    "a fresh compile cache every "
                                    "iteration; hoist it out")
                elif isinstance(child.func, ast.Call) \
                        and _is_jax_jit(child.func.func):
                    flag(child, "jax.jit(...)(...) immediately invoked — "
                                "the cache is discarded after one call; "
                                "bind the jitted callable once")
            walk(child, child_in_loop)

    walk(tree, False)

    # (b)+(c) static-argument discipline at call sites of known-jitted fns
    for fn in [n for n in ast.walk(tree)
               if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]:
        idx = _AssignIndex()
        idx.visit(fn)
        for call in [n for n in ast.walk(fn) if isinstance(n, ast.Call)]:
            name = leaf_name(call.func)
            info = registry.get(name or "")
            if info is None:
                continue
            statics = info.static_params()
            static_exprs: List[Tuple[str, ast.AST]] = []
            for kw in call.keywords:
                if kw.arg in statics:
                    static_exprs.append((kw.arg, kw.value))
            for i, arg in enumerate(call.args):
                if i in info.static_nums or (
                        i < len(info.params)
                        and info.params[i] in statics):
                    static_exprs.append((info.params[i]
                                         if i < len(info.params)
                                         else f"arg{i}", arg))
            for pname, expr in static_exprs:
                if isinstance(expr, (ast.List, ast.Dict, ast.Set)) or (
                        isinstance(expr, ast.Call)
                        and dotted(expr.func) in ("np.array",
                                                  "np.asarray")):
                    flag(expr, f"unhashable static argument "
                               f"'{pname}' to jitted '{name}' — every "
                               f"call re-traces")
                    continue
                dd, bk = _resolve_props(expr, idx.assigns)
                if dd and not bk:
                    flag(expr,
                         f"data-dependent static argument '{pname}' to "
                         f"jitted '{name}' without a padding bucket — "
                         f"every distinct value compiles a new "
                         f"executable; round it through a bucket "
                         f"(u_bucket/_next_pow2/_pad_to)")


# ---------------------------------------------------------------------------
# QK103 — Pallas kernel contract
# ---------------------------------------------------------------------------

def _has_f32_cast(call: ast.Call) -> bool:
    for n in ast.walk(call):
        if isinstance(n, ast.Attribute) and n.attr == "astype":
            return True
        if isinstance(n, ast.Attribute) and n.attr in ("float32",):
            return True
    return False


def check_qk103(tree: ast.AST, path: str, pragmas: FilePragmas,
                findings: List[Finding]) -> None:
    parts = path.replace(os.sep, "/").split("/")
    if config.KERNELS_DIR_FRAGMENT not in parts:
        return
    is_compat = os.path.basename(path) == config.PALLAS_COMPAT_FILE

    def flag(node, msg):
        if not pragmas.disabled(node.lineno, "QK103"):
            findings.append(Finding("QK103", path, node.lineno,
                                    node.col_offset, msg))

    # (a) version-churned pltpu names only through pallas_compat
    if not is_compat:
        for node in ast.walk(tree):
            if isinstance(node, ast.Attribute) \
                    and node.attr in config.PLTPU_COMPAT_ONLY \
                    and root_name(node.value or node) in ("pltpu",):
                flag(node, f"direct pltpu.{node.attr} — dispatch through "
                           f"kernels/pallas_compat.py (the one-file "
                           f"version seam)")
            if isinstance(node, (ast.ImportFrom,)) and node.module \
                    and "pallas" in node.module:
                for alias in node.names:
                    if alias.name in config.PLTPU_COMPAT_ONLY:
                        flag(node, f"importing {alias.name} directly — "
                                   f"use kernels/pallas_compat.py")

    for fn in [n for n in ast.walk(tree)
               if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]:
        src_calls = [n for n in ast.walk(fn) if isinstance(n, ast.Call)]
        is_launcher = any(
            leaf_name(c.func) in ("pallas_call",
                                  "prefetch_scalar_grid_spec")
            for c in src_calls)
        is_kernel_body = fn.name.endswith("_kernel")
        q8 = "q8" in fn.name or "int8" in fn.name

        # (b) launchers must carry a divisibility / padding guard
        if is_launcher and not is_compat:
            has_guard = False
            for n in ast.walk(fn):
                if isinstance(n, ast.Assert) and any(
                        isinstance(m, ast.Mod)
                        for m in ast.walk(n.test)
                        if isinstance(m, ast.operator) or
                        isinstance(m, ast.Mod)):
                    has_guard = True
                if isinstance(n, ast.Assert):
                    for m in ast.walk(n.test):
                        if isinstance(m, ast.BinOp) \
                                and isinstance(m.op, ast.Mod):
                            has_guard = True
                if isinstance(n, ast.Call) \
                        and leaf_name(n.func) in config.BUCKET_CALLS:
                    has_guard = True
                if isinstance(n, (ast.While, ast.If)):
                    for m in ast.walk(n.test if hasattr(n, "test")
                                      else n):
                        if isinstance(m, ast.BinOp) \
                                and isinstance(m.op, ast.Mod):
                            has_guard = True
            if not has_guard:
                flag(fn, f"'{fn.name}' launches a Pallas kernel without "
                         f"a tile-divisibility guard (assert X % block "
                         f"== 0, or pad via _pad_to/_next_pow2) — "
                         f"non-dividing grids truncate silently")

        # (c) int8 paths accumulate in int32
        if q8:
            for c in src_calls:
                if leaf_name(c.func) in ("dot_general", "dot", "matmul",
                                         "einsum"):
                    pet = None
                    for kw in c.keywords:
                        if kw.arg == "preferred_element_type":
                            pet = leaf_name(kw.value)
                    if pet is None and _has_f32_cast(c):
                        continue    # explicit dequant-to-f32 operand
                    if pet != "int32":
                        flag(c, f"int8 kernel '{fn.name}' runs a dot "
                                f"without preferred_element_type="
                                f"jnp.int32 — int8 accumulation "
                                f"overflows at d>=128")

        # (d) no f64 inside kernel bodies
        if is_kernel_body:
            for n in ast.walk(fn):
                bad = (isinstance(n, ast.Attribute)
                       and n.attr == "float64") or (
                    isinstance(n, ast.Constant)
                    and n.value == "float64")
                if bad:
                    flag(n, f"float64 inside kernel body '{fn.name}' — "
                            f"TPUs have no f64; use f32 accumulation")


# ---------------------------------------------------------------------------
# QK104 — donation after use
# ---------------------------------------------------------------------------

def check_qk104(tree: ast.AST, path: str, pragmas: FilePragmas,
                registry: Dict[str, JitInfo],
                findings: List[Finding]) -> None:
    donators = {n: i for n, i in registry.items()
                if (i.donate_nums or i.donate_names)
                and not i.donate_unknown}
    if not donators:
        return

    for fn in [n for n in ast.walk(tree)
               if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]:
        # collect (call, donated dotted names, store-lines, load-lines)
        stores: Dict[str, List[int]] = {}
        loads: Dict[str, List[int]] = {}
        calls: List[Tuple[ast.Call, List[str]]] = []
        for node in ast.walk(fn):
            if isinstance(node, (ast.Name, ast.Attribute)):
                d = dotted(node)
                if d is None:
                    continue
                ctx = getattr(node, "ctx", None)
                if isinstance(ctx, ast.Store):
                    stores.setdefault(d, []).append(node.lineno)
                elif isinstance(ctx, ast.Load):
                    loads.setdefault(d, []).append(node.lineno)
            if isinstance(node, ast.Call):
                info = donators.get(leaf_name(node.func) or "")
                if info is None:
                    continue
                donated: List[str] = []
                for pos in info.donated_positions():
                    if pos < len(node.args):
                        d = dotted(node.args[pos])
                        if d:
                            donated.append(d)
                for kw in node.keywords:
                    if kw.arg in info.donate_names:
                        d = dotted(kw.value)
                        if d:
                            donated.append(d)
                if donated:
                    calls.append((node, donated))
        for call, donated in calls:
            if pragmas.disabled(call.lineno, "QK104"):
                continue
            for name in donated:
                # attribute loads of the *donated buffer's fields* count
                use_lines = [ln for d, lns in loads.items()
                             if d == name or d.startswith(name + ".")
                             for ln in lns if ln > call.lineno]
                if not use_lines:
                    continue
                first_use = min(use_lines)
                rebinds = [ln for ln in stores.get(name, ())
                           if call.lineno <= ln <= first_use]
                if not rebinds:
                    findings.append(Finding(
                        "QK104", path, first_use, 0,
                        f"'{name}' donated to jitted "
                        f"'{leaf_name(call.func)}' at line "
                        f"{call.lineno} is read again here — the "
                        f"buffer is invalidated by donation; copy "
                        f"first or drop donate_argnums"))


# ---------------------------------------------------------------------------
# QK105 — serving shared state outside the write barrier
# ---------------------------------------------------------------------------

def _owners_of(attr: str) -> List[str]:
    return [cls for cls, attrs in config.GUARDED_STATE.items()
            if attr in attrs]


def check_qk105(tree: ast.AST, path: str, pragmas: FilePragmas,
                findings: List[Finding]) -> None:
    def flag(node, attr, how):
        if pragmas.disabled(node.lineno, "QK105"):
            return
        owners = " / ".join(_owners_of(attr))
        findings.append(Finding(
            "QK105", path, node.lineno, node.col_offset,
            f"{how} of write-barrier-guarded field '.{attr}' "
            f"(owned by {owners}) outside the owning class — route "
            f"through the owner's API (docs/serving.md write-barrier "
            f"discipline)"))

    def guarded_attr_node(node) -> Optional[ast.Attribute]:
        """The guarded Attribute being mutated, unwrapping subscripts."""
        while isinstance(node, ast.Subscript):
            node = node.value
        if isinstance(node, ast.Attribute) \
                and node.attr in config.GUARDED_ATTRS:
            return node
        return None

    def allowed(attr_node: ast.Attribute,
                class_stack: Tuple[str, ...]) -> bool:
        # A class mutating its own ``self.X`` is the owner's prerogative
        # (the linter cannot infer types; guarded-state violations are
        # cross-object, e.g. ``self.scheduler.done.clear()``).
        base = attr_node.value
        return isinstance(base, ast.Name) and base.id == "self"

    def visit(node, class_stack: Tuple[str, ...]):
        for child in ast.iter_child_nodes(node):
            stack = class_stack
            if isinstance(child, ast.ClassDef):
                stack = class_stack + (child.name,)
            if isinstance(child, (ast.Assign, ast.AugAssign,
                                  ast.AnnAssign, ast.Delete)):
                targets = (child.targets
                           if isinstance(child, (ast.Assign, ast.Delete))
                           else [child.target])
                for tgt in targets:
                    g = guarded_attr_node(tgt)
                    if g is not None and not allowed(g, class_stack):
                        flag(child, g.attr,
                             "augmented write" if isinstance(
                                 child, ast.AugAssign) else "write")
            elif isinstance(child, ast.Call) \
                    and isinstance(child.func, ast.Attribute) \
                    and child.func.attr in config.MUTATING_METHODS:
                g = guarded_attr_node(child.func.value)
                if g is not None and not allowed(g, class_stack):
                    flag(child, g.attr,
                         f"mutating call .{child.func.attr}()")
            visit(child, stack)

    visit(tree, ())


# ---------------------------------------------------------------------------
# QK2xx — lock discipline & happens-before (concurrency rule family)
# ---------------------------------------------------------------------------
#
# Intra-procedural lock-set analysis over the methods of every class that
# owns ``config.GUARDED_BY`` state (the concurrency layer on top of
# QK105's *who-writes* check):
#
#   QK201  access to a guarded ``self.<field>`` while the field's
#          declared lock is not in the lock-set
#   QK202  acquiring a lock while holding one that is *later* in
#          ``config.LOCK_ORDER``
#   QK203  a ``config.BLOCKING_CALLS`` call while an admission lock
#          (``config.ADMISSION_LOCKS``) is held
#   QK204  a guarded mutable field returned raw or stored into another
#          object (the alias outlives the lock scope)
#
# The lock-set is seeded from ``@guarded_by("<lock>")`` decorators and
# def-line ``# quakecheck: holds(<lock>)`` pragmas, grows through
# ``with self._lock:`` blocks and linear ``acquire()``/``release()``
# pairs, and propagates into ``_``-private helpers as the intersection
# of the lock-sets at their intra-class call sites (fixpoint).

_ORDER_INDEX = {name: i for i, name in enumerate(config.LOCK_ORDER)}


def _qualify_lock(name: str, cls: str) -> str:
    return name if "." in name else f"{cls}.{name}"


def _resolve_lock(node: ast.AST, cls: str) -> Optional[str]:
    """Qualified lock name for an acquisition expression, or None.

    ``self._lock`` -> ``<cls>._lock``; ``self.cache._lock`` resolves the
    intermediate attribute through ``config.INSTANCE_ATTRS``.
    """
    if not isinstance(node, ast.Attribute):
        return None
    attr = node.attr
    base = node.value
    if isinstance(base, ast.Name) and base.id == "self":
        return f"{cls}.{attr}"
    if (isinstance(base, ast.Attribute)
            and isinstance(base.value, ast.Name)
            and base.value.id == "self"
            and base.attr in config.INSTANCE_ATTRS):
        return f"{config.INSTANCE_ATTRS[base.attr]}.{attr}"
    return None


def _is_lockish(name: Optional[str]) -> bool:
    return name is not None and ("lock" in name.rsplit(".", 1)[-1].lower())


def _guarded_by_decorator_locks(fn, cls: str) -> Set[str]:
    out: Set[str] = set()
    for dec in fn.decorator_list:
        if (isinstance(dec, ast.Call)
                and leaf_name(dec.func) == "guarded_by"
                and dec.args
                and isinstance(dec.args[0], ast.Constant)
                and isinstance(dec.args[0].value, str)):
            out.add(_qualify_lock(dec.args[0].value, cls))
    return out


def _copy_wrapped(node: ast.AST) -> bool:
    """True when ``node`` is a copy-producing call (``list(...)``,
    ``x.copy()``, ``np.asarray(...)`` ...)."""
    if isinstance(node, ast.Call):
        name = leaf_name(node.func)
        return name in config.COPYING_CALLS
    return False


class _ClassLockAnalysis:
    """QK201-QK204 over one class body."""

    def __init__(self, cls: ast.ClassDef, path: str, pragmas: FilePragmas,
                 findings: List[Finding]):
        self.cls = cls
        self.name = cls.name
        self.path = path
        self.pragmas = pragmas
        self.findings = findings
        self.guarded: Dict[str, str] = {
            f: _qualify_lock(l, self.name)
            for f, l in config.GUARDED_BY.get(self.name, {}).items()}
        self.methods: Dict[str, ast.FunctionDef] = {
            n.name: n for n in cls.body
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}
        # helper name -> lock-sets observed at intra-class call sites
        self.callsites: Dict[str, List[frozenset]] = {}
        self.seeds: Dict[str, Set[str]] = {}
        self.emit = False

    # -- seeds ---------------------------------------------------------

    def _explicit_seed(self, fn) -> Set[str]:
        seed = _guarded_by_decorator_locks(fn, self.name)
        seed |= {_qualify_lock(l, self.name)
                 for l in self.pragmas.holds(fn.lineno)}
        return seed

    def run(self) -> None:
        for name, fn in self.methods.items():
            self.seeds[name] = self._explicit_seed(fn)
        # fixpoint: helper seeds grow from call-site intersections; each
        # round re-records call sites under the latest seeds
        for _ in range(10):
            self.callsites = {}
            for fn in self.methods.values():
                self._walk_fn(fn)
            changed = False
            for name, sites in self.callsites.items():
                if name not in self.methods or not name.startswith("_") \
                        or name.startswith("__"):
                    continue
                inter = frozenset.intersection(*sites) if sites \
                    else frozenset()
                new = self._explicit_seed(self.methods[name]) | set(inter)
                if new != self.seeds.get(name):
                    self.seeds[name] = new
                    changed = True
            if not changed:
                break
        self.emit = True
        for fn in self.methods.values():
            self._walk_fn(fn)

    # -- traversal -----------------------------------------------------

    def _walk_fn(self, fn) -> None:
        self._fn = fn
        self._walk_block(fn.body, set(self.seeds.get(fn.name, ())))

    def _held_at(self, line: int, held: Set[str]) -> Set[str]:
        extra = {_qualify_lock(l, self.name)
                 for l in self.pragmas.holds(line)}
        return held | extra

    def _flag(self, rule: str, node: ast.AST, msg: str) -> None:
        if not self.emit:
            return
        if self.pragmas.disabled(node.lineno, rule):
            return
        self.findings.append(Finding(rule, self.path, node.lineno,
                                     node.col_offset, msg))

    def _acquire(self, lock: str, node: ast.AST, held: Set[str]) -> None:
        if lock in held:          # RLock re-entry
            return
        ni = _ORDER_INDEX.get(lock)
        if ni is not None:
            for h in self._held_at(node.lineno, held):
                hi = _ORDER_INDEX.get(h)
                if hi is not None and hi > ni:
                    self._flag(
                        "QK202", node,
                        f"acquiring '{lock}' while holding '{h}' "
                        f"inverts the declared lock order "
                        f"({' -> '.join(config.LOCK_ORDER)}); take "
                        f"'{lock}' first or release '{h}'")

    def _walk_block(self, stmts: Sequence[ast.stmt],
                    held: Set[str]) -> None:
        held = set(held)
        for stmt in stmts:
            # linear acquire()/release() tracking at block level
            if isinstance(stmt, ast.Expr) and isinstance(stmt.value,
                                                         ast.Call):
                call = stmt.value
                if isinstance(call.func, ast.Attribute):
                    lk = _resolve_lock(call.func.value, self.name)
                    if call.func.attr == "acquire" and _is_lockish(lk):
                        self._acquire(lk, stmt, held)
                        self._scan_exprs(stmt, held)
                        held.add(lk)
                        continue
                    if call.func.attr == "release" and _is_lockish(lk):
                        held.discard(lk)
                        continue
            self._stmt(stmt, held)

    def _stmt(self, stmt: ast.stmt, held: Set[str]) -> None:
        if isinstance(stmt, (ast.With, ast.AsyncWith)):
            inner = set(held)
            for item in stmt.items:
                lk = _resolve_lock(item.context_expr, self.name)
                if _is_lockish(lk):
                    self._acquire(lk, item.context_expr, inner)
                    inner.add(lk)
                else:
                    self._scan_expr(item.context_expr, held)
            self._walk_block(stmt.body, inner)
        elif isinstance(stmt, ast.If):
            self._scan_expr(stmt.test, held)
            self._walk_block(stmt.body, held)
            self._walk_block(stmt.orelse, held)
        elif isinstance(stmt, ast.While):
            self._scan_expr(stmt.test, held)
            self._walk_block(stmt.body, held)
            self._walk_block(stmt.orelse, held)
        elif isinstance(stmt, (ast.For, ast.AsyncFor)):
            self._scan_expr(stmt.iter, held)
            self._walk_block(stmt.body, held)
            self._walk_block(stmt.orelse, held)
        elif isinstance(stmt, ast.Try):
            self._walk_block(stmt.body, held)
            for h in stmt.handlers:
                self._walk_block(h.body, held)
            self._walk_block(stmt.orelse, held)
            self._walk_block(stmt.finalbody, held)
        elif isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            # nested def (closure): deferred execution — it runs under
            # whatever locks its *caller* holds, so analyze with its own
            # explicit seeds only (annotate with holds()/guarded_by)
            self._walk_block(stmt.body, self._explicit_seed(stmt))
        elif isinstance(stmt, ast.ClassDef):
            pass
        else:
            # simple statement: scan every expression node it contains
            for node in ast.walk(stmt):
                self._scan_node(node, held)
            self._qk204(stmt, held)

    def _scan_expr(self, expr: ast.AST, held: Set[str]) -> None:
        for node in ast.walk(expr):
            self._scan_node(node, held)

    def _scan_node(self, n: ast.AST, held: Set[str]) -> None:
        # QK201 — guarded self.<field> access outside the lock
        if (isinstance(n, ast.Attribute)
                and isinstance(n.value, ast.Name)
                and n.value.id == "self"
                and n.attr in self.guarded
                and self._fn.name not in ("__init__", "__new__")):
            lock = self.guarded[n.attr]
            eff = self._held_at(n.lineno, held)
            if lock not in eff:
                self._flag(
                    "QK201", n,
                    f"'self.{n.attr}' is guarded by '{lock}' "
                    f"(config.GUARDED_BY) but the lock-set here is "
                    f"{sorted(eff) if eff else '{}'} — wrap the access "
                    f"in 'with self.{lock.rsplit('.', 1)[-1]}:' or "
                    f"document the carrier with "
                    f"'# quakecheck: holds({lock})'")
        # QK203 — blocking call under an admission lock; helper call
        # sites recorded for seed propagation
        if isinstance(n, ast.Call):
            cname = leaf_name(n.func)
            if cname in config.BLOCKING_CALLS:
                eff = self._held_at(n.lineno, held)
                adm = eff & config.ADMISSION_LOCKS
                if adm:
                    self._flag(
                        "QK203", n,
                        f"blocking call '{cname}()' while holding "
                        f"admission lock '{sorted(adm)[0]}' — every "
                        f"concurrent submit_* caller stalls behind it; "
                        f"move the blocking work outside the lock "
                        f"(engine-lock scope)")
            if (isinstance(n.func, ast.Attribute)
                    and isinstance(n.func.value, ast.Name)
                    and n.func.value.id == "self"
                    and n.func.attr in self.methods):
                self.callsites.setdefault(n.func.attr, []).append(
                    frozenset(self._held_at(n.lineno, held)))

    def _guarded_mutable_attr(self, node: ast.AST) -> Optional[str]:
        if (isinstance(node, ast.Attribute)
                and isinstance(node.value, ast.Name)
                and node.value.id == "self"
                and node.attr in self.guarded
                and node.attr not in config.SCALAR_GUARDED):
            return node.attr
        return None

    def _qk204(self, stmt: ast.stmt, held: Set[str]) -> None:
        if self._fn.name in ("__init__", "__new__"):
            return
        if isinstance(stmt, ast.Return) and stmt.value is not None:
            attr = self._guarded_mutable_attr(stmt.value)
            if attr is not None and not _copy_wrapped(stmt.value):
                self._flag(
                    "QK204", stmt,
                    f"returning guarded mutable 'self.{attr}' hands the "
                    f"caller an alias that outlives "
                    f"'{self.guarded[attr]}' — return a copy "
                    f"(list/dict/.copy()) or transfer ownership by "
                    f"rebinding the field first")
        elif isinstance(stmt, ast.Assign):
            attr = self._guarded_mutable_attr(stmt.value)
            if attr is None:
                return
            for tgt in stmt.targets:
                if (isinstance(tgt, ast.Attribute)
                        and not (isinstance(tgt.value, ast.Name)
                                 and tgt.value.id == "self")):
                    self._flag(
                        "QK204", stmt,
                        f"storing guarded mutable 'self.{attr}' into "
                        f"'{dotted(tgt) or 'another object'}' escapes "
                        f"'{self.guarded[attr]}' — store a copy")


def check_qk2xx(tree: ast.AST, path: str, pragmas: FilePragmas,
                findings: List[Finding]) -> None:
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef):
            _ClassLockAnalysis(node, path, pragmas, findings).run()


# ---------------------------------------------------------------------------
# QK301 — swallowed exceptions in runtime paths (docs/serving.md failure
# semantics: every failure is terminal-status-counted, degraded-to, or
# retried — never silently dropped).  Scoped to config.SWALLOW_DIR_FRAGMENT
# paths; an intentional drop carries # quakecheck: allow-swallow(<why>).
# ---------------------------------------------------------------------------

_BROAD_EXC_NAMES = {"Exception", "BaseException"}


def _handler_only_drops(handler: ast.ExceptHandler) -> bool:
    """True when the handler body does nothing but discard the error:
    ``pass`` / ``...`` / ``continue`` statements only."""
    for stmt in handler.body:
        if isinstance(stmt, (ast.Pass, ast.Continue)):
            continue
        if (isinstance(stmt, ast.Expr)
                and isinstance(stmt.value, ast.Constant)
                and stmt.value.value is Ellipsis):
            continue
        return False
    return True


def _handler_reraises(handler: ast.ExceptHandler) -> bool:
    return any(isinstance(n, ast.Raise) for n in ast.walk(handler))


def _broad_exc_caught(type_node: ast.AST) -> bool:
    nodes = (type_node.elts if isinstance(type_node, ast.Tuple)
             else [type_node])
    return any(leaf_name(n) in _BROAD_EXC_NAMES for n in nodes)


def check_qk301(tree: ast.AST, path: str, pragmas: FilePragmas,
                findings: List[Finding]) -> None:
    parts = path.replace(os.sep, "/").split("/")
    if config.SWALLOW_DIR_FRAGMENT not in parts:
        return

    def flag(node, msg):
        if pragmas.disabled(node.lineno, "QK301"):
            return
        if pragmas.allows_swallow(node.lineno):
            return
        findings.append(Finding("QK301", path, node.lineno,
                                node.col_offset, msg))

    for node in ast.walk(tree):
        if not isinstance(node, ast.Try):
            continue
        for h in node.handlers:
            if h.type is None:
                if not _handler_reraises(h):
                    flag(h, "bare 'except:' swallows everything "
                            "(including KeyboardInterrupt) — catch a "
                            "concrete exception, re-raise, or document "
                            "with # quakecheck: allow-swallow(<why>)")
            elif _broad_exc_caught(h.type) and _handler_only_drops(h):
                flag(h, "broad exception handler silently drops the "
                        "error — count it, log it, degrade, or document "
                        "with # quakecheck: allow-swallow(<why>)")


# ---------------------------------------------------------------------------
# QK302 — durability I/O discipline (docs/durability.md).  Scoped to
# config.DURABILITY_PATH_FRAGMENT paths; in scope, a write-mode open()
# must be paired with an fsync in the same function (a write the OS may
# still be buffering is not durable), and manifest/checkpoint files must
# be published via temp + rename, never written in place.  An intentional
# unsynced write carries # quakecheck: allow-nosync(<why>).
# ---------------------------------------------------------------------------

_WRITE_MODE_CHARS = set("wax+")


def _in_durability_path(path: str) -> bool:
    parts = path.replace(os.sep, "/").split("/")
    frag = config.DURABILITY_PATH_FRAGMENT
    return any(p == frag or p.startswith(frag + ".") for p in parts)


def _open_write_mode(call: ast.Call) -> bool:
    """True for ``open(..., mode)`` calls whose mode literal writes."""
    if leaf_name(call.func) != "open":
        return False
    mode: Optional[ast.AST] = None
    if len(call.args) >= 2:
        mode = call.args[1]
    for kw in call.keywords:
        if kw.arg == "mode":
            mode = kw.value
    if not (isinstance(mode, ast.Constant) and isinstance(mode.value, str)):
        return False   # default "r", or dynamic — not provably a write
    return bool(_WRITE_MODE_CHARS & set(mode.value))


def _path_arg_hints_manifest(call: ast.Call) -> bool:
    """True when the path operand of ``open`` contains a string literal
    naming a manifest/checkpoint (config.MANIFEST_HINTS)."""
    target: Optional[ast.AST] = call.args[0] if call.args else None
    for kw in call.keywords:
        if kw.arg == "file":
            target = kw.value
    if target is None:
        return False
    for n in ast.walk(target):
        if isinstance(n, ast.Constant) and isinstance(n.value, str):
            low = n.value.lower()
            if any(h in low for h in config.MANIFEST_HINTS):
                return True
    return False


def _shallow_nodes(func: ast.AST) -> Iterable[ast.AST]:
    """Walk a function body without descending into nested defs/classes —
    the pairing contract is per-function."""
    stack = list(func.body)
    while stack:
        node = stack.pop()
        yield node
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            continue
        stack.extend(ast.iter_child_nodes(node))


def check_qk302(tree: ast.AST, path: str, pragmas: FilePragmas,
                findings: List[Finding]) -> None:
    if not _in_durability_path(path):
        return

    for func in ast.walk(tree):
        if not isinstance(func, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        write_opens: List[ast.Call] = []
        has_fsync = False
        has_rename = False
        for node in _shallow_nodes(func):
            if not isinstance(node, ast.Call):
                continue
            if _open_write_mode(node):
                write_opens.append(node)
            name = leaf_name(node.func)
            if name in config.FSYNC_CALLS:
                has_fsync = True
            if name in config.RENAME_CALLS:
                has_rename = True
        for call in write_opens:
            if pragmas.disabled(call.lineno, "QK302"):
                continue
            if not has_fsync and not pragmas.allows_nosync(call.lineno):
                findings.append(Finding(
                    "QK302", path, call.lineno, call.col_offset,
                    f"write-mode open in '{func.name}' with no fsync in "
                    "the same function — an unsynced write is not "
                    "durable: fsync before closing, or document with "
                    "# quakecheck: allow-nosync(<why>)"))
            if _path_arg_hints_manifest(call) and not has_rename:
                findings.append(Finding(
                    "QK302", path, call.lineno, call.col_offset,
                    f"manifest/checkpoint written in place in "
                    f"'{func.name}' — a crash mid-write leaves a torn "
                    "file that validates as the newest state: write to "
                    "a temp name and publish with os.rename/os.replace"))


# ---------------------------------------------------------------------------
# QK401 — wall-clock / stdout discipline (docs/observability.md).  Scoped
# to core runtime paths (a "repro" and a "core" path component): latency
# accounting must come from the injectable monotonic clock so fake-clock
# tests stay deterministic, and the serving hot path reports through the
# metrics registry / trace emitter, never stdout.  Documented exceptions
# carry # quakecheck: allow-wallclock(<why>).
# ---------------------------------------------------------------------------

def check_qk401(tree: ast.AST, path: str, pragmas: FilePragmas,
                findings: List[Finding]) -> None:
    parts = path.replace(os.sep, "/").split("/")
    if (config.SWALLOW_DIR_FRAGMENT not in parts
            or config.RUNTIME_CORE_FRAGMENT not in parts):
        return

    def flag(node, msg):
        if pragmas.disabled(node.lineno, "QK401"):
            return
        if pragmas.allows_wallclock(node.lineno):
            return
        findings.append(Finding("QK401", path, node.lineno,
                                node.col_offset, msg))

    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        name = dotted(node.func)
        if name in config.WALLCLOCK_CALLS or (
                isinstance(node.func, ast.Name) and node.func.id == "time"):
            flag(node, "wall-clock read in a core runtime path — take the "
                       "injectable monotonic clock (the `clock` parameter, "
                       "default time.perf_counter) so fake-clock tests and "
                       "latency accounting stay deterministic, or document "
                       "with # quakecheck: allow-wallclock(<why>)")
        elif (isinstance(node.func, ast.Name)
                and node.func.id in config.STDOUT_CALLS):
            flag(node, "print() in a core runtime path — report through "
                       "the metrics registry / trace emitter "
                       "(docs/observability.md), or document with "
                       "# quakecheck: allow-wallclock(<why>)")


# ---------------------------------------------------------------------------
# QK100 — malformed pragmas
# ---------------------------------------------------------------------------

def check_qk100(path: str, pragmas: FilePragmas,
                findings: List[Finding]) -> None:
    for line, p in pragmas.by_line.items():
        if p.allow_sync and not p.allow_sync_reason.strip():
            findings.append(Finding(
                "QK100", path, line, 0,
                "allow-sync pragma without a reason — intentional syncs "
                "must be documented: # quakecheck: allow-sync(<why>)"))
        if p.allow_swallow and not p.allow_swallow_reason.strip():
            findings.append(Finding(
                "QK100", path, line, 0,
                "allow-swallow pragma without a reason — intentional "
                "swallows must be documented: "
                "# quakecheck: allow-swallow(<why>)"))
        if p.allow_nosync and not p.allow_nosync_reason.strip():
            findings.append(Finding(
                "QK100", path, line, 0,
                "allow-nosync pragma without a reason — intentional "
                "unsynced writes must be documented: "
                "# quakecheck: allow-nosync(<why>)"))
        if p.allow_wallclock and not p.allow_wallclock_reason.strip():
            findings.append(Finding(
                "QK100", path, line, 0,
                "allow-wallclock pragma without a reason — intentional "
                "wall-clock reads must be documented: "
                "# quakecheck: allow-wallclock(<why>)"))
        if p.bad_holds:
            findings.append(Finding(
                "QK100", path, line, 0,
                "holds() pragma names no lock — declare the carrier: "
                "# quakecheck: holds(<lock>)"))


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def lint_source(source: str, path: str,
                registry: Optional[Dict[str, JitInfo]] = None,
                select: Optional[Set[str]] = None) -> List[Finding]:
    tree = ast.parse(source, filename=path)
    pragmas = parse_pragmas(source)
    if registry is None:
        registry = collect_registry({path: tree})
    findings: List[Finding] = []
    check_qk100(path, pragmas, findings)
    check_qk101(tree, path, pragmas, registry, findings)
    check_qk102(tree, path, pragmas, registry, findings)
    check_qk103(tree, path, pragmas, findings)
    check_qk104(tree, path, pragmas, registry, findings)
    check_qk105(tree, path, pragmas, findings)
    check_qk2xx(tree, path, pragmas, findings)
    check_qk301(tree, path, pragmas, findings)
    check_qk302(tree, path, pragmas, findings)
    check_qk401(tree, path, pragmas, findings)
    if select:
        # prefix match: --select QK2 picks the whole QK2xx family
        findings = [f for f in findings
                    if any(f.rule.startswith(s) for s in select)]
    return sorted(findings, key=lambda f: (f.path, f.line, f.rule))


def iter_py_files(paths: Sequence[str]) -> List[str]:
    out: List[str] = []
    for p in paths:
        if os.path.isfile(p) and p.endswith(".py"):
            out.append(p)
        elif os.path.isdir(p):
            for root, dirs, files in os.walk(p):
                dirs[:] = [d for d in dirs
                           if d not in ("__pycache__", ".git")]
                out.extend(os.path.join(root, f) for f in sorted(files)
                           if f.endswith(".py"))
    return sorted(set(out))


def lint_paths(paths: Sequence[str],
               select: Optional[Set[str]] = None) -> List[Finding]:
    files = iter_py_files(paths)
    trees: Dict[str, ast.AST] = {}
    sources: Dict[str, str] = {}
    findings: List[Finding] = []
    for f in files:
        try:
            with open(f, "r", encoding="utf-8") as fh:
                src = fh.read()
            trees[f] = ast.parse(src, filename=f)
            sources[f] = src
        except SyntaxError as e:
            findings.append(Finding("QK100", f, e.lineno or 0, 0,
                                    f"syntax error: {e.msg}"))
    registry = collect_registry(trees)
    for f in sorted(trees):
        findings.extend(lint_source(sources[f], f, registry=registry,
                                    select=select))
    # lint_source re-parses; dedupe syntax-error doubles
    return sorted(set(findings), key=lambda x: (x.path, x.line, x.rule))
