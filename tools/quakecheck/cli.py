"""Command line front-end: ``python -m tools.quakecheck src/``."""
from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional

from .core import RULES, lint_paths


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="quakecheck",
        description="Device-discipline static analysis for the Quake "
                    "executor stack.")
    ap.add_argument("paths", nargs="+",
                    help="files or directories to lint")
    ap.add_argument("--select", default=None,
                    help="comma-separated rule ids or prefixes "
                         "(e.g. QK101,QK104 or QK2 for the whole "
                         "concurrency family)")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="emit findings as JSON")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule table and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule, desc in sorted(RULES.items()):
            print(f"{rule}  {desc}")
        return 0

    select = ({r.strip() for r in args.select.split(",") if r.strip()}
              if args.select else None)
    findings = lint_paths(args.paths, select=select)
    if args.as_json:
        print(json.dumps([f.__dict__ for f in findings], indent=2))
    else:
        for f in findings:
            print(f.render())
        if findings:
            print(f"\nquakecheck: {len(findings)} finding(s)",
                  file=sys.stderr)
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
