"""quakecheck — device-discipline static analysis for the Quake executor
stack.

The hot path's latency wins depend on invariants that are easy to regress
silently: no stray host syncs inside device-resident functions, jit caches
that stay warm across batches, Pallas kernels that honour the tiling and
accumulation contract, donated buffers that are never read again, and
serving shared state mutated only behind the write barrier.  These are
checkable properties; quakecheck checks them mechanically.

Rule families (see ``docs/static_analysis.md``):

  QK101  host-sync-in-device-path    (implicit device->host pulls)
  QK102  jit-cache-fragmentation     (per-call jits, unhashable / unbucketed
                                      data-dependent static args)
  QK103  pallas-kernel-contract      (compat dispatch, tile divisibility,
                                      int8->int32 accumulation, no f64)
  QK104  donation-after-use          (donated operand read after the call)
  QK105  serving-shared-state        (guarded fields mutated outside the
                                      owning class / write barrier)

Intentional violations carry pragmas::

    x = np.asarray(td)   # quakecheck: allow-sync(kth-distance pull)
    frag()               # quakecheck: disable=QK102(factory jit, built once)

Run ``python -m tools.quakecheck src/`` from the repo root; exit status is
non-zero iff findings remain.
"""
from .core import Finding, lint_paths, lint_source  # noqa: F401

__all__ = ["Finding", "lint_paths", "lint_source"]
