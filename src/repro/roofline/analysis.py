"""Three-term roofline analysis from compiled artifacts (deliverable g).

    compute term    = HLO_FLOPs_per_device   / peak_FLOP/s
    memory term     = HLO_bytes_per_device   / HBM_bw
    collective term = wire_bytes_per_device  / link_bw

``cost_analysis()`` on the SPMD module gives *per-device* flops/bytes
(verified empirically).  Collective bytes are NOT in cost_analysis — we
parse the optimized HLO: every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute, with ring-algorithm wire-byte formulas and
group sizes from ``replica_groups``.

Hardware model (TPU v5e per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
50 GB/s/link ICI (single-link conservative basis; the task's
``collective_bytes / (chips x link_bw)`` convention).
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Dict, Optional

import numpy as np

HW_V5E = {"peak_flops": 197e12, "hbm_bw": 819e9, "ici_bw": 50e9}

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_DEF_RE = re.compile(
    r"%([\w.\-]+)\s*=\s*\(?([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")


def _type_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collectives(hlo_text: str) -> Dict:
    """Sum per-device wire bytes over every collective in the module."""
    sizes: Dict[str, int] = {}
    for m in _DEF_RE.finditer(hlo_text):
        sizes[m.group(1)] = _type_bytes(m.group(2), m.group(3))

    per_kind: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    count = 0
    for line in hlo_text.splitlines():
        kind = None
        for k in _COLLECTIVES:
            if re.search(rf"\s=\s.*\b{k}(-start)?\(", line):
                kind = k
                break
        if kind is None:
            continue
        dm = _DEF_RE.search(line)
        if dm is None:
            continue
        result_bytes = _type_bytes(dm.group(2), dm.group(3))
        # group size
        gs = 1
        gm = _GROUPS_IOTA_RE.search(line)
        if gm:
            gs = int(gm.group(2))
        else:
            gl = _GROUPS_LIST_RE.search(line)
            if gl:
                gs = len(gl.group(1).split(","))
        if gs <= 1:
            continue
        # operand bytes (for reduce-scatter the operand is the big side)
        ops = re.findall(rf"{kind}(?:-start)?\(([^)]*)\)", line)
        operand_bytes = 0
        if ops:
            for name in re.findall(r"%([\w.\-]+)", ops[0]):
                operand_bytes += sizes.get(name, 0)
        frac = (gs - 1) / gs
        if kind == "all-reduce":
            wire = 2.0 * result_bytes * frac
        elif kind == "all-gather":
            wire = result_bytes * frac
        elif kind == "reduce-scatter":
            wire = (operand_bytes or result_bytes * gs) * frac
        elif kind == "all-to-all":
            wire = result_bytes * frac
        else:  # collective-permute
            wire = result_bytes
        per_kind[kind] += wire
        count += 1
    total = sum(per_kind.values())
    return {"wire_bytes_per_device": total, "ops": count,
            "by_kind": {k: v for k, v in per_kind.items() if v}}


def analyze_compiled(compiled, mesh, *, arch: str = "", shape: str = "",
                     hw: Dict = HW_V5E) -> Dict:
    """Trip-count-aware roofline terms for one compiled cell.

    flops / bytes / wire-bytes come from ``hlo_cost.analyze`` (XLA's
    ``cost_analysis()`` counts while bodies once — worthless for
    scan-over-layers programs); per-device residency from
    ``memory_analysis()``."""
    from . import hlo_cost
    c = hlo_cost.analyze(compiled.as_text())
    flops = c.flops
    bytes_acc = c.bytes_accessed
    mem = compiled.memory_analysis()
    per_dev_bytes = (mem.argument_size_in_bytes + mem.output_size_in_bytes
                     + mem.temp_size_in_bytes - mem.alias_size_in_bytes)

    t_comp = flops / hw["peak_flops"]
    t_mem = bytes_acc / hw["hbm_bw"]
    t_coll = c.wire_bytes / hw["ici_bw"]
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dominant = max(terms, key=terms.get)

    n_dev = mesh.devices.size
    mf = model_flops(arch, shape)
    useful = (mf / n_dev / max(flops, 1.0)) if mf else None
    return {
        "arch": arch, "shape": shape, "devices": n_dev,
        "flops_per_device_tf": flops / 1e12,
        "hlo_bytes_per_device_gb": bytes_acc / 1e9,
        "bytes_per_device_gb": per_dev_bytes / 1e9,
        "collective_gb": c.wire_bytes / 1e9,
        "collective_ops": c.collective_ops,
        "collective_by_kind": {k: round(v / 1e9, 4)
                               for k, v in c.wire_by_kind.items()},
        "dynamic_whiles": c.dynamic_whiles,
        "t_compute_ms": t_comp * 1e3,
        "t_memory_ms": t_mem * 1e3,
        "t_collective_ms": t_coll * 1e3,
        "dominant": dominant,
        "model_flops_total": mf,
        "useful_flops_ratio": useful,
        "roofline_fraction": (t_comp / max(t_comp, t_mem, t_coll)
                              if max(terms.values()) > 0 else None),
    }


# ---------------------------------------------------------------------------
# MODEL_FLOPS: analytic "useful work" per cell (6ND convention for LM)
# ---------------------------------------------------------------------------

def model_flops(arch: str, shape: str) -> Optional[float]:
    try:
        from ..configs import get_arch
        spec = get_arch(arch)
    except Exception:
        return None
    cfg = spec.model_config()
    if spec.family == "lm":
        return _lm_model_flops(cfg, shape)
    if spec.family == "gnn":
        return _gnn_model_flops(cfg, shape)
    if spec.family == "recsys":
        return _recsys_model_flops(arch, cfg, shape)
    if spec.family == "ann":
        return _ann_model_flops(cfg, shape)
    return None


def _lm_model_flops(cfg, shape: str) -> float:
    from ..configs.families import LM_SHAPES
    from ..models.transformer import active_param_count
    sh = LM_SHAPES[shape]
    n = active_param_count(cfg)
    b, s = sh["batch"], sh["seq"]
    hdh = cfg.n_heads * cfg.head_dim
    if sh["kind"] == "train":
        # 6ND + causal attention 6 * L * S^2/2 * Hdh * 2(QK+PV) per batch row
        return 6.0 * n * b * s + 6.0 * cfg.n_layers * b * s * s * hdh
    if sh["kind"] == "prefill":
        return 2.0 * n * b * s + 2.0 * cfg.n_layers * b * s * s * hdh
    # decode: one token, full-cache attention
    return 2.0 * n * b + 4.0 * cfg.n_layers * b * s * hdh


def _gnn_model_flops(cfg, shape: str) -> float:
    from ..configs.families import GNN_SHAPES
    sh = GNN_SHAPES[shape]
    e = sh["n_edges"] * (2 * sh.get("n_graphs", 1) if "n_graphs" in sh
                         else 1)
    n = sh.get("n_graphs", 1) * sh["n_nodes"] if "n_graphs" in sh \
        else sh["n_nodes"]
    d_in = sh["d_feat"]
    f = 0.0
    for layer in range(cfg.n_layers):
        last = layer == cfg.n_layers - 1
        heads = 1 if last else cfg.n_heads
        d_out = cfg.n_classes if last else cfg.d_hidden
        f += 2.0 * n * d_in * heads * d_out      # projection
        f += 6.0 * e * heads * d_out             # scores+softmax+aggregate
        d_in = d_out * (1 if last else heads)
    return 3.0 * f                                # fwd + bwd


def _recsys_model_flops(arch: str, cfg, shape: str) -> float:
    from ..configs.families import RECSYS_SHAPES
    sh = RECSYS_SHAPES[shape]
    b = sh.get("n_cand", sh.get("batch", 1))

    def mlp_flops(dims):
        return sum(2.0 * dims[i] * dims[i + 1] for i in range(len(dims) - 1))

    if arch == "din":
        per = (cfg.seq_len * mlp_flops((4 * cfg.embed_dim,) + cfg.attn_mlp
                                       + (1,))
               + mlp_flops((2 * cfg.embed_dim + cfg.n_dense,) + cfg.mlp
                           + (1,)))
    elif arch == "sasrec":
        d = cfg.embed_dim
        per = cfg.n_blocks * (4 * cfg.seq_len * d * d * 2
                              + 2 * cfg.seq_len * cfg.seq_len * d * 2)
    elif arch == "two-tower-retrieval":
        per = 2 * mlp_flops((cfg.embed_dim,) + cfg.tower_mlp) \
            + 2 * cfg.tower_mlp[-1]
    else:  # dlrm
        f = cfg.n_sparse + 1
        per = (mlp_flops((cfg.n_dense,) + cfg.bot_mlp)
               + 2.0 * f * f * cfg.embed_dim
               + mlp_flops((cfg.n_interactions + cfg.embed_dim,)
                           + cfg.top_mlp))
    mult = 3.0 if sh["kind"] == "train" else 1.0
    return mult * b * per


def _ann_model_flops(dims: Dict, shape: str) -> float:
    from ..configs.quake_arch import QUAKE_SHAPES
    sh = QUAKE_SHAPES[shape]
    p, s_cap, d = dims["p"], dims["s_cap"], dims["d"]
    if sh["kind"] == "assign":
        return 2.0 * sh["n"] * p * d
    b = sh["batch"]
    route = 2.0 * b * p * d
    if sh["kind"] == "fixed":
        return route + 2.0 * b * sh["nprobe"] * s_cap * d
    if sh["kind"] == "brute":
        return 2.0 * b * p * s_cap * d
    # adaptive: nominal 2 rounds x chunk partitions per shard
    return route + 2.0 * b * 2 * 2 * s_cap * d
