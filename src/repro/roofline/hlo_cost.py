"""Trip-count-aware HLO cost analysis.

``compiled.cost_analysis()`` counts every while-loop body exactly once —
useless for scan-over-layers models (an 88-layer transformer reports 1/88th
of its flops) and it ignores collectives entirely.  This module re-derives
the three roofline inputs directly from the optimized HLO text:

  * walks the call graph (ENTRY -> while bodies / fusion callees) carrying a
    *trip multiplier* from ``backend_config={"known_trip_count":{"n":...}}``
    (lax.scan / fori_loop always annotate it; dynamic ``while_loop``s fall
    back to x1 and are flagged),
  * flops: dot ops from operand shapes + contracting dims; elementwise and
    reduce ops at 1 flop/element,
  * memory bytes: operand + result bytes of every top-level instruction
    (fused computations count only at their fusion's I/O boundary, matching
    XLA's convention),
  * collective wire bytes: ring formulas per kind x replica-group size x
    trip multiplier.

Validated against ``cost_analysis()`` on loop-free programs in the tests.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "s4": 1, "s8": 1, "u2": 1, "u4": 1, "u8": 1,
    "s16": 2, "u16": 2, "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "exponential", "exponential-minus-one", "log", "log-plus-one",
    "rsqrt", "sqrt", "tanh", "logistic", "sine", "cosine", "power",
    "compare", "select", "and", "or", "xor", "not", "floor", "ceil",
    "round-nearest-afz", "round-nearest-even", "clamp", "remainder",
    "atan2", "sign", "convert", "erf", "cbrt",
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_TRIP_RE = re.compile(r'known_trip_count[":{\s]+n["\s:]+(\d+)')
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_CALL_RE = re.compile(r"(?:calls|body|to_apply|branch_computations)="
                      r"[{]?%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")


def _shape_info(type_str: str) -> Tuple[int, List[Tuple[str, List[int]]]]:
    """Total bytes + per-element (dtype, dims) list from a type string
    (handles tuples)."""
    total = 0
    elems = []
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        shape = [int(d) for d in dims.split(",")] if dims else []
        n = 1
        for d in shape:
            n *= d
        total += n * _DTYPE_BYTES[dt]
        elems.append((dt, shape))
    return total, elems


@dataclass
class Instr:
    name: str
    opcode: str
    result_bytes: int
    result_elems: int
    result_shapes: List[Tuple[str, List[int]]]
    operands: List[str]
    line: str


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)


_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")
_COMP_START_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->")


def parse_module(text: str) -> Tuple[Dict[str, Computation], str]:
    comps: Dict[str, Computation] = {}
    entry = None
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            if line.endswith("{"):
                m = _COMP_START_RE.match(line.strip())
                if m:
                    cur = Computation(m.group(1))
                    if line.strip().startswith("ENTRY"):
                        entry = m.group(1)
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, type_str, opcode, rest = m.groups()
        rb, shapes = _shape_info(type_str)
        elems = sum(int(np_prod(s[1])) for s in shapes)
        # operand names: %refs inside the first (...) group
        depth, i, args = 1, 0, ""
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
            args += ch
        operands = re.findall(r"%([\w.\-]+)", args)
        cur.instrs.append(Instr(name, opcode, rb, elems, shapes, operands,
                                line))
    return comps, entry or ""


def np_prod(xs) -> int:
    n = 1
    for x in xs:
        n *= x
    return n


def _dot_flops(instr: Instr, defs: Dict[str, Instr]) -> float:
    lhs = defs.get(instr.operands[0]) if instr.operands else None
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.line)
    contract = 1
    if lhs is not None and m and lhs.result_shapes:
        dims = lhs.result_shapes[0][1]
        for i in (int(x) for x in m.group(1).split(",") if x):
            if i < len(dims):
                contract *= dims[i]
    return 2.0 * instr.result_elems * contract


def _collective_wire_bytes(instr: Instr, defs: Dict[str, Instr],
                           kind: str) -> float:
    gs = 1
    gm = _GROUPS_IOTA_RE.search(instr.line)
    if gm:
        gs = int(gm.group(2))
    else:
        gl = _GROUPS_LIST_RE.search(instr.line)
        if gl:
            gs = len(gl.group(1).split(","))
    if gs <= 1:
        return 0.0
    frac = (gs - 1) / gs
    rb = instr.result_bytes
    ob = sum(defs[o].result_bytes for o in instr.operands if o in defs)
    if kind == "all-reduce":
        return 2.0 * rb * frac
    if kind == "all-gather":
        return rb * frac
    if kind == "reduce-scatter":
        return (ob or rb * gs) * frac
    if kind == "all-to-all":
        return rb * frac
    return float(rb)  # collective-permute


@dataclass
class HloCost:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    wire_bytes: float = 0.0
    wire_by_kind: Dict[str, float] = field(default_factory=dict)
    collective_ops: int = 0
    dynamic_whiles: int = 0        # loops without known trip counts (x1)

    def add(self, other: "HloCost", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes_accessed += other.bytes_accessed * mult
        self.wire_bytes += other.wire_bytes * mult
        for k, v in other.wire_by_kind.items():
            self.wire_by_kind[k] = self.wire_by_kind.get(k, 0) + v * mult
        self.collective_ops += other.collective_ops
        self.dynamic_whiles += other.dynamic_whiles


_SKIP_BYTES = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "while", "conditional", "call", "after-all",
               "iota", "partition-id", "replica-id", "custom-call"}

_SLICE_OPS = {"dynamic-slice", "slice", "gather"}


def _fusion_slice_discount(ins: Instr, called: Optional[Computation],
                           defs: Dict[str, Instr]) -> float:
    """Bytes to subtract from a fusion's operand accounting: operands whose
    only in-fusion consumers are slicing ops are read slice-wise."""
    if called is None:
        return 0.0
    params: Dict[int, Instr] = {}
    users: Dict[str, List[Instr]] = {}
    for sub in called.instrs:
        if sub.opcode == "parameter":
            m = re.search(r"parameter\((\d+)\)", sub.line)
            if m:
                params[int(m.group(1))] = sub
        for o in sub.operands:
            users.setdefault(o, []).append(sub)
    discount = 0.0
    for idx, opname in enumerate(ins.operands):
        if opname not in defs or idx not in params:
            continue
        p = params[idx]
        consumers = users.get(p.name, [])
        if consumers and all(c.opcode in _SLICE_OPS for c in consumers):
            sliced = sum(c.result_bytes for c in consumers)
            full = defs[opname].result_bytes
            if sliced < full:
                discount += full - sliced
    return discount


def analyze(text: str) -> HloCost:
    comps, entry = parse_module(text)
    defs: Dict[str, Instr] = {}
    for comp in comps.values():
        for ins in comp.instrs:
            defs[ins.name] = ins

    memo: Dict[str, HloCost] = {}

    def comp_cost(name: str) -> HloCost:
        if name in memo:
            return memo[name]
        memo[name] = HloCost()  # cycle guard
        total = HloCost()
        comp = comps.get(name)
        if comp is None:
            return total
        for ins in comp.instrs:
            op = ins.opcode
            kind = next((k for k in _COLLECTIVES if op.startswith(k)), None)
            # flops
            if op == "dot":
                total.flops += _dot_flops(ins, defs)
            elif op in _ELEMENTWISE:
                total.flops += ins.result_elems
            elif op == "reduce":
                ops_ = [defs[o] for o in ins.operands if o in defs]
                total.flops += max((o.result_elems for o in ops_),
                                   default=ins.result_elems)
            # bytes — XLA conventions: sliced/gathered reads count only the
            # transferred elements, in-place updates only the update.
            if op not in _SKIP_BYTES:
                if op in ("dynamic-slice", "slice", "gather"):
                    total.bytes_accessed += 2.0 * ins.result_bytes
                elif op == "dynamic-update-slice":
                    upd = (defs[ins.operands[1]].result_bytes
                           if len(ins.operands) > 1
                           and ins.operands[1] in defs else 0)
                    total.bytes_accessed += 2.0 * upd
                elif op == "scatter":
                    upd = (defs[ins.operands[2]].result_bytes
                           if len(ins.operands) > 2
                           and ins.operands[2] in defs else ins.result_bytes)
                    total.bytes_accessed += 2.0 * upd
                elif op == "broadcast":
                    total.bytes_accessed += ins.result_bytes
                else:
                    ob = sum(defs[o].result_bytes for o in ins.operands
                             if o in defs)
                    total.bytes_accessed += ob + ins.result_bytes
            # collectives
            if kind is not None and not op.endswith("-done"):
                w = _collective_wire_bytes(ins, defs, kind)
                if w > 0:
                    total.wire_bytes += w
                    total.wire_by_kind[kind] = \
                        total.wire_by_kind.get(kind, 0) + w
                    total.collective_ops += 1
            # recursion
            if op == "while":
                tm = _TRIP_RE.search(ins.line)
                trip = int(tm.group(1)) if tm else 1
                if tm is None:
                    total.dynamic_whiles += 1
                bm = _CALL_RE.search(ins.line)
                cm = _COND_RE.search(ins.line)
                if bm:
                    total.add(comp_cost(bm.group(1)), trip)
                if cm:
                    total.add(comp_cost(cm.group(1)), trip + 1)
            elif op == "fusion":
                fm = _CALL_RE.search(ins.line)
                if fm:
                    sub = comp_cost(fm.group(1))
                    # fused instrs: count flops (they execute) but not bytes
                    # (fusion I/O already counted)
                    total.flops += sub.flops
                    total.wire_bytes += sub.wire_bytes
                    total.collective_ops += sub.collective_ops
                    # correction: a fusion operand that is only *sliced*
                    # inside (dynamic-slice of a stacked scan input) reads
                    # the slice, not the whole array
                    total.bytes_accessed -= _fusion_slice_discount(
                        ins, comps.get(fm.group(1)), defs)
            elif op == "conditional":
                for branch in re.findall(r"%([\w.\-]+)", ins.line.split(
                        "branch_computations=")[-1])[:8] \
                        if "branch_computations" in ins.line else []:
                    total.add(comp_cost(branch), 1.0)
        memo[name] = total
        return total

    return comp_cost(entry)
