"""Roofline analysis from compiled dry-run artifacts (§Roofline)."""
from .analysis import HW_V5E, analyze_compiled, model_flops  # noqa: F401
from .analysis import parse_collectives  # noqa: F401
