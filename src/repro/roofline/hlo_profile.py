"""Op-level attribution for the §Perf loop: which collectives / memory ops
dominate a compiled cell.  This is the 'profile' of the hypothesis->change->
measure cycle on a dry-run-only container — wall-time traces don't exist,
the optimized HLO is the ground truth.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Tuple

from . import hlo_cost
from .hlo_cost import (_COLLECTIVES, _TRIP_RE, _CALL_RE, _collective_wire_bytes,
                       Instr, parse_module)


def top_collectives(text: str, n: int = 12) -> List[Dict]:
    """Collectives ranked by trip-weighted wire bytes."""
    comps, entry = parse_module(text)
    defs: Dict[str, Instr] = {}
    for comp in comps.values():
        for ins in comp.instrs:
            defs[ins.name] = ins

    # trip multiplier per computation (entry = 1; while bodies *= trip)
    mult: Dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    order = [entry]
    seen = {entry}
    while order:
        name = order.pop(0)
        comp = comps.get(name)
        if comp is None:
            continue
        for ins in comp.instrs:
            if ins.opcode in ("while", "fusion", "call", "conditional"):
                tm = _TRIP_RE.search(ins.line)
                trip = int(tm.group(1)) if tm else 1
                for callee in re.findall(
                        r"(?:calls|body|condition|to_apply)=%?([\w.\-]+)",
                        ins.line):
                    mult[callee] += mult[name] * (
                        trip if ins.opcode == "while" else 1)
                    if callee not in seen:
                        seen.add(callee)
                        order.append(callee)

    rows = []
    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m <= 0:
            continue
        for ins in comp.instrs:
            kind = next((k for k in _COLLECTIVES
                         if ins.opcode.startswith(k)), None)
            if kind is None or ins.opcode.endswith("-done"):
                continue
            w = _collective_wire_bytes(ins, defs, kind)
            if w <= 0:
                continue
            shape = ins.line.split("=")[1].strip().split(" ")[0]
            rows.append({"kind": kind, "shape": shape, "trips": m,
                         "wire_gb_total": w * m / 1e9,
                         "comp": cname, "name": ins.name})
    rows.sort(key=lambda r: -r["wire_gb_total"])
    return rows[:n]


def top_memory_ops(text: str, n: int = 12) -> List[Tuple[str, float, str]]:
    """Opcode classes ranked by trip-weighted HBM bytes (fusion-boundary
    convention, same as hlo_cost)."""
    comps, entry = parse_module(text)
    defs: Dict[str, Instr] = {}
    for comp in comps.values():
        for ins in comp.instrs:
            defs[ins.name] = ins

    mult: Dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    order, seen = [entry], {entry}
    while order:
        name = order.pop(0)
        comp = comps.get(name)
        if comp is None:
            continue
        for ins in comp.instrs:
            if ins.opcode in ("while", "fusion", "call", "conditional"):
                tm = _TRIP_RE.search(ins.line)
                trip = int(tm.group(1)) if tm else 1
                for callee in re.findall(
                        r"(?:calls|body|condition|to_apply)=%?([\w.\-]+)",
                        ins.line):
                    mult[callee] += mult[name] * (
                        trip if ins.opcode == "while" else 1)
                    if callee not in seen:
                        seen.add(callee)
                        order.append(callee)

    # fusion callee bodies don't count bytes; group leaf ops by example
    agg: Dict[str, float] = defaultdict(float)
    example: Dict[str, str] = {}
    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m <= 0 or ".fused" in cname or cname.startswith("fused"):
            continue
        for ins in comp.instrs:
            op = ins.opcode
            if op in hlo_cost._SKIP_BYTES:
                continue
            if op in ("dynamic-slice", "slice", "gather"):
                b = 2.0 * ins.result_bytes
            elif op == "dynamic-update-slice":
                b = 2.0 * (defs[ins.operands[1]].result_bytes
                           if len(ins.operands) > 1
                           and ins.operands[1] in defs else 0)
            elif op == "broadcast":
                b = ins.result_bytes
            else:
                b = ins.result_bytes + sum(
                    defs[o].result_bytes for o in ins.operands if o in defs)
            key = f"{op}"
            agg[key] += b * m
            shape = ins.line.split("=")[1].strip().split(" ")[0]
            if agg[key] == b * m or ins.result_bytes > 1e8:
                example[key] = shape
    rows = sorted(agg.items(), key=lambda kv: -kv[1])[:n]
    return [(k, v / 1e9, example.get(k, "")) for k, v in rows]
