"""Deterministic, checkpointable batch pipelines for the model zoo.

Every pipeline is a pure function of (seed, step) — the *cursor is the step
index*, so resuming after a failure only needs the step from the checkpoint
manifest (no iterator state to persist).  This is the property the
fault-tolerant train loop relies on (train/loop.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import numpy as np


def _rng(seed: int, step: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([seed, step + 1_000_003]))


# ---------------------------------------------------------------------------
# Language modeling
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class TokenPipeline:
    """Zipf-distributed synthetic token stream with Markov-ish locality so
    the loss actually decreases during smoke training."""
    vocab_size: int
    batch: int
    seq_len: int
    seed: int = 0

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = _rng(self.seed, step)
        b, s, v = self.batch, self.seq_len, self.vocab_size
        # structured stream: tokens repeat locally (predictable structure)
        base = rng.zipf(1.3, size=(b, s)).astype(np.int64) % v
        rep = rng.random((b, s)) < 0.5
        tokens = base.copy()
        tokens[:, 1:] = np.where(rep[:, 1:], tokens[:, :-1], base[:, 1:])
        return {"tokens": tokens.astype(np.int32)}


# ---------------------------------------------------------------------------
# RecSys
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class RecsysPipeline:
    """Click-through batches: dense features, Zipfian categorical ids per
    field, user history sequences, and labels generated from a hidden linear
    model (so training has signal)."""
    batch: int
    n_dense: int = 13
    n_sparse: int = 26
    vocab: int = 100_000
    hist_len: int = 50
    seed: int = 0

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = _rng(self.seed, step)
        dense = rng.normal(size=(self.batch, self.n_dense)).astype(np.float32)
        sparse = (rng.zipf(1.2, size=(self.batch, self.n_sparse))
                  % self.vocab).astype(np.int32)
        hist = (rng.zipf(1.2, size=(self.batch, self.hist_len))
                % self.vocab).astype(np.int32)
        hist_len = rng.integers(1, self.hist_len + 1, self.batch)
        hist_mask = (np.arange(self.hist_len)[None, :]
                     < hist_len[:, None])
        target = (rng.zipf(1.2, size=(self.batch,)) % self.vocab
                  ).astype(np.int32)
        # hidden ground-truth model for labels
        w = _rng(self.seed, -1).normal(size=self.n_dense)
        logit = dense @ w + 0.3 * ((sparse.sum(1) % 7) - 3) \
            + 0.5 * ((target % 5) - 2)
        label = (logit + rng.normal(size=self.batch) > 0)
        return {"dense": dense, "sparse": sparse, "history": hist,
                "history_mask": hist_mask.astype(np.bool_),
                "target_item": target,
                "label": label.astype(np.float32)}


# ---------------------------------------------------------------------------
# GNN (full-graph batches are static; this covers minibatch mode)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class GraphMinibatchPipeline:
    """Seeded neighbor-sampled minibatches over a fixed CSR graph."""
    graph: object               # CSRGraph
    feats: np.ndarray
    labels: np.ndarray
    batch_nodes: int
    fanouts: Tuple[int, ...] = (15, 10)
    seed: int = 0

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        from .graphs import sampled_subgraph
        rng = _rng(self.seed, step)
        seeds = rng.choice(self.graph.n_nodes, size=self.batch_nodes,
                           replace=False)
        src, dst, nodes = sampled_subgraph(self.graph, seeds, self.fanouts,
                                           seed=self.seed + step)
        return {"src": src, "dst": dst,
                "feats": self.feats[nodes],
                "labels": self.labels[nodes],
                "n_nodes": np.int32(len(nodes))}
