"""Configurable vector-search workload generator (paper §7.1).

Parameters mirror the paper's generator: vectors per operation, operation
count, operation mix (read/write ratio) and *spatial skew* — queries and
updates sampled from hot clusters so both read and write skew are
controllable.  Produces a deterministic stream of operations:

    ("insert", vectors, ids) | ("delete", ids) | ("query", vectors, gt_fn)

MSTuring-RO / MSTuring-IH style workloads from the paper are instances
(see ``readonly_workload`` / ``insert_heavy_workload``); the Wikipedia trace
lives in ``wikipedia.py``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterator, List, Optional, Tuple

import numpy as np

from .datasets import VectorDataset, zipf_weights


@dataclass
class WorkloadConfig:
    n_operations: int = 100
    vectors_per_op: int = 1000
    read_fraction: float = 0.5        # share of ops that are query batches
    delete_fraction: float = 0.0      # share of *write* ops that delete
    query_skew: float = 0.0           # 0 = uniform; >0 = zipf over clusters
    write_skew: float = 0.0
    queries_per_op: int = 100
    k: int = 10
    seed: int = 0


@dataclass
class Operation:
    kind: str                          # insert | delete | query
    vectors: Optional[np.ndarray] = None
    ids: Optional[np.ndarray] = None
    queries: Optional[np.ndarray] = None


@dataclass
class Workload:
    """Materialized operation stream + initial state."""
    initial_vectors: np.ndarray
    initial_ids: np.ndarray
    operations: List[Operation]
    dataset: VectorDataset
    config: WorkloadConfig

    def resident_ids_after(self, t: int) -> np.ndarray:
        """Ids resident in the index after operation t (for ground truth)."""
        alive = set(self.initial_ids.tolist())
        for op in self.operations[:t + 1]:
            if op.kind == "insert":
                alive.update(op.ids.tolist())
            elif op.kind == "delete":
                alive.difference_update(op.ids.tolist())
        return np.asarray(sorted(alive), dtype=np.int64)


class IncrementalGroundTruth:
    """Brute-force top-k ground truth over the *resident* subset of a
    dataset, maintained incrementally across a workload replay.

    The per-op replay loops used to rebuild the sorted resident-id array
    and re-slice the ``(N_res, d)`` matrix from scratch before every
    query op — an O(N) re-materialization on top of the unavoidable
    O(B*N_res) GEMM.  This helper tracks inserts/deletes as set edits and
    materializes the resident matrix (plus cached squared norms for L2)
    lazily, only when a query op actually arrives after a membership
    change.  Shared by ``launch/serve.py``, ``benchmarks/bench_serving.py``
    and ``benchmarks/workload_driver.py``.
    """

    def __init__(self, ds: VectorDataset,
                 initial_ids: Optional[np.ndarray] = None):
        self.ds = ds
        self._resident = set() if initial_ids is None else \
            {int(i) for i in initial_ids}
        self._dirty = True
        self._ids: Optional[np.ndarray] = None      # sorted resident ids
        self._x: Optional[np.ndarray] = None        # (N_res, d) view
        self._x2: Optional[np.ndarray] = None       # cached ||x||^2 (l2)

    @property
    def resident_ids(self) -> np.ndarray:
        self._materialize()
        return self._ids

    def insert(self, ids: np.ndarray) -> None:
        self._resident.update(int(i) for i in np.asarray(ids).ravel())
        self._dirty = True

    def delete(self, ids: np.ndarray) -> None:
        self._resident.difference_update(
            int(i) for i in np.asarray(ids).ravel())
        self._dirty = True

    def apply(self, op: "Operation") -> None:
        """Fold one workload operation's membership effect."""
        if op.kind == "insert":
            self.insert(op.ids)
        elif op.kind == "delete":
            self.delete(op.ids)

    def _materialize(self) -> None:
        if not self._dirty:
            return
        self._ids = np.asarray(sorted(self._resident), dtype=np.int64)
        self._x = self.ds.vectors[self._ids]
        self._x2 = (np.sum(self._x.astype(np.float64) ** 2, axis=1)
                    if self.ds.metric == "l2" else None)
        self._dirty = False

    def topk(self, queries: np.ndarray, k: int) -> np.ndarray:
        """(B, k) external-id ground truth for ``queries`` against the
        current resident set (exact, brute force)."""
        self._materialize()
        q = np.asarray(queries, dtype=np.float32)
        if q.ndim == 1:
            q = q[None, :]
        if len(self._ids) == 0:
            return np.full((q.shape[0], k), -1, dtype=np.int64)
        if self.ds.metric == "l2":
            d = self._x2[None, :] - 2.0 * (q @ self._x.T)
        else:
            d = -(q @ self._x.T)
        kk = min(k, d.shape[1])
        part = np.argpartition(d, kk - 1, axis=1)[:, :kk]
        order = np.take_along_axis(d, part, axis=1).argsort(
            axis=1, kind="stable")
        idx = np.take_along_axis(part, order, axis=1)
        out = self._ids[idx]
        if kk < k:
            out = np.concatenate(
                [out, np.full((q.shape[0], k - kk), -1, np.int64)], axis=1)
        return out


def generate(ds: VectorDataset, cfg: WorkloadConfig,
             initial_fraction: float = 0.3) -> Workload:
    """Build a workload over ``ds``: a fraction of vectors resident up front,
    the rest streamed in; queries jittered residents with cluster skew."""
    rng = np.random.default_rng(cfg.seed)
    n = ds.n
    n_init = int(n * initial_fraction)
    perm = rng.permutation(n)
    init, pool = perm[:n_init], perm[n_init:]
    pool_pos = 0
    resident = list(init)

    n_clusters = len(ds.centers)
    qw = zipf_weights(n_clusters, 1.0 + cfg.query_skew) \
        if cfg.query_skew > 0 else np.full(n_clusters, 1.0 / n_clusters)
    ww = zipf_weights(n_clusters, 1.0 + cfg.write_skew) \
        if cfg.write_skew > 0 else np.full(n_clusters, 1.0 / n_clusters)
    # randomize which clusters are hot (decoupled from cluster id)
    qw = qw[rng.permutation(n_clusters)]
    ww = ww[rng.permutation(n_clusters)]

    ops: List[Operation] = []
    for t in range(cfg.n_operations):
        if rng.random() < cfg.read_fraction:
            res = np.asarray(resident)
            cids = rng.choice(n_clusters, size=cfg.queries_per_op, p=qw)
            base = np.empty(cfg.queries_per_op, dtype=np.int64)
            res_cluster = ds.cluster_of[res]
            for c in np.unique(cids):
                cand = res[res_cluster == c]
                if len(cand) == 0:
                    cand = res
                sel = cids == c
                base[sel] = rng.choice(cand, size=int(sel.sum()))
            q = (ds.vectors[base]
                 + rng.normal(size=(cfg.queries_per_op, ds.dim))
                 .astype(np.float32) * 0.05)
            ops.append(Operation("query", queries=q.astype(np.float32)))
        elif (cfg.delete_fraction > 0
              and rng.random() < cfg.delete_fraction
              and len(resident) > cfg.vectors_per_op * 2):
            res = np.asarray(resident)
            cids = rng.choice(n_clusters, size=cfg.vectors_per_op, p=ww)
            res_cluster = ds.cluster_of[res]
            victims: List[int] = []
            for c in np.unique(cids):
                cand = res[res_cluster == c]
                if len(cand) == 0:
                    cand = res
                sel = int((cids == c).sum())
                victims.extend(rng.choice(cand, size=min(sel, len(cand)),
                                          replace=False).tolist())
            victims = np.unique(np.asarray(victims, dtype=np.int64))
            resident = [r for r in resident if r not in set(victims.tolist())]
            ops.append(Operation("delete", ids=victims))
        else:
            take = min(cfg.vectors_per_op, len(pool) - pool_pos)
            if take <= 0:
                ops.append(Operation("query", queries=ds.vectors[
                    rng.integers(0, n, cfg.queries_per_op)]))
                continue
            ids = pool[pool_pos:pool_pos + take]
            pool_pos += take
            resident.extend(ids.tolist())
            ops.append(Operation("insert", vectors=ds.vectors[ids],
                                 ids=ids.astype(np.int64)))
    return Workload(initial_vectors=ds.vectors[init],
                    initial_ids=init.astype(np.int64),
                    operations=ops, dataset=ds, config=cfg)


def readonly_workload(ds: VectorDataset, n_ops: int = 20,
                      queries_per_op: int = 200, skew: float = 0.5,
                      seed: int = 0) -> Workload:
    """MSTuring-RO analogue: pure search."""
    return generate(ds, WorkloadConfig(
        n_operations=n_ops, read_fraction=1.0, query_skew=skew,
        queries_per_op=queries_per_op, seed=seed), initial_fraction=1.0)


def insert_heavy_workload(ds: VectorDataset, n_ops: int = 50,
                          vectors_per_op: int = 2000,
                          queries_per_op: int = 100,
                          seed: int = 0) -> Workload:
    """MSTuring-IH analogue: 90% insert / 10% search, growing 10x."""
    return generate(ds, WorkloadConfig(
        n_operations=n_ops, read_fraction=0.1,
        vectors_per_op=vectors_per_op, queries_per_op=queries_per_op,
        write_skew=0.5, seed=seed), initial_fraction=0.1)
