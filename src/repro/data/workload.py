"""Configurable vector-search workload generator (paper §7.1).

Parameters mirror the paper's generator: vectors per operation, operation
count, operation mix (read/write ratio) and *spatial skew* — queries and
updates sampled from hot clusters so both read and write skew are
controllable.  Produces a deterministic stream of operations:

    ("insert", vectors, ids) | ("delete", ids) | ("query", vectors, gt_fn)

MSTuring-RO / MSTuring-IH style workloads from the paper are instances
(see ``readonly_workload`` / ``insert_heavy_workload``); the Wikipedia trace
lives in ``wikipedia.py``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterator, List, Optional, Tuple

import numpy as np

from .datasets import VectorDataset, zipf_weights


@dataclass
class WorkloadConfig:
    n_operations: int = 100
    vectors_per_op: int = 1000
    read_fraction: float = 0.5        # share of ops that are query batches
    delete_fraction: float = 0.0      # share of *write* ops that delete
    query_skew: float = 0.0           # 0 = uniform; >0 = zipf over clusters
    write_skew: float = 0.0
    queries_per_op: int = 100
    k: int = 10
    seed: int = 0


@dataclass
class Operation:
    kind: str                          # insert | delete | query
    vectors: Optional[np.ndarray] = None
    ids: Optional[np.ndarray] = None
    queries: Optional[np.ndarray] = None


@dataclass
class Workload:
    """Materialized operation stream + initial state."""
    initial_vectors: np.ndarray
    initial_ids: np.ndarray
    operations: List[Operation]
    dataset: VectorDataset
    config: WorkloadConfig

    def resident_ids_after(self, t: int) -> np.ndarray:
        """Ids resident in the index after operation t (for ground truth)."""
        alive = set(self.initial_ids.tolist())
        for op in self.operations[:t + 1]:
            if op.kind == "insert":
                alive.update(op.ids.tolist())
            elif op.kind == "delete":
                alive.difference_update(op.ids.tolist())
        return np.asarray(sorted(alive), dtype=np.int64)


def generate(ds: VectorDataset, cfg: WorkloadConfig,
             initial_fraction: float = 0.3) -> Workload:
    """Build a workload over ``ds``: a fraction of vectors resident up front,
    the rest streamed in; queries jittered residents with cluster skew."""
    rng = np.random.default_rng(cfg.seed)
    n = ds.n
    n_init = int(n * initial_fraction)
    perm = rng.permutation(n)
    init, pool = perm[:n_init], perm[n_init:]
    pool_pos = 0
    resident = list(init)

    n_clusters = len(ds.centers)
    qw = zipf_weights(n_clusters, 1.0 + cfg.query_skew) \
        if cfg.query_skew > 0 else np.full(n_clusters, 1.0 / n_clusters)
    ww = zipf_weights(n_clusters, 1.0 + cfg.write_skew) \
        if cfg.write_skew > 0 else np.full(n_clusters, 1.0 / n_clusters)
    # randomize which clusters are hot (decoupled from cluster id)
    qw = qw[rng.permutation(n_clusters)]
    ww = ww[rng.permutation(n_clusters)]

    ops: List[Operation] = []
    for t in range(cfg.n_operations):
        if rng.random() < cfg.read_fraction:
            res = np.asarray(resident)
            cids = rng.choice(n_clusters, size=cfg.queries_per_op, p=qw)
            base = np.empty(cfg.queries_per_op, dtype=np.int64)
            res_cluster = ds.cluster_of[res]
            for c in np.unique(cids):
                cand = res[res_cluster == c]
                if len(cand) == 0:
                    cand = res
                sel = cids == c
                base[sel] = rng.choice(cand, size=int(sel.sum()))
            q = (ds.vectors[base]
                 + rng.normal(size=(cfg.queries_per_op, ds.dim))
                 .astype(np.float32) * 0.05)
            ops.append(Operation("query", queries=q.astype(np.float32)))
        elif (cfg.delete_fraction > 0
              and rng.random() < cfg.delete_fraction
              and len(resident) > cfg.vectors_per_op * 2):
            res = np.asarray(resident)
            cids = rng.choice(n_clusters, size=cfg.vectors_per_op, p=ww)
            res_cluster = ds.cluster_of[res]
            victims: List[int] = []
            for c in np.unique(cids):
                cand = res[res_cluster == c]
                if len(cand) == 0:
                    cand = res
                sel = int((cids == c).sum())
                victims.extend(rng.choice(cand, size=min(sel, len(cand)),
                                          replace=False).tolist())
            victims = np.unique(np.asarray(victims, dtype=np.int64))
            resident = [r for r in resident if r not in set(victims.tolist())]
            ops.append(Operation("delete", ids=victims))
        else:
            take = min(cfg.vectors_per_op, len(pool) - pool_pos)
            if take <= 0:
                ops.append(Operation("query", queries=ds.vectors[
                    rng.integers(0, n, cfg.queries_per_op)]))
                continue
            ids = pool[pool_pos:pool_pos + take]
            pool_pos += take
            resident.extend(ids.tolist())
            ops.append(Operation("insert", vectors=ds.vectors[ids],
                                 ids=ids.astype(np.int64)))
    return Workload(initial_vectors=ds.vectors[init],
                    initial_ids=init.astype(np.int64),
                    operations=ops, dataset=ds, config=cfg)


def readonly_workload(ds: VectorDataset, n_ops: int = 20,
                      queries_per_op: int = 200, skew: float = 0.5,
                      seed: int = 0) -> Workload:
    """MSTuring-RO analogue: pure search."""
    return generate(ds, WorkloadConfig(
        n_operations=n_ops, read_fraction=1.0, query_skew=skew,
        queries_per_op=queries_per_op, seed=seed), initial_fraction=1.0)


def insert_heavy_workload(ds: VectorDataset, n_ops: int = 50,
                          vectors_per_op: int = 2000,
                          queries_per_op: int = 100,
                          seed: int = 0) -> Workload:
    """MSTuring-IH analogue: 90% insert / 10% search, growing 10x."""
    return generate(ds, WorkloadConfig(
        n_operations=n_ops, read_fraction=0.1,
        vectors_per_op=vectors_per_op, queries_per_op=queries_per_op,
        write_skew=0.5, seed=seed), initial_fraction=0.1)
