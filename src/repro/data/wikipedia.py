"""Wikipedia-12M-style workload (paper §7.1, scaled down).

Reproduces the *structure* of the paper's trace from public pageview
dynamics without the 12M-embedding download:

  * the corpus grows month over month (new pages arrive in clustered bursts
    — fresh topics concentrate in embedding-space regions: write skew),
  * query traffic follows a Zipf popularity distribution over pages whose
    hot set *drifts* between months (read skew + temporal drift),
  * each month = one insert batch followed by a query batch at roughly the
    paper's 50/50 read/write ratio, inner-product metric.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List

import numpy as np

from .datasets import VectorDataset, zipf_weights
from .workload import Operation, Workload, WorkloadConfig


def wikipedia_workload(n_total: int = 60_000, dim: int = 48,
                       months: int = 12, initial_fraction: float = 0.15,
                       queries_per_month: int = 1000, zipf_a: float = 1.05,
                       drift: float = 0.15, n_topics: int = 64,
                       seed: int = 0) -> Workload:
    """Scaled Wikipedia-12M analogue (defaults ~60k vectors, 12 months)."""
    rng = np.random.default_rng(seed)
    # topic centers; later topics appear over time (new-page bursts)
    centers = rng.normal(size=(n_topics, dim)) * 5.0
    topic_birth = np.sort(rng.integers(0, months, n_topics))
    topic_birth[: n_topics // 4] = 0  # a quarter of topics exist at t=0

    # allocate pages to topics with power-law sizes
    w = zipf_weights(n_topics, 1.1)
    counts = rng.multinomial(n_total, w)
    vecs, topic_of, birth = [], [], []
    for t in range(n_topics):
        if counts[t] == 0:
            continue
        v = centers[t] + rng.normal(size=(counts[t], dim))
        vecs.append(v)
        topic_of.append(np.full(counts[t], t))
        birth.append(np.full(counts[t], topic_birth[t]))
    x = np.concatenate(vecs).astype(np.float32)
    topic_of = np.concatenate(topic_of)
    birth = np.concatenate(birth)
    # normalize-ish for inner product (embeddings trained w/ dot similarity)
    x /= np.maximum(np.linalg.norm(x, axis=1, keepdims=True), 1e-6) / 4.0
    ds = VectorDataset(x, topic_of, centers.astype(np.float32), metric="ip")

    # month-0 residents: born at 0, plus a slice of everything else
    init_mask = birth == 0
    extra = rng.random(n_total) < initial_fraction
    init_mask |= extra & (birth == 0)
    init_ids = np.where(init_mask)[0]

    # per-page popularity: Zipf, re-ranked each month by a drifting score
    pop_rank = rng.permutation(n_total).astype(np.float64)
    ops: List[Operation] = []
    resident = init_ids.tolist()
    resident_set = set(resident)
    for m in range(1, months + 1):
        # --- monthly insert burst: pages born this month ---
        newly = np.where(birth == min(m, months - 1))[0]
        newly = np.asarray([i for i in newly if i not in resident_set],
                           dtype=np.int64)
        if len(newly):
            ops.append(Operation("insert", vectors=x[newly],
                                 ids=newly))
            resident.extend(newly.tolist())
            resident_set.update(newly.tolist())
        # --- popularity drift ---
        pop_rank += rng.normal(size=n_total) * drift * n_total
        res = np.asarray(resident)
        order = np.argsort(pop_rank[res])
        zw = zipf_weights(len(res), zipf_a)
        probs = np.empty(len(res))
        probs[order] = zw
        # --- monthly queries sampled by popularity ---
        qsel = rng.choice(res, size=queries_per_month, p=probs)
        q = x[qsel] + rng.normal(
            size=(queries_per_month, dim)).astype(np.float32) * 0.05
        ops.append(Operation("query", queries=q.astype(np.float32)))

    cfg = WorkloadConfig(n_operations=len(ops), seed=seed)
    return Workload(initial_vectors=x[init_ids],
                    initial_ids=init_ids.astype(np.int64),
                    operations=ops, dataset=ds, config=cfg)
