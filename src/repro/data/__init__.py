"""Data substrate: synthetic vector datasets, the configurable workload
generator and Wikipedia-like trace (paper §7.1), graph generators + neighbor
sampler, and deterministic checkpointable batch pipelines for the model zoo.
"""
from . import datasets, graphs, pipelines, workload, wikipedia  # noqa: F401
