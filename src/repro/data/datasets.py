"""Synthetic vector datasets for index benchmarks.

Deterministic generators standing in for SIFT / MSTuring / Wikipedia
embeddings: mixtures of anisotropic Gaussian clusters with power-law cluster
sizes — the regime partitioned indexes are designed for (real embedding
spaces are strongly clustered; uniform noise is the adversarial case and is
available via ``uniform``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np


@dataclass
class VectorDataset:
    vectors: np.ndarray          # (n, d) float32
    cluster_of: np.ndarray       # (n,) generating cluster id
    centers: np.ndarray          # (c, d)
    metric: str = "l2"

    @property
    def n(self) -> int:
        return self.vectors.shape[0]

    @property
    def dim(self) -> int:
        return self.vectors.shape[1]

    def ground_truth(self, queries: np.ndarray, k: int,
                     exclude_self: bool = False) -> np.ndarray:
        """Exact top-k ids (brute force, blocked to bound memory)."""
        q = np.ascontiguousarray(queries, np.float32)
        out = np.empty((len(q), k), dtype=np.int64)
        x = self.vectors
        x2 = np.sum(x.astype(np.float64) ** 2, axis=1)
        for i0 in range(0, len(q), 256):
            qs = q[i0:i0 + 256]
            if self.metric == "l2":
                d = x2[None, :] - 2.0 * (qs @ x.T)
            else:
                d = -(qs @ x.T)
            idx = np.argpartition(d, k - 1, axis=1)[:, :k]
            dd = np.take_along_axis(d, idx, axis=1)
            o = np.argsort(dd, axis=1, kind="stable")
            out[i0:i0 + 256] = np.take_along_axis(idx, o, axis=1)
        return out


def clustered(n: int, dim: int, n_clusters: int = 64, seed: int = 0,
              spread: float = 1.0, center_scale: float = 6.0,
              power: float = 1.2, metric: str = "l2") -> VectorDataset:
    """Power-law-sized Gaussian mixture ('embedding-like')."""
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(n_clusters, dim)) * center_scale
    w = (1.0 / np.arange(1, n_clusters + 1) ** power)
    w /= w.sum()
    counts = rng.multinomial(n, w)
    xs, cid = [], []
    for c in range(n_clusters):
        if counts[c] == 0:
            continue
        scale = spread * (0.5 + rng.random())
        xs.append(centers[c] + rng.normal(size=(counts[c], dim)) * scale)
        cid.append(np.full(counts[c], c))
    x = np.concatenate(xs).astype(np.float32)
    cid = np.concatenate(cid)
    perm = rng.permutation(len(x))
    return VectorDataset(x[perm], cid[perm], centers.astype(np.float32),
                         metric)


def uniform(n: int, dim: int, seed: int = 0,
            metric: str = "l2") -> VectorDataset:
    """Uniform Gaussian — the hard case for partitioned indexes."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, dim)).astype(np.float32)
    return VectorDataset(x, np.zeros(n, dtype=np.int64),
                         np.zeros((1, dim), dtype=np.float32), metric)


def queries_near(ds: VectorDataset, n_queries: int, seed: int = 1,
                 jitter: float = 0.1,
                 cluster_probs: Optional[np.ndarray] = None) -> np.ndarray:
    """Queries as jittered data points, optionally with cluster-level skew
    (``cluster_probs`` over ``ds.centers`` rows)."""
    rng = np.random.default_rng(seed)
    if cluster_probs is None:
        base = rng.integers(0, ds.n, n_queries)
    else:
        cp = cluster_probs / cluster_probs.sum()
        cids = rng.choice(len(cp), size=n_queries, p=cp)
        base = np.empty(n_queries, dtype=np.int64)
        for c in np.unique(cids):
            pool = np.where(ds.cluster_of == c)[0]
            if len(pool) == 0:
                pool = np.arange(ds.n)
            sel = cids == c
            base[sel] = rng.choice(pool, size=int(sel.sum()))
    q = ds.vectors[base] + rng.normal(
        size=(n_queries, ds.dim)).astype(np.float32) * jitter
    return q.astype(np.float32)


def zipf_weights(n: int, a: float = 1.1) -> np.ndarray:
    w = 1.0 / np.arange(1, n + 1, dtype=np.float64) ** a
    return w / w.sum()
