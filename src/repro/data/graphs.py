"""Graph substrate: CSR graphs, generators, and the neighbor sampler.

JAX has no sparse-graph engine — message passing in this framework runs on
edge lists via ``jax.ops.segment_sum`` (see models/gnn.py), and the
``minibatch_lg`` shape requires a *real* neighbor sampler (fanout 15-10),
implemented here over CSR with deterministic numpy sampling.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np


@dataclass
class CSRGraph:
    indptr: np.ndarray     # (n+1,) int64
    indices: np.ndarray    # (nnz,) int32 — neighbor ids
    n_nodes: int

    @property
    def n_edges(self) -> int:
        return len(self.indices)

    def degree(self) -> np.ndarray:
        return np.diff(self.indptr)


def from_edges(src: np.ndarray, dst: np.ndarray, n_nodes: int) -> CSRGraph:
    order = np.argsort(src, kind="stable")
    src, dst = src[order], dst[order]
    indptr = np.searchsorted(src, np.arange(n_nodes + 1))
    return CSRGraph(indptr.astype(np.int64), dst.astype(np.int32), n_nodes)


def to_edges(g: CSRGraph) -> Tuple[np.ndarray, np.ndarray]:
    src = np.repeat(np.arange(g.n_nodes, dtype=np.int32), g.degree())
    return src, g.indices


# ---------------------------------------------------------------------------
# Generators
# ---------------------------------------------------------------------------

def community_graph(n_nodes: int, avg_degree: float, n_comm: int = 16,
                    p_in: float = 0.9, d_feat: int = 64, n_classes: int = 7,
                    seed: int = 0) -> Tuple[CSRGraph, np.ndarray, np.ndarray]:
    """Cora/citation-like: community structure, features correlated with
    labels.  Returns (graph, features (n, d), labels (n,))."""
    rng = np.random.default_rng(seed)
    comm = rng.integers(0, n_comm, n_nodes)
    n_edges = int(n_nodes * avg_degree)
    src = rng.integers(0, n_nodes, n_edges)
    same = rng.random(n_edges) < p_in
    dst = np.empty(n_edges, dtype=np.int64)
    # intra-community edges: pick a random node from the same community
    order = np.argsort(comm, kind="stable")
    bounds = np.searchsorted(comm[order], np.arange(n_comm + 1))
    for c in range(n_comm):
        sel = same & (comm[src] == c)
        pool = order[bounds[c]:bounds[c + 1]]
        if len(pool) and sel.any():
            dst[sel] = rng.choice(pool, size=int(sel.sum()))
    dst[~same] = rng.integers(0, n_nodes, int((~same).sum()))
    # symmetrize
    s = np.concatenate([src, dst])
    d = np.concatenate([dst, src])
    keep = s != d
    g = from_edges(s[keep], d[keep], n_nodes)
    labels = comm % n_classes
    proto = rng.normal(size=(n_classes, d_feat)) * 2.0
    feats = (proto[labels] + rng.normal(size=(n_nodes, d_feat))
             ).astype(np.float32)
    return g, feats, labels.astype(np.int32)


def power_law_graph(n_nodes: int, avg_degree: float,
                    seed: int = 0) -> CSRGraph:
    """Preferential-attachment-ish degree distribution (products/reddit-like
    topology at reduced scale)."""
    rng = np.random.default_rng(seed)
    n_edges = int(n_nodes * avg_degree)
    # Zipf-weighted endpoints give heavy-tailed degrees cheaply
    w = 1.0 / np.arange(1, n_nodes + 1) ** 0.5
    w /= w.sum()
    src = rng.choice(n_nodes, size=n_edges, p=w)
    dst = rng.integers(0, n_nodes, n_edges)
    keep = src != dst
    s = np.concatenate([src[keep], dst[keep]])
    d = np.concatenate([dst[keep], src[keep]])
    return from_edges(s, d, n_nodes)


def molecule_batch(batch: int, n_nodes: int = 30, n_edges: int = 64,
                   d_feat: int = 16, seed: int = 0
                   ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Batched small graphs (block-diagonal edge list).

    Returns (src, dst, feats (batch*n_nodes, d), graph_of (batch*n_nodes,)).
    """
    rng = np.random.default_rng(seed)
    srcs, dsts = [], []
    for b in range(batch):
        # random connected-ish molecule: a path + random chords
        path = np.arange(n_nodes - 1)
        s = np.concatenate([path, rng.integers(0, n_nodes,
                                               n_edges - (n_nodes - 1))])
        t = np.concatenate([path + 1, rng.integers(0, n_nodes,
                                                   n_edges - (n_nodes - 1))])
        srcs.append(s + b * n_nodes)
        dsts.append(t + b * n_nodes)
    src = np.concatenate(srcs).astype(np.int32)
    dst = np.concatenate(dsts).astype(np.int32)
    src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
    feats = rng.normal(size=(batch * n_nodes, d_feat)).astype(np.float32)
    graph_of = np.repeat(np.arange(batch, dtype=np.int32), n_nodes)
    return src, dst, feats, graph_of


# ---------------------------------------------------------------------------
# Neighbor sampler (minibatch_lg shape)
# ---------------------------------------------------------------------------

@dataclass
class SampledBlock:
    """One hop of a sampled computation graph, padded to fixed fanout.

    ``neighbors[i, f]`` is the f-th sampled neighbor of seed i (self-loop
    padding when degree < fanout — standard GraphSAGE practice)."""
    seeds: np.ndarray          # (n_seeds,)
    neighbors: np.ndarray      # (n_seeds, fanout) int32
    mask: np.ndarray           # (n_seeds, fanout) bool — real vs padded


def sample_blocks(g: CSRGraph, seeds: np.ndarray, fanouts: Sequence[int],
                  rng: np.random.Generator) -> List[SampledBlock]:
    """Multi-hop fanout sampling: returns blocks outermost-hop-last; the
    frontier of each block is the seed set of the next."""
    blocks: List[SampledBlock] = []
    frontier = np.asarray(seeds, dtype=np.int64)
    for fanout in fanouts:
        deg = g.indptr[frontier + 1] - g.indptr[frontier]
        neigh = np.empty((len(frontier), fanout), dtype=np.int32)
        mask = deg[:, None] > 0
        # vectorized sample-with-replacement from each neighbor list
        offs = (rng.random((len(frontier), fanout))
                * np.maximum(deg, 1)[:, None]).astype(np.int64)
        neigh = g.indices[(g.indptr[frontier][:, None] + offs)
                          .astype(np.int64)]
        neigh = np.where(mask, neigh, frontier[:, None].astype(np.int32))
        blocks.append(SampledBlock(
            seeds=frontier, neighbors=neigh,
            mask=np.broadcast_to(mask, neigh.shape)))
        frontier = np.unique(neigh.ravel()).astype(np.int64)
    return blocks


def sampled_subgraph(g: CSRGraph, seeds: np.ndarray,
                     fanouts: Sequence[int], seed: int = 0
                     ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Flatten sampled blocks into one (src, dst, nodes) edge list over a
    compacted node set — the form models/gnn.py consumes."""
    rng = np.random.default_rng(seed)
    blocks = sample_blocks(g, seeds, fanouts, rng)
    srcs, dsts = [], []
    for blk in blocks:
        s = np.repeat(blk.seeds, blk.neighbors.shape[1])
        d = blk.neighbors.ravel()
        keep = blk.mask.ravel()
        srcs.append(d[keep])           # message flows neighbor -> seed
        dsts.append(s[keep])
    src = np.concatenate(srcs)
    dst = np.concatenate(dsts)
    nodes = np.unique(np.concatenate([src, dst]))
    remap = np.full(g.n_nodes, -1, dtype=np.int64)
    remap[nodes] = np.arange(len(nodes))
    return (remap[src].astype(np.int32), remap[dst].astype(np.int32),
            nodes.astype(np.int64))
