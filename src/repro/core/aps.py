"""Adaptive Partition Scanning (paper §5, Algorithm 1).

APS decides, per query, how many partitions to scan to hit a recall target:

1. consider the ``f_M * N`` nearest candidate partitions,
2. scan the nearest partition, initializing the query radius ``rho`` (distance
   to the current k-th nearest neighbor),
3. estimate each unscanned candidate's probability of holding a true neighbor
   from hyperspherical-cap intersection volumes (geometry.py),
4. scan candidates in descending probability until the accumulated recall
   estimate ``r = sum_{scanned} p_i`` exceeds the target, recomputing
   probabilities only when ``rho`` shrank by more than ``tau_rho``
   (paper opt. #2) using the precomputed beta table (paper opt. #1).

Two implementations share the estimator math:
  * ``aps_scan`` — the host-driven sequential loop used by the dynamic index
    (faithful Algorithm 1; partition contents are ragged).
  * ``estimate_probs`` / ``recall_estimate`` — jnp functions reused by the
    mesh-sharded engine (distributed.py) inside ``lax.while_loop`` rounds.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, List, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from . import geometry

Array = jnp.ndarray


# ---------------------------------------------------------------------------
# Estimator math (jnp; usable inside jit and from the host loop)
# ---------------------------------------------------------------------------

def estimate_probs(d0_sq: Array, di_sq: Array, cc_dist: Array, rho_sq: Array,
                   table: Array, valid: Array) -> Tuple[Array, Array]:
    """p0 and per-candidate probabilities (Eqs. 7-9).

    d0_sq: ||q-c0||^2 scalar; di_sq (M,): ||q-ci||^2; cc_dist (M,):
    ||ci-c0||; rho_sq: current radius^2; valid (M,): candidate mask with the
    nearest centroid excluded.  All squared quantities — APS never needs the
    unsquared query-centroid distances.
    """
    rho = jnp.sqrt(jnp.maximum(rho_sq, 1e-30))
    h = geometry.bisector_margins(d0_sq, di_sq, cc_dist)
    v = geometry.cap_fraction(h / rho, table)
    v = jnp.where(valid, v, 0.0)
    return geometry.partition_probabilities(v, valid)


def estimate_probs_np(d0_sq: float, di_sq: np.ndarray, cc_dist: np.ndarray,
                      rho_sq: float, table, valid: np.ndarray
                      ) -> Tuple[float, np.ndarray]:
    """Numpy mirror of ``estimate_probs`` for the host scan loop (no jax
    dispatch overhead per radius recompute).  Tested for equivalence.

    ``table`` is either the precomputed 1024-point beta grid (paper opt. #1,
    interpolated) or a callable ``beta_fn(x) -> I_x(a, 1/2)`` evaluating the
    regularized incomplete beta exactly — the APS-RP ablation variant that
    skips precomputation (paper Table 2)."""
    rho = np.sqrt(max(rho_sq, 1e-30))
    h = (di_sq - d0_sq) / (2.0 * np.maximum(cc_dist, 1e-20))
    t = np.clip(h / rho, -1.0, 1.0)
    x = np.clip(1.0 - t * t, 0.0, 1.0)
    if callable(table):
        half = 0.5 * np.asarray(table(x), dtype=np.float64)
    else:
        n = len(table)
        pos = x * (n - 1)
        lo = np.clip(np.floor(pos).astype(np.int64), 0, n - 2)
        frac = pos - lo
        half = 0.5 * (table[lo] * (1.0 - frac) + table[lo + 1] * frac)
    v = np.where(t >= 0, half, 1.0 - half)
    v = np.where(valid, v, 0.0)
    total = float(v.sum())
    if total <= 0:
        return 1.0, np.zeros_like(v)
    vn = v / total
    p0 = float(np.exp(np.sum(np.log1p(-np.clip(vn[valid], 0.0, 1 - 1e-7)))))
    p = (1.0 - p0) * vn
    return p0, p


def estimate_probs_batch(d0_sq, di_sq, cc_dist, rho_sq, table, valid):
    """``estimate_probs_np`` lifted to ``(B, M)`` candidate arrays — the
    estimator core of the vectorized batch planner (``multiquery``).

    d0_sq (B,): ||q_b - c0_b||^2; di_sq (B, M): per-candidate squared
    distances; cc_dist (B, M): ||c_i - c0_b||; rho_sq (B,): per-query
    radius^2; valid (B, M): candidate mask.  Convention: column 0 of every
    row holds that query's nearest candidate and is excluded
    (``valid[:, 0]`` is False) — under that convention each row is
    bitwise-identical to a per-row ``estimate_probs_np`` call (same
    pairwise-summation trees), which is what the planner parity tests
    pin down.  Other mask patterns are handled correctly (every valid
    column contributes to ``p0``) but only agree with the scalar mirror
    to float rounding.

    Works unchanged on host numpy arrays (the executor's default) and on
    jnp arrays (jittable — the device-planner variant); ``table`` is the
    precomputed beta grid (callables are host-only).

    Returns (p0 (B,), p (B, M)).
    """
    xp = np if isinstance(di_sq, np.ndarray) else jnp
    rho = xp.sqrt(xp.maximum(rho_sq, 1e-30))[:, None]
    h = (di_sq - d0_sq[:, None]) / (2.0 * xp.maximum(cc_dist, 1e-20))
    t = xp.clip(h / rho, -1.0, 1.0)
    x = xp.clip(1.0 - t * t, 0.0, 1.0)
    if callable(table):
        if xp is not np:
            raise TypeError("callable beta tables are host-only; pass the "
                            "precomputed grid for the jnp path")
        half = 0.5 * np.asarray(table(x), dtype=np.float64)
    else:
        tbl = xp.asarray(table)
        n = tbl.shape[0]
        pos = x * (n - 1)
        itype = np.int64 if xp is np else jnp.int32
        lo = xp.clip(xp.floor(pos).astype(itype), 0, n - 2)
        frac = pos - lo
        half = 0.5 * (tbl[lo] * (1.0 - frac) + tbl[lo + 1] * frac)
    v = xp.where(t >= 0, half, 1.0 - half)
    v = xp.where(valid, v, 0.0)
    total = v.sum(axis=1)
    ok = total > 0
    vn = v / xp.where(ok, total, 1.0)[:, None]
    # p0 = prod over valid candidates.  The tail slice reproduces
    # estimate_probs_np's compacted vn[valid] summation tree exactly under
    # the planner convention (column 0 invalid -> its term is an exact
    # 0.0, an additive identity); adding the column-0 term separately
    # keeps unconventional masks correct too.
    log1m = xp.where(valid, xp.log1p(-xp.clip(vn, 0.0, 1.0 - 1e-7)), 0.0)
    p0 = xp.exp(log1m[:, 1:].sum(axis=1) + log1m[:, 0])
    p0 = xp.where(ok, p0, 1.0)
    p = xp.where(ok[:, None], (1.0 - p0)[:, None] * vn, 0.0)
    return p0, p


def rho_sq_batch(kth, *, metric: str, q_norm_sq=None, max_norm_sq=None):
    """Vectorized item-distance -> squared-geometry-radius map: the batched
    mirror of ``QuakeIndex._rho_sq_from_item_dist`` used by the multi-round
    batched executor and the fused device planner.

    ``kth`` (B,) is the running k-th item distance in minimization
    convention (true squared L2, or -score for IP).  For IP the radius
    lives in the MIPS-augmented space: rho^2 = ||q||^2 + M^2 + 2 * (-s_k).
    Works on numpy and jnp arrays alike (same xp-dispatch convention as
    ``estimate_probs_batch``).
    """
    xp = np if isinstance(kth, np.ndarray) else jnp
    if metric == "l2":
        return xp.maximum(kth, 0.0)
    return xp.maximum(q_norm_sq + max_norm_sq + 2.0 * kth, 0.0)


# ---------------------------------------------------------------------------
# Host-driven Algorithm 1 (dynamic index path)
# ---------------------------------------------------------------------------

@dataclass
class APSResult:
    ids: np.ndarray            # (k,) item ids (vector ids or child partition ids)
    dists: np.ndarray          # (k,) minimization-convention distances
    scanned: np.ndarray        # partition indices scanned, in scan order
    nprobe: int = 0
    recall_estimate: float = 0.0
    recompute_count: int = 0
    trace: List[float] = field(default_factory=list)


class TopK:
    """Simple numpy top-k accumulator (minimization convention)."""

    def __init__(self, k: int):
        self.k = k
        self.dists = np.full(k, np.inf, dtype=np.float64)
        self.ids = np.full(k, -1, dtype=np.int64)

    def update(self, dists: np.ndarray, ids: np.ndarray) -> None:
        if len(dists) == 0:
            return
        d = np.concatenate([self.dists, dists.astype(np.float64)])
        i = np.concatenate([self.ids, ids.astype(np.int64)])
        if len(d) > self.k:
            sel = np.argpartition(d, self.k - 1)[:self.k]
            sel = sel[np.argsort(d[sel], kind="stable")]
        else:
            sel = np.argsort(d, kind="stable")
        self.dists, self.ids = d[sel], i[sel]

    @property
    def full(self) -> bool:
        return np.isfinite(self.dists[self.k - 1])

    @property
    def kth(self) -> float:
        return float(self.dists[self.k - 1])


def aps_scan(
    *,
    cand_centroid_dists_sq: np.ndarray,   # (M,) ||q - c_i||^2 (geometry space)
    cand_cc_dists: np.ndarray,            # (M,) ||c_i - c_nearest||
    scan_partition: Callable[[int], Tuple[np.ndarray, np.ndarray]],
    item_dist_to_rho_sq: Callable[[float], float],
    k: int,
    recall_target: float,
    table: np.ndarray,
    tau_rho: float = 0.01,
    max_scan: int | None = None,
) -> APSResult:
    """Algorithm 1 over an arbitrary candidate set.

    ``scan_partition(m)`` scans candidate m (local index into the candidate
    arrays) and returns (dists, ids) of its items in minimization convention.
    ``item_dist_to_rho_sq`` maps the current k-th item distance to the
    squared radius in the geometry space (identity for L2 on raw vectors;
    MIPS augmentation otherwise).
    """
    m_total = len(cand_centroid_dists_sq)
    assert m_total >= 1
    order0 = int(np.argmin(cand_centroid_dists_sq))
    heap = TopK(k)
    max_scan = m_total if max_scan is None else min(max_scan, m_total)

    # --- scan the nearest partition, set rho ---
    scanned_mask = np.zeros(m_total, dtype=bool)
    scan_order: List[int] = [order0]
    d, i = scan_partition(order0)
    heap.update(d, i)
    scanned_mask[order0] = True

    d0_sq = float(cand_centroid_dists_sq[order0])
    di = np.asarray(cand_centroid_dists_sq, dtype=np.float64)
    cc = np.maximum(np.asarray(cand_cc_dists, dtype=np.float64), 1e-12)
    tbl = table if callable(table) else np.asarray(table, dtype=np.float64)
    valid = np.ones(m_total, dtype=bool)
    valid[order0] = False

    recomputes = 0

    def compute_probs(rho_sq: float) -> Tuple[float, np.ndarray]:
        nonlocal recomputes
        recomputes += 1
        return estimate_probs_np(d0_sq, di, cc, rho_sq, tbl, valid)

    if not heap.full:
        # Fewer than k items seen: no radius yet -> conservatively keep
        # scanning by centroid-distance order until the heap fills.
        p0, probs = 0.0, None
        rho_sq = np.inf
    else:
        rho_sq = item_dist_to_rho_sq(heap.kth)
        p0, probs = compute_probs(rho_sq)

    result = APSResult(ids=heap.ids, dists=heap.dists,
                       scanned=np.asarray(scan_order), nprobe=1,
                       recall_estimate=p0)
    r = p0
    trace = [r]

    while r < recall_target and len(scan_order) < max_scan:
        if probs is None:  # heap not yet full: nearest-centroid order
            rem = np.where(~scanned_mask)[0]
            nxt = int(rem[np.argmin(cand_centroid_dists_sq[rem])])
        else:
            masked = np.where(scanned_mask, -np.inf, probs)
            nxt = int(np.argmax(masked))
            if masked[nxt] == -np.inf:
                break
        d, i = scan_partition(nxt)
        heap.update(d, i)
        scanned_mask[nxt] = True
        scan_order.append(nxt)

        if heap.full:
            new_rho_sq = item_dist_to_rho_sq(heap.kth)
            if probs is None or (
                    abs(np.sqrt(new_rho_sq) - np.sqrt(rho_sq))
                    > tau_rho * np.sqrt(rho_sq)):
                rho_sq = new_rho_sq
                p0, probs = compute_probs(rho_sq)
        if probs is not None:
            # r = p0 + sum of probabilities of scanned non-nearest candidates
            r = p0 + float(np.sum(np.where(scanned_mask & valid, probs, 0.0)))
        trace.append(r)

    result.ids = heap.ids
    result.dists = heap.dists
    result.scanned = np.asarray(scan_order)
    result.nprobe = len(scan_order)
    result.recall_estimate = float(r)
    result.recompute_count = recomputes
    result.trace = trace
    return result
