# The paper's primary contribution: Quake's adaptive partitioned index.
#   geometry     — hyperspherical-cap recall math (paper §5)
#   cost_model   — lambda(s) latency model + cost deltas (paper §4.1/§4.2.2)
#   kmeans       — jit-compiled clustering (build/split/refine substrate)
#   aps          — Adaptive Partition Scanning (paper §5, Algorithm 1)
#   index        — dynamic multi-level partitioned index (paper §3)
#   maintenance  — estimate/verify/commit maintenance loop (paper §4.2)
#   distributed  — mesh-sharded serving engine (paper §6, TPU adaptation)
#   multiquery   — batched scan-once-per-partition policy (paper §7.4)
#   journal      — mutation journal: the snapshot invalidation protocol
#                  (per-partition dirty sets, COW delta refresh, §8.2)
#   serving      — online serving runtime: micro-batching queue,
#                  cross-batch union riding, result cache,
#                  drift-triggered maintenance (§3's online loop)
from .index import QuakeConfig, QuakeIndex, SearchResult  # noqa: F401
from .journal import Delta, MutationJournal  # noqa: F401
from .maintenance import Maintainer, MaintenancePolicy  # noqa: F401
from .cost_model import LatencyModel  # noqa: F401
from .distributed import (EngineConfig, IndexSnapshot,  # noqa: F401
                          ShardedQuakeEngine, SnapshotPatch)
from .serving import (STATUS_FAILED, STATUS_OK,  # noqa: F401
                      STATUS_PARTIAL, STATUS_SHED, TERMINAL_STATUSES,
                      MaintenanceScheduler, MaintenanceTriggers,
                      QueryResult, ResultCache, ServingConfig,
                      ServingRuntime)
