"""Mutation journal — the invalidation protocol between the dynamic index
and its copy-on-write device snapshots (paper §8.2).

The dynamic ``QuakeIndex`` is a host-side structure; searches are served
from dense device-resident ``IndexSnapshot``s (batched executor, sharded
engine).  Before this module the coherence contract was a single integer:
any mutation bumped ``index.version`` and every consumer rebuilt its full
``(P, S_cap, d)`` snapshot — a one-vector insert cost an O(N*d) host
rebuild plus a full device transfer.

The journal replaces the blanket counter with *what actually changed*:

  * ``record(dirty=...)``        — content changes confined to known level-0
                                   partitions (insert / delete / refine);
                                   consumers patch exactly those rows.
  * ``record(structural=True)``  — the partition directory itself changed
                                   (split / merge / level add-remove);
                                   consumers must rebuild.
  * ``record()``                 — a mutation that does not touch the base
                                   level (upper-level split/merge); bumps
                                   the version clock, dirties nothing.

``version`` stays a monotonic clock so existing fingerprint-style
consumers keep working; ``delta_since(v)`` folds every entry after ``v``
into one :class:`Delta`.  Entries are trimmed beyond ``max_entries`` —
a consumer older than the trim floor gets ``None`` (= rebuild), so the
journal is bounded regardless of how stale a snapshot is.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Iterable, Optional, Set

__all__ = ["Delta", "JournalEntry", "MutationJournal"]


@dataclass(frozen=True)
class JournalEntry:
    version: int                 # clock value after this mutation
    dirty: frozenset             # level-0 partition ids with content changes
    structural: bool             # partition directory changed
    reason: str = ""             # "insert" | "delete" | "split" | ...


@dataclass
class Delta:
    """Folded view of every journal entry after some consumer version."""
    dirty: Set[int] = field(default_factory=set)
    structural: bool = False

    @property
    def empty(self) -> bool:
        return not self.dirty and not self.structural


class MutationJournal:
    """Bounded log of index mutations, folded on demand per consumer."""

    def __init__(self, max_entries: int = 4096):
        self.version = 0           # monotonic mutation clock
        self.max_entries = max_entries
        self._entries: Deque[JournalEntry] = deque()
        self._floor = 0            # deltas from versions < _floor are lost
        self.overflowed = False    # ever trimmed? consumers older than the
                                   # floor silently lose their delta path
                                   # (delta_since -> None -> full rebuild),
                                   # so the loss window is surfaced
                                   # explicitly (ServingRuntime.stats())
        self.overflow_count = 0    # entries trimmed so far

    # ------------------------------------------------------------------
    # Producer side (QuakeIndex / Maintainer)
    # ------------------------------------------------------------------

    def record(self, dirty: Optional[Iterable[int]] = None,
               structural: bool = False, reason: str = "") -> int:
        """Log one mutation; returns the new version."""
        self.version += 1
        dset = frozenset(int(j) for j in dirty) if dirty is not None \
            else frozenset()
        self._entries.append(JournalEntry(
            version=self.version, dirty=dset,
            structural=structural, reason=reason))
        while len(self._entries) > self.max_entries:
            self._floor = self._entries.popleft().version
            self.overflowed = True
            self.overflow_count += 1
        return self.version

    # ------------------------------------------------------------------
    # Consumer side (snapshot caches)
    # ------------------------------------------------------------------

    def delta_since(self, version: int) -> Optional[Delta]:
        """Fold entries after ``version`` into one Delta.

        Returns an *empty* Delta when the consumer is current, and ``None``
        when the journal can no longer reconstruct the gap (consumer older
        than the trim floor) — the caller must fall back to a full rebuild.
        """
        if version >= self.version:
            return Delta()
        if version < self._floor:
            return None
        d = Delta()
        for e in self._entries:
            if e.version <= version:
                continue
            d.dirty |= e.dirty
            d.structural |= e.structural
        return d

    def entries_since(self, version: int) -> list:
        """Raw entries after ``version`` (introspection / logging)."""
        return [e for e in self._entries if e.version > version]
