"""Mesh-sharded Quake serving engine — the TPU adaptation of NUMA-aware
query processing (paper §6, Algorithm 2).

Mapping (see DESIGN.md §3):

  NUMA node                  ->  TPU chip (HBM = local memory)
  round-robin partition      ->  partition axis sharded over ("pod","data")
  placement
  worker threads scan local  ->  SPMD: every device scans only its resident
  partitions                     partition shard (shard_map)
  coordinator merges every   ->  per-round hierarchical top-k merge
  T_wait + recall check          (all_gather over the partition axes) +
                                 all-reduced APS recall estimate; a
                                 lax.while_loop exits when every query in the
                                 batch has met its recall target
  work stealing              ->  none (SPMD lock-step); balance is structural,
                                 maintained by the cost model's split policy

The engine serves *snapshots* of the dynamic index (copy-on-write semantics,
paper §8.2): ``IndexSnapshot.from_index`` pads the base level into a dense
``(P, S_cap, d)`` tensor.  Three compiled search paths:

  * ``search_fixed``     — static nprobe per query (baseline; static HLO,
                           the roofline reference point).
  * ``search_adaptive``  — APS rounds in a ``lax.while_loop``; each round
                           every device scans its next ``chunk`` best local
                           partitions for every active query (Algorithm 2).
  * ``search_bruteforce``— exact scan of the full shard (ground truth, the
                           large-batch multi-query policy, and the two-tower
                           ``retrieval_cand`` path).

Query parallelism: the batch is sharded over the ``model`` axis when one is
present, so a (pod, data, model) mesh gives partition parallelism x query
parallelism — the 2-D analogue of "threads within a NUMA node".
"""
from __future__ import annotations

import functools
import math
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..compat import shard_map
from ..kernels.ref import MASK_DIST, merge_topk, pairwise_l2_sq
from . import geometry
from .index import QuakeIndex

Array = jax.Array


# ---------------------------------------------------------------------------
# Snapshot
# ---------------------------------------------------------------------------

# Row-replacement scatters behind apply_delta.  The donated variant
# updates the operand buffer in place (XLA input-output aliasing): the
# refresh cost is O(dirty rows), not O(snapshot) — but the donated array
# is consumed.  Bucket padding in build_patch keeps the set of compiled
# (shape, dtype) specializations small.
_scatter_rows = jax.jit(lambda a, sel, u: a.at[sel].set(u))
_scatter_rows_donated = jax.jit(lambda a, sel, u: a.at[sel].set(u),
                                donate_argnums=(0,))


@dataclass
class SnapshotPatch:
    """Host-side replacement rows for a subset of snapshot partitions —
    the unit of incremental (copy-on-write) refresh.  Built against a fixed
    slot capacity by ``IndexSnapshot.build_patch``; consumed on device by
    ``IndexSnapshot.apply_delta`` and by host-side mirrors (executor
    ``_flat_ids``/``_sizes``)."""
    rows: np.ndarray        # (R,) int32 partition ids, sorted; the tail
                            # may duplicate the last row (bucket padding —
                            # identical updates, inert under scatter)
    data: np.ndarray        # (R, S_cap, d) float32
    ids: np.ndarray         # (R, S_cap) int32, -1 on padding
    centroids: np.ndarray   # (R, d) float32
    sizes: np.ndarray       # (R,) int32


@jax.tree_util.register_dataclass
@dataclass
class IndexSnapshot:
    """Dense, shardable view of the base level.

    data:      (P, S_cap, d)  padded partition contents
    ids:       (P, S_cap)     external ids (int32), -1 on padding
    centroids: (P, d)
    sizes:     (P,)           true sizes (0 marks padding partitions)
    beta_table:(1024,)        precomputed regularized-incomplete-beta values
    """
    data: Array
    ids: Array
    centroids: Array
    sizes: Array
    beta_table: Array
    scales: Optional[Array] = None   # (P, S_cap) per-slot dequant scales
                                     # when data holds int8 codes (§8.2)

    @property
    def num_partitions(self) -> int:
        return self.data.shape[0]

    @property
    def capacity(self) -> int:
        return self.data.shape[1]

    @property
    def dim(self) -> int:
        return self.data.shape[2]

    @staticmethod
    def align_capacity(s_cap: int) -> int:
        """Round a slot capacity up so Pallas scan tiles divide it exactly:
        next power of two below 512, next multiple of 512 above."""
        s_cap = max(s_cap, 8)
        if s_cap <= 512:
            p2 = 8
            while p2 < s_cap:
                p2 *= 2
            return p2
        return -(-s_cap // 512) * 512

    @staticmethod
    def from_index(index: QuakeIndex, pad_partitions_to: int = 1,
                   capacity: Optional[int] = None,
                   headroom: float = 1.0,
                   allow_truncation: bool = False) -> "IndexSnapshot":
        """Dense snapshot of the base level.

        ``headroom`` pads the slot capacity beyond the current largest
        partition (>1.0 leaves slack so subsequent ``apply_delta`` patches
        rarely force a reshape).  An explicit ``capacity`` smaller than the
        largest partition raises unless ``allow_truncation=True``; with
        truncation allowed the recorded ``sizes`` are clamped to what was
        actually stored, so they always agree with the ``ids >= 0`` mask.
        """
        lvl0 = index.levels[0]
        p_real = lvl0.num_partitions
        p = ((p_real + pad_partitions_to - 1)
             // pad_partitions_to) * pad_partitions_to
        sizes = np.zeros(p, dtype=np.int32)
        sizes[:p_real] = lvl0.sizes()
        if capacity is None:
            s_cap = max(int(math.ceil(int(sizes.max(initial=0))
                                      * max(headroom, 1.0))), 1)
        else:
            s_cap = capacity
        s_cap = IndexSnapshot.align_capacity(s_cap)
        if int(sizes.max(initial=0)) > s_cap and not allow_truncation:
            raise ValueError(
                f"IndexSnapshot capacity {s_cap} would truncate a "
                f"partition of size {int(sizes.max())}; pass "
                "allow_truncation=True to store a lossy snapshot")
        d = index.dim
        data = np.zeros((p, s_cap, d), dtype=np.float32)
        ids = np.full((p, s_cap), -1, dtype=np.int32)
        for j in range(p_real):
            s = min(int(sizes[j]), s_cap)
            sizes[j] = s          # recorded size == stored size, always
            data[j, :s] = lvl0.vectors[j][:s]
            ext = lvl0.ids[j][:s]
            if len(ext) and int(ext.max()) > np.iinfo(np.int32).max:
                raise ValueError(
                    "IndexSnapshot stores external ids as int32; id "
                    f"{int(ext.max())} does not fit (partition {j})")
            ids[j, :s] = ext
        cents = np.zeros((p, d), dtype=np.float32)
        cents[:p_real] = lvl0.centroids
        # padding partitions: park centroids far away so routing never
        # selects them (MASK via sizes==0 also applies)
        if p > p_real:
            cents[p_real:] = 1e6
        table = geometry.betainc_table(
            d if index.config.metric == "l2" else d + 1)
        return IndexSnapshot(
            data=jnp.asarray(data), ids=jnp.asarray(ids),
            centroids=jnp.asarray(cents), sizes=jnp.asarray(sizes),
            beta_table=jnp.asarray(table))

    # ------------------------------------------------------------------
    # Incremental (copy-on-write) refresh
    # ------------------------------------------------------------------

    @staticmethod
    def build_patch(index: QuakeIndex, rows, capacity: int,
                    bucket: int = 16) -> "SnapshotPatch":
        """Host-side patch for ``rows`` (level-0 partition ids) against a
        snapshot of slot capacity ``capacity``.  Raises ``ValueError`` if a
        row no longer fits — the caller falls back to a full rebuild.

        ``bucket`` floors the padded row count; above it the count rounds
        to the next power of two (padding duplicates the last row — an
        identical-update no-op under scatter).  Each distinct patch shape
        pays one scatter compile per process, so the power-of-two ladder
        caps that at ~log2(P) compiles total regardless of how the dirty
        set size drifts across refreshes."""
        lvl0 = index.levels[0]
        uniq = sorted({int(j) for j in rows})
        if uniq and (uniq[0] < 0 or uniq[-1] >= lvl0.num_partitions):
            raise ValueError(f"patch rows {uniq} outside partition "
                             f"directory [0, {lvl0.num_partitions})")
        if uniq and bucket > 1:
            r_pad = bucket
            while r_pad < len(uniq):
                r_pad *= 2
            uniq = uniq + [uniq[-1]] * (r_pad - len(uniq))
        rows = np.asarray(uniq, dtype=np.int32)
        r, d = len(rows), index.dim
        data = np.zeros((r, capacity, d), dtype=np.float32)
        ids = np.full((r, capacity), -1, dtype=np.int32)
        sizes = np.zeros(r, dtype=np.int32)
        for i, j in enumerate(rows):
            s = len(lvl0.vectors[j])
            if s > capacity:
                raise ValueError(
                    f"partition {j} (size {s}) exceeds snapshot "
                    f"capacity {capacity}")
            ext = lvl0.ids[j]
            if s and int(ext.max()) > np.iinfo(np.int32).max:
                raise ValueError(
                    "IndexSnapshot stores external ids as int32; id "
                    f"{int(ext.max())} does not fit (partition {j})")
            data[i, :s] = lvl0.vectors[j]
            ids[i, :s] = ext
            sizes[i] = s
        cents = np.ascontiguousarray(
            lvl0.centroids[rows], dtype=np.float32) if r else \
            np.zeros((0, d), dtype=np.float32)
        return SnapshotPatch(rows=rows, data=data, ids=ids,
                             centroids=cents, sizes=sizes)

    def apply_delta(self, patch: "SnapshotPatch",
                    donate: bool = False) -> "IndexSnapshot":
        """Return a new snapshot with the patch rows replaced on device;
        only the patch moves host->device.

        ``donate=False`` (true copy-on-write): the previous snapshot stays
        readable — in-flight readers keep serving from it — at the cost of
        an O(P*S_cap*d) device-side buffer copy.  ``donate=True`` updates
        the donated buffers in place (the patch cost is O(dirty rows), the
        executor steady-state) but *consumes* this snapshot: the caller
        must own it exclusively, and any handle to it is dead afterwards.
        """
        if self.scales is not None:
            raise ValueError("apply_delta does not support quantized "
                             "(int8) snapshots; rebuild instead")
        if len(patch.rows) == 0:
            return self
        if int(patch.rows.max()) >= self.num_partitions:
            raise ValueError("patch rows outside snapshot partition range")
        if patch.data.shape[1] != self.capacity:
            raise ValueError(
                f"patch capacity {patch.data.shape[1]} != snapshot "
                f"capacity {self.capacity}")
        sel = jnp.asarray(patch.rows)
        set_rows = _scatter_rows_donated if donate else _scatter_rows
        return IndexSnapshot(
            data=set_rows(self.data, sel,
                          jnp.asarray(patch.data).astype(self.data.dtype)),
            ids=set_rows(self.ids, sel,
                         jnp.asarray(patch.ids).astype(self.ids.dtype)),
            centroids=set_rows(
                self.centroids, sel,
                jnp.asarray(patch.centroids).astype(self.centroids.dtype)),
            sizes=set_rows(self.sizes, sel,
                           jnp.asarray(patch.sizes).astype(self.sizes.dtype)),
            beta_table=self.beta_table,
            scales=None)

    @staticmethod
    def synthetic(p: int, s_cap: int, d: int, seed: int = 0,
                  dtype=jnp.float32) -> "IndexSnapshot":
        """Random snapshot for benchmarks / dry-runs (no host data)."""
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        cents = jax.random.normal(k1, (p, d), dtype) * 3.0
        noise = jax.random.normal(k2, (p, s_cap, d), dtype)
        data = cents[:, None, :] + noise
        ids = jnp.arange(p * s_cap, dtype=jnp.int32).reshape(p, s_cap)
        sizes = jnp.full((p,), s_cap, jnp.int32)
        table = jnp.asarray(geometry.betainc_table(d))
        return IndexSnapshot(data, ids, cents, sizes, table)


# ---------------------------------------------------------------------------
# Sharded engine
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class EngineConfig:
    metric: str = "l2"
    k: int = 100
    nprobe: int = 16             # search_fixed probes (per whole index)
    chunk: int = 2               # adaptive: local partitions per round
    max_rounds: int = 16
    recall_target: float = 0.9
    batch_axis: Optional[str] = "model"   # query-parallel axis (None = off)
    part_axes: Tuple[str, ...] = ("data",)  # partition-parallel axes
    # --- scan implementation (§Perf hillclimb) ---
    #  "gather":       per-query gather + einsum (paper-faithful XLA
    #                  baseline; every scanned byte moves ~3x through HBM)
    #  "union_jnp":    batch-deduped union scan (paper §7.4 multi-query
    #                  policy applied per shard) via gather + one GEMM
    #  "union_pallas": union scan through the scalar-prefetch Pallas kernel
    #                  — each selected block streams HBM->VMEM exactly once
    scan_impl: str = "gather"
    union_cap: Optional[int] = None  # union size; None = B_loc * n_sel
                                     # (set lower under read skew — hot
                                     # partitions dedupe across the batch)
    storage_dtype: str = "f32"       # "bf16" halves scan traffic (beyond-
                                     # paper; distances accumulate in f32)
    rounds: Optional[int] = None     # search_batch early-exit round budget
                                     # (APS mode): None = as many geometric
                                     # rounds as the plan needs, 1 = one
                                     # monolithic fixed-plan scan


class ShardedQuakeEngine:
    """Compiled search over a sharded snapshot."""

    def __init__(self, mesh: Mesh, config: EngineConfig):
        self.mesh = mesh
        self.cfg = config
        axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        self.n_part_shards = int(np.prod([axis_sizes[a]
                                          for a in config.part_axes]))
        self.batch_axis = config.batch_axis if (
            config.batch_axis in mesh.axis_names) else None
        self.n_batch_shards = axis_sizes.get(self.batch_axis, 1) \
            if self.batch_axis else 1
        # journal-aware sharded snapshot cache (refresh_snapshot)
        self._snap: Optional[IndexSnapshot] = None
        self._snap_version = -1
        self._host_sizes: Optional[np.ndarray] = None  # (P,) host mirror
        self._planner_cache = None   # multiquery.PlannerCache (search_batch)
        self._planned_fns = {}   # n_union -> jitted planned-batch executor
        self.full_rebuilds = 0
        self.delta_refreshes = 0

    # ---- sharding specs ----
    def snapshot_spec(self) -> IndexSnapshot:
        pa = P(self.cfg.part_axes)
        return IndexSnapshot(
            data=pa, ids=pa, centroids=pa, sizes=pa, beta_table=P(),
            scales=pa if self.cfg.storage_dtype == "int8" else None)

    def shard_snapshot(self, snap: IndexSnapshot) -> IndexSnapshot:
        pa = NamedSharding(self.mesh, P(self.cfg.part_axes))
        rep = NamedSharding(self.mesh, P())
        data, scales = snap.data, None
        if self.cfg.storage_dtype == "bf16":
            data = data.astype(jnp.bfloat16)
        elif self.cfg.storage_dtype == "int8":
            # IVF residual SQ8 (paper §8.2): quantize x - c_j, the exact
            # query-centroid term is restored in-kernel
            from ..kernels.scan_topk_indexed import quantize_int8_residual
            data, scales = quantize_int8_residual(snap.data, snap.centroids)
            scales = jax.device_put(scales, pa)
        return IndexSnapshot(
            data=jax.device_put(data, pa),
            ids=jax.device_put(snap.ids, pa),
            centroids=jax.device_put(snap.centroids, pa),
            sizes=jax.device_put(snap.sizes, pa),
            beta_table=jax.device_put(snap.beta_table, rep),
            scales=scales)

    def refresh_snapshot(self, index: QuakeIndex) -> IndexSnapshot:
        """Cached device-sharded snapshot of the dynamic index, kept
        coherent through the index's mutation journal (the same
        invalidation protocol the batched executor uses).  Content deltas
        confined to known partitions patch only the dirty rows of the
        resident sharded arrays — no host re-densify, no full transfer;
        structural changes, int8 storage (rows would need requantizing),
        capacity overflow, or a trimmed journal re-shard a full rebuild.
        """
        if self._snap is not None and self.cfg.storage_dtype != "int8":
            delta = index.journal.delta_since(self._snap_version)
            if delta is not None and not delta.structural:
                lvl0 = index.levels[0]
                p_real = lvl0.num_partitions
                dirty = sorted(j for j in delta.dirty if j < p_real)
                if not dirty:
                    self._snap_version = index.version
                    return self._snap
                cap = self._snap.capacity
                max_frac = index.config.snapshot_max_dirty_frac
                if (len(dirty) <= max_frac * max(p_real, 1)
                        and p_real <= self._snap.num_partitions
                        and max(len(lvl0.vectors[j]) for j in dirty) <= cap):
                    try:
                        patch = IndexSnapshot.build_patch(index, dirty, cap)
                        # the engine owns its cached sharded snapshot:
                        # in-place row patch; handles returned from earlier
                        # refresh_snapshot calls are consumed
                        self._snap = self._snap.apply_delta(patch,
                                                            donate=True)
                    except ValueError:
                        pass
                    else:
                        self._host_sizes[patch.rows] = patch.sizes
                        self._snap_version = index.version
                        self.delta_refreshes += 1
                        return self._snap
        host = IndexSnapshot.from_index(
            index, pad_partitions_to=self.n_part_shards,
            headroom=index.config.snapshot_headroom)
        self._snap = self.shard_snapshot(host)
        self._host_sizes = np.array(host.sizes)
        self._snap_version = index.version
        self.full_rebuilds += 1
        return self._snap

    def pad_queries(self, q: Array) -> Array:
        b = q.shape[0]
        bs = self.n_batch_shards
        bp = ((b + bs - 1) // bs) * bs
        if bp != b:
            q = jnp.concatenate(
                [q, jnp.zeros((bp - b, q.shape[1]), q.dtype)])
        return q

    # ------------------------------------------------------------------
    # shard-local primitives
    # ------------------------------------------------------------------

    def _local_centroid_dists(self, q: Array, snap: IndexSnapshot) -> Array:
        """(B_loc, P_loc) centroid distances in minimization convention,
        masked on padding partitions."""
        if self.cfg.metric == "l2":
            d = pairwise_l2_sq(q, snap.centroids)
        else:
            d = -(q @ snap.centroids.T)
        return jnp.where(snap.sizes[None, :] > 0, d, MASK_DIST)

    def _scan_selected(self, q: Array, snap: IndexSnapshot,
                       sel: Array) -> Tuple[Array, Array]:
        """Scan ``sel`` (B_loc, n_sel) local partitions per query; returns
        (dists (B_loc, n_sel*S), ids) in minimization convention.

        This gather + batched-GEMV *is* the memory-bound hot loop: each
        selected partition block is streamed from HBM exactly once.
        """
        blocks = jnp.take(snap.data, sel, axis=0)       # (B, n, S, d)
        bids = jnp.take(snap.ids, sel, axis=0)          # (B, n, S)
        valid = bids >= 0
        blocks32 = blocks.astype(jnp.float32)
        if self.cfg.metric == "l2":
            x2 = jnp.sum(blocks32 * blocks32, axis=-1)
            qx = jnp.einsum("bnsd,bd->bns", blocks32, q,
                            preferred_element_type=jnp.float32)
            q2 = jnp.sum(q * q, axis=-1)[:, None, None]
            dist = x2 - 2.0 * qx + q2
        else:
            dist = -jnp.einsum("bnsd,bd->bns", blocks32, q,
                               preferred_element_type=jnp.float32)
        dist = jnp.where(valid, dist, MASK_DIST)
        b = dist.shape[0]
        return dist.reshape(b, -1), bids.reshape(b, -1)

    def _scan_packed(self, q: Array, snap: IndexSnapshot, selected: Array,
                     k: int, n_union: int,
                     priority: Optional[Array] = None
                     ) -> Tuple[Array, Array]:
        """Packed union scan of a dense ``selected`` (B, P_loc) bool probe
        matrix: ``pack_union`` (frequency-ranked with an optional anchor
        ``priority``, so ``n_union`` truncation keeps the partitions most
        queries probe and never a query's nearest) + one packed top-k scan
        in the engine's storage dtype.  Returns (dists (B, k), external
        ids (B, k)) ascending.
        """
        from ..kernels import ops as kops
        cfg = self.cfg
        sel_u, qmask = kops.pack_union(selected, n_union,
                                       priority=priority)  # (U,), (B, U)
        valid = snap.ids >= 0                            # (P_loc, S)
        if snap.scales is not None:                      # int8 residuals
            d, flat = kops.scan_selected_topk_q8(
                q, snap.data, snap.scales, valid, sel_u, qmask, k,
                metric=cfg.metric, centroids=snap.centroids)
        else:
            impl = "pallas" if cfg.scan_impl == "union_pallas" else "jnp"
            d, flat = kops.scan_selected_topk(
                q, snap.data, valid, sel_u, qmask, k, metric=cfg.metric,
                impl=impl)
        ids_flat = snap.ids.reshape(-1)
        ext = jnp.where(flat >= 0,
                        jnp.take(ids_flat, jnp.maximum(flat, 0)), -1)
        return d, ext.astype(jnp.int32)

    def _scan_union_topk(self, q: Array, snap: IndexSnapshot, sel: Array,
                         k: int) -> Tuple[Array, Array]:
        """Union-deduped scan of per-query selections ``sel`` (B, n):
        the batch's selected partitions are packed into one static union and
        each block is scanned once for the whole batch (paper §7.4 policy),
        preserving per-query probe semantics via a selection mask.

        Returns (dists (B, k), external ids (B, k)) ascending.
        """
        cfg = self.cfg
        b, n_sel = sel.shape
        p_loc = snap.num_partitions
        n_union = min(cfg.union_cap or b * n_sel, p_loc)
        selected = jnp.zeros((b, p_loc), jnp.bool_).at[
            jnp.arange(b)[:, None], sel].set(True)
        # sel arrives best-first (top_k order): column 0 is each query's
        # nearest local partition — anchor it above the frequency ranking
        anchor = jnp.zeros((p_loc,), jnp.bool_).at[sel[:, 0]].set(True)
        return self._scan_packed(q, snap, selected, k, n_union,
                                 priority=anchor.astype(jnp.int32) * (b + 1))

    def _merge_global(self, d_loc: Array, i_loc: Array, k: int
                      ) -> Tuple[Array, Array]:
        """Hierarchical top-k merge across the partition shards (the
        coordinator-thread analogue): all_gather local candidates, re-select.
        Collective volume: B * n_shards * k * 8 bytes — negligible next to
        the scan traffic."""
        axes = self.cfg.part_axes
        dg = jax.lax.all_gather(d_loc, axes, axis=1, tiled=True)
        ig = jax.lax.all_gather(i_loc, axes, axis=1, tiled=True)
        vals, sel = jax.lax.top_k(-dg, k)
        return -vals, jnp.take_along_axis(ig, sel, axis=1)

    # ------------------------------------------------------------------
    # fixed-nprobe search (static baseline)
    # ------------------------------------------------------------------

    def _search_fixed_local(self, q: Array, snap: IndexSnapshot
                            ) -> Tuple[Array, Array]:
        cfg = self.cfg
        # per-shard probe share, ceil so the union covers >= nprobe
        n_loc = max(1, -(-cfg.nprobe // self.n_part_shards))
        n_loc = min(n_loc, snap.num_partitions)
        cd = self._local_centroid_dists(q, snap)
        _, sel = jax.lax.top_k(-cd, n_loc)              # (B, n_loc)
        if cfg.scan_impl != "gather":
            d_loc, i_loc = self._scan_union_topk(q, snap, sel, cfg.k)
            return self._merge_global(d_loc, i_loc, cfg.k)
        d, i = self._scan_selected(q, snap, sel)
        k = min(cfg.k, d.shape[1])
        vals, pos = jax.lax.top_k(-d, k)
        d_loc, i_loc = -vals, jnp.take_along_axis(i, pos, axis=1)
        if k < cfg.k:
            pad_d = jnp.full((d.shape[0], cfg.k - k), MASK_DIST)
            pad_i = jnp.full((d.shape[0], cfg.k - k), -1, i_loc.dtype)
            d_loc = jnp.concatenate([d_loc, pad_d], axis=1)
            i_loc = jnp.concatenate([i_loc, pad_i], axis=1)
        return self._merge_global(d_loc, i_loc, cfg.k)

    # ------------------------------------------------------------------
    # adaptive search (APS rounds; Algorithm 2)
    # ------------------------------------------------------------------

    def _search_adaptive_local(self, q: Array, snap: IndexSnapshot
                               ) -> Tuple[Array, Array, Array, Array]:
        cfg = self.cfg
        b = q.shape[0]
        p_loc = snap.num_partitions
        chunk = min(cfg.chunk, p_loc)
        axes = cfg.part_axes

        cd = self._local_centroid_dists(q, snap)         # (B, P_loc)
        # global nearest centroid distance (for c0 and margins)
        d0 = jax.lax.pmin(jnp.min(cd, axis=1), axes)     # (B,)
        # ||ci - c0||: c0 gathered via a global argmin — emulate with a
        # masked select + psum broadcast of the winning centroid.
        is_min = (cd <= d0[:, None]).astype(q.dtype)
        # tie-break: normalize so exactly weight-1 total across all shards
        w = is_min / jnp.maximum(jax.lax.psum(
            jnp.sum(is_min, axis=1), axes), 1.0)[:, None]
        c0 = jax.lax.psum(w @ snap.centroids, axes)      # (B, d)
        cc = jnp.sqrt(jnp.maximum(pairwise_l2_sq(c0, snap.centroids), 1e-12))

        def probs(rho_sq: Array, scanned: Array) -> Tuple[Array, Array]:
            """Global recall estimate r per query (Eqs. 7-9 across shards)."""
            rho = jnp.sqrt(jnp.maximum(rho_sq, 1e-30))[:, None]
            h = (cd - d0[:, None]) / (2.0 * jnp.maximum(cc, 1e-12))
            v = geometry.cap_fraction(h / rho, snap.beta_table)
            cand = (snap.sizes[None, :] > 0) & (cd > d0[:, None])
            v = jnp.where(cand, v, 0.0)
            tot = jax.lax.psum(jnp.sum(v, axis=1), axes)[:, None]
            vn = jnp.where(tot > 0, v / jnp.maximum(tot, 1e-20), 0.0)
            log1m = jnp.where(cand, jnp.log1p(-jnp.clip(vn, 0, 1 - 1e-7)),
                              0.0)
            p0 = jnp.exp(jax.lax.psum(jnp.sum(log1m, axis=1), axes))
            p0 = jnp.where(tot[:, 0] > 0, p0, 1.0)
            p = (1.0 - p0[:, None]) * vn
            r = p0 + jax.lax.psum(
                jnp.sum(jnp.where(scanned, p, 0.0), axis=1), axes)
            return r, p

        def rho_from_topk(td: Array) -> Array:
            kth = td[:, -1]
            if cfg.metric == "l2":
                return jnp.maximum(kth, 0.0)
            # MIPS: rho^2 in augmented space; snapshot data pre-normalized
            # geometry uses max-norm from centroid table (approximation)
            q2 = jnp.sum(q * q, axis=-1)
            m2 = jnp.max(jnp.sum(snap.centroids ** 2, axis=-1))
            m2 = jax.lax.pmax(m2, axes)
            return jnp.maximum(q2 + m2 + 2.0 * kth, 0.0)

        def body(state):
            rnd, scanned, td, ti, r = state
            # next chunk of unscanned local partitions by probability order
            # (centroid-distance order is probability order for fixed rho)
            masked = jnp.where(scanned, MASK_DIST, cd)
            _, sel = jax.lax.top_k(-masked, chunk)       # (B, chunk)
            newly = jax.nn.one_hot(sel, p_loc, dtype=jnp.bool_).any(axis=1)
            scanned2 = scanned | newly
            if cfg.scan_impl != "gather":
                d, i = self._scan_union_topk(q, snap, sel, cfg.k)
            else:
                d, i = self._scan_selected(q, snap, sel)
            td2, ti2 = merge_topk(td, ti, d, i, cfg.k)
            tdg, _ = self._merge_global(td2, ti2, cfg.k)
            r2, _ = probs(rho_from_topk(tdg), scanned2)
            return rnd + 1, scanned2, td2, ti2, r2

        def cond(state):
            rnd, scanned, td, ti, r = state
            unscanned = jax.lax.psum(
                jnp.sum(~scanned, axis=1), axes)         # (B,)
            active = (r < cfg.recall_target) & (unscanned > 0)
            return (rnd < cfg.max_rounds) & jnp.any(active)

        init = (jnp.zeros((), jnp.int32),
                jnp.zeros((b, p_loc), jnp.bool_),
                jnp.full((b, cfg.k), MASK_DIST, jnp.float32),
                jnp.full((b, cfg.k), -1, jnp.int32),
                jnp.zeros((b,), jnp.float32))
        state = body(init)  # round 1 always scans (initializes rho)
        rnd, scanned, td, ti, r = jax.lax.while_loop(cond, body, state)
        dg, ig = self._merge_global(td, ti, cfg.k)
        nprobe = jax.lax.psum(jnp.sum(scanned, axis=1), axes)
        return dg, ig, r, nprobe

    # ------------------------------------------------------------------
    # brute force (exact; multi-query policy / ground truth / retrieval)
    # ------------------------------------------------------------------

    def _search_brute_local(self, q: Array, snap: IndexSnapshot
                            ) -> Tuple[Array, Array]:
        cfg = self.cfg
        p_loc, s_cap, d = snap.data.shape
        flat = snap.data.reshape(p_loc * s_cap, d)
        fids = snap.ids.reshape(p_loc * s_cap)
        if cfg.metric == "l2":
            dist = pairwise_l2_sq(q, flat)
        else:
            dist = -(q @ flat.T)
        dist = jnp.where(fids[None, :] >= 0, dist, MASK_DIST)
        k = min(cfg.k, dist.shape[1])
        vals, pos = jax.lax.top_k(-dist, k)
        return self._merge_global(-vals, fids[pos], cfg.k)

    # ------------------------------------------------------------------
    # public jitted entry points
    # ------------------------------------------------------------------

    def query_spec(self) -> P:
        return P(self.batch_axis) if self.batch_axis else P()

    def mapped_fn(self, kind: str):
        """The shard_map'd (unjitted) search callable — used directly by the
        dry-run lowering and wrapped by the jitted properties below."""
        fn, n_out = {"fixed": (self._search_fixed_local, 2),
                     "adaptive": (self._search_adaptive_local, 4),
                     "brute": (self._search_brute_local, 2)}[kind]
        qspec = self.query_spec()
        out_specs = tuple([qspec] * n_out)
        return shard_map(
            fn, mesh=self.mesh,
            in_specs=(qspec, self.snapshot_spec()),
            out_specs=out_specs if n_out > 1 else qspec,
            check_vma=False)

    @functools.cached_property
    def search_fixed(self):
        return jax.jit(self.mapped_fn("fixed"))

    @functools.cached_property
    def search_adaptive(self):
        return jax.jit(self.mapped_fn("adaptive"))

    @functools.cached_property
    def search_bruteforce(self):
        return jax.jit(self.mapped_fn("brute"))

    # ------------------------------------------------------------------
    # planner-driven multi-query entry (shares core.multiquery.plan_batch)
    # ------------------------------------------------------------------

    def _search_planned_local(self, q: Array, snap: IndexSnapshot,
                              selected: Array, anchor: Array, *,
                              n_union: int) -> Tuple[Array, Array]:
        prio = anchor.astype(jnp.int32) * (selected.shape[0] + 1)
        d_loc, i_loc = self._scan_packed(q, snap, selected, self.cfg.k,
                                         n_union, priority=prio)
        return self._merge_global(d_loc, i_loc, self.cfg.k)

    def _planned_fn(self, n_union: int):
        """Jitted SPMD executor for a planned batch: the (B, P) probe
        matrix is sharded with the snapshot (batch axis x partition axes),
        each device packs its local slice of the union (``pack_union``)
        and scans it once, and the per-round hierarchical merge combines
        shard-local top-k.  One compile per bucketed local-union size,
        cached per engine instance (a class-level lru_cache would pin
        engines and their compiled closures for the process lifetime)."""
        cached = self._planned_fns.get(n_union)
        if cached is not None:
            return cached
        qspec = self.query_spec()
        sel_spec = P(self.batch_axis, self.cfg.part_axes) \
            if self.batch_axis else P(None, self.cfg.part_axes)
        fn = functools.partial(self._search_planned_local, n_union=n_union)
        jitted = jax.jit(shard_map(
            fn, mesh=self.mesh,
            in_specs=(qspec, self.snapshot_spec(), sel_spec,
                      P(self.cfg.part_axes)),
            out_specs=(qspec, qspec), check_vma=False))
        self._planned_fns[n_union] = jitted
        return jitted

    def search_batch(self, index: QuakeIndex, queries: np.ndarray,
                     k: Optional[int] = None,
                     nprobe: Optional[int] = None,
                     recall_target: Optional[float] = None,
                     union_cap: Optional[int] = None,
                     rounds: Optional[int] = None):
        """Multi-query search over the sharded snapshot through the *same*
        host batch planner as the device-resident executor
        (``core.multiquery.plan_batch``): per-query probe sets (vectorized
        APS when ``nprobe`` is None) are planned once against the dynamic
        index, then scattered into a dense (B, P) probe matrix whose
        partition axis is sharded with the snapshot — each device packs
        and scans only its local slice of the batch union.  APS-planned
        searches run through the *same* multi-round early-exit loop as
        the host executor (``multiquery.run_round_loop``): per round only
        live queries' rows of the probe matrix are populated, so every
        shard's local pack sees the per-shard slice of the live mask and
        later rounds shrink with the hard tail (``rounds=1``, pinned
        ``nprobe``, or a ``union_cap`` — whose truncation is defined on
        the whole-batch plan — fall back to the one-shot scan).  Returns
        ``multiquery.BatchResult`` (top-``min(k, cfg.k)`` columns).
        """
        from .multiquery import (BatchResult, PlannerCache,  # avoid cycle
                                 plan_batch)
        cfg = self.cfg
        k = cfg.k if k is None else min(k, cfg.k)
        q = np.ascontiguousarray(queries, dtype=np.float32)
        if q.ndim == 1:
            q = q[None, :]
        b = q.shape[0]
        if b == 0:
            return BatchResult(ids=np.zeros((0, k), dtype=np.int64),
                               dists=np.zeros((0, k), dtype=np.float64),
                               nprobe=np.zeros(0, dtype=np.int64))
        snap = self.refresh_snapshot(index)
        # planner state (centroid norms, calibrated radii) rides the same
        # fingerprint protocol as the host executor's caches
        if self._planner_cache is None or \
                self._planner_cache.index is not index:
            self._planner_cache = PlannerCache(index)
        pc = self._planner_cache.ensure_fresh()
        cap = union_cap if union_cap is not None else cfg.union_cap
        rounds = cfg.rounds if rounds is None else rounds
        if rounds is not None and rounds < 1:
            raise ValueError(f"rounds must be >= 1 or None, got {rounds}")
        if nprobe is None and rounds != 1 and cap is None:
            target = recall_target if recall_target is not None \
                else index.config.recall_target
            return self._search_batch_rounds(index, q, k, target, rounds,
                                             snap, pc)
        # cfg.union_cap caps the *plan* (like the host executor), so the
        # returned stats and effective nprobe reflect what was scanned
        plan = plan_batch(index, q, k, nprobe=nprobe,
                          recall_target=recall_target,
                          union_cap=cap,
                          cent_norms=pc._cent_norms, cache=pc)
        qp = self.pad_queries(jnp.asarray(q))
        p_pad = snap.num_partitions
        # the plan's packed union defines the cap semantics + stats; each
        # shard re-packs its local slice of it below (different work: the
        # local union is what the shard's scan grid iterates)
        sel_cols = plan.sel[:plan.n_real]
        selected = np.zeros((qp.shape[0], p_pad), dtype=bool)
        selected[np.ix_(np.arange(b), sel_cols)] = \
            plan.qmask[:, :plan.n_real]
        # static per-shard union size: the largest local share of the
        # batch union, bucketed so recompiles stay rare
        p_loc = p_pad // self.n_part_shards
        u_loc = int(np.bincount(sel_cols // p_loc,
                                minlength=self.n_part_shards).max())
        u_loc = min(max(-(-max(u_loc, 1) // 8) * 8, 1), p_loc)
        anchor = np.zeros(p_pad, dtype=bool)
        anchor[plan.anchor] = True
        d, ids = self._planned_fn(u_loc)(qp, snap, jnp.asarray(selected),
                                         jnp.asarray(anchor))
        d = np.asarray(d, dtype=np.float64)[:b, :k]
        ids = np.asarray(ids)[:b, :k]
        d = np.where(d >= MASK_DIST, np.inf, d)
        ids = np.where(np.isinf(d), -1, ids)
        sizes = self._host_sizes[sel_cols]   # snapshot-refreshed mirror,
                                             # not an O(P) host walk
        return BatchResult(
            ids=ids.astype(np.int64), dists=d,
            partitions_scanned=int(plan.n_real),
            vectors_scanned=int(sizes.sum()),
            comparisons=int((plan.qmask[:, :plan.n_real].astype(np.int64)
                             * sizes[None, :]).sum()),
            nprobe=plan.nprobe, recall_estimate=plan.recall_est)

    def _search_batch_rounds(self, index: QuakeIndex, q: np.ndarray,
                             k: int, target: float,
                             rounds: Optional[int], snap: IndexSnapshot,
                             pc):
        """The engine side of the shared Algorithm-2 round loop: each
        round scatters only live queries' next probe-sequence window into
        the sharded (B, P) probe matrix and reuses the jitted planned-
        batch executor (per-shard ``pack_union`` + packed scan + global
        merge); the shared driver owns the running top-k, the refined
        recall estimate, and the live mask."""
        from .multiquery import (BatchResult, _batch_rho_fn,  # avoid cycle
                                 plan_rounds, run_round_loop)
        b = q.shape[0]
        rplan = plan_rounds(index, q, k, target, cache=pc,
                            cent_norms=pc._cent_norms)
        qp = self.pad_queries(jnp.asarray(q))
        bp = qp.shape[0]
        p_pad = snap.num_partitions
        p_loc = p_pad // self.n_part_shards

        rr = np.broadcast_to(np.arange(b)[:, None], rplan.seq.shape)

        def scan_round(take, kept):
            selected = np.zeros((bp, p_pad), dtype=bool)
            selected[rr[take], rplan.seq[take]] = True
            # static per-shard union size: largest local share, bucketed
            u_loc = int(np.bincount(kept // p_loc,
                                    minlength=self.n_part_shards).max())
            u_loc = min(max(-(-max(u_loc, 1) // 8) * 8, 1), p_loc)
            anchor = np.zeros(p_pad, dtype=bool)   # uncapped: no priority
            d, ids = self._planned_fn(u_loc)(qp, snap,
                                             jnp.asarray(selected),
                                             jnp.asarray(anchor))
            sizes = self._host_sizes[kept]
            st = {"partitions": int(len(kept)),
                  "vectors": int(sizes.sum()),
                  "comparisons": int(
                      self._host_sizes[rplan.seq[take]].sum())}
            return d[:b], ids[:b], st

        td, ti, nprobe, r_est, n_rounds, trace, stats = run_round_loop(
            rplan, k, target, index._beta_table, _batch_rho_fn(index, q),
            scan_round, rounds=rounds, k_keep=self.cfg.k)
        dd = np.asarray(td, dtype=np.float64)[:, :k]
        ids = np.asarray(ti)[:, :k]
        dd = np.where(dd >= MASK_DIST, np.inf, dd)
        ids = np.where(np.isinf(dd), -1, ids)
        return BatchResult(
            ids=ids.astype(np.int64), dists=dd,
            partitions_scanned=stats["partitions"],
            vectors_scanned=stats["vectors"],
            comparisons=stats["comparisons"],
            nprobe=nprobe, recall_estimate=r_est,
            rounds=n_rounds, round_trace=trace)
