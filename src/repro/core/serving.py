"""Online serving runtime: micro-batching queue, cross-batch union riding,
query-aware result caching, drift-triggered maintenance (paper §3's
continuously running serving loop, made a first-class subsystem).

The paper's headline numbers come from an *online* system that interleaves
skewed queries, updates and cost-model maintenance.  The pieces below turn
the batched executor (``core/multiquery.py``) into that system:

  * **Micro-batching queue** — single queries and query batches are
    admitted into a bounded queue and coalesced into executor batches
    (size- or deadline-triggered flush, explicit ``flush``/``drain`` for
    replay drivers).  Coalescing only changes *when* work runs,
    never what a query scans: plans are per-query and the calibrated APS
    radius is pinned per snapshot fingerprint by a deterministic
    resident-sample calibration (``calibrate_radius_resident``), so the
    same operation stream yields the same results under any flush timing
    — top-k id sets exactly, distances to scan-arithmetic (f32)
    rounding (the coalescing-determinism contract; ``docs/serving.md``).
  * **Cross-batch union riding** — the :class:`RoundScheduler`
    generalizes ``run_round_loop``'s live-mask/union machinery to a
    *changing* query population: queries admitted while earlier batches
    are mid-rounds join the next round, and every round's partition
    union is shared across all in-flight batches — when a newcomer's
    planned probes overlap partitions an in-flight plan is about to
    stream, the partition block streams once and serves both.  Within
    one co-admitted group a partition streams at most once (the same
    guarantee ``run_round_loop`` gives one batch), and the streamed
    footprint never exceeds the union of the per-batch fixed plans (the
    riding-footprint invariant, asserted in ``tests/test_serving.py``).
  * **Query-aware result cache** — :class:`ResultCache` keys normalized
    queries by sign-LSH code (or exact bytes), verifies hits against the
    stored exemplar within a tolerance, and invalidates per partition
    from the index's mutation journal: an entry remembers its planned
    probe footprint, and any journal delta dirtying one of those
    partitions (or any structural change) drops it — the QVCache policy
    on top of the PR 2 invalidation protocol.
  * **Drift-triggered maintenance** — :class:`MaintenanceScheduler`
    replaces run-after-every-op with triggers: journal dirty mass,
    cost-model drift, and access-histogram shift over the served-batch
    access frequencies the scheduler feeds back into
    ``PartitionStats`` (Stage 0) — the batched scan path otherwise
    bypasses the statistics the cost model plans with.

``ServingRuntime`` composes the four and is what ``launch/serve.py`` and
``benchmarks/bench_serving.py`` drive.
"""
from __future__ import annotations

import logging
import threading
import time
from collections import OrderedDict
from dataclasses import dataclass, field, replace
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from ..faults import FaultInjector
from ..kernels.ref import MASK_DIST
from ..obs import Observability
from ..sanitize import TrackedLock, note_guarded, observability_counters
from . import aps as aps_mod
from . import multiquery as mq
from .cost_model import LatencyModel
from .durability import DurabilityManager, RecoveryReport, recover_index
from .index import QuakeIndex
from .maintenance import (Maintainer, MaintenanceReport, checkpoint_index,
                          restore_index)

__all__ = ["ServingConfig", "ServingRuntime", "QueryResult", "ResultCache",
           "MaintenanceScheduler", "MaintenanceTriggers", "RoundScheduler",
           "calibrate_radius_resident", "STATUS_OK", "STATUS_PARTIAL",
           "STATUS_SHED", "STATUS_FAILED", "TERMINAL_STATUSES"]

logger = logging.getLogger("repro.serving")

# Terminal query statuses (docs/serving.md failure semantics): every
# admitted query reaches exactly one of these — no query ever vanishes.
STATUS_OK = "OK"            # full planned search completed
STATUS_PARTIAL = "PARTIAL"  # latency budget expired; running top-k returned
STATUS_SHED = "SHED"        # dropped by admission control, never searched
STATUS_FAILED = "FAILED"    # scan backend failed after retries
TERMINAL_STATUSES = (STATUS_OK, STATUS_PARTIAL, STATUS_SHED, STATUS_FAILED)


@dataclass
class ServingConfig:
    """Knobs for one :class:`ServingRuntime`.

    Deadline precedence: ``flush_deadline_ms`` (milliseconds) **wins**
    over ``flush_deadline`` (seconds) whenever both are set —
    ``__post_init__`` folds the milliseconds knob into
    ``flush_deadline``, so runtime code only ever reads the seconds
    field.  Both are validated at construction: a zero or negative
    deadline is a configuration error (it would make every admission
    flush immediately, silently disabling micro-batching), not a
    "flush never" sentinel — that sentinel is ``None``.
    """
    k: int = 10
    recall_target: Optional[float] = None  # None -> index.config.recall_target
    rounds: Optional[int] = None       # per-query probe-round budget
                                       # (None = as many geometric rounds
                                       # as the plan needs)
    early_exit: bool = False           # retire queries whose refined APS
                                       # estimate clears the target before
                                       # their plan is exhausted.  Scans
                                       # less, but exit points depend on
                                       # what rode alongside — trades the
                                       # strict coalescing-determinism
                                       # contract for footprint savings.
    flush_size: int = 64               # queued queries that force a flush
    flush_deadline: Optional[float] = None  # seconds the oldest queued
                                       # query may wait before an
                                       # admission (or the background
                                       # ticker) forces a flush (None =
                                       # size-triggered / explicit only)
    flush_deadline_ms: Optional[float] = None  # same knob in ms; wins
                                       # over flush_deadline when set
    ticker: bool = True                # run the background deadline
                                       # ticker thread when a deadline
                                       # is configured (off for
                                       # fake-clock tests, which call
                                       # tick() themselves)
    record_admissions: bool = False    # keep a totally ordered admission
                                       # log (engine-lock order) for
                                       # single-threaded replay of a
                                       # concurrent run
    interleave_rounds: int = 1         # scheduler rounds run per flush (the
                                       # in-flight window newcomers ride)
    b_bucket: int = 16                 # active-row padding bucket (bounds
                                       # distinct jitted scan shapes)
    storage_dtype: str = "f32"         # executor snapshot format
    impl: str = "auto"                 # scan kernel implementation
    planner: str = "vectorized"        # APS batch planner variant
    scan_backend: str = "auto"         # "device": packed snapshot scans
                                       # (scan_probe_round — the TPU
                                       # path); "host": per-partition
                                       # GEMMs over the index's ragged
                                       # buffers (the CPU fast path —
                                       # write barriers freeze the index
                                       # within an epoch, so the live
                                       # buffers are snapshot-coherent);
                                       # "auto" picks host off-TPU
    # --- result cache (0 entries disables) ---
    cache_entries: int = 0
    cache_bits: int = 0                # sign-LSH key bits; 0 = exact bytes
    cache_tol: float = 0.0             # exemplar L2 tolerance.  0 = exact
                                       # query match only (preserves the
                                       # coalescing-determinism contract:
                                       # an identical repeat always maps
                                       # to the same result).  > 0 serves
                                       # *near*-duplicates the exemplar's
                                       # top-k — whether the exemplar
                                       # completed before the repeat
                                       # arrived depends on flush timing,
                                       # so approximate caching, like
                                       # early_exit, trades the strict
                                       # determinism contract away
    cache_seed: int = 0
    record_stats: bool = True          # feed served access frequencies
                                       # into PartitionStats (off for
                                       # warm-up / shadow runtimes)
    # --- maintenance triggers ---
    maint_min_ops: int = 4
    maint_dirty_frac: float = 0.25
    maint_cost_drift: float = 0.15
    maint_access_shift: float = 0.6
    maint_max_ops: Optional[int] = 64
    # --- durability (core/durability.py, docs/durability.md) ---
    wal_dir: Optional[str] = None      # WAL + checkpoint directory; None
                                       # disables durability (everything
                                       # stays memory-resident)
    fsync: str = "batch"               # WAL fsync policy: "always" (per
                                       # append), "batch" (every
                                       # wal_batch_ops appends), "off"
                                       # (flush to OS only — a crash may
                                       # lose the whole unsynced tail)
    wal_batch_ops: int = 32            # fsync cadence under "batch"
    ckpt_every_ops: Optional[int] = 256  # checkpoint every N logged write
                                       # ops (None = only the attach
                                       # baseline and forced /
                                       # post-maintenance checkpoints)
    keep_checkpoints: int = 2          # generations retained after prune
    # --- per-query latency budgets (docs/serving.md failure semantics) ---
    deadline_s: Optional[float] = None  # default per-query budget; a query
                                       # whose budget expires retires at
                                       # the end of the current round with
                                       # its running top-k, status PARTIAL
                                       # (submit_query's deadline_s arg
                                       # overrides per query; None = no
                                       # budget)
    # --- admission control / load shedding ---
    queue_cap: Optional[int] = None    # max queued (not yet admitted)
                                       # queries; None = unbounded
    queue_policy: str = "block"        # on a full queue: "block" (the
                                       # submitter pays for a flush, then
                                       # retries — backpressure),
                                       # "shed-oldest" (evict the oldest
                                       # queued query with an immediate
                                       # SHED result, admit the newcomer),
                                       # "shed-newest" (SHED the newcomer)
    # --- degradation governor ---
    govern: bool = False               # under sustained queue pressure,
                                       # step the effective recall target
                                       # down / tighten per-query probe
                                       # budgets; restore on recovery
    govern_high: float = 0.75          # flush-batch fill fraction of
                                       # queue_cap that counts as pressure
    govern_low: float = 0.25           # fill fraction that counts as calm
    govern_patience: int = 2           # consecutive pressured (calm)
                                       # flushes before a degrade
                                       # (restore) step
    govern_step: float = 0.05          # recall-target reduction per step
    govern_max_steps: int = 4
    govern_min_target: float = 0.5     # floor for the effective target
    govern_probe_frac: float = 0.7     # per-step multiplicative cap on
                                       # per-query probe budgets (the
                                       # serving-layer union_cap analog:
                                       # plans are truncated to this
                                       # fraction of their probe count)
    # --- scan-fault retry (capped exponential backoff) ---
    scan_retries: int = 2              # retries per failed round scan
                                       # before the in-flight batch fails
    scan_backoff_s: float = 0.001      # first-retry backoff; doubles per
                                       # attempt ...
    scan_backoff_max_s: float = 0.05   # ... up to this cap
    # --- observability (repro.obs, docs/observability.md) ---
    metrics: bool = True               # wire the Observability bundle
                                       # (metrics registry + per-query
                                       # trace spans + calibration
                                       # tracker) into the runtime.  Off:
                                       # every hook is a None check —
                                       # results are byte-identical either
                                       # way (a test asserts it)
    trace_capacity: int = 1024         # completed trace spans retained in
                                       # the tracer's ring buffer
    calibration_window: int = 256      # rolling window (samples) for the
                                       # predicted-vs-observed calibration
                                       # error gauges

    def __post_init__(self) -> None:
        if self.flush_deadline is not None and self.flush_deadline <= 0:
            raise ValueError(
                f"flush_deadline must be positive (got "
                f"{self.flush_deadline}); use None for size-triggered/"
                f"explicit flushes only")
        if self.flush_deadline_ms is not None:
            if self.flush_deadline_ms <= 0:
                raise ValueError(
                    f"flush_deadline_ms must be positive (got "
                    f"{self.flush_deadline_ms}); use None for "
                    f"size-triggered/explicit flushes only")
            self.flush_deadline = self.flush_deadline_ms / 1000.0
        if self.deadline_s is not None and self.deadline_s <= 0:
            raise ValueError(f"deadline_s must be positive "
                             f"(got {self.deadline_s})")
        if self.queue_cap is not None and self.queue_cap < 1:
            raise ValueError(f"queue_cap must be >= 1 "
                             f"(got {self.queue_cap})")
        if self.queue_policy not in ("block", "shed-oldest", "shed-newest"):
            raise ValueError(f"queue_policy must be block/shed-oldest/"
                             f"shed-newest, got {self.queue_policy!r}")
        if not 0.0 < self.govern_low <= self.govern_high <= 1.0:
            raise ValueError(
                f"governor thresholds need 0 < govern_low <= govern_high "
                f"<= 1 (got {self.govern_low}, {self.govern_high})")
        if self.govern_patience < 1 or self.govern_max_steps < 1:
            raise ValueError("govern_patience and govern_max_steps "
                             "must be >= 1")
        if not 0.0 < self.govern_probe_frac <= 1.0:
            raise ValueError(f"govern_probe_frac must be in (0, 1] "
                             f"(got {self.govern_probe_frac})")
        if self.scan_retries < 0 or self.scan_backoff_s < 0 \
                or self.scan_backoff_max_s < 0:
            raise ValueError("scan retry/backoff knobs must be "
                             "non-negative")
        if self.fsync not in ("always", "batch", "off"):
            raise ValueError(f"fsync must be always/batch/off, "
                             f"got {self.fsync!r}")
        if self.wal_batch_ops < 1:
            raise ValueError(f"wal_batch_ops must be >= 1 "
                             f"(got {self.wal_batch_ops})")
        if self.ckpt_every_ops is not None and self.ckpt_every_ops < 1:
            raise ValueError(f"ckpt_every_ops must be >= 1 or None "
                             f"(got {self.ckpt_every_ops})")
        if self.keep_checkpoints < 1:
            raise ValueError(f"keep_checkpoints must be >= 1 "
                             f"(got {self.keep_checkpoints})")
        if self.trace_capacity < 1:
            raise ValueError(f"trace_capacity must be >= 1 "
                             f"(got {self.trace_capacity})")
        if self.calibration_window < 1:
            raise ValueError(f"calibration_window must be >= 1 "
                             f"(got {self.calibration_window})")


@dataclass
class QueryResult:
    """Per-query serving outcome (the single-row mirror of
    ``multiquery.BatchResult``).

    ``status`` is terminal: ``OK`` (full planned search), ``PARTIAL``
    (latency budget expired — ``ids``/``dists`` are the running top-k at
    the end of the last round and ``recall_estimate`` is the round
    loop's refined APS estimate over what was actually scanned, 0.0
    when the top-k never filled), ``SHED`` (dropped by admission
    control, never searched) or ``FAILED`` (scan backend failed after
    retries; ``error`` carries the cause).  Every admitted query gets
    exactly one — docs/serving.md, failure semantics."""
    ids: np.ndarray                 # (k,) external ids, -1 on misses
    dists: np.ndarray               # (k,) minimization convention
    nprobe: int = 0                 # partitions this query consumed
    recall_estimate: float = np.nan
    rounds: int = 0                 # scan rounds the query took cells in
    from_cache: bool = False
    latency_s: float = 0.0          # submit -> result wall time
    status: str = STATUS_OK         # terminal status (see above)
    error: str = ""                 # failure cause (FAILED only)
    t_submit: float = 0.0           # admission clock value (trace spans)
    batch: int = -1                 # coalesced admission group, -1 if
                                    # the query never reached the
                                    # scheduler (cache hit / shed)


def calibrate_radius_resident(index: QuakeIndex, k: int,
                              n_sample: int = 8) -> float:
    """Deterministic, query-independent APS radius calibration: sample
    resident vectors (first row of up to ``n_sample`` evenly spaced
    non-empty partitions) as pseudo-queries and run the batched
    calibration search.  Unlike the planner's default batch-sample
    calibration, the result depends only on index state — so per-query
    plans (and therefore served results) are invariant under how the
    serving queue happened to coalesce the batch that triggered the
    calibration."""
    lvl0 = index.levels[0]
    sizes = lvl0.sizes()
    nz = np.nonzero(sizes)[0]
    if len(nz) == 0:
        return np.inf
    pick = nz[np.unique(np.linspace(0, len(nz) - 1,
                                    min(n_sample, len(nz))).astype(int))]
    qs = np.stack([lvl0.vectors[int(j)][0] for j in pick]).astype(np.float32)
    # resident vectors match themselves at distance 0 (rank 1), which
    # would bias the k-th distance low and make the planner underprobe —
    # calibrate past rank k+1 (the unbiased k-th for a query *near* but
    # not identical to a stored vector), with extra slack ranks: a
    # modestly inflated radius only makes the planner scan more, never
    # less, which is the recall-safe side of the approximation
    return mq._calibrate_kth_batched(index, qs, k + 1 + max(1, k // 2),
                                     mq._aps_candidate_budget(index))


# ---------------------------------------------------------------------------
# Query-aware result cache (QVCache-style, journal-invalidated)
# ---------------------------------------------------------------------------

class ResultCache:
    """LRU top-k result cache keyed by normalized-query code.

    ``bits > 0`` keys queries by the sign pattern of ``bits`` fixed random
    projections (nearby queries collide, so Zipf-popular queries with
    per-request jitter still hit); ``bits == 0`` keys by exact query
    bytes.  A key collision alone never serves a result: the hit must
    also be within ``tol`` L2 distance of the stored exemplar query
    (``tol == 0`` = identical queries only), and the served result is
    the exemplar's — approximate for ``tol > 0`` in exactly the way ANN
    serving already is.

    Every entry remembers the **planned probe footprint** of the search
    that produced it.  Invalidation is driven by the index's mutation
    journal: ``invalidate_partitions(dirty)`` drops every entry whose
    footprint intersects the dirty set (content changes outside an
    entry's footprint cannot change what that entry's plan would have
    scanned — inserts and deletes move no centroids, so the probe set
    over an unchanged directory is unchanged), and any structural delta
    clears the cache (partition ids are re-assigned by split/merge
    swap-remove, so footprints stop meaning anything).

    Thread safety: every public method takes ``_lock``
    (``ResultCache._lock`` in the declared ``LOCK_ORDER``).  Because a
    search runs *outside* any cache lock, a ``put`` can race an
    invalidation that happened after the search was admitted — every
    invalidation bumps a **generation counter**, admission captures it,
    and ``put(..., gen=...)`` drops the entry (counted in
    ``stale_puts``) when the generations no longer match.  Without this
    a drained result would re-insert an entry the journal already
    declared stale (the QK201 exemplar race; see
    tests/quakecheck_fixtures/qk201_bad.py).
    """

    def __init__(self, max_entries: int = 4096, bits: int = 0,
                 tol: float = 0.0, seed: int = 0):
        self._lock = TrackedLock("ResultCache._lock")
        self.max_entries = max_entries
        self.bits = bits
        self.tol = float(tol)
        self._seed = seed
        self._proj: Optional[np.ndarray] = None
        self._store: "OrderedDict[int, dict]" = OrderedDict()  # eid -> entry
        self._by_key: Dict[bytes, List[int]] = {}
        self._by_part: Dict[int, set] = {}
        self._next_eid = 0
        self._gen = 0
        self.hits = 0
        self.misses = 0
        self.invalidated = 0
        self.stale_puts = 0

    def __len__(self) -> int:
        with self._lock:
            return len(self._store)

    @property
    def generation(self) -> int:
        """Invalidation generation — capture at admission, hand back to
        ``put``; a mismatch means an invalidation happened in between."""
        with self._lock:
            return self._gen

    def _key(self, q: np.ndarray) -> bytes:
        if self.bits <= 0:
            return q.tobytes()
        if self._proj is None or self._proj.shape[1] != q.shape[0]:
            rng = np.random.default_rng(self._seed)
            self._proj = rng.normal(
                size=(self.bits, q.shape[0])).astype(np.float32)
        return np.packbits(self._proj @ q >= 0.0).tobytes()

    def get(self, q: np.ndarray, k: int) -> Optional[dict]:
        q = np.ascontiguousarray(q, dtype=np.float32)
        with self._lock:
            note_guarded(self, "_store")
            best, best_d = None, np.inf
            for eid in self._by_key.get(self._key(q), ()):
                e = self._store[eid]
                if e["k"] != k:
                    continue
                d = float(np.linalg.norm(q - e["q"]))
                if d <= self.tol and d < best_d:
                    best, best_d = e, d
            if best is None:
                self.misses += 1
                return None
            self._store.move_to_end(best["eid"])
            self.hits += 1
            # shallow copy: the caller reads fields after the lock drops,
            # and the entry itself may be evicted meanwhile
            return dict(best)

    def put(self, q: np.ndarray, k: int, ids: np.ndarray, dists: np.ndarray,
            footprint: np.ndarray, nprobe: int = 0,
            recall_estimate: float = np.nan,
            gen: Optional[int] = None) -> None:
        with self._lock:
            note_guarded(self, "_store")
            if self.max_entries <= 0:
                return
            if gen is not None and gen != self._gen:
                # an invalidation ran after this result was admitted:
                # inserting it would resurrect journal-stale state
                self.stale_puts += 1
                return
            q = np.ascontiguousarray(q, dtype=np.float32)
            key = self._key(q)
            eid = self._next_eid
            self._next_eid += 1
            fp = np.unique(np.asarray(footprint, dtype=np.int64))
            self._store[eid] = {
                "eid": eid, "key": key, "k": k, "q": q.copy(),
                "ids": np.asarray(ids).copy(),
                "dists": np.asarray(dists).copy(),
                "footprint": fp, "nprobe": int(nprobe),
                "recall_estimate": float(recall_estimate)}
            self._by_key.setdefault(key, []).append(eid)
            for p in fp:
                self._by_part.setdefault(int(p), set()).add(eid)
            while len(self._store) > self.max_entries:
                old_eid, old_entry = self._store.popitem(last=False)  # LRU
                self._unlink(old_eid, old_entry)

    def _unlink(self, eid: int, entry: dict) -> None:
        eids = self._by_key.get(entry["key"], [])
        if eid in eids:
            eids.remove(eid)
            if not eids:
                del self._by_key[entry["key"]]
        for p in entry["footprint"]:
            s = self._by_part.get(int(p))
            if s is not None:
                s.discard(eid)
                if not s:
                    del self._by_part[int(p)]

    def _remove(self, eid: int) -> None:
        entry = self._store.pop(eid, None)
        if entry is not None:
            self._unlink(eid, entry)

    def invalidate_partitions(self, dirty: Iterable[int]) -> int:
        """Drop every entry whose planned footprint touches ``dirty``."""
        with self._lock:
            note_guarded(self, "_store")
            doomed: set = set()
            for p in dirty:
                doomed |= self._by_part.get(int(p), set())
            for eid in doomed:
                self._remove(eid)
            self.invalidated += len(doomed)
            self._gen += 1          # in-flight puts are now suspect
            return len(doomed)

    def clear(self) -> None:
        with self._lock:
            note_guarded(self, "_store")
            self.invalidated += len(self._store)
            self._store.clear()
            self._by_key.clear()
            self._by_part.clear()
            self._gen += 1          # in-flight puts are now suspect

    def counters(self) -> dict:
        """Lock-consistent copy of the cache telemetry."""
        with self._lock:
            return {"entries": len(self._store), "hits": self.hits,
                    "misses": self.misses,
                    "invalidated": self.invalidated,
                    "stale_puts": self.stale_puts,
                    "generation": self._gen}


# ---------------------------------------------------------------------------
# Drift-triggered maintenance scheduling
# ---------------------------------------------------------------------------

@dataclass
class MaintenanceTriggers:
    """When the serving loop should pay for a maintenance pass.

    ``min_ops`` rate-limits passes; beyond it a pass runs when any drift
    signal fires: the journal's folded dirty mass since the last pass
    (``dirty_frac`` of the partition directory — the Incremental-IVF
    decoupling of maintenance cadence from the op stream), the
    cost-model estimate moving by ``cost_drift`` relative to the cost at
    the last pass (Eq. 2 over current sizes and served access
    frequencies), or the served access histogram shifting by
    ``access_shift`` total-variation distance (read-skew drift: the same
    partitions, differently hot).  ``max_ops`` forces a pass regardless
    — the backstop that bounds how stale statistics can get."""
    min_ops: int = 4
    dirty_frac: float = 0.25
    cost_drift: float = 0.15
    access_shift: float = 0.6
    max_ops: Optional[int] = 64


class MaintenanceScheduler:
    """Replaces run-after-every-op with drift triggers over the journal,
    the cost model, and the served access histogram.

    Thread safety: public methods take ``_lock``
    (``MaintenanceScheduler._lock``, innermost in the declared
    ``LOCK_ORDER``) — the runtime's engine lock already serializes
    maintenance *work*; this lock only keeps the trigger counters and
    history coherent for concurrent ``stats()`` readers."""

    def __init__(self, maintainer: Maintainer,
                 triggers: Optional[MaintenanceTriggers] = None):
        self._lock = TrackedLock("MaintenanceScheduler._lock")
        self.maintainer = maintainer
        self.index = maintainer.index
        self.triggers = triggers or MaintenanceTriggers()
        self.ops_since = 0
        self.history: List[dict] = []
        self._rebaseline()

    def _freq_vector(self) -> np.ndarray:
        lvl0 = self.index.levels[0]
        return lvl0.stats.access_freq(lvl0.num_partitions,
                                      self.index.config.default_access_freq)

    def _rebaseline(self) -> None:
        with self._lock:
            self._last_version = self.index.version
            self._last_cost = self.maintainer.total_cost()
            self._last_freqs = self._freq_vector().copy()
            self.ops_since = 0

    def note_op(self, n: int = 1) -> None:
        with self._lock:
            self.ops_since += n

    def due(self) -> Optional[str]:
        """Trigger that fired, or None.  Cheap: one journal fold, one
        O(P) cost evaluation, one O(P) histogram distance."""
        with self._lock:
            t = self.triggers
            if self.ops_since < t.min_ops:
                return None
            if t.max_ops is not None and self.ops_since >= t.max_ops:
                return "op_budget"
            delta = self.index.journal.delta_since(self._last_version)
            if delta is None:
                return "journal_trimmed"
            if delta.structural:
                return "structural"
            p = max(self.index.num_partitions, 1)
            if len(delta.dirty) >= t.dirty_frac * p:
                return "dirty_mass"
            cost = self.maintainer.total_cost()
            if abs(cost - self._last_cost) >= t.cost_drift * max(
                    self._last_cost, 1e-9):
                return "cost_drift"
            f, g = self._freq_vector(), self._last_freqs
            m = min(len(f), len(g))
            fs, gs = float(f[:m].sum()), float(g[:m].sum())
            if m and fs > 0 and gs > 0:
                shift = 0.5 * float(np.abs(f[:m] / fs - g[:m] / gs).sum())
                if shift >= t.access_shift:
                    return "access_shift"
            return None

    def run_if_due(self, force: bool = False) -> Optional[MaintenanceReport]:
        reason = "forced" if force else self.due()
        if reason is None:
            return None
        # the actual pass runs outside _lock: the runtime's engine lock
        # serializes maintenance work, and holding the innermost lock
        # across index mutation would pin every stats() reader behind it
        rep = self.maintainer.run()
        with self._lock:
            self.history.append({
                "reason": reason, "ops_since": self.ops_since,
                "splits": rep.splits, "merges": rep.merges,
                "cost_before": round(rep.cost_before, 1),
                "cost_after": round(rep.cost_after, 1)})
        self._rebaseline()
        return rep

    def snapshot(self) -> dict:
        """Lock-consistent deep copy of the trigger telemetry."""
        with self._lock:
            return {"runs": len(self.history),
                    "reasons": [h["reason"] for h in self.history],
                    "history": [dict(h) for h in self.history],
                    "ops_since": self.ops_since}


# ---------------------------------------------------------------------------
# Host scan backend (CPU fast path for the riding rounds)
# ---------------------------------------------------------------------------

def host_scan_round(index: QuakeIndex, q: np.ndarray, seq: np.ndarray,
                    take: np.ndarray, kept: np.ndarray, k_keep: int,
                    q_norm_sq: Optional[np.ndarray] = None):
    """One riding round scanned on host: for every union partition, one
    BLAS GEMM over exactly the queries that take it and exactly the rows
    it holds — the ragged-buffer mirror of the packed device scan, with
    no padded-slot compute (the index docstring's rationale for the
    ``numpy`` backend: per-partition scans are tiny on CPU and device
    dispatch would dominate).  Serving write barriers freeze the index
    within a scheduler epoch, so scanning the live buffers is coherent
    with the plan.  The partition is still streamed/computed once for
    all riders — the amortization the round union exists for.

    Returns (dists (B, k_keep), ids (B, k_keep) **external** ids, stats)
    with MASK_DIST / -1 padding — same conventions as the device scan
    except ids are already external (no flat-index indirection).
    """
    lvl0 = index.levels[0]
    b = q.shape[0]
    metric = index.config.metric
    if metric == "l2" and q_norm_sq is None:
        q_norm_sq = np.sum(q.astype(np.float64) ** 2, axis=1)
    cand_d: List[List[np.ndarray]] = [[] for _ in range(b)]
    cand_i: List[List[np.ndarray]] = [[] for _ in range(b)]
    vectors = comparisons = 0
    # one pass over the taken cells groups query rows by partition —
    # O(nnz log nnz) instead of a full (B, M) mask scan per partition
    rr, cc = np.nonzero(take)
    if len(rr):
        parts = seq[rr, cc]
        order = np.argsort(parts, kind="stable")
        rr, parts = rr[order], parts[order]
        bounds = np.nonzero(np.diff(parts))[0] + 1
        starts = np.concatenate([np.zeros(1, dtype=np.int64), bounds])
        groups = dict(zip(parts[starts].tolist(), np.split(rr, bounds)))
    else:
        groups = {}
    for j in kept:
        j = int(j)
        rows = groups.get(j, ())
        x = lvl0.vectors[j]
        s = x.shape[0]
        vectors += s
        if s == 0 or len(rows) == 0:
            continue
        comparisons += s * len(rows)
        qj = q[rows]
        if metric == "l2":
            d = (lvl0.sqnorms[j][None, :].astype(np.float64)
                 - 2.0 * (qj @ x.T) + q_norm_sq[rows][:, None])
        else:
            d = -(qj @ x.T).astype(np.float64)
        kk = min(k_keep, s)
        if kk < s:
            part = np.argpartition(d, kk - 1, axis=1)[:, :kk]
            dd = np.take_along_axis(d, part, axis=1)
            ii = lvl0.ids[j][part]
        else:
            dd, ii = d, np.broadcast_to(lvl0.ids[j], d.shape)
        for r, row in enumerate(rows):
            cand_d[row].append(dd[r])
            cand_i[row].append(ii[r])
    out_d = np.full((b, k_keep), MASK_DIST, dtype=np.float64)
    out_i = np.full((b, k_keep), -1, dtype=np.int64)
    for row in range(b):
        if not cand_d[row]:
            continue
        d = np.concatenate(cand_d[row])
        i = np.concatenate(cand_i[row])
        kk = min(k_keep, len(d))
        sel = np.argpartition(d, kk - 1)[:kk] if kk < len(d) \
            else np.arange(len(d))
        out_d[row, :kk] = d[sel]
        out_i[row, :kk] = i[sel]
    order = np.argsort(out_d, axis=1, kind="stable")
    out_d = np.take_along_axis(out_d, order, axis=1)
    out_i = np.take_along_axis(out_i, order, axis=1)
    st = {"partitions": int(len(kept)), "vectors": int(vectors),
          "comparisons": int(comparisons)}
    return out_d, out_i, st


# ---------------------------------------------------------------------------
# Cross-batch riding round scheduler
# ---------------------------------------------------------------------------

@dataclass
class _Pending:
    """One in-flight query's round state (the per-row decomposition of
    ``run_round_loop``'s batch arrays, so membership can change)."""
    qid: int
    q: np.ndarray              # (d,)
    q_norm_sq: float
    seq: np.ndarray            # (M,) scan-ordered candidate partitions
    count: int                 # planned probe budget (fixed-plan cells)
    geo: np.ndarray            # (M,) seq-aligned geometry distances
    cc: np.ndarray             # (M,) seq-aligned center-center distances
    wins: List[Tuple[int, int]]
    win_ptr: int
    scanned: np.ndarray        # (M,) bool — cells consumed so far
    r_est: float
    td: np.ndarray             # (k_keep,) running top distances
    ti: np.ndarray             # (k_keep,) running top flat indices
    t_submit: float
    batch: int                 # admission group (riding accounting)
    rounds: int = 0            # rounds this query took cells in
    deadline: Optional[float] = None  # absolute clock value the latency
                               # budget expires at (None = no budget)


class RoundScheduler:
    """Cross-batch generalization of ``run_round_loop``: drives probe
    rounds over a query population that *changes between rounds*.

    Queries join via :meth:`admit` (planned against the executor's
    current snapshot); each :meth:`step` takes every in-flight query's
    next probe window, forms one shared partition union, lets every
    query additionally consume all of its not-yet-scanned probes landing
    in that union (union riding, now across admission groups), scans the
    union once (``BatchedSearchExecutor.scan_probe_round``), folds the
    result into per-query running top-k state, and retires queries whose
    plan is exhausted — or, with ``early_exit``, whose refined APS
    estimate cleared the target.

    Invariants (asserted by ``tests/test_serving.py``):
      * footprint: partitions streamed across all rounds ⊆ the union of
        the admitted batches' fixed plans (riding consumes planned cells
        early; it never adds partitions a plan didn't contain);
      * co-admitted amortization: while no new group is admitted
        mid-flight, a partition block streams at most once — exactly
        ``run_round_loop``'s per-batch guarantee, extended to every
        batch coalesced into the group.

    With ``early_exit=False`` every query consumes exactly its fixed
    plan, so results are independent of how admission interleaved with
    rounds — the runtime's coalescing-determinism contract.
    """

    def __init__(self, executor: "mq.BatchedSearchExecutor", k: int,
                 target: float, rounds: Optional[int] = None,
                 early_exit: bool = False, b_bucket: int = 16,
                 record_stats: bool = True, scan_backend: str = "auto",
                 clock: Optional[Callable[[], float]] = None,
                 faults: Optional[FaultInjector] = None,
                 scan_retries: int = 2, scan_backoff_s: float = 0.001,
                 scan_backoff_max_s: float = 0.05, obs=None):
        self._lock = TrackedLock("RoundScheduler._lock")
        self._clock = clock or time.perf_counter
        # repro.obs.Observability bundle or None; its locks rank after
        # RoundScheduler._lock in sanitize.LOCK_ORDER, so recording from
        # inside a locked step can never invert the order
        self.obs = obs
        self.ex = executor
        self.index = executor.index
        self.k = k
        self.target = target
        self.probe_frac: Optional[float] = None  # governor probe-budget cap
        self.round_budget = rounds
        self.early_exit = early_exit
        self.b_bucket = max(b_bucket, 1)
        self.record_stats = record_stats
        self.faults = faults
        self.scan_retries = max(int(scan_retries), 0)
        self.scan_backoff_s = float(scan_backoff_s)
        self.scan_backoff_max_s = float(scan_backoff_max_s)
        self._last_scan_error: Optional[BaseException] = None
        if scan_backend == "auto":
            import jax
            scan_backend = ("device" if jax.default_backend() == "tpu"
                            else "host")
        if scan_backend not in ("host", "device"):
            raise ValueError(f"scan_backend must be host/device/auto, "
                             f"got {scan_backend!r}")
        self.scan_backend = scan_backend
        self.active: List[_Pending] = []
        self.done: List[tuple] = []     # (qid, QueryResult, q, footprint)
        self._epoch_key = None
        self._snap = None
        self._m: Optional[int] = None
        self._k_keep = k
        self._rerank = False
        self._batches = 0
        # riding / invariant telemetry
        self.rounds_run = 0
        self.round_streams: List[np.ndarray] = []   # kept ids per round
        self.plan_footprints: List[np.ndarray] = [] # per admitted batch
        self.partitions_streamed = 0
        self.vectors_streamed = 0
        self.comparisons = 0
        # failure / degradation telemetry
        self.partials = 0           # budget-expired retirements
        self.failures = 0           # FAILED retirements
        self.failed_batches = 0     # rounds whose scan exhausted retries
        self.scan_faults = 0        # scan attempts that raised
        self.scan_retries_used = 0  # backoff retries taken
        # deferred hot-path observability: per-round samples accumulate
        # here as plain appends under the already-held scheduler lock
        # and drain through ``flush_obs`` in ONE registry update + ONE
        # tracer emit per collect pass — even a batched TrackedLock
        # acquisition per round is measurable against a ~100us query
        # (the obs-overhead bench cell gates this path's cost)
        self._obs_walls: List[float] = []
        self._obs_parts = 0
        self._obs_vecs = 0
        self._obs_rounds: List[dict] = []
        self._obs_flushes: List[dict] = []
        self._cal_tick = 0

    def set_degradation(self, target: float,
                        probe_frac: Optional[float]) -> None:
        """Governor hook: effective recall target and per-query probe-
        budget fraction for *subsequent* admissions (``None`` = no cap).
        In-flight queries keep the plans they were admitted with."""
        with self._lock:
            self.target = float(target)
            self.probe_frac = probe_frac

    # -- admission -----------------------------------------------------

    def admit(self, queries: np.ndarray, qids: Sequence[int],
              t_submit: Optional[Sequence[float]] = None,
              deadlines: Optional[Sequence[Optional[float]]] = None) -> None:
        """Plan one coalesced batch and add its queries to the in-flight
        population.  All admissions between drains must see the same
        snapshot fingerprint (writes barrier through the runtime).
        ``deadlines`` are absolute clock values (same clock as the
        scheduler's) at which each query's latency budget expires —
        expired queries retire ``PARTIAL`` at the end of the round that
        noticed (None entries have no budget)."""
        with self._lock:
            note_guarded(self, "active")
            q = np.ascontiguousarray(queries, dtype=np.float32)
            if q.ndim == 1:
                q = q[None, :]
            b = q.shape[0]
            if b == 0:
                return
            if self.scan_backend == "host":
                # no device snapshot: rounds scan the live ragged
                # buffers, which the runtime's write barriers freeze
                # within an epoch
                self.ex.planner_cache.ensure_fresh()
                snap = None
            else:
                snap = self.ex.snapshot()
            fp = self.ex._fingerprint()
            if self.active and fp != self._epoch_key:
                raise RuntimeError(
                    "snapshot changed under in-flight queries; drain() "
                    "before mutating the index (the runtime's write "
                    "barrier does this)")
            self._epoch_key = fp
            self._snap = snap
            self._rerank = (snap is not None and snap.scales is not None
                            and self.ex.int8_rerank
                            and self.ex._host_f32 is not None)
            self._k_keep = 2 * self.k if self._rerank else self.k
            rplan = mq.plan_rounds(self.index, q, self.k, self.target,
                                   planner=self.ex.planner,
                                   cache=self.ex.planner_cache,
                                   cent_norms=self.ex._cent_norms)
            m = rplan.seq.shape[1]
            if self._m is None or not self.active:
                self._m = m
            assert m == self._m, (m, self._m)
            now = self._clock()
            ts = t_submit if t_submit is not None else [now] * b
            dls = deadlines if deadlines is not None else [None] * b
            qn = np.sum(q.astype(np.float64) ** 2, axis=1)
            batch_id = self._batches
            self._batches += 1
            if self.obs is not None:
                # flush metadata for span synthesis: spans reference it
                # through their batch id (QueryTracer.note_flushes)
                # instead of paying a per-query flush event here
                self._obs_flushes.append(
                    {"batch": batch_id, "t": now, "n": b})
            eff_counts = []
            for i in range(b):
                count = int(rplan.counts[i])
                if self.probe_frac is not None:
                    # governor degradation: truncate the plan to a
                    # fraction of its probe budget (footprint bound —
                    # the serving-layer union_cap analog)
                    count = max(1, int(np.ceil(count * self.probe_frac)))
                eff_counts.append(count)
                self.active.append(_Pending(
                    qid=int(qids[i]), q=q[i], q_norm_sq=float(qn[i]),
                    seq=rplan.seq[i], count=count,
                    geo=rplan.geo[i], cc=rplan.cc[i],
                    wins=mq._round_windows(count, self.round_budget),
                    win_ptr=0, scanned=np.zeros(m, dtype=bool),
                    r_est=float(rplan.recall_est[i]),
                    td=np.full(self._k_keep, MASK_DIST, dtype=np.float64),
                    ti=np.full(self._k_keep, -1, dtype=np.int64),
                    t_submit=float(ts[i]), batch=batch_id,
                    deadline=None if dls[i] is None else float(dls[i])))
            self.plan_footprints.append(
                np.unique(np.concatenate(
                    [rplan.seq[i][:eff_counts[i]] for i in range(b)])))
            if self.record_stats:
                lvl0 = self.index.levels[0]
                lvl0.stats.ensure(lvl0.num_partitions)
                lvl0.stats.record_batch(np.zeros(0, np.int64),
                                        np.zeros(0), b)

    # -- rounds --------------------------------------------------------

    def step(self) -> bool:
        """Run one shared probe round.  Returns False once nothing is in
        flight (all queries retired)."""
        with self._lock:
            return self._step_locked()

    def _step_locked(self) -> bool:
        note_guarded(self, "active")
        rows = self.active
        if not rows:
            return False
        b = len(rows)
        m = self._m
        seq_mat = np.stack([pq.seq for pq in rows])
        scanned = np.stack([pq.scanned for pq in rows])
        counts = np.asarray([pq.count for pq in rows])
        cols = np.arange(m)[None, :]
        within = cols < counts[:, None]
        avail = within & ~scanned

        base = np.zeros((b, m), dtype=bool)
        for i, pq in enumerate(rows):
            # advance past windows that riding already consumed
            while pq.win_ptr < len(pq.wins):
                c0, c1 = pq.wins[pq.win_ptr]
                if avail[i, c0:c1].any():
                    base[i, c0:c1] = avail[i, c0:c1]
                    break
                pq.win_ptr += 1
        if not base.any():
            self._retire(rows, np.ones(b, dtype=bool), scanned, within)
            return bool(self.active)

        kept = np.unique(seq_mat[base])
        p = self.index.levels[0].num_partitions
        in_union = np.zeros(max(int(seq_mat.max()) + 1, p), dtype=bool)
        in_union[kept] = True
        take = avail & in_union[seq_mat]
        scanned |= take

        q_mat = np.stack([pq.q for pq in rows])
        if self.faults is not None:
            self.faults.stall("slow_round")   # injected straggler round
        t_scan = self._clock()
        scan = self._scan_with_retry(q_mat, seq_mat, take, kept, rows)
        if scan is None:
            # retries exhausted: fail the affected in-flight batch —
            # every query gets a terminal FAILED result and the runtime
            # (queue, ticker, future admissions) stays alive
            self._fail_inflight(rows, scanned, within)
            return bool(self.active)
        d, flat, st = scan

        # fold into per-query running top-k (host side: rows churn)
        td = np.stack([pq.td for pq in rows])
        ti = np.stack([pq.ti for pq in rows])
        cat_d = np.concatenate([td, d], axis=1)
        cat_i = np.concatenate([ti, flat], axis=1)
        order = np.argsort(cat_d, axis=1, kind="stable")[:, :self._k_keep]
        td = np.take_along_axis(cat_d, order, axis=1)
        ti = np.take_along_axis(cat_i, order, axis=1)

        took = take.any(axis=1)
        takers = [] if self.obs is not None else None
        for i, pq in enumerate(rows):
            pq.scanned = scanned[i]
            pq.td = td[i]
            pq.ti = ti[i]
            pq.rounds += int(took[i])
            if takers is not None and took[i]:
                takers.append(pq.qid)

        self.rounds_run += 1
        self.round_streams.append(kept)
        self.partitions_streamed += st["partitions"]
        self.vectors_streamed += st["vectors"]
        self.comparisons += st["comparisons"]
        if self.obs is not None:
            t_now = self._clock()
            dt_scan = t_now - t_scan
            self._obs_walls.append(dt_scan)
            self._obs_parts += int(st["partitions"])
            self._obs_vecs += int(st["vectors"])
            # predicted-vs-observed scan cost, sampled every 4th round
            # (first round always): ``predict_scan_ns`` over the folded
            # sizes is a numpy pass per call, and roughly-one-sample-
            # per-flush keeps the rolling error just as live at a
            # quarter of the cost
            self._cal_tick += 1
            if self._cal_tick % 4 == 1:
                self.obs.calibration.record_scan(
                    self.index.levels[0].sizes_of(kept), dt_scan)
            # one metadata record per round — the taker qids are how
            # spans recover per-round scan events at read time
            # (QueryTracer.note_rounds); no per-query work here
            self._obs_rounds.append({
                "t": t_now, "round": self.rounds_run,
                "partitions": int(st["partitions"]),
                "vectors": int(st["vectors"]),
                "wall_s": dt_scan, "takers": takers})
        if self.record_stats:
            parts, cnts = np.unique(seq_mat[take], return_counts=True)
            lvl0 = self.index.levels[0]
            lvl0.stats.ensure(lvl0.num_partitions)
            lvl0.stats.record_batch(parts, cnts, 0)

        finished = ~(within & ~scanned).any(axis=1)
        statuses = np.full(b, STATUS_OK, dtype=object)
        now = self._clock()
        expired = np.asarray([pq.deadline is not None and now >= pq.deadline
                              for pq in rows])
        if self.early_exit or bool((expired & ~finished).any()):
            # refined APS estimate from the *running* k-th distance —
            # the early-exit retirement test, and what a budget-expired
            # query's PARTIAL result reports as the recall it earned
            kth = td[:, self.k - 1]
            full = kth < MASK_DIST
            if self.index.config.metric == "l2":
                rho_sq = aps_mod.rho_sq_batch(kth, metric="l2")
            else:
                qn = np.asarray([pq.q_norm_sq for pq in rows])
                rho_sq = aps_mod.rho_sq_batch(
                    kth, metric="ip", q_norm_sq=qn,
                    max_norm_sq=self.index._max_norm_sq)
            rho_sq = np.where(full, rho_sq, np.inf)
            geo_mat = np.stack([pq.geo for pq in rows])
            cc_mat = np.stack([pq.cc for pq in rows])
            valid = np.ones((b, m), dtype=bool)
            valid[:, 0] = False
            p0, probs = aps_mod.estimate_probs_batch(
                geo_mat[:, 0], geo_mat, cc_mat, rho_sq,
                self.index._beta_table, valid)
            r = p0 + np.where(scanned & valid, probs, 0.0).sum(axis=1)
            if self.early_exit:
                for i, pq in enumerate(rows):
                    if full[i]:
                        pq.r_est = float(r[i])
                finished |= full & (r >= self.target)
            partial = expired & ~finished
            if partial.any():
                for i in np.nonzero(partial)[0]:
                    # finite by construction: the refined estimate over
                    # what was actually scanned, or 0.0 when the top-k
                    # never filled (the honest lower bound) — never the
                    # full-plan estimate the query didn't earn
                    rows[i].r_est = float(r[i]) if full[i] else 0.0
                statuses[partial] = STATUS_PARTIAL
                self.partials += int(partial.sum())
                finished |= partial
        self._retire(rows, finished, scanned, within, statuses)
        return True

    # -- fault handling ------------------------------------------------

    def _scan_once(self, q_mat: np.ndarray, seq_mat: np.ndarray,
                   take: np.ndarray, kept: np.ndarray,
                   rows: List[_Pending]):
        b, m = take.shape
        if self.scan_backend == "host":
            return host_scan_round(
                self.index, q_mat, seq_mat, take, kept, self._k_keep,
                q_norm_sq=np.asarray([pq.q_norm_sq for pq in rows]))
        # pad the active rows on a geometric ladder (b_bucket * 2^i)
        # so the jitted scan sees O(log B) distinct (B, M) shapes as
        # the in-flight population grows/shrinks; pad rows carry
        # take=False (inert under the scan mask)
        b_pad = self.b_bucket
        while b_pad < b:
            b_pad *= 2
        q_pad = q_mat
        if b_pad > b:
            q_pad = np.concatenate(
                [q_mat,
                 np.zeros((b_pad - b, q_mat.shape[1]), np.float32)])
            seq_pad = np.concatenate(
                [seq_mat, np.zeros((b_pad - b, m), seq_mat.dtype)])
            take_pad = np.concatenate(
                [take, np.zeros((b_pad - b, m), bool)])
        else:
            seq_pad, take_pad = seq_mat, take
        d, flat, st = self.ex.scan_probe_round(
            jnp.asarray(q_pad), jnp.asarray(seq_pad.astype(np.int32)),
            take_pad, kept, self._k_keep, snap=self._snap, u_pow2=True,
            seq_host=seq_pad)
        # the scheduler's running top-k folds on host because the row
        # set churns every round (admissions/retirements) — one pull
        # per round over the active rows
        # quakecheck: allow-sync(per-round fold: host top-k over a churning row set)
        d = np.asarray(d, dtype=np.float64)[:b]
        flat = np.asarray(flat, dtype=np.int64)[:b]  # quakecheck: allow-sync(per-round fold)
        return d, flat, st

    def _scan_with_retry(self, q_mat: np.ndarray, seq_mat: np.ndarray,
                         take: np.ndarray, kept: np.ndarray,
                         rows: List[_Pending]):
        """One round scan with capped exponential backoff.  Returns the
        scan triple, or None once ``scan_retries`` retries are exhausted
        (the caller fails the in-flight batch).  A scan exception —
        injected or real — never propagates out of the scheduler."""
        attempt = 0
        while True:
            try:
                if self.faults is not None:
                    self.faults.check("scan")
                return self._scan_once(q_mat, seq_mat, take, kept, rows)
            except Exception as e:
                self.scan_faults += 1
                self._last_scan_error = e
                if attempt >= self.scan_retries:
                    return None
                self.scan_retries_used += 1
                self._sleep(min(self.scan_backoff_s * (2.0 ** attempt),
                                self.scan_backoff_max_s))
                attempt += 1

    def _sleep(self, delay: float) -> None:
        if delay <= 0:
            return
        fn = self.faults.sleep_fn if self.faults is not None else time.sleep
        fn(delay)

    def _fail_inflight(self, rows: List[_Pending], scanned: np.ndarray,
                       within: np.ndarray) -> None:
        """Retire every in-flight query with a terminal FAILED result
        (ids -1 / dists inf) carrying the scan error.  Queued-but-not-
        admitted queries are unaffected — only the batch whose scan
        exhausted its retries fails."""
        err = repr(self._last_scan_error)
        self.failed_batches += 1
        now = self._clock()
        for i, pq in enumerate(rows):
            res = QueryResult(
                ids=np.full(self.k, -1, dtype=np.int64),
                dists=np.full(self.k, np.inf, dtype=np.float64),
                nprobe=int((scanned[i] & within[i]).sum()),
                recall_estimate=0.0, rounds=pq.rounds,
                latency_s=now - pq.t_submit,
                status=STATUS_FAILED, error=err,
                t_submit=pq.t_submit, batch=pq.batch)
            self.failures += 1
            self.done.append((pq.qid, res, None, None))
        self.active = []
        logger.warning("round scan failed after %d retries (%s): "
                       "failed %d in-flight queries",
                       self.scan_retries, err, len(rows))

    def _retire(self, rows: List[_Pending], finished: np.ndarray,
                scanned: np.ndarray, within: np.ndarray,
                statuses: Optional[np.ndarray] = None) -> None:
        idxs = np.nonzero(finished)[0]
        if len(idxs):
            now = self._clock()
            td = np.stack([rows[i].td for i in idxs])
            ti = np.stack([rows[i].ti for i in idxs])
            if self._rerank:
                qd = np.stack([rows[i].q for i in idxs])
                dd, flat = self.ex._rerank_exact(qd, ti, self.k)
            else:
                dd, flat = td[:, :self.k], ti[:, :self.k]
            if self.scan_backend == "host":
                ids = flat        # host rounds carry external ids directly
            else:
                ids = np.where(flat >= 0,
                               self.ex._flat_ids[np.maximum(flat, 0)], -1)
            dd = np.where(dd >= MASK_DIST, np.inf, dd)
            for row, i in enumerate(idxs):
                pq = rows[i]
                status = (STATUS_OK if statuses is None
                          else str(statuses[i]))
                res = QueryResult(
                    ids=ids[row].astype(np.int64), dists=dd[row],
                    nprobe=int((scanned[i] & within[i]).sum()),
                    recall_estimate=pq.r_est, rounds=pq.rounds,
                    latency_s=now - pq.t_submit,
                    status=status,
                    t_submit=pq.t_submit, batch=pq.batch)
                # PARTIAL results never enter the cache (the caller
                # checks status): the footprint is still the plan's, so
                # pass it along for telemetry, not for caching
                self.done.append((pq.qid, res, pq.q,
                                  pq.seq[:pq.count]))
        self.active = [pq for i, pq in enumerate(rows) if not finished[i]]

    def take_done(self) -> List[tuple]:
        """Hand off and clear the finished-query list — the write-barrier
        API for consuming ``done`` (callers must not mutate the list in
        place; ownership of the returned batch transfers to the caller)."""
        with self._lock:
            note_guarded(self, "done")
            out = self.done
            self.done = []
            return out

    def drain(self) -> None:
        while self.step():
            pass

    def flush_obs(self) -> None:
        """Drain the deferred round/flush observability (accumulated by
        the locked step and admit as plain appends) into ONE batched
        registry update and the tracer's metadata streams.  The runtime
        calls this on every collect pass — before terminal records are
        closed, so span synthesis has the metadata its spans reference
        — and from ``metrics_snapshot`` so snapshots never lag
        in-flight rounds."""
        if self.obs is None:
            return
        with self._lock:
            note_guarded(self, "_obs_rounds")
            walls, self._obs_walls = self._obs_walls, []
            rounds, self._obs_rounds = self._obs_rounds, []
            flushes, self._obs_flushes = self._obs_flushes, []
            parts, vecs = self._obs_parts, self._obs_vecs
            self._obs_parts = 0
            self._obs_vecs = 0
        if walls:
            self.obs.metrics.update(
                counters={"scheduler.rounds": len(walls),
                          "scheduler.partitions_streamed": parts,
                          "scheduler.vectors_streamed": vecs},
                observations={"scheduler.round_wall_s": walls})
        if flushes:
            self.obs.tracer.note_flushes(flushes)
        if rounds:
            self.obs.tracer.note_rounds(rounds)

    def has_active(self) -> bool:
        with self._lock:
            return bool(self.active)

    def epoch_key(self):
        with self._lock:
            return self._epoch_key

    def epoch_footprint(self) -> np.ndarray:
        """Distinct partitions streamed so far (invariant telemetry)."""
        with self._lock:
            if not self.round_streams:
                return np.zeros(0, dtype=np.int64)
            return np.unique(np.concatenate(self.round_streams))

    def snapshot(self) -> dict:
        """Lock-consistent copy of the riding telemetry (what
        ``ServingRuntime.stats()`` reports)."""
        with self._lock:
            return {
                "rounds_run": self.rounds_run,
                "admitted_batches": self._batches,
                "in_flight": len(self.active),
                "partitions_streamed": self.partitions_streamed,
                "partitions_planned": int(sum(
                    len(f) for f in self.plan_footprints)),
                "vectors_streamed": self.vectors_streamed,
                "comparisons": self.comparisons,
                "partials": self.partials,
                "failures": self.failures,
                "failed_batches": self.failed_batches,
                "scan_faults": self.scan_faults,
                "scan_retries_used": self.scan_retries_used,
                "effective_target": self.target,
                "probe_frac": self.probe_frac,
            }


# ---------------------------------------------------------------------------
# The runtime
# ---------------------------------------------------------------------------

class ServingRuntime:
    """Admission queue + riding scheduler + result cache + drift-triggered
    maintenance over one dynamic :class:`QuakeIndex`.

    Queries enter through :meth:`submit_query` / :meth:`submit_batch` and
    complete asynchronously (``flush_size`` admissions force a flush,
    ``flush_deadline``/``flush_deadline_ms`` bounds how long a queued
    query can wait — enforced at admission time and by a background
    ticker thread so a lone query still flushes with no further
    arrivals).  Writes are barriers: they drain the in-flight
    population, mutate the index, invalidate cache entries through the
    journal delta, and give the maintenance scheduler a chance to run.
    :meth:`drain` completes everything in flight; :meth:`result` returns
    a query's :class:`QueryResult`.

    **Threading model** (docs/serving.md): safe for concurrent
    ``submit_*`` / ``result`` / ``stats`` callers.  Two runtime locks —
    ``_engine_lock`` (reentrant, outermost) serializes all *blocking*
    engine work: flush bodies, scheduler rounds, write barriers,
    maintenance; ``_lock`` (the admission lock) is held only for queue /
    results / counter bookkeeping and is never held across blocking
    calls (quakecheck QK203 enforces this).  Lock order is declared in
    ``sanitize.LOCK_ORDER``; component locks
    (``RoundScheduler._lock`` / ``ResultCache._lock`` /
    ``MaintenanceScheduler._lock``) nest inside.  The coalescing
    determinism contract survives concurrency: the engine lock totally
    orders admissions and writes, and with ``record_admissions`` that
    order is logged so a single-threaded replay reproduces identical
    results (tests/test_serving_concurrency.py).
    """

    def __init__(self, index: QuakeIndex,
                 config: Optional[ServingConfig] = None,
                 maintainer: Optional[Maintainer] = None,
                 lam: Optional[LatencyModel] = None,
                 clock: Optional[Callable[[], float]] = None,
                 faults: Optional[FaultInjector] = None):
        self.index = index
        self.cfg = config or ServingConfig()
        self.target = (self.cfg.recall_target
                       if self.cfg.recall_target is not None
                       else index.config.recall_target)
        self._engine_lock = TrackedLock("ServingRuntime._engine_lock")
        self._lock = TrackedLock("ServingRuntime._lock")
        self._clock = clock or time.perf_counter
        self._faults = faults
        self.executor = mq.BatchedSearchExecutor(
            index, impl=self.cfg.impl, storage_dtype=self.cfg.storage_dtype,
            planner=self.cfg.planner, rounds=self.cfg.rounds,
            part_bucket=32)   # shape-stable snapshots across maintenance
        self.cache = (ResultCache(self.cfg.cache_entries,
                                  bits=self.cfg.cache_bits,
                                  tol=self.cfg.cache_tol,
                                  seed=self.cfg.cache_seed)
                      if self.cfg.cache_entries > 0 else None)
        maintainer = maintainer or Maintainer(index, lam
                                              or LatencyModel(dim=index.dim))
        if faults is not None:
            maintainer.faults = faults
        self.maintenance = MaintenanceScheduler(
            maintainer,
            MaintenanceTriggers(
                min_ops=self.cfg.maint_min_ops,
                dirty_frac=self.cfg.maint_dirty_frac,
                cost_drift=self.cfg.maint_cost_drift,
                access_shift=self.cfg.maint_access_shift,
                max_ops=self.cfg.maint_max_ops))
        # observability bundle (repro.obs, docs/observability.md): the
        # registry/tracer/calibration locks rank innermost in
        # sanitize.LOCK_ORDER, so every hook below is legal under any
        # runtime lock.  cfg.metrics=False leaves it None — every hook
        # is then a None check and results are byte-identical
        self.obs = (Observability(
            lam=maintainer.lam,
            trace_capacity=self.cfg.trace_capacity,
            calibration_window=self.cfg.calibration_window)
            if self.cfg.metrics else None)
        self.scheduler = RoundScheduler(
            self.executor, self.cfg.k, self.target,
            rounds=self.cfg.rounds, early_exit=self.cfg.early_exit,
            b_bucket=self.cfg.b_bucket,
            record_stats=self.cfg.record_stats,
            scan_backend=self.cfg.scan_backend,
            clock=self._clock, faults=faults,
            scan_retries=self.cfg.scan_retries,
            scan_backoff_s=self.cfg.scan_backoff_s,
            scan_backoff_max_s=self.cfg.scan_backoff_max_s,
            obs=self.obs)
        # durability: WAL + checkpoint store (docs/durability.md).  The
        # attach writes a baseline checkpoint of the index as handed in;
        # fault injection arms only after that (startup is not a
        # steady-state crash point)
        self.durability = (DurabilityManager(
            index, self.cfg.wal_dir, fsync=self.cfg.fsync,
            wal_batch_ops=self.cfg.wal_batch_ops,
            ckpt_every_ops=self.cfg.ckpt_every_ops,
            keep_checkpoints=self.cfg.keep_checkpoints, faults=faults)
            if self.cfg.wal_dir is not None else None)
        self.recovery_report: Optional[RecoveryReport] = None
        # queue entries: (qid, query, t_submit, absolute deadline | None)
        self._queue: List[Tuple[int, np.ndarray, float,
                                Optional[float]]] = []
        self._maintaining = False
        self._next_qid = 0
        self.results: Dict[int, QueryResult] = {}
        self._cache_version = index.version
        self._admission_log: List[tuple] = []
        self._admit_gen: Dict[int, int] = {}
        self.queries_submitted = 0
        self.cache_hits = 0
        self.write_ops = 0
        # failure / degradation telemetry (docs/serving.md)
        self.shed_queries = 0
        self._status_counts = {s: 0 for s in TERMINAL_STATUSES}
        self.cache_errors = 0
        self._cache_disabled = False
        self.ticker_errors = 0
        self.ticker_restarts = 0
        self.ticker_wedged = False
        self.maintenance_failures = 0
        self._overflow_since_flush = False
        self._base_target = self.target
        self._govern_steps = 0
        self._pressure_streak = 0
        self._calm_streak = 0
        self._govern_degrades = 0
        self._govern_restores = 0
        self._closed = False
        self._ticker_wake = threading.Event()
        self._ticker_error: Optional[BaseException] = None
        self._ticker_thread: Optional[threading.Thread] = None
        self._ensure_ticker()

    # -- lifecycle -----------------------------------------------------

    def close(self) -> None:
        """Stop the deadline ticker (idempotent).  Queued / in-flight
        work is left as is — call :meth:`drain` first to finish it.

        A ticker that fails to join within 5 s is *wedged* (stuck in a
        scan or a lock) — that is logged, counted in
        ``stats()['ticker_wedged']``, and the thread reference is kept
        so the condition stays observable, instead of being silently
        dropped."""
        self._closed = True
        self._ticker_wake.set()
        t = self._ticker_thread
        if t is not None:
            t.join(timeout=5.0)
            if t.is_alive():
                with self._lock:
                    self.ticker_wedged = True
                logger.error(
                    "serving ticker did not stop within 5s join budget "
                    "(wedged in a scan or lock); thread left daemonized "
                    "— see stats()['ticker_wedged']")
            else:
                self._ticker_thread = None
        if self.durability is not None:
            self.durability.close()

    def __enter__(self) -> "ServingRuntime":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    @classmethod
    def recover(cls, wal_dir: str,
                config: Optional[ServingConfig] = None,
                **kwargs) -> "ServingRuntime":
        """Crash recovery entry point: rebuild the index from the newest
        *valid* checkpoint plus the WAL suffix under ``wal_dir``
        (fingerprint-verified, torn tail truncated —
        ``durability.recover_index``), then serve it with durability
        re-attached to the same directory.  The attach writes a fresh
        baseline checkpoint of the recovered state, so the next crash
        recovers from here even if the old WAL was damaged.  Details of
        what was recovered are on ``runtime.recovery_report``."""
        idx, report = recover_index(wal_dir)
        cfg = replace(config, wal_dir=wal_dir) if config is not None \
            else ServingConfig(wal_dir=wal_dir)
        rt = cls(idx, cfg, **kwargs)
        rt.recovery_report = report
        return rt

    # -- admission -----------------------------------------------------

    def submit_query(self, q: np.ndarray,
                     deadline_s: Optional[float] = None) -> int:
        """Admit one query; returns its ticket (qid).  Thread-safe: the
        admission lock covers ticketing, the cache probe and enqueueing;
        the flush a size/deadline trigger forces runs *after* it drops
        (blocking work never happens under the admission lock).

        ``deadline_s`` is this query's latency budget (overrides
        ``cfg.deadline_s``; None = config default): past it the query
        retires at the end of the current round with its running top-k,
        status ``PARTIAL``.  A full bounded queue applies
        ``cfg.queue_policy``: ``shed-newest`` completes this query
        immediately with status ``SHED``, ``shed-oldest`` sheds the
        oldest queued query instead, ``block`` makes this submitter pay
        for a flush and retry (backpressure)."""
        q = np.ascontiguousarray(q, dtype=np.float32).reshape(-1)
        if deadline_s is None:
            deadline_s = self.cfg.deadline_s
        elif deadline_s <= 0:
            raise ValueError(f"deadline_s must be positive "
                             f"(got {deadline_s})")
        self._ensure_ticker()
        while True:
            now = self._clock()
            do_flush = False
            overflow = False
            with self._lock:
                note_guarded(self, "_queue")
                cap = self.cfg.queue_cap
                if cap is not None and len(self._queue) >= cap:
                    policy = self.cfg.queue_policy
                    if policy == "shed-newest":
                        qid = self._alloc_qid_locked()
                        self._shed_locked(qid, now, now)
                        return qid
                    elif policy == "shed-oldest":
                        old_qid, _oq, old_t, _od = self._queue.pop(0)
                        self._shed_locked(old_qid, old_t, now)
                    else:   # block: this submitter pays for a flush,
                            # then retries — backpressure without holding
                            # the admission lock across blocking work
                        self._overflow_since_flush = True
                        overflow = True
                if not overflow:
                    qid = self._alloc_qid_locked()
                    if self.cache is not None and not self._cache_disabled:
                        if self.index.version != self._cache_version:
                            self._invalidate_cache_locked()  # out-of-band
                        hit = self._cache_guarded(
                            self.cache.get, q, self.cfg.k)
                        if hit is not None:
                            self.cache_hits += 1
                            self._status_counts[STATUS_OK] += 1
                            latency = self._clock() - now
                            self.results[qid] = QueryResult(
                                ids=hit["ids"].copy(),
                                dists=hit["dists"].copy(),
                                nprobe=hit["nprobe"],
                                recall_estimate=hit["recall_estimate"],
                                from_cache=True,
                                latency_s=latency)
                            if self.obs is not None:
                                self.obs.metrics.observe(
                                    "serving.latency_s", latency)
                                self.obs.tracer.close_many(({
                                    "qid": qid, "status": STATUS_OK,
                                    "events": [
                                        {"e": "admit", "t": now},
                                        {"e": "cache_hit",
                                         "t": now + latency},
                                        {"e": "done",
                                         "t": now + latency,
                                         "status": STATUS_OK,
                                         "cache": True,
                                         "latency_s": latency}]},))
                            return qid
                    deadline = (None if deadline_s is None
                                else now + deadline_s)
                    # the admit trace event is deferred to flush time
                    # (the queue entry carries the admit timestamp): a
                    # per-submit tracer acquisition is measurable on the
                    # hot path, a batched one at flush is not
                    self._queue.append((qid, q, now, deadline))
                    do_flush = len(self._queue) >= self.cfg.flush_size or (
                        self.cfg.flush_deadline is not None
                        and now - self._queue[0][2]
                        >= self.cfg.flush_deadline)
            if overflow:
                self.flush()
                continue
            if do_flush:
                self.flush()
            return qid

    def _alloc_qid_locked(self) -> int:
        # caller holds self._lock (propagated seed)
        qid = self._next_qid
        self._next_qid += 1
        self.queries_submitted += 1
        return qid

    def _shed_locked(self, qid: int, t_submit: float, now: float) -> None:
        # caller holds self._lock (propagated seed).  SHED is terminal:
        # the query completes immediately, empty-handed but accounted.
        self.shed_queries += 1
        self._status_counts[STATUS_SHED] += 1
        self.results[qid] = QueryResult(
            ids=np.full(self.cfg.k, -1, dtype=np.int64),
            dists=np.full(self.cfg.k, np.inf, dtype=np.float64),
            recall_estimate=0.0, latency_s=now - t_submit,
            status=STATUS_SHED)
        if self.obs is not None:
            self.obs.tracer.close_many(({
                "qid": qid, "status": STATUS_SHED,
                "events": [
                    {"e": "admit", "t": t_submit},
                    {"e": "done", "t": now, "status": STATUS_SHED,
                     "latency_s": now - t_submit}]},))

    def _cache_guarded(self, fn, *args, **kwargs):
        """One cache-backend call; a failure degrades the runtime to
        cache-off mode (counted, logged) instead of erroring the query
        that happened to probe — the cache is an optimization, never a
        correctness dependency."""
        try:
            if self._faults is not None:
                self._faults.check("cache")
            return fn(*args, **kwargs)
        except Exception as e:
            with self._lock:    # reentrant under the admission lock
                self.cache_errors += 1
                self._cache_disabled = True
            logger.warning("cache backend failed (%r): degrading to "
                           "cache-off mode", e)
            return None

    def submit_batch(self, queries: np.ndarray,
                     deadline_s: Optional[float] = None) -> List[int]:
        """Admit a query batch (one qid per row)."""
        q = np.ascontiguousarray(queries, dtype=np.float32)
        if q.ndim == 1:
            q = q[None, :]
        return [self.submit_query(q[i], deadline_s=deadline_s)
                for i in range(q.shape[0])]

    # -- deadline ticker ----------------------------------------------

    def tick(self) -> bool:
        """One deadline check: when the oldest queued query has waited
        past ``flush_deadline``, admit the queue and run it to
        completion (a deadline exists to bound answer latency — leaving
        the batch in flight for the next admission to finish would miss
        the point under light traffic).  Called by the background ticker
        thread; fake-clock tests call it directly.  Returns whether a
        flush ran."""
        deadline = self.cfg.flush_deadline
        if deadline is None:
            return False
        if self._faults is not None:
            self._faults.check("ticker")
        with self._lock:
            due = bool(self._queue) and (
                self._clock() - self._queue[0][2] >= deadline)
        if due:
            with self._engine_lock:
                self._drain_engine()
        return due

    def _ticker_loop(self) -> None:
        period = max(self.cfg.flush_deadline / 4.0, 1e-3)
        while not self._closed:
            self._ticker_wake.wait(period)
            if self._closed:
                break
            try:
                self.tick()
            except BaseException as e:
                # record the death and exit; the next admission notices
                # the dead thread and restarts the ticker (counted in
                # stats()['ticker_restarts']) — deadline flushes degrade
                # for at most one inter-arrival gap, never silently die
                self._ticker_error = e
                with self._lock:
                    self.ticker_errors += 1
                logger.warning("serving ticker died (%r); will restart "
                               "on next admission", e)
                break

    def _ensure_ticker(self) -> None:
        """Start — or restart, after a ticker death — the background
        deadline ticker.  Called at construction and on every admission,
        so a dead ticker is impossible to miss: the very next submit
        revives it."""
        if self.cfg.flush_deadline is None or not self.cfg.ticker \
                or self._closed:
            return
        with self._lock:
            t = self._ticker_thread
            if t is not None and t.is_alive():
                return
            if t is not None:
                self.ticker_restarts += 1
            t = threading.Thread(target=self._ticker_loop,
                                 name="serving-ticker", daemon=True)
            self._ticker_thread = t
            t.start()

    # -- scheduling ----------------------------------------------------

    def _ensure_radius(self) -> None:
        """Pin the APS radius for the current snapshot fingerprint with
        the deterministic resident-sample calibration, so batch planning
        never calibrates from whatever queries happened to coalesce."""
        cache = self.executor.planner_cache.ensure_fresh()
        if cache.get_radius(self.cfg.k, self.target) is None:
            cache.put_radius(self.cfg.k, self.target,
                             calibrate_radius_resident(self.index,
                                                       self.cfg.k))

    def flush(self) -> None:
        """Coalesce the queue into one executor batch, admit it to the
        riding scheduler, and advance in-flight rounds."""
        with self._engine_lock:
            self._flush_engine()

    def _flush_engine(self) -> None:
        with self._lock:
            note_guarded(self, "_queue")
            batch = list(self._queue)
            self._queue.clear()
            overflow = self._overflow_since_flush
            self._overflow_since_flush = False
        if self.cfg.govern:
            self._govern(len(batch), overflow)
        if batch:
            if (self.scheduler.has_active()
                    and self.executor._fingerprint()
                    != self.scheduler.epoch_key()):
                self.scheduler.drain()     # out-of-band mutation barrier
            self._ensure_radius()
            qids = [t[0] for t in batch]
            qs = np.stack([t[1] for t in batch])
            ts = [t[2] for t in batch]
            dls = [t[3] for t in batch]
            gen = self.cache.generation if self.cache is not None else 0
            with self._lock:
                for qid in qids:
                    self._admit_gen[qid] = gen
                if self.cfg.record_admissions:
                    self._admission_log.append(("q", tuple(qids)))
            self.scheduler.admit(qs, qids, ts, deadlines=dls)
            if self.obs is not None:
                # the queue-wait distribution lives in the registry;
                # the span's admit/flush events are synthesized at read
                # time from the terminal record's t_submit/batch and
                # the scheduler's flush metadata — no per-query tracer
                # work on this path
                t_adm = self._clock()
                waits = [t_adm - ft for ft in ts]
                self.obs.metrics.update(
                    counters={"serving.flushes": 1},
                    observations={"serving.queue_wait_s": waits})
            self.maintenance.note_op()
        for _ in range(max(self.cfg.interleave_rounds, 0)):
            if not self.scheduler.step():
                break
        self._collect()

    def _govern(self, batch_fill: int, overflow: bool) -> None:
        """Degradation governor (docs/serving.md): under sustained queue
        pressure, step the scheduler's effective recall target down
        (``govern_step`` per step, floored at ``govern_min_target``) and
        cap per-query probe budgets (``govern_probe_frac ** steps`` —
        the serving-layer union_cap analog); restore stepwise on
        sustained calm.  Pressure = an admission hit the queue cap since
        the last flush, or the flush drained >= ``govern_high *
        queue_cap`` queries; calm = no overflow and < ``govern_low *
        queue_cap``.  ``govern_patience`` consecutive signals are
        required per transition; every transition is counted."""
        cap = self.cfg.queue_cap
        if cap is None:
            return
        pressured = overflow or batch_fill >= self.cfg.govern_high * cap
        calm = (not overflow) and batch_fill < self.cfg.govern_low * cap
        with self._lock:
            if pressured:
                self._pressure_streak += 1
                self._calm_streak = 0
            elif calm:
                self._calm_streak += 1
                self._pressure_streak = 0
            else:
                self._pressure_streak = 0
                self._calm_streak = 0
            steps = self._govern_steps
            if (pressured
                    and self._pressure_streak >= self.cfg.govern_patience
                    and steps < self.cfg.govern_max_steps):
                steps += 1
                self._pressure_streak = 0
                self._govern_degrades += 1
            elif (calm and self._calm_streak >= self.cfg.govern_patience
                    and steps > 0):
                steps -= 1
                self._calm_streak = 0
                self._govern_restores += 1
            prev = self._govern_steps
            if steps == prev:
                return
            self._govern_steps = steps
        target = max(self.cfg.govern_min_target,
                     self._base_target - self.cfg.govern_step * steps)
        frac = (None if steps == 0
                else self.cfg.govern_probe_frac ** steps)
        self.scheduler.set_degradation(target, frac)
        logger.info("governor %s to step %d (target %.3f, probe_frac %s)",
                    "degraded" if steps > prev else "restored",
                    steps, target, frac)

    def drain(self) -> None:
        """Flush the queue and run rounds until nothing is in flight.
        Drains are also where read-only streams get their maintenance
        check: without it the access-shift trigger (read-skew drift) and
        the op-budget backstop could only ever fire on a write barrier."""
        with self._engine_lock:
            self._drain_engine()
        self.maybe_maintain()

    def _drain_engine(self) -> None:
        self._flush_engine()
        self.scheduler.drain()
        self._collect()

    def _collect(self) -> None:
        if self.obs is not None:
            # deferred round events first, so a span that completes in
            # this pass still reads admit -> flush -> round* -> done
            self.scheduler.flush_obs()
        done_lat, done_events = [], []
        t_done = self._clock() if self.obs is not None else 0.0
        for qid, res, q, footprint in self.scheduler.take_done():
            with self._lock:
                note_guarded(self, "results")
                self.results[qid] = res
                self._status_counts[res.status] += 1
                gen = self._admit_gen.pop(qid, None)
                cache_on = (self.cache is not None
                            and not self._cache_disabled)
            if self.obs is not None:
                done_lat.append(res.latency_s)
                # one compact DONE_FIELDS tuple per query — the span's
                # admit/flush/round events are synthesized at read time
                # from t_submit/batch and the scheduler metadata
                done_events.append((
                    qid, t_done, res.status, res.rounds, res.nprobe,
                    float(res.recall_estimate), res.latency_s,
                    res.t_submit, res.batch))
            # only OK results enter the cache: PARTIAL top-k is whatever
            # the budget allowed (serving it to a later identical query
            # would silently repeat the degradation), FAILED has no data
            if cache_on and res.status == STATUS_OK and q is not None:
                self._cache_guarded(
                    self.cache.put, q, self.cfg.k, res.ids, res.dists,
                    footprint, nprobe=res.nprobe,
                    recall_estimate=res.recall_estimate, gen=gen)
        if self.obs is not None and done_events:
            # batched post-loop recording: one registry and one tracer
            # acquisition per collect pass, not per completed query
            self.obs.metrics.update(
                observations={"serving.latency_s": done_lat})
            self.obs.tracer.close_many(done_events)

    def result(self, qid: int) -> Optional[QueryResult]:
        """The query's result, or None while it is still in flight."""
        with self._lock:
            note_guarded(self, "results")
            return self.results.get(qid)

    # -- writes (barriers) --------------------------------------------

    def submit_insert(self, x: np.ndarray, ids: np.ndarray) -> None:
        with self._engine_lock:
            self._drain_engine()
            if self.durability is not None:
                # write-ahead, in engine-lock (= admission) order: if the
                # append crashes, the op was never applied — recovery
                # lands on the prefix before it
                self.durability.log_insert(x, ids)
            self.index.insert(x, ids)
            if self.cfg.record_admissions:
                with self._lock:
                    self._admission_log.append(
                        ("insert", np.array(x, copy=True),
                         np.array(ids, copy=True)))
            self._after_write()

    def submit_delete(self, ids: np.ndarray) -> int:
        with self._engine_lock:
            self._drain_engine()
            if self.durability is not None:
                self.durability.log_delete(ids)
            removed = self.index.delete(ids)
            if self.cfg.record_admissions:
                with self._lock:
                    self._admission_log.append(
                        ("delete", np.array(ids, copy=True)))
            self._after_write()
            return removed

    def _after_write(self) -> None:
        with self._lock:
            self.write_ops += 1
            self._invalidate_cache_locked()
        self.maintenance.note_op()
        self.maybe_maintain()
        # cadence checkpoint (callers hold the engine lock; never under
        # the admission lock — this is disk I/O).  A post-maintenance
        # forced checkpoint just above resets the cadence, so at most
        # one checkpoint runs per write
        if self.durability is not None and self.durability.checkpoint_due():
            self.durability.checkpoint()

    def _invalidate_cache_locked(self) -> None:
        # callers hold self._lock (propagated seed); serializing the
        # version check with admission-side cache probes is the point
        if self.cache is None:
            self._cache_version = self.index.version
            return
        delta = self.index.journal.delta_since(self._cache_version)
        if delta is None or delta.structural:
            self.cache.clear()
        elif delta.dirty:
            self.cache.invalidate_partitions(delta.dirty)
        self._cache_version = self.index.version

    def admission_log(self) -> List[tuple]:
        """Copy of the recorded admission order (engine-lock total
        order); requires ``cfg.record_admissions``."""
        with self._lock:
            return list(self._admission_log)

    def maybe_maintain(self, force: bool = False
                       ) -> Optional[MaintenanceReport]:
        """Run a maintenance pass if a drift trigger fired (or forced).
        In-flight work is drained first (maintenance is a barrier);
        maintenance mutations then invalidate the cache through the same
        journal path as writes."""
        with self._engine_lock:
            with self._lock:
                if self._maintaining:
                    return None
                self._maintaining = True
            try:
                if not force and self.maintenance.due() is None:
                    return None
                self._drain_engine()
                ver_before = self.index.version
                ckpt = checkpoint_index(self.index)
                try:
                    rep = self.maintenance.run_if_due(force=force)
                except Exception as e:
                    # self-healing: a maintenance crash mid-recluster
                    # rolls the index (levels, id map, journal version)
                    # back to the pre-pass checkpoint, so snapshots,
                    # planner caches and the result cache stay coherent.
                    # Trigger state was not rebaselined, so the next
                    # drift check retries the pass.
                    restore_index(self.index, ckpt)
                    with self._lock:
                        self.maintenance_failures += 1
                    logger.warning("maintenance pass crashed (%r): "
                                   "rolled back, will retry on next "
                                   "trigger", e)
                    return None
                if rep is not None:
                    if self.obs is not None:
                        # maintenance-decision audit record: which
                        # trigger fired and what the pass changed
                        hist = self.maintenance.snapshot()["history"]
                        reason = (hist[-1].get("reason", "forced")
                                  if hist else "forced")
                        reg = self.obs.metrics
                        reg.inc(f"maintenance.trigger.{reason}")
                        reg.inc("maintenance.splits", int(rep.splits))
                        reg.inc("maintenance.merges", int(rep.merges))
                        self.obs.tracer.audit("maintenance", {
                            "t": self._clock(), "reason": reason,
                            "splits": int(rep.splits),
                            "merges": int(rep.merges),
                            "cost_before": float(rep.cost_before),
                            "cost_after": float(rep.cost_after)})
                    with self._lock:
                        self._invalidate_cache_locked()
                    if self.durability is not None \
                            and self.index.version != ver_before:
                        # maintenance effects are NOT replayable from the
                        # WAL (they depend on served access statistics
                        # the log does not carry), so a committed pass is
                        # made durable immediately, before serving
                        # resumes.  A crash before this checkpoint's
                        # rename loses the pass — the same rollback
                        # semantics as an in-process maintenance crash;
                        # consistent, because no write follows it yet.
                        self.durability.log_maintenance(
                            f"splits={rep.splits},merges={rep.merges},"
                            f"level_added={rep.level_added},"
                            f"level_removed={rep.level_removed}")
                        self.durability.checkpoint(force=True)
                return rep
            finally:
                with self._lock:
                    self._maintaining = False

    # -- telemetry -----------------------------------------------------

    def stats(self) -> dict:
        """Deep-copied, per-component lock-consistent snapshot.  Takes
        the admission and component locks (never the engine lock, which
        may be mid-scan) — each component's counters are internally
        consistent; cross-component skew is bounded by what completed
        between the snapshots."""
        sch = self.scheduler.snapshot()
        maint = self.maintenance.snapshot()
        cache = self.cache.counters() if self.cache is not None else None
        with self._lock:
            out = {
                "queries_submitted": self.queries_submitted,
                "queries_completed": len(self.results),
                "queue_depth": len(self._queue),
                "cache_hits": self.cache_hits,
                "write_ops": self.write_ops,
                "queries_shed": self.shed_queries,
                "status_counts": dict(self._status_counts),
                "cache_errors": self.cache_errors,
                "cache_disabled": self._cache_disabled,
                "ticker_errors": self.ticker_errors,
                "ticker_restarts": self.ticker_restarts,
                "ticker_wedged": self.ticker_wedged,
                "maintenance_failures": self.maintenance_failures,
                "governor": {
                    "steps": self._govern_steps,
                    "degrades": self._govern_degrades,
                    "restores": self._govern_restores,
                },
            }
        out["cache_entries"] = cache["entries"] if cache else 0
        out["cache_invalidated"] = cache["invalidated"] if cache else 0
        out["cache_stale_puts"] = cache["stale_puts"] if cache else 0
        planned = sch["partitions_planned"]
        out.update({
            "rounds_run": sch["rounds_run"],
            "admitted_batches": sch["admitted_batches"],
            "in_flight": sch["in_flight"],
            "partitions_streamed": sch["partitions_streamed"],
            "partitions_planned": planned,
            "riding_savings": round(
                1.0 - sch["partitions_streamed"] / planned, 4)
            if planned else 0.0,
            "vectors_streamed": sch["vectors_streamed"],
            "comparisons": sch["comparisons"],
            "partials": sch["partials"],
            "failures": sch["failures"],
            "failed_batches": sch["failed_batches"],
            "scan_faults": sch["scan_faults"],
            "scan_retries_used": sch["scan_retries_used"],
            "effective_target": sch["effective_target"],
            "probe_frac": sch["probe_frac"],
            "maintenance_runs": maint["runs"],
            "maintenance_reasons": maint["reasons"],
        })
        # journal overflow surfaces the silent data-loss window: past the
        # trim floor, delta consumers (snapshot caches, incremental
        # checkpoints) fall back to full rebuilds (GIL-atomic scalars;
        # no lock needed)
        out["journal_overflowed"] = self.index.journal.overflowed
        out["journal_overflow_count"] = self.index.journal.overflow_count
        out["durability"] = (self.durability.stats()
                             if self.durability is not None else None)
        return out

    def metrics_snapshot(self) -> dict:
        """Unified exposition: one flat dict of every counter the stack
        exposes, under stable dotted names (docs/observability.md pins
        them; tests/test_observability.py carries the golden set).
        Merges the federated ``stats()`` components (``serving.*``,
        ``serving.status.*``, ``serving.governor.*``, ``maintenance.*``,
        ``durability.*``), fault-injection arrival/trip counts
        (``faults.*``), the sanitizer's compile/concurrency bridge
        (``sanitize.*``), and — when ``cfg.metrics`` is on — the live
        registry (histograms flattened to ``<name>.p50`` etc.) plus
        tracer counters (``trace.*``).  Values are numbers only:
        booleans become 0/1, lists/strings/None are dropped.  Renders
        to Prometheus text via ``repro.obs.to_prometheus``."""
        flat: dict = {}

        def put(prefix, mapping):
            for key, v in mapping.items():
                name = f"{prefix}.{key}"
                if isinstance(v, dict):
                    put(name, v)
                elif isinstance(v, bool):
                    flat[name] = int(v)
                elif isinstance(v, (int, float)):
                    flat[name] = v

        st = self.stats()
        durability = st.pop("durability", None)
        st.pop("maintenance_reasons", None)     # re-counted below
        put("serving", {k: v for k, v in st.items()
                        if k not in ("status_counts", "governor",
                                     "maintenance_runs")})
        put("serving.status", st.get("status_counts", {}))
        put("serving.governor", st.get("governor", {}))
        maint = self.maintenance.snapshot()
        flat["maintenance.runs"] = maint["runs"]
        flat["maintenance.ops_since"] = maint["ops_since"]
        for reason in maint["reasons"]:
            key = f"maintenance.trigger.{reason}"
            flat[key] = flat.get(key, 0) + 1
        if durability:
            put("durability", durability)
        if self._faults is not None:
            put("faults", self._faults.counters())
        put("sanitize", observability_counters())
        if self.obs is not None:
            self.scheduler.flush_obs()  # don't lag in-flight rounds
            put("trace", self.obs.tracer.counters())
            flat.update(self.obs.metrics.snapshot())
        return flat
