"""Hyperspherical-cap geometry for APS recall estimation (paper §5).

Given query ``q``, radius ``rho`` (distance to the current k-th nearest
neighbor) and candidate partition centroids, APS approximates each non-nearest
partition as the half-space beyond the perpendicular bisector between the
nearest centroid ``c0`` and that partition's centroid ``ci``.  The fraction of
the query hypersphere's volume beyond the bisector is a hyperspherical cap
whose volume has a closed form via the regularized incomplete beta function
(Li 2010):

    cap_frac(h) = 1/2 * I_{1-(h/rho)^2}((d+1)/2, 1/2)        for 0 <= h <= rho

where ``h`` is the distance from the sphere center to the cutting hyperplane.
For h < 0 (center beyond the plane) the fraction is ``1 - cap_frac(-h)``.

Per the paper's performance optimization, ``I_x(a, 1/2)`` is precomputed on a
1024-point grid at index-build time and linearly interpolated per query.

Inner-product (MIPS) support: we use the standard MIPS -> L2 reduction on the
*centroid geometry*:  x -> [x, sqrt(M^2 - ||x||^2)], q -> [q, 0] (M = max
centroid norm).  Nearest-centroid order under L2 in the augmented space equals
inner-product order, and the k-th best score s_k maps to a radius
rho^2 = ||q||^2 + M^2 - 2 s_k, so the same cap machinery applies unchanged.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

_TABLE_POINTS = 1024


@functools.lru_cache(maxsize=64)
def betainc_table(dim: int, n_points: int = _TABLE_POINTS) -> np.ndarray:
    """Precomputed I_x((dim+1)/2, 1/2) over x in [0, 1] (paper §5 opt. #1)."""
    xs = np.linspace(0.0, 1.0, n_points, dtype=np.float64)
    a = (dim + 1) / 2.0
    vals = jax.scipy.special.betainc(a, 0.5, jnp.asarray(xs))
    return np.asarray(vals, dtype=np.float32)


def exact_beta_fn(dim: int):
    """Exact (non-precomputed) regularized-incomplete-beta evaluator for the
    APS-RP ablation (paper Table 2).  One jitted vector evaluation per recall
    recompute — the honest cost of skipping the table precomputation."""
    a = (dim + 1) / 2.0
    f = jax.jit(lambda xs: jax.scipy.special.betainc(a, 0.5, xs))

    def beta(x: np.ndarray) -> np.ndarray:
        return np.asarray(f(jnp.asarray(x, dtype=jnp.float32)),
                          dtype=np.float64)

    return beta


def cap_fraction_exact(h_over_rho: Array, dim: int) -> Array:
    """Exact cap volume fraction; h_over_rho in [-1, 1], clipped outside."""
    t = jnp.clip(h_over_rho, -1.0, 1.0)
    x = jnp.clip(1.0 - t * t, 0.0, 1.0)
    a = (dim + 1) / 2.0
    half = 0.5 * jax.scipy.special.betainc(a, 0.5, x)
    return jnp.where(t >= 0, half, 1.0 - half)


def cap_fraction(h_over_rho: Array, table: Array) -> Array:
    """Table-interpolated cap fraction (the fast path used per query)."""
    t = jnp.clip(h_over_rho, -1.0, 1.0)
    x = jnp.clip(1.0 - t * t, 0.0, 1.0)
    n = table.shape[0]
    pos = x * (n - 1)
    lo = jnp.clip(jnp.floor(pos).astype(jnp.int32), 0, n - 2)
    frac = pos - lo.astype(pos.dtype)
    val = table[lo] * (1.0 - frac) + table[lo + 1] * frac
    half = 0.5 * val
    return jnp.where(t >= 0, half, 1.0 - half)


def bisector_margins(d0_sq: Array, di_sq: Array, cc_dist: Array) -> Array:
    """Distance from the query to the perpendicular bisector between the
    nearest centroid c0 and each candidate centroid ci.

    d0_sq: ||q - c0||^2 (scalar), di_sq: ||q - ci||^2 (M,),
    cc_dist: ||ci - c0|| (M,).  h_i >= 0 whenever c0 is truly nearest.
    """
    return (di_sq - d0_sq) / (2.0 * jnp.maximum(cc_dist, 1e-20))


def partition_probabilities(v: Array, valid: Array) -> tuple[Array, Array]:
    """Paper Eqs. (8)-(9): normalize cap volumes over the M-1 non-nearest
    candidates, p0 = prod(1 - v_j), remainder split proportionally.

    v: raw cap fractions (M,) for non-nearest candidates (entries where
    ``valid`` is False are ignored).  Returns (p0 scalar, p_i (M,)).
    """
    v = jnp.where(valid, v, 0.0)
    total = jnp.sum(v)
    vn = jnp.where(total > 0, v / jnp.maximum(total, 1e-20), 0.0)
    # log-space product for stability with many small terms
    log1m = jnp.where(valid, jnp.log1p(-jnp.clip(vn, 0.0, 1.0 - 1e-7)), 0.0)
    p0 = jnp.exp(jnp.sum(log1m))
    p0 = jnp.where(total > 0, p0, 1.0)
    p = (1.0 - p0) * vn
    return p0, p


@dataclass(frozen=True)
class MipsGeometry:
    """Augmentation constants for inner-product metric (see module doc)."""
    max_norm_sq: float

    def rho_sq(self, q_norm_sq: Array, kth_score: Array) -> Array:
        return jnp.maximum(q_norm_sq + self.max_norm_sq - 2.0 * kth_score,
                           0.0)


def augment_for_mips(x: np.ndarray, max_norm_sq: float | None = None
                     ) -> tuple[np.ndarray, float]:
    """Append sqrt(M^2 - ||x||^2) column; returns (augmented, M^2)."""
    n2 = np.sum(x.astype(np.float64) ** 2, axis=-1)
    if max_norm_sq is None:
        max_norm_sq = float(np.max(n2)) if len(n2) else 1.0
    extra = np.sqrt(np.maximum(max_norm_sq - n2, 0.0))
    return (np.concatenate([x, extra[:, None]], axis=-1).astype(x.dtype),
            max_norm_sq)
