"""Quake's query-latency cost model (paper §4.1).

    C = sum_l sum_j  A_lj * lambda(s_lj)

``lambda(s)`` is the latency of scanning a partition of ``s`` vectors.  The
paper measures it by offline profiling and notes it is non-linear in ``s``
because of top-k selection overhead.  We provide both:

* an analytic default  lambda(s) = c_f + c_lin*s + c_sel*s*log2(s)   (ns),
  whose shape matches the profile (linear memory term + selection term), and
* ``profile()`` which times the actual jitted scan on this machine for a grid
  of sizes and least-squares-fits the coefficients — the paper's offline
  profiling step.

All cost math is in nanoseconds and plain numpy: the maintenance loop is a
host-side control plane, not a jitted data path.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field, replace

import numpy as np


@dataclass(frozen=True)
class LatencyModel:
    """lambda(s): scan latency (ns) for a partition of s vectors.

    Defaults approximate a d~100 scan at DRAM bandwidth plus a top-k
    selection term; ``profile()`` replaces them with measured values.  The
    paper's own example profile (λ(500)=1200µs vs λ(250)=550µs) is strongly
    superlinear — the selection term carries that."""
    c_fixed: float = 200.0       # per-partition dispatch overhead
    c_lin: float = 1.5           # per-vector memory/FMA term (ns/vector)
    c_sel: float = 0.25          # selection term coefficient (ns/vector/log2)
    dim: int = 0                 # informational: profiled dimensionality

    def __call__(self, s) -> np.ndarray:
        s = np.asarray(s, dtype=np.float64)
        logs = np.log2(np.maximum(s, 2.0))
        lat = self.c_fixed + self.c_lin * s + self.c_sel * s * logs
        return np.where(s > 0, lat, 0.0)

    def scaled(self, factor: float) -> "LatencyModel":
        return replace(self, c_fixed=self.c_fixed * factor,
                       c_lin=self.c_lin * factor, c_sel=self.c_sel * factor)

    def predict_scan_ns(self, sizes) -> float:
        """Predicted wall time (ns) of one scan over partitions of the
        given sizes: Eq. (2) with A=1 per scanned partition.  This is the
        prediction the calibration tracker (repro.obs) compares against
        observed scan wall time — its rolling error is the drift signal."""
        s = np.asarray(sizes, dtype=np.float64)
        if s.size == 0:
            return 0.0
        return float(np.sum(self(s)))


def fit_latency_model(sizes: np.ndarray, lats_ns: np.ndarray,
                      dim: int = 0) -> LatencyModel:
    """Least-squares fit of (c_fixed, c_lin, c_sel) to measured latencies."""
    s = np.asarray(sizes, dtype=np.float64)
    y = np.asarray(lats_ns, dtype=np.float64)
    A = np.stack([np.ones_like(s), s, s * np.log2(np.maximum(s, 2.0))], 1)
    coef, *_ = np.linalg.lstsq(A, y, rcond=None)
    coef = np.maximum(coef, 0.0)  # physical non-negativity
    return LatencyModel(float(coef[0]), float(coef[1]), float(coef[2]), dim)


def profile(dim: int, k: int = 100,
            sizes=(64, 256, 1024, 4096, 16384),
            repeats: int = 5, seed: int = 0) -> LatencyModel:
    """Offline profiling of the real scan path (paper §4.1 'measured through
    offline profiling').  Times the jitted scan_topk on this host.

    Warm-up is compile-counted, not guessed: each size re-runs the scan
    until a call triggers zero new XLA compilations
    (``sanitize.warm_until_stable``), so the timed loop deterministically
    measures the steady state — a single untracked warm call can leave
    lazily-reached shapes compiling inside the timed region and skew the
    fitted coefficients."""
    import jax.numpy as jnp

    from ..kernels import ops
    from .. import sanitize

    rng = np.random.default_rng(seed)
    lats = []
    q = jnp.asarray(rng.normal(size=(1, dim)), jnp.float32)
    for s in sizes:
        x = jnp.asarray(rng.normal(size=(s, dim)), jnp.float32)
        kk = min(k, s)
        sanitize.warm_until_stable(
            lambda: ops.scan_topk(q, x, kk,
                                  impl="jnp")[0].block_until_ready())
        t0 = time.perf_counter()
        for _ in range(repeats):
            ops.scan_topk(q, x, kk, impl="jnp")[0].block_until_ready()
        lats.append((time.perf_counter() - t0) / repeats * 1e9)
    return fit_latency_model(np.asarray(sizes), np.asarray(lats), dim)


@dataclass
class PartitionStats:
    """Per-level tracking of sizes + access frequencies over the sliding
    window W (paper Stage 0).  ``hits`` counts queries that scanned each
    partition; ``window`` counts queries seen since the last reset."""
    hits: np.ndarray = field(default_factory=lambda: np.zeros(0))
    window: int = 0

    def ensure(self, n: int) -> None:
        if len(self.hits) < n:
            self.hits = np.concatenate(
                [self.hits, np.zeros(n - len(self.hits))])

    def record(self, scanned: np.ndarray) -> None:
        self.hits[scanned] += 1
        self.window += 1

    def record_batch(self, parts: np.ndarray, counts: np.ndarray,
                     n_queries: int) -> None:
        """Batched Stage-0 update from a packed multi-query scan:
        ``counts[i]`` queries scanned partition ``parts[i]`` and the scan
        served ``n_queries`` queries in total.  Equivalent to ``record``
        called once per query with that query's scanned set — this is how
        the serving runtime feeds served-batch access frequencies back
        into the maintenance cost model, which the batched executor path
        otherwise bypasses."""
        self.hits[parts] += np.asarray(counts, dtype=np.float64)
        self.window += int(n_queries)

    def boost(self, parts: np.ndarray, freq: float) -> None:
        """Bump partitions' access *frequency* by ``freq`` (converted to
        window-scaled hits, so ``access_freq`` moves by ``freq`` at the
        current window).  The maintenance merge path uses this to credit
        receiver partitions with the merged partition's traffic for later
        estimates in the same round."""
        self.hits[parts] += freq * max(self.window, 1)

    def access_freq(self, n: int, default: float = 0.0) -> np.ndarray:
        """A_lj in [0,1]; ``default`` is used before any query arrives."""
        self.ensure(n)
        if self.window == 0:
            return np.full(n, default)
        return self.hits[:n] / self.window

    def reset(self) -> None:
        self.hits[:] = 0
        self.window = 0

    # --- structural edits (keep stats aligned with partition ids) ---
    def split(self, j: int, alpha: float) -> None:
        """Partition j split into (j, new_last): children inherit alpha * A."""
        h = self.hits[j] * alpha
        self.hits[j] = h
        self.hits = np.append(self.hits, h)

    def remove(self, j: int) -> None:
        """Partition j deleted; swap-remove to match index storage layout."""
        self.hits[j] = self.hits[-1]
        self.hits = self.hits[:-1]


def total_cost(lam: LatencyModel, sizes_per_level, freqs_per_level) -> float:
    """Paper Eq. (2): C = sum_l sum_j A_lj * lambda(s_lj)  (ns/query)."""
    c = 0.0
    for sizes, freqs in zip(sizes_per_level, freqs_per_level):
        c += float(np.sum(np.asarray(freqs) * lam(np.asarray(sizes))))
    return c


def split_delta_estimate(lam: LatencyModel, n_l: int, size: float,
                         freq: float, alpha: float) -> float:
    """Paper Eq. (6): Delta'Split = DeltaO+ - A*lam(s) + 2*alpha*A*lam(s/2)."""
    d_over = lam(n_l + 1) - lam(n_l)  # extra centroid at the parent scan
    return float(d_over - freq * lam(size) + 2 * alpha * freq * lam(size / 2))


def split_delta_verify(lam: LatencyModel, n_l: int, size_before: float,
                       freq: float, size_l: float, size_r: float,
                       alpha: float) -> float:
    """Paper Eq. (4) with measured child sizes but Stage-1 frequency
    assumptions (A_child = alpha * A_parent)."""
    d_over = lam(n_l + 1) - lam(n_l)
    return float(d_over - freq * lam(size_before)
                 + alpha * freq * (lam(size_l) + lam(size_r)))


def merge_delta_estimate(lam: LatencyModel, n_l: int, size: float,
                         freq: float, recv_sizes: np.ndarray,
                         recv_freqs: np.ndarray) -> float:
    """Merge (delete) estimate with uniform redistribution over receivers
    (paper Eq. (5) with ds_m = s/|R|, dA_m = A/|R|)."""
    r = max(len(recv_sizes), 1)
    d_over = lam(n_l - 1) - lam(n_l)
    ds, da = size / r, freq / r
    bump = np.sum((recv_freqs + da) * lam(recv_sizes + ds)
                  - recv_freqs * lam(recv_sizes))
    return float(d_over - freq * lam(size) + bump)


def merge_delta_verify(lam: LatencyModel, n_l: int, size: float, freq: float,
                       recv_sizes_before: np.ndarray,
                       recv_sizes_after: np.ndarray,
                       recv_freqs: np.ndarray, recv_extra_freq: np.ndarray,
                       ) -> float:
    """Paper Eq. (5) with the *actual* receiver set and measured sizes."""
    d_over = lam(n_l - 1) - lam(n_l)
    bump = np.sum((recv_freqs + recv_extra_freq) * lam(recv_sizes_after)
                  - recv_freqs * lam(recv_sizes_before))
    return float(d_over - freq * lam(size) + bump)
