"""Quake's multi-level partitioned index (paper §3) — the dynamic engine.

The partition directory (ragged inverted lists, id maps, statistics) is a
host-side control plane; scans run through a pluggable backend:

  * ``numpy``  — BLAS matmul + argpartition; the fast path for the online
                 engine on CPU (per-partition scans are tiny and jax dispatch
                 overhead would dominate).
  * ``jnp``    — jitted oracle path (XLA), used for validation.
  * ``pallas`` — the fused TPU kernel in interpret mode on CPU / Mosaic on
                 TPU.

Level structure: level 0 partitions hold data vectors; level ``l`` partitions
group the *centroids* of level ``l-1`` (paper: "These centroids can be
further partitioned ... to create additional levels").  Search walks
top-down, running APS at every level; the items returned by APS at level
``l>0`` are exactly the candidate partitions (plus centroid distances) for
level ``l-1``.

The compiled, mesh-sharded engine (``distributed.ShardedIndexView``) consumes
snapshots of this structure.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from ..kernels import ops
from . import aps as aps_mod
from . import geometry, kmeans
from .cost_model import LatencyModel, PartitionStats
from .journal import MutationJournal

__all__ = ["QuakeConfig", "QuakeIndex", "Level", "SearchResult"]


@dataclass
class QuakeConfig:
    metric: str = "l2"                  # "l2" | "ip"
    f_m: float = 0.05                   # base-level initial candidate fraction
    f_m_upper: float = 0.25             # candidate fraction at upper levels
    min_candidates: int = 32            # floor on the APS candidate set; f_M
                                        # percentages are tuned for >=1000
                                        # partitions (paper SIFT1M) and starve
                                        # the estimator on small indexes
    recall_target: float = 0.9
    recall_target_upper: float = 0.99   # fixed for higher levels (paper §5.1)
    tau_rho: float = 0.01               # radius recompute threshold
    scan_impl: str = "numpy"            # numpy | jnp | pallas
    enable_aps: bool = True             # ablation: static nprobe when False
    fixed_nprobe: int = 16              # used when enable_aps=False
    # --- maintenance (paper §8.1 defaults, rescaled to our lambda) ---
    # The paper sets tau = 250ns against a profile where lambda(500) =
    # 1.2e6 ns (their Xeon, d>=100, k=100 scans).  Our profiled lambda(500)
    # is ~2e3 ns (numpy, d=32), so the equivalent threshold is
    # 250 * (2e3 / 1.2e6) ~= 0.4 ns.  We default to 2 ns — the same
    # "tiny fraction of one partition-scan" semantics as the paper.
    tau_ns: float = 2.0                 # commit threshold tau
    alpha: float = 0.9                  # split access-scaling
    refine_radius: int = 50             # r_f
    refine_iters: int = 1
    min_partition_size: int = 32        # merge candidates below this size
    default_access_freq: float = 0.05   # prior before stats exist
    # --- levels ---
    level_add_threshold: int = 4096     # add top level when N_top exceeds
    level_remove_threshold: int = 64    # drop top level when N_top below
    # --- snapshot refresh (COW delta path, paper §8.2) ---
    snapshot_headroom: float = 1.5      # slack factor on snapshot slot
                                        # capacity so insert deltas rarely
                                        # force a full reshape/rebuild
    snapshot_max_dirty_frac: float = 0.5  # delta-refresh only while dirty
                                        # partitions <= frac * P; beyond
                                        # that a full rebuild is cheaper
    # --- batched executor (multiquery.py) ---
    union_cap: Optional[int] = None     # max distinct partitions one batch
                                        # scans (frequency-ranked truncation
                                        # under read skew; None = unbounded)
                                        # — the batched-executor mirror of
                                        # EngineConfig.union_cap
    planner_radius_ttl: int = 64        # batches a calibrated APS radius may
                                        # be reused for before the planner
                                        # cache recalibrates (bounds query-
                                        # distribution-drift staleness; see
                                        # multiquery.PlannerCache)
    seed: int = 0


@dataclass
class Level:
    """One level of the hierarchy.  Exactly one of (vectors, children) is
    populated: level 0 stores data vectors, upper levels store child
    partition-index lists."""
    centroids: np.ndarray                       # (P, d) float32
    vectors: Optional[List[np.ndarray]] = None  # level 0: (s_j, d) each
    ids: Optional[List[np.ndarray]] = None      # level 0: external ids
    sqnorms: Optional[List[np.ndarray]] = None  # level 0: cached ||x||^2
    children: Optional[List[np.ndarray]] = None  # level>0: level-1 part idx
    parent: Optional[np.ndarray] = None         # partition idx at level+1
    stats: PartitionStats = field(default_factory=PartitionStats)

    @property
    def num_partitions(self) -> int:
        return self.centroids.shape[0]

    def partition_size(self, j: int) -> int:
        if self.vectors is not None:
            return len(self.vectors[j])
        return len(self.children[j])

    def sizes(self) -> np.ndarray:
        n = self.num_partitions
        if self.vectors is not None:
            return np.asarray([len(self.vectors[j]) for j in range(n)])
        return np.asarray([len(self.children[j]) for j in range(n)])

    def sizes_of(self, idx) -> np.ndarray:
        """Sizes of just the given partitions — the per-round
        calibration hook uses this instead of ``sizes()[idx]`` so the
        cost scales with the scanned set, not the level width."""
        store = self.vectors if self.vectors is not None else self.children
        return np.asarray([len(store[j]) for j in np.asarray(idx).ravel()])


@dataclass
class SearchResult:
    ids: np.ndarray
    dists: np.ndarray          # minimization convention (-score for ip)
    nprobe: Dict[int, int]     # partitions scanned per level
    recall_estimate: float
    vectors_scanned: int = 0

    @property
    def scores(self) -> np.ndarray:
        return -self.dists


class QuakeIndex:
    """Dynamic multi-level partitioned ANN index with APS search."""

    def __init__(self, dim: int, config: Optional[QuakeConfig] = None):
        self.dim = dim
        self.config = config or QuakeConfig()
        self.levels: List[Level] = []
        self.id_map: Dict[int, int] = {}     # external id -> level-0 partition
        self.journal = MutationJournal()     # per-partition dirty sets +
                                             # structural flags; snapshot
                                             # caches consume deltas from it
        self._rng = np.random.default_rng(self.config.seed)
        self.geometry_dim = dim if self.config.metric == "l2" else dim + 1
        self._beta_table = geometry.betainc_table(self.geometry_dim)
        self._max_norm_sq = 1e-12           # MIPS augmentation constant M^2
        self._aug_extra: List[Optional[np.ndarray]] = []  # per level cache
        self.maintenance_log: List[dict] = []

    # ------------------------------------------------------------------
    # Construction
    # ------------------------------------------------------------------

    @classmethod
    def build(cls, x: np.ndarray, ids: Optional[np.ndarray] = None,
              num_partitions: Optional[int] = None,
              level_sizes: Optional[Sequence[int]] = None,
              config: Optional[QuakeConfig] = None,
              kmeans_iters: int = 10) -> "QuakeIndex":
        """Build from data.  ``num_partitions`` defaults to sqrt(n) (paper
        §7.2).  ``level_sizes`` optionally gives partition counts for upper
        levels, e.g. (40000, 500) for the two-level SIFT10M setup."""
        x = np.ascontiguousarray(x, dtype=np.float32)
        n, dim = x.shape
        idx = cls(dim, config)
        if ids is None:
            ids = np.arange(n, dtype=np.int64)
        if level_sizes is None:
            p0 = num_partitions or max(1, int(round(math.sqrt(n))))
            level_sizes = (p0,)
        idx._max_norm_sq = max(float(np.max(np.sum(
            x.astype(np.float64) ** 2, axis=1), initial=0.0)), 1e-12)

        # level 0
        p0 = min(level_sizes[0], n)
        cents, assign = kmeans.kmeans(x, p0, iters=kmeans_iters,
                                      seed=idx.config.seed)
        vectors, vids = [], []
        for j in range(p0):
            sel = assign == j
            vectors.append(np.ascontiguousarray(x[sel]))
            vids.append(ids[sel].astype(np.int64))
        lvl0 = Level(centroids=cents, vectors=vectors, ids=vids,
                     sqnorms=[np.sum(v.astype(np.float64) ** 2, axis=1)
                              .astype(np.float32) for v in vectors])
        idx.levels.append(lvl0)
        for ext, j in zip(ids, assign):
            idx.id_map[int(ext)] = int(j)

        # upper levels: cluster the centroids of the level below
        for p_l in level_sizes[1:]:
            idx._add_level_from(p_l, kmeans_iters)
        idx._aug_extra = [None] * len(idx.levels)
        return idx

    def _add_level_from(self, p_l: int, iters: int = 10) -> None:
        below = self.levels[-1]
        cents_below = below.centroids
        p_l = min(p_l, cents_below.shape[0])
        cents, assign = kmeans.kmeans(cents_below, p_l, iters=iters,
                                      seed=self.config.seed + len(self.levels))
        children = [np.where(assign == j)[0].astype(np.int64)
                    for j in range(p_l)]
        below.parent = assign.astype(np.int64)
        self.levels.append(Level(centroids=cents, children=children))
        self._aug_extra = [None] * len(self.levels)
        # upper levels are not part of the base-level snapshot: bump the
        # clock (planning structures changed) but dirty nothing
        self.journal.record(reason="level_add")

    def remove_top_level(self) -> None:
        """Drop the top level (paper §4.2.1 Remove Level): the level below is
        then scanned fully at query time."""
        assert len(self.levels) >= 2
        self.levels.pop()
        self.levels[-1].parent = None
        self._aug_extra = [None] * len(self.levels)
        self.journal.record(reason="level_remove")

    # ------------------------------------------------------------------
    # Metric helpers
    # ------------------------------------------------------------------

    def _centroid_geo_dists(self, q: np.ndarray, level_idx: int,
                            part_ids: np.ndarray
                            ) -> Tuple[np.ndarray, np.ndarray]:
        """Returns (geometry-space squared distances (M,), scan-order keys).

        For L2 both are ||q-c||^2.  For IP the geometry distances live in the
        MIPS-augmented space (||q||^2 + M^2 - 2 s) while the scan keys are
        -s; both orders coincide.
        """
        c = self.levels[level_idx].centroids[part_ids]
        if self.config.metric == "l2":
            d = (np.sum(q * q) + np.sum(c * c, axis=1) - 2.0 * (c @ q))
            d = np.maximum(d, 0.0)
            return d, d
        s = c @ q
        geo = np.maximum(np.sum(q * q) + self._max_norm_sq - 2.0 * s, 0.0)
        return geo, -s

    def _centroid_cc_dists(self, level_idx: int, part_ids: np.ndarray,
                           nearest_local: int) -> np.ndarray:
        """||c_i - c_0|| in geometry space (augmented for IP)."""
        c = self.levels[level_idx].centroids[part_ids].astype(np.float64)
        c0 = c[nearest_local]
        d2 = np.sum((c - c0) ** 2, axis=1)
        if self.config.metric == "ip":
            e = self._augment_extra(level_idx)[part_ids]
            d2 = d2 + (e - e[nearest_local]) ** 2
        return np.sqrt(np.maximum(d2, 0.0))

    def _augment_extra(self, level_idx: int) -> np.ndarray:
        cached = self._aug_extra[level_idx]
        c = self.levels[level_idx].centroids
        if cached is None or len(cached) != c.shape[0]:
            n2 = np.sum(c.astype(np.float64) ** 2, axis=1)
            m2 = self._max_norm_sq
            cached = np.sqrt(np.maximum(m2 - n2, 0.0))
            self._aug_extra[level_idx] = cached
        return cached

    def _rho_sq_from_item_dist(self, q_norm_sq: float):
        if self.config.metric == "l2":
            return lambda kth: max(kth, 0.0)
        m2 = self._max_norm_sq
        # item dist = -score  ->  rho^2 = ||q||^2 + M^2 - 2 score
        return lambda kth: max(q_norm_sq + m2 + 2.0 * kth, 0.0)

    # ------------------------------------------------------------------
    # Scanning backends
    # ------------------------------------------------------------------

    def _scan_vectors(self, q: np.ndarray, x: np.ndarray,
                      x2: Optional[np.ndarray], item_ids: np.ndarray,
                      k: int) -> Tuple[np.ndarray, np.ndarray]:
        """Scan a ragged buffer; returns (dists, ids) of its top-min(k, s)."""
        impl = self.config.scan_impl
        if impl == "numpy":
            if self.config.metric == "l2":
                if x2 is None:
                    x2 = np.sum(x * x, axis=1)
                d = x2 - 2.0 * (x @ q) + np.sum(q * q)
            else:
                d = -(x @ q)
            if len(d) > k:
                sel = np.argpartition(d, k - 1)[:k]
                return d[sel], item_ids[sel]
            return d, item_ids
        dd, ii = ops.scan_topk(jnp.asarray(q[None, :]), jnp.asarray(x),
                               min(k, x.shape[0]), metric=self.config.metric,
                               impl=impl)
        dd = np.asarray(dd[0])
        ii = np.asarray(ii[0])
        keep = ii >= 0
        return dd[keep], item_ids[ii[keep]]

    def _scan_level_partition(self, q: np.ndarray, level_idx: int, j: int,
                              k: int) -> Tuple[np.ndarray, np.ndarray]:
        level = self.levels[level_idx]
        if level.vectors is not None:
            return self._scan_vectors(q, level.vectors[j], level.sqnorms[j],
                                      level.ids[j], k)
        child = level.children[j]
        below = self.levels[level_idx - 1]
        return self._scan_vectors(q, below.centroids[child], None, child, k)

    # ------------------------------------------------------------------
    # Search (paper §5)
    # ------------------------------------------------------------------

    def search(self, q: np.ndarray, k: int,
               recall_target: Optional[float] = None,
               nprobe: Optional[int] = None,
               record_stats: bool = True) -> SearchResult:
        """APS search.  ``nprobe`` (or config.enable_aps=False) switches to a
        fixed number of probes at the base level — the static baseline."""
        q = np.ascontiguousarray(q, dtype=np.float32).reshape(-1)
        cfg = self.config
        target = recall_target if recall_target is not None else \
            cfg.recall_target
        q_norm_sq = float(np.sum(q.astype(np.float64) ** 2))
        rho_fn = self._rho_sq_from_item_dist(q_norm_sq)

        L = len(self.levels)
        top = self.levels[-1]
        cand = np.arange(top.num_partitions)
        cand_geo, _ = self._centroid_geo_dists(q, L - 1, cand)
        nprobe_per_level: Dict[int, int] = {}
        vectors_scanned = 0
        recall_est = 1.0

        for l in range(L - 1, -1, -1):
            level = self.levels[l]
            if l == 0:
                k_l, tgt, f_m = k, target, cfg.f_m
            else:
                below_n = self.levels[l - 1].num_partitions
                f_m_below = cfg.f_m if l - 1 == 0 else cfg.f_m_upper
                # APS at level l must find, with high recall, the candidates
                # the level below will consider:
                k_l = max(k, int(math.ceil(f_m_below * below_n)))
                tgt, f_m = cfg.recall_target_upper, cfg.f_m_upper
            n_consider = max(int(math.ceil(f_m * level.num_partitions)),
                             cfg.min_candidates)
            use_aps = cfg.enable_aps and nprobe is None
            if not use_aps and l == 0:
                # fixed-nprobe baselines scan exactly nprobe partitions; the
                # f_M candidate restriction only applies to APS
                n_consider = max(n_consider,
                                 nprobe if nprobe is not None
                                 else cfg.fixed_nprobe)
            n_consider = min(max(n_consider, 1), len(cand))
            # restrict to the n_consider nearest candidates
            if n_consider < len(cand):
                sel = np.argpartition(cand_geo, n_consider - 1)[:n_consider]
                cand, cand_geo = cand[sel], cand_geo[sel]
            nearest_local = int(np.argmin(cand_geo))
            cc = self._centroid_cc_dists(l, cand, nearest_local)

            sizes = level.sizes()
            scanned_count = [0]

            def scan_fn(m: int, _l=l, _cand=cand, _k=k_l, _sc=scanned_count):
                _sc[0] += int(sizes[_cand[m]])
                return self._scan_level_partition(q, _l, int(_cand[m]), _k)

            if use_aps:
                res = aps_mod.aps_scan(
                    cand_centroid_dists_sq=cand_geo,
                    cand_cc_dists=cc,
                    scan_partition=scan_fn,
                    item_dist_to_rho_sq=rho_fn,
                    k=k_l, recall_target=tgt, table=self._beta_table,
                    tau_rho=cfg.tau_rho)
            else:
                n_fixed = nprobe if nprobe is not None else cfg.fixed_nprobe
                res = self._fixed_scan(cand_geo, scan_fn, k_l,
                                       min(n_fixed, len(cand)))
            vectors_scanned += scanned_count[0]
            nprobe_per_level[l] = res.nprobe
            if record_stats:
                level.stats.ensure(level.num_partitions)
                level.stats.record(cand[res.scanned])
            if l == 0:
                recall_est = res.recall_estimate
                keep = res.ids >= 0
                return SearchResult(ids=res.ids[keep],
                                    dists=res.dists[keep],
                                    nprobe=nprobe_per_level,
                                    recall_estimate=recall_est,
                                    vectors_scanned=vectors_scanned)
            # descend: top items are level l-1 partition ids
            keep = res.ids >= 0
            cand = res.ids[keep].astype(np.int64)
            # geometry distances for the next level from the item distances
            if cfg.metric == "l2":
                cand_geo = np.maximum(res.dists[keep], 0.0)
            else:
                cand_geo = np.maximum(
                    q_norm_sq + self._max_norm_sq + 2.0 * res.dists[keep],
                    0.0)
            if len(cand) == 0:  # degenerate hierarchy: fall back to full set
                cand = np.arange(self.levels[l - 1].num_partitions)
                cand_geo, _ = self._centroid_geo_dists(q, l - 1, cand)
        raise AssertionError("unreachable")

    def search_batch(self, queries: np.ndarray, k: int,
                     nprobe: Optional[int] = None,
                     recall_target: Optional[float] = None,
                     impl: str = "auto",
                     union_cap: Optional[int] = None,
                     storage_dtype: Optional[str] = None,
                     rounds: Optional[int] = None):
        """Batched multi-query search (paper §7.4) through the
        device-resident executor: per-query probe sets are planned by the
        vectorized batch planner (APS-driven when ``nprobe`` is None) and
        executed as multi-round early-exit probe rounds (paper
        Algorithm 2): each round scans one packed partition union via the
        ``scan_topk_indexed`` kernel and queries whose refined recall
        estimate clears the target drop out of later rounds.  ``rounds``
        bounds the round budget (1 = single fixed-plan scan; also the
        shape nprobe-pinned searches always take).  ``union_cap`` bounds
        each scanned union (frequency-ranked, for read-skewed batches);
        ``storage_dtype`` ("f32"/"bf16"/"int8") selects the snapshot
        storage format.  Single-query search is the B=1 case of the same
        path.  Returns ``multiquery.BatchResult`` — APS-planned results
        carry per-query ``recall_estimate``s like the per-query path.
        """
        from .multiquery import batch_search  # late: avoid import cycle
        return batch_search(self, queries, k, nprobe=nprobe,
                            recall_target=recall_target, impl=impl,
                            union_cap=union_cap,
                            storage_dtype=storage_dtype, rounds=rounds)

    @staticmethod
    def _fixed_scan(cand_geo, scan_fn, k, n_fixed) -> aps_mod.APSResult:
        order = np.argsort(cand_geo, kind="stable")[:max(n_fixed, 1)]
        heap = aps_mod.TopK(k)
        for m in order:
            d, i = scan_fn(int(m))
            heap.update(d, i)
        return aps_mod.APSResult(ids=heap.ids, dists=heap.dists,
                                 scanned=np.asarray(order),
                                 nprobe=len(order), recall_estimate=np.nan)

    # ------------------------------------------------------------------
    # Updates (paper §3 Adaptive Incremental Maintenance - data path)
    # ------------------------------------------------------------------

    def _route_to_base(self, x: np.ndarray) -> np.ndarray:
        """Vectorized top-down routing to the nearest base partition."""
        L = len(self.levels)
        n = x.shape[0]
        if L == 1:
            return kmeans.assign(x, self.levels[0].centroids)
        # nearest top partition for all points
        cur = kmeans.assign(x, self.levels[-1].centroids).astype(np.int64)
        for l in range(L - 1, 0, -1):
            level = self.levels[l]
            below = self.levels[l - 1]
            nxt = np.empty(n, dtype=np.int64)
            for p in np.unique(cur):
                sel = np.where(cur == p)[0]
                child = level.children[p]
                if len(child) == 0:  # empty group: fall back to global
                    nxt[sel] = kmeans.assign(x[sel], below.centroids)
                    continue
                sub = kmeans.assign(x[sel], below.centroids[child])
                nxt[sel] = child[sub]
            cur = nxt
        return cur

    def insert(self, x: np.ndarray, ids: np.ndarray) -> None:
        x = np.ascontiguousarray(x, dtype=np.float32)
        ids = np.asarray(ids, dtype=np.int64)
        if x.shape[0] == 0:
            return
        self._max_norm_sq = max(self._max_norm_sq, float(np.max(
            np.sum(x.astype(np.float64) ** 2, axis=1), initial=0.0)))
        self._aug_extra = [None] * len(self.levels)
        assign = self._route_to_base(x)
        self.journal.record(dirty=np.unique(assign), reason="insert")
        lvl0 = self.levels[0]
        for j in np.unique(assign):
            sel = assign == j
            lvl0.vectors[j] = np.concatenate([lvl0.vectors[j], x[sel]])
            lvl0.ids[j] = np.concatenate([lvl0.ids[j], ids[sel]])
            lvl0.sqnorms[j] = np.concatenate(
                [lvl0.sqnorms[j],
                 np.sum(x[sel].astype(np.float64) ** 2, 1).astype(np.float32)])
        for ext, j in zip(ids, assign):
            self.id_map[int(ext)] = int(j)

    def delete(self, ids: np.ndarray) -> int:
        """Delete by external id with immediate compaction; returns #removed."""
        ids = np.asarray(ids, dtype=np.int64)
        by_part: Dict[int, list] = {}
        removed = 0
        for ext in ids:
            j = self.id_map.pop(int(ext), None)
            if j is not None:
                by_part.setdefault(j, []).append(int(ext))
        if by_part:
            self.journal.record(dirty=by_part.keys(), reason="delete")
        lvl0 = self.levels[0]
        for j, exts in by_part.items():
            mask = ~np.isin(lvl0.ids[j], np.asarray(exts, dtype=np.int64))
            removed += int((~mask).sum())
            lvl0.vectors[j] = np.ascontiguousarray(lvl0.vectors[j][mask])
            lvl0.ids[j] = lvl0.ids[j][mask]
            lvl0.sqnorms[j] = lvl0.sqnorms[j][mask]
        return removed

    # ------------------------------------------------------------------
    # Durability (core/durability.py, docs/durability.md)
    # ------------------------------------------------------------------

    def save(self, root: str) -> dict:
        """Durable save: a full atomic checkpoint under ``root`` (next
        free generation, CRC-manifested, fingerprinted).  Returns the
        manifest.  ``root`` may already hold a WAL + older generations —
        the new checkpoint supersedes them."""
        from .durability import save_index  # late: avoid import cycle
        return save_index(self, root)

    @classmethod
    def load(cls, root: str) -> "QuakeIndex":
        """Load the newest *valid* checkpoint under ``root``, replay any
        WAL suffix, and verify the stored fingerprint — the full
        recovery path (``durability.recover_index``).  Raises
        ``durability.RecoveryError`` when nothing valid survives."""
        from .durability import recover_index  # late: avoid import cycle
        idx, _report = recover_index(root)
        return idx

    # ------------------------------------------------------------------
    # Introspection
    # ------------------------------------------------------------------

    @property
    def version(self) -> int:
        """Monotonic mutation clock, backed by the journal.  Snapshot
        caches fingerprint on it and ask ``journal.delta_since(v)`` for the
        cheap (dirty-partition patch) refresh path."""
        return self.journal.version

    @property
    def num_vectors(self) -> int:
        return sum(len(v) for v in self.levels[0].vectors)

    @property
    def num_partitions(self) -> int:
        return self.levels[0].num_partitions

    def check_invariants(self) -> None:
        """Structural invariants used by property tests."""
        lvl0 = self.levels[0]
        assert len(lvl0.vectors) == len(lvl0.ids) == lvl0.num_partitions
        for v, i, s in zip(lvl0.vectors, lvl0.ids, lvl0.sqnorms):
            assert v.shape[0] == i.shape[0] == s.shape[0]
            assert v.shape[1] == self.dim
        all_ids = np.concatenate([i for i in lvl0.ids]) if \
            lvl0.num_partitions else np.zeros(0)
        assert len(all_ids) == len(set(all_ids.tolist())) == len(self.id_map)
        for ext, j in self.id_map.items():
            assert 0 <= j < lvl0.num_partitions
        # parent/child coherence
        for l in range(1, len(self.levels)):
            level = self.levels[l]
            below = self.levels[l - 1]
            below_n = below.num_partitions
            seen = np.concatenate([c for c in level.children]) if \
                level.num_partitions else np.zeros(0, dtype=np.int64)
            assert len(seen) == below_n, (len(seen), below_n)
            assert len(np.unique(seen)) == below_n
            if len(seen):
                assert seen.min() >= 0 and seen.max() < below_n
            assert below.parent is not None and len(below.parent) == below_n
            for pj in range(level.num_partitions):
                assert (below.parent[level.children[pj]] == pj).all()
