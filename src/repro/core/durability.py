"""Crash-consistent durability: write-ahead log, atomic checkpoints,
fingerprint-verified recovery (docs/durability.md).

Everything the serving stack promised so far — replay determinism from
the engine-lock admission order (PR 7), rollback-consistent maintenance
(PR 8) — was memory-resident: a process crash lost every write since
startup.  This module makes the same guarantees hold across crashes:

  * :class:`WriteAheadLog` — framed, CRC32-checksummed, length-prefixed
    records appended *before* the index mutation they describe, in the
    engine-lock total order, so single-threaded replay of the log suffix
    reproduces the live index byte-identically (the PR 7 admission-log
    property, now on disk).  ``fsync`` policy is configurable:
    ``always`` (fsync per append), ``batch`` (every ``batch_ops``
    appends), ``off`` (never — the OS page cache decides what survives).
  * checkpoints — per-partition blobs plus a JSON manifest, written into
    a temp directory, fsynced file-by-file, then atomically
    ``os.rename``d into place.  Generation-numbered; journal-dirty-set
    driven, so partitions untouched since the previous generation are
    hard-linked instead of rewritten.
  * :func:`recover_index` — selects the newest checkpoint that passes
    CRC + manifest validation, replays the WAL suffix past the
    checkpoint's LSN, truncates any torn tail to the last valid prefix,
    and verifies the result against the manifest's stored
    ``index_state_fingerprint``.

Crash model (exercised by the fault sites in ``repro.faults`` and the
kill-point harness in tests/test_durability.py): a crash may tear the
last WAL frame at any byte, flip bits in an unsynced frame, lose any
suffix of unsynced bytes, or abort a checkpoint before its rename.  In
every case recovery lands on a *prefix* of the admitted write sequence
and proves it with the fingerprint.

Thread-safety: none of the classes here carry their own lock.  Every
mutating call happens under ``ServingRuntime._engine_lock`` — the WAL
append must be ordered by the same total order as the index mutation it
logs, so a separate lock could only create ordering bugs, not fix them.
Counter attributes are GIL-atomic scalars; ``stats()`` may read them
from any thread.
"""
from __future__ import annotations

import dataclasses
import io
import json
import os
import shutil
import struct
import zlib
from dataclasses import dataclass
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from ..faults import InjectedFault, index_state_fingerprint
from .index import Level, QuakeConfig, QuakeIndex

__all__ = [
    "WAL_MAGIC", "WAL_NAME", "REC_INSERT", "REC_DELETE", "REC_MAINT",
    "REC_FP", "WalRecord", "read_wal", "WriteAheadLog",
    "write_checkpoint", "validate_checkpoint", "select_checkpoint",
    "list_checkpoints", "load_checkpoint", "save_index", "recover_index",
    "RecoveryError", "RecoveryReport", "DurabilityManager",
]

# --------------------------------------------------------------------------
# WAL record format (docs/durability.md)
#
#   file   = magic, frame*
#   frame  = crc32:u32le, body
#   body   = payload_len:u32le, lsn:u64le, rtype:u8, payload
#
# crc32 covers the whole body (header included), so a bit flip in the
# length or LSN fields fails the checksum just like one in the payload.
# LSNs are strictly increasing within a file; the reader stops at the
# first frame that is short, checksum-invalid, or LSN-regressive, and
# reports the byte offset of the last valid prefix.
# --------------------------------------------------------------------------

WAL_MAGIC = b"QWAL1\n\x00\x00"
WAL_NAME = "wal.log"
_CRC = struct.Struct("<I")
_BODY_HDR = struct.Struct("<IQB")        # payload_len, lsn, rtype

REC_INSERT = 1     # payload: npy(x float32 (n,d)), npy(ids int64 (n,))
REC_DELETE = 2     # payload: npy(ids int64 (n,))
REC_MAINT = 3      # payload: utf-8 reason; informational on replay
REC_FP = 4         # payload: raw sha256 index_state_fingerprint digest
REC_NAMES = {REC_INSERT: "insert", REC_DELETE: "delete",
             REC_MAINT: "maint", REC_FP: "fingerprint"}


def _pack_arrays(*arrays: np.ndarray) -> bytes:
    """Concatenated ``.npy`` serialization (pickle-free) of ``arrays``."""
    buf = io.BytesIO()
    for a in arrays:
        np.save(buf, np.ascontiguousarray(a), allow_pickle=False)
    return buf.getvalue()


def _unpack_arrays(data: bytes, n: int) -> List[np.ndarray]:
    buf = io.BytesIO(data)
    return [np.load(buf, allow_pickle=False) for _ in range(n)]


@dataclass(frozen=True)
class WalRecord:
    lsn: int
    rtype: int
    payload: bytes


def read_wal(path: str) -> Tuple[List[WalRecord], int, str]:
    """Parse a WAL file, stopping at the first invalid frame.

    Returns ``(records, valid_bytes, reason)`` where ``valid_bytes`` is
    the length of the longest valid prefix (magic included) and
    ``reason`` is why parsing stopped: ``clean`` (whole file valid),
    ``missing``, ``short_magic`` / ``bad_magic``, ``torn_header`` /
    ``torn_payload`` (frame cut short), ``crc_mismatch``, or
    ``lsn_regression``.  Never raises on corrupt input — a torn or
    bit-flipped tail is the expected post-crash state, and the valid
    prefix is the recovery contract.
    """
    try:
        with open(path, "rb") as f:
            data = f.read()
    except OSError:
        return [], 0, "missing"
    if len(data) < len(WAL_MAGIC):
        return [], 0, "short_magic"
    if data[: len(WAL_MAGIC)] != WAL_MAGIC:
        return [], 0, "bad_magic"
    off = len(WAL_MAGIC)
    records: List[WalRecord] = []
    reason = "clean"
    head = _CRC.size + _BODY_HDR.size
    while off < len(data):
        if off + head > len(data):
            reason = "torn_header"
            break
        (crc,) = _CRC.unpack_from(data, off)
        plen, lsn, rtype = _BODY_HDR.unpack_from(data, off + _CRC.size)
        end = off + head + plen
        if end > len(data):
            reason = "torn_payload"
            break
        body = data[off + _CRC.size:end]
        if zlib.crc32(body) & 0xFFFFFFFF != crc:
            reason = "crc_mismatch"
            break
        if records and lsn <= records[-1].lsn:
            reason = "lsn_regression"
            break
        records.append(WalRecord(lsn=lsn, rtype=rtype,
                                 payload=data[off + head:end]))
        off = end
    return records, off if reason != "clean" else len(data), reason


class WriteAheadLog:
    """Append-only framed log with a configurable fsync policy.

    Opening an existing file truncates any invalid tail back to the
    last valid prefix (the crash-recovery contract) and continues LSNs
    after the last surviving record.  ``faults`` wires in the
    ``wal_torn_write`` / ``wal_corrupt_record`` / ``fsync_dropped``
    sites; the first two model a crash mid-append (they leave a
    damaged tail and raise :class:`InjectedFault`), after which the log
    refuses further appends — the process is considered dead and must
    recover.
    """

    def __init__(self, path: str, fsync: str = "batch", batch_ops: int = 32,
                 faults=None):
        if fsync not in ("always", "batch", "off"):
            raise ValueError(f"fsync policy must be always|batch|off, "
                             f"got {fsync!r}")
        self.path = path
        self.policy = fsync
        self.batch_ops = max(int(batch_ops), 1)
        self.faults = faults
        self.appends = 0
        self.bytes_written = 0
        self.fsyncs = 0
        self.fsyncs_dropped = 0
        self.torn_writes = 0
        self.corrupt_writes = 0
        self._pending_ops = 0
        self._poisoned = False

        records, valid, reason = read_wal(path)
        self.open_reason = reason
        self.last_lsn = records[-1].lsn if records else 0
        self.truncated_on_open = 0
        if reason not in ("clean", "missing"):
            size = os.path.getsize(path)
            self.truncated_on_open = size - valid
            with open(path, "r+b") as f:
                f.truncate(valid)
                f.flush()
                os.fsync(f.fileno())
        self._f = open(path, "ab")
        pre = self._f.tell()
        if pre == 0:
            self._f.write(WAL_MAGIC)
            self._f.flush()
        # bytes that existed before this process are already on disk
        self._synced_size = pre
        self._fsync()

    # -- durability --------------------------------------------------------

    def _fsync(self) -> bool:
        """fsync the log; returns False when the ``fsync_dropped`` fault
        eats it (the policy *believes* it synced — the insidious failure
        mode — so the batch counter resets either way, but
        ``_synced_size`` only advances on a real fsync)."""
        self._pending_ops = 0
        if self.faults is not None and self.faults.fire("fsync_dropped"):
            self.fsyncs_dropped += 1
            return False
        self._f.flush()
        os.fsync(self._f.fileno())
        self.fsyncs += 1
        self._synced_size = self._f.tell()
        return True

    def sync(self) -> bool:
        """Force an fsync regardless of policy."""
        return self._fsync()

    @property
    def unsynced_bytes(self) -> int:
        return (self._f.tell() - self._synced_size) if self._f else 0

    # -- appending ---------------------------------------------------------

    def append(self, rtype: int, payload: bytes) -> int:
        """Frame and append one record; returns its LSN.  Must be called
        under the engine lock, *before* the index mutation it logs."""
        if self._f is None:
            raise RuntimeError("WAL is closed")
        if self._poisoned:
            raise RuntimeError(
                "WAL tail damaged by an injected crash; the process is "
                "considered dead — recover before appending")
        lsn = self.last_lsn + 1
        body = _BODY_HDR.pack(len(payload), lsn, rtype) + payload
        frame = _CRC.pack(zlib.crc32(body) & 0xFFFFFFFF) + body
        if self.faults is not None and self.faults.fire("wal_torn_write"):
            # crash mid-write: a strict prefix of the frame reaches the
            # file (cut point derived from the frame, so deterministic)
            cut = 1 + zlib.crc32(b"torn" + body) % (len(frame) - 1)
            self._f.write(frame[:cut])
            self._f.flush()
            self.torn_writes += 1
            self._poisoned = True
            raise InjectedFault("wal_torn_write", self.torn_writes)
        if self.faults is not None and self.faults.fire("wal_corrupt_record"):
            # bit flip in the written frame (bad sector / firmware bug)
            k = zlib.crc32(b"flip" + body) % len(frame)
            bad = bytearray(frame)
            bad[k] ^= 0x40
            self._f.write(bytes(bad))
            self._f.flush()
            self.corrupt_writes += 1
            self._poisoned = True
            raise InjectedFault("wal_corrupt_record", self.corrupt_writes)
        self._f.write(frame)
        self._f.flush()
        self.last_lsn = lsn
        self.appends += 1
        self.bytes_written += len(frame)
        self._pending_ops += 1
        if self.policy == "always" or (self.policy == "batch"
                                       and self._pending_ops >= self.batch_ops):
            self._fsync()
        return lsn

    # -- lifecycle ---------------------------------------------------------

    def close(self) -> None:
        if self._f is None:
            return
        if not self._poisoned:
            self._fsync()
        self._f.close()
        self._f = None

    def simulate_crash(self, keep_unsynced: int = 0) -> int:
        """Model a process/OS crash: everything fsynced survives, plus at
        most ``keep_unsynced`` bytes of the flushed-but-unsynced tail
        (the page cache wrote back a prefix before power cut).  Truncates
        the file accordingly, closes the log, and returns the surviving
        size."""
        if self._f is None:
            raise RuntimeError("WAL is closed")
        size = self._f.tell()
        self._f.close()
        self._f = None
        keep = min(max(int(keep_unsynced), 0),
                   max(size - self._synced_size, 0))
        survive = self._synced_size + keep
        # quakecheck: allow-nosync(simulating post-crash disk state)
        with open(self.path, "r+b") as f:
            f.truncate(survive)
        return survive


# --------------------------------------------------------------------------
# Checkpoints
#
#   <root>/ckpt-<generation:08d>/
#       p<j:06d>-g<gen:08d>.bin    npy(ids int64), npy(vectors f32)
#       meta-g<gen:08d>.bin        per-level centroids + children arrays
#       MANIFEST.json              generation, wal_lsn, fingerprint, CRCs
#
# Written into a ".tmp-" sibling, every file fsynced, the directory
# fsynced, then atomically renamed into place: a crash at any point
# leaves either no ckpt-N directory or a complete one.  Partition blobs
# keep the generation that wrote them in their *name*, so an unchanged
# partition is hard-linked from the previous generation (same inode,
# zero bytes rewritten) and the manifest's name list still identifies it.
# --------------------------------------------------------------------------

CKPT_FORMAT = 1
CKPT_PREFIX = "ckpt-"
TMP_PREFIX = ".tmp-"
MANIFEST_NAME = "MANIFEST.json"


def _fsync_dir(path: str) -> None:
    """fsync a directory so its entries (renames included) are durable."""
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _write_blob(path: str, data: bytes) -> int:
    """Write + flush + fsync one file; returns its CRC32."""
    with open(path, "wb") as f:
        f.write(data)
        f.flush()
        os.fsync(f.fileno())
    return zlib.crc32(data) & 0xFFFFFFFF


def _part_blob(lvl0: Level, j: int) -> bytes:
    return _pack_arrays(np.asarray(lvl0.ids[j], dtype=np.int64),
                        np.ascontiguousarray(lvl0.vectors[j],
                                             dtype=np.float32))


def write_checkpoint(index: QuakeIndex, root: str, generation: int,
                     wal_lsn: int, write_op_count: int,
                     dirty: Optional[Set[int]] = None,
                     prev_manifest: Optional[dict] = None,
                     prev_dir: Optional[str] = None,
                     faults=None) -> Tuple[dict, dict]:
    """Write generation ``generation`` atomically; returns
    ``(manifest, stats)``.

    ``dirty`` (with ``prev_manifest``/``prev_dir``) enables the
    incremental path: base-level partitions *not* in ``dirty`` are
    hard-linked from the previous generation instead of rewritten (CRC
    carried over from the previous manifest).  Pass ``dirty=None`` for a
    full rewrite — required after structural maintenance or when the
    journal can no longer say what changed.
    """
    gendir = os.path.join(root, f"{CKPT_PREFIX}{generation:08d}")
    tmpdir = os.path.join(root, f"{TMP_PREFIX}{CKPT_PREFIX}{generation:08d}")
    if os.path.exists(gendir):
        raise ValueError(f"checkpoint generation {generation} already exists")
    if os.path.exists(tmpdir):               # debris from an aborted attempt
        shutil.rmtree(tmpdir)
    os.makedirs(tmpdir)
    stats = {"partitions_written": 0, "partitions_linked": 0,
             "link_fallback_copies": 0}

    lvl0 = index.levels[0]
    files: Dict[str, dict] = {}
    part_names: List[str] = []
    prev_files = (prev_manifest or {}).get("files", {})
    prev_parts = (prev_manifest or {}).get("partitions", [])
    for j in range(lvl0.num_partitions):
        if (dirty is not None and j not in dirty and j < len(prev_parts)
                and prev_dir is not None
                and prev_parts[j] in prev_files):
            name = prev_parts[j]
            try:
                os.link(os.path.join(prev_dir, name),
                        os.path.join(tmpdir, name))
                files[name] = dict(prev_files[name])
                part_names.append(name)
                stats["partitions_linked"] += 1
                continue
            except OSError:
                # filesystem without hard links (or the previous blob is
                # gone): fall through and rewrite the partition
                stats["link_fallback_copies"] += 1
        name = f"p{j:06d}-g{generation:08d}.bin"
        data = _part_blob(lvl0, j)
        files[name] = {"crc": _write_blob(os.path.join(tmpdir, name), data),
                       "size": len(data)}
        part_names.append(name)
        stats["partitions_written"] += 1

    # meta blob: per-level centroids; upper-level children arrays are
    # serialized *verbatim* — their in-array order feeds kmeans.assign
    # tie-breaks in _route_to_base, so reordering would break replay
    # determinism.  parent arrays are their exact inverse and are
    # rebuilt at load.
    meta_arrays: List[np.ndarray] = []
    levels_desc: List[dict] = []
    for level in index.levels:
        meta_arrays.append(np.ascontiguousarray(level.centroids,
                                                dtype=np.float32))
        levels_desc.append({"partitions": int(level.num_partitions),
                            "children": level.children is not None})
        if level.children is not None:
            for child in level.children:
                meta_arrays.append(np.asarray(child, dtype=np.int64))
    meta_name = f"meta-g{generation:08d}.bin"
    data = _pack_arrays(*meta_arrays)
    files[meta_name] = {"crc": _write_blob(os.path.join(tmpdir, meta_name),
                                           data),
                        "size": len(data)}

    manifest = {
        "format": CKPT_FORMAT,
        "generation": int(generation),
        "wal_lsn": int(wal_lsn),
        "write_op_count": int(write_op_count),
        "fingerprint": index_state_fingerprint(index).hex(),
        "dim": int(index.dim),
        "max_norm_sq": float(index._max_norm_sq),
        "config": dataclasses.asdict(index.config),
        "levels": levels_desc,
        "meta": meta_name,
        "partitions": part_names,
        "files": files,
    }
    _write_blob(os.path.join(tmpdir, MANIFEST_NAME),
                json.dumps(manifest, sort_keys=True, indent=1).encode())
    _fsync_dir(tmpdir)
    if faults is not None:
        faults.check("ckpt_crash_before_rename")
    os.rename(tmpdir, gendir)
    _fsync_dir(root)
    return manifest, stats


def validate_checkpoint(gendir: str) -> Optional[dict]:
    """Parse and verify one checkpoint directory; returns the manifest on
    success, ``None`` on any damage (unreadable / unparseable manifest,
    missing blob, size or CRC mismatch) — an invalid candidate is
    *rejected*, never raised on, so recovery can fall back to an older
    generation."""
    try:
        with open(os.path.join(gendir, MANIFEST_NAME), "rb") as f:
            manifest = json.loads(f.read().decode("utf-8"))
    except (OSError, ValueError):
        return None
    if not isinstance(manifest, dict) or manifest.get("format") != CKPT_FORMAT:
        return None
    try:
        files = manifest["files"]
        names = list(manifest["partitions"]) + [manifest["meta"]]
        for name in dict.fromkeys(names):
            info = files[name]
            with open(os.path.join(gendir, name), "rb") as f:
                data = f.read()
            if (len(data) != int(info["size"])
                    or zlib.crc32(data) & 0xFFFFFFFF != int(info["crc"])):
                return None
    except (OSError, KeyError, TypeError, ValueError):
        return None
    return manifest


def list_checkpoints(root: str) -> List[Tuple[int, str]]:
    """``(generation, path)`` for every ckpt-* directory, ascending.
    Tmp debris and non-numeric names are ignored."""
    out: List[Tuple[int, str]] = []
    try:
        entries = os.listdir(root)
    except OSError:
        return []
    for name in entries:
        if not name.startswith(CKPT_PREFIX):
            continue
        try:
            gen = int(name[len(CKPT_PREFIX):])
        except ValueError:
            continue
        path = os.path.join(root, name)
        if os.path.isdir(path):
            out.append((gen, path))
    return sorted(out)


def select_checkpoint(root: str) -> Tuple[Optional[str], Optional[dict]]:
    """Newest checkpoint that passes :func:`validate_checkpoint`."""
    for _gen, path in reversed(list_checkpoints(root)):
        manifest = validate_checkpoint(path)
        if manifest is not None:
            return path, manifest
    return None, None


def load_checkpoint(gendir: str, manifest: dict) -> QuakeIndex:
    """Materialize a :class:`QuakeIndex` from a validated checkpoint.
    Derived state is rebuilt deterministically: sqnorms from the stored
    f32 vectors (the same formula insert/build use), id_map from the id
    lists, parent arrays from the verbatim children arrays.  The journal
    and partition stats start fresh — they are serving-session state,
    not logical index state (the fingerprint ignores them)."""
    cfg = QuakeConfig(**manifest["config"])
    idx = QuakeIndex(int(manifest["dim"]), cfg)
    n_meta = sum(1 + (d["partitions"] if d["children"] else 0)
                 for d in manifest["levels"])
    with open(os.path.join(gendir, manifest["meta"]), "rb") as f:
        meta = _unpack_arrays(f.read(), n_meta)
    pos = 0
    levels: List[Level] = []
    for d in manifest["levels"]:
        cents = np.ascontiguousarray(meta[pos], dtype=np.float32)
        pos += 1
        if d["children"]:
            children = [np.asarray(meta[pos + j], dtype=np.int64)
                        for j in range(d["partitions"])]
            pos += d["partitions"]
            levels.append(Level(centroids=cents, children=children))
        else:
            levels.append(Level(centroids=cents, vectors=[], ids=[],
                                sqnorms=[]))
    lvl0 = levels[0]
    for name in manifest["partitions"]:
        with open(os.path.join(gendir, name), "rb") as f:
            ids, vecs = _unpack_arrays(f.read(), 2)
        ids = np.asarray(ids, dtype=np.int64)
        vecs = np.ascontiguousarray(vecs, dtype=np.float32)
        lvl0.ids.append(ids)
        lvl0.vectors.append(vecs)
        lvl0.sqnorms.append(np.sum(vecs.astype(np.float64) ** 2, axis=1)
                            .astype(np.float32))
    for l in range(1, len(levels)):
        parent = np.zeros(levels[l - 1].num_partitions, dtype=np.int64)
        for pj, child in enumerate(levels[l].children):
            parent[child] = pj
        levels[l - 1].parent = parent
    idx.levels = levels
    idx._aug_extra = [None] * len(levels)
    idx._max_norm_sq = float(manifest["max_norm_sq"])
    for j, ids in enumerate(lvl0.ids):
        for ext in ids:
            idx.id_map[int(ext)] = j
    return idx


def save_index(index: QuakeIndex, root: str) -> dict:
    """One-shot durable save (``QuakeIndex.save``): a full checkpoint at
    the next free generation, with ``wal_lsn`` set past everything in
    the existing WAL so a subsequent recovery replays nothing on top."""
    os.makedirs(root, exist_ok=True)
    records, _valid, _reason = read_wal(os.path.join(root, WAL_NAME))
    last_lsn = records[-1].lsn if records else 0
    ckpts = list_checkpoints(root)
    next_gen = (ckpts[-1][0] + 1) if ckpts else 1
    _path, prev = select_checkpoint(root)
    if prev is not None:
        last_lsn = max(last_lsn, int(prev["wal_lsn"]))
    manifest, _stats = write_checkpoint(index, root, next_gen,
                                        wal_lsn=last_lsn, write_op_count=0)
    return manifest


# --------------------------------------------------------------------------
# Recovery
# --------------------------------------------------------------------------

class RecoveryError(RuntimeError):
    """No valid checkpoint, or the recovered state failed fingerprint
    verification — damage recovery cannot paper over."""


@dataclass
class RecoveryReport:
    root: str
    generation: int
    ckpt_wal_lsn: int
    wal_last_lsn: int
    wal_reason: str
    wal_truncated_bytes: int
    records_replayed: int
    inserts_replayed: int
    deletes_replayed: int
    fingerprint_checks: int
    write_ops_recovered: int     # cumulative admitted write ops the
                                 # recovered state contains (checkpoint
                                 # count + replayed WAL suffix) — always
                                 # a prefix of the admission order
    fingerprint: str


def recover_index(root: str, verify: bool = True
                  ) -> Tuple[QuakeIndex, RecoveryReport]:
    """The full recovery path (docs/durability.md):

    1. select the newest checkpoint passing CRC + manifest validation
       (damaged generations are skipped, not fatal);
    2. load it and verify ``index_state_fingerprint`` against the
       manifest;
    3. replay the WAL suffix (records with LSN past the checkpoint's),
       verifying any fingerprint records against the replayed state;
    4. truncate the WAL's torn/corrupt tail back to its valid prefix.

    Raises :class:`RecoveryError` when no generation validates or a
    fingerprint check fails.  Torn tails and corrupt records are *not*
    errors — recovery lands on the last valid prefix by design.
    """
    gendir, manifest = select_checkpoint(root)
    if manifest is None:
        raise RecoveryError(f"no valid checkpoint under {root!r}")
    idx = load_checkpoint(gendir, manifest)
    if verify and index_state_fingerprint(idx).hex() != \
            manifest["fingerprint"]:
        raise RecoveryError(
            f"checkpoint {gendir!r} loaded but its fingerprint does not "
            f"match the manifest — refusing to serve corrupt state")

    wal_path = os.path.join(root, WAL_NAME)
    records, valid, reason = read_wal(wal_path)
    truncated = 0
    if reason not in ("clean", "missing"):
        size = os.path.getsize(wal_path)
        truncated = size - valid
        with open(wal_path, "r+b") as f:
            f.truncate(valid)
            f.flush()
            os.fsync(f.fileno())

    ckpt_lsn = int(manifest["wal_lsn"])
    n_rec = n_ins = n_del = n_fp = 0
    write_ops = int(manifest["write_op_count"])
    for rec in records:
        if rec.lsn <= ckpt_lsn:
            continue
        n_rec += 1
        if rec.rtype == REC_INSERT:
            x, ids = _unpack_arrays(rec.payload, 2)
            idx.insert(np.ascontiguousarray(x, dtype=np.float32),
                       np.asarray(ids, dtype=np.int64))
            n_ins += 1
            write_ops += 1
        elif rec.rtype == REC_DELETE:
            (ids,) = _unpack_arrays(rec.payload, 1)
            idx.delete(np.asarray(ids, dtype=np.int64))
            n_del += 1
            write_ops += 1
        elif rec.rtype == REC_FP:
            n_fp += 1
            if verify and index_state_fingerprint(idx) != rec.payload:
                raise RecoveryError(
                    f"WAL fingerprint record at lsn {rec.lsn} does not "
                    f"match the replayed state")
        # REC_MAINT is informational: a committed maintenance pass is
        # made durable by the forced checkpoint that immediately follows
        # it (DurabilityManager protocol); a crash in between loses the
        # pass — the same rollback semantics as an in-process crash.
    report = RecoveryReport(
        root=root, generation=int(manifest["generation"]),
        ckpt_wal_lsn=ckpt_lsn,
        wal_last_lsn=records[-1].lsn if records else 0,
        wal_reason=reason, wal_truncated_bytes=truncated,
        records_replayed=n_rec, inserts_replayed=n_ins,
        deletes_replayed=n_del, fingerprint_checks=n_fp,
        write_ops_recovered=write_ops,
        fingerprint=index_state_fingerprint(idx).hex())
    return idx, report


# --------------------------------------------------------------------------
# DurabilityManager — the piece ServingRuntime owns
# --------------------------------------------------------------------------

class DurabilityManager:
    """WAL + checkpoint store for one live index.

    Protocol (all calls under the runtime's engine lock):

      * ``log_insert`` / ``log_delete`` *before* the index mutation —
        write-ahead, so a crash mid-append loses the op cleanly (it was
        never applied) and the log order equals the admission order.
      * ``log_maintenance`` + ``checkpoint(force=True)`` immediately
        after a committed maintenance pass: maintenance effects depend
        on served access statistics that are not in the WAL, so they
        are made durable by checkpoint, not by replay.  A crash before
        the checkpoint's rename loses the pass — consistent, because no
        write follows it yet.
      * ``checkpoint()`` every ``ckpt_every_ops`` logged write ops,
        incremental via the journal dirty set.

    Attaching writes a fresh full baseline checkpoint of the live index
    (generation ``prev+1``) with ``wal_lsn`` past everything already in
    the WAL: whatever history the directory holds, recovery from the
    baseline reproduces exactly the state that was attached.
    """

    def __init__(self, index: QuakeIndex, root: str, fsync: str = "batch",
                 wal_batch_ops: int = 32,
                 ckpt_every_ops: Optional[int] = 256,
                 keep_checkpoints: int = 2, faults=None):
        os.makedirs(root, exist_ok=True)
        self.index = index
        self.root = root
        self.faults = faults
        self.ckpt_every_ops = ckpt_every_ops
        self.keep_checkpoints = max(int(keep_checkpoints), 1)
        self.write_op_count = 0          # admitted write ops since attach
        self.ops_since_ckpt = 0
        self.checkpoints_written = 0
        self.checkpoint_failures = 0
        self.partitions_written = 0
        self.partitions_linked = 0
        self.link_fallback_copies = 0
        self.generation = 0
        self.last_ckpt_wal_lsn = 0
        self.closed = False
        self._ckpt_journal_version = 0
        self._prev_manifest: Optional[dict] = None
        self._prev_dir: Optional[str] = None
        # fault injection is armed only after attach: the attach baseline
        # models process startup, not a steady-state crash point
        self.wal = WriteAheadLog(os.path.join(root, WAL_NAME), fsync=fsync,
                                 batch_ops=wal_batch_ops, faults=None)
        self._attach()
        self.wal.faults = faults

    def _attach(self) -> None:
        ckpts = list_checkpoints(self.root)
        prev_gen = ckpts[-1][0] if ckpts else 0
        _path, prev = select_checkpoint(self.root)
        base_lsn = self.wal.last_lsn
        if prev is not None:
            # a crash can truncate the WAL below a manifest's LSN; new
            # appends must never reuse LSNs any manifest already covers
            base_lsn = max(base_lsn, int(prev["wal_lsn"]))
        self.wal.last_lsn = base_lsn
        gen = prev_gen + 1
        manifest, stats = write_checkpoint(
            self.index, self.root, gen, wal_lsn=base_lsn, write_op_count=0)
        self._note_checkpoint(gen, manifest, stats)
        self.wal.append(REC_FP, index_state_fingerprint(self.index))
        self._prune()

    # -- logging (write-ahead; call BEFORE the index mutation) -------------

    def log_insert(self, x: np.ndarray, ids: np.ndarray) -> int:
        lsn = self.wal.append(REC_INSERT, _pack_arrays(
            np.ascontiguousarray(x, dtype=np.float32),
            np.asarray(ids, dtype=np.int64)))
        self.write_op_count += 1
        self.ops_since_ckpt += 1
        return lsn

    def log_delete(self, ids: np.ndarray) -> int:
        lsn = self.wal.append(REC_DELETE, _pack_arrays(
            np.asarray(ids, dtype=np.int64)))
        self.write_op_count += 1
        self.ops_since_ckpt += 1
        return lsn

    def log_maintenance(self, reason: str) -> int:
        return self.wal.append(REC_MAINT, reason.encode("utf-8"))

    # -- checkpointing -----------------------------------------------------

    def checkpoint_due(self) -> bool:
        return (self.ckpt_every_ops is not None
                and self.ops_since_ckpt >= self.ckpt_every_ops)

    def checkpoint(self, force: bool = False) -> bool:
        """Write the next generation (incremental when the journal still
        covers the gap since the previous one).  On success the WAL gets
        a fingerprint record, so a recovery that replays past this point
        re-verifies itself.  Returns False when not due."""
        if self.closed:
            raise RuntimeError("DurabilityManager is closed")
        if not force and not self.checkpoint_due():
            return False
        dirty: Optional[Set[int]] = None
        delta = self.index.journal.delta_since(self._ckpt_journal_version)
        if (delta is not None and not delta.structural
                and self._prev_manifest is not None
                and len(self._prev_manifest["partitions"])
                == self.index.levels[0].num_partitions):
            dirty = set(delta.dirty)
        gen = self.generation + 1
        try:
            manifest, stats = write_checkpoint(
                self.index, self.root, gen, wal_lsn=self.wal.last_lsn,
                write_op_count=self.write_op_count, dirty=dirty,
                prev_manifest=self._prev_manifest if dirty is not None
                else None,
                prev_dir=self._prev_dir, faults=self.faults)
        except InjectedFault:
            self.checkpoint_failures += 1
            raise
        self._note_checkpoint(gen, manifest, stats)
        self.wal.append(REC_FP, index_state_fingerprint(self.index))
        self._prune()
        return True

    def _note_checkpoint(self, gen: int, manifest: dict, stats: dict) -> None:
        self.generation = gen
        self._prev_manifest = manifest
        self._prev_dir = os.path.join(self.root, f"{CKPT_PREFIX}{gen:08d}")
        self._ckpt_journal_version = self.index.journal.version
        self.last_ckpt_wal_lsn = int(manifest["wal_lsn"])
        self.ops_since_ckpt = 0
        self.checkpoints_written += 1
        self.partitions_written += stats["partitions_written"]
        self.partitions_linked += stats["partitions_linked"]
        self.link_fallback_copies += stats["link_fallback_copies"]

    def _prune(self) -> None:
        """Drop all but the newest ``keep_checkpoints`` generations.
        Hard-linked blobs stay alive through their inodes, so pruning a
        generation never damages a newer one that links into it."""
        ckpts = list_checkpoints(self.root)
        for _gen, path in ckpts[:-self.keep_checkpoints]:
            shutil.rmtree(path, ignore_errors=True)

    # -- lifecycle / introspection ----------------------------------------

    def close(self) -> None:
        if self.closed:
            return
        self.closed = True
        self.wal.close()

    def simulate_crash(self, keep_unsynced: int = 0) -> int:
        """Kill the process model: close nothing cleanly, truncate the
        WAL to what a real crash would leave (see
        :meth:`WriteAheadLog.simulate_crash`)."""
        self.closed = True
        return self.wal.simulate_crash(keep_unsynced)

    def stats(self) -> dict:
        return {
            "root": self.root,
            "generation": self.generation,
            "write_op_count": self.write_op_count,
            "ops_since_ckpt": self.ops_since_ckpt,
            "last_ckpt_wal_lsn": self.last_ckpt_wal_lsn,
            "checkpoints_written": self.checkpoints_written,
            "checkpoint_failures": self.checkpoint_failures,
            "partitions_written": self.partitions_written,
            "partitions_linked": self.partitions_linked,
            "link_fallback_copies": self.link_fallback_copies,
            "wal_appends": self.wal.appends,
            "wal_last_lsn": self.wal.last_lsn,
            "wal_bytes_written": self.wal.bytes_written,
            "wal_fsyncs": self.wal.fsyncs,
            "wal_fsyncs_dropped": self.wal.fsyncs_dropped,
            "wal_unsynced_bytes": self.wal.unsynced_bytes,
            "wal_truncated_on_open": self.wal.truncated_on_open,
        }
