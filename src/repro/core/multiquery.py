"""Device-resident batched multi-query executor (paper §7.4, policy from
[26]/[34] — the incremental-IVF maintenance line of Mohoney et al.).

Single-query processing scans each needed partition once *per query*; with a
batch we invert the mapping — group queries by the partitions they access and
scan every needed partition exactly **once per batch**, amortizing the
partition read across all queries that probe it.  On TPU this turns B GEMVs
per partition into one ``(B_p, d) x (d, s)`` GEMM — MXU-shaped work.

Architecture (see ``docs/batched_execution.md``):

  1. **Plan**: per-query probe sets, either a fixed ``nprobe`` (the paper's
     Fig. 5 policy) or APS-driven per-query counts.  The APS planner is
     *vectorized*: one batched centroid-distance + top-``n_consider`` pass
     over the whole batch (``ops.scan_topk`` on device, or the equivalent
     host GEMM), the recall estimator run on ``(B, n_consider)`` arrays
     (``aps.estimate_probs_batch``), and the k-NN radius calibrated with a
     single batched sample search — no per-query Python loop.  The
     pre-vectorization loop survives as ``_aps_probe_counts_loop`` (the
     parity oracle and the bench baseline).
  2. **Pack**: the batch's probe sets collapse into one partition union +
     a per-query ``(B, U)`` mask through the device-side
     ``kernels.ops.pack_union`` primitive (frequency-ranked, so a
     ``union_cap`` keeps the hottest partitions under read skew — the
     batched-executor mirror of ``EngineConfig.union_cap``).
  3. **Scan** (device): calls to ``kernels.ops.scan_selected_topk`` —
     the scalar-prefetch ``scan_topk_indexed`` Pallas kernel streams each
     selected partition HBM->VMEM exactly once and folds the running top-k
     in VMEM (interpret mode on CPU CI, Mosaic on TPU; ``impl="jnp"`` is
     the XLA oracle path).  With ``storage_dtype="bf16"``/``"int8"`` the
     cached snapshot holds bf16 vectors / int8 IVF residual codes
     (``quantize_int8_residual``) and the scan streams 2x/4x fewer bytes
     through ``scan_selected_topk``/``scan_selected_topk_q8``.
  4. **Rounds** (Algorithm 2): APS-planned searches chunk the probe
     sequences into geometrically growing rounds (``run_round_loop``):
     each round packs only *live* queries' next probes (plus "union
     rides" — every not-yet-scanned probe landing in the round's union,
     so a partition block streams at most once per batch), folds the
     scan into a device-resident running top-k (``ops.topk_merge``),
     re-estimates per-query recall from the running k-th distance, and
     retires queries that cleared the target.  ``rounds=1`` degenerates
     to the monolithic fixed-plan scan.  The fully-jitted planner
     variant (``planner="fused"``, ``_fused_plan_probes``) runs centroid
     pass + estimator + selection in one jit with zero host round-trips
     in between — the TPU planner path.

Single-query search is the B=1 case of the same executor
(``per_query_search`` below, and ``QuakeIndex.search_batch`` with one row);
the mesh-sharded engine shares the same planner through
``ShardedQuakeEngine.search_batch`` (plan on host, pack+scan per shard).

The executor serves a cached ``IndexSnapshot`` of the dynamic index
(copy-on-write semantics, paper §8.2), kept coherent through the index's
mutation journal: dirty-partition deltas patch only the touched rows on
device; structural changes (split/merge/level, capacity overflow) and int8
snapshots (rows would need requantizing) fall back to a full rebuild.  See
``docs/snapshot_lifecycle.md``.
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass, replace
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops
from ..kernels.ref import MASK_DIST
from . import aps as aps_mod
from .index import QuakeIndex

STORAGE_DTYPES = ("f32", "bf16", "int8")


@dataclass
class BatchResult:
    ids: np.ndarray        # (B, k) external ids, -1 on misses
    dists: np.ndarray      # (B, k) minimization convention, inf on misses
    partitions_scanned: int = 0   # partition blocks streamed (union size,
                                  # summed over rounds on the early-exit path)
    vectors_scanned: int = 0      # vectors streamed from memory: each union
                                  # partition is read once per round it
                                  # appears in
    comparisons: int = 0          # query-vector distance evaluations (the
                                  # per-query-loop equivalent of
                                  # vectors_scanned; ratio = amortization)
    nprobe: Optional[np.ndarray] = None   # (B,) effective probes per query
                                          # (== planned unless union-capped
                                          # or the query exited early)
    recall_estimate: Optional[np.ndarray] = None  # (B,) APS recall estimate
                                          # (planner cutoff estimate on the
                                          # fixed-plan path, refined running
                                          # estimate on the round path; NaN
                                          # where no radius was available;
                                          # None for nprobe-pinned searches)
    rounds: int = 1                       # probe rounds executed
    round_trace: Optional[dict] = None    # early-exit shape: per-round
                                          # live-query counts / vectors /
                                          # partitions / comparisons


@dataclass
class BatchPlan:
    """Output of the host-side batch planner."""
    sel: np.ndarray      # (U_pad,) union partition ids, frequency-ranked
                         # (tail entries duplicate sel[0] for tile-count
                         # padding and carry all-False masks)
    qmask: np.ndarray    # (B, U_pad) bool — query b probes union slot u
    nprobe: np.ndarray   # (B,) effective per-query probe count (probes
                         # surviving the union cap)
    n_real: int          # distinct partitions actually scanned
    planned: Optional[np.ndarray] = None  # (B,) pre-cap planned counts
    anchor: Optional[np.ndarray] = None   # (B,) each query's nearest
                                          # partition (cap-proof probes)
    recall_est: Optional[np.ndarray] = None  # (B,) planner recall estimate
                                          # at the planned cutoff (APS
                                          # planners only; NaN on fallback
                                          # rows with no radius)
    sel_dev: Optional[object] = None      # device residents of sel/qmask
    qmask_dev: Optional[object] = None    # (the executor scans these; the
                                          # host mirrors above are the
                                          # introspection/distribution
                                          # contract)


@dataclass
class RoundPlan:
    """Per-query probe *sequences* plus the estimator state the multi-round
    early-exit executor needs to re-score recall between rounds (Algorithm 2
    semantics for the host path).  All candidate arrays are aligned to the
    scan order: column 0 is the query's nearest partition, later columns
    descend by the planner's scan-probability ranking (an order that is
    invariant under the radius shrinking — cap fractions are monotone in
    the bisector margin for any rho)."""
    seq: np.ndarray         # (B, M) candidate partitions in scan order
    counts: np.ndarray      # (B,) planned probe counts (the fixed-plan
                            # budget; rounds chunk through seq[:, :count])
    geo: np.ndarray         # (B, M) seq-aligned geometry-space sq distances
    cc: np.ndarray          # (B, M) seq-aligned ||c_i - c_0|| distances
    recall_est: np.ndarray  # (B,) planner estimate at the planned cutoff
    seq_dev: Optional[object] = None  # device-resident int32 seq (set by
                            # the fused planner so the round executor
                            # never re-uploads what the device produced)


# ---------------------------------------------------------------------------
# Centroid passes (shared by the fixed-nprobe and APS planners)
# ---------------------------------------------------------------------------

def _centroid_dists(index: QuakeIndex, q: np.ndarray,
                    cent_norms: Optional[np.ndarray] = None) -> np.ndarray:
    """(B, P) level-0 centroid distances in scan-order convention
    (squared L2, or -score for IP — both rank like the geometry dists).
    ``cent_norms`` is the executor-cached ``||c||^2`` (recomputed only on
    snapshot refresh, not per call)."""
    cents = index.levels[0].centroids
    if index.config.metric == "l2":
        if cent_norms is None:
            cent_norms = np.sum(cents * cents, axis=1)
        return (np.sum(q * q, 1)[:, None] + cent_norms[None, :]
                - 2.0 * (q @ cents.T))
    return -(q @ cents.T)


def _centroid_geo_batch(index: QuakeIndex, q: np.ndarray,
                        cent_norms: Optional[np.ndarray] = None
                        ) -> np.ndarray:
    """(B, P) geometry-space squared centroid distances — the batched
    mirror of per-query ``index._centroid_geo_dists`` (MIPS-augmented
    space for IP, so the same cap machinery applies)."""
    if index.config.metric == "l2":
        # same expression as the fixed-path keys; one formula to keep
        # bitwise-consistent with the loop oracle
        return np.maximum(_centroid_dists(index, q, cent_norms), 0.0)
    s = q @ index.levels[0].centroids.T
    return np.maximum(np.sum(q * q, 1)[:, None] + index._max_norm_sq
                      - 2.0 * s, 0.0)


# ---------------------------------------------------------------------------
# Radius calibration
# ---------------------------------------------------------------------------

def _calib_sample(b: int) -> np.ndarray:
    return np.unique(np.linspace(0, b - 1, min(8, b)).astype(int))


def _calibrate_kth_loop(index: QuakeIndex, q: np.ndarray, k: int,
                        target: float) -> float:
    """Legacy calibration: one full host APS search per sample query (the
    pre-vectorization planner's dominant fixed cost — kept as the bench
    baseline)."""
    kths = []
    for s in _calib_sample(q.shape[0]):
        r = index.search(q[s], k, recall_target=target, record_stats=False)
        if len(r.dists):
            kths.append(float(r.dists[min(k, len(r.dists)) - 1]))
    return float(np.median(kths)) if kths else np.inf


_CALIB_NPROBE = 8   # per-sample probes for radius calibration: the kth
                    # distance within the 8 nearest partitions; an
                    # over-estimate of the true kth distance only inflates
                    # the radius, which makes the planner scan *more* —
                    # never less — so the approximation is recall-safe


def _calibrate_kth_batched(index: QuakeIndex, q: np.ndarray, k: int,
                           n_consider: int,
                           cache: Optional[PlannerCache] = None) -> float:
    """Amortized calibration: ONE batched sample search — every sample row
    is scanned against the union of the samples' top-``_CALIB_NPROBE``
    candidate partitions in a single GEMM over the index's resident
    buffers (no per-sample search loop).  Scanning a neighbour sample's
    partitions only tightens the estimate."""
    qs = q[_calib_sample(q.shape[0])]
    p = index.levels[0].num_partitions
    # cached norms are only valid while the cache's fingerprint is
    # current (maintenance refinement moves centroids without changing P)
    norms = None
    if cache is not None and cache._key == cache._fingerprint():
        norms = cache._cent_norms
    cd = _centroid_dists(index, qs, norms)
    n_cal = min(n_consider, _CALIB_NPROBE, p)
    if n_cal < p:
        probes = np.argpartition(cd, n_cal - 1, axis=1)[:, :n_cal]
        union = np.unique(probes)
    else:
        union = np.arange(p)
    lvl0 = index.levels[0]
    xs = [lvl0.vectors[j] for j in union]
    v = int(sum(len(x) for x in xs))
    if v == 0:
        return np.inf
    x = np.concatenate(xs)                                # (V, d)
    if index.config.metric == "l2":
        x2 = np.concatenate([lvl0.sqnorms[j] for j in union])
        d = (x2[None, :] - 2.0 * (qs @ x.T)
             + np.sum(qs * qs, 1)[:, None])
    else:
        d = -(qs @ x.T)
    kk = min(k, v)
    kth = np.partition(d, kk - 1, axis=1)[:, kk - 1]
    return float(np.median(kth.astype(np.float64)))


class PlannerCache:
    """Snapshot-fingerprinted planner state: cached centroid norms +
    calibrated APS radii, invalidated by the journal fingerprint.  The
    one implementation behind both serving paths — the
    ``BatchedSearchExecutor`` composes one, and the sharded engine's
    ``search_batch`` keeps its own — so the invalidation key can never
    diverge between them.

    Cached radii additionally expire after ``radius_ttl`` reuses: on a
    static index the fingerprint never moves, and a radius calibrated
    from one batch's sample can go stale if the *query* distribution
    drifts — the TTL bounds that staleness at ~1 recalibration per
    ``radius_ttl`` batches (amortized cost stays negligible).  The TTL
    defaults to ``QuakeConfig.planner_radius_ttl`` so serving stacks tune
    it in one place (executor and sharded-engine caches both flow through
    here); an explicit ``radius_ttl`` argument still overrides."""

    RADIUS_TTL = 64

    def __init__(self, index: QuakeIndex, radius_ttl: Optional[int] = None):
        self.index = index
        if radius_ttl is None:
            radius_ttl = getattr(index.config, "planner_radius_ttl",
                                 self.RADIUS_TTL)
        self.radius_ttl = radius_ttl
        self._key = None
        self._cent_norms = None
        self._kth_cache = {}     # (key, k, target) -> [kth_med, uses]
        self._dev = None         # fused-planner device residents

    def _fingerprint(self):
        return (self.index.version, self.index.num_partitions,
                self.index.num_vectors)

    def ensure_fresh(self):
        fp = self._fingerprint()
        if self._key != fp:
            cents = self.index.levels[0].centroids
            self._cent_norms = np.sum(cents * cents, axis=1)
            self._kth_cache = {}
            self._dev = None
            self._key = fp
        return self

    def device_arrays(self):
        """(centroids, MIPS augmentation extras, beta table) resident on
        device for the fused single-jit planner — uploaded once per
        snapshot fingerprint, not per batch."""
        if self._key != self._fingerprint() or self._dev is None:
            self.ensure_fresh()
            idx = self.index
            cents = jnp.asarray(idx.levels[0].centroids)
            if idx.config.metric == "ip":
                aug = jnp.asarray(
                    idx._augment_extra(0).astype(np.float32))
            else:
                aug = jnp.zeros((cents.shape[0],), jnp.float32)
            self._dev = (cents, aug, jnp.asarray(idx._beta_table))
        return self._dev

    def get_radius(self, k: int, target: float) -> Optional[float]:
        if self._key != self._fingerprint():
            return None
        entry = self._kth_cache.get((self._key, k, float(target)))
        if entry is None or entry[1] >= self.radius_ttl:
            return None
        entry[1] += 1
        return entry[0]

    def put_radius(self, k: int, target: float, kth_med: float) -> None:
        if self._key == self._fingerprint():
            self._kth_cache[(self._key, k, float(target))] = [kth_med, 0]


# ---------------------------------------------------------------------------
# APS probe planning: per-query loop (parity oracle) and vectorized
# ---------------------------------------------------------------------------

def _aps_candidate_budget(index: QuakeIndex) -> int:
    cfg = index.config
    p = index.levels[0].num_partitions
    return min(max(int(np.ceil(cfg.f_m * p)), cfg.min_candidates), p)


def _aps_probe_counts_loop(index: QuakeIndex, q: np.ndarray, k: int,
                           target: float,
                           kth_med: Optional[float] = None,
                           geo: Optional[np.ndarray] = None,
                           ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """The pre-vectorization planner: per-query Python loop (per-query
    centroid distances over all P, per-query argsort, scalar
    ``estimate_probs_np``, per-query cc distances) — the parity oracle
    for ``_aps_probe_counts_batched`` and the wall-time baseline in
    ``bench_multiquery --cell planner``.  Pass a shared ``geo`` matrix
    (``_centroid_geo_batch``) to pin parity bitwise — per-query GEMV and
    batched GEMM round differently.  Returns (sel (B, n_max),
    valid (B, n_max), per-query probe counts (B,))."""
    b = q.shape[0]
    p = index.levels[0].num_partitions
    n_consider = _aps_candidate_budget(index)
    if kth_med is None:
        kth_med = _calibrate_kth_loop(index, q, k, target)

    sel = np.zeros((b, n_consider), dtype=np.int64)
    valid = np.zeros((b, n_consider), dtype=bool)
    counts = np.empty(b, dtype=np.int64)
    table = index._beta_table
    for i in range(b):
        qi = q[i]
        geo_i = geo[i] if geo is not None else \
            index._centroid_geo_dists(qi, 0, np.arange(p))[0]
        order = np.argsort(geo_i, kind="stable")[:n_consider]
        rho_fn = index._rho_sq_from_item_dist(
            float(np.sum(qi.astype(np.float64) ** 2)))
        rho_sq = rho_fn(kth_med) if np.isfinite(kth_med) else np.inf
        if not np.isfinite(rho_sq) or rho_sq <= 0 or len(order) == 1:
            m = len(order)  # no radius: conservative full candidate scan
            probes = order
        else:
            cc = index._centroid_cc_dists(0, order, 0)
            vmask = np.ones(len(order), dtype=bool)
            vmask[0] = False
            p0, probs = aps_mod.estimate_probs_np(
                float(geo_i[order[0]]), geo_i[order].astype(np.float64),
                cc, rho_sq, table, vmask)
            if p0 >= target:
                m, probes = 1, order[:1]
            else:
                desc = np.argsort(-probs, kind="stable")
                desc = desc[desc != 0]     # nearest is always scanned
                r_cum = p0 + np.cumsum(probs[desc])
                reach = np.nonzero(r_cum >= target)[0]
                extra = (reach[0] + 1) if len(reach) else len(desc)
                m = int(min(1 + extra, len(order)))
                probes = np.concatenate([order[:1], order[desc[:m - 1]]])
        sel[i, :m] = probes
        valid[i, :m] = True
        counts[i] = m
    n_max = int(counts.max())
    return sel[:, :n_max], valid[:, :n_max], counts


def _aps_probe_counts_batched(index: QuakeIndex, q: np.ndarray, k: int,
                              target: float,
                              kth_med: Optional[float] = None,
                              geo: Optional[np.ndarray] = None,
                              cent_norms: Optional[np.ndarray] = None,
                              cache: Optional[PlannerCache] = None,
                              pass_impl: str = "numpy",
                              full: bool = False):
    """Vectorized APS planner: the whole batch planned with array ops.

    The centroid pass is either the host batched GEMM (``pass_impl=
    "numpy"`` — bitwise-parity path with the loop oracle) or one jitted
    ``ops.scan_topk`` call (``"scan_topk"`` — the device pass; same probe
    sets up to matmul rounding).  The estimator is
    ``aps.estimate_probs_batch`` on ``(B, n_consider)`` arrays; the k-NN
    radius comes from one batched sample search instead of up-to-8 host
    APS searches.  Returns ``_aps_probe_counts_loop``'s (sel, valid,
    counts) contract plus a fourth element — the per-query recall
    estimate at the planned cutoff (NaN on no-radius fallback rows).
    With ``full=True`` it instead returns the :class:`RoundPlan` the
    multi-round executor consumes (full scan-ordered candidate sequences
    plus seq-aligned estimator inputs).
    """
    b = q.shape[0]
    cfg = index.config
    m = _aps_candidate_budget(index)
    if kth_med is None:
        # steady-state serving amortizes calibration across batches: the
        # planner cache keys the radius on its snapshot fingerprint (with
        # a reuse TTL against query-distribution drift), re-checking the
        # fingerprint at lookup so a direct call against a
        # mutated-but-unrefreshed index never reuses a stale radius
        if cache is not None:
            kth_med = cache.get_radius(k, target)
        if kth_med is None:
            kth_med = _calibrate_kth_batched(index, q, k, m, cache=cache)
            if cache is not None:
                cache.put_radius(k, target, kth_med)

    cents = index.levels[0].centroids
    if pass_impl == "scan_topk":
        # one jitted centroid-distance + top-n_consider pass on device
        cd, order = ops.scan_topk(jnp.asarray(q), jnp.asarray(cents), m,
                                  metric=cfg.metric, impl="auto")
        # the batched APS estimator runs on host over the centroid pass
        # output, so the pass result is pulled once per plan
        # quakecheck: allow-sync(planner boundary pull for the host APS estimator)
        cd = np.asarray(cd, dtype=np.float64)
        order = np.asarray(order, dtype=np.int64)  # quakecheck: allow-sync(planner boundary pull)
        if cfg.metric == "l2":
            geo_sel = np.maximum(cd, 0.0)
        else:   # minimization keys are -score; lift into MIPS geometry
            q2 = np.sum(q.astype(np.float64) ** 2, axis=1)
            geo_sel = np.maximum(
                q2[:, None] + index._max_norm_sq + 2.0 * cd, 0.0)
    else:
        if geo is None:
            geo = _centroid_geo_batch(index, q, cent_norms)
        order = np.argsort(geo, axis=1, kind="stable")[:, :m]
        geo_sel = np.take_along_axis(geo, order, axis=1).astype(np.float64)

    # per-query radius in geometry space (same rho map as the loop)
    q_norm = np.sum(q.astype(np.float64) ** 2, axis=1)
    if np.isfinite(kth_med):
        if cfg.metric == "l2":
            rho_sq = np.full(b, max(float(kth_med), 0.0))
        else:
            rho_sq = np.maximum(
                q_norm + index._max_norm_sq + 2.0 * float(kth_med), 0.0)
    else:
        rho_sq = np.full(b, np.inf)
    fallback = ~np.isfinite(rho_sq) | (rho_sq <= 0) | (m == 1)

    if m > 1:
        # batched cc distances: ||c_i - c0|| per query in geometry space
        cg = cents[order].astype(np.float64)              # (B, M, d)
        d2 = np.sum((cg - cg[:, :1, :]) ** 2, axis=2)
        if cfg.metric == "ip":
            e = index._augment_extra(0)[order]            # (B, M)
            d2 = d2 + (e - e[:, :1]) ** 2
        cc = np.sqrt(np.maximum(d2, 0.0))

        valid = np.ones((b, m), dtype=bool)
        valid[:, 0] = False
        p0, probs = aps_mod.estimate_probs_batch(
            geo_sel[:, 0], geo_sel, cc, rho_sq, index._beta_table, valid)

        # probability-descending scan order (nearest always first); forcing
        # the nearest's key to +inf reproduces the loop's stable
        # argsort-then-drop exactly
        neg = -probs
        neg[:, 0] = np.inf
        desc = np.argsort(neg, axis=1, kind="stable")[:, :m - 1]
        r_cum = p0[:, None] + np.cumsum(
            np.take_along_axis(probs, desc, axis=1), axis=1)
        reached = r_cum >= target
        extra = np.where(reached.any(axis=1),
                         np.argmax(reached, axis=1) + 1, m - 1)
        counts = np.where(p0 >= target, 1, np.minimum(1 + extra, m))
        seq = np.concatenate(
            [order[:, :1], np.take_along_axis(order, desc, axis=1)], axis=1)
        r_at = np.take_along_axis(
            r_cum, np.maximum(counts - 2, 0)[:, None], axis=1)[:, 0]
        r_est = np.where(counts <= 1, p0, r_at)
    else:
        counts = np.ones(b, dtype=np.int64)
        seq = order
        r_est = np.full(b, np.nan)
    counts = np.where(fallback, m, counts).astype(np.int64)
    seq = np.where(fallback[:, None], order, seq)
    r_est = np.where(fallback, np.nan, r_est)

    if full:
        if m > 1:
            def _seq_align(a):
                return np.where(
                    fallback[:, None], a,
                    np.concatenate(
                        [a[:, :1], np.take_along_axis(a, desc, axis=1)],
                        axis=1))
            geo_seq = _seq_align(geo_sel)
            cc_seq = _seq_align(cc)
        else:
            geo_seq = geo_sel
            cc_seq = np.zeros((b, 1))
        return RoundPlan(seq=seq.astype(np.int64), counts=counts,
                         geo=geo_seq.astype(np.float64),
                         cc=cc_seq.astype(np.float64), recall_est=r_est)

    n_max = int(counts.max())
    vmask = np.arange(n_max)[None, :] < counts[:, None]
    sel = np.where(vmask, seq[:, :n_max], 0).astype(np.int64)
    return sel, vmask, counts, r_est


# ---------------------------------------------------------------------------
# Fused single-jit device planner (TPU planner path)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("m", "metric"))
def _fused_plan_probes(q, cents, aug_extra, max_norm_sq, kth_med, table,
                       target, *, m: int, metric: str):
    """The whole APS batch planner as ONE jitted function: centroid pass
    (``ops.scan_topk`` consumed directly on device), geometric beta-table
    lookup, recall estimation (``aps.estimate_probs_batch`` on jnp
    arrays) and probe *selection* (probability-descending cumulative
    cutoff at the recall target, candidate-budget clamping) — no host
    round-trip anywhere between the centroid pass and the selected probe
    sets.  The numpy planner (``_aps_probe_counts_batched``) is the
    parity oracle, exactly as the loop planner is for it.

    Returns (seq (B, M) int32 scan-ordered candidates, counts (B,) int32,
    recall_est (B,) f32, geo_seq (B, M), cc_seq (B, M)) — everything the
    round executor needs, still resident on device.
    """
    b = q.shape[0]
    cd, order = ops.scan_topk(q, cents, m, metric=metric, impl="auto")
    order = order.astype(jnp.int32)
    if metric == "l2":
        geo_sel = jnp.maximum(cd, 0.0)
        rho_sq = jnp.broadcast_to(jnp.maximum(kth_med, 0.0), (b,))
    else:   # minimization keys are -score; lift into MIPS geometry
        q2 = jnp.sum(q.astype(jnp.float32) ** 2, axis=1)
        geo_sel = jnp.maximum(q2[:, None] + max_norm_sq + 2.0 * cd, 0.0)
        rho_sq = jnp.maximum(q2 + max_norm_sq + 2.0 * kth_med, 0.0)
    rho_sq = jnp.where(jnp.isfinite(kth_med), rho_sq, jnp.inf)
    if m == 1:
        return (order, jnp.ones((b,), jnp.int32),
                jnp.full((b,), jnp.nan, jnp.float32), geo_sel,
                jnp.zeros((b, 1), jnp.float32))
    fallback = ~jnp.isfinite(rho_sq) | (rho_sq <= 0)

    cg = jnp.take(cents, order, axis=0)                   # (B, M, d)
    d2 = jnp.sum((cg - cg[:, :1, :]) ** 2, axis=2)
    if metric == "ip":
        e = jnp.take(aug_extra, order)                    # (B, M)
        d2 = d2 + (e - e[:, :1]) ** 2
    cc = jnp.sqrt(jnp.maximum(d2, 0.0))

    valid = jnp.ones((b, m), jnp.bool_).at[:, 0].set(False)
    p0, probs = aps_mod.estimate_probs_batch(
        geo_sel[:, 0], geo_sel, cc, rho_sq, table, valid)

    # probability-descending scan order (nearest always first); the +inf
    # key on the nearest reproduces the numpy argsort-then-drop exactly
    neg = (-probs).at[:, 0].set(jnp.inf)
    desc = jnp.argsort(neg, axis=1)[:, :m - 1]            # stable sort
    r_cum = p0[:, None] + jnp.cumsum(
        jnp.take_along_axis(probs, desc, axis=1), axis=1)
    reached = r_cum >= target
    extra = jnp.where(reached.any(axis=1),
                      jnp.argmax(reached, axis=1) + 1, m - 1)
    counts = jnp.where(p0 >= target, 1, jnp.minimum(1 + extra, m))
    counts = jnp.where(fallback, m, counts).astype(jnp.int32)

    def _seq_align(a):
        tail = jnp.take_along_axis(a, desc, axis=1)
        return jnp.where(fallback[:, None], a,
                         jnp.concatenate([a[:, :1], tail], axis=1))
    seq = _seq_align(order)
    geo_seq = _seq_align(geo_sel)
    cc_seq = _seq_align(cc)
    r_at = jnp.take_along_axis(
        r_cum, jnp.maximum(counts - 2, 0)[:, None], axis=1)[:, 0]
    r_est = jnp.where(counts <= 1, p0, r_at)
    r_est = jnp.where(fallback, jnp.nan, r_est).astype(jnp.float32)
    return seq, counts, r_est, geo_seq, cc_seq


def _aps_probe_counts_fused(index: QuakeIndex, q: np.ndarray, k: int,
                            target: float,
                            kth_med: Optional[float] = None,
                            cache: Optional[PlannerCache] = None,
                            full: bool = False):
    """Host wrapper for the fused device planner: radius calibration and
    cache lookups stay on host (identical policy to the numpy planner),
    then one ``_fused_plan_probes`` call plans the whole batch on device.
    Same return contracts as ``_aps_probe_counts_batched``."""
    b = q.shape[0]
    cfg = index.config
    m = _aps_candidate_budget(index)
    if kth_med is None:
        if cache is not None:
            kth_med = cache.get_radius(k, target)
        if kth_med is None:
            kth_med = _calibrate_kth_batched(index, q, k, m, cache=cache)
            if cache is not None:
                cache.put_radius(k, target, kth_med)
    if cache is not None:
        cents_d, aug_d, table_d = cache.device_arrays()
    else:
        cents_d = jnp.asarray(index.levels[0].centroids)
        aug_d = jnp.asarray(index._augment_extra(0).astype(np.float32)) \
            if cfg.metric == "ip" else \
            jnp.zeros((cents_d.shape[0],), jnp.float32)
        table_d = jnp.asarray(index._beta_table)
    seq_d, counts_d, r_d, geo_d, cc_d = _fused_plan_probes(
        jnp.asarray(q), cents_d, aug_d,
        np.float32(index._max_norm_sq), np.float32(kth_med), table_d,
        np.float32(target), m=m, metric=cfg.metric)

    # the planner contract (probe selection, round chunking, the host APS
    # re-estimator) is host-side — one pull per plan at this boundary
    # quakecheck: allow-sync(fused planner boundary: host plan contract)
    counts = np.asarray(counts_d, dtype=np.int64)
    seq = np.asarray(seq_d, dtype=np.int64)  # quakecheck: allow-sync(fused planner boundary)
    r_est = np.asarray(r_d, dtype=np.float64)  # quakecheck: allow-sync(fused planner boundary)
    if full:
        return RoundPlan(seq=seq, counts=counts,
                         geo=np.asarray(geo_d, dtype=np.float64),   # quakecheck: allow-sync(fused planner boundary)
                         cc=np.asarray(cc_d, dtype=np.float64),     # quakecheck: allow-sync(fused planner boundary)
                         recall_est=r_est,
                         seq_dev=seq_d.astype(jnp.int32))
    n_max = int(counts.max())
    vmask = np.arange(n_max)[None, :] < counts[:, None]
    sel = np.where(vmask, seq[:, :n_max], 0).astype(np.int64)
    return sel, vmask, counts, r_est


# ---------------------------------------------------------------------------
# Pack: probe sets -> partition union + per-query mask (device primitive)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("p", "n_union", "u_pad"))
def _pack_plan(sel_q, qvalid, nearest, n_real, *, p: int, n_union: int,
               u_pad: int):
    """Scatter per-query probe sets into a (B, P) selection matrix, pack
    it through the device-side ``pack_union`` primitive, and apply the
    inert-tail discipline on device: union slots at or past ``n_real``
    (a dynamic scalar — distinct values share one executable) duplicate
    slot 0 under an all-False mask, and the static bucket width ``u_pad``
    is reached by appending more such slots when it exceeds the packable
    width.  ``nearest`` (B,) anchors each query's nearest partition above
    the frequency ranking so a union cap never drops a query's best
    probe."""
    b = sel_q.shape[0]
    rows = jnp.broadcast_to(jnp.arange(b)[:, None], sel_q.shape)
    selected = jnp.zeros((b, p), jnp.bool_).at[rows, sel_q].max(qvalid)
    anchor = jnp.zeros((p,), jnp.bool_).at[nearest].set(True)
    sel, qmask = ops.pack_union(selected, n_union,
                                priority=anchor.astype(jnp.int32)
                                * (b + 1))
    live = jnp.arange(n_union) < n_real
    sel = jnp.where(live, sel, sel[0])
    qmask = qmask & live[None, :]
    if u_pad > n_union:
        sel = jnp.concatenate(
            [sel, jnp.full((u_pad - n_union,), sel[0], sel.dtype)])
        qmask = jnp.concatenate(
            [qmask, jnp.zeros((b, u_pad - n_union), jnp.bool_)], axis=1)
    return sel, qmask


def plan_batch(index: QuakeIndex, q: np.ndarray, k: int,
               nprobe: Optional[int] = None,
               recall_target: Optional[float] = None,
               u_bucket: int = 8,
               union_cap: Optional[int] = None,
               planner: str = "vectorized",
               cent_norms: Optional[np.ndarray] = None,
               cache: Optional[PlannerCache] = None) -> BatchPlan:
    """Plan one batched scan: per-query probe sets -> partition union +
    per-query mask.

    ``planner`` selects the APS probe planner: ``"vectorized"`` (default;
    the batched host implementation), ``"fused"`` (the single-jit device
    planner — centroid pass, estimator and selection in one jitted call)
    or ``"loop"`` (the per-query baseline).
    ``union_cap`` bounds the number of distinct partitions the batch scans:
    the union is frequency-ranked (``pack_union`` keeps the partitions most
    queries probe), so under read skew a cap well below B*nprobe drops only
    rarely-probed tail partitions — ``BatchPlan.nprobe`` reports the
    *effective* per-query probes after capping (``planned`` keeps the
    pre-cap counts).  ``u_bucket`` rounds the union size up so the jitted
    scan sees few distinct shapes (pad slots duplicate a real partition and
    carry an all-False mask — they add work, never wrong results).
    """
    b = q.shape[0]
    p = index.levels[0].num_partitions

    if b == 0:
        # empty batch: one inert pad slot, no query rows
        return BatchPlan(sel=np.zeros(1, dtype=np.int64),
                         qmask=np.zeros((0, 1), dtype=bool),
                         nprobe=np.zeros(0, dtype=np.int64), n_real=0,
                         planned=np.zeros(0, dtype=np.int64))

    r_est = None
    if nprobe is not None:
        cd = _centroid_dists(index, q, cent_norms)
        n = int(max(1, min(nprobe, p)))
        if n < p:
            sel_q = np.argpartition(cd, n - 1, axis=1)[:, :n]
        else:
            sel_q = np.broadcast_to(np.arange(p), (b, p)).copy()
        qvalid = np.ones((b, n), dtype=bool)
        counts = np.full(b, n, dtype=np.int64)
        nearest = np.argmin(cd, axis=1)
    else:
        target = recall_target if recall_target is not None \
            else index.config.recall_target
        if planner == "loop":
            sel_q, qvalid, counts = _aps_probe_counts_loop(
                index, q, k, target)
        elif planner == "fused":
            sel_q, qvalid, counts, r_est = _aps_probe_counts_fused(
                index, q, k, target, cache=cache)
        else:
            sel_q, qvalid, counts, r_est = _aps_probe_counts_batched(
                index, q, k, target, cent_norms=cent_norms, cache=cache)
        nearest = sel_q[:, 0]   # APS probe sequences lead with the nearest

    # ---- union + (B, U) mask via the device-side pack primitive ----
    hit = np.zeros(p, dtype=bool)
    hit[sel_q[qvalid]] = True
    n_hits = int(hit.sum())
    if union_cap:
        # floor the cap at the distinct-anchor count: the anchor priority
        # ranks every query's nearest partition first, so with this floor
        # no query ever loses its whole probe set to the cap (a cap below
        # the anchor count would otherwise return silent all-miss rows)
        n_anchor = int(len(np.unique(nearest)))
        n_real = min(n_hits, max(union_cap, n_anchor))
    else:
        n_real = n_hits
    n_real = max(n_real, 1)
    u_pad = max(-(-n_real // u_bucket) * u_bucket, 1)
    n_dev = min(u_pad, p)
    # bucket the probe-set width too: APS counts.max() varies per batch,
    # and an unbucketed width would retrace the jitted pack per batch
    # (pad columns carry qvalid=False — inert under the scatter)
    n_cols = sel_q.shape[1]
    c_pad = max(-(-n_cols // u_bucket) * u_bucket, 1)
    if c_pad > n_cols:
        sel_q = np.concatenate(
            [sel_q, np.zeros((b, c_pad - n_cols), dtype=sel_q.dtype)], 1)
        qvalid = np.concatenate(
            [qvalid, np.zeros((b, c_pad - n_cols), dtype=bool)], 1)
    # pack + inert-tail masking stay on device (n_real rides as a dynamic
    # scalar, so distinct cap/hit counts share one executable); the scan
    # consumes sel_d/qmask_d directly — no host round trip on the hot path
    sel_d, qmask_d = _pack_plan(jnp.asarray(sel_q), jnp.asarray(qvalid),
                                jnp.asarray(nearest), n_real, p=p,
                                n_union=n_dev, u_pad=u_pad)
    # the distributed engine and plan introspection read sel/qmask on
    # host: one read-only pull at the plan boundary, never re-uploaded
    # quakecheck: allow-sync(host plan mirror for distributed/introspection)
    sel = np.asarray(sel_d, dtype=np.int64)
    qmask = np.asarray(qmask_d)  # quakecheck: allow-sync(host plan mirror)
    eff = qmask[:, :n_real].sum(axis=1).astype(np.int64)
    if r_est is not None:
        # a cap that truncated a query's probes invalidates its planner
        # estimate (it was computed at the pre-cap cutoff) — report NaN
        # rather than overstate the achievable recall
        r_est = np.where(eff < counts, np.nan, r_est)
    return BatchPlan(sel=sel, qmask=qmask, nprobe=eff, n_real=n_real,
                     planned=counts, anchor=np.asarray(nearest,
                                                       dtype=np.int64),
                     recall_est=r_est, sel_dev=sel_d, qmask_dev=qmask_d)


# ---------------------------------------------------------------------------
# Multi-round early-exit execution (Algorithm 2 for the batched host path)
# ---------------------------------------------------------------------------

def plan_rounds(index: QuakeIndex, q: np.ndarray, k: int, target: float,
                planner: str = "vectorized",
                cache: Optional[PlannerCache] = None,
                cent_norms: Optional[np.ndarray] = None) -> RoundPlan:
    """APS probe planning for the multi-round executor: full scan-ordered
    candidate sequences plus the seq-aligned estimator inputs (geometry
    distances, center-center distances) the round loop re-scores recall
    with.  ``planner`` is ``"vectorized"`` (host) or ``"fused"`` (the
    single-jit device planner); the loop baseline has no round form."""
    if planner == "fused":
        return _aps_probe_counts_fused(index, q, k, target, cache=cache,
                                       full=True)
    return _aps_probe_counts_batched(index, q, k, target,
                                     cent_norms=cent_norms, cache=cache,
                                     full=True)


def _round_windows(n_max: int, rounds: Optional[int] = None):
    """Column windows [(c0, c1), ...] chunking a probe list of length
    ``n_max`` into geometrically growing rounds: single-probe windows
    while exits are most likely (Algorithm 2 exits concentrate within the
    first few probes — the per-probe exit checks are what the fixed plan
    lacks), then doubling windows so the hard tail amortizes dispatch.
    A ``rounds`` budget merges the tail into the final round, so the
    windows always cover the full planned list — ``rounds=1`` degenerates
    to one fixed-plan scan."""
    wins, c0, w = [], 0, 1
    while c0 < n_max:
        wins.append((c0, min(c0 + w, n_max)))
        c0 += w
        if len(wins) >= 3:          # probe-at-a-time for probes 1..3
            w *= 2
    if rounds is not None and rounds >= 1 and len(wins) > rounds:
        wins = wins[:rounds - 1] + [(wins[rounds - 1][0], n_max)]
    return wins


def run_round_loop(plan: RoundPlan, k: int, target: float, table,
                   rho_fn, scan_round, *, rounds: Optional[int] = None,
                   k_keep: Optional[int] = None,
                   deadline_s: Optional[float] = None,
                   clock=None):
    """Algorithm 2 round driver, shared by the host batched executor and
    the sharded engine's ``search_batch``.

    Each round, every *live* query advances through the next window of
    its planned probe sequence; the window's partitions form the round's
    union, and every live query additionally consumes all of its
    not-yet-scanned probes that happen to land in that union ("union
    riding": a partition block is streamed at most once per batch — the
    round decomposition never re-streams what the monolithic scan would
    read once, so early exit can only shrink the footprint).
    ``scan_round(take, kept)`` packs and scans the round — ``take``
    (B, M) marks the probe-sequence cells consumed this round, ``kept``
    the union partition ids — and returns device ``(dists (B, k_keep),
    ids (B, k_keep), stats)``.

    The driver owns the device-resident running top-k
    (``ops.topk_merge``), pulls only the per-query k-th distance each
    round, re-estimates APS recall from that *running* radius
    (``aps.estimate_probs_batch`` over the plan's seq-aligned candidates,
    restricted to the still-live rows), and masks out queries whose
    estimate cleared the target — later rounds shrink to the hard tail.
    Queries whose top-k is not yet full never exit (no radius -> keep
    scanning, the same rule as the sequential Algorithm 1 loop).
    ``union_cap`` runs never reach this driver: the cap's footprint
    bound is defined as plan-level truncation, so capped searches take
    the one-shot fixed-plan scan (a per-round cap would re-bound each
    round separately and let the batch total exceed the cap).

    ``deadline_s`` is a wall-clock budget for the whole loop (measured
    by ``clock``, default ``time.perf_counter``): when it expires the
    loop stops *at the end of the current round* — at least one round
    always runs — and the still-live queries' running top-k is returned
    as-is (their partial results; ``trace["budget_expired"]`` /
    ``trace["timed_out_rows"]`` report that it happened).  This is the
    per-query latency-budget primitive the serving runtime's
    ``PARTIAL`` status is built on (docs/serving.md).

    Returns (top dists, top ids — both device, ascending — nprobe (B,),
    recall_est (B,), rounds executed, per-round trace dict, totals).
    """
    b, m = plan.seq.shape
    counts = plan.counts
    k_keep = k if k_keep is None else k_keep
    n_max = int(counts.max(initial=1))
    wins = _round_windows(n_max, rounds)
    td = jnp.full((b, k_keep), MASK_DIST, jnp.float32)
    ti = jnp.full((b, k_keep), -1, jnp.int32)
    live = np.ones(b, dtype=bool)
    r_est = np.asarray(plan.recall_est, dtype=np.float64).copy()
    scanned = np.zeros((b, m), dtype=bool)
    valid = np.ones((b, m), dtype=bool)
    valid[:, 0] = False
    cols = np.arange(m)[None, :]
    within = cols < counts[:, None]
    p_hi = int(plan.seq.max()) + 1
    # the pinned per-round trace schema (docs/observability.md; a test
    # in tests/test_observability.py asserts these exact keys): parallel
    # per-round lists plus two scalar outcome flags — the serving trace
    # emitter and benchmarks/common.round_trajectory both rely on it
    trace = {"round_live": [], "round_partitions": [],
             "round_vectors": [], "round_comparisons": [],
             "round_kth": [], "round_wall_s": [],
             "budget_expired": False, "timed_out_rows": 0}
    clock = clock or time.perf_counter
    t0 = clock()
    n_rounds = 0
    for c0, c1 in wins:
        if not live.any():
            break
        if (deadline_s is not None and n_rounds > 0
                and clock() - t0 >= deadline_s):
            # budget spent: retire at the end of the last completed
            # round with the running top-k (partial results)
            trace["budget_expired"] = True
            trace["timed_out_rows"] = int(live.sum())
            break
        avail = live[:, None] & within & ~scanned
        base = avail & (cols >= c0) & (cols < c1)
        if not base.any():
            continue          # window already consumed by riding
        kept = np.unique(plan.seq[base])
        in_union = np.zeros(p_hi, dtype=bool)
        in_union[kept] = True
        take = avail & in_union[plan.seq]
        scanned |= take
        n_rounds += 1
        t_round = clock()
        trace["round_live"].append(int(live.sum()))
        d, i, st = scan_round(take, kept)
        td, ti = ops.topk_merge(td, ti, d, i, k_keep)
        for key in ("partitions", "vectors", "comparisons"):
            trace[f"round_{key}"].append(int(st[key]))
        # refined recall estimate from the *running* k-th distance —
        # live rows only; exited rows' estimates are frozen
        rows = np.nonzero(live)[0]
        # quakecheck: allow-sync(Algorithm 2's per-round kth-distance pull: the early-exit recall re-estimate is host-side by design)
        kth = np.asarray(td[rows, k - 1], dtype=np.float64)
        full_heap = kth < MASK_DIST
        rho_sq = np.where(full_heap, rho_fn(kth, rows), np.inf)
        p0, probs = aps_mod.estimate_probs_batch(
            plan.geo[rows, 0], plan.geo[rows], plan.cc[rows], rho_sq,
            table, valid[rows])
        r = p0 + np.where(scanned[rows] & valid[rows], probs,
                          0.0).sum(axis=1)
        r_est[rows[full_heap]] = r[full_heap]
        live[rows[full_heap & (r >= target)]] = False
        # per-round running k-th distance (median over rows whose heap
        # is full) and round wall time — the topk_merge above already
        # synced, so kth is host data and this costs no extra pull
        trace["round_kth"].append(
            float(np.median(kth[full_heap])) if full_heap.any() else None)
        trace["round_wall_s"].append(clock() - t_round)
    stats = {k_: int(np.sum(v)) for k_, v in
             (("partitions", trace["round_partitions"]),
              ("vectors", trace["round_vectors"]),
              ("comparisons", trace["round_comparisons"]))}
    return (td, ti, scanned.sum(axis=1).astype(np.int64), r_est,
            n_rounds, trace, stats)


def _batch_rho_fn(index: QuakeIndex, q: np.ndarray):
    """Vectorized kth-item-distance -> squared-geometry-radius map for the
    round loop (the batched mirror of ``_rho_sq_from_item_dist``).  The
    returned callable takes (kth, rows) where ``rows`` selects the query
    rows ``kth`` corresponds to (the driver's live subset)."""
    if index.config.metric == "l2":
        return lambda kth, rows=None: aps_mod.rho_sq_batch(kth,
                                                           metric="l2")
    qn = np.sum(q.astype(np.float64) ** 2, axis=1)
    m2 = index._max_norm_sq
    return lambda kth, rows=None: aps_mod.rho_sq_batch(
        kth, metric="ip", q_norm_sq=qn if rows is None else qn[rows],
        max_norm_sq=m2)


class BatchedSearchExecutor:
    """Executes planned batches against a device-resident snapshot.

    The snapshot (dense ``(P, S_cap, d)`` + ids + sizes) is cached and kept
    coherent with the dynamic index through its mutation journal: content
    mutations confined to known partitions (insert/delete/refine) patch
    only the touched rows on device (``IndexSnapshot.apply_delta``, COW
    semantics — paper §8.2), while structural changes (split/merge/level),
    capacity overflow, or a dirty set larger than
    ``config.snapshot_max_dirty_frac * P`` fall back to a full rebuild.
    Full rebuilds allocate ``config.snapshot_headroom`` slack capacity so
    insert deltas rarely force a reshape.  Searches then run one packed
    union scan per batch.

    ``storage_dtype`` sets the scan storage format (paper §8.2 vector
    compression): ``"f32"`` (exact), ``"bf16"`` (2x less scan traffic,
    delta-refresh capable — patches cast on device), or ``"int8"`` (IVF
    residual SQ8 through ``scan_selected_topk_q8``, 4x less traffic;
    content deltas would need requantization, so any journal delta forces
    a full rebuild — the same policy as the sharded engine).
    """

    def __init__(self, index: QuakeIndex, impl: str = "auto",
                 u_bucket: int = 8, headroom: Optional[float] = None,
                 max_dirty_frac: Optional[float] = None,
                 storage_dtype: str = "f32",
                 union_cap: Optional[int] = None,
                 planner: str = "vectorized",
                 int8_rerank: bool = True,
                 rounds: Optional[int] = None,
                 part_bucket: int = 1):
        if storage_dtype not in STORAGE_DTYPES:
            raise ValueError(f"storage_dtype must be one of "
                             f"{STORAGE_DTYPES}, got {storage_dtype!r}")
        self.index = index
        self.impl = impl
        self.u_bucket = u_bucket
        self.part_bucket = max(part_bucket, 1)  # snapshot partition-count
                                 # rounding: a maintenance split/merge that
                                 # stays within the bucket keeps every
                                 # (P, S_cap, d) scan operand shape — and
                                 # therefore every compiled scan — alive
                                 # across the rebuild (serving runtimes
                                 # set 32; 1 = exact count)
        self.storage_dtype = storage_dtype
        self.planner = planner
        self.rounds = rounds     # early-exit round budget for APS-planned
                                 # searches: None = as many geometric
                                 # rounds as the plan needs, 1 = the
                                 # monolithic fixed-plan scan
        self.int8_rerank = int8_rerank   # exact re-rank of the int8 scan's
                                         # top-2k from a host f32 mirror
                                         # (B*2k row gather — negligible
                                         # next to the scan)
        self._host_f32 = None            # (P*S_cap, d) mirror, int8 only
        cfg = index.config
        self.union_cap = cfg.union_cap if union_cap is None else union_cap
        self.headroom = cfg.snapshot_headroom if headroom is None \
            else headroom
        self.max_dirty_frac = cfg.snapshot_max_dirty_frac \
            if max_dirty_frac is None else max_dirty_frac
        self._snap = None
        self._key = None         # fingerprint the snapshot reflects
        self._valid = None       # (P, S_cap) bool, device
        self._flat_ids = None    # (P*S_cap,) host
        self._sizes = None       # (P,) host
        self.planner_cache = PlannerCache(index)  # centroid norms +
                                 # calibrated radii, fingerprint-keyed
                                 # (refreshed with the snapshot)
        self.full_rebuilds = 0   # refresh telemetry (tests / bench)
        self.delta_refreshes = 0

    def _fingerprint(self):
        return (self.index.version, self.index.num_partitions,
                self.index.num_vectors)

    @property
    def _cent_norms(self):
        return self.planner_cache._cent_norms

    def _refresh_host_mirrors(self):
        self.planner_cache.ensure_fresh()

    def refresh(self):
        """Full rebuild of the device snapshot from the dynamic index.

        The slot capacity is *sticky*: a rebuild never shrinks it below
        the previous snapshot's (a maintenance split that halves the
        largest partition would otherwise halve ``S_cap`` and invalidate
        every compiled scan shape, only for the next insert wave to grow
        it back).  Monotone capacity costs padded slack rows — which the
        headroom policy already accepts — and keeps the ``(P, S_cap, d)``
        operand shape, and therefore the compiled scans, alive across
        maintenance epochs."""
        import math as _math
        from .distributed import IndexSnapshot  # late: avoid import cycle
        lvl0 = self.index.levels[0]
        max_sz = int(max((len(v) for v in lvl0.vectors), default=0))
        cap = max(int(_math.ceil(max_sz * max(self.headroom, 1.0))), 1)
        if self._snap is not None:
            cap = max(cap, int(self._snap.capacity))
        pad_to = self.part_bucket
        if self.part_bucket > 1:
            # partition padding is sticky too, with 25% growth slack, so
            # a handful of maintenance splits never crosses the pad
            # boundary and re-shapes the scan operands
            pad_to = (-(-int(lvl0.num_partitions * 1.25)
                        // self.part_bucket) * self.part_bucket)
            if self._snap is not None:
                pad_to = max(pad_to, int(self._snap.num_partitions))
            # from_index treats pad_partitions_to as a rounding multiple:
            # the absolute-target usage here is only sound while the
            # target covers the live count (ceil(p/pad_to) == 1)
            pad_to = max(pad_to, lvl0.num_partitions)
        snap = IndexSnapshot.from_index(self.index, capacity=cap,
                                        pad_partitions_to=pad_to)
        self._valid = snap.ids >= 0
        self._flat_ids = np.array(snap.ids).reshape(-1)
        self._sizes = np.array(snap.sizes)
        if self.storage_dtype == "bf16":
            snap = replace(snap, data=snap.data.astype(jnp.bfloat16))
        elif self.storage_dtype == "int8":
            from ..kernels.scan_topk_indexed import quantize_int8_residual
            if self.int8_rerank:
                self._host_f32 = np.array(snap.data).reshape(
                    -1, snap.data.shape[-1])
            codes, scales = quantize_int8_residual(snap.data, snap.centroids)
            snap = replace(snap, data=codes, scales=scales)
        self._snap = snap
        self._refresh_host_mirrors()
        self._key = self._fingerprint()
        self.full_rebuilds += 1
        return self._snap

    def _refresh_delta(self, delta) -> bool:
        """Patch the dirty partition rows in place of a rebuild.  Returns
        False when the delta is not applicable (structural change, capacity
        overflow, dirty set too large, or int8 storage — residual codes
        would need requantizing) — caller falls back to ``refresh``.
        """
        from .distributed import IndexSnapshot  # late: avoid import cycle
        if self._snap.scales is not None:
            return False          # int8: requantize via full rebuild
        idx = self.index
        lvl0 = idx.levels[0]
        p_real = lvl0.num_partitions
        if delta.structural or p_real > self._snap.num_partitions:
            return False
        dirty = sorted(j for j in delta.dirty if j < p_real)
        if len(dirty) > self.max_dirty_frac * max(p_real, 1):
            return False
        if not dirty:
            # clock moved without base-level content changes (e.g. an
            # upper-level split): snapshot already coherent
            self._key = self._fingerprint()
            return True
        cap = self._snap.capacity
        if max(len(lvl0.vectors[j]) for j in dirty) > cap:
            return False      # a partition outgrew its slack slots
        try:
            patch = IndexSnapshot.build_patch(idx, dirty, cap)
            # donate: the executor owns its cached snapshot exclusively,
            # so the patch updates the device buffers in place — refresh
            # cost is O(dirty rows), not O(index)
            self._snap = self._snap.apply_delta(patch, donate=True)
        except ValueError:
            return False
        from .distributed import _scatter_rows_donated
        sel = patch.rows
        self._valid = _scatter_rows_donated(
            self._valid, jnp.asarray(sel), jnp.asarray(patch.ids >= 0))
        self._flat_ids.reshape(self._snap.num_partitions, cap)[sel] = \
            patch.ids
        self._sizes[sel] = patch.sizes
        self._refresh_host_mirrors()   # refine deltas can move centroids
        self._key = self._fingerprint()
        self.delta_refreshes += 1
        return True

    def _rerank_exact(self, q: np.ndarray, flat: np.ndarray, k: int
                      ) -> Tuple[np.ndarray, np.ndarray]:
        """Exact f32 re-rank of the int8 scan's candidate list: one gather
        of the (B, 2k) candidate rows from the host mirror + exact
        distances, then top-k.  Recovers the quantization-induced rank
        flips near the k-th boundary at negligible extra traffic."""
        b, k2 = flat.shape
        d = self._host_f32.shape[1]
        x = self._host_f32[np.maximum(flat, 0).reshape(-1)]
        x = x.reshape(b, k2, d)
        if self.index.config.metric == "l2":
            diff = x - q[:, None, :]
            de = np.einsum("bkd,bkd->bk", diff, diff, dtype=np.float64)
        else:
            de = -np.einsum("bkd,bd->bk", x, q, dtype=np.float64)
        de = np.where(flat >= 0, de, np.inf)
        order = np.argsort(de, axis=1, kind="stable")[:, :k]
        return (np.take_along_axis(de, order, axis=1),
                np.take_along_axis(flat, order, axis=1))

    def snapshot(self):
        if self._snap is None:
            return self.refresh()
        fp = self._fingerprint()
        if self._key == fp:
            return self._snap
        delta = self.index.journal.delta_since(self._key[0])
        if delta is None or not self._refresh_delta(delta):
            self.refresh()
        return self._snap

    def search(self, queries: np.ndarray, k: int,
               nprobe: Optional[int] = None,
               recall_target: Optional[float] = None,
               impl: Optional[str] = None,
               union_cap: Optional[int] = None,
               rounds: Optional[int] = None) -> BatchResult:
        q = np.ascontiguousarray(queries, dtype=np.float32)
        if q.ndim == 1:
            q = q[None, :]
        if q.shape[0] == 0:
            return BatchResult(ids=np.zeros((0, k), dtype=np.int64),
                               dists=np.zeros((0, k), dtype=np.float64),
                               nprobe=np.zeros(0, dtype=np.int64),
                               recall_estimate=np.zeros(0))
        snap = self.snapshot()
        rounds = self.rounds if rounds is None else rounds
        if rounds is not None and rounds < 1:
            raise ValueError(f"rounds must be >= 1 or None, got {rounds}")
        cap = self.union_cap if union_cap is None else union_cap
        # early-exit rounds engage only where APS recall machinery exists:
        # nprobe-pinned searches have no per-query estimate to exit on,
        # rounds=1 forces the monolithic fixed-plan scan, the loop
        # planner has no round (seq-aligned) form, and union_cap runs
        # keep the one-shot capped plan (the cap's footprint bound is
        # plan-level; per-round caps would let the batch total exceed it)
        if nprobe is None and rounds != 1 and self.planner != "loop" \
                and not cap:
            target = recall_target if recall_target is not None \
                else self.index.config.recall_target
            return self._search_rounds(q, k, target, rounds, impl=impl,
                                       snap=snap)
        plan = plan_batch(self.index, q, k, nprobe=nprobe,
                          recall_target=recall_target,
                          u_bucket=self.u_bucket,
                          union_cap=self.union_cap if union_cap is None
                          else union_cap,
                          planner=self.planner,
                          cent_norms=self._cent_norms,
                          cache=self.planner_cache)
        # the planner's packed plan is already device-resident; re-upload
        # only if a caller hands in a host-constructed BatchPlan
        sel_dev = plan.sel_dev if plan.sel_dev is not None \
            else jnp.asarray(plan.sel.astype(np.int32))
        qmask_dev = plan.qmask_dev if plan.qmask_dev is not None \
            else jnp.asarray(plan.qmask)
        if snap.scales is not None:     # int8 residual codes
            rerank = self.int8_rerank and self._host_f32 is not None
            k_scan = 2 * k if rerank else k
            dd, flat = ops.scan_selected_topk_q8(
                jnp.asarray(q), snap.data, snap.scales, self._valid,
                sel_dev, qmask_dev, k_scan,
                metric=self.index.config.metric, centroids=snap.centroids)
            if rerank:
                # quakecheck: allow-sync(int8 rerank gathers from the host f32 mirror)
                dd, flat = self._rerank_exact(q, np.asarray(flat), k)
        else:
            dd, flat = ops.scan_selected_topk(
                jnp.asarray(q), snap.data, self._valid,
                sel_dev, qmask_dev, k,
                metric=self.index.config.metric, impl=impl or self.impl)
        # quakecheck: allow-sync(result boundary: BatchResult is a host contract)
        dd = np.asarray(dd, dtype=np.float64)
        flat = np.asarray(flat)  # quakecheck: allow-sync(result boundary)
        ids = np.where(flat >= 0,
                       self._flat_ids[np.maximum(flat, 0)], -1)
        dd = np.where(dd >= MASK_DIST, np.inf, dd)

        sizes_sel = self._sizes[plan.sel[:plan.n_real]]
        return BatchResult(
            ids=ids.astype(np.int64), dists=dd,
            partitions_scanned=int(plan.n_real),
            vectors_scanned=int(sizes_sel.sum()),
            comparisons=int((plan.qmask[:, :plan.n_real].astype(np.int64)
                             * sizes_sel[None, :]).sum()),
            nprobe=plan.nprobe, recall_estimate=plan.recall_est)

    def scan_probe_round(self, q_dev, seq_dev, take: np.ndarray,
                         kept: np.ndarray, k_keep: int, snap=None,
                         impl: Optional[str] = None,
                         u_pow2: bool = False,
                         seq_host: Optional[np.ndarray] = None):
        """One packed partition-union scan for a probe round over an
        arbitrary query row set: ``q_dev`` (B, d) queries, ``seq_dev``
        (B, M) scan-ordered candidate partitions, ``take`` (B, M) bool
        marking the probe-sequence cells consumed this round, ``kept``
        the round's distinct union partition ids.  Packs through
        ``ops.pack_round_masked`` (bucketed union width, inert tail
        applied on device) and scans the snapshot once; returns device
        ``(dists (B, k_keep), flat idx (B, k_keep), stats)`` in
        ``run_round_loop``'s ``scan_round`` contract.

        This is the scan primitive both round drivers share: the
        fixed-membership per-batch loop (``_search_rounds``) and the
        serving scheduler's cross-batch riding rounds
        (``core/serving.py``), where the active row set changes between
        rounds as queued batches join mid-flight.  ``u_pow2`` switches
        the union padding from linear ``u_bucket`` steps to a geometric
        ladder (``u_bucket * 2^i``) — serving rounds see wildly varying
        union sizes, and the ladder bounds the distinct compiled scan
        shapes at log cost instead of linear.

        ``seq_host`` is the host mirror of ``seq_dev``: with it the
        per-round comparison count is exact (every taken cell weighted
        by its partition size — candidate partitions are distinct within
        a row, so this equals the packed qmask accounting) without
        pulling the packed plan off device; without it the stats report
        ``comparisons == vectors`` (each streamed partition counted
        once).
        """
        snap = self.snapshot() if snap is None else snap
        # pack against the snapshot's (padded) partition count: stable
        # across rebuilds when part_bucket > 1, so the jitted pack
        # survives maintenance epochs
        p = max(self.index.levels[0].num_partitions,
                int(snap.num_partitions))
        prio0 = jnp.zeros((p,), jnp.int32)   # uncapped: no anchor boost
        n_real = max(len(kept), 1)
        u_pad = max(-(-n_real // self.u_bucket) * self.u_bucket, 1)
        if u_pow2:
            u_pad = self.u_bucket * ops._next_pow2(
                -(-n_real // self.u_bucket))
        # pack + inert-tail masking on device (no host round trip; the
        # dynamic n_real scalar shares one executable across round sizes)
        sel_dev, qmask_dev = ops.pack_round_masked(
            seq_dev, jnp.asarray(take), prio0, n_real, p=p, u_pad=u_pad)
        # stats from the host-side plan data the caller already holds —
        # the packed plan itself never leaves the device
        sizes_kept = self._sizes[np.asarray(kept, dtype=np.int64)]
        vectors = int(sizes_kept.sum())
        if seq_host is not None:
            comparisons = int(self._sizes[seq_host[take]].sum())
        else:
            comparisons = vectors
        st = {"partitions": int(n_real), "vectors": vectors,
              "comparisons": comparisons}
        if snap.scales is not None:
            d, flat = ops.scan_selected_topk_q8(
                q_dev, snap.data, snap.scales, self._valid,
                sel_dev, qmask_dev, k_keep,
                metric=self.index.config.metric, centroids=snap.centroids)
        else:
            d, flat = ops.scan_selected_topk(
                q_dev, snap.data, self._valid, sel_dev, qmask_dev,
                k_keep, metric=self.index.config.metric,
                impl=impl or self.impl)
        return d, flat, st

    def _search_rounds(self, q: np.ndarray, k: int, target: float,
                       rounds: Optional[int],
                       impl: Optional[str] = None,
                       snap=None) -> BatchResult:
        """Multi-round early-exit search (Algorithm 2 semantics): the
        planned probe sequences are chunked into geometrically growing
        rounds; each round packs only *live* queries' next probes
        (``ops.pack_round``), scans them once
        (``scan_selected_topk``/``_q8``), folds the result into a
        device-resident running top-k, and the shared round driver
        re-estimates per-query recall from the running k-th distance —
        queries that clear the target stop paying for further rounds."""
        idx = self.index
        snap = self.snapshot() if snap is None else snap
        rplan = plan_rounds(idx, q, k, target, planner=self.planner,
                            cache=self.planner_cache,
                            cent_norms=self._cent_norms)
        q_dev = jnp.asarray(q)
        seq_dev = rplan.seq_dev if rplan.seq_dev is not None \
            else jnp.asarray(rplan.seq.astype(np.int32))
        rerank = (snap.scales is not None and self.int8_rerank
                  and self._host_f32 is not None)
        k_keep = 2 * k if rerank else k

        def scan_round(take, kept):
            return self.scan_probe_round(q_dev, seq_dev, take, kept,
                                         k_keep, snap=snap, impl=impl,
                                         seq_host=rplan.seq)

        td, ti, nprobe, r_est, n_rounds, trace, stats = run_round_loop(
            rplan, k, target, idx._beta_table, _batch_rho_fn(idx, q),
            scan_round, rounds=rounds, k_keep=k_keep)
        if rerank:
            # quakecheck: allow-sync(int8 rerank gathers from the host f32 mirror)
            dd, flat = self._rerank_exact(q, np.asarray(ti), k)
        else:
            # quakecheck: allow-sync(result boundary: BatchResult is a host contract)
            dd = np.asarray(td, dtype=np.float64)[:, :k]
            flat = np.asarray(ti)[:, :k]  # quakecheck: allow-sync(result boundary)
        ids = np.where(flat >= 0,
                       self._flat_ids[np.maximum(flat, 0)], -1)
        dd = np.where(dd >= MASK_DIST, np.inf, dd)
        return BatchResult(
            ids=ids.astype(np.int64), dists=dd,
            partitions_scanned=stats["partitions"],
            vectors_scanned=stats["vectors"],
            comparisons=stats["comparisons"],
            nprobe=nprobe, recall_estimate=r_est,
            rounds=n_rounds, round_trace=trace)


def get_executor(index: QuakeIndex,
                 storage_dtype: Optional[str] = None
                 ) -> BatchedSearchExecutor:
    """The index's cached executor for ``storage_dtype`` (snapshot reuse
    across calls; one executor — and one device snapshot — per storage
    format).  ``None`` means the default f32 executor."""
    key = storage_dtype or "f32"
    cache = getattr(index, "_batch_executors", None)
    if cache is None:
        cache = index._batch_executors = {}
    ex = cache.get(key)
    if ex is None or ex.index is not index:
        # identity guard: a transplanted __dict__ (copy/pickle) carries
        # the cache but its executors still point at the source index
        ex = BatchedSearchExecutor(index, storage_dtype=key)
        cache[key] = ex
    return ex


def batch_search(index: QuakeIndex, queries: np.ndarray, k: int,
                 nprobe: Optional[int] = None,
                 recall_target: Optional[float] = None,
                 impl: str = "auto",
                 union_cap: Optional[int] = None,
                 storage_dtype: Optional[str] = None,
                 rounds: Optional[int] = None) -> BatchResult:
    """Scan-each-partition-once batched search over the dynamic index.

    Partition selection per query uses centroid order with a fixed
    ``nprobe`` (the policy in the paper's Fig. 5 experiment), or, when
    ``nprobe`` is None, APS-driven per-query probe counts (see
    ``plan_batch``) executed as multi-round early-exit probe rounds
    (Algorithm 2; ``rounds=1`` forces the monolithic fixed-plan scan).
    The scan itself is device-resident packed union scans;
    ``storage_dtype`` picks the f32/bf16/int8 snapshot format and
    ``union_cap`` bounds the scanned union under read skew (plan-level
    truncation — capped searches take the one-shot fixed plan).
    """
    return get_executor(index, storage_dtype).search(
        queries, k, nprobe=nprobe, recall_target=recall_target, impl=impl,
        union_cap=union_cap, rounds=rounds)


def per_query_search(index: QuakeIndex, queries: np.ndarray, k: int,
                     nprobe: Optional[int] = None,
                     recall_target: Optional[float] = None,
                     impl: str = "auto") -> BatchResult:
    """Baseline: one-at-a-time search — the B=1 case of the same executor,
    so partitions are re-scanned per query (Faiss-IVF behaviour) but the
    code path and kernels are identical to the batched policy, including
    the APS planner when ``recall_target`` drives probe counts."""
    q = np.ascontiguousarray(queries, dtype=np.float32)
    if q.shape[0] == 0:
        return BatchResult(ids=np.zeros((0, k), dtype=np.int64),
                           dists=np.zeros((0, k), dtype=np.float64),
                           nprobe=np.zeros(0, dtype=np.int64))
    ex = get_executor(index)
    ids, dists, parts, vecs, comps = [], [], 0, 0, 0
    nps, rests, max_rounds = [], [], 1
    for row in q:
        r = ex.search(row[None, :], k, nprobe=nprobe,
                      recall_target=recall_target, impl=impl)
        ids.append(r.ids[0])
        dists.append(r.dists[0])
        parts += r.partitions_scanned
        vecs += r.vectors_scanned
        comps += r.comparisons
        nps.append(int(r.nprobe[0]) if r.nprobe is not None else 0)
        rests.append(float(r.recall_estimate[0])
                     if r.recall_estimate is not None else np.nan)
        max_rounds = max(max_rounds, r.rounds)
    rest = np.asarray(rests)
    return BatchResult(ids=np.stack(ids), dists=np.stack(dists),
                       partitions_scanned=parts, vectors_scanned=vecs,
                       comparisons=comps, nprobe=np.asarray(nps),
                       recall_estimate=None if np.isnan(rest).all()
                       else rest, rounds=max_rounds)
