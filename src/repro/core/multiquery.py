"""Batched multi-query execution (paper §7.4, policy from [26]/[34]).

Single-query processing scans each needed partition once *per query*; with a
batch we invert the mapping — group queries by the partitions they access and
scan every needed partition exactly **once per batch**, amortizing the
partition read across all queries that probe it.  On TPU this turns B
GEMVs per partition into one (B_p, d) x (d, s) GEMM — MXU-shaped work.

The mesh-sharded equivalent for very large batches degenerates to
``ShardedQuakeEngine.search_bruteforce`` (every partition needed by someone);
this host-side implementation covers the dynamic-index engine and the QPS
benchmark.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from .index import QuakeIndex


@dataclass
class BatchResult:
    ids: np.ndarray        # (B, k)
    dists: np.ndarray      # (B, k) minimization convention
    partitions_scanned: int = 0
    vectors_scanned: int = 0


def batch_search(index: QuakeIndex, queries: np.ndarray, k: int,
                 nprobe: Optional[int] = None,
                 recall_target: Optional[float] = None) -> BatchResult:
    """Scan-each-partition-once batched search over the dynamic index.

    Partition selection per query uses centroid order with a fixed ``nprobe``
    (the policy in the paper's Fig. 5 experiment), or, when ``nprobe`` is
    None, the per-query APS nprobe from a calibration pass over a sample of
    the batch (cheap adaptive hybrid: APS picks *how many*, the batch
    executor amortizes *the scanning*).
    """
    q = np.ascontiguousarray(queries, dtype=np.float32)
    b, d = q.shape
    lvl0 = index.levels[0]
    cents = lvl0.centroids
    p = cents.shape[0]

    if nprobe is None:
        sample = q[np.linspace(0, b - 1, min(16, b)).astype(int)]
        probes = [index.search(s, k,
                               recall_target=recall_target or
                               index.config.recall_target,
                               record_stats=False).nprobe[0]
                  for s in sample]
        nprobe = int(np.ceil(np.percentile(probes, 90)))
    nprobe = max(1, min(nprobe, p))

    # ---- route: per-query nprobe nearest centroids (one GEMM) ----
    if index.config.metric == "l2":
        cd = (np.sum(q * q, 1)[:, None] + np.sum(cents * cents, 1)[None, :]
              - 2.0 * (q @ cents.T))
    else:
        cd = -(q @ cents.T)
    sel = np.argpartition(cd, nprobe - 1, axis=1)[:, :nprobe]   # (B, nprobe)

    # ---- invert: partition -> queries ----
    part_queries: Dict[int, List[int]] = {}
    flat_parts = sel.ravel()
    flat_qids = np.repeat(np.arange(b), nprobe)
    order = np.argsort(flat_parts, kind="stable")
    fp, fq = flat_parts[order], flat_qids[order]
    bounds = np.searchsorted(fp, np.arange(p + 1))

    out_d = np.full((b, k), np.inf, dtype=np.float64)
    out_i = np.full((b, k), -1, dtype=np.int64)
    parts_scanned = 0
    vecs_scanned = 0

    # ---- scan each needed partition once, against its query group ----
    for j in range(p):
        lo, hi = bounds[j], bounds[j + 1]
        if lo == hi:
            continue
        qids = fq[lo:hi]
        x = lvl0.vectors[j]
        s = x.shape[0]
        if s == 0:
            continue
        parts_scanned += 1
        vecs_scanned += s * len(qids)
        qs = q[qids]
        if index.config.metric == "l2":
            dist = (lvl0.sqnorms[j][None, :] - 2.0 * (qs @ x.T)
                    + np.sum(qs * qs, 1)[:, None])
        else:
            dist = -(qs @ x.T)
        kk = min(k, s)
        if s > kk:
            part = np.argpartition(dist, kk - 1, axis=1)[:, :kk]
        else:
            part = np.broadcast_to(np.arange(s), (len(qids), s))
        pd = np.take_along_axis(dist, part, axis=1)
        pi = lvl0.ids[j][part]
        # merge into running top-k rows for these queries
        md = np.concatenate([out_d[qids], pd], axis=1)
        mi = np.concatenate([out_i[qids], pi], axis=1)
        sel2 = np.argpartition(md, k - 1, axis=1)[:, :k]
        out_d[qids] = np.take_along_axis(md, sel2, axis=1)
        out_i[qids] = np.take_along_axis(mi, sel2, axis=1)

    # final per-row sort
    o = np.argsort(out_d, axis=1, kind="stable")
    return BatchResult(ids=np.take_along_axis(out_i, o, axis=1),
                       dists=np.take_along_axis(out_d, o, axis=1),
                       partitions_scanned=parts_scanned,
                       vectors_scanned=vecs_scanned)


def per_query_search(index: QuakeIndex, queries: np.ndarray, k: int,
                     nprobe: Optional[int] = None) -> BatchResult:
    """Baseline: one-at-a-time search (partitions re-scanned per query)."""
    ids, dists = [], []
    vecs = 0
    for q in queries:
        r = index.search(q, k, nprobe=nprobe, record_stats=False)
        pad = k - len(r.ids)
        ids.append(np.pad(r.ids, (0, pad), constant_values=-1))
        dists.append(np.pad(r.dists, (0, pad), constant_values=np.inf))
        vecs += r.vectors_scanned
    return BatchResult(ids=np.stack(ids), dists=np.stack(dists),
                       vectors_scanned=vecs)
