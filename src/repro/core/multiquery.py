"""Device-resident batched multi-query executor (paper §7.4, policy from
[26]/[34] — the incremental-IVF maintenance line of Mohoney et al.).

Single-query processing scans each needed partition once *per query*; with a
batch we invert the mapping — group queries by the partitions they access and
scan every needed partition exactly **once per batch**, amortizing the
partition read across all queries that probe it.  On TPU this turns B GEMVs
per partition into one ``(B_p, d) x (d, s)`` GEMM — MXU-shaped work.

Architecture (this module is the host-side control plane; the scan is the
same packed-scan primitive the sharded engine uses per shard):

  1. **Plan** (host): per-query probe sets, either a fixed ``nprobe`` (the
     paper's Fig. 5 policy) or APS-driven per-query counts — the estimator
     math of ``aps.estimate_probs_np`` run against a radius calibrated on a
     sample of the batch (APS picks *how many*, the batch executor amortizes
     *the scanning*).
  2. **Pack** (host): the batch's probe sets collapse into one partition
     union + a per-query ``(B, U)`` mask (`kernels.ops.pack_union` is the
     device-side twin used inside the sharded engine).
  3. **Scan** (device): one call to ``kernels.ops.scan_selected_topk`` —
     the scalar-prefetch ``scan_topk_indexed`` Pallas kernel streams each
     selected partition HBM->VMEM exactly once and folds the running top-k
     in VMEM (interpret mode on CPU CI, Mosaic on TPU; ``impl="jnp"`` is
     the XLA oracle path).

Single-query search is the B=1 case of the same executor
(``per_query_search`` below, and ``QuakeIndex.search_batch`` with one row);
the mesh-sharded equivalent for very large batches degenerates to
``ShardedQuakeEngine.search_bruteforce``.

The executor serves a cached ``IndexSnapshot`` of the dynamic index
(copy-on-write semantics, paper §8.2), kept coherent through the index's
mutation journal: dirty-partition deltas patch only the touched rows on
device; structural changes (split/merge/level, capacity overflow) fall
back to a full rebuild.  See ``docs/snapshot_lifecycle.md``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from ..kernels import ops
from ..kernels.ref import MASK_DIST
from . import aps as aps_mod
from .index import QuakeIndex


@dataclass
class BatchResult:
    ids: np.ndarray        # (B, k) external ids, -1 on misses
    dists: np.ndarray      # (B, k) minimization convention, inf on misses
    partitions_scanned: int = 0   # distinct partitions streamed (union size)
    vectors_scanned: int = 0      # vectors streamed from memory: each union
                                  # partition is read once for the whole batch
    comparisons: int = 0          # query-vector distance evaluations (the
                                  # per-query-loop equivalent of
                                  # vectors_scanned; ratio = amortization)
    nprobe: Optional[np.ndarray] = None   # (B,) planned probes per query


@dataclass
class BatchPlan:
    """Output of the host-side batch planner."""
    sel: np.ndarray      # (U_pad,) union partition ids (tail entries may
                         # duplicate sel[0] for tile-count padding)
    qmask: np.ndarray    # (B, U_pad) bool — query b probes union slot u
    nprobe: np.ndarray   # (B,) per-query probe count
    n_real: int          # distinct real partitions (sel[:n_real] unique)


def _centroid_dists(index: QuakeIndex, q: np.ndarray) -> np.ndarray:
    """(B, P) level-0 centroid distances in scan-order convention
    (squared L2, or -score for IP — both rank like the geometry dists)."""
    cents = index.levels[0].centroids
    if index.config.metric == "l2":
        return (np.sum(q * q, 1)[:, None] + np.sum(cents * cents, 1)[None, :]
                - 2.0 * (q @ cents.T))
    return -(q @ cents.T)


def _aps_probe_counts(index: QuakeIndex, q: np.ndarray, k: int,
                      target: float
                      ) -> Tuple[np.ndarray, np.ndarray, int]:
    """APS-driven per-query probe sets: the paper's recall estimator run as
    a *planner* — the radius rho comes from full APS searches on a small
    sample of the batch, then every query picks the smallest probe set whose
    estimated recall clears the target.  Returns (sel (B, n_max), valid
    (B, n_max), per-query probe counts (B,))."""
    b = q.shape[0]
    p = index.levels[0].num_partitions
    cfg = index.config
    n_consider = min(max(int(np.ceil(cfg.f_m * p)), cfg.min_candidates), p)

    # --- calibrate the k-NN radius on a batch sample (full host APS) ---
    sample = np.linspace(0, b - 1, min(8, b)).astype(int)
    kths = []
    for s in np.unique(sample):
        r = index.search(q[s], k, recall_target=target, record_stats=False)
        if len(r.dists):
            kths.append(float(r.dists[min(k, len(r.dists)) - 1]))
    kth_med = float(np.median(kths)) if kths else np.inf

    sel = np.zeros((b, n_consider), dtype=np.int64)
    valid = np.zeros((b, n_consider), dtype=bool)
    counts = np.empty(b, dtype=np.int64)
    table = index._beta_table
    for i in range(b):
        qi = q[i]
        geo, _ = index._centroid_geo_dists(qi, 0, np.arange(p))
        order = np.argsort(geo, kind="stable")[:n_consider]
        rho_fn = index._rho_sq_from_item_dist(
            float(np.sum(qi.astype(np.float64) ** 2)))
        rho_sq = rho_fn(kth_med) if np.isfinite(kth_med) else np.inf
        if not np.isfinite(rho_sq) or rho_sq <= 0 or len(order) == 1:
            m = len(order)  # no radius: conservative full candidate scan
            probes = order
        else:
            cc = index._centroid_cc_dists(0, order, 0)
            vmask = np.ones(len(order), dtype=bool)
            vmask[0] = False
            p0, probs = aps_mod.estimate_probs_np(
                float(geo[order[0]]), geo[order].astype(np.float64),
                cc, rho_sq, table, vmask)
            if p0 >= target:
                m, probes = 1, order[:1]
            else:
                desc = np.argsort(-probs, kind="stable")
                desc = desc[desc != 0]     # nearest is always scanned
                r_cum = p0 + np.cumsum(probs[desc])
                reach = np.nonzero(r_cum >= target)[0]
                extra = (reach[0] + 1) if len(reach) else len(desc)
                m = int(min(1 + extra, len(order)))
                probes = np.concatenate([order[:1], order[desc[:m - 1]]])
        sel[i, :m] = probes
        valid[i, :m] = True
        counts[i] = m
    n_max = int(counts.max())
    return sel[:, :n_max], valid[:, :n_max], counts


def plan_batch(index: QuakeIndex, q: np.ndarray, k: int,
               nprobe: Optional[int] = None,
               recall_target: Optional[float] = None,
               u_bucket: int = 8) -> BatchPlan:
    """Plan one batched scan: per-query probe sets -> partition union +
    per-query mask.  ``u_bucket`` rounds the union size up so the jitted
    scan sees few distinct shapes (pad slots duplicate a real partition and
    carry an all-False mask — they add work, never wrong results)."""
    b = q.shape[0]
    p = index.levels[0].num_partitions

    if b == 0:
        # empty batch: one inert pad slot, no query rows
        return BatchPlan(sel=np.zeros(1, dtype=np.int64),
                         qmask=np.zeros((0, 1), dtype=bool),
                         nprobe=np.zeros(0, dtype=np.int64), n_real=0)

    if nprobe is not None:
        cd = _centroid_dists(index, q)
        n = int(max(1, min(nprobe, p)))
        if n < p:
            sel_q = np.argpartition(cd, n - 1, axis=1)[:, :n]
        else:
            sel_q = np.broadcast_to(np.arange(p), (b, p)).copy()
        qvalid = np.ones((b, n), dtype=bool)
        counts = np.full(b, n, dtype=np.int64)
    else:
        target = recall_target if recall_target is not None \
            else index.config.recall_target
        sel_q, qvalid, counts = _aps_probe_counts(index, q, k, target)

    union = np.unique(sel_q[qvalid])
    u = len(union)
    u_pad = max(-(-u // u_bucket) * u_bucket, 1)
    sel = np.concatenate([union, np.full(u_pad - u, union[0],
                                         dtype=union.dtype)])
    qmask = np.zeros((b, u_pad), dtype=bool)
    pos = np.searchsorted(union, sel_q)          # only valid where qvalid
    rows = np.broadcast_to(np.arange(b)[:, None], sel_q.shape)
    qmask[rows[qvalid], pos[qvalid]] = True
    return BatchPlan(sel=sel, qmask=qmask, nprobe=counts, n_real=u)


class BatchedSearchExecutor:
    """Executes planned batches against a device-resident snapshot.

    The snapshot (dense ``(P, S_cap, d)`` + ids + sizes) is cached and kept
    coherent with the dynamic index through its mutation journal: content
    mutations confined to known partitions (insert/delete/refine) patch
    only the touched rows on device (``IndexSnapshot.apply_delta``, COW
    semantics — paper §8.2), while structural changes (split/merge/level),
    capacity overflow, or a dirty set larger than
    ``config.snapshot_max_dirty_frac * P`` fall back to a full rebuild.
    Full rebuilds allocate ``config.snapshot_headroom`` slack capacity so
    insert deltas rarely force a reshape.  Searches then run one packed
    union scan per batch.
    """

    def __init__(self, index: QuakeIndex, impl: str = "auto",
                 u_bucket: int = 8, headroom: Optional[float] = None,
                 max_dirty_frac: Optional[float] = None):
        self.index = index
        self.impl = impl
        self.u_bucket = u_bucket
        cfg = index.config
        self.headroom = cfg.snapshot_headroom if headroom is None \
            else headroom
        self.max_dirty_frac = cfg.snapshot_max_dirty_frac \
            if max_dirty_frac is None else max_dirty_frac
        self._snap = None
        self._key = None         # fingerprint the snapshot reflects
        self._valid = None       # (P, S_cap) bool, device
        self._flat_ids = None    # (P*S_cap,) host
        self._sizes = None       # (P,) host
        self.full_rebuilds = 0   # refresh telemetry (tests / bench)
        self.delta_refreshes = 0

    def _fingerprint(self):
        return (self.index.version, self.index.num_partitions,
                self.index.num_vectors)

    def refresh(self):
        """Full rebuild of the device snapshot from the dynamic index."""
        from .distributed import IndexSnapshot  # late: avoid import cycle
        self._snap = IndexSnapshot.from_index(self.index,
                                              headroom=self.headroom)
        self._valid = self._snap.ids >= 0
        self._flat_ids = np.array(self._snap.ids).reshape(-1)
        self._sizes = np.array(self._snap.sizes)
        self._key = self._fingerprint()
        self.full_rebuilds += 1
        return self._snap

    def _refresh_delta(self, delta) -> bool:
        """Patch the dirty partition rows in place of a rebuild.  Returns
        False when the delta is not applicable (structural change, capacity
        overflow, dirty set too large) — caller falls back to ``refresh``.
        """
        from .distributed import IndexSnapshot  # late: avoid import cycle
        idx = self.index
        lvl0 = idx.levels[0]
        p_real = lvl0.num_partitions
        if delta.structural or p_real > self._snap.num_partitions:
            return False
        dirty = sorted(j for j in delta.dirty if j < p_real)
        if len(dirty) > self.max_dirty_frac * max(p_real, 1):
            return False
        if not dirty:
            # clock moved without base-level content changes (e.g. an
            # upper-level split): snapshot already coherent
            self._key = self._fingerprint()
            return True
        cap = self._snap.capacity
        if max(len(lvl0.vectors[j]) for j in dirty) > cap:
            return False      # a partition outgrew its slack slots
        try:
            patch = IndexSnapshot.build_patch(idx, dirty, cap)
            # donate: the executor owns its cached snapshot exclusively,
            # so the patch updates the device buffers in place — refresh
            # cost is O(dirty rows), not O(index)
            self._snap = self._snap.apply_delta(patch, donate=True)
        except ValueError:
            return False
        from .distributed import _scatter_rows_donated
        sel = patch.rows
        self._valid = _scatter_rows_donated(
            self._valid, jnp.asarray(sel), jnp.asarray(patch.ids >= 0))
        self._flat_ids.reshape(self._snap.num_partitions, cap)[sel] = \
            patch.ids
        self._sizes[sel] = patch.sizes
        self._key = self._fingerprint()
        self.delta_refreshes += 1
        return True

    def snapshot(self):
        if self._snap is None:
            return self.refresh()
        fp = self._fingerprint()
        if self._key == fp:
            return self._snap
        delta = self.index.journal.delta_since(self._key[0])
        if delta is None or not self._refresh_delta(delta):
            self.refresh()
        return self._snap

    def search(self, queries: np.ndarray, k: int,
               nprobe: Optional[int] = None,
               recall_target: Optional[float] = None,
               impl: Optional[str] = None) -> BatchResult:
        q = np.ascontiguousarray(queries, dtype=np.float32)
        if q.ndim == 1:
            q = q[None, :]
        if q.shape[0] == 0:
            return BatchResult(ids=np.zeros((0, k), dtype=np.int64),
                               dists=np.zeros((0, k), dtype=np.float64),
                               nprobe=np.zeros(0, dtype=np.int64))
        snap = self.snapshot()
        plan = plan_batch(self.index, q, k, nprobe=nprobe,
                          recall_target=recall_target,
                          u_bucket=self.u_bucket)
        dd, flat = ops.scan_selected_topk(
            jnp.asarray(q), snap.data, self._valid,
            jnp.asarray(plan.sel.astype(np.int32)),
            jnp.asarray(plan.qmask), k,
            metric=self.index.config.metric, impl=impl or self.impl)
        dd = np.asarray(dd, dtype=np.float64)
        flat = np.asarray(flat)
        ids = np.where(flat >= 0,
                       self._flat_ids[np.maximum(flat, 0)], -1)
        dd = np.where(dd >= MASK_DIST, np.inf, dd)

        sizes_sel = self._sizes[plan.sel[:plan.n_real]]
        return BatchResult(
            ids=ids.astype(np.int64), dists=dd,
            partitions_scanned=int(plan.n_real),
            vectors_scanned=int(sizes_sel.sum()),
            comparisons=int((plan.qmask[:, :plan.n_real].astype(np.int64)
                             * sizes_sel[None, :]).sum()),
            nprobe=plan.nprobe)


def get_executor(index: QuakeIndex) -> BatchedSearchExecutor:
    """The index's cached executor (snapshot reuse across calls)."""
    ex = getattr(index, "_batch_executor", None)
    if ex is None or ex.index is not index:
        ex = BatchedSearchExecutor(index)
        index._batch_executor = ex
    return ex


def batch_search(index: QuakeIndex, queries: np.ndarray, k: int,
                 nprobe: Optional[int] = None,
                 recall_target: Optional[float] = None,
                 impl: str = "auto") -> BatchResult:
    """Scan-each-partition-once batched search over the dynamic index.

    Partition selection per query uses centroid order with a fixed
    ``nprobe`` (the policy in the paper's Fig. 5 experiment), or, when
    ``nprobe`` is None, APS-driven per-query probe counts (see
    ``plan_batch``).  The scan itself is one device-resident packed union
    scan per batch.
    """
    return get_executor(index).search(queries, k, nprobe=nprobe,
                                      recall_target=recall_target, impl=impl)


def per_query_search(index: QuakeIndex, queries: np.ndarray, k: int,
                     nprobe: Optional[int] = None,
                     impl: str = "auto") -> BatchResult:
    """Baseline: one-at-a-time search — the B=1 case of the same executor,
    so partitions are re-scanned per query (Faiss-IVF behaviour) but the
    code path and kernels are identical to the batched policy."""
    q = np.ascontiguousarray(queries, dtype=np.float32)
    if q.shape[0] == 0:
        return BatchResult(ids=np.zeros((0, k), dtype=np.int64),
                           dists=np.zeros((0, k), dtype=np.float64),
                           nprobe=np.zeros(0, dtype=np.int64))
    ex = get_executor(index)
    ids, dists, parts, vecs, comps = [], [], 0, 0, 0
    nps = []
    for row in q:
        r = ex.search(row[None, :], k, nprobe=nprobe, impl=impl)
        ids.append(r.ids[0])
        dists.append(r.dists[0])
        parts += r.partitions_scanned
        vecs += r.vectors_scanned
        comps += r.comparisons
        nps.append(int(r.nprobe[0]) if r.nprobe is not None else 0)
    return BatchResult(ids=np.stack(ids), dists=np.stack(dists),
                       partitions_scanned=parts, vectors_scanned=vecs,
                       comparisons=comps, nprobe=np.asarray(nps))
