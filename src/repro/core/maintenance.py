"""Adaptive incremental maintenance (paper §4).

Bottom-up pass over the hierarchy; per level the five stages:

  Stage 0  statistics are tracked continuously by the index (sizes + access
           frequencies over the sliding window W),
  Stage 1  *estimate*: Δ'Split (Eq. 6) / Δ'Merge (uniform-redistribution
           Eq. 5) for every partition; actions with Δ' < -τ become tentative,
  Stage 2  *verify*: the action's outcome is computed (2-means child sizes /
           actual receiver sets) and the exact Δ (Eqs. 4/5) re-evaluated with
           measured sizes but Stage-1 frequency assumptions,
  Stage 3  *commit / reject*: commit iff Δ < -τ — this is what makes total
           cost monotonically non-increasing under a fixed workload,
  Stage 4  propagate to level l+1.

Our verify is *virtual*: the split assignment / receiver assignment is
computed without mutating the index, the exact Δ evaluated, and only a commit
mutates — semantically identical to apply-then-rollback but cheaper.

Split commits are followed by partition refinement (k-means seeded with
current centroids over the r_f neighboring partitions, paper §4.2.1), whose
cost-model effect is intentionally unmodeled (captured by future statistics).

Generalization note: the paper's centroid-overhead term ΔO± = λ(N_l ± 1) −
λ(N_l) treats the centroid list as one flat scan.  With a parent level
present the new centroid lands in a specific parent partition; we charge
A_parent · (λ(s_parent ± 1) − λ(s_parent)) instead, which reduces exactly to
the paper's formula in the single-level case (implicit top: A = 1,
s = N_l).
"""
from __future__ import annotations

import copy
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from . import cost_model as cm
from . import kmeans
from .cost_model import LatencyModel
from .index import Level, QuakeIndex

__all__ = ["Maintainer", "MaintenanceReport", "MaintenancePolicy",
           "checkpoint_index", "restore_index"]


# ---------------------------------------------------------------------------
# Crash recovery: checkpoint / restore around a maintenance pass
# ---------------------------------------------------------------------------

def checkpoint_index(index: QuakeIndex) -> dict:
    """Deep snapshot of everything a maintenance pass may mutate, so a
    crash mid-recluster (split/merge committed, pass not finished) can
    roll back to exactly the pre-pass state — including the journal, so
    ``index.version`` is unchanged and snapshot/cache consumers keyed on
    it stay coherent.  Levels hold numpy containers plus
    ``PartitionStats``; ``copy.deepcopy`` covers both."""
    j = index.journal
    return {
        "levels": copy.deepcopy(index.levels),
        "id_map": dict(index.id_map),
        "max_norm_sq": index._max_norm_sq,
        "maintenance_log_len": len(index.maintenance_log),
        "journal_version": j.version,
        "journal_entries": list(j._entries),
        "journal_floor": j._floor,
        "journal_overflowed": j.overflowed,
        "journal_overflow_count": j.overflow_count,
    }


def restore_index(index: QuakeIndex, ckpt: dict) -> None:
    """Roll the index back to a :func:`checkpoint_index` state."""
    index.levels = ckpt["levels"]
    index.id_map = ckpt["id_map"]
    index._max_norm_sq = ckpt["max_norm_sq"]
    del index.maintenance_log[ckpt["maintenance_log_len"]:]
    index._aug_extra = [None] * len(index.levels)
    j = index.journal
    j.version = ckpt["journal_version"]
    j._entries = deque(ckpt["journal_entries"])
    j._floor = ckpt["journal_floor"]
    # .get: tolerate pre-overflow-flag checkpoints (dicts are in-process
    # only, but restore must not KeyError on one taken before the flag
    # existed in a mixed-version test)
    j.overflowed = ckpt.get("journal_overflowed", j.overflowed)
    j.overflow_count = ckpt.get("journal_overflow_count", j.overflow_count)


@dataclass
class MaintenancePolicy:
    """Ablation switches (paper Table 7 variants)."""
    use_cost_model: bool = True     # False -> size-threshold policy (NoCost)
    use_refinement: bool = True     # False -> NoRef
    use_rejection: bool = True      # False -> NoRej (skip verify gate)
    split_size_threshold: float = 2.0   # NoCost: split if size > thr * mean
    merge_size_threshold: float = 0.2   # NoCost: merge if size < thr * mean


@dataclass
class MaintenanceReport:
    cost_before: float = 0.0
    cost_after: float = 0.0
    splits: int = 0
    merges: int = 0
    rejected_splits: int = 0
    rejected_merges: int = 0
    level_added: bool = False
    level_removed: bool = False
    actions: List[dict] = field(default_factory=list)


class Maintainer:
    """Drives maintenance for a QuakeIndex against a latency model."""

    def __init__(self, index: QuakeIndex, lam: Optional[LatencyModel] = None,
                 policy: Optional[MaintenancePolicy] = None):
        self.index = index
        self.lam = lam or LatencyModel(dim=index.dim)
        self.policy = policy or MaintenancePolicy()
        # optional repro.faults.FaultInjector: when set, every committed
        # split/merge is an arrival at the "maintenance" site, so a
        # chaos run crashes the pass *after* the index has mutated —
        # the serving runtime's checkpoint/rollback is what makes that
        # survivable (docs/serving.md failure semantics)
        self.faults = None

    # ------------------------------------------------------------------
    # Cost accounting
    # ------------------------------------------------------------------

    def level_freqs(self, l: int) -> np.ndarray:
        level = self.index.levels[l]
        return level.stats.access_freq(level.num_partitions,
                                       self.index.config.default_access_freq)

    def total_cost(self) -> float:
        """Paper Eq. (2) over all levels, plus the implicit top scan."""
        idx = self.index
        c = 0.0
        for l, level in enumerate(idx.levels):
            c += float(np.sum(self.level_freqs(l)
                              * self.lam(level.sizes())))
        c += float(self.lam(idx.levels[-1].num_partitions))  # top centroids
        return c

    def _parent_overhead(self, l: int, delta: int) -> float:
        """A_parent * (λ(s_p + delta) - λ(s_p)); implicit top if l is top."""
        idx = self.index
        if l == len(idx.levels) - 1:
            n = idx.levels[l].num_partitions
            return float(self.lam(n + delta) - self.lam(n))
        # charge the *average* parent (estimate stage doesn't know which);
        # verify uses the actual parent
        parent_level = idx.levels[l + 1]
        freqs = self.level_freqs(l + 1)
        sizes = parent_level.sizes()
        return float(np.mean(freqs * (self.lam(sizes + delta)
                                      - self.lam(sizes))))

    def _parent_overhead_exact(self, l: int, j: int, delta: int) -> float:
        idx = self.index
        if l == len(idx.levels) - 1:
            n = idx.levels[l].num_partitions
            return float(self.lam(n + delta) - self.lam(n))
        p = int(idx.levels[l].parent[j])
        s = idx.levels[l + 1].partition_size(p)
        a = float(self.level_freqs(l + 1)[p])
        return a * float(self.lam(s + delta) - self.lam(s))

    # ------------------------------------------------------------------
    # Main loop
    # ------------------------------------------------------------------

    def run(self, reset_stats: bool = True) -> MaintenanceReport:
        idx = self.index
        version_before = idx.version
        rep = MaintenanceReport(cost_before=self.total_cost())
        for l in range(len(idx.levels)):
            self._run_level(l, rep)
        self._maybe_adjust_levels(rep)
        rep.cost_after = self.total_cost()
        # Snapshot invalidation rides on the journal entries written by the
        # committed actions themselves (split/merge/refine/level) — a pass
        # where nothing commits leaves the version clock untouched and no
        # consumer rebuilds anything.
        if reset_stats:
            for level in idx.levels:
                level.stats.reset()
        idx.maintenance_log.append(rep.__dict__ | {
            "partitions": [lv.num_partitions for lv in idx.levels],
            "version": idx.version,
            "journal": [{"version": e.version, "reason": e.reason,
                         "structural": e.structural,
                         "dirty": sorted(e.dirty)}
                        for e in idx.journal.entries_since(version_before)]})
        return rep

    # ------------------------------------------------------------------
    # Per-level pass
    # ------------------------------------------------------------------

    def _run_level(self, l: int, rep: MaintenanceReport) -> None:
        idx = self.index
        cfg = idx.config
        level = idx.levels[l]
        lam = self.lam
        pol = self.policy

        sizes = level.sizes().astype(np.float64)
        freqs = self.level_freqs(l).astype(np.float64)
        n_l = level.num_partitions
        if n_l <= 1:
            return

        # ---------------- Stage 1: estimate ----------------
        candidates: List[Tuple[float, str, int]] = []
        if pol.use_cost_model:
            d_over_p = self._parent_overhead(l, +1)
            d_over_m = self._parent_overhead(l, -1)
            for j in range(n_l):
                if sizes[j] >= 2:
                    est = (d_over_p - freqs[j] * lam(sizes[j])
                           + 2 * cfg.alpha * freqs[j] * lam(sizes[j] / 2))
                    if est < -cfg.tau_ns:
                        candidates.append((float(est), "split", j))
                if sizes[j] < cfg.min_partition_size and n_l > 2:
                    recv = self._nearest_partitions(l, j, 10)
                    est = cm.merge_delta_estimate(
                        lam, n_l, sizes[j], freqs[j], sizes[recv],
                        freqs[recv])
                    if est < -cfg.tau_ns:
                        candidates.append((float(est), "merge", j))
        else:
            # NoCost ablation: pure size thresholding (LIRE-style)
            mean_size = max(float(sizes.mean()), 1.0)
            for j in range(n_l):
                if sizes[j] > pol.split_size_threshold * mean_size \
                        and sizes[j] >= 2:
                    candidates.append((-np.inf, "split", j))
                elif sizes[j] < pol.merge_size_threshold * mean_size \
                        and n_l > 2:
                    candidates.append((-np.inf, "merge", j))

        candidates.sort(key=lambda t: t[0])
        touched: set = set()

        for est, kind, j in candidates:
            if j in touched or j >= level.num_partitions:
                continue
            if kind == "split":
                ok = self._try_split(l, j, float(freqs[j]), rep, touched)
                rep.splits += ok
                rep.rejected_splits += (not ok)
            else:
                ok = self._try_merge(l, j, float(freqs[j]), freqs, rep,
                                     touched)
                rep.merges += ok
                rep.rejected_merges += (not ok)

    # ------------------------------------------------------------------
    # Split
    # ------------------------------------------------------------------

    def _members(self, l: int, j: int) -> Tuple[np.ndarray, np.ndarray]:
        """(item vectors, item ids) of partition j at level l."""
        idx = self.index
        level = idx.levels[l]
        if level.vectors is not None:
            return level.vectors[j], level.ids[j]
        child = level.children[j]
        return idx.levels[l - 1].centroids[child], child

    def _try_split(self, l: int, j: int, freq: float,
                   rep: MaintenanceReport, touched: set) -> bool:
        idx = self.index
        cfg = idx.config
        level = idx.levels[l]
        x, ids = self._members(l, j)
        s = len(x)
        if s < 2:
            return False

        # ----- Stage 2: verify (virtual apply) -----
        c2, a2 = kmeans.split_two(x, seed=cfg.seed + j)
        s_l, s_r = int((a2 == 0).sum()), int((a2 == 1).sum())
        if s_l == 0 or s_r == 0:
            return False
        d_over = self._parent_overhead_exact(l, j, +1)
        delta = (d_over - freq * float(self.lam(s))
                 + cfg.alpha * freq * float(self.lam(s_l) + self.lam(s_r)))
        gate = self.policy.use_rejection and self.policy.use_cost_model
        committed = (delta < -cfg.tau_ns) if gate else True
        rep.actions.append({"level": l, "part": j, "kind": "split",
                            "delta": delta, "committed": committed,
                            "sizes": (s, s_l, s_r)})
        if not committed:
            return False

        # ----- Stage 3: commit -----
        new_j = level.num_partitions
        self._apply_split(l, j, c2, a2)
        if self.faults is not None:
            self.faults.check("maintenance")   # crash mid-recluster
        touched.update({j, new_j})
        if self.policy.use_refinement:
            self._refine_around(l, [j, new_j])
        return True

    def _apply_split(self, l: int, j: int, c2: np.ndarray, a2: np.ndarray
                     ) -> None:
        idx = self.index
        # base-level splits change the partition directory itself:
        # structural for snapshot consumers.  Upper-level splits only touch
        # planning structures — bump the clock, dirty nothing.
        idx.journal.record(structural=(l == 0),
                           reason="split" if l == 0 else "split_upper")
        level = idx.levels[l]
        new_j = level.num_partitions
        level.centroids = np.concatenate([level.centroids, c2[1:2]])
        level.centroids[j] = c2[0]
        if level.vectors is not None:
            x, ids_, sq = level.vectors[j], level.ids[j], level.sqnorms[j]
            keep, move = a2 == 0, a2 == 1
            level.vectors[j] = np.ascontiguousarray(x[keep])
            level.ids[j] = ids_[keep]
            level.sqnorms[j] = sq[keep]
            level.vectors.append(np.ascontiguousarray(x[move]))
            level.ids.append(ids_[move])
            level.sqnorms.append(sq[move])
            for ext in level.ids[new_j]:
                idx.id_map[int(ext)] = new_j
        else:
            child = level.children[j]
            level.children[j] = child[a2 == 0]
            level.children.append(child[a2 == 1])
            below = idx.levels[l - 1]
            below.parent[level.children[new_j]] = new_j
        # stats: children inherit alpha * parent's window hits
        level.stats.ensure(level.num_partitions - 1)
        level.stats.split(j, idx.config.alpha)
        # parent bookkeeping: the new centroid joins j's parent partition
        if l < len(idx.levels) - 1:
            p = int(level.parent[j])
            level.parent = np.append(level.parent, p)
            up = idx.levels[l + 1]
            up.children[p] = np.append(up.children[p], new_j)
        idx._aug_extra = [None] * len(idx.levels)

    def _refine_around(self, l: int, seeds: List[int]) -> None:
        """Partition refinement (paper §4.2.1): one k-means round seeded by
        current centroids over the r_f nearest partitions to the split."""
        idx = self.index
        cfg = idx.config
        level = idx.levels[l]
        neigh = set()
        for j in seeds:
            neigh.update(self._nearest_partitions(
                l, j, cfg.refine_radius).tolist())
        neigh.update(seeds)
        group = np.asarray(sorted(neigh), dtype=np.int64)
        if len(group) < 2:
            return
        parts = [self._members(l, int(g)) for g in group]
        if sum(len(p[0]) for p in parts) == 0:
            return
        # contents + centroids of exactly ``group`` change: a delta-
        # refreshable content mutation at the base level
        idx.journal.record(dirty=group if l == 0 else None,
                           reason="refine" if l == 0 else "refine_upper")
        cents, new_parts = kmeans.refine(
            parts, level.centroids[group], iters=cfg.refine_iters)
        level.centroids[group] = cents
        if level.vectors is not None:
            for g, (xg, ig) in zip(group, new_parts):
                g = int(g)
                level.vectors[g] = np.ascontiguousarray(xg)
                level.ids[g] = ig
                level.sqnorms[g] = np.sum(
                    xg.astype(np.float64) ** 2, axis=1).astype(np.float32)
                for ext in ig:
                    idx.id_map[int(ext)] = g
        else:
            below = idx.levels[l - 1]
            for g, (_, cg) in zip(group, new_parts):
                g = int(g)
                level.children[g] = cg.astype(np.int64)
                below.parent[level.children[g]] = g
        idx._aug_extra = [None] * len(idx.levels)

    # ------------------------------------------------------------------
    # Merge
    # ------------------------------------------------------------------

    def _nearest_partitions(self, l: int, j: int, r: int) -> np.ndarray:
        level = self.index.levels[l]
        c = level.centroids
        d = np.sum((c - c[j]) ** 2, axis=1)
        d[j] = np.inf
        r = min(r, level.num_partitions - 1)
        return np.argpartition(d, r - 1)[:r] if r >= 1 else \
            np.zeros(0, dtype=np.int64)

    def _try_merge(self, l: int, j: int, freq: float, freqs: np.ndarray,
                   rep: MaintenanceReport, touched: set) -> bool:
        idx = self.index
        cfg = idx.config
        level = idx.levels[l]
        n_l = level.num_partitions
        if n_l <= 2:
            return False
        x, ids = self._members(l, j)
        s = len(x)

        # ----- Stage 2: verify (virtual) -----
        if s > 0:
            mask = np.ones(n_l, dtype=bool)
            mask[j] = False
            others = np.where(mask)[0]
            sub = kmeans.assign(x, level.centroids[others])
            recv = others[sub]
        else:
            recv = np.zeros(0, dtype=np.int64)
        recv_ids, recv_counts = np.unique(recv, return_counts=True)
        if touched.intersection(recv_ids.tolist()):
            return False
        sizes = level.sizes().astype(np.float64)
        d_over = self._parent_overhead_exact(l, j, -1)
        extra_freq = freq * (recv_counts / max(s, 1))
        delta = cm.merge_delta_verify(
            self.lam, n_l, s, freq, sizes[recv_ids],
            sizes[recv_ids] + recv_counts, freqs[recv_ids], extra_freq)
        gate = self.policy.use_rejection and self.policy.use_cost_model
        committed = (delta < -cfg.tau_ns) if gate else True
        rep.actions.append({"level": l, "part": j, "kind": "merge",
                            "delta": delta, "committed": committed,
                            "size": s, "receivers": len(recv_ids)})
        if not committed:
            return False

        # ----- Stage 3: commit -----
        self._apply_merge(l, j, recv, extra_hits=extra_freq,
                          recv_ids=recv_ids)
        if self.faults is not None:
            self.faults.check("maintenance")   # crash mid-recluster
        touched.update(recv_ids.tolist())
        touched.add(j)
        return True

    def _apply_merge(self, l: int, j: int, recv: np.ndarray,
                     extra_hits: np.ndarray, recv_ids: np.ndarray) -> None:
        idx = self.index
        # merges swap-remove a partition: the directory shrinks and the
        # last partition changes id — structural at the base level
        idx.journal.record(structural=(l == 0),
                           reason="merge" if l == 0 else "merge_upper")
        level = idx.levels[l]
        x, ids = self._members(l, j)
        # 1) move members to receivers
        if level.vectors is not None:
            sq = level.sqnorms[j]
            for m in recv_ids:
                sel = recv == m
                level.vectors[m] = np.concatenate([level.vectors[m], x[sel]])
                level.ids[m] = np.concatenate([level.ids[m], ids[sel]])
                level.sqnorms[m] = np.concatenate([level.sqnorms[m], sq[sel]])
                for ext in ids[sel]:
                    idx.id_map[int(ext)] = int(m)
        else:
            below = idx.levels[l - 1]
            for m in recv_ids:
                sel = recv == m
                level.children[m] = np.concatenate(
                    [level.children[m], ids[sel]])
                below.parent[ids[sel]] = int(m)
        # receiver frequency bump for later estimates in this round
        level.stats.ensure(level.num_partitions)
        level.stats.boost(recv_ids, extra_hits)

        # 2) swap-remove partition j
        last = level.num_partitions - 1
        if l < len(idx.levels) - 1:
            up = idx.levels[l + 1]
            pj = int(level.parent[j])
            up.children[pj] = up.children[pj][up.children[pj] != j]
        if j != last:
            level.centroids[j] = level.centroids[last]
            if level.vectors is not None:
                level.vectors[j] = level.vectors[last]
                level.ids[j] = level.ids[last]
                level.sqnorms[j] = level.sqnorms[last]
                for ext in level.ids[j]:
                    idx.id_map[int(ext)] = j
            else:
                level.children[j] = level.children[last]
                idx.levels[l - 1].parent[level.children[j]] = j
            if l < len(idx.levels) - 1:
                p_last = int(level.parent[last])
                up = idx.levels[l + 1]
                up.children[p_last] = np.where(
                    up.children[p_last] == last, j, up.children[p_last])
                level.parent[j] = p_last
        level.centroids = level.centroids[:last]
        if level.vectors is not None:
            level.vectors.pop()
            level.ids.pop()
            level.sqnorms.pop()
        else:
            level.children.pop()
        if level.parent is not None:
            level.parent = level.parent[:last]
        level.stats.remove(j)
        idx._aug_extra = [None] * len(idx.levels)

    # ------------------------------------------------------------------
    # Level add / remove (paper §4.2.1)
    # ------------------------------------------------------------------

    def _maybe_adjust_levels(self, rep: MaintenanceReport) -> None:
        idx = self.index
        cfg = idx.config
        top = idx.levels[-1]
        if top.num_partitions > cfg.level_add_threshold:
            p_new = max(2, int(round(np.sqrt(top.num_partitions))))
            idx._add_level_from(p_new)
            rep.level_added = True
        elif (len(idx.levels) > 1
              and top.num_partitions < cfg.level_remove_threshold):
            idx.remove_top_level()
            rep.level_removed = True
