"""Jit-compiled k-means for partition construction, split, and refinement.

Shapes are padded to power-of-2 buckets with a validity mask so the jit cache
stays bounded while partitions grow/shrink (the dynamic index calls this with
ever-changing sizes).  Empty clusters are reseeded to the points currently
farthest from their assigned centroid (standard Lloyd repair), keeping all k
clusters alive — Quake's maintenance assumes every partition has a centroid.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops
from ..kernels.ref import MASK_DIST, pairwise_l2_sq

Array = jax.Array


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


@functools.partial(jax.jit, static_argnames=("k", "iters"))
def _lloyd(xp: Array, mask: Array, init_c: Array, k: int, iters: int
           ) -> Tuple[Array, Array, Array]:
    """Masked Lloyd iterations.  xp (Np, d) padded points, mask (Np,) bool,
    init_c (k, d).  Returns (centroids, assign, objective)."""

    def step(c, _):
        d = pairwise_l2_sq(xp, c)                      # (Np, k)
        d = jnp.where(mask[:, None], d, MASK_DIST)
        assign = jnp.argmin(d, axis=1)
        mind = jnp.min(d, axis=1)
        w = mask.astype(xp.dtype)
        sums = jax.ops.segment_sum(xp * w[:, None], assign, num_segments=k)
        cnts = jax.ops.segment_sum(w, assign, num_segments=k)
        new_c = jnp.where(cnts[:, None] > 0,
                          sums / jnp.maximum(cnts[:, None], 1.0), c)
        # Reseed empties to the currently worst-fit points (masked-valid).
        worst = jnp.argsort(jnp.where(mask, -mind, -0.0))[:k]
        empty = cnts == 0
        new_c = jnp.where(empty[:, None], xp[worst], new_c)
        obj = jnp.sum(jnp.where(mask, mind, 0.0))
        return new_c, obj

    c, objs = jax.lax.scan(step, init_c, None, length=iters)
    d = pairwise_l2_sq(xp, c)
    d = jnp.where(mask[:, None], d, MASK_DIST)
    assign = jnp.argmin(d, axis=1).astype(jnp.int32)
    return c, assign, objs


def kmeans(x: np.ndarray, k: int, iters: int = 10, seed: int = 0,
           init: str = "random") -> Tuple[np.ndarray, np.ndarray]:
    """Host-friendly k-means.  x (n, d) numpy -> (centroids (k,d),
    assignments (n,)).  Pads n to a power-of-2 bucket for jit-cache reuse."""
    n, d = x.shape
    k = min(k, n)
    rng = np.random.default_rng(seed)
    npad = _next_pow2(max(n, 8))
    xp = np.zeros((npad, d), dtype=np.float32)
    xp[:n] = x
    mask = np.zeros(npad, dtype=bool)
    mask[:n] = True

    if init == "pp":
        init_c = _kmeanspp_init(x, k, rng)
    else:
        init_c = x[rng.choice(n, size=k, replace=False)].astype(np.float32)

    c, assign, _ = _lloyd(jnp.asarray(xp), jnp.asarray(mask),
                          jnp.asarray(init_c), k, iters)
    # np.array (not asarray): jax buffers are read-only; callers mutate.
    return np.array(c), np.array(assign[:n])


def _kmeanspp_init(x: np.ndarray, k: int, rng: np.random.Generator
                   ) -> np.ndarray:
    """D^2-sampling seeding (host loop; only used at index build)."""
    n = x.shape[0]
    centroids = [x[rng.integers(n)]]
    d2 = np.sum((x - centroids[0]) ** 2, axis=1)
    for _ in range(1, k):
        probs = d2 / max(d2.sum(), 1e-12)
        idx = rng.choice(n, p=probs)
        centroids.append(x[idx])
        d2 = np.minimum(d2, np.sum((x - centroids[-1]) ** 2, axis=1))
    return np.stack(centroids).astype(np.float32)


def split_two(x: np.ndarray, iters: int = 8, seed: int = 0
              ) -> Tuple[np.ndarray, np.ndarray]:
    """2-means split of one partition (paper §4.2.1 Split).  Returns
    (2 centroids, assignment in {0,1})."""
    if x.shape[0] < 2:
        raise ValueError("cannot split a partition with < 2 vectors")
    c, a = kmeans(x, 2, iters=iters, seed=seed)
    # Guard: if 2-means degenerated to one side, force a median split along
    # the principal axis so the split is always well-defined.
    if (a == 0).all() or (a == 1).all():
        center = x.mean(0)
        xc = x - center
        # power iteration for the principal direction (cheap, host-side)
        v = np.ones(x.shape[1], dtype=np.float64)
        for _ in range(8):
            v = xc.T @ (xc @ v)
            v /= max(np.linalg.norm(v), 1e-12)
        proj = xc @ v
        a = (proj > np.median(proj)).astype(np.int32)
        if (a == 0).all() or (a == 1).all():  # all projections equal
            a = (np.arange(x.shape[0]) % 2).astype(np.int32)
        c = np.stack([x[a == 0].mean(0), x[a == 1].mean(0)]).astype(np.float32)
    return c, a


_ASSIGN_HOST_MAX = 1 << 22   # n*p below this: host GEMM path


def assign(x: np.ndarray, centroids: np.ndarray,
           impl: str = "auto") -> np.ndarray:
    """Nearest-centroid assignment via the fused kernel.

    Maintenance-sized problems (merge verifies, refine reassignment,
    insert routing — arbitrary, constantly changing (n, p) shapes) take
    a host GEMM instead: the jitted kernel would pay a fresh XLA compile
    for nearly every novel shape, which dominates the maintenance pass
    wall time on CPU.  Large builds still go through the kernel."""
    if (impl == "auto" and not ops._on_tpu()
            and x.shape[0] * centroids.shape[0] <= _ASSIGN_HOST_MAX):
        xs = np.asarray(x, dtype=np.float32)
        c = np.asarray(centroids, dtype=np.float32)
        d = np.sum(c * c, axis=1)[None, :] - 2.0 * (xs @ c.T)
        return np.argmin(d, axis=1).astype(np.int32)
    a, _ = ops.kmeans_assign(jnp.asarray(x, jnp.float32),
                             jnp.asarray(centroids, jnp.float32), impl=impl)
    return np.asarray(a)


def refine(parts: list, centroids: np.ndarray, iters: int = 1,
           ) -> Tuple[np.ndarray, list]:
    """Partition refinement (paper §4.2.1): k-means seeded by the current
    centroids over the union of the given partitions' vectors, then
    reassignment.  ``parts`` is a list of (vectors (s_j, d), ids (s_j,))
    aligned with ``centroids`` rows.  Returns (new_centroids, new_parts).
    """
    xs = np.concatenate([p[0] for p in parts], axis=0)
    ids = np.concatenate([p[1] for p in parts], axis=0)
    k, d = centroids.shape
    n = xs.shape[0]
    npad = _next_pow2(max(n, 8))
    xp = np.zeros((npad, d), dtype=np.float32)
    xp[:n] = xs
    mask = np.zeros(npad, dtype=bool)
    mask[:n] = True
    c, a, _ = _lloyd(jnp.asarray(xp), jnp.asarray(mask),
                     jnp.asarray(centroids, jnp.float32), k, iters)
    c = np.array(c)
    a = np.array(a[:n])
    new_parts = []
    for j in range(k):
        sel = a == j
        new_parts.append((xs[sel], ids[sel]))
        if not sel.any():
            c[j] = centroids[j]  # keep old centroid for a (now) empty part
    return c, new_parts
