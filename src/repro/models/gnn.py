"""GAT (Velickovic et al., arXiv:1710.10903) via edge-list message passing.

JAX sparse is BCOO-only, so message passing is built from first principles:
gather endpoints -> per-edge attention scores -> ``segment_softmax`` over
destination -> ``segment_sum`` scatter (kernel taxonomy §GNN: SDDMM ->
edge-softmax -> SpMM, expressed as segment ops).

Distribution: **edge-parallel** — the edge list is sharded across the data
axes; every segment reduction takes a local partial then a ``psum`` over the
axis (pass ``axis=("pod","data")`` inside ``repro.compat.shard_map``, the
version-portable alias — see docs/compat.md).  Node features are
replicated (fine for Cora/molecule; ogb_products keeps features resident and
trades the replicated gather — see DESIGN.md §6 / the §Perf log).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .layers import dense_init

Array = jax.Array


@dataclass(frozen=True)
class GATConfig:
    d_in: int
    d_hidden: int = 8
    n_heads: int = 8
    n_layers: int = 2
    n_classes: int = 7
    negative_slope: float = 0.2
    dp_axes: Tuple[str, ...] = ("pod", "data")


def init_params(key: Array, cfg: GATConfig) -> Dict[str, Any]:
    layers = []
    d_in = cfg.d_in
    for i in range(cfg.n_layers):
        last = i == cfg.n_layers - 1
        heads = 1 if last else cfg.n_heads
        d_out = cfg.n_classes if last else cfg.d_hidden
        k1, k2, k3, key = jax.random.split(key, 4)
        layers.append({
            "w": dense_init(k1, (d_in, heads, d_out), 0),
            "a_src": dense_init(k2, (heads, d_out), 1),
            "a_dst": dense_init(k3, (heads, d_out), 1),
            "b": jnp.zeros((heads, d_out)),
        })
        d_in = d_out * heads
    return {"layers": layers}


def param_specs(cfg: GATConfig) -> Dict[str, Any]:
    return {"layers": [{"w": P(None, None, None), "a_src": P(None, None),
                        "a_dst": P(None, None), "b": P(None, None)}
                       for _ in range(cfg.n_layers)]}


def _psum(x: Array, axis) -> Array:
    return jax.lax.psum(x, axis) if axis is not None else x


def _pmax(x: Array, axis) -> Array:
    return jax.lax.pmax(x, axis) if axis is not None else x


def gat_layer(lp: Dict[str, Array], h: Array, src: Array, dst: Array,
              n_nodes: int, cfg: GATConfig, last: bool,
              axis=None) -> Array:
    """One GAT layer over (possibly sharded) edges.

    h: (N, d_in) node features (replicated); src/dst: (E_loc,) local edges.
    """
    wh = jnp.einsum("nd,dho->nho", h, lp["w"].astype(h.dtype))  # (N,H,dO)
    s_src = jnp.sum(wh * lp["a_src"].astype(h.dtype), axis=-1)  # (N,H)
    s_dst = jnp.sum(wh * lp["a_dst"].astype(h.dtype), axis=-1)
    e = s_src[src] + s_dst[dst]                                 # (E,H)
    e = jax.nn.leaky_relu(e, cfg.negative_slope)

    # distributed segment softmax over incoming edges of each dst.
    # stop_gradient: max-subtraction is gradient-neutral in softmax and
    # pmax has no differentiation rule.
    smax = jax.ops.segment_max(jax.lax.stop_gradient(e), dst,
                               num_segments=n_nodes)
    smax = _pmax(jnp.nan_to_num(smax, neginf=-1e30), axis)
    smax = jnp.maximum(smax, -1e30)
    ex = jnp.exp(e - smax[dst])
    denom = _psum(jax.ops.segment_sum(ex, dst, num_segments=n_nodes), axis)
    alpha = ex / jnp.maximum(denom[dst], 1e-20)                 # (E,H)

    msg = wh[src] * alpha[..., None]                            # (E,H,dO)
    out = _psum(jax.ops.segment_sum(msg, dst, num_segments=n_nodes), axis)
    out = out + lp["b"].astype(h.dtype)
    if last:
        return jnp.mean(out, axis=1)                            # avg heads
    return jax.nn.elu(out.reshape(n_nodes, -1))                 # concat


def forward(params: Dict[str, Any], feats: Array, src: Array, dst: Array,
            cfg: GATConfig, axis=None) -> Array:
    """Node logits (N, n_classes)."""
    h = feats
    n_nodes = feats.shape[0]
    for i, lp in enumerate(params["layers"]):
        h = gat_layer(lp, h, src, dst, n_nodes, cfg,
                      last=(i == cfg.n_layers - 1), axis=axis)
    return h


def loss_fn(params: Dict[str, Any], feats: Array, src: Array, dst: Array,
            labels: Array, cfg: GATConfig, axis=None,
            label_mask: Optional[Array] = None) -> Array:
    logits = forward(params, feats, src, dst, cfg, axis=axis)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None].astype(jnp.int32),
                               axis=-1)[:, 0]
    nll = lse - gold
    if label_mask is not None:
        return jnp.sum(nll * label_mask) / jnp.maximum(
            jnp.sum(label_mask), 1.0)
    return jnp.mean(nll)


def graph_pool_logits(params: Dict[str, Any], feats: Array, src: Array,
                      dst: Array, graph_of: Array, n_graphs: int,
                      cfg: GATConfig, axis=None) -> Array:
    """Batched-small-graph mode (``molecule`` shape): mean-pool node
    representations per graph -> graph logits."""
    node_logits = forward(params, feats, src, dst, cfg, axis=axis)
    sums = jax.ops.segment_sum(node_logits, graph_of, num_segments=n_graphs)
    cnt = jax.ops.segment_sum(jnp.ones_like(graph_of, jnp.float32),
                              graph_of, num_segments=n_graphs)
    return sums / jnp.maximum(cnt[:, None], 1.0)
