"""Decoder-only transformer LM (dense + MoE), GQA, RoPE, flash attention.

Covers the five assigned LM architectures (mistral-large-123b, granite-34b,
qwen2.5-14b, qwen3-moe-235b-a22b, llama4-scout-17b-16e) through one config.

Paths:
  * ``forward_train``  — full causal forward -> logits (flash attention,
                         lax.scan over layers, optional remat)
  * ``prefill``        — forward + emit KV cache (inference prefill)
  * ``decode_step``    — one token against a KV cache (inference decode;
                         linear in context, works for 524k contexts with a
                         sequence-sharded cache)

Sharding (DESIGN.md §6): weights FSDP-sharded over ("pod","data") and
tensor-parallel over "model"; the residual stream is sequence-sharded over
"model" between blocks (Megatron-SP; GSPMD inserts the all-gather /
reduce-scatter pair at block boundaries from the sharding constraints).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .layers import (apply_rope, decode_attention, dense_init,
                     flash_attention, rmsnorm, rope_frequencies)

Array = jax.Array


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int                      # per-expert hidden
    n_shared: int = 0              # shared (always-on) experts
    capacity_factor: float = 1.25
    group_size: int = 512
    router_aux_weight: float = 0.01
    impl: str = "dense"            # GShard one-hot dispatch/combine
                                   # einsums (the GSPMD-friendly form);
                                   # an argsort-bucketed dispatch is a
                                   # potential §Perf follow-up


@dataclass(frozen=True)
class TransformerConfig:
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: Optional[int] = None
    qkv_bias: bool = False
    rope_theta: float = 1_000_000.0
    moe: Optional[MoEConfig] = None
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16
    remat: bool = True
    q_block: int = 512
    k_block: int = 1024
    # mesh axis groups
    dp_axes: Tuple[str, ...] = ("pod", "data")
    tp_axis: str = "model"
    seq_shard_activations: bool = True
    # grouped-GQA attention: contract against unrepeated K/V (K/V traffic
    # / (H/K)).  Set by the launcher when tp divides n_kv_heads or the
    # group width (families._adapt_lm_cfg); False = legacy repeat path.
    attn_grouped: bool = False
    # "jnp": blockwise-scan flash in XLA (score tiles round-trip HBM);
    # "pallas": fused VMEM kernel (kernels/flash_attention.py) — the
    # TPU-native hot path for the serving cells (interpret mode on CPU).
    attn_impl: str = "jnp"

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads


# ---------------------------------------------------------------------------
# Init + sharding specs
# ---------------------------------------------------------------------------

def init_params(key: Array, cfg: TransformerConfig) -> Dict[str, Any]:
    l, d, h, k = cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads
    dh, f, v = cfg.head_dim, cfg.d_ff, cfg.vocab_size
    dt = cfg.param_dtype
    keys = iter(jax.random.split(key, 32))

    def dn(shape, in_axis=1):  # layer-stacked dense
        return dense_init(next(keys), shape, in_axis, dt)

    attn = {"wq": dn((l, d, h * dh)), "wk": dn((l, d, k * dh)),
            "wv": dn((l, d, k * dh)), "wo": dn((l, h * dh, d))}
    if cfg.qkv_bias:
        attn |= {"bq": jnp.zeros((l, h * dh), dt),
                 "bk": jnp.zeros((l, k * dh), dt),
                 "bv": jnp.zeros((l, k * dh), dt)}
    params: Dict[str, Any] = {
        "embed": dense_init(next(keys), (v, d), 1, dt),
        "ln1": jnp.ones((l, d), dt), "ln2": jnp.ones((l, d), dt),
        "attn": attn,
        "ln_f": jnp.ones((d,), dt),
        "lm_head": dense_init(next(keys), (d, v), 0, dt),
    }
    if cfg.moe is None:
        params["mlp"] = {"w_gate": dn((l, d, f)), "w_up": dn((l, d, f)),
                         "w_down": dn((l, f, d), in_axis=1)}
    else:
        e, fe = cfg.moe.n_experts, cfg.moe.d_ff
        params["moe"] = {
            "router": dn((l, d, e)),
            "w_gate": dn((l, e, d, fe), in_axis=2),
            "w_up": dn((l, e, d, fe), in_axis=2),
            "w_down": dn((l, e, fe, d), in_axis=2),
        }
        if cfg.moe.n_shared:
            fs = cfg.moe.d_ff * cfg.moe.n_shared
            params["shared_mlp"] = {"w_gate": dn((l, d, fs)),
                                    "w_up": dn((l, d, fs)),
                                    "w_down": dn((l, fs, d), in_axis=1)}
    return params


def param_specs(cfg: TransformerConfig) -> Dict[str, Any]:
    dp, tp = cfg.dp_axes, cfg.tp_axis
    attn = {"wq": P(None, dp, tp), "wk": P(None, dp, tp),
            "wv": P(None, dp, tp), "wo": P(None, tp, dp)}
    if cfg.qkv_bias:
        attn |= {"bq": P(None, tp), "bk": P(None, tp), "bv": P(None, tp)}
    specs: Dict[str, Any] = {
        "embed": P(tp, dp),
        "ln1": P(None, None), "ln2": P(None, None),
        "attn": attn,
        "ln_f": P(None),
        "lm_head": P(dp, tp),
    }
    if cfg.moe is None:
        specs["mlp"] = {"w_gate": P(None, dp, tp), "w_up": P(None, dp, tp),
                        "w_down": P(None, tp, dp)}
    else:
        specs["moe"] = {"router": P(None, dp, None),
                        "w_gate": P(None, tp, dp, None),
                        "w_up": P(None, tp, dp, None),
                        "w_down": P(None, tp, None, dp)}
        if cfg.moe.n_shared:
            specs["shared_mlp"] = {"w_gate": P(None, dp, tp),
                                   "w_up": P(None, dp, tp),
                                   "w_down": P(None, tp, dp)}
    return specs


def _constrain(x: Array, spec: Optional[P]) -> Array:
    if spec is None:
        return x
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):  # no mesh in context (CPU unit tests)
        return x


# ---------------------------------------------------------------------------
# MoE layer
# ---------------------------------------------------------------------------

def moe_ffn(p: Dict[str, Array], x: Array, cfg: TransformerConfig,
            tp_spec: Optional[P]) -> Tuple[Array, Array]:
    """GShard-style top-k MoE with capacity.  x: (B, S, D) -> (out, aux).

    One-hot dispatch/combine einsums — the GSPMD-friendly baseline; the
    (g, E, C) slot one-hot is the known traffic cost (visible as the
    dispatch einsum/concat bytes in the qwen3 §Roofline row).
    """
    mcfg = cfg.moe
    b, s, d = x.shape
    e, k = mcfg.n_experts, mcfg.top_k
    g = min(mcfg.group_size, b * s)
    t = b * s
    ng = -(-t // g)
    xf = x.reshape(t, d)
    if ng * g != t:
        xf = jnp.pad(xf, ((0, ng * g - t), (0, 0)))
    xg = xf.reshape(ng, g, d)

    logits = jnp.einsum("gtd,de->gte", xg, p["router"].astype(x.dtype),
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)              # (ng, g, k)
    top_w = top_w / jnp.maximum(jnp.sum(top_w, -1, keepdims=True), 1e-9)

    # aux load-balance loss (Switch): E * sum_e f_e * P_e
    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean(jax.nn.one_hot(top_e[..., 0], e), axis=(0, 1))
    aux = e * jnp.sum(me * ce) * mcfg.router_aux_weight

    cap = int(math.ceil(g * k * mcfg.capacity_factor / e / 4.0) * 4)
    onehot = jax.nn.one_hot(top_e, e, dtype=jnp.float32)  # (ng,g,k,E)
    flat = onehot.reshape(ng, g * k, e)
    pos = jnp.cumsum(flat, axis=1) * flat - 1.0            # slot per token
    keep = (pos >= 0) & (pos < cap)
    slot = jax.nn.one_hot(pos, cap, dtype=jnp.float32) \
        * keep[..., None].astype(jnp.float32)              # (ng,g*k,E,C)
    gates = (slot * top_w.reshape(ng, g * k, 1, 1))
    dispatch = slot.reshape(ng, g, k, e, cap).sum(2)       # (ng,g,E,C)
    combine = gates.reshape(ng, g, k, e, cap).sum(2)
    dispatch = _constrain(dispatch, tp_spec)
    combine = _constrain(combine, tp_spec)

    ein = jnp.einsum("gsec,gsd->gecd", dispatch.astype(x.dtype), xg,
                     preferred_element_type=jnp.float32).astype(x.dtype)
    hg = jnp.einsum("gecd,edf->gecf", ein, p["w_gate"].astype(x.dtype))
    hu = jnp.einsum("gecd,edf->gecf", ein, p["w_up"].astype(x.dtype))
    ho = jax.nn.silu(hg) * hu
    eout = jnp.einsum("gecf,efd->gecd", ho, p["w_down"].astype(x.dtype))
    out = jnp.einsum("gsec,gecd->gsd", combine.astype(x.dtype), eout,
                     preferred_element_type=jnp.float32).astype(x.dtype)
    out = out.reshape(ng * g, d)[:t].reshape(b, s, d)
    return out, aux


# ---------------------------------------------------------------------------
# Transformer block + stacks
# ---------------------------------------------------------------------------

def _qkv(lp, x, cfg):
    b, s, d = x.shape
    dh = cfg.head_dim
    q = x @ lp["attn"]["wq"].astype(x.dtype)
    kk = x @ lp["attn"]["wk"].astype(x.dtype)
    v = x @ lp["attn"]["wv"].astype(x.dtype)
    if cfg.qkv_bias:
        q = q + lp["attn"]["bq"].astype(x.dtype)
        kk = kk + lp["attn"]["bk"].astype(x.dtype)
        v = v + lp["attn"]["bv"].astype(x.dtype)
    return (q.reshape(b, s, cfg.n_heads, dh),
            kk.reshape(b, s, cfg.n_kv_heads, dh),
            v.reshape(b, s, cfg.n_kv_heads, dh))


def _ffn(lp, x, cfg, tp_spec):
    if cfg.moe is None:
        h = jax.nn.silu(x @ lp["mlp"]["w_gate"].astype(x.dtype)) \
            * (x @ lp["mlp"]["w_up"].astype(x.dtype))
        h = _constrain(h, None)
        return h @ lp["mlp"]["w_down"].astype(x.dtype), jnp.zeros((),
                                                                  jnp.float32)
    out, aux = moe_ffn(lp["moe"], x, cfg, tp_spec)
    if cfg.moe.n_shared:
        sh = jax.nn.silu(x @ lp["shared_mlp"]["w_gate"].astype(x.dtype)) \
            * (x @ lp["shared_mlp"]["w_up"].astype(x.dtype))
        out = out + sh @ lp["shared_mlp"]["w_down"].astype(x.dtype)
    return out, aux


def _act_specs(cfg: TransformerConfig):
    dp, tp = cfg.dp_axes, cfg.tp_axis
    seq = tp if cfg.seq_shard_activations else None
    return {
        "resid": P(dp, seq, None),      # (B, S, D) sequence-sharded (SP)
        "heads": P(dp, None, tp, None),  # (B, S, H, dh) head-sharded (TP)
        "moe_disp": P(dp, None, tp, None) if cfg.moe else None,
    }


def block(lp: Dict[str, Any], x: Array, positions: Array,
          cfg: TransformerConfig, freqs: Array) -> Tuple[Array, Array]:
    sp = _act_specs(cfg)
    h = rmsnorm(x, lp["ln1"].astype(x.dtype))
    q, k, v = _qkv(lp, h, cfg)
    q = _constrain(apply_rope(q, positions, freqs), sp["heads"])
    # k/v left unconstrained: n_kv_heads may not divide the tp axis; GSPMD
    # propagates the projection's output sharding through the reshape.
    k = apply_rope(k, positions, freqs)
    att = flash_attention(q, k, v, causal=True, q_block=cfg.q_block,
                          k_block=cfg.k_block, grouped=cfg.attn_grouped)
    b, s, _, _ = att.shape
    att = att.reshape(b, s, cfg.n_heads * cfg.head_dim)
    x = x + _constrain(att @ lp["attn"]["wo"].astype(x.dtype), sp["resid"])
    h2 = rmsnorm(x, lp["ln2"].astype(x.dtype))
    f, aux = _ffn(lp, h2, cfg, sp["moe_disp"])
    x = x + _constrain(f, sp["resid"])
    return x, aux


def _layer_tree(params):
    return {k: params[k] for k in params
            if k in ("ln1", "ln2", "attn", "mlp", "moe", "shared_mlp")}


def forward_train(params: Dict[str, Any], tokens: Array,
                  cfg: TransformerConfig) -> Tuple[Array, Array]:
    """tokens (B, S) int32 -> (logits (B, S, V) fp32, aux_loss scalar)."""
    sp = _act_specs(cfg)
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.compute_dtype)
    x = _constrain(x, sp["resid"])
    positions = jnp.broadcast_to(jnp.arange(tokens.shape[1]), tokens.shape)
    freqs = rope_frequencies(cfg.head_dim, cfg.rope_theta)

    def body(carry, lp):
        x, aux = carry
        x, a = block(lp, x, positions, cfg, freqs)
        return (x, aux + a), None

    body_fn = body
    if cfg.remat:
        body_fn = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    (x, aux), _ = jax.lax.scan(body_fn, (x, jnp.zeros((), jnp.float32)),
                               _layer_tree(params))
    x = rmsnorm(x, params["ln_f"].astype(x.dtype))
    logits = (x @ params["lm_head"].astype(x.dtype)).astype(jnp.float32)
    return logits, aux


def lm_loss(params: Dict[str, Any], tokens: Array,
            cfg: TransformerConfig) -> Array:
    """Next-token cross-entropy (+ MoE aux)."""
    logits, aux = forward_train(params, tokens, cfg)
    tgt = tokens[:, 1:]
    lg = logits[:, :-1]
    lse = jax.nn.logsumexp(lg, axis=-1)
    gold = jnp.take_along_axis(lg, tgt[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold) + aux


def hidden_states(params: Dict[str, Any], tokens: Array,
                  cfg: TransformerConfig) -> Tuple[Array, Array]:
    """Forward up to the final norm (no unembedding): (B, S, D), aux."""
    sp = _act_specs(cfg)
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.compute_dtype)
    x = _constrain(x, sp["resid"])
    positions = jnp.broadcast_to(jnp.arange(tokens.shape[1]), tokens.shape)
    freqs = rope_frequencies(cfg.head_dim, cfg.rope_theta)

    def body(carry, lp):
        x, aux = carry
        x, a = block(lp, x, positions, cfg, freqs)
        return (x, aux + a), None

    body_fn = body
    if cfg.remat:
        body_fn = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    (x, aux), _ = jax.lax.scan(body_fn, (x, jnp.zeros((), jnp.float32)),
                               _layer_tree(params))
    return rmsnorm(x, params["ln_f"].astype(x.dtype)), aux


def lm_loss_chunked(params: Dict[str, Any], tokens: Array,
                    cfg: TransformerConfig, chunk: int = 512) -> Array:
    """Memory-bounded loss: the (B, S, V) logits tensor is never
    materialized — the unembedding + cross-entropy run per sequence chunk
    under remat.  Required for 150k-vocab 4k-seq training cells."""
    x, aux = hidden_states(params, tokens, cfg)
    b, s, d = x.shape
    tgt = jnp.pad(tokens[:, 1:], ((0, 0), (0, 1)))           # (B, S)
    mask = jnp.arange(s) < (s - 1)
    n_chunks = -(-s // chunk)
    s_pad = n_chunks * chunk
    if s_pad != s:
        x = jnp.pad(x, ((0, 0), (0, s_pad - s), (0, 0)))
        tgt = jnp.pad(tgt, ((0, 0), (0, s_pad - s)))
        mask = jnp.pad(mask, (0, s_pad - s))
    xr = x.reshape(b, n_chunks, chunk, d)
    tr_ = tgt.reshape(b, n_chunks, chunk)
    mr = mask.reshape(n_chunks, chunk)

    @functools.partial(jax.checkpoint,
                       policy=jax.checkpoint_policies.nothing_saveable)
    def one(ci):
        lg = (xr[:, ci] @ params["lm_head"].astype(x.dtype)
              ).astype(jnp.float32)
        lse = jax.nn.logsumexp(lg, axis=-1)
        gold = jnp.take_along_axis(lg, tr_[:, ci][..., None],
                                   axis=-1)[..., 0]
        return jnp.sum((lse - gold) * mr[ci][None, :])

    def scan_body(tot, ci):
        return tot + one(ci), None

    total, _ = jax.lax.scan(scan_body, jnp.zeros(()), jnp.arange(n_chunks))
    return total / (b * (s - 1)) + aux


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def cache_specs(cfg: TransformerConfig, seq_shard: bool = False):
    """KV cache sharding: batch over dp; sequence over model when the cache
    dominates memory (decode_32k / long_500k -> flash-decoding layout)."""
    dp, tp = cfg.dp_axes, cfg.tp_axis
    if seq_shard:
        return P(None, dp, tp, None, None)      # (L, B, S, K, dh)
    return P(None, dp, None, tp, None)


def prefill(params: Dict[str, Any], tokens: Array, cfg: TransformerConfig
            ) -> Tuple[Array, Tuple[Array, Array]]:
    """Full-prompt forward; returns (last-position logits, KV cache)."""
    sp = _act_specs(cfg)
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.compute_dtype)
    x = _constrain(x, sp["resid"])
    positions = jnp.broadcast_to(jnp.arange(tokens.shape[1]), tokens.shape)
    freqs = rope_frequencies(cfg.head_dim, cfg.rope_theta)

    def body(x, lp):
        h = rmsnorm(x, lp["ln1"].astype(x.dtype))
        q, k, v = _qkv(lp, h, cfg)
        # heads constraint keeps attention TP-sharded (without it GSPMD
        # replicates the whole attention block per device — §Perf hc3 it2)
        q = _constrain(apply_rope(q, positions, freqs), sp["heads"])
        k = apply_rope(k, positions, freqs)
        if cfg.attn_impl == "pallas":
            from ..kernels.flash_attention import flash_attention_pallas
            att = flash_attention_pallas(q, k, v, causal=True,
                                         q_block=cfg.q_block,
                                         k_block=cfg.k_block)
        else:
            att = flash_attention(q, k, v, causal=True,
                                  q_block=cfg.q_block, k_block=cfg.k_block,
                                  grouped=cfg.attn_grouped)
        b, s, _, _ = att.shape
        att = att.reshape(b, s, cfg.n_heads * cfg.head_dim)
        x = x + _constrain(att @ lp["attn"]["wo"].astype(x.dtype),
                           sp["resid"])
        h2 = rmsnorm(x, lp["ln2"].astype(x.dtype))
        f, _ = _ffn(lp, h2, cfg, sp["moe_disp"])
        return x + _constrain(f, sp["resid"]), (k, v)

    x, kv = jax.lax.scan(body, x, _layer_tree(params))
    x = rmsnorm(x[:, -1:], params["ln_f"].astype(x.dtype))
    logits = (x @ params["lm_head"].astype(x.dtype)).astype(jnp.float32)
    return logits[:, 0], kv


def decode_step(params: Dict[str, Any], token: Array, cache_k: Array,
                cache_v: Array, cache_len: Array, cfg: TransformerConfig,
                update_cache: bool = True
                ) -> Tuple[Array, Tuple[Array, Array]]:
    """One decode step.  token (B,) int32; cache (L, B, S, K, dh);
    cache_len (B,) current lengths.  Linear in S."""
    x = jnp.take(params["embed"], token[:, None],
                 axis=0).astype(cfg.compute_dtype)     # (B, 1, D)
    freqs = rope_frequencies(cfg.head_dim, cfg.rope_theta)
    positions = cache_len[:, None]

    def body(x, layer):
        lp, ck, cv = layer
        h = rmsnorm(x, lp["ln1"].astype(x.dtype))
        q, k, v = _qkv(lp, h, cfg)
        q = apply_rope(q, positions, freqs)
        k = apply_rope(k, positions, freqs)
        if update_cache:
            bidx = jnp.arange(x.shape[0])
            ck = ck.at[bidx, cache_len].set(k[:, 0].astype(ck.dtype))
            cv = cv.at[bidx, cache_len].set(v[:, 0].astype(cv.dtype))
            att = decode_attention(q, ck, cv, cache_len + 1)
        else:
            att = decode_attention(q, ck, cv, cache_len)
        att = att.reshape(x.shape[0], 1, cfg.n_heads * cfg.head_dim)
        x = x + att @ lp["attn"]["wo"].astype(x.dtype)
        h2 = rmsnorm(x, lp["ln2"].astype(x.dtype))
        f, _ = _ffn(lp, h2, cfg, None)
        return x + f, (ck, cv)

    def scan_body(x, layer):
        return body(x, layer)

    x, (ck_new, cv_new) = jax.lax.scan(
        scan_body, x, (_layer_tree(params), cache_k, cache_v))
    x = rmsnorm(x, params["ln_f"].astype(x.dtype))
    logits = (x @ params["lm_head"].astype(x.dtype)).astype(jnp.float32)
    return logits[:, 0], (ck_new, cv_new)


def param_count(cfg: TransformerConfig) -> int:
    """Analytic parameter count (for 6ND model-FLOPs in the roofline)."""
    d, dh = cfg.d_model, cfg.head_dim
    attn = d * dh * (cfg.n_heads + 2 * cfg.n_kv_heads) + cfg.n_heads * dh * d
    if cfg.moe is None:
        ffn = 3 * d * cfg.d_ff
    else:
        ffn = cfg.moe.n_experts * 3 * d * cfg.moe.d_ff + d * cfg.moe.n_experts
        ffn += cfg.moe.n_shared * 3 * d * cfg.moe.d_ff
    per_layer = attn + ffn + 2 * d
    return cfg.n_layers * per_layer + 2 * cfg.vocab_size * d + d


def active_param_count(cfg: TransformerConfig) -> int:
    """Active (per-token) parameters — MoE counts top_k + shared experts."""
    if cfg.moe is None:
        return param_count(cfg)
    d, dh = cfg.d_model, cfg.head_dim
    attn = d * dh * (cfg.n_heads + 2 * cfg.n_kv_heads) + cfg.n_heads * dh * d
    ffn = (cfg.moe.top_k + cfg.moe.n_shared) * 3 * d * cfg.moe.d_ff \
        + d * cfg.moe.n_experts
    per_layer = attn + ffn + 2 * d
    return cfg.n_layers * per_layer + 2 * cfg.vocab_size * d + d
