"""Model zoo: GQA transformer LM (dense + MoE), GAT, and four recsys models
(DIN / SASRec / two-tower / DLRM).  Pure-JAX pytree params with matching
PartitionSpec trees for the production mesh."""
from . import gnn, layers, recsys, transformer  # noqa: F401
