"""RecSys model zoo: DIN, SASRec, two-tower retrieval, DLRM.

Shared substrate: huge sparse embedding tables, **row-sharded over the
'model' mesh axis** (classic DLRM model parallelism) and looked up with
``jnp.take`` + segment reductions (JAX has no nn.EmbeddingBag — building it
is part of the system, kernel taxonomy §RecSys).  Dense towers are pure
data-parallel.

The two-tower model is where Quake plugs in directly: ``retrieval_cand``
scores one query against 10^6 candidates — served either brute-force
(batched dot over the sharded candidate matrix) or through the Quake index
(examples/retrieval_serving.py); the paper's technique *is* this use case.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .layers import (apply_mlp, dense_init, embedding_bag, init_mlp,
                     rmsnorm, spec_mlp)

Array = jax.Array


# ---------------------------------------------------------------------------
# DIN — Deep Interest Network (arXiv:1706.06978)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class DINConfig:
    vocab: int = 1_000_000
    embed_dim: int = 18
    seq_len: int = 100
    attn_mlp: Tuple[int, ...] = (80, 40)
    mlp: Tuple[int, ...] = (200, 80)
    n_dense: int = 13
    tp_axis: str = "model"


def din_init(key: Array, cfg: DINConfig) -> Dict[str, Any]:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d = cfg.embed_dim
    return {
        "item_embed": dense_init(k1, (cfg.vocab, d), 1),
        # target-attention MLP over [h, t, h-t, h*t]
        "attn": init_mlp(k2, (4 * d,) + cfg.attn_mlp + (1,)),
        # final MLP over [pooled, target, dense]
        "mlp": init_mlp(k3, (2 * d + cfg.n_dense,) + cfg.mlp + (1,)),
    }


def din_specs(cfg: DINConfig) -> Dict[str, Any]:
    return {"item_embed": P(cfg.tp_axis, None),
            "attn": spec_mlp((4 * cfg.embed_dim,) + cfg.attn_mlp + (1,)),
            "mlp": spec_mlp((2 * cfg.embed_dim + cfg.n_dense,)
                            + cfg.mlp + (1,))}


def din_forward(params: Dict[str, Any], batch: Dict[str, Array],
                cfg: DINConfig) -> Array:
    hist = jnp.take(params["item_embed"], batch["history"], axis=0)
    tgt = jnp.take(params["item_embed"], batch["target_item"], axis=0)
    t = jnp.broadcast_to(tgt[:, None, :], hist.shape)
    ai = jnp.concatenate([hist, t, hist - t, hist * t], axis=-1)
    scores = apply_mlp(params["attn"], ai, act=jax.nn.sigmoid)[..., 0]
    scores = jnp.where(batch["history_mask"], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    pooled = jnp.einsum("bt,btd->bd", w, hist)
    x = jnp.concatenate([pooled, tgt, batch["dense"]], axis=-1)
    return apply_mlp(params["mlp"], x, act=jax.nn.relu)[..., 0]


def din_loss(params, batch, cfg: DINConfig) -> Array:
    logit = din_forward(params, batch, cfg)
    y = batch["label"]
    return jnp.mean(jnp.maximum(logit, 0) - logit * y
                    + jnp.log1p(jnp.exp(-jnp.abs(logit))))


# ---------------------------------------------------------------------------
# SASRec — self-attentive sequential recommendation (arXiv:1808.09781)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class SASRecConfig:
    vocab: int = 1_000_000
    embed_dim: int = 50
    n_blocks: int = 2
    n_heads: int = 1
    seq_len: int = 50
    tp_axis: str = "model"


def sasrec_init(key: Array, cfg: SASRecConfig) -> Dict[str, Any]:
    d = cfg.embed_dim
    keys = iter(jax.random.split(key, 4 + 6 * cfg.n_blocks))
    blocks = []
    for _ in range(cfg.n_blocks):
        blocks.append({
            "wq": dense_init(next(keys), (d, d), 0),
            "wk": dense_init(next(keys), (d, d), 0),
            "wv": dense_init(next(keys), (d, d), 0),
            "wo": dense_init(next(keys), (d, d), 0),
            "ff1": dense_init(next(keys), (d, d), 0),
            "ff2": dense_init(next(keys), (d, d), 0),
            "ln1": jnp.ones((d,)), "ln2": jnp.ones((d,)),
        })
    return {"item_embed": dense_init(next(keys), (cfg.vocab, d), 1),
            "pos_embed": dense_init(next(keys), (cfg.seq_len, d), 1),
            "blocks": blocks, "ln_f": jnp.ones((d,))}


def sasrec_specs(cfg: SASRecConfig) -> Dict[str, Any]:
    blk = {"wq": P(None, None), "wk": P(None, None), "wv": P(None, None),
           "wo": P(None, None), "ff1": P(None, None), "ff2": P(None, None),
           "ln1": P(None), "ln2": P(None)}
    return {"item_embed": P(cfg.tp_axis, None), "pos_embed": P(None, None),
            "blocks": [dict(blk) for _ in range(cfg.n_blocks)],
            "ln_f": P(None)}


def sasrec_encode(params: Dict[str, Any], history: Array, mask: Array,
                  cfg: SASRecConfig) -> Array:
    """(B, T) item history -> (B, d) sequence representation."""
    b, t = history.shape
    d = cfg.embed_dim
    x = jnp.take(params["item_embed"], history, axis=0)
    x = x + params["pos_embed"][None, :t, :]
    causal = jnp.tril(jnp.ones((t, t), bool))
    attn_mask = causal[None, :, :] & mask[:, None, :]
    for blk in params["blocks"]:
        h = rmsnorm(x, blk["ln1"])
        q, k, v = h @ blk["wq"], h @ blk["wk"], h @ blk["wv"]
        s = jnp.einsum("bqd,bkd->bqk", q, k) / jnp.sqrt(float(d))
        s = jnp.where(attn_mask, s, -1e30)
        a = jax.nn.softmax(s, axis=-1)
        x = x + (jnp.einsum("bqk,bkd->bqd", a, v) @ blk["wo"])
        h2 = rmsnorm(x, blk["ln2"])
        x = x + jax.nn.relu(h2 @ blk["ff1"]) @ blk["ff2"]
    x = rmsnorm(x, params["ln_f"])
    # last valid position
    last = jnp.maximum(jnp.sum(mask, axis=1) - 1, 0)
    return x[jnp.arange(b), last]


def sasrec_loss(params, batch, cfg: SASRecConfig) -> Array:
    """In-batch sampled softmax over next items."""
    h = sasrec_encode(params, batch["history"], batch["history_mask"], cfg)
    tgt = jnp.take(params["item_embed"], batch["target_item"], axis=0)
    logits = h @ tgt.T                                   # (B, B) in-batch
    labels = jnp.arange(h.shape[0])
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = logits[jnp.arange(h.shape[0]), labels]
    return jnp.mean(lse - gold)


# ---------------------------------------------------------------------------
# Two-tower retrieval (Yi et al., RecSys'19)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class TwoTowerConfig:
    user_vocab: int = 1_000_000
    item_vocab: int = 1_000_000
    embed_dim: int = 256
    tower_mlp: Tuple[int, ...] = (1024, 512, 256)
    hist_len: int = 50
    temperature: float = 0.05
    tp_axis: str = "model"


def twotower_init(key: Array, cfg: TwoTowerConfig) -> Dict[str, Any]:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d = cfg.embed_dim
    return {"user_embed": dense_init(k1, (cfg.user_vocab, d), 1),
            "item_embed": dense_init(k2, (cfg.item_vocab, d), 1),
            "user_tower": init_mlp(k3, (d,) + cfg.tower_mlp),
            "item_tower": init_mlp(k4, (d,) + cfg.tower_mlp)}


def twotower_specs(cfg: TwoTowerConfig) -> Dict[str, Any]:
    d = cfg.embed_dim
    return {"user_embed": P(cfg.tp_axis, None),
            "item_embed": P(cfg.tp_axis, None),
            "user_tower": spec_mlp((d,) + cfg.tower_mlp, cfg.tp_axis),
            "item_tower": spec_mlp((d,) + cfg.tower_mlp, cfg.tp_axis)}


def user_repr(params, batch, cfg: TwoTowerConfig) -> Array:
    u = embedding_bag(params["user_embed"], batch["history"], mode="mean",
                      valid=batch["history_mask"])
    u = apply_mlp(params["user_tower"], u, act=jax.nn.relu)
    return u / jnp.maximum(jnp.linalg.norm(u, axis=-1, keepdims=True), 1e-6)


def item_repr(params, item_ids: Array, cfg: TwoTowerConfig) -> Array:
    i = jnp.take(params["item_embed"], item_ids, axis=0)
    i = apply_mlp(params["item_tower"], i, act=jax.nn.relu)
    return i / jnp.maximum(jnp.linalg.norm(i, axis=-1, keepdims=True), 1e-6)


def twotower_loss(params, batch, cfg: TwoTowerConfig) -> Array:
    """In-batch sampled softmax with logQ correction (Zipf propensity)."""
    u = user_repr(params, batch, cfg)
    v = item_repr(params, batch["target_item"], cfg)
    logits = (u @ v.T) / cfg.temperature
    # logQ correction: in-batch negatives are Zipf-skewed, correct by -log q
    logq = -jnp.log1p(batch["target_item"].astype(jnp.float32))
    logits = logits - logq[None, :]
    n = u.shape[0]
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.diag(logits)
    return jnp.mean(lse - gold)


def retrieval_scores(params, batch, candidates: Array,
                     cfg: TwoTowerConfig) -> Array:
    """``retrieval_cand``: (B, n_cand) scores against encoded candidates —
    one GEMM over the (pre-encoded, sharded) candidate matrix.  The ANN
    alternative routes this through the Quake engine."""
    u = user_repr(params, batch, cfg)
    return u @ candidates.T


# ---------------------------------------------------------------------------
# DLRM (arXiv:1906.00091) — RM-2 configuration
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class DLRMConfig:
    n_dense: int = 13
    n_sparse: int = 26
    vocab: int = 1_000_000
    embed_dim: int = 64
    bot_mlp: Tuple[int, ...] = (512, 256, 64)
    top_mlp: Tuple[int, ...] = (512, 512, 256, 1)
    tp_axis: str = "model"

    @property
    def n_interactions(self) -> int:
        f = self.n_sparse + 1
        return f * (f - 1) // 2


def dlrm_init(key: Array, cfg: DLRMConfig) -> Dict[str, Any]:
    k1, k2, k3 = jax.random.split(key, 3)
    tables = dense_init(k1, (cfg.n_sparse, cfg.vocab, cfg.embed_dim), 2)
    top_in = cfg.n_interactions + cfg.embed_dim
    return {"tables": tables,
            "bot": init_mlp(k2, (cfg.n_dense,) + cfg.bot_mlp),
            "top": init_mlp(k3, (top_in,) + cfg.top_mlp)}


def dlrm_specs(cfg: DLRMConfig) -> Dict[str, Any]:
    top_in = cfg.n_interactions + cfg.embed_dim
    return {"tables": P(None, cfg.tp_axis, None),
            "bot": spec_mlp((cfg.n_dense,) + cfg.bot_mlp),
            "top": spec_mlp((top_in,) + cfg.top_mlp)}


def dlrm_forward(params: Dict[str, Any], batch: Dict[str, Array],
                 cfg: DLRMConfig) -> Array:
    dense = apply_mlp(params["bot"], batch["dense"], act=jax.nn.relu,
                      final_act=True)                     # (B, d)
    # per-field lookup: tables (F, V, d), ids (B, F)
    emb = _dlrm_lookup(params["tables"], batch["sparse"])
    feats = jnp.concatenate([dense[:, None, :], emb], axis=1)  # (B,F+1,d)
    inter = jnp.einsum("bfd,bgd->bfg", feats, feats)
    f = feats.shape[1]
    iu, ju = jnp.triu_indices(f, k=1)
    flat = inter[:, iu, ju]                                # (B, F(F-1)/2)
    x = jnp.concatenate([dense, flat], axis=-1)
    return apply_mlp(params["top"], x, act=jax.nn.relu)[..., 0]


def _dlrm_lookup(tables: Array, sparse: Array) -> Array:
    """tables (F, V, d), sparse ids (B, F) -> (B, F, d)."""
    def one(table, ids):
        return jnp.take(table, ids, axis=0)
    return jax.vmap(one, in_axes=(0, 1), out_axes=1)(tables, sparse)


def dlrm_loss(params, batch, cfg: DLRMConfig) -> Array:
    logit = dlrm_forward(params, batch, cfg)
    y = batch["label"]
    return jnp.mean(jnp.maximum(logit, 0) - logit * y
                    + jnp.log1p(jnp.exp(-jnp.abs(logit))))
