"""Shared neural layers (pure-JAX, pytree params).

Conventions:
  * params are nested dicts of jnp arrays; every ``init_*`` has a matching
    ``spec_*`` returning the same tree of ``PartitionSpec`` leaves.
  * compute dtype vs param dtype are separated (bf16 compute on TPU).
  * attention is flash-style (blockwise online softmax via ``lax.scan``) so
    32k-token prefill never materializes (S, S) scores.
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

Array = jax.Array
NEG_INF = -1e30


def dense_init(key, shape, in_axis: int = 0, dtype=jnp.float32) -> Array:
    fan_in = shape[in_axis]
    std = 1.0 / math.sqrt(fan_in)
    return jax.random.normal(key, shape, dtype) * std


def rmsnorm(x: Array, scale: Array, eps: float = 1e-6) -> Array:
    # f32 accumulation via the dot, NOT via casting x: casting the input
    # makes XLA hoist a convert of the whole remat-saved residual stack out
    # of the backward scan (an 88-layer f32 copy resident across the bwd).
    ss = jnp.einsum("...d,...d->...", x, x,
                    preferred_element_type=jnp.float32)
    var = ss / x.shape[-1]
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv[..., None] * scale.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(d_head: int, theta: float = 10_000.0) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2,
                                       dtype=jnp.float32) / d_head))


def apply_rope(x: Array, positions: Array, freqs: Array) -> Array:
    """x: (..., S, H, dh); positions: (..., S)."""
    angles = positions[..., :, None, None].astype(jnp.float32) \
        * freqs[None, None, :]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., 0::2], x[..., 1::2]
    out1 = x1 * cos - x2 * sin
    out2 = x2 * cos + x1 * sin
    out = jnp.stack([out1, out2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Flash-style attention (blockwise online softmax)
# ---------------------------------------------------------------------------

def _attn_block(q, k, v, mask, scale):
    """One (qb, kb) tile: returns (scores_max, exp_sum, weighted_v)."""
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    s = jnp.where(mask, s, NEG_INF)
    m = jnp.max(s, axis=-1)                         # (b,h,q)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)                          # noqa: E741
    o = jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return m, l, o


def _attn_block_grouped(q5, k, v, mask, scale):
    """Grouped-GQA tile: q5 (b, qb, g, r, d), k/v (b, kb, g, d).

    Contracts against the *unrepeated* K/V — the broadcast over the ``r``
    query heads per group happens inside the einsum, so MQA/GQA K/V is
    never materialized at ``h = g*r`` width (§Perf hillclimb 3: the repeat
    inflated K/V traffic and TP all-gathers by ``r``x — 48x for MQA).
    Returns (m, l (b,g,r,qb), o (b,qb,g,r,d)).
    """
    s = jnp.einsum("bqgrd,bkgd->bgrqk", q5, k,
                   preferred_element_type=jnp.float32) * scale
    s = jnp.where(mask[:, None], s, NEG_INF)        # mask (b, 1, 1, qb, kb)
    m = jnp.max(s, axis=-1)                         # (b,g,r,q)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)                          # noqa: E741
    o = jnp.einsum("bgrqk,bkgd->bqgrd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return m, l, o


def flash_attention(q: Array, k: Array, v: Array, *, causal: bool,
                    q_block: int = 512, k_block: int = 1024,
                    q_offset: int = 0, grouped: bool = False) -> Array:
    """Memory-bounded attention.  q: (B, Sq, H, dh); k/v: (B, Sk, K, dh)
    with GQA (H % K == 0).  Never materializes (Sq, Sk) — scans KV blocks
    with running (max, denom, acc) per q block.

    ``grouped=True`` keeps K/V at its native ``K`` heads and broadcasts
    over the ``H/K`` query heads per group inside the tile einsum — K/V
    bytes and TP all-gathers shrink by ``H/K``x (48x for MQA; §Perf
    hillclimb 3).  Use it when the TP axis divides ``K`` or ``H/K`` so the
    5-D query reshape shards cleanly; the legacy repeat path is the
    fallback for awkward head counts (e.g. 8 kv heads on a 16-way axis).

    ``q_offset`` is the absolute position of q[0] (prefill chunks/decode).
    """
    b, sq, h, dh = q.shape
    _, sk, kh, _ = k.shape
    assert h % kh == 0
    rep = h // kh
    if not grouped and rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    scale = 1.0 / math.sqrt(dh)
    q_block = min(q_block, sq)
    k_block = min(k_block, sk)
    nq = -(-sq // q_block)
    nk = -(-sk // k_block)
    sq_pad, sk_pad = nq * q_block, nk * k_block
    if sq_pad != sq:
        q = jnp.pad(q, ((0, 0), (0, sq_pad - sq), (0, 0), (0, 0)))
    if sk_pad != sk:
        k = jnp.pad(k, ((0, 0), (0, sk_pad - sk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, sk_pad - sk), (0, 0), (0, 0)))

    kw = kh if grouped else h
    if grouped:
        q_r = q.reshape(b, nq, q_block, kh, rep, dh)
    else:
        q_r = q.reshape(b, nq, q_block, h, dh)
    k_r = k.reshape(b, nk, k_block, kw, dh)
    v_r = v.reshape(b, nk, k_block, kw, dh)
    qpos = q_offset + jnp.arange(sq_pad).reshape(nq, q_block)
    kpos = jnp.arange(sk_pad).reshape(nk, k_block)
    kvalid = (jnp.arange(sk_pad) < sk).reshape(nk, k_block)

    def outer(qi, qb):
        # remat: the backward pass recomputes each block's (scores, probs)
        # instead of saving the (B, H, qb, kb) tile per step — without this
        # the inner scan's AD residuals materialize the full S x S scores.
        @functools.partial(jax.checkpoint,
                           policy=jax.checkpoint_policies.nothing_saveable)
        def inner(carry, ki):
            m_run, l_run, o_run = carry
            kb, vb = k_r[:, ki], v_r[:, ki]
            mask = kvalid[ki][None, None, None, :]
            if causal:
                cm = qpos[qi][:, None] >= kpos[ki][None, :]
                mask = mask & cm[None, None, :, :]
            if grouped:
                m_blk, l_blk, o_blk = _attn_block_grouped(
                    qb, kb, vb, mask, scale)
                a_shape = lambda a: a.transpose(0, 3, 1, 2)[..., None]
            else:
                m_blk, l_blk, o_blk = _attn_block(qb, kb, vb, mask, scale)
                a_shape = lambda a: a.transpose(0, 2, 1)[..., None]
            m_new = jnp.maximum(m_run, m_blk)
            a1 = jnp.exp(m_run - m_new)
            a2 = jnp.exp(m_blk - m_new)
            l_new = l_run * a1 + l_blk * a2
            o_new = o_run * a_shape(a1) + o_blk * a_shape(a2)
            return (m_new, l_new, o_new), None

        if grouped:
            m0 = jnp.full((b, kh, rep, q_block), NEG_INF, jnp.float32)
            l0 = jnp.zeros((b, kh, rep, q_block), jnp.float32)
            o0 = jnp.zeros((b, q_block, kh, rep, dh), jnp.float32)
        else:
            m0 = jnp.full((b, h, q_block), NEG_INF, jnp.float32)
            l0 = jnp.zeros((b, h, q_block), jnp.float32)
            o0 = jnp.zeros((b, q_block, h, dh), jnp.float32)
        (m, l, o), _ = jax.lax.scan(inner, (m0, l0, o0),  # noqa: E741
                                    jnp.arange(nk))
        if grouped:
            denom = jnp.maximum(l, 1e-20).transpose(0, 3, 1, 2)[..., None]
            return (o / denom).reshape(b, q_block, h, dh)
        denom = jnp.maximum(l, 1e-20).transpose(0, 2, 1)[..., None]
        return o / denom

    out = jax.lax.map(lambda qi: outer(qi, q_r[:, qi]), jnp.arange(nq))
    out = jnp.moveaxis(out, 0, 1).reshape(b, sq_pad, h, dh)[:, :sq]
    return out.astype(q.dtype)


def decode_attention(q: Array, k_cache: Array, v_cache: Array,
                     cache_len: Array) -> Array:
    """Single-position decode: q (B, 1, H, dh) against (B, S, K, dh) cache.
    Linear in S — this is why ``long_500k`` decode is runnable even for
    full-attention architectures (DESIGN.md §5).  The KV cache may be
    sequence-sharded; XLA turns the masked softmax reductions into
    collectives (flash-decoding schedule emerges from the sharding)."""
    b, _, h, dh = q.shape
    _, s, kh, _ = k_cache.shape
    rep = h // kh
    scale = 1.0 / math.sqrt(dh)
    qh = q[:, 0].reshape(b, kh, rep, dh)
    scores = jnp.einsum("bkrd,bskd->bksr", qh, k_cache,
                        preferred_element_type=jnp.float32) * scale
    valid = (jnp.arange(s)[None, :] < cache_len[:, None])
    scores = jnp.where(valid[:, None, :, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=2)
    out = jnp.einsum("bksr,bskd->bkrd", w.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, h, dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_mlp(key, dims, dtype=jnp.float32) -> Dict[str, Any]:
    """Plain MLP tower: dims = (in, h1, ..., out)."""
    ks = jax.random.split(key, len(dims) - 1)
    return {"w": [dense_init(ks[i], (dims[i], dims[i + 1]), 0, dtype)
                  for i in range(len(dims) - 1)],
            "b": [jnp.zeros((dims[i + 1],), dtype)
                  for i in range(len(dims) - 1)]}


def spec_mlp(dims, hidden_axis: Optional[str] = None):
    n = len(dims) - 1
    return {"w": [P(None, hidden_axis) if i < n - 1 else P(hidden_axis, None)
                  for i in range(n)],
            "b": [P(hidden_axis) if i < n - 1 else P(None)
                  for i in range(n)]}


def apply_mlp(params, x: Array, act=jax.nn.relu,
              final_act: bool = False) -> Array:
    n = len(params["w"])
    for i, (w, b) in enumerate(zip(params["w"], params["b"])):
        x = x @ w.astype(x.dtype) + b.astype(x.dtype)
        if i < n - 1 or final_act:
            x = act(x)
    return x


# ---------------------------------------------------------------------------
# Embedding bag (recsys substrate — JAX has no nn.EmbeddingBag)
# ---------------------------------------------------------------------------

def embedding_bag(table: Array, ids: Array, *, mode: str = "sum",
                  weights: Optional[Array] = None,
                  valid: Optional[Array] = None) -> Array:
    """Gather + reduce over the last axis of ``ids``: (..., n) -> (..., D).

    Built from ``jnp.take`` + masked sum — the jnp.take lowers to a gather
    that GSPMD partitions when ``table`` is row-sharded over 'model'
    (each shard gathers its resident rows, psum combines).

    ``mode="clip"`` on the take: out-of-range ids clamp to the last row
    (EmbeddingBag semantics, and identical inside/outside jit) instead of
    jnp.take's default NaN-fill outside jit.
    """
    vecs = jnp.take(table, ids, axis=0, mode="clip")     # (..., n, D)
    if weights is not None:
        vecs = vecs * weights[..., None]
    if valid is not None:
        vecs = jnp.where(valid[..., None], vecs, 0.0)
    out = jnp.sum(vecs, axis=-2)
    if mode == "mean":
        cnt = (jnp.sum(valid, axis=-1, keepdims=True) if valid is not None
               else ids.shape[-1])
        out = out / jnp.maximum(cnt, 1)
    return out


def segment_softmax(scores: Array, segment_ids: Array,
                    num_segments: int) -> Array:
    """Softmax over variable-size groups (GNN edge softmax substrate)."""
    smax = jax.ops.segment_max(scores, segment_ids, num_segments)
    smax = jnp.nan_to_num(smax, neginf=0.0)
    ex = jnp.exp(scores - smax[segment_ids])
    denom = jax.ops.segment_sum(ex, segment_ids, num_segments)
    return ex / jnp.maximum(denom[segment_ids], 1e-20)
