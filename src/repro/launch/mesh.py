"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — required because the dry-run forces 512
virtual host devices while tests/benches must see the single real device.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 = 256 chips per pod; the multi-pod mesh adds a leading 2-pod
    axis (512 chips).  Axis roles: ("pod",) "data" = DP/FSDP,
    "model" = TP/EP (and query-parallel for the quake engine)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1) -> Mesh:
    """Mesh over whatever devices exist (tests / local benches)."""
    devs = np.array(jax.devices())
    n = len(devs)
    assert n % model == 0
    return Mesh(devs.reshape(n // model, model), ("data", "model"))


def describe(mesh: Mesh) -> str:
    return f"{dict(zip(mesh.axis_names, mesh.devices.shape))} " \
           f"({mesh.devices.size} devices)"
