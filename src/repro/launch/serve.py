"""End-to-end Quake serving driver (deliverable b — the paper's kind).

Replays a dynamic, skewed workload (Wikipedia-like by default) against the
**online serving runtime** (``core/serving.py``): queries flow through the
micro-batching queue into cross-batch riding probe rounds over the batched
executor, repeated queries can hit the journal-invalidated result cache,
and maintenance runs when a drift trigger fires instead of after every
operation — the full online system of paper §3.  Reports per-op latency /
recall, riding and cache telemetry, and the maintenance history.

    PYTHONPATH=src python -m repro.launch.serve --months 8 --n 30000

``--per-op`` replays the legacy one-search-at-a-time / maintain-every-op
loop instead (the baseline ``benchmarks/bench_serving.py`` measures
against).
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from ..core import (LatencyModel, Maintainer, QuakeConfig, QuakeIndex,
                    ServingConfig, ServingRuntime)
from ..data import wikipedia
from ..data.workload import IncrementalGroundTruth
from ..faults import FaultInjector
from ..obs import summarize, to_prometheus


def parse_fault_spec(spec: str, seed: int = 0) -> FaultInjector:
    """``site=rate[,site=rate...]`` -> a seeded injector, e.g.
    ``--faults scan=0.05,maintenance=1.0`` (sites: see FaultInjector)."""
    rates = {}
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        site, _, rate = part.partition("=")
        rates[site.strip()] = float(rate)
    return FaultInjector(seed=seed, rates=rates)


def _recall_rows(ids_rows, gt: np.ndarray, k: int) -> list:
    return [len(set(np.asarray(ids).tolist()) & set(gt[i].tolist())) / k
            for i, ids in enumerate(ids_rows)]


def _recall(ids_rows, gt: np.ndarray, k: int) -> float:
    return float(np.mean(_recall_rows(ids_rows, gt, k)))


def dump_metrics(rt: ServingRuntime, path: str) -> None:
    """Write the unified metrics snapshot as JSON plus a sibling
    ``<path>.prom`` in Prometheus text exposition format."""
    flat = rt.metrics_snapshot()
    with open(path, "w") as f:
        json.dump(flat, f, indent=2, sort_keys=True)
        f.write("\n")
    with open(path + ".prom", "w") as f:
        f.write(to_prometheus(flat))


def _warm_runtime(index, wl, scfg: ServingConfig) -> None:
    """Compile the runtime's jitted scan/pack shapes before timing: a
    shadow runtime (no cache, no stats feedback, no maintenance) serves
    the first query op once.  XLA's compile cache is per-process and
    keyed on shapes, so the timed runtime starts steady-state — the same
    warm-before-measure discipline as the other bench cells.  The index
    is not mutated (queries only) and the shadow keeps its own planner
    cache, so the timed replay is unaffected."""
    import dataclasses
    qops = [op for op in wl.operations if op.kind == "query"]
    if not qops:
        return
    shadow_cfg = dataclasses.replace(
        scfg, cache_entries=0, record_stats=False,
        maint_min_ops=10 ** 9, maint_max_ops=None)
    shadow = ServingRuntime(index, shadow_cfg)
    try:
        shadow.submit_batch(qops[0].queries)
        shadow.drain()
    finally:
        shadow.close()


def replay_runtime(wl, cfg: QuakeConfig, scfg: ServingConfig,
                   verbose: bool = True, warm: bool = False,
                   settle: bool = False,
                   faults: FaultInjector | None = None,
                   metrics_out: str | None = None,
                   trace_out: str | None = None,
                   metrics_every: int = 16) -> dict:
    """Replay a workload through the serving runtime; returns the summary
    dict ``bench_serving`` consumes (wall-clock excludes ground truth;
    ``warm=True`` pre-compiles the jitted shapes so the measurement is
    steady-state serving, not XLA compile time; ``settle=True`` runs one
    maintenance pass right after the build, before serving starts —
    fresh k-means builds leave oversized partitions that the paper's
    system would split immediately)."""
    k = scfg.k
    t0 = time.time()
    index = QuakeIndex.build(wl.initial_vectors, wl.initial_ids, config=cfg)
    maintainer = Maintainer(index, LatencyModel(dim=index.dim))
    if settle:
        maintainer.run()
    if warm:
        _warm_runtime(index, wl, scfg)
    rt = ServingRuntime(index, scfg, maintainer=maintainer, faults=faults)
    if verbose:
        print(f"built: {index.num_vectors} vectors, "
              f"{index.num_partitions} partitions ({time.time()-t0:.1f}s)")

    gt_inc = IncrementalGroundTruth(wl.dataset, wl.initial_ids)
    recalls, latencies = [], []
    serve_s = 0.0
    n_queries = 0
    for t, op in enumerate(wl.operations):
        if op.kind == "insert":
            t0 = time.perf_counter()
            rt.submit_insert(op.vectors, op.ids)
            dt = time.perf_counter() - t0
            serve_s += dt
            gt_inc.insert(op.ids)
            if verbose:
                print(f"[{t:3d}] insert {len(op.ids):6d}  {dt*1e3:7.1f}ms")
        elif op.kind == "delete":
            t0 = time.perf_counter()
            rt.submit_delete(op.ids)
            dt = time.perf_counter() - t0
            serve_s += dt
            gt_inc.delete(op.ids)
            if verbose:
                print(f"[{t:3d}] delete {len(op.ids):6d}  {dt*1e3:7.1f}ms")
        else:
            q = op.queries
            gt = gt_inc.topk(q, k)
            t0 = time.perf_counter()
            qids = rt.submit_batch(q)
            rt.drain()
            dt = time.perf_counter() - t0
            serve_s += dt
            n_queries += len(q)
            res = [rt.result(i) for i in qids]
            per_q = _recall_rows([r.ids for r in res], gt, k)
            rec = float(np.mean(per_q))
            recalls.append(rec)
            latencies.extend(r.latency_s for r in res)
            if rt.obs is not None:
                # calibration telemetry: the runtime's APS-style recall
                # estimate vs incremental-ground-truth recall, per query
                for r, true_rec in zip(res, per_q):
                    if np.isfinite(r.recall_estimate):
                        rt.obs.calibration.record_recall(
                            r.recall_estimate, true_rec)
            if verbose:
                hits = sum(r.from_cache for r in res)
                print(f"[{t:3d}] query  {len(q):6d}  "
                      f"{dt/len(q)*1e6:7.0f}us/q  recall={rec:.3f}  "
                      f"cache={hits}/{len(q)}  "
                      f"parts={index.num_partitions}")
        if metrics_out and (t + 1) % max(metrics_every, 1) == 0:
            dump_metrics(rt, metrics_out)   # periodic exposition flush
    rt.drain()
    st = rt.stats()
    if metrics_out:
        dump_metrics(rt, metrics_out)
    if trace_out and rt.obs is not None:
        rt.obs.tracer.dump_jsonl(trace_out)
    cal = None
    if rt.obs is not None:
        cal = {"latency_rel_err": rt.obs.calibration.latency_error(),
               "recall_abs_err": rt.obs.calibration.recall_error()}
    rt.close()                    # join the deadline ticker, if configured
    lat = summarize(latencies)    # the repo-wide shared percentile path
    out = {"mode": "runtime", "serve_s": round(serve_s, 3),
           "n_queries": n_queries,
           "qps": round(n_queries / max(serve_s, 1e-9), 1),
           "mean_recall": round(float(np.mean(recalls)), 4)
           if recalls else None,
           "p50_latency_us": round(lat["p50"] * 1e6, 1),
           "p99_latency_us": round(lat["p99"] * 1e6, 1),
           "final_partitions": index.num_partitions,
           "maintenance_runs": st["maintenance_runs"],
           "maintenance_reasons": st["maintenance_reasons"],
           "cache_hits": st["cache_hits"],
           "riding_savings": st["riding_savings"],
           "rounds_run": st["rounds_run"],
           "status_counts": dict(st["status_counts"]),
           "queries_shed": st["queries_shed"]}
    if cal is not None:
        out["calibration"] = cal
    if faults is not None or st["maintenance_failures"] or \
            st["cache_disabled"] or st["ticker_errors"]:
        out["failure_telemetry"] = {
            "scan_faults": st["scan_faults"],
            "scan_retries_used": st["scan_retries_used"],
            "failed_batches": st["failed_batches"],
            "maintenance_failures": st["maintenance_failures"],
            "cache_errors": st["cache_errors"],
            "cache_disabled": st["cache_disabled"],
            "ticker_errors": st["ticker_errors"],
            "ticker_restarts": st["ticker_restarts"],
            "governor": st["governor"]}
    if verbose:
        print(f"done. qps={out['qps']} recall={out['mean_recall']} "
              f"p99={out['p99_latency_us']}us maint={st['maintenance_runs']} "
              f"({','.join(st['maintenance_reasons']) or 'none'}) "
              f"cache_hits={st['cache_hits']} "
              f"riding_savings={st['riding_savings']} "
              f"statuses={dict(st['status_counts'])}")
        if cal is not None:
            print(f"calibration: latency_rel_err={cal['latency_rel_err']} "
                  f"recall_abs_err={cal['recall_abs_err']}")
        if "failure_telemetry" in out:
            print(f"failure telemetry: {out['failure_telemetry']}")
    return out


def replay_per_op(wl, cfg: QuakeConfig, k: int, verbose: bool = True,
                  maint_every_op: bool = True,
                  settle: bool = False) -> dict:
    """The legacy per-op loop: one ``index.search`` per query (with the
    configured recall target threaded through, which the old driver
    dropped) and a full maintenance pass after every operation."""
    t0 = time.time()
    index = QuakeIndex.build(wl.initial_vectors, wl.initial_ids, config=cfg)
    maintainer = Maintainer(index, LatencyModel(dim=index.dim))
    if settle:
        maintainer.run()
    if verbose:
        print(f"built: {index.num_vectors} vectors, "
              f"{index.num_partitions} partitions ({time.time()-t0:.1f}s)")
    gt_inc = IncrementalGroundTruth(wl.dataset, wl.initial_ids)
    recalls, latencies = [], []
    serve_s = 0.0
    n_queries = 0
    for t, op in enumerate(wl.operations):
        if op.kind == "insert":
            t0 = time.perf_counter()
            index.insert(op.vectors, op.ids)
            serve_s += time.perf_counter() - t0
            gt_inc.insert(op.ids)
        elif op.kind == "delete":
            t0 = time.perf_counter()
            index.delete(op.ids)
            serve_s += time.perf_counter() - t0
            gt_inc.delete(op.ids)
        else:
            q = op.queries
            gt = gt_inc.topk(q, k)
            t0 = time.perf_counter()
            rows = []
            for i in range(len(q)):
                tq = time.perf_counter()
                r = index.search(q[i], k,
                                 recall_target=cfg.recall_target)
                latencies.append(time.perf_counter() - tq)
                rows.append(r.ids)
            dt = time.perf_counter() - t0
            serve_s += dt
            n_queries += len(q)
            rec = _recall(rows, gt, k)
            recalls.append(rec)
            if verbose:
                print(f"[{t:3d}] query  {len(q):6d}  "
                      f"{dt/len(q)*1e6:7.0f}us/q  recall={rec:.3f}")
        if maint_every_op:
            t0 = time.perf_counter()
            maintainer.run()
            serve_s += time.perf_counter() - t0
    lat = summarize(latencies)    # the repo-wide shared percentile path
    out = {"mode": "per_op", "serve_s": round(serve_s, 3),
           "n_queries": n_queries,
           "qps": round(n_queries / max(serve_s, 1e-9), 1),
           "mean_recall": round(float(np.mean(recalls)), 4)
           if recalls else None,
           "p50_latency_us": round(lat["p50"] * 1e6, 1),
           "p99_latency_us": round(lat["p99"] * 1e6, 1),
           "final_partitions": index.num_partitions}
    if verbose:
        print(f"done. qps={out['qps']} recall={out['mean_recall']} "
              f"p99={out['p99_latency_us']}us")
    return out


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=30_000)
    ap.add_argument("--dim", type=int, default=32)
    ap.add_argument("--months", type=int, default=8)
    ap.add_argument("--queries-per-month", type=int, default=500)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--recall-target", type=float, default=0.9)
    ap.add_argument("--rounds", type=int, default=None,
                    help="probe-round budget per query plan")
    ap.add_argument("--flush-size", type=int, default=64)
    ap.add_argument("--cache-entries", type=int, default=4096)
    ap.add_argument("--cache-bits", type=int, default=0)
    ap.add_argument("--cache-tol", type=float, default=0.0)
    ap.add_argument("--early-exit", action="store_true")
    ap.add_argument("--no-maintenance", action="store_true")
    ap.add_argument("--per-op", action="store_true",
                    help="legacy per-op replay (maintain after every op)")
    # failure semantics (docs/serving.md): budgets, admission control,
    # degradation governor, fault injection
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="per-query latency budget; expired queries "
                         "retire PARTIAL with their running top-k")
    ap.add_argument("--queue-cap", type=int, default=None,
                    help="bounded admission queue (default unbounded)")
    ap.add_argument("--queue-policy", default="block",
                    choices=["block", "shed-oldest", "shed-newest"])
    ap.add_argument("--govern", action="store_true",
                    help="enable the degradation governor (lower the "
                         "effective recall target under queue pressure)")
    ap.add_argument("--faults", default=None, metavar="SITE=RATE[,..]",
                    help="inject seeded faults, e.g. "
                         "scan=0.05,maintenance=1.0,cache=1.0")
    ap.add_argument("--fault-seed", type=int, default=0)
    # crash-consistent durability (docs/durability.md)
    ap.add_argument("--wal-dir", default=None,
                    help="durability root: write-ahead log + checkpoints "
                         "(off by default)")
    ap.add_argument("--fsync", default="batch",
                    choices=["always", "batch", "off"],
                    help="WAL fsync policy (default batch)")
    ap.add_argument("--recover", action="store_true",
                    help="recover the index from --wal-dir (newest valid "
                         "checkpoint + WAL replay), print the recovery "
                         "report, and exit")
    # observability exposition (docs/observability.md)
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="dump the unified metrics snapshot as JSON to "
                         "PATH (plus PATH.prom in Prometheus text "
                         "format), refreshed periodically during the "
                         "replay and once at the end")
    ap.add_argument("--metrics-every", type=int, default=16,
                    help="refresh --metrics-out every N workload ops")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="dump the query-trace ring buffer as JSON-lines")
    ap.add_argument("--no-metrics", action="store_true",
                    help="disable the metrics registry / tracer / "
                         "calibration tracker entirely")
    args = ap.parse_args(argv)

    if args.recover:
        if args.wal_dir is None:
            ap.error("--recover requires --wal-dir")
        from ..core.serving import ServingRuntime as _RT
        rt = _RT.recover(args.wal_dir,
                         ServingConfig(k=args.k, fsync=args.fsync))
        rep = rt.recovery_report
        print(f"recovered {rt.index.num_vectors} vectors / "
              f"{rt.index.num_partitions} partitions from {rep.root}")
        print(f"  checkpoint generation {rep.generation} "
              f"(wal_lsn={rep.ckpt_wal_lsn})")
        print(f"  wal: last_lsn={rep.wal_last_lsn} tail={rep.wal_reason} "
              f"truncated={rep.wal_truncated_bytes}B")
        print(f"  replayed {rep.records_replayed} records "
              f"({rep.inserts_replayed} inserts, "
              f"{rep.deletes_replayed} deletes, "
              f"{rep.fingerprint_checks} fingerprint checks)")
        print(f"  write ops recovered: {rep.write_ops_recovered}")
        print(f"  fingerprint: {rep.fingerprint}")
        rt.close()
        return

    wl = wikipedia.wikipedia_workload(
        n_total=args.n, dim=args.dim, months=args.months,
        queries_per_month=args.queries_per_month)
    cfg = QuakeConfig(metric="ip", recall_target=args.recall_target)
    if args.per_op:
        replay_per_op(wl, cfg, args.k,
                      maint_every_op=not args.no_maintenance)
        return
    scfg = ServingConfig(
        k=args.k, recall_target=args.recall_target, rounds=args.rounds,
        early_exit=args.early_exit, flush_size=args.flush_size,
        cache_entries=args.cache_entries, cache_bits=args.cache_bits,
        cache_tol=args.cache_tol,
        deadline_s=args.deadline_s, queue_cap=args.queue_cap,
        queue_policy=args.queue_policy, govern=args.govern,
        wal_dir=args.wal_dir, fsync=args.fsync,
        metrics=not args.no_metrics)
    if args.no_maintenance:
        scfg.maint_min_ops = 10 ** 9      # triggers never reach min_ops
        scfg.maint_max_ops = None
    faults = (parse_fault_spec(args.faults, seed=args.fault_seed)
              if args.faults else None)
    replay_runtime(wl, cfg, scfg, faults=faults,
                   metrics_out=args.metrics_out, trace_out=args.trace_out,
                   metrics_every=args.metrics_every)


if __name__ == "__main__":
    main()
