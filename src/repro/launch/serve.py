"""End-to-end Quake serving driver (deliverable b — the paper's kind).

Replays a dynamic, skewed workload (Wikipedia-like by default) against the
dynamic index: APS search per query batch, batched inserts/deletes, and the
cost-model maintenance loop after every operation — the full online system
of paper §3.  Reports per-phase latency/recall and the maintenance history.

    PYTHONPATH=src python -m repro.launch.serve --months 8 --n 30000
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from ..core import LatencyModel, Maintainer, QuakeConfig, QuakeIndex
from ..core.multiquery import batch_search
from ..data import wikipedia


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=30_000)
    ap.add_argument("--dim", type=int, default=32)
    ap.add_argument("--months", type=int, default=8)
    ap.add_argument("--queries-per-month", type=int, default=500)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--recall-target", type=float, default=0.9)
    ap.add_argument("--no-maintenance", action="store_true")
    ap.add_argument("--batch-mode", action="store_true",
                    help="use the multi-query batched executor")
    args = ap.parse_args(argv)

    wl = wikipedia.wikipedia_workload(
        n_total=args.n, dim=args.dim, months=args.months,
        queries_per_month=args.queries_per_month)
    ds = wl.dataset
    cfg = QuakeConfig(metric="ip", recall_target=args.recall_target)
    t0 = time.time()
    index = QuakeIndex.build(wl.initial_vectors, wl.initial_ids, config=cfg)
    maintainer = Maintainer(index, LatencyModel(dim=args.dim))
    print(f"built: {index.num_vectors} vectors, "
          f"{index.num_partitions} partitions ({time.time()-t0:.1f}s)")

    resident = {int(i) for i in wl.initial_ids}
    for t, op in enumerate(wl.operations):
        if op.kind == "insert":
            t0 = time.time()
            index.insert(op.vectors, op.ids)
            resident.update(int(i) for i in op.ids)
            dt_u = time.time() - t0
            print(f"[{t:3d}] insert {len(op.ids):6d}  {dt_u*1e3:7.1f}ms")
        elif op.kind == "delete":
            t0 = time.time()
            index.delete(op.ids)
            resident.difference_update(int(i) for i in op.ids)
            print(f"[{t:3d}] delete {len(op.ids):6d}  "
                  f"{(time.time()-t0)*1e3:7.1f}ms")
        else:
            q = op.queries
            res_ids = np.asarray(sorted(resident))
            x_res = ds.vectors[res_ids]
            gt = res_ids[np.argsort(-(q @ x_res.T), axis=1)[:, :args.k]]
            t0 = time.time()
            if args.batch_mode:
                out = batch_search(index, q, args.k)
                hits = [len(set(out.ids[i]) & set(gt[i])) / args.k
                        for i in range(len(q))]
                nprobe = np.nan
            else:
                hits, nprobes = [], []
                for i in range(len(q)):
                    r = index.search(q[i], args.k)
                    hits.append(len(set(r.ids) & set(gt[i])) / args.k)
                    nprobes.append(r.nprobe[0])
                nprobe = float(np.mean(nprobes))
            dt_q = (time.time() - t0) / len(q)
            print(f"[{t:3d}] query  {len(q):6d}  {dt_q*1e6:7.0f}us/q  "
                  f"recall={np.mean(hits):.3f}  nprobe={nprobe:.1f}  "
                  f"parts={index.num_partitions}")
        if not args.no_maintenance:
            t0 = time.time()
            rep = maintainer.run()
            if rep.splits or rep.merges:
                print(f"      maint: {rep.splits} splits {rep.merges} "
                      f"merges ({time.time()-t0:.2f}s) cost "
                      f"{rep.cost_before:.0f}->{rep.cost_after:.0f}ns")
    print("done.")


if __name__ == "__main__":
    main()
