"""End-to-end LM training driver (deliverable b).

Trains a small-to-mid LM on the synthetic token pipeline with the full
production stack: sharded params (host mesh), microbatch accumulation,
AdamW + cosine schedule, async checkpointing, fault-tolerant supervision.

    PYTHONPATH=src python -m repro.launch.train --preset lm-20m --steps 200
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from ..data.pipelines import TokenPipeline
from ..models import transformer as tr
from ..train import (AdamWConfig, CheckpointManager, LoopConfig, init_state,
                     train_loop)
from ..train import steps as steps_mod
from .mesh import describe, make_host_mesh

PRESETS = {
    # ~100M-class config scaled to what a CPU container can step through;
    # on a real pod swap the preset, nothing else changes.
    "lm-100m": tr.TransformerConfig(
        n_layers=12, d_model=768, n_heads=12, n_kv_heads=4, d_ff=3072,
        vocab_size=32768, compute_dtype=jnp.float32, remat=False),
    "lm-20m": tr.TransformerConfig(
        n_layers=8, d_model=384, n_heads=8, n_kv_heads=2, d_ff=1536,
        vocab_size=8192, compute_dtype=jnp.float32, remat=False),
    "lm-tiny": tr.TransformerConfig(
        n_layers=4, d_model=128, n_heads=4, n_kv_heads=2, d_ff=512,
        vocab_size=2048, compute_dtype=jnp.float32, remat=False),
}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="lm-tiny", choices=sorted(PRESETS))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="results/ckpt_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args(argv)

    cfg = PRESETS[args.preset]
    mesh = make_host_mesh()
    print(f"mesh: {describe(mesh)}; arch: {args.preset} "
          f"(~{tr.param_count(cfg)/1e6:.1f}M params)")

    params = tr.init_params(jax.random.PRNGKey(0), cfg)
    opt_state = init_state(params)
    ocfg = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                       total_steps=args.steps)
    pipe = TokenPipeline(cfg.vocab_size, args.batch, args.seq)

    def loss(p, batch):
        return tr.lm_loss(p, batch["tokens"], cfg)

    step = jax.jit(steps_mod.make_train_step(loss, ocfg,
                                             args.microbatches),
                   donate_argnums=(0, 1))

    def step_fn(state, batch):
        p, o = state
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        p, o, m = step(p, o, batch)
        return (p, o), m

    ckpt = CheckpointManager(args.ckpt_dir)
    t0 = time.time()
    report = train_loop((params, opt_state), step_fn, pipe.batch_at, ckpt,
                        LoopConfig(n_steps=args.steps,
                                   ckpt_every=args.ckpt_every),
                        log=print)
    dt = time.time() - t0
    print(f"done: {len(report.losses)} steps in {dt:.1f}s, "
          f"loss {report.losses[0]:.3f} -> {report.losses[-1]:.3f}, "
          f"restarts={report.restarts}")


if __name__ == "__main__":
    main()
