"""Launchers: production mesh builders, the multi-pod dry-run driver, and
the end-to-end train/serve entry points.

NOTE: import ``repro.launch.dryrun`` only in a dedicated process — it forces
512 virtual host devices before jax initializes.
"""
from .mesh import describe, make_host_mesh, make_production_mesh  # noqa: F401
