# The 512-device virtual platform MUST be configured before jax (or
# anything importing jax) is imported — jax locks the device count on
# first backend initialization.
import os  # noqa: E402
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

"""Multi-pod dry-run driver (deliverable e).

For every (architecture x input shape) cell and each production mesh
(single-pod 16x16, multi-pod 2x16x16):

    lowered  = jit(step, in_shardings=...).lower(*abstract_args)
    compiled = lowered.compile()
    -> memory_analysis()  (proves the cell fits per-device HBM)
    -> cost_analysis()    (FLOPs/bytes for the roofline, §Roofline)
    -> collective bytes parsed from the optimized HLO

Results stream to JSON for EXPERIMENTS.md.  Any failure here (sharding
mismatch, OOM at compile, unsupported collective) is a bug in the system.

Usage:
    python -m repro.launch.dryrun --all
    python -m repro.launch.dryrun --arch qwen2.5-14b --shape train_4k
    python -m repro.launch.dryrun --arch quake-ann --multi-pod-only
"""
import argparse
import json
import time
import traceback
from typing import Dict, Optional

import jax

from ..compat import cost_analysis_dict
from ..configs import REGISTRY, get_arch
from ..roofline.analysis import analyze_compiled, HW_V5E
from .mesh import describe, make_production_mesh


def run_cell(arch: str, shape: str, mesh, *, verbose: bool = True) -> Dict:
    spec = get_arch(arch)
    t0 = time.time()
    lowering = spec.build(shape, mesh, smoke=False)
    lowered = lowering.lower()
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = cost_analysis_dict(compiled)
    result = analyze_compiled(compiled, mesh, arch=arch, shape=shape)
    result.update({
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "description": lowering.description,
    })
    if verbose:
        print(f"  [OK] {arch} x {shape}: "
              f"{result['bytes_per_device_gb']:.2f} GB/dev, "
              f"{result['flops_per_device_tf']:.2f} TF/dev, "
              f"coll {result['collective_gb']:.3f} GB "
              f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)")
        print(f"       dominant: {result['dominant']} | "
              f"t_comp {result['t_compute_ms']:.3f}ms "
              f"t_mem {result['t_memory_ms']:.3f}ms "
              f"t_coll {result['t_collective_ms']:.3f}ms")
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--multi-pod-only", action="store_true")
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    assert len(jax.devices()) >= 512, \
        "dry-run needs the 512 virtual devices (import order bug?)"

    meshes = []
    if not args.multi_pod_only:
        meshes.append(("single_pod", make_production_mesh(multi_pod=False)))
    if not args.single_pod_only:
        meshes.append(("multi_pod", make_production_mesh(multi_pod=True)))

    cells = []
    for name, spec in REGISTRY.items():
        if args.arch and name != args.arch:
            continue
        for shape in spec.shapes:
            if args.shape and shape != args.shape:
                continue
            cells.append((name, shape))
    if not cells:
        raise SystemExit("no cells selected")

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results = {}
    if args.skip_existing and os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)

    failures = []
    for mesh_name, mesh in meshes:
        print(f"=== mesh {mesh_name}: {describe(mesh)} ===")
        for arch, shape in cells:
            key = f"{mesh_name}/{arch}/{shape}"
            if (args.skip_existing and key in results
                    and "error" not in results[key]):
                print(f"  [skip] {key}")
                continue
            try:
                results[key] = run_cell(arch, shape, mesh)
            except Exception as e:  # noqa: BLE001 — report all failures
                traceback.print_exc()
                failures.append((key, repr(e)))
                results[key] = {"error": repr(e)}
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1)

    print(f"\n{len(results) - len(failures)} cells OK, "
          f"{len(failures)} failed -> {args.out}")
    if failures:
        for k, e in failures:
            print(f"  FAIL {k}: {e}")
        raise SystemExit(1)


if __name__ == "__main__":
    main()
