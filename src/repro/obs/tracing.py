"""Per-query trace spans, recorded compactly and expanded at read time.

Span lifecycle (docs/observability.md has the full diagram):

    admit ──┬── cache_hit ──────────────┬── done(status, ...)
            ├── (shed) ─────────────────┤
            └── flush ── round* ────────┘

The hot path never builds that event list.  It records three compact
streams — one terminal record per query (``close_many``), one metadata
record per coalesced flush (``note_flushes``), and one per scheduler
round with the qids that took cells (``note_rounds``) — and ``spans()``
joins them back into per-query event lists on demand.  A query's span
costs one dict and one ring append on the serving path instead of one
tracer acquisition and one event dict per lifecycle stage; the
obs-overhead bench cell gates exactly this.

Cache hits and shed queries complete at a single instant, so those
paths pass a prebuilt ``{"qid", "status", "events": [...]}`` record
through ``close_many`` unchanged.

Timestamps come from the runtime's injectable monotonic clock, so
traces are deterministic under fake clocks and are *durations*, not
wall-clock dates (QK401, docs/static_analysis.md).

``QueryTracer._lock`` sits next-to-innermost in
``repro.sanitize.LOCK_ORDER``: recording is legal under any runtime
lock and acquires nothing else.
"""
from __future__ import annotations

import json
from collections import deque
from typing import Dict, List, Mapping

from ..sanitize import TrackedLock, note_guarded

__all__ = ["DONE_FIELDS", "QueryTracer"]

# field order of a compact terminal record (a plain tuple: building a
# dict per query on the serving hot path is measurable; building nine
# tuple slots is not) — expanded into the span's ``done`` event by
# ``spans()``
DONE_FIELDS = ("qid", "t", "status", "rounds", "nprobe",
               "recall_estimate", "latency_s", "t_submit", "batch")


def _json_default(o):
    try:
        return float(o)          # numpy scalars and the like
    except (TypeError, ValueError):
        return str(o)


class QueryTracer:
    """Bounded ring of per-query trace spans plus audit records."""

    def __init__(self, capacity: int = 1024):
        self._lock = TrackedLock("QueryTracer._lock")
        self.capacity = max(1, int(capacity))
        # terminal records and audits; oldest evicted first
        self._ring: deque = deque(maxlen=self.capacity)
        # span-synthesis metadata, bounded separately: flush records
        # keyed by batch id, round records carrying taker qids.  A span
        # whose metadata has been evicted just renders fewer events.
        self._flushes: deque = deque(maxlen=self.capacity)
        self._rounds: deque = deque(maxlen=4 * self.capacity)
        self.emitted = 0
        self.dropped = 0        # spans evicted from the ring

    # -- recording (hot path) ------------------------------------------
    def close_many(self, recs) -> None:
        """Record terminal records under ONE lock acquisition.  Each
        record either carries a prebuilt span (``{"qid", "status",
        "events": [...]}``) or is a compact ``DONE_FIELDS`` tuple that
        ``spans()`` expands against the flush/round metadata."""
        with self._lock:
            note_guarded(self, "_ring")
            ring = self._ring
            avail = ring.maxlen - len(ring)
            n = 0
            for rec in recs:
                ring.append(rec)
                n += 1
            self.emitted += n
            if n > avail:
                self.dropped += n - avail

    def note_flushes(self, recs) -> None:
        """Record flush metadata (``{"batch", "t", "n"}``) — one per
        coalesced admission, referenced by spans through their batch
        id."""
        with self._lock:
            note_guarded(self, "_flushes")
            self._flushes.extend(recs)

    def note_rounds(self, recs) -> None:
        """Record round metadata (``{"t", "round", "partitions",
        "vectors", "wall_s", "takers"}``) — one per scheduler round;
        ``takers`` lists the qids that took cells, which is how spans
        recover their per-round scan events."""
        with self._lock:
            note_guarded(self, "_rounds")
            self._rounds.extend(recs)

    def audit(self, kind: str, record: Mapping) -> None:
        """Append a non-query audit record (e.g. a maintenance decision:
        which trigger fired, split/merge deltas) to the same ring."""
        entry = {"audit": str(kind)}
        entry.update(record)
        with self._lock:
            note_guarded(self, "_ring")
            self._ring.append(entry)

    # -- reading -------------------------------------------------------
    def spans(self) -> List[dict]:
        """Completed spans and audit records, oldest first.  Compact
        terminal records are expanded here into the full
        admit -> flush -> round* -> done event list (treat the result
        as read-only)."""
        with self._lock:
            ring = list(self._ring)
            flushes = {f["batch"]: f for f in self._flushes}
            rounds = list(self._rounds)
        by_qid: Dict[int, List[dict]] = {}
        for rr in rounds:
            for qid in rr["takers"]:
                by_qid.setdefault(qid, []).append(rr)
        out = []
        for entry in ring:
            if isinstance(entry, dict):
                # prebuilt span (cache hit / shed) or audit record
                out.append(dict(entry))
                continue
            (qid, t, status, rounds_n, nprobe, recall_est, latency_s,
             t_submit, batch) = entry
            events = [{"e": "admit", "t": t_submit}]
            f = flushes.get(batch)
            if f is not None:
                events.append({"e": "flush", "t": f["t"],
                               "batch": f["batch"]})
            for rr in by_qid.get(qid, ()):
                events.append({"e": "round", "t": rr["t"],
                               "round": rr["round"],
                               "partitions": rr["partitions"],
                               "vectors": rr["vectors"],
                               "wall_s": rr["wall_s"]})
            events.append({"e": "done", "t": t, "status": status,
                           "rounds": rounds_n, "nprobe": nprobe,
                           "recall_estimate": recall_est,
                           "latency_s": latency_s})
            out.append({"qid": qid, "status": status, "events": events})
        return out

    def counters(self) -> Dict[str, int]:
        with self._lock:
            return {"emitted": self.emitted, "dropped": self.dropped,
                    "completed": len(self._ring),
                    "flushes_tracked": len(self._flushes),
                    "rounds_tracked": len(self._rounds)}

    def dump_jsonl(self, path: str) -> int:
        """Write completed spans as JSON-lines; returns the span count."""
        spans = self.spans()
        with open(path, "w", encoding="utf-8") as f:
            for s in spans:
                f.write(json.dumps(s, default=_json_default) + "\n")
        return len(spans)
