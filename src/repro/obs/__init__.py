"""End-to-end observability for the serving stack (docs/observability.md).

Three pieces, bundled by :class:`Observability` and wired into
``ServingRuntime`` when ``ServingConfig.metrics`` is on:

* :class:`~repro.obs.registry.MetricsRegistry` — counters, gauges, and
  log-bucketed latency histograms behind the innermost-ranked lock.
* :class:`~repro.obs.tracing.QueryTracer` — per-query trace spans
  (admit → queue-wait → flush → per-round scan → terminal status) in a
  bounded ring, dumpable as JSON-lines.
* :class:`~repro.obs.calibration.CalibrationTracker` — rolling
  predicted-vs-observed latency error and estimated-vs-true recall
  error, the feedback signal for the paper's two predictive models.

``summarize`` is the repo's single shared percentile path; everything
that reports a p50/p95/p99 routes through it.
"""
from __future__ import annotations

from .calibration import CalibrationTracker
from .registry import Histogram, MetricsRegistry, summarize, to_prometheus
from .tracing import QueryTracer

__all__ = ["CalibrationTracker", "Histogram", "MetricsRegistry",
           "Observability", "QueryTracer", "summarize", "to_prometheus"]


class Observability:
    """The per-runtime bundle: one registry, one tracer, one tracker."""

    def __init__(self, lam=None, trace_capacity: int = 1024,
                 calibration_window: int = 256):
        self.metrics = MetricsRegistry()
        self.tracer = QueryTracer(capacity=trace_capacity)
        self.calibration = CalibrationTracker(
            self.metrics, lam=lam, window=calibration_window)
