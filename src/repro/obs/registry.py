"""Thread-safe metrics registry: counters, gauges, and log-bucketed
latency histograms with deterministic percentile snapshots.

Recording is designed for the serving hot path:

* ``MetricsRegistry._lock`` is the **innermost** rank in
  ``repro.sanitize.LOCK_ORDER`` (mirrored in
  ``tools/quakecheck/config.py``), so a counter bump or histogram
  observation is legal while holding any runtime lock and can never
  invert the lock order or touch the engine lock.
* A record is a dict get + add under a short critical section — no
  allocation beyond first use of a name, no device work, no I/O.

Histograms are log-bucketed: bucket ``i`` covers
``[MIN * G**(i-1), MIN * G**i)`` with ``MIN = 1 ns`` and ``G = 2**(1/8)``
(eight buckets per octave), so any reported percentile is within
~4.4 % relative error of the exact order statistic — and is clamped to
the exact observed ``[min, max]`` envelope, making single-sample and
tail snapshots exact.  ``summarize`` is the one shared percentile path
for the repo: every p50/p95/p99 printed by ``launch/serve.py`` or
``benchmarks/bench_serving.py`` routes through the same bucketing, so a
p99 means the same thing everywhere (docs/observability.md).
"""
from __future__ import annotations

import math
import re
from typing import Dict, Iterable, Mapping, Optional

import numpy as np

from ..sanitize import TrackedLock, note_guarded

__all__ = ["Histogram", "MetricsRegistry", "summarize", "to_prometheus"]

_HIST_MIN = 1e-9                      # 1 ns: anything at/below lands in bucket 0
_HIST_GROWTH = 2.0 ** 0.125           # 8 buckets per octave, <=4.4% rel. error
_LOG_GROWTH = math.log(_HIST_GROWTH)

_EMPTY_SNAPSHOT = {"count": 0, "sum": 0.0, "min": 0.0, "max": 0.0,
                   "mean": 0.0, "p50": 0.0, "p95": 0.0, "p99": 0.0}


class Histogram:
    """Log-bucketed scalar histogram (not thread-safe on its own; the
    registry serializes access, and the standalone ``summarize`` helper
    is single-threaded).

    Recording is write-optimized: ``observe``/``observe_many`` only
    append the raw value to a pending buffer (one list append per
    sample — the serving hot path records two samples per query, so
    even a ``math.log`` per sample is measurable).  The buffer folds
    into buckets in one vectorized numpy pass every ``_FOLD_AT``
    samples and on every read."""

    __slots__ = ("counts", "count", "total", "vmin", "vmax", "_pending")

    _FOLD_AT = 4096

    def __init__(self):
        self.counts: Dict[int, int] = {}
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf
        self._pending: list = []

    def observe(self, value: float) -> None:
        self._pending.append(value)
        if len(self._pending) >= self._FOLD_AT:
            self._fold()

    def observe_many(self, values) -> None:
        """Bulk observe: one buffer extend, folded lazily."""
        p = self._pending
        if isinstance(values, np.ndarray):
            p.extend(values.tolist())
        else:
            p.extend(values)
        if len(p) >= self._FOLD_AT:
            self._fold()

    def _fold(self) -> None:
        """Bucket the pending raw samples in one vectorized pass.
        Truncating the log-ratio matches ``int()`` on positives, so the
        buckets are identical to a per-sample ``math.log`` loop;
        non-finite samples are discarded here, same as a per-sample
        filter would."""
        p = self._pending
        if not p:
            return
        self._pending = []
        arr = np.asarray(p, dtype=np.float64).ravel()
        arr = arr[np.isfinite(arr)]
        if arr.size == 0:
            return
        idx = np.zeros(arr.shape, dtype=np.int64)
        big = arr > _HIST_MIN
        idx[big] = (np.log(arr[big] / _HIST_MIN)
                    / _LOG_GROWTH).astype(np.int64) + 1
        counts = self.counts
        for i, c in zip(*np.unique(idx, return_counts=True)):
            i = int(i)
            counts[i] = counts.get(i, 0) + int(c)
        self.count += int(arr.size)
        self.total += float(arr.sum())
        self.vmin = min(self.vmin, float(arr.min()))
        self.vmax = max(self.vmax, float(arr.max()))

    def percentile(self, q: float) -> float:
        """Value at quantile ``q`` in [0, 1], deterministic given the
        observations: geometric midpoint of the covering bucket, clamped
        to the exact observed [min, max]."""
        self._fold()
        if self.count == 0:
            return math.nan
        rank = min(max(q, 0.0), 1.0) * (self.count - 1)
        cum = 0
        for idx in sorted(self.counts):
            cum += self.counts[idx]
            if cum > rank:
                if idx == 0:
                    est = _HIST_MIN
                else:
                    est = _HIST_MIN * _HIST_GROWTH ** (idx - 0.5)
                return min(max(est, self.vmin), self.vmax)
        return self.vmax

    def snapshot(self) -> Dict[str, float]:
        self._fold()
        if self.count == 0:
            return dict(_EMPTY_SNAPSHOT)
        return {"count": self.count, "sum": self.total,
                "min": self.vmin, "max": self.vmax,
                "mean": self.total / self.count,
                "p50": self.percentile(0.50),
                "p95": self.percentile(0.95),
                "p99": self.percentile(0.99)}


def summarize(values: Iterable[float]) -> Dict[str, float]:
    """The shared percentile path over *raw* samples: exact order
    statistics (linear interpolation, ``numpy.percentile`` semantics)
    in the same snapshot shape the registry histograms expose
    (count/sum/min/max/mean/p50/p95/p99).  Streaming histograms must
    bucket (±4.4% relative error at 8 buckets/octave); when the full
    sample list is in hand there is no reason to pay that quantization
    — ratio gates like the obs-overhead cell would otherwise snap to
    whole bucket widths.  Empty input yields zeros."""
    xs = sorted(float(v) for v in values)
    n = len(xs)
    if n == 0:
        return dict(_EMPTY_SNAPSHOT)

    def pct(q: float) -> float:
        pos = q * (n - 1)
        lo = int(pos)
        hi = min(lo + 1, n - 1)
        return xs[lo] + (pos - lo) * (xs[hi] - xs[lo])

    total = sum(xs)
    return {"count": n, "sum": total, "min": xs[0], "max": xs[-1],
            "mean": total / n,
            "p50": pct(0.50), "p95": pct(0.95), "p99": pct(0.99)}


class MetricsRegistry:
    """Thread-safe registry of named counters, gauges, and histograms.

    Names are stable dotted strings (``serving.latency_s``,
    ``calibration.recall.abs_err`` — see docs/observability.md); the
    snapshot flattens histograms to ``<name>.p50`` etc.
    """

    def __init__(self):
        self._lock = TrackedLock("MetricsRegistry._lock")
        self._counters: Dict[str, float] = {}
        self._gauges: Dict[str, float] = {}
        self._histograms: Dict[str, Histogram] = {}

    # -- recording -----------------------------------------------------
    def inc(self, name: str, n: float = 1) -> None:
        with self._lock:
            note_guarded(self, "_counters")
            self._counters[name] = self._counters.get(name, 0) + n

    def set_gauge(self, name: str, value: float) -> None:
        with self._lock:
            note_guarded(self, "_gauges")
            self._gauges[name] = float(value)

    def observe(self, name: str, value: float) -> None:
        with self._lock:
            note_guarded(self, "_histograms")
            h = self._histograms.get(name)
            if h is None:
                h = self._histograms[name] = Histogram()
            h.observe(value)

    def update(self, counters: Optional[Mapping[str, float]] = None,
               gauges: Optional[Mapping[str, float]] = None,
               observations: Optional[Mapping[str, Iterable[float]]] = None,
               ) -> None:
        """Batched recording under ONE lock acquisition — the hot-path
        entry point.  ``TrackedLock.acquire`` carries lock-order and
        contention accounting, so per-sample ``inc``/``observe`` calls
        from a per-flush loop are measurably more expensive than one
        ``update`` with the samples batched (the obs-overhead bench
        cell gates exactly this).  ``observations`` values are
        iterables of samples."""
        with self._lock:
            note_guarded(self, "_counters")
            if counters:
                for name, n in counters.items():
                    self._counters[name] = self._counters.get(name, 0) + n
            if gauges:
                for name, v in gauges.items():
                    self._gauges[name] = float(v)
            if observations:
                for name, values in observations.items():
                    h = self._histograms.get(name)
                    if h is None:
                        h = self._histograms[name] = Histogram()
                    h.observe_many(values)

    # -- reading -------------------------------------------------------
    def counter(self, name: str) -> float:
        with self._lock:
            return self._counters.get(name, 0)

    def gauge(self, name: str) -> float:
        with self._lock:
            return self._gauges.get(name, math.nan)

    def histogram(self, name: str) -> Dict[str, float]:
        with self._lock:
            h = self._histograms.get(name)
            return h.snapshot() if h is not None else dict(_EMPTY_SNAPSHOT)

    def snapshot(self) -> Dict[str, float]:
        """One coherent flat dict: counters and gauges verbatim,
        histograms expanded to ``<name>.{count,sum,min,max,mean,p50,p95,p99}``."""
        with self._lock:
            out: Dict[str, float] = dict(self._counters)
            out.update(self._gauges)
            for name, h in self._histograms.items():
                for k, v in h.snapshot().items():
                    out[f"{name}.{k}"] = v
        return out


def to_prometheus(flat: Mapping[str, object], prefix: str = "quake") -> str:
    """Render a flat metrics dict as Prometheus text exposition.  Dotted
    names map to ``<prefix>_<name with non-alnum -> _>``; non-numeric and
    non-finite values are skipped (the JSON dump keeps them)."""
    lines = []
    for name in sorted(flat):
        v = flat[name]
        if isinstance(v, bool):
            v = int(v)
        if not isinstance(v, (int, float)):
            continue
        if not math.isfinite(float(v)):
            continue
        metric = prefix + "_" + re.sub(r"[^a-zA-Z0-9_]", "_", str(name))
        lines.append(f"{metric} {float(v):.9g}")
    return "\n".join(lines) + "\n"
