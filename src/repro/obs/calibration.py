"""Calibration telemetry: are the paper's two predictive models honest?

Quake steers execution with predictions — the ``LatencyModel`` cost
model (paper Eq. 2) picks maintenance actions and latency budgets, and
the APS ``recall_estimate`` decides when a query may stop scanning.
This tracker continuously compares both against ground truth and
exposes the rolling error as first-class registry metrics, so model
drift shows up on a dashboard instead of as silently missed targets:

* **latency**: predicted scan cost over the partitions actually folded
  (``LatencyModel.predict_scan_ns``) vs the observed scan wall time,
  recorded by ``RoundScheduler`` once per scheduler round.
* **recall**: the served ``recall_estimate`` vs true recall against
  ``IncrementalGroundTruth``, recorded per sampled query by the replay
  harnesses that hold ground truth (``launch/serve.py``,
  ``bench_serving --cell obs-overhead``).

Registry names (docs/observability.md):
``calibration.latency.{samples,rel_err,predicted_s.*,observed_s.*}``
and ``calibration.recall.{samples,abs_err}``.
"""
from __future__ import annotations

import math
from collections import deque
from typing import Optional

from ..sanitize import TrackedLock, note_guarded
from .registry import MetricsRegistry

__all__ = ["CalibrationTracker"]


class CalibrationTracker:
    """Rolling predicted-vs-observed error over a bounded window."""

    def __init__(self, registry: MetricsRegistry, lam=None, window: int = 256):
        self._lock = TrackedLock("CalibrationTracker._lock")
        self.registry = registry
        self.lam = lam                      # LatencyModel or None
        self._lat_err: deque = deque(maxlen=max(1, int(window)))
        self._rec_err: deque = deque(maxlen=max(1, int(window)))

    # -- latency -------------------------------------------------------
    def record_scan(self, sizes, observed_s: float) -> None:
        """One scheduler round: partitions of ``sizes`` were scanned in
        ``observed_s`` wall seconds."""
        if self.lam is None:
            return
        observed = float(observed_s)
        if not math.isfinite(observed) or observed <= 0.0:
            return
        predicted = float(self.lam.predict_scan_ns(sizes)) * 1e-9
        rel = abs(observed - predicted) / observed
        with self._lock:
            note_guarded(self, "_lat_err")
            self._lat_err.append(rel)
            err = sum(self._lat_err) / len(self._lat_err)
        self.registry.update(
            counters={"calibration.latency.samples": 1},
            gauges={"calibration.latency.rel_err": err},
            observations={"calibration.latency.predicted_s": (predicted,),
                          "calibration.latency.observed_s": (observed,)})

    # -- recall --------------------------------------------------------
    def record_recall(self, estimated: float, true: float) -> None:
        """One sampled query: the APS estimate vs brute-force truth."""
        est = float(estimated)
        tru = float(true)
        if not (math.isfinite(est) and math.isfinite(tru)):
            return
        with self._lock:
            note_guarded(self, "_rec_err")
            self._rec_err.append(abs(est - tru))
            err = sum(self._rec_err) / len(self._rec_err)
        self.registry.update(
            counters={"calibration.recall.samples": 1},
            gauges={"calibration.recall.abs_err": err})

    # -- reading -------------------------------------------------------
    def latency_error(self) -> Optional[float]:
        """Rolling mean relative latency error, or None before any sample."""
        with self._lock:
            if not self._lat_err:
                return None
            return sum(self._lat_err) / len(self._lat_err)

    def recall_error(self) -> Optional[float]:
        """Rolling mean absolute recall error, or None before any sample."""
        with self._lock:
            if not self._rec_err:
                return None
            return sum(self._rec_err) / len(self._rec_err)
