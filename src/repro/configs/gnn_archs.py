"""gat-cora [arXiv:1710.10903]: 2L, d_hidden=8, 8 heads, attn aggregator."""
from __future__ import annotations

from ..models import gnn
from .base import ArchSpec, register
from .families import GNN_SHAPES, build_gnn


def gat_cora() -> gnn.GATConfig:
    # d_in is per-shape (each cell fixes its own d_feat); 1433 is Cora's.
    return gnn.GATConfig(d_in=1433, d_hidden=8, n_heads=8, n_layers=2,
                         n_classes=7)


def gat_cora_smoke() -> gnn.GATConfig:
    return gnn.GATConfig(d_in=64, d_hidden=8, n_heads=4, n_layers=2,
                         n_classes=7)


register(ArchSpec(
    name="gat-cora", family="gnn", source="arXiv:1710.10903",
    shapes=tuple(GNN_SHAPES),
    model_config=gat_cora, smoke_config=gat_cora_smoke,
    build=lambda shape, mesh, smoke=False: build_gnn(
        (gat_cora_smoke if smoke else gat_cora)(), shape, mesh, smoke=smoke),
    notes="SDDMM->edge-softmax->SpMM regime via segment ops; edge-parallel "
          "sharding; minibatch_lg uses the fanout-15/10 neighbor sampler"))
