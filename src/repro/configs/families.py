"""Per-family Lowering builders (LM / GNN / RecSys).

Shapes are the assignment's cells; ``smoke=True`` swaps in tiny dimensions
(same code path, CPU-runnable).  All full-size arguments are
ShapeDtypeStructs — nothing allocates.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..compat import shard_map
from ..models import gnn, recsys, transformer as tr
from ..train import optimizer as opt, steps
from .base import SDS, Lowering, dp_axes_for, named_sharding_tree

OPT_CFG = opt.AdamWConfig()


def _adapt_lm_cfg(cfg: tr.TransformerConfig, mesh: Mesh
                  ) -> tr.TransformerConfig:
    # grouped-GQA attention when the 5-D (b,s,g,rep,d) query reshape keeps
    # a tp-divisible head factor; otherwise the repeat path shards cleanly
    tp = int(mesh.shape.get(cfg.tp_axis, 1))
    rep = cfg.n_heads // cfg.n_kv_heads
    grouped = (cfg.n_kv_heads % tp == 0) or (rep % tp == 0)
    return dataclasses.replace(cfg, dp_axes=dp_axes_for(mesh),
                               attn_grouped=grouped)


def _param_shardings(mesh, spec_tree):
    return named_sharding_tree(mesh, spec_tree)


def _opt_shardings(mesh, param_sh):
    return opt.AdamWState(step=NamedSharding(mesh, P()),
                          m=param_sh, v=param_sh)


# ---------------------------------------------------------------------------
# LM family
# ---------------------------------------------------------------------------

LM_SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1, seq_shard=True),
}
LM_SMOKE_SHAPES = {
    "train_4k": dict(kind="train", seq=64, batch=2),
    "prefill_32k": dict(kind="prefill", seq=128, batch=2),
    "decode_32k": dict(kind="decode", seq=128, batch=2),
    "long_500k": dict(kind="decode", seq=256, batch=1, seq_shard=True),
}


def build_lm(cfg: tr.TransformerConfig, shape: str, mesh: Mesh,
             smoke: bool = False, loss_chunk: int = 512,
             microbatches: int = 2, cast_params: bool = True) -> Lowering:
    sh = dict((LM_SMOKE_SHAPES if smoke else LM_SHAPES)[shape])
    cfg = _adapt_lm_cfg(cfg, mesh)
    if smoke and sh["batch"] > 1:
        # smoke batches must divide the dp shard count of whatever mesh
        import numpy as _np
        n_dp = int(_np.prod([mesh.shape[a] for a in cfg.dp_axes]))
        sh["batch"] = max(sh["batch"], n_dp)
    dp = cfg.dp_axes
    key = jax.random.PRNGKey(0)
    params_s = jax.eval_shape(lambda k: tr.init_params(k, cfg), key)
    pspec = tr.param_specs(cfg)
    psh = _param_shardings(mesh, pspec)
    rep = NamedSharding(mesh, P())

    if sh["kind"] == "train":
        opt_s = jax.eval_shape(opt.init_state, params_s)
        osh = _opt_shardings(mesh, psh)
        batch = {"tokens": SDS((sh["batch"], sh["seq"]), jnp.int32)}
        bsh = {"tokens": NamedSharding(mesh, P(dp, None))}
        loss = functools.partial(_lm_loss_adapter, cfg=cfg,
                                 chunk=loss_chunk)
        mb = 1 if smoke else microbatches
        # cast params to compute dtype once per step so FSDP all-gathers
        # move bf16, not f32 master weights (§Perf hillclimb 2, iter 1)
        cast = cfg.compute_dtype if (
            cast_params and cfg.compute_dtype != cfg.param_dtype) else None
        fn = steps.make_train_step(loss, OPT_CFG, microbatches=mb,
                                   cast_dtype=cast)
        return Lowering(
            mesh=mesh, fn=fn, args=(params_s, opt_s, batch),
            in_shardings=(psh, osh, bsh),
            donate_argnums=(0, 1),
            description=f"lm train B={sh['batch']} S={sh['seq']} mb={mb}")

    if sh["kind"] == "prefill":
        tokens = SDS((sh["batch"], sh["seq"]), jnp.int32)
        tsh = NamedSharding(mesh, P(dp, None))
        fn = functools.partial(_lm_prefill_adapter, cfg=cfg)
        return Lowering(
        mesh=mesh, fn=fn, args=(params_s, tokens),
                        in_shardings=(psh, tsh),
                        description=f"lm prefill B={sh['batch']} "
                                    f"S={sh['seq']}")

    # decode (incl. long_500k: sequence-sharded KV cache, flash-decoding)
    b, s = sh["batch"], sh["seq"]
    l, k, dh = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    cache = SDS((l, b, s, k, dh), jnp.bfloat16)
    # head-shard the cache only when kv heads divide the tp axis (MQA/GQA
    # usually don't at tp=16); otherwise shard the sequence dim — XLA then
    # emits the flash-decoding partial-softmax collectives.
    tp_size = mesh.shape[cfg.tp_axis]
    seq_shard = sh.get("seq_shard", False) or (cfg.n_kv_heads % tp_size != 0)
    cspec = tr.cache_specs(cfg, seq_shard=seq_shard)
    if seq_shard and b == 1:
        # batch cannot shard: spread the sequence over every axis
        cspec = P(None, None, dp + (cfg.tp_axis,), None, None)
    csh = NamedSharding(mesh, cspec)
    token = SDS((b,), jnp.int32)
    clen = SDS((b,), jnp.int32)
    tsh = NamedSharding(mesh, P(dp) if b > 1 else P())
    fn = functools.partial(_lm_decode_adapter, cfg=cfg)
    return Lowering(
        mesh=mesh, fn=fn,
                    args=(params_s, token, cache, cache, clen),
                    in_shardings=(psh, tsh, csh, csh, tsh),
                    donate_argnums=(2, 3),
                    description=f"lm decode B={b} ctx={s}"
                                f"{' seq-sharded' if seq_shard else ''}")


def _lm_loss_adapter(params, batch, *, cfg, chunk):
    return tr.lm_loss_chunked(params, batch["tokens"], cfg, chunk=chunk)


def _lm_prefill_adapter(params, tokens, *, cfg):
    return tr.prefill(params, tokens, cfg)


def _lm_decode_adapter(params, token, ck, cv, clen, *, cfg):
    return tr.decode_step(params, token, ck, cv, clen, cfg)


# ---------------------------------------------------------------------------
# GNN family (gat-cora)
# ---------------------------------------------------------------------------

GNN_SHAPES = {
    "full_graph_sm": dict(kind="full", n_nodes=2708, n_edges=10556,
                          d_feat=1433),
    "minibatch_lg": dict(kind="full", n_nodes=147_456, n_edges=196_608,
                         d_feat=602),   # padded 1024-seed fanout-15/10 block
    "ogb_products": dict(kind="full", n_nodes=2_449_029,
                         n_edges=61_859_140, d_feat=100),
    "molecule": dict(kind="pooled", n_graphs=128, n_nodes=30, n_edges=64,
                     d_feat=1433),
}
GNN_SMOKE_SHAPES = {
    "full_graph_sm": dict(kind="full", n_nodes=256, n_edges=1024,
                          d_feat=64),
    "minibatch_lg": dict(kind="full", n_nodes=512, n_edges=2048, d_feat=32),
    "ogb_products": dict(kind="full", n_nodes=512, n_edges=4096, d_feat=32),
    "molecule": dict(kind="pooled", n_graphs=4, n_nodes=30, n_edges=64,
                     d_feat=16),
}


def build_gnn(cfg: gnn.GATConfig, shape: str, mesh: Mesh,
              smoke: bool = False) -> Lowering:
    sh = (GNN_SMOKE_SHAPES if smoke else GNN_SHAPES)[shape]
    dp = dp_axes_for(mesh)
    cfg = dataclasses.replace(cfg, d_in=sh["d_feat"], dp_axes=dp)
    key = jax.random.PRNGKey(0)
    params_s = jax.eval_shape(lambda k: gnn.init_params(k, cfg), key)
    psh = _param_shardings(mesh, gnn.param_specs(cfg))
    opt_s = jax.eval_shape(opt.init_state, params_s)
    osh = _opt_shardings(mesh, psh)
    rep = NamedSharding(mesh, P())
    esh = NamedSharding(mesh, P(dp))

    n_shards = int(np.prod([mesh.shape[a] for a in dp]))
    n_edges = -(-sh["n_edges"] // n_shards) * n_shards  # pad to shardable
    if sh["kind"] == "pooled":
        n_nodes = sh["n_graphs"] * sh["n_nodes"]
        n_edges_total = -(-sh["n_graphs"] * sh["n_edges"] * 2
                          // n_shards) * n_shards
        batch = {"src": SDS((n_edges_total,), jnp.int32),
                 "dst": SDS((n_edges_total,), jnp.int32),
                 "feats": SDS((n_nodes, sh["d_feat"]), jnp.float32),
                 "graph_of": SDS((n_nodes,), jnp.int32),
                 "labels": SDS((sh["n_graphs"],), jnp.int32)}
        bsh = {"src": esh, "dst": esh, "feats": rep, "graph_of": rep,
               "labels": rep}
        fn = _make_gnn_pooled_step(cfg, mesh, sh["n_graphs"])
    else:
        batch = {"src": SDS((n_edges,), jnp.int32),
                 "dst": SDS((n_edges,), jnp.int32),
                 "feats": SDS((sh["n_nodes"], sh["d_feat"]), jnp.float32),
                 "labels": SDS((sh["n_nodes"],), jnp.int32)}
        bsh = {"src": esh, "dst": esh, "feats": rep, "labels": rep}
        fn = _make_gnn_step(cfg, mesh)
    return Lowering(
        mesh=mesh, fn=fn, args=(params_s, opt_s, batch),
                    in_shardings=(psh, osh, bsh), donate_argnums=(0, 1),
                    description=f"gnn {shape}: {sh}")


def _make_gnn_step(cfg: gnn.GATConfig, mesh: Mesh):
    """Edge-parallel train step: grads computed inside shard_map (collectives
    in gnn.forward make per-shard grads globally correct via psum
    transpose), optimizer applied on replicated params."""
    dp = cfg.dp_axes

    def local_grad(params, batch):
        def loss(p):
            return gnn.loss_fn(p, batch["feats"], batch["src"],
                               batch["dst"], batch["labels"], cfg, axis=dp)
        l, g = jax.value_and_grad(loss)(params)
        return l, g

    def step(params, opt_state, batch):
        mapped = shard_map(
            local_grad, mesh=mesh,
            in_specs=(P(), {"src": P(dp), "dst": P(dp), "feats": P(),
                            "labels": P()}),
            out_specs=(P(), P()), check_vma=True)
        loss, grads = mapped(params, batch)
        params, opt_state, info = opt.apply_update(params, grads, opt_state,
                                                   OPT_CFG)
        return params, opt_state, {"loss": loss, **info}

    return step


def _make_gnn_pooled_step(cfg: gnn.GATConfig, mesh: Mesh, n_graphs: int):
    dp = cfg.dp_axes

    def local_grad(params, batch):
        def loss(p):
            logits = gnn.graph_pool_logits(
                p, batch["feats"], batch["src"], batch["dst"],
                batch["graph_of"], n_graphs, cfg, axis=dp)
            lse = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(
                logits, batch["labels"][:, None].astype(jnp.int32),
                axis=-1)[:, 0]
            return jnp.mean(lse - gold)
        return jax.value_and_grad(loss)(params)

    def step(params, opt_state, batch):
        mapped = shard_map(
            local_grad, mesh=mesh,
            in_specs=(P(), {"src": P(dp), "dst": P(dp), "feats": P(),
                            "graph_of": P(), "labels": P()}),
            out_specs=(P(), P()), check_vma=True)
        loss, grads = mapped(params, batch)
        params, opt_state, info = opt.apply_update(params, grads, opt_state,
                                                   OPT_CFG)
        return params, opt_state, {"loss": loss, **info}

    return step


# ---------------------------------------------------------------------------
# RecSys family
# ---------------------------------------------------------------------------

RECSYS_SHAPES = {
    "train_batch": dict(kind="train", batch=65536),
    "serve_p99": dict(kind="serve", batch=512),
    "serve_bulk": dict(kind="serve", batch=262144),
    "retrieval_cand": dict(kind="retrieval", batch=1, n_cand=1_000_000),
}
RECSYS_SMOKE_SHAPES = {
    "train_batch": dict(kind="train", batch=32),
    "serve_p99": dict(kind="serve", batch=8),
    "serve_bulk": dict(kind="serve", batch=64),
    "retrieval_cand": dict(kind="retrieval", batch=1, n_cand=512),
}

RECSYS_FNS = {
    "din": (recsys.din_init, recsys.din_specs, recsys.din_loss,
            recsys.din_forward),
    "sasrec": (recsys.sasrec_init, recsys.sasrec_specs, recsys.sasrec_loss,
               None),
    "two-tower-retrieval": (recsys.twotower_init, recsys.twotower_specs,
                            recsys.twotower_loss, None),
    "dlrm-rm2": (recsys.dlrm_init, recsys.dlrm_specs, recsys.dlrm_loss,
                 recsys.dlrm_forward),
}


def _recsys_batch_specs(model: str, mcfg, batch: int, mesh: Mesh,
                        hist_len: int):
    dp = dp_axes_for(mesh)
    bsh = NamedSharding(mesh, P(dp))
    b2 = NamedSharding(mesh, P(dp, None))
    rep = NamedSharding(mesh, P())
    batch_s = {"dense": SDS((batch, 13), jnp.float32),
               "sparse": SDS((batch, getattr(mcfg, "n_sparse", 26)),
                             jnp.int32),
               "history": SDS((batch, hist_len), jnp.int32),
               "history_mask": SDS((batch, hist_len), jnp.bool_),
               "target_item": SDS((batch,), jnp.int32),
               "label": SDS((batch,), jnp.float32)}
    specs = {"dense": b2, "sparse": b2, "history": b2, "history_mask": b2,
             "target_item": bsh, "label": bsh}
    return batch_s, specs


def build_recsys(model: str, mcfg, shape: str, mesh: Mesh,
                 smoke: bool = False) -> Lowering:
    sh = (RECSYS_SMOKE_SHAPES if smoke else RECSYS_SHAPES)[shape]
    init_fn, specs_fn, loss_fn, fwd_fn = RECSYS_FNS[model]
    key = jax.random.PRNGKey(0)
    params_s = jax.eval_shape(lambda k: init_fn(k, mcfg), key)
    psh = _param_shardings(mesh, specs_fn(mcfg))
    hist_len = getattr(mcfg, "seq_len", getattr(mcfg, "hist_len", 50))
    dp = dp_axes_for(mesh)
    rep = NamedSharding(mesh, P())

    if sh["kind"] == "train":
        batch_s, bsh = _recsys_batch_specs(model, mcfg, sh["batch"], mesh,
                                           hist_len)
        opt_s = jax.eval_shape(opt.init_state, params_s)
        osh = _opt_shardings(mesh, psh)
        fn = steps.make_train_step(
            functools.partial(_recsys_loss_adapter, loss_fn=loss_fn,
                              mcfg=mcfg), OPT_CFG)
        return Lowering(
        mesh=mesh, fn=fn, args=(params_s, opt_s, batch_s),
                        in_shardings=(psh, osh, bsh), donate_argnums=(0, 1),
                        description=f"{model} train B={sh['batch']}")

    if sh["kind"] == "serve":
        batch_s, bsh = _recsys_batch_specs(model, mcfg, sh["batch"], mesh,
                                           hist_len)
        fwd = fwd_fn or functools.partial(_recsys_score_adapter, model=model)
        fn = functools.partial(_recsys_serve_adapter, fwd=fwd, mcfg=mcfg)
        return Lowering(
        mesh=mesh, fn=fn, args=(params_s, batch_s),
                        in_shardings=(psh, bsh),
                        description=f"{model} serve B={sh['batch']}")

    # retrieval_cand: one user context against n_cand candidates
    n_shards = int(np.prod([mesh.shape[a] for a in dp + ("model",)]))
    n_cand = -(-sh["n_cand"] // n_shards) * n_shards  # pad to shardable
    user = {"history": SDS((1, hist_len), jnp.int32),
            "history_mask": SDS((1, hist_len), jnp.bool_),
            "dense": SDS((1, 13), jnp.float32)}
    ush = {"history": rep, "history_mask": rep, "dense": rep}
    cands = SDS((n_cand,), jnp.int32)
    csh = NamedSharding(mesh, P(dp + ("model",)))
    fn = functools.partial(_recsys_retrieval_adapter, model=model, mcfg=mcfg)
    return Lowering(
        mesh=mesh, fn=fn, args=(params_s, user, cands),
                    in_shardings=(psh, ush, csh),
                    description=f"{model} retrieval n_cand={n_cand}")


def _recsys_loss_adapter(params, batch, *, loss_fn, mcfg):
    return loss_fn(params, batch, mcfg)


def _recsys_serve_adapter(params, batch, *, fwd, mcfg):
    return fwd(params, batch, mcfg)


def _recsys_score_adapter(params, batch, mcfg, *, model):
    """Serve scores for the models whose natural serve output is a
    relevance score (sasrec next-item / two-tower user-item)."""
    if model == "sasrec":
        h = recsys.sasrec_encode(params, batch["history"],
                                 batch["history_mask"], mcfg)
        tgt = jnp.take(params["item_embed"], batch["target_item"], axis=0)
        return jnp.sum(h * tgt, axis=-1)
    u = recsys.user_repr(params, batch, mcfg)
    v = recsys.item_repr(params, batch["target_item"], mcfg)
    return jnp.sum(u * v, axis=-1)


def _recsys_retrieval_adapter(params, user, cand_ids, *, model, mcfg):
    """Score 1M candidates for one user — batched dot / broadcast ranking,
    never a loop.  (The ANN-served variant goes through the Quake engine —
    see examples/retrieval_serving.py.)"""
    if model == "two-tower-retrieval":
        u = recsys.user_repr(params, user, mcfg)            # (1, d)
        v = recsys.item_repr(params, cand_ids, mcfg)        # (N, d)
        return (u @ v.T)[0]
    if model == "sasrec":
        h = recsys.sasrec_encode(params, user["history"],
                                 user["history_mask"], mcfg)
        v = jnp.take(params["item_embed"], cand_ids, axis=0)
        return (h @ v.T)[0]
    if model == "din":
        n = cand_ids.shape[0]
        batch = {"history": jnp.broadcast_to(user["history"],
                                             (n,) + user["history"].shape[1:]),
                 "history_mask": jnp.broadcast_to(
                     user["history_mask"],
                     (n,) + user["history_mask"].shape[1:]),
                 "dense": jnp.broadcast_to(user["dense"], (n, 13)),
                 "target_item": cand_ids}
        return recsys.din_forward(params, batch, mcfg)
    # dlrm: vary the first sparse field (item), fix the rest
    n = cand_ids.shape[0]
    sparse = jnp.zeros((n, mcfg.n_sparse), jnp.int32)
    sparse = sparse.at[:, 0].set(cand_ids)
    batch = {"dense": jnp.broadcast_to(user["dense"], (n, mcfg.n_dense)),
             "sparse": sparse}
    return recsys.dlrm_forward(params, batch, mcfg)
