"""The five assigned LM architectures (exact published configs)."""
from __future__ import annotations

import functools

import jax.numpy as jnp

from ..models import transformer as tr
from .base import ArchSpec, register
from .families import LM_SHAPES, build_lm

LM_SHAPE_NAMES = tuple(LM_SHAPES)


def _lm_spec(name, source, full_cfg_fn, smoke_cfg_fn, notes="",
             microbatches=2):
    return register(ArchSpec(
        name=name, family="lm", source=source, shapes=LM_SHAPE_NAMES,
        model_config=full_cfg_fn, smoke_config=smoke_cfg_fn,
        build=lambda shape, mesh, smoke=False, **kw: build_lm(
            (smoke_cfg_fn if smoke else full_cfg_fn)(), shape, mesh,
            smoke=smoke, **({"microbatches": microbatches} | kw)),
        notes=notes))


# -- mistral-large-123b [hf:mistralai/Mistral-Large-Instruct-2407] ----------

def mistral_large_123b() -> tr.TransformerConfig:
    return tr.TransformerConfig(
        n_layers=88, d_model=12288, n_heads=96, n_kv_heads=8, d_head=128,
        d_ff=28672, vocab_size=32768)


def mistral_large_smoke() -> tr.TransformerConfig:
    return tr.TransformerConfig(
        n_layers=2, d_model=128, n_heads=8, n_kv_heads=2, d_head=16,
        d_ff=256, vocab_size=512, remat=False,
        compute_dtype=jnp.float32)


_lm_spec("mistral-large-123b", "hf:mistralai/Mistral-Large-Instruct-2407",
         mistral_large_123b, mistral_large_smoke,
         notes="dense 88L GQA kv=8", microbatches=4)


# -- granite-34b [arXiv:2405.04324] — llama-arch code model, MQA ------------

def granite_34b() -> tr.TransformerConfig:
    return tr.TransformerConfig(
        n_layers=88, d_model=6144, n_heads=48, n_kv_heads=1, d_head=128,
        d_ff=24576, vocab_size=49152)


def granite_smoke() -> tr.TransformerConfig:
    return tr.TransformerConfig(
        n_layers=2, d_model=96, n_heads=6, n_kv_heads=1, d_head=16,
        d_ff=192, vocab_size=512, remat=False,
        compute_dtype=jnp.float32)


_lm_spec("granite-34b", "arXiv:2405.04324", granite_34b, granite_smoke,
         notes="dense 88L MQA (kv=1), code model")


# -- qwen2.5-14b [hf:Qwen/Qwen2.5-14B] — GQA + QKV bias ---------------------

def qwen25_14b() -> tr.TransformerConfig:
    return tr.TransformerConfig(
        n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_head=128,
        d_ff=13824, vocab_size=152064, qkv_bias=True)


def qwen25_smoke() -> tr.TransformerConfig:
    return tr.TransformerConfig(
        n_layers=2, d_model=80, n_heads=5, n_kv_heads=1, d_head=16,
        d_ff=160, vocab_size=512, qkv_bias=True, remat=False,
        compute_dtype=jnp.float32)


_lm_spec("qwen2.5-14b", "hf:Qwen/Qwen2.5-14B", qwen25_14b, qwen25_smoke,
         notes="dense 48L GQA kv=8, QKV bias, 152k vocab")


# -- qwen3-moe-235b-a22b [hf:Qwen/Qwen3-235B-A22B] — 128e top-8 -------------

def qwen3_moe_235b() -> tr.TransformerConfig:
    return tr.TransformerConfig(
        n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, d_head=128,
        d_ff=0, vocab_size=151936,
        moe=tr.MoEConfig(n_experts=128, top_k=8, d_ff=1536))


def qwen3_moe_smoke() -> tr.TransformerConfig:
    return tr.TransformerConfig(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=0, vocab_size=512, remat=False,
        compute_dtype=jnp.float32,
        moe=tr.MoEConfig(n_experts=8, top_k=2, d_ff=32, group_size=64))


_lm_spec("qwen3-moe-235b-a22b", "hf:Qwen/Qwen3-235B-A22B",
         qwen3_moe_235b, qwen3_moe_smoke, notes="MoE 128e top-8, 94L")


# -- llama4-scout-17b-16e [hf:meta-llama/Llama-4-Scout-17B-16E] -------------
# MoE 16 routed experts top-1 + 1 shared expert; multimodal early fusion —
# the vision frontend is a STUB per the assignment (text backbone only).

def llama4_scout() -> tr.TransformerConfig:
    return tr.TransformerConfig(
        n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_head=128,
        d_ff=0, vocab_size=202048,
        moe=tr.MoEConfig(n_experts=16, top_k=1, d_ff=8192, n_shared=1))


def llama4_scout_smoke() -> tr.TransformerConfig:
    return tr.TransformerConfig(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=0, vocab_size=512, remat=False,
        compute_dtype=jnp.float32,
        moe=tr.MoEConfig(n_experts=4, top_k=1, d_ff=64, n_shared=1,
                         group_size=64))


_lm_spec("llama4-scout-17b-a16e", "hf:meta-llama/Llama-4-Scout-17B-16E",
         llama4_scout, llama4_scout_smoke,
         notes="MoE 16e top-1 + shared expert; modality frontend stubbed")
