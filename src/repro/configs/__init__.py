"""Architecture registry: the 10 assigned architectures + quake-ann.

``get_arch(name).build(shape, mesh, smoke=...)`` returns a Lowering for any
(arch x shape x mesh) cell; ``all_cells()`` enumerates the full table.
"""
from .base import (ArchSpec, Lowering, REGISTRY, all_cells,  # noqa: F401
                   get_arch)
from . import gnn_archs, lm_archs, quake_arch, recsys_archs  # noqa: F401
