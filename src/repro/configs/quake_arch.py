"""quake-ann: the paper's own serving configuration as a first-class arch.

An MSTURING100M-scale snapshot (1.6e8 padded slots, d=128) sharded over the
partition axes, with four shape cells:

  * serve_fixed_1k    — 1024 queries, static nprobe (baseline engine)
  * serve_adaptive_1k — 1024 queries, APS rounds (the paper's contribution)
  * bulk_brute_8k     — 8192 queries, exact multi-query scan
  * maint_assign_1m   — maintenance hot op: route 1M inserted vectors to
                        partitions (fused distance+argmin)

These cells are what the §Perf hillclimb of the paper's own technique
iterates on.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..core.distributed import EngineConfig, IndexSnapshot, ShardedQuakeEngine
from ..kernels import ref
from .base import SDS, ArchSpec, Lowering, dp_axes_for, register

FULL = dict(p=16384, s_cap=12288, d=128, k=100)
SMOKE = dict(p=64, s_cap=64, d=32, k=10)

QUAKE_SHAPES = {
    "serve_fixed_1k": dict(kind="fixed", batch=1024, nprobe=64),
    "serve_adaptive_1k": dict(kind="adaptive", batch=1024),
    "bulk_brute_8k": dict(kind="brute", batch=8192),
    "maint_assign_1m": dict(kind="assign", n=1_000_000),
}
QUAKE_SMOKE_SHAPES = {
    "serve_fixed_1k": dict(kind="fixed", batch=16, nprobe=4),
    "serve_adaptive_1k": dict(kind="adaptive", batch=16),
    "bulk_brute_8k": dict(kind="brute", batch=32),
    "maint_assign_1m": dict(kind="assign", n=4096),
}


def _snapshot_sds(dims, n_shards: int, storage: str = "f32"
                  ) -> IndexSnapshot:
    p = -(-dims["p"] // n_shards) * n_shards
    dt = {"f32": jnp.float32, "bf16": jnp.bfloat16,
          "int8": jnp.int8}[storage]
    return IndexSnapshot(
        data=SDS((p, dims["s_cap"], dims["d"]), dt),
        ids=SDS((p, dims["s_cap"]), jnp.int32),
        centroids=SDS((p, dims["d"]), jnp.float32),
        sizes=SDS((p,), jnp.int32),
        beta_table=SDS((1024,), jnp.float32),
        scales=(SDS((p, dims["s_cap"]), jnp.float32)
                if storage == "int8" else None))


def build_quake(shape: str, mesh, smoke: bool = False,
                engine_overrides: dict | None = None) -> Lowering:
    dims = SMOKE if smoke else FULL
    sh = (QUAKE_SMOKE_SHAPES if smoke else QUAKE_SHAPES)[shape]
    dp = dp_axes_for(mesh)

    if sh["kind"] == "assign":
        # maintenance routing: points sharded over dp, centroids replicated
        from ..kernels.ref import kmeans_assign_ref
        pts = SDS((sh["n"], dims["d"]), jnp.float32)
        cents = SDS((dims["p"], dims["d"]), jnp.float32)
        return Lowering(
        mesh=mesh, fn=kmeans_assign_ref, args=(pts, cents),
            in_shardings=(NamedSharding(mesh, P(dp, None)),
                          NamedSharding(mesh, P())),
            description=f"quake maintenance assign n={sh['n']}")

    cfg = EngineConfig(metric="l2", k=dims["k"],
                       nprobe=sh.get("nprobe", 16),
                       part_axes=dp, batch_axis="model",
                       **(engine_overrides or {}))
    eng = ShardedQuakeEngine(mesh, cfg)
    snap = _snapshot_sds(dims, eng.n_part_shards, cfg.storage_dtype)
    b = sh["batch"]
    q = SDS((b, dims["d"]), jnp.float32)
    qsh = NamedSharding(mesh, eng.query_spec())
    snap_sh = jax.tree.map(
        lambda s: NamedSharding(mesh, s), eng.snapshot_spec(),
        is_leaf=lambda x: isinstance(x, P))
    return Lowering(
        mesh=mesh, fn=eng.mapped_fn(sh["kind"]), args=(q, snap),
                    in_shardings=(qsh, snap_sh),
                    description=f"quake {sh['kind']} B={b} "
                                f"P={snap.data.shape[0]}")


register(ArchSpec(
    name="quake-ann", family="ann",
    source="Quake (this paper)", shapes=tuple(QUAKE_SHAPES),
    model_config=lambda: dict(FULL),
    smoke_config=lambda: dict(SMOKE),
    build=build_quake,
    notes="the paper's own serving engine on the production mesh"))
