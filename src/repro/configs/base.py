"""Architecture registry protocol.

Every assigned architecture provides an ``ArchSpec``:

  * ``model_config()`` — the exact published configuration,
  * ``smoke_config()`` — a reduced same-family config for CPU smoke tests,
  * ``shapes``          — its assigned input-shape cells,
  * ``build(shape, mesh, smoke)`` — a ``Lowering``: the jittable step
    function, abstract (ShapeDtypeStruct) arguments, and in/out shardings
    for the production mesh.  ``dryrun.py`` calls
    ``jit(fn, in_shardings=...).lower(*args).compile()`` on it.

Nothing here allocates device memory for full-size configs — parameters and
optimizer state are ``jax.eval_shape`` results.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Pytree = Any
SDS = jax.ShapeDtypeStruct


@dataclass
class Lowering:
    """Everything needed to lower+compile one (arch x shape x mesh) cell."""
    fn: Callable
    args: Tuple[Pytree, ...]            # ShapeDtypeStruct pytrees
    in_shardings: Tuple[Pytree, ...]    # NamedSharding pytrees
    mesh: Optional[Any] = None          # context mesh: makes the model's
    # internal with_sharding_constraint(PartitionSpec) calls effective
    donate_argnums: Tuple[int, ...] = ()
    static_argnums: Tuple[int, ...] = ()
    description: str = ""

    def lower(self):
        jitted = jax.jit(self.fn, in_shardings=self.in_shardings,
                         donate_argnums=self.donate_argnums)
        if self.mesh is not None:
            with jax.set_mesh(self.mesh):
                return jitted.lower(*self.args)
        return jitted.lower(*self.args)


@dataclass
class ArchSpec:
    name: str
    family: str                          # "lm" | "gnn" | "recsys" | "ann"
    source: str                          # citation tag from the assignment
    shapes: Tuple[str, ...]
    model_config: Callable[[], Any]
    smoke_config: Callable[[], Any]
    build: Callable[..., Lowering]       # (shape, mesh, smoke=False)
    notes: str = ""


REGISTRY: Dict[str, ArchSpec] = {}


def register(spec: ArchSpec) -> ArchSpec:
    REGISTRY[spec.name] = spec
    return spec


def get_arch(name: str) -> ArchSpec:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(REGISTRY)}")
    return REGISTRY[name]


def all_cells():
    for name, spec in REGISTRY.items():
        for shape in spec.shapes:
            yield name, shape


def named_sharding_tree(mesh: Mesh, spec_tree: Pytree) -> Pytree:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def eval_params(init_fn: Callable, *args) -> Pytree:
    """Abstract parameter tree — no allocation."""
    return jax.eval_shape(functools.partial(init_fn, *args))


def dp_axes_for(mesh: Mesh) -> Tuple[str, ...]:
    """Data-parallel axes present in this mesh (pod is dp when present)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
