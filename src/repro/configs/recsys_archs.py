"""The four assigned recsys architectures (exact published configs)."""
from __future__ import annotations

from ..models import recsys
from .base import ArchSpec, register
from .families import RECSYS_SHAPES, build_recsys

SHAPES = tuple(RECSYS_SHAPES)


def _recsys_spec(name, source, full_fn, smoke_fn, notes=""):
    return register(ArchSpec(
        name=name, family="recsys", source=source, shapes=SHAPES,
        model_config=full_fn, smoke_config=smoke_fn,
        build=lambda shape, mesh, smoke=False: build_recsys(
            name, (smoke_fn if smoke else full_fn)(), shape, mesh,
            smoke=smoke),
        notes=notes))


# -- DIN [arXiv:1706.06978] --------------------------------------------------

def din() -> recsys.DINConfig:
    return recsys.DINConfig(vocab=10_000_000, embed_dim=18, seq_len=100,
                            attn_mlp=(80, 40), mlp=(200, 80))


def din_smoke() -> recsys.DINConfig:
    return recsys.DINConfig(vocab=1000, embed_dim=18, seq_len=50,
                            attn_mlp=(80, 40), mlp=(200, 80))


_recsys_spec("din", "arXiv:1706.06978", din, din_smoke,
             notes="target-attention over user history; 10M-row table")


# -- SASRec [arXiv:1808.09781] ------------------------------------------------

def sasrec() -> recsys.SASRecConfig:
    return recsys.SASRecConfig(vocab=1_000_000, embed_dim=50, n_blocks=2,
                               n_heads=1, seq_len=50)


def sasrec_smoke() -> recsys.SASRecConfig:
    return recsys.SASRecConfig(vocab=1000, embed_dim=50, n_blocks=2,
                               n_heads=1, seq_len=50)


_recsys_spec("sasrec", "arXiv:1808.09781", sasrec, sasrec_smoke,
             notes="self-attentive sequential; in-batch softmax loss")


# -- Two-tower retrieval [RecSys'19 YouTube] ----------------------------------

def two_tower() -> recsys.TwoTowerConfig:
    return recsys.TwoTowerConfig(user_vocab=10_000_000,
                                 item_vocab=10_000_000, embed_dim=256,
                                 tower_mlp=(1024, 512, 256))


def two_tower_smoke() -> recsys.TwoTowerConfig:
    return recsys.TwoTowerConfig(user_vocab=1000, item_vocab=1000,
                                 embed_dim=256, tower_mlp=(1024, 512, 256))


_recsys_spec("two-tower-retrieval", "RecSys'19 (YouTube)", two_tower,
             two_tower_smoke,
             notes="sampled-softmax retrieval with logQ correction; "
                   "retrieval_cand is Quake's direct use case "
                   "(DESIGN.md §5)")


# -- DLRM RM-2 [arXiv:1906.00091] ----------------------------------------------

def dlrm_rm2() -> recsys.DLRMConfig:
    return recsys.DLRMConfig(n_dense=13, n_sparse=26, vocab=5_000_000,
                             embed_dim=64, bot_mlp=(512, 256, 64),
                             top_mlp=(512, 512, 256, 1))


def dlrm_smoke() -> recsys.DLRMConfig:
    return recsys.DLRMConfig(n_dense=13, n_sparse=26, vocab=1000,
                             embed_dim=64, bot_mlp=(512, 256, 64),
                             top_mlp=(512, 512, 256, 1))


_recsys_spec("dlrm-rm2", "arXiv:1906.00091", dlrm_rm2, dlrm_smoke,
             notes="26 row-sharded 5M-row tables; dot interaction")
