"""Runtime sanitizer harness for the device-resident executor stack.

Three composable guards (see ``docs/static_analysis.md``):

* **Transfer guard** — ``jax.transfer_guard("disallow")`` turns any
  *implicit* device<->host transfer inside the guarded region into an
  error.  Explicit ``jax.device_put`` / ``jax.device_get`` / ``jnp.asarray``
  conversions still work, so the guarded region proves the hot path only
  moves data at its declared boundaries (the quakecheck ``allow-sync``
  points).
* **NaN debugging** — ``jax.debug_nans`` re-runs de-optimized on NaN
  production so silent NaN propagation in kernels fails loudly.
* **Compile-event counter** — counts real XLA compilations via
  ``jax.monitoring``'s ``backend_compile`` duration events.  This is the
  ground truth for jit-cache discipline: the shape-padding buckets
  (``u_bucket``/``b_bucket``/``part_bucket``) exist to keep this counter
  flat, and ``results/compile_budget.json`` pins per-entry-point budgets
  that CI enforces (:func:`assert_compile_budget`).

``sanitized()`` stacks them; tests opt in through the ``sanitized``
pytest fixture (``tests/conftest.py``).  ``cost_model.profile`` uses the
counter to warm deterministically: re-run until a call compiles nothing,
instead of hoping one warm call covered every shape.
"""
from __future__ import annotations

import contextlib
import json
import threading
from pathlib import Path
from typing import Dict, Iterator, Optional

import jax

__all__ = ["compile_count", "compile_events", "sanitized",
           "warm_until_stable", "load_compile_budget",
           "assert_compile_budget", "BUDGET_PATH"]

BUDGET_PATH = Path(__file__).resolve().parents[2] / "results" \
    / "compile_budget.json"

_lock = threading.Lock()
_count = 0
_registered = False


def _listener(event: str, duration: float, **kwargs) -> None:
    # '/jax/core/compile/backend_compile_duration' fires once per actual
    # XLA compilation (cache hits don't emit it); match loosely so a
    # renamed prefix on a newer JAX still counts (the counter-sanity test
    # in tests/test_sanitize.py fails loudly if the event disappears).
    global _count
    if "backend_compile" in event:
        with _lock:
            _count += 1


def _ensure_listener() -> None:
    # jax.monitoring has no unregister API: register once, snapshot the
    # counter per context instead.
    global _registered
    with _lock:
        if _registered:
            return
        jax.monitoring.register_event_duration_secs_listener(_listener)
        _registered = True


def compile_count() -> int:
    """Monotonic count of XLA compilations observed so far."""
    _ensure_listener()
    with _lock:
        return _count


class CompileEvents:
    """Counter scope: ``new()`` is the number of compilations since the
    scope opened (or since the last ``reset()``)."""

    def __init__(self) -> None:
        self._start = compile_count()

    def new(self) -> int:
        return compile_count() - self._start

    def reset(self) -> None:
        self._start = compile_count()


@contextlib.contextmanager
def compile_events() -> Iterator[CompileEvents]:
    yield CompileEvents()


@contextlib.contextmanager
def sanitized(transfers: bool = True, nans: bool = True,
              compiles: bool = True) -> Iterator[Optional[CompileEvents]]:
    """Run the enclosed block under the stacked sanitizers.

    Yields the :class:`CompileEvents` scope when ``compiles`` is on
    (else None).  Device operands must be staged with explicit
    ``device_put``/``jnp.asarray`` *before* entering when ``transfers``
    is on — that is the point.
    """
    with contextlib.ExitStack() as stack:
        if transfers:
            stack.enter_context(jax.transfer_guard("disallow"))
        if nans:
            stack.enter_context(jax.debug_nans(True))
        yield CompileEvents() if compiles else None


def warm_until_stable(fn, *, max_rounds: int = 8) -> int:
    """Call ``fn()`` until a call triggers zero new compilations (the
    deterministic warm-up ``cost_model.profile`` uses — a single warm
    call can miss shapes reached lazily).  Returns the number of warm
    calls made; raises if the compile count never settles."""
    ev = CompileEvents()
    for i in range(max_rounds):
        ev.reset()
        fn()
        if ev.new() == 0:
            return i + 1
    raise RuntimeError(
        f"compile count did not stabilize after {max_rounds} warm calls "
        f"— the timed path re-traces per call (jit cache fragmentation; "
        f"see quakecheck QK102)")


def load_compile_budget(path: Optional[Path] = None) -> Dict[str, int]:
    """The per-entry-point compile budgets (``results/compile_budget.json``
    ``{"budgets": {entry_point: max_compiles}}``)."""
    p = Path(path) if path is not None else BUDGET_PATH
    with open(p, "r", encoding="utf-8") as fh:
        data = json.load(fh)
    return {k: int(v) for k, v in data["budgets"].items()}


def assert_compile_budget(entry_point: str, observed: int,
                          path: Optional[Path] = None) -> None:
    """Fail (AssertionError) if ``observed`` compilations exceed the
    entry point's pinned budget.  Unknown entry points fail too: a new
    hot path must declare its budget before CI will gate it."""
    budgets = load_compile_budget(path)
    if entry_point not in budgets:
        raise AssertionError(
            f"no compile budget declared for {entry_point!r} in "
            f"{path or BUDGET_PATH} — add one (budgets: "
            f"{sorted(budgets)})")
    budget = budgets[entry_point]
    assert observed <= budget, (
        f"{entry_point}: {observed} compilations observed, budget is "
        f"{budget} — a shape-padding bucket regressed (quakecheck QK102; "
        f"see docs/static_analysis.md)")
