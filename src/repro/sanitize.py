"""Runtime sanitizer harness for the device-resident executor stack.

Three composable guards (see ``docs/static_analysis.md``):

* **Transfer guard** — ``jax.transfer_guard("disallow")`` turns any
  *implicit* device<->host transfer inside the guarded region into an
  error.  Explicit ``jax.device_put`` / ``jax.device_get`` / ``jnp.asarray``
  conversions still work, so the guarded region proves the hot path only
  moves data at its declared boundaries (the quakecheck ``allow-sync``
  points).
* **NaN debugging** — ``jax.debug_nans`` re-runs de-optimized on NaN
  production so silent NaN propagation in kernels fails loudly.
* **Compile-event counter** — counts real XLA compilations via
  ``jax.monitoring``'s ``backend_compile`` duration events.  This is the
  ground truth for jit-cache discipline: the shape-padding buckets
  (``u_bucket``/``b_bucket``/``part_bucket``) exist to keep this counter
  flat, and ``results/compile_budget.json`` pins per-entry-point budgets
  that CI enforces (:func:`assert_compile_budget`).

``sanitized()`` stacks them; tests opt in through the ``sanitized``
pytest fixture (``tests/conftest.py``).  ``cost_model.profile`` uses the
counter to warm deterministically: re-run until a call compiles nothing,
instead of hoping one warm call covered every shape.

The fourth guard is the **concurrency sanitizer** — the runtime twin of
quakecheck's QK2xx lock-discipline rules (``tools/quakecheck``):

* :class:`TrackedLock` wraps ``threading.RLock`` with a rank from the
  declared :data:`LOCK_ORDER` and a per-thread held stack; acquiring
  against the order is counted always and raises inside an active
  :class:`LockOrderWatchdog`.
* :func:`note_guarded` is an eraser-style guarded-field access checker:
  each ``(object, field)`` access intersects the candidate lock-set
  across threads; two threads touching a field with no common lock is a
  guarded-field violation.
* :class:`ConcurrencyEvents` mirrors :class:`CompileEvents` — a counter
  scope over acquisitions / contention / order violations / guarded
  violations, so a hammer test asserts "zero violations" as a delta.

``sanitized(locks=True)`` arms the watchdog alongside the other guards.
"""
from __future__ import annotations

import contextlib
import functools
import json
import threading
from pathlib import Path
from typing import Dict, Iterator, List, Optional, Tuple

import jax

__all__ = ["compile_count", "compile_events", "sanitized",
           "warm_until_stable", "load_compile_budget",
           "assert_compile_budget", "BUDGET_PATH",
           "LOCK_ORDER", "TrackedLock", "LockOrderWatchdog",
           "ConcurrencyEvents", "concurrency_counters", "note_guarded",
           "guarded_by", "observability_counters"]

BUDGET_PATH = Path(__file__).resolve().parents[2] / "results" \
    / "compile_budget.json"

_lock = threading.Lock()
_count = 0
_registered = False


def _listener(event: str, duration: float, **kwargs) -> None:
    # '/jax/core/compile/backend_compile_duration' fires once per actual
    # XLA compilation (cache hits don't emit it); match loosely so a
    # renamed prefix on a newer JAX still counts (the counter-sanity test
    # in tests/test_sanitize.py fails loudly if the event disappears).
    global _count
    if "backend_compile" in event:
        with _lock:
            _count += 1


def _ensure_listener() -> None:
    # jax.monitoring has no unregister API: register once, snapshot the
    # counter per context instead.
    global _registered
    with _lock:
        if _registered:
            return
        jax.monitoring.register_event_duration_secs_listener(_listener)
        _registered = True


def compile_count() -> int:
    """Monotonic count of XLA compilations observed so far."""
    _ensure_listener()
    with _lock:
        return _count


class CompileEvents:
    """Counter scope: ``new()`` is the number of compilations since the
    scope opened (or since the last ``reset()``)."""

    def __init__(self) -> None:
        self._start = compile_count()

    def new(self) -> int:
        return compile_count() - self._start

    def reset(self) -> None:
        self._start = compile_count()


@contextlib.contextmanager
def compile_events() -> Iterator[CompileEvents]:
    yield CompileEvents()


@contextlib.contextmanager
def sanitized(transfers: bool = True, nans: bool = True,
              compiles: bool = True,
              locks: bool = False) -> Iterator[Optional[CompileEvents]]:
    """Run the enclosed block under the stacked sanitizers.

    Yields the :class:`CompileEvents` scope when ``compiles`` is on
    (else None).  Device operands must be staged with explicit
    ``device_put``/``jnp.asarray`` *before* entering when ``transfers``
    is on — that is the point.  ``locks=True`` arms the
    :class:`LockOrderWatchdog` (lock-order violations raise, guarded
    field accesses are eraser-checked).
    """
    with contextlib.ExitStack() as stack:
        if transfers:
            stack.enter_context(jax.transfer_guard("disallow"))
        if nans:
            stack.enter_context(jax.debug_nans(True))
        if locks:
            stack.enter_context(LockOrderWatchdog())
        yield CompileEvents() if compiles else None


def warm_until_stable(fn, *, max_rounds: int = 8) -> int:
    """Call ``fn()`` until a call triggers zero new compilations (the
    deterministic warm-up ``cost_model.profile`` uses — a single warm
    call can miss shapes reached lazily).  Returns the number of warm
    calls made; raises if the compile count never settles."""
    ev = CompileEvents()
    for i in range(max_rounds):
        ev.reset()
        fn()
        if ev.new() == 0:
            return i + 1
    raise RuntimeError(
        f"compile count did not stabilize after {max_rounds} warm calls "
        f"— the timed path re-traces per call (jit cache fragmentation; "
        f"see quakecheck QK102)")


def load_compile_budget(path: Optional[Path] = None) -> Dict[str, int]:
    """The per-entry-point compile budgets (``results/compile_budget.json``
    ``{"budgets": {entry_point: max_compiles}}``)."""
    p = Path(path) if path is not None else BUDGET_PATH
    with open(p, "r", encoding="utf-8") as fh:
        data = json.load(fh)
    return {k: int(v) for k, v in data["budgets"].items()}


def assert_compile_budget(entry_point: str, observed: int,
                          path: Optional[Path] = None) -> None:
    """Fail (AssertionError) if ``observed`` compilations exceed the
    entry point's pinned budget.  Unknown entry points fail too: a new
    hot path must declare its budget before CI will gate it."""
    budgets = load_compile_budget(path)
    if entry_point not in budgets:
        raise AssertionError(
            f"no compile budget declared for {entry_point!r} in "
            f"{path or BUDGET_PATH} — add one (budgets: "
            f"{sorted(budgets)})")
    budget = budgets[entry_point]
    assert observed <= budget, (
        f"{entry_point}: {observed} compilations observed, budget is "
        f"{budget} — a shape-padding bucket regressed (quakecheck QK102; "
        f"see docs/static_analysis.md)")


# ---------------------------------------------------------------------------
# Concurrency sanitizer — runtime twin of quakecheck QK2xx
# ---------------------------------------------------------------------------

# Declared global lock partial order, outermost first.  This is the
# runtime twin of ``tools.quakecheck.config.LOCK_ORDER`` — a test in
# tests/test_sanitize.py asserts the two agree, so the linter and the
# watchdog can never drift apart.
LOCK_ORDER: Tuple[str, ...] = (
    "ServingRuntime._engine_lock",
    "ServingRuntime._lock",
    "RoundScheduler._lock",
    "ResultCache._lock",
    "MaintenanceScheduler._lock",
    # observability locks rank innermost: recording a metric, emitting a
    # trace event, or folding a calibration sample must be legal while
    # holding any runtime lock, and never the other way around
    # (docs/observability.md)
    "QueryTracer._lock",
    "CalibrationTracker._lock",
    "MetricsRegistry._lock",
)
_LOCK_RANK = {name: i for i, name in enumerate(LOCK_ORDER)}

_cc_lock = threading.Lock()
_cc_counters = {"acquisitions": 0, "contended": 0,
                "order_violations": 0, "guarded_violations": 0}
_cc_violations: List[str] = []       # human-readable, capped
_VIOLATION_CAP = 64
_watchdog_depth = 0                  # > 0: strict mode (raise) + eraser on
_tls = threading.local()


def _held_stack() -> List["TrackedLock"]:
    stack = getattr(_tls, "held", None)
    if stack is None:
        stack = _tls.held = []
    return stack


def _watchdog_active() -> bool:
    return _watchdog_depth > 0


def _record_violation(kind: str, message: str) -> None:
    with _cc_lock:
        _cc_counters[kind] += 1
        if len(_cc_violations) < _VIOLATION_CAP:
            _cc_violations.append(f"{kind}: {message}")
    if _watchdog_active():
        raise RuntimeError(f"concurrency sanitizer: {message}")


def concurrency_counters() -> Dict[str, int]:
    """Snapshot of the monotonic concurrency counters."""
    with _cc_lock:
        return dict(_cc_counters)


def concurrency_violations() -> List[str]:
    """The recorded violation messages (bounded buffer)."""
    with _cc_lock:
        return list(_cc_violations)


def observability_counters() -> Dict[str, int]:
    """Bridge for ``ServingRuntime.metrics_snapshot()``: the sanitizer's
    compile-event and concurrency counters as one flat dict, so XLA
    recompiles and lock-order violations surface under the same dotted
    namespace as the serving metrics (docs/observability.md)."""
    out: Dict[str, int] = dict(concurrency_counters())
    out["compile_count"] = compile_count()
    return out


class TrackedLock:
    """A reentrant lock that knows its name and its place.

    Drop-in for ``threading.RLock`` on the serving classes: context
    manager, ``acquire``/``release``, plus ``held()`` /
    ``assert_held()`` so guarded methods can verify their contract.
    Acquiring against :data:`LOCK_ORDER` while holding a later-ranked
    lock is always *counted*; under an active
    :class:`LockOrderWatchdog` it raises.
    """

    __slots__ = ("name", "_rank", "_inner", "_owner", "_depth")

    def __init__(self, name: str) -> None:
        self.name = name
        self._rank = _LOCK_RANK.get(name)
        self._inner = threading.RLock()
        self._owner: Optional[int] = None
        self._depth = 0

    def __repr__(self) -> str:
        return f"TrackedLock({self.name!r})"

    def held(self) -> bool:
        """True when the *calling thread* holds this lock."""
        return self._owner == threading.get_ident()

    def assert_held(self) -> None:
        if not self.held():
            raise AssertionError(
                f"{self.name} must be held here (see docs/serving.md "
                f"threading model)")

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        me = threading.get_ident()
        if self._owner == me:                       # reentrant fast path
            self._inner.acquire()
            self._depth += 1
            return True
        if self._rank is not None:
            for held in _held_stack():
                if held._rank is not None and held._rank > self._rank:
                    _record_violation(
                        "order_violations",
                        f"acquiring '{self.name}' while holding "
                        f"'{held.name}' inverts LOCK_ORDER "
                        f"({' -> '.join(LOCK_ORDER)})")
        got = self._inner.acquire(False)
        if not got:
            with _cc_lock:
                _cc_counters["contended"] += 1
            if not blocking:
                return False
            got = self._inner.acquire(True, timeout)
            if not got:
                return False
        self._owner = me
        self._depth = 1
        _held_stack().append(self)
        with _cc_lock:
            _cc_counters["acquisitions"] += 1
        if _watchdog_active():
            _WATCHDOG_TRACE.append((me, self.name))
        return True

    def release(self) -> None:
        if self._owner != threading.get_ident():
            raise RuntimeError(f"releasing {self.name} from a thread "
                               f"that does not hold it")
        self._depth -= 1
        if self._depth == 0:
            self._owner = None
            stack = _held_stack()
            if self in stack:
                stack.remove(self)
        self._inner.release()

    def __enter__(self) -> "TrackedLock":
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()


# Bounded per-process acquisition trace (thread id, lock name), recorded
# only while a watchdog is active; LockOrderWatchdog.trace() filters it.
class _Trace:
    def __init__(self, cap: int = 4096) -> None:
        self._items: List[Tuple[int, str]] = []
        self._cap = cap
        self._lk = threading.Lock()

    def append(self, item: Tuple[int, str]) -> None:
        with self._lk:
            if len(self._items) < self._cap:
                self._items.append(item)

    def snapshot(self) -> List[Tuple[int, str]]:
        with self._lk:
            return list(self._items)

    def cut(self) -> int:
        with self._lk:
            return len(self._items)


_WATCHDOG_TRACE = _Trace()


# -- eraser-style guarded-field checker -------------------------------------

# (id(owner), field) -> [candidate lock-name set or None, thread-id set].
# Lockset algorithm (Savage et al.): the candidate set starts as the
# first access's held locks and is intersected on every later access; an
# empty candidate once a *second* thread has touched the field means no
# common lock protects it.
_eraser_lock = threading.Lock()
_eraser_state: Dict[Tuple[int, str], List] = {}


def note_guarded(owner: object, field: str) -> None:
    """Record an access to ``owner.<field>`` under the current thread's
    lock-set.  No-op unless a :class:`LockOrderWatchdog` is active, so
    production paths can call it unconditionally."""
    if not _watchdog_active():
        return
    held = frozenset(lk.name for lk in _held_stack())
    me = threading.get_ident()
    key = (id(owner), field)
    with _eraser_lock:
        st = _eraser_state.get(key)
        if st is None:
            _eraser_state[key] = [set(held), {me}]
            return
        st[0] &= held
        st[1].add(me)
        violation = len(st[1]) >= 2 and not st[0]
        if violation:                    # reset so we report once
            st[0] = set(held)
            st[1] = {me}
    if violation:
        _record_violation(
            "guarded_violations",
            f"field '{type(owner).__name__}.{field}' accessed by "
            f"multiple threads with no common lock (eraser lockset "
            f"empty)")


def guarded_by(lock_name: str):
    """Runtime twin of the static ``@guarded_by`` annotation: marks the
    method (quakecheck seeds its lock-set from the same decorator) and,
    under an active watchdog, asserts the named lock is actually held on
    entry.  ``lock_name`` is an attribute of ``self`` (``"_lock"``)."""
    attr = lock_name.rsplit(".", 1)[-1]

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(self, *args, **kwargs):
            if _watchdog_active():
                lk = getattr(self, attr, None)
                if isinstance(lk, TrackedLock) and not lk.held():
                    _record_violation(
                        "guarded_violations",
                        f"{type(self).__name__}.{fn.__name__} declared "
                        f"@guarded_by({lock_name!r}) but the lock is "
                        f"not held")
            return fn(self, *args, **kwargs)
        wrapper.__quakecheck_guarded_by__ = lock_name
        return wrapper
    return deco


class ConcurrencyEvents:
    """Counter scope over the concurrency sanitizer, mirroring
    :class:`CompileEvents`: each property is the delta since the scope
    opened (or the last ``reset()``)."""

    def __init__(self) -> None:
        self._start = concurrency_counters()

    def _delta(self, key: str) -> int:
        return concurrency_counters()[key] - self._start[key]

    @property
    def acquisitions(self) -> int:
        return self._delta("acquisitions")

    @property
    def contended(self) -> int:
        return self._delta("contended")

    @property
    def order_violations(self) -> int:
        return self._delta("order_violations")

    @property
    def guarded_violations(self) -> int:
        return self._delta("guarded_violations")

    def violations(self) -> int:
        return self.order_violations + self.guarded_violations

    def reset(self) -> None:
        self._start = concurrency_counters()


class LockOrderWatchdog:
    """Context manager arming the concurrency sanitizer.

    While active: lock-order violations *raise* (instead of only
    counting), :func:`note_guarded` records eraser locksets, and every
    :class:`TrackedLock` acquisition is appended to a bounded trace —
    ``trace()`` returns this scope's (thread id, lock name) sequence,
    i.e. the per-thread acquisition stacks flattened in real order.
    """

    def __init__(self) -> None:
        self.events: Optional[ConcurrencyEvents] = None
        self._cut = 0

    def __enter__(self) -> "LockOrderWatchdog":
        global _watchdog_depth
        with _cc_lock:
            _watchdog_depth += 1
        self._cut = _WATCHDOG_TRACE.cut()
        self.events = ConcurrencyEvents()
        return self

    def __exit__(self, *exc) -> None:
        global _watchdog_depth
        with _cc_lock:
            _watchdog_depth -= 1
            if _watchdog_depth == 0:
                _eraser_state.clear()
        return None

    def trace(self) -> List[Tuple[int, str]]:
        return _WATCHDOG_TRACE.snapshot()[self._cut:]

    def stacks(self) -> Dict[int, List[str]]:
        out: Dict[int, List[str]] = {}
        for tid, name in self.trace():
            out.setdefault(tid, []).append(name)
        return out
