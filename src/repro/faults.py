"""Deterministic fault injection for the serving stack.

The serving runtime promises that every admitted query reaches exactly
one terminal status and that the index survives component failures
(docs/serving.md, "Failure semantics").  Promises like that are only as
good as the harness that exercises them, so this module provides the
seeded injector that tests and ``bench_serving --chaos`` wire into
:class:`repro.core.serving.ServingRuntime`.

Sites (``FaultInjector.SITES``), one per failure the runtime must
survive:

  ``scan``          the round scan backend raises (device OOM, kernel
                    bug, host BLAS failure) — the scheduler retries with
                    capped exponential backoff, then fails the affected
                    in-flight batch with ``FAILED`` results.
  ``slow_round``    a round stalls for ``delay_s`` (straggler device /
                    noisy neighbor) — queries with latency budgets
                    retire ``PARTIAL`` instead of waiting the stall out.
  ``maintenance``   the maintainer crashes mid-recluster, after split /
                    merge commits have already mutated the index — the
                    runtime rolls back to the pre-pass checkpoint
                    (index version unchanged) and the next drift trigger
                    retries.
  ``cache``         the result-cache backend raises — the runtime
                    degrades to cache-off mode instead of erroring the
                    query that happened to probe it.
  ``ticker``        the background deadline ticker's tick raises — the
                    ticker survives (counted), and a dead ticker thread
                    is restarted on the next admission.

Durability I/O sites (``core/durability.py``, docs/durability.md) —
these model *crashes*, not transient errors; after one fires the WAL
tail is damaged and the process under test is considered dead until it
recovers:

  ``wal_torn_write``       a crash mid-append: a strict prefix of the
                           framed record reaches the file, then
                           :class:`InjectedFault` — recovery must
                           truncate back to the last valid frame.
  ``wal_corrupt_record``   a bit flip in the written frame (bad sector)
                           — the CRC rejects it and recovery lands on
                           the prefix before it.
  ``ckpt_crash_before_rename``  the checkpoint temp directory is fully
                           written and fsynced but the process dies
                           before the atomic rename — recovery must
                           fall back to the previous generation.
  ``fsync_dropped``        fsync silently does nothing (lying disk /
                           dropped barrier); no exception — the damage
                           only shows at the next simulated crash,
                           which loses the unsynced tail.

Determinism: each site draws from its own ``numpy`` generator seeded by
``(seed, site)``, so whether the N-th *arrival at a site* fires is
reproducible regardless of how threads interleave across sites.  A
``threading.Lock`` keeps each per-site stream internally ordered.

``sleep_fn`` lets fake-clock tests advance virtual time instead of
actually sleeping (both for ``slow_round`` stalls and for the
scheduler's retry backoff).
"""
from __future__ import annotations

import threading
import time
import zlib
from typing import Callable, Dict, Optional

import numpy as np

__all__ = ["FaultInjector", "InjectedFault"]


class InjectedFault(RuntimeError):
    """Raised by :meth:`FaultInjector.check` when a site fires."""

    def __init__(self, site: str, n: int):
        super().__init__(f"injected fault at site {site!r} (trip #{n})")
        self.site = site
        self.n = n


class FaultInjector:
    """Seeded, site-registered fault source.

    ``rates`` maps site name -> probability per arrival in [0, 1]
    (sites absent from the map never fire; rate 1.0 fires on every
    arrival — how the chaos tests make maintenance crash
    deterministically).  ``delay_s`` is the stall injected when
    ``slow_round`` fires.
    """

    SITES = ("scan", "slow_round", "maintenance", "cache", "ticker",
             "wal_torn_write", "wal_corrupt_record",
             "ckpt_crash_before_rename", "fsync_dropped")

    def __init__(self, seed: int = 0, rates: Optional[Dict[str, float]] = None,
                 delay_s: float = 0.0,
                 sleep_fn: Callable[[float], None] = time.sleep):
        rates = dict(rates or {})
        unknown = set(rates) - set(self.SITES)
        if unknown:
            raise ValueError(f"unknown fault sites: {sorted(unknown)} "
                             f"(known: {list(self.SITES)})")
        self.seed = seed
        self.rates = rates
        self.delay_s = float(delay_s)
        self.sleep_fn = sleep_fn
        self._mu = threading.Lock()
        # per-site generators: the draw sequence at one site is a pure
        # function of (seed, site, arrival ordinal), independent of what
        # other sites saw in between
        self._rng = {s: np.random.default_rng(
            [seed, zlib.crc32(s.encode())]) for s in self.SITES}
        self.draws = {s: 0 for s in self.SITES}
        self.trips = {s: 0 for s in self.SITES}

    def fire(self, site: str) -> bool:
        """One arrival at ``site``; True when the fault fires."""
        rate = self.rates.get(site, 0.0)
        with self._mu:
            self.draws[site] += 1
            if rate <= 0.0:
                return False
            hit = (rate >= 1.0
                   or float(self._rng[site].random()) < rate)
            if hit:
                self.trips[site] += 1
            return hit

    def check(self, site: str) -> None:
        """Raise :class:`InjectedFault` when ``site`` fires."""
        if self.fire(site):
            with self._mu:
                n = self.trips[site]
            raise InjectedFault(site, n)

    def stall(self, site: str = "slow_round") -> float:
        """Sleep ``delay_s`` (via ``sleep_fn``) when ``site`` fires;
        returns the injected delay (0.0 when it did not fire)."""
        if self.fire(site) and self.delay_s > 0.0:
            self.sleep_fn(self.delay_s)
            return self.delay_s
        return 0.0

    def counters(self) -> dict:
        """Snapshot of per-site arrival and trip counts."""
        with self._mu:
            return {"draws": dict(self.draws), "trips": dict(self.trips)}


def index_state_fingerprint(index) -> bytes:
    """Deterministic digest of an index's logical state.  Two indexes
    that served the same surviving operation stream — e.g. a chaos run
    whose maintenance crashes all rolled back vs a fault-free replay,
    or a crash-recovered index vs a replay of its recovered write
    prefix — must produce identical digests (the recovery acceptance
    checks in tests/test_serving_chaos.py, tests/test_durability.py,
    and ``bench_serving --cell chaos,durability``).

    Canonical-ordering contract (what makes the digest stable):

    * Levels are hashed top-down in list order; per level, the centroid
      matrix is hashed **verbatim** (contiguous float64) — partition
      *numbering* is physical state, not presentation, because it feeds
      ``kmeans.assign`` tie-breaks when routing future inserts.
    * Upper levels hash each child array **sorted**: child-set
      membership is logical state, but the in-array order is not hashed
      here (it is preserved exactly by checkpoints for replay
      determinism; see durability.write_checkpoint).
    * Base-level partitions hash ``(ids sorted ascending, vectors
      re-ordered to match)`` — so the *arrival order* of rows inside a
      partition is canonicalized away.  Insert/delete sequences that
      commute (touch disjoint ids and route to the same partitions)
      therefore fingerprint identically regardless of interleaving.
    * Everything else — sqnorms, journal, partition stats, maintenance
      log, caches — is derived or session state and is deliberately
      excluded; save/load round-trips must preserve the digest
      (tests/test_durability.py::test_fingerprint_*).

    Vectors and centroids are hashed as float64 *widenings* of their
    stored float32 values, which is exact, so a digest match means
    bit-identical stored state."""
    import hashlib
    h = hashlib.sha256()
    for level in index.levels:
        h.update(np.ascontiguousarray(
            level.centroids, dtype=np.float64).tobytes())
        if level.vectors is None:
            for child in level.children:
                h.update(np.sort(np.asarray(child)).tobytes())
            continue
        for j in range(level.num_partitions):
            ids = np.asarray(level.ids[j])
            order = np.argsort(ids, kind="stable")
            h.update(ids[order].tobytes())
            h.update(np.ascontiguousarray(
                level.vectors[j][order], dtype=np.float64).tobytes())
    return h.digest()
