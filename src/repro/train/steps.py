"""Train/serve step builders: pjit sharding, microbatch accumulation, and
the explicit-DP compressed-gradient variant.

``make_train_step`` is the production path: GSPMD shards params/optimizer
state per the model's spec tree; gradient reduction happens inside the
compiled program (overlapped with the backward pass by XLA's latency-hiding
scheduler — compute/comm overlap comes from the compiler, the framework's
job is to keep the collectives off the critical path, see §Perf).

``make_compressed_dp_step`` demonstrates int8 error-feedback gradient
compression over an explicit shard_map data-parallel axis (8x less gradient
traffic; used when ICI/DCN bandwidth — e.g. cross-pod — is the bottleneck).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..compat import shard_map
from . import optimizer as opt

Array = jax.Array
Pytree = Any


def make_train_step(loss_fn: Callable[[Pytree, Any], Array],
                    opt_cfg: opt.AdamWConfig,
                    microbatches: int = 1,
                    cast_dtype: Optional[Any] = None):
    """Returns step(params, opt_state, batch) -> (params, opt_state, metrics).

    ``microbatches`` > 1 splits the (already device-sharded) batch on axis 0
    and accumulates grads in fp32 via lax.scan — activation memory divides
    by the microbatch count while keeping the same global batch.

    ``cast_dtype`` (e.g. bf16) casts the floating param tree ONCE per step
    before the loss.  Without it, ``w.astype(x.dtype)`` inside the layer
    makes GSPMD all-gather the f32 master weights and convert *after* —
    2x the FSDP wire bytes and 2x the gathered-weight HBM traffic (§Perf
    hillclimb 2, iteration 1).  Grads flow back through the cast, arriving
    f32 for the optimizer; the dp reduction itself runs in cast_dtype.
    """
    if cast_dtype is not None:
        inner_loss = loss_fn

        def loss_fn(p, b):  # noqa: F811 — deliberate wrap
            pc = jax.tree.map(
                lambda x: x.astype(cast_dtype)
                if jnp.issubdtype(x.dtype, jnp.floating) else x, p)
            return inner_loss(pc, b)

    def step(params, opt_state, batch):
        if microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                mb = b // microbatches
                return x[:mb * microbatches].reshape(
                    microbatches, mb, *x.shape[1:])
            mbatch = jax.tree.map(split, batch)
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def acc(carry, mb):
                tot_l, tot_g = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                tot_g = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), tot_g, g)
                return (tot_l + l, tot_g), None

            (loss, grads), _ = jax.lax.scan(
                acc, (jnp.zeros(()), zeros), mbatch)
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)
        params, opt_state, info = opt.apply_update(
            params, grads, opt_state, opt_cfg)
        return params, opt_state, {"loss": loss, **info}

    return step


def jit_train_step(step_fn, mesh: Mesh, param_spec: Pytree,
                   batch_spec: Pytree, donate: bool = True):
    """Compile with explicit in/out shardings (params+opt state sharded per
    spec, batch per batch_spec, metrics replicated)."""
    def to_sharding(spec_tree):
        return jax.tree.map(
            lambda s: NamedSharding(mesh, s), spec_tree,
            is_leaf=lambda x: isinstance(x, P))
    ps = to_sharding(param_spec)
    os_ = opt.AdamWState(step=NamedSharding(mesh, P()),
                         m=ps, v=ps)
    bs = to_sharding(batch_spec)
    rep = NamedSharding(mesh, P())
    return jax.jit(step_fn,
                   in_shardings=(ps, os_, bs),
                   out_shardings=(ps, os_, rep),
                   donate_argnums=(0, 1) if donate else ())


def make_compressed_dp_step(loss_fn, opt_cfg: opt.AdamWConfig, mesh: Mesh,
                            dp_axes=("pod", "data")):
    """Explicit-DP step: params replicated, batch sharded over dp_axes,
    gradients all-reduced with int8 error-feedback compression."""

    def local_step(params, opt_state, residual, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        grads, residual = opt.compressed_psum(grads, residual, dp_axes)
        loss = jax.lax.pmean(loss, dp_axes)
        params, opt_state, info = opt.apply_update(
            params, grads, opt_state, opt_cfg)
        return params, opt_state, residual, {"loss": loss, **info}

    rep = P()
    shard0 = P(dp_axes)  # spec prefix: batch pytree sharded on axis 0
    mapped = shard_map(
        local_step, mesh=mesh,
        in_specs=(rep, rep, rep, shard0),
        out_specs=(rep, rep, rep, rep), check_vma=False)
    return jax.jit(mapped)


# ---------------------------------------------------------------------------
# Serve steps
# ---------------------------------------------------------------------------

def make_serve_step(apply_fn: Callable[..., Any]):
    """Wrap a pure forward for serving; jitted by the caller with the
    appropriate shardings (see launch/dryrun.py)."""
    @functools.wraps(apply_fn)
    def serve(params, *inputs):
        return apply_fn(params, *inputs)
    return serve
