"""Training substrate: pure-JAX AdamW (+schedules, clipping, int8
error-feedback gradient compression), step builders with explicit shardings
and microbatch accumulation, atomic/async checkpointing with elastic
restore, and the fault-tolerant training supervisor."""
from . import checkpoint, loop, optimizer, steps  # noqa: F401
from .checkpoint import CheckpointManager  # noqa: F401
from .loop import LoopConfig, train_loop  # noqa: F401
from .optimizer import AdamWConfig, init_state  # noqa: F401
