"""Fault-tolerant training supervisor.

The step function is pure and the data pipeline is step-indexed
(data/pipelines.py), so recovery is: restore latest checkpoint -> resume at
``manifest.step`` -> the pipeline regenerates exactly the batches that
followed.  Failures are surfaced as exceptions from the step (injectable for
tests via ``failure_injector``); the supervisor restores and retries with
bounded attempts.

Straggler mitigation hook: per-step wall time feeds an EWMA; steps slower
than ``straggler_factor`` x EWMA are counted and reported (on a real
multi-host deployment this signal drives re-sharding / hot-spare swap — here
it is monitoring plus the basis for the elastic-rescale path in
checkpoint.py).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from .checkpoint import CheckpointManager

Pytree = Any


@dataclass
class LoopConfig:
    n_steps: int = 100
    ckpt_every: int = 25
    max_restarts: int = 3
    straggler_factor: float = 3.0
    log_every: int = 10


@dataclass
class LoopReport:
    losses: List[float] = field(default_factory=list)
    step_times: List[float] = field(default_factory=list)
    restarts: int = 0
    stragglers: int = 0
    resumed_from: Optional[int] = None


def train_loop(init_state: Pytree, step_fn: Callable,
               batch_at: Callable[[int], Any], ckpt: CheckpointManager,
               cfg: LoopConfig,
               failure_injector: Optional[Callable[[int], None]] = None,
               log: Callable[[str], None] = lambda s: None) -> LoopReport:
    """``step_fn(state, batch) -> (state, metrics)``; ``state`` is any
    pytree (e.g. (params, opt_state)).  Returns the report; final state is
    checkpointed."""
    report = LoopReport()
    state = init_state
    start = 0
    if ckpt.latest_step() is not None:
        state, manifest = ckpt.restore(init_state)
        start = manifest["step"]
        report.resumed_from = start
        log(f"resumed from step {start}")

    ewma = None
    step = start
    attempts = 0
    while step < cfg.n_steps:
        try:
            if failure_injector is not None:
                failure_injector(step)
            t0 = time.perf_counter()
            state, metrics = step_fn(state, batch_at(step))
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            report.losses.append(loss)
            report.step_times.append(dt)
            ewma = dt if ewma is None else 0.9 * ewma + 0.1 * dt
            if dt > cfg.straggler_factor * ewma and len(
                    report.step_times) > 3:
                report.stragglers += 1
                log(f"straggler step {step}: {dt:.3f}s vs ewma {ewma:.3f}s")
            step += 1
            attempts = 0
            if step % cfg.ckpt_every == 0 or step == cfg.n_steps:
                ckpt.save(step, state)
            if step % cfg.log_every == 0:
                log(f"step {step}: loss={loss:.4f} ({dt*1e3:.0f}ms)")
        except Exception as e:  # noqa: BLE001 — supervisor boundary
            attempts += 1
            report.restarts += 1
            log(f"step {step} failed ({e!r}); restart {attempts}")
            if attempts > cfg.max_restarts:
                raise
            if ckpt.latest_step() is not None:
                state, manifest = ckpt.restore(init_state)
                step = manifest["step"]
            else:
                state = init_state
                step = 0
    ckpt.wait()
    return report
