"""Checkpointing with atomic commits, async writes, and elastic restore.

Format: one ``.npz`` of flattened leaves (keys = pytree paths) + a JSON
manifest (step, config hash, mesh shape, data cursor, wall time).  Arrays
are saved in *logical* (unsharded) shape, so ``restore`` can re-place them
onto **any** mesh / sharding — this is what makes elastic rescale (512 -> 256
chips after losing a pod, or scale-up) a restore-time operation rather than
a migration tool.  Commit protocol: write to ``<name>.tmp/`` then
``os.replace`` — a crash mid-write never corrupts the latest checkpoint.

Deployment note: in a real multi-host pod each host writes only its
addressable shards (per-host files keyed by shard index) — the single-file
path here is the single-process container specialization; the manifest
format already carries the mesh metadata needed for the sharded layout.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time
from dataclasses import dataclass
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any
_SEP = "/"


def _flatten(tree: Pytree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten(tree_like: Pytree, flat: Dict[str, np.ndarray]) -> Pytree:
    paths, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    leaves = []
    for path, like in paths:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(like.shape):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs "
                f"model {like.shape}")
        leaves.append(arr)
    return treedef.unflatten(leaves)


def config_hash(obj: Any) -> str:
    return hashlib.sha1(repr(obj).encode()).hexdigest()[:12]


@dataclass
class CheckpointManager:
    directory: str
    keep: int = 3
    async_write: bool = True

    def __post_init__(self):
        os.makedirs(self.directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ---------------- save ----------------

    def save(self, step: int, state: Pytree,
             extra: Optional[Dict] = None, block: bool = False) -> str:
        """Snapshot-then-write: leaves are device_get'ed synchronously (the
        cheap part), serialization happens on a background thread."""
        self.wait()
        flat = _flatten(state)
        manifest = {"step": int(step), "time": time.time(),
                    "leaves": len(flat), **(extra or {})}
        name = f"ckpt_{step:08d}"

        def write():
            tmp = os.path.join(self.directory, name + ".tmp")
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "arrays.npz"), **flat)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            final = os.path.join(self.directory, name)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(tmp, final)
            self._gc()

        if self.async_write and not block:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()
        return os.path.join(self.directory, name)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        ckpts = self.list()
        for old in ckpts[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, old),
                          ignore_errors=True)

    # ---------------- restore ----------------

    def list(self):
        return sorted(d for d in os.listdir(self.directory)
                      if d.startswith("ckpt_") and not d.endswith(".tmp"))

    def latest_step(self) -> Optional[int]:
        ckpts = self.list()
        return int(ckpts[-1].split("_")[1]) if ckpts else None

    def restore(self, state_like: Pytree, step: Optional[int] = None,
                shardings: Optional[Pytree] = None
                ) -> tuple[Pytree, Dict]:
        """Load into the structure of ``state_like``.  ``shardings`` (a
        pytree of NamedSharding, possibly for a *different* mesh than the
        one that saved) re-places every leaf — elastic restore."""
        self.wait()
        ckpts = self.list()
        if not ckpts:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        name = f"ckpt_{step:08d}" if step is not None else ckpts[-1]
        path = os.path.join(self.directory, name)
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        with np.load(os.path.join(path, "arrays.npz")) as z:
            flat = {k: z[k] for k in z.files}
        state = _unflatten(state_like, flat)
        if shardings is not None:
            state = jax.tree.map(
                lambda x, s: jax.device_put(jnp.asarray(x), s),
                state, shardings)
        else:
            state = jax.tree.map(jnp.asarray, state)
        return state, manifest
