"""Pure-JAX optimizer substrate (no optax dependency).

AdamW with decoupled weight decay, global-norm clipping, warmup+cosine
schedule, and optional **error-feedback int8 gradient compression** for the
data-parallel all-reduce (the distributed-optimization trick; used with the
explicit shard_map DP step in ``steps.py``).  Optimizer state is a pytree
sharded exactly like the params (ZeRO-3 via GSPMD).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array
Pytree = Any


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class AdamWState(NamedTuple):
    step: Array
    m: Pytree
    v: Pytree


def schedule(cfg: AdamWConfig, step: Array) -> Array:
    """Linear warmup + cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) \
        * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_state(params: Pytree) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32),
                         params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.copy, zeros))


def global_norm(tree: Pytree) -> Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads: Pytree, max_norm: float
                        ) -> Tuple[Pytree, Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * scale, grads), norm


def apply_update(params: Pytree, grads: Pytree, state: AdamWState,
                 cfg: AdamWConfig) -> Tuple[Pytree, AdamWState, Dict]:
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state.step + 1
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m2 = cfg.b1 * m + (1 - cfg.b1) * g
        v2 = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m2 / b1c
        vh = v2 / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay \
            * p.astype(jnp.float32)
        return (p - lr * delta.astype(p.dtype)).astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree.flatten(params)
    flat = [upd(p, g, m, v) for p, g, m, v in zip(
        flat_p, treedef.flatten_up_to(grads),
        treedef.flatten_up_to(state.m), treedef.flatten_up_to(state.v))]
    new_p = treedef.unflatten([t[0] for t in flat])
    new_m = treedef.unflatten([t[1] for t in flat])
    new_v = treedef.unflatten([t[2] for t in flat])
    return new_p, AdamWState(step, new_m, new_v), \
        {"grad_norm": gnorm, "lr": lr}


# ---------------------------------------------------------------------------
# Gradient compression (error feedback) — for the explicit-DP shard_map step
# ---------------------------------------------------------------------------

def compress_int8(g: Array) -> Tuple[Array, Array]:
    """Per-tensor symmetric int8 quantization; returns (q, scale)."""
    amax = jnp.max(jnp.abs(g))
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: Array, scale: Array) -> Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(grads: Pytree, residual: Pytree, axis
                    ) -> Tuple[Pytree, Pytree]:
    """Error-feedback compressed all-reduce: quantize (grad + residual) to
    int8, psum the int8 payload (8x less ICI traffic), keep the
    quantization error as the next step's residual."""
    def one(g, r):
        g32 = g.astype(jnp.float32) + r
        q, scale = compress_int8(g32)
        deq_local = decompress_int8(q, scale)
        new_r = g32 - deq_local
        summed = jax.lax.psum(q.astype(jnp.int32), axis)
        scale_max = jax.lax.pmax(scale, axis)
        n = jax.lax.psum(1, axis)
        return (summed.astype(jnp.float32) * scale_max / n), new_r

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(residual)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (jax.tree.unflatten(treedef, [o[0] for o in out]),
            jax.tree.unflatten(treedef, [o[1] for o in out]))


def init_residual(params: Pytree) -> Pytree:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
