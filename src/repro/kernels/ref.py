"""Pure-jnp oracles for the Pallas kernels.

These are the ground-truth implementations used by tests (``assert_allclose`` /
recall@k against the kernels) and as the default CPU execution path (the Pallas
kernels run in ``interpret=True`` mode on CPU, which is far too slow for
benchmarks; the jnp path is what XLA:CPU executes).

The paper's hot loop is the *partition scan*: distances from a query batch to a
block of database vectors plus top-k selection (Quake §6, SimSIMD/AVX512 on x86
→ MXU matmul on TPU).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array

# Large-but-finite sentinel: keeps masked lanes inert without generating NaNs
# in downstream arithmetic (inf - inf).  Plain float so Pallas kernels can use
# it without capturing a traced constant.
MASK_DIST = 3.0e38


def pairwise_l2_sq(queries: Array, xs: Array) -> Array:
    """Squared L2 distances, (Q, d) x (N, d) -> (Q, N), via the matmul identity.

    ||q - x||^2 = ||q||^2 + ||x||^2 - 2 q.x  — one GEMM + rank-1 updates, the
    MXU-friendly form the Pallas kernel mirrors.
    """
    q2 = jnp.sum(queries * queries, axis=-1, keepdims=True)  # (Q, 1)
    x2 = jnp.sum(xs * xs, axis=-1)  # (N,)
    qx = queries @ xs.T  # (Q, N)
    d = q2 + x2[None, :] - 2.0 * qx
    return jnp.maximum(d, 0.0)


def pairwise_ip(queries: Array, xs: Array) -> Array:
    """Inner-product scores, (Q, d) x (N, d) -> (Q, N)."""
    return queries @ xs.T


def scan_distances(queries: Array, xs: Array, metric: str = "l2",
                   valid: Optional[Array] = None) -> Array:
    """Distance matrix in *minimization* convention.

    For ``metric="ip"`` we return negated scores so that smaller is always
    better; callers that need raw scores negate back.  ``valid`` is an (N,)
    bool mask; invalid rows get MASK_DIST.
    """
    if metric == "l2":
        d = pairwise_l2_sq(queries, xs)
    elif metric == "ip":
        d = -pairwise_ip(queries, xs)
    else:
        raise ValueError(f"unknown metric: {metric}")
    if valid is not None:
        d = jnp.where(valid[None, :], d, MASK_DIST)
    return d


@functools.partial(jax.jit, static_argnames=("k", "metric"))
def scan_topk_ref(queries: Array, xs: Array, k: int, metric: str = "l2",
                  valid: Optional[Array] = None) -> Tuple[Array, Array]:
    """Oracle fused scan: top-k (distances, indices) per query.

    Returns distances in minimization convention (negated scores for ip) and
    int32 indices into ``xs``.  Padded/invalid entries surface as MASK_DIST
    with index -1.
    """
    d = scan_distances(queries, xs, metric, valid)
    neg = -d
    vals, idx = jax.lax.top_k(neg, k)  # top_k maximizes
    dists = -vals
    idx = jnp.where(dists >= MASK_DIST, -1, idx)
    return dists, idx.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=())
def kmeans_assign_ref(xs: Array, centroids: Array,
                      valid: Optional[Array] = None) -> Tuple[Array, Array]:
    """Oracle fused assign: nearest centroid (argmin L2) per point.

    Returns (assignments int32 (N,), min squared distances (N,)).  Invalid
    points (mask False) get assignment -1.
    """
    d = pairwise_l2_sq(xs, centroids)  # (N, C)
    assign = jnp.argmin(d, axis=-1).astype(jnp.int32)
    mind = jnp.min(d, axis=-1)
    if valid is not None:
        assign = jnp.where(valid, assign, -1)
        mind = jnp.where(valid, mind, MASK_DIST)
    return assign, mind


@functools.partial(jax.jit, static_argnames=("k", "metric"))
def scan_selected_ref(queries: Array, data: Array, aux_valid: Array,
                      sel: Array, qmask: Array, k: int, metric: str = "l2",
                      ) -> Tuple[Array, Array]:
    """Oracle for the indexed scan: top-k over a union of selected blocks.

    queries (B, d); data (P, S, d); aux_valid (P, S) bool (True = real row);
    sel (U,) int32 partition ids; qmask (B, U) bool (True = query b wants
    block u).  Returns (dists (B, k) ascending, flat idx = partition*S+slot),
    minimization convention, misses = MASK_DIST / -1.
    """
    blocks = jnp.take(data, sel, axis=0).astype(jnp.float32)  # (U, S, d)
    valid = jnp.take(aux_valid, sel, axis=0)        # (U, S)
    queries = queries.astype(jnp.float32)
    if metric == "l2":
        x2 = jnp.sum(blocks * blocks, axis=-1)      # (U, S)
        qx = jnp.einsum("usd,bd->bus", blocks, queries)
        q2 = jnp.sum(queries * queries, axis=-1)[:, None, None]
        dist = jnp.maximum(x2[None] - 2.0 * qx + q2, 0.0)
    else:
        dist = -jnp.einsum("usd,bd->bus", blocks, queries)
    dist = jnp.where(valid[None], dist, MASK_DIST)
    dist = jnp.where(qmask[:, :, None], dist, MASK_DIST)
    S = data.shape[1]
    flat_idx = (sel[:, None] * S
                + jnp.arange(S, dtype=jnp.int32)[None, :])  # (U, S)
    b = queries.shape[0]
    dist = dist.reshape(b, -1)
    idx = jnp.broadcast_to(flat_idx.reshape(1, -1), dist.shape)
    k_eff = min(k, dist.shape[1])
    vals, pos = jax.lax.top_k(-dist, k_eff)
    d_out, i_out = -vals, jnp.take_along_axis(idx, pos, axis=1)
    i_out = jnp.where(d_out >= MASK_DIST, -1, i_out)
    return d_out, i_out.astype(jnp.int32)


def merge_topk(dists_a: Array, idx_a: Array, dists_b: Array, idx_b: Array,
               k: int) -> Tuple[Array, Array]:
    """Merge two sorted-or-not top-k candidate sets per query row -> top-k."""
    d = jnp.concatenate([dists_a, dists_b], axis=-1)
    i = jnp.concatenate([idx_a, idx_b], axis=-1)
    vals, sel = jax.lax.top_k(-d, k)
    return -vals, jnp.take_along_axis(i, sel, axis=-1)
