"""Pallas TPU kernel: fused distance + argmin (k-means assignment step).

Quake's maintenance path (split / refinement / insert routing, §4.2) is
dominated by nearest-centroid assignment.  The naive jnp form materializes the
(N, C) distance matrix in HBM; this kernel keeps only a running
(min-dist, argmin) pair per point in VMEM while streaming centroid blocks —
one HBM pass over points and centroids.

Grid = (point_tiles, centroid_blocks), dimension_semantics
(PARALLEL, ARBITRARY); scratch carries the running minimum across the
sequential centroid dimension.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import pallas_compat
from .ref import MASK_DIST

Array = jax.Array


def _kmeans_assign_kernel(x_ref, c_ref, aux_ref, out_a_ref, out_d_ref,
                          run_d, run_a, *, nblocks: int, block_c: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        run_d[...] = jnp.full_like(run_d, MASK_DIST)
        run_a[...] = jnp.full_like(run_a, -1)

    x = x_ref[...]        # (TN, d)
    c = c_ref[...]        # (TC, d)
    aux = aux_ref[...]    # (1, TC): ||c||^2 + pad bias
    xc = jax.lax.dot_general(
        x, c, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    dist = aux.astype(jnp.float32) - 2.0 * xc          # (TN, TC)

    base = j * block_c
    cidx = base + jax.lax.broadcasted_iota(jnp.int32, dist.shape, 1)

    blk_min = jnp.min(dist, axis=1, keepdims=True)      # (TN, 1)
    # argmin without gathers: smallest index attaining the min.
    is_min = dist <= blk_min
    blk_arg = jnp.min(jnp.where(is_min, cidx, jnp.int32(2**30)), axis=1,
                      keepdims=True)

    better = blk_min < run_d[...]
    run_d[...] = jnp.where(better, blk_min, run_d[...])
    run_a[...] = jnp.where(better, blk_arg, run_a[...])

    @pl.when(j == nblocks - 1)
    def _write():
        out_d_ref[...] = run_d[...]
        out_a_ref[...] = run_a[...]


@functools.partial(
    jax.jit, static_argnames=("block_n", "block_c", "interpret"))
def kmeans_assign_pallas(xs: Array, centroids: Array, aux: Array, *,
                         block_n: int = 512, block_c: int = 128,
                         interpret: bool = True) -> Tuple[Array, Array]:
    """Fused assignment.  Pre-padded shapes:

    xs:        (N, d), N % block_n == 0
    centroids: (C, d), C % block_c == 0
    aux:       (1, C) = ||c||^2 (+ MASK_DIST bias on padded centroid rows)

    Returns (assign int32 (N, 1), min_dist (N, 1)); min_dist omits the
    per-point ||x||^2 term (caller adds it back if actual distances needed).
    """
    N, d = xs.shape
    C, _ = centroids.shape
    assert N % block_n == 0 and C % block_c == 0, (N, C)
    nn, nb = N // block_n, C // block_c

    kernel = functools.partial(_kmeans_assign_kernel, nblocks=nb,
                               block_c=block_c)
    out_a, out_d = pl.pallas_call(
        kernel,
        grid=(nn, nb),
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_c, d), lambda i, j: (j, 0)),
            pl.BlockSpec((1, block_c), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((block_n, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, 1), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N, 1), jnp.int32),
            jax.ShapeDtypeStruct((N, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_n, 1), jnp.float32),
            pltpu.VMEM((block_n, 1), jnp.int32),
        ],
        compiler_params=pallas_compat.compiler_params(
            dimension_semantics=(pallas_compat.PARALLEL,
                                 pallas_compat.ARBITRARY)),
        interpret=interpret,
        name="quake_kmeans_assign",
    )(xs, centroids, aux)
    return out_a, out_d
