"""Pallas TPU kernel: *indexed* fused partition scan + top-k.

The sharded engine's hot loop scans a per-batch **selection** of partition
blocks out of the device-resident snapshot ``(P, S, d)``.  The baseline XLA
path must ``gather`` the selected blocks into a fresh buffer and then run a
GEMM over the copy — every scanned byte moves through HBM ~3x (gather read,
gather write, dot read; plus a layout copy the dot may insert).

This kernel removes the copy entirely: the selected partition indices are a
**scalar-prefetch operand**, so the BlockSpec ``index_map`` streams each
selected block HBM->VMEM exactly once, the MXU computes the distance tile,
and a bitonic network folds it into the running top-k held in VMEM scratch.
HBM traffic = U * S * d * bytes + (tiny) outputs — the roofline minimum for
scanning U partitions.

Per-query probe semantics are preserved by an optional ``(B, U)`` bias
(0 where query b selected block u, MASK_DIST otherwise), so the fused union
scan returns *exactly* the same top-k as the per-query gather path.

Grid: ``(q_tiles, U, S/TS)`` with dimension_semantics
(PARALLEL, ARBITRARY, ARBITRARY) — the two sequential axes walk selected
blocks and their sub-tiles while the running top-k scratch persists.

Validated in interpret mode against ``ref.scan_selected_ref`` (tests sweep
shapes/selection patterns/metrics); Mosaic/TPU is the deployment target.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import pallas_compat
from .ref import MASK_DIST
from .scan_topk import _is_pow2, bitonic_sort, merge_sorted_topk

Array = jax.Array


def _scan_indexed_kernel(sel_ref, q_ref, x_ref, aux_ref, qmask_ref,
                         out_d_ref, out_i_ref, run_d, run_i, *,
                         k_pad: int, coef: float, n_sel: int, n_sub: int,
                         block_s: int, s_cap: int):
    u = pl.program_id(1)
    s = pl.program_id(2)

    @pl.when((u == 0) & (s == 0))
    def _init():
        run_d[...] = jnp.full_like(run_d, MASK_DIST)
        run_i[...] = jnp.full_like(run_i, -1)

    q = q_ref[...]                      # (TQ, d)
    x = x_ref[0]                        # (TS, d)
    aux = aux_ref[0]                    # (TS,): ||x||^2 (+pad bias) or bias
    qb = qmask_ref[...]                 # (TQ, 1): per-query selection bias
    qx = jax.lax.dot_general(
        q, x, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)      # MXU (TQ, TS)
    dist = aux[None, :].astype(jnp.float32) + coef * qx \
        + qb.astype(jnp.float32)

    part = sel_ref[u]                   # selected partition id (scalar)
    base = part * s_cap + s * block_s
    idx = base + jax.lax.broadcasted_iota(jnp.int32, dist.shape, 1)

    d_sorted, i_sorted = bitonic_sort(dist, idx)
    m_d, m_i = merge_sorted_topk(run_d[...], run_i[...],
                                 d_sorted[:, :k_pad], i_sorted[:, :k_pad])
    run_d[...] = m_d
    run_i[...] = m_i

    @pl.when((u == n_sel - 1) & (s == n_sub - 1))
    def _write():
        out_d_ref[...] = run_d[...]
        out_i_ref[...] = run_i[...]


@functools.partial(
    jax.jit,
    static_argnames=("k_pad", "metric", "block_q", "block_s", "interpret"))
def scan_topk_indexed_pallas(queries: Array, data: Array, aux: Array,
                             sel: Array, qmask: Array, *, k_pad: int,
                             metric: str = "l2", block_q: int = 128,
                             block_s: int = 512, interpret: bool = True,
                             ) -> Tuple[Array, Array]:
    """Fused selected-block scan + top-k.  Shapes (pre-padded):

    queries: (B, d), B % block_q == 0
    data:    (P, S, d), S % block_s == 0
    aux:     (P, S)    — ``||x||^2 + pad_bias`` (L2) or ``pad_bias`` (IP)
    sel:     (U,) int32 — partition ids to scan (scalar-prefetched)
    qmask:   (B, U) f32 — 0 where query b wants block u, MASK_DIST otherwise
             (pass zeros to let every query see every selected block)

    Returns ascending (dists (B, k_pad), flat idx (B, k_pad)) where idx is
    ``partition * S + slot``; L2 dists omit ``||q||^2`` (caller adds back).
    """
    assert _is_pow2(block_s) and _is_pow2(k_pad) and k_pad <= block_s
    B, d = queries.shape
    P, S, _ = data.shape
    U = sel.shape[0]
    assert B % block_q == 0 and S % block_s == 0, (B, S, block_q, block_s)
    nq, ns = B // block_q, S // block_s
    coef = -2.0 if metric == "l2" else -1.0

    kernel = functools.partial(
        _scan_indexed_kernel, k_pad=k_pad, coef=coef, n_sel=U, n_sub=ns,
        block_s=block_s, s_cap=S)
    grid_spec = pallas_compat.prefetch_scalar_grid_spec(
        num_scalar_prefetch=1,
        grid=(nq, U, ns),
        in_specs=[
            pl.BlockSpec((block_q, d), lambda i, u, s, sel_r: (i, 0)),
            pl.BlockSpec((1, block_s, d),
                         lambda i, u, s, sel_r: (sel_r[u], s, 0)),
            pl.BlockSpec((1, block_s),
                         lambda i, u, s, sel_r: (sel_r[u], s)),
            pl.BlockSpec((block_q, 1), lambda i, u, s, sel_r: (i, u)),
        ],
        out_specs=[
            pl.BlockSpec((block_q, k_pad), lambda i, u, s, sel_r: (i, 0)),
            pl.BlockSpec((block_q, k_pad), lambda i, u, s, sel_r: (i, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, k_pad), jnp.float32),
            pltpu.VMEM((block_q, k_pad), jnp.int32),
        ],
    )
    out_d, out_i = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, k_pad), jnp.float32),
            jax.ShapeDtypeStruct((B, k_pad), jnp.int32),
        ],
        compiler_params=pallas_compat.compiler_params(
            dimension_semantics=(pallas_compat.PARALLEL,
                                 pallas_compat.ARBITRARY,
                                 pallas_compat.ARBITRARY)),
        interpret=interpret,
        name="quake_scan_topk_indexed",
    )(sel, queries, data, aux, qmask)
    return out_d, out_i


# ---------------------------------------------------------------------------
# int8-quantized variant (paper §8.2 "Vector Compression", §Perf HC1 iter 5)
# ---------------------------------------------------------------------------

def _scan_indexed_q8_kernel(sel_ref, q_ref, qscale_ref, x_ref, scale_ref,
                            aux_ref, qc_ref, qmask_ref, out_d_ref,
                            out_i_ref, run_d, run_i, *, k_pad: int,
                            coef: float, n_sel: int, n_sub: int,
                            block_s: int, s_cap: int):
    """Same scan, int8 codes: the MXU runs int8 x int8 -> int32 and the
    scalar product is dequantized with per-query x per-slot scales.  The
    dominant HBM stream (the vector codes) shrinks 4x vs f32.

    Residual (IVF-SQ8) form: codes encode x - c_j; the exact f32
    query-centroid dot rides in ``qc`` (per query x selected block) so
    only the small residual term carries quantization error:
        q.x = q.c_j + s_q * s_x * (q_i8 . r_i8).
    Plain form passes qc = 0.
    """
    u = pl.program_id(1)
    s = pl.program_id(2)

    @pl.when((u == 0) & (s == 0))
    def _init():
        run_d[...] = jnp.full_like(run_d, MASK_DIST)
        run_i[...] = jnp.full_like(run_i, -1)

    q = q_ref[...]                      # (TQ, d) int8 codes
    x = x_ref[0]                        # (TS, d) int8 codes
    aux = aux_ref[0]                    # (TS,): dequantized ||x||^2 + bias
    qb = qmask_ref[...]                 # (TQ, 1)
    qc = qc_ref[...]                    # (TQ, 1) f32 q . c_{sel[u]}
    qs = qscale_ref[...]                # (TQ, 1) per-query dequant scale
    xs = scale_ref[0]                   # (TS,)  per-slot dequant scale
    qx_i = jax.lax.dot_general(
        q, x, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32)        # MXU int8 path
    qx = qc.astype(jnp.float32) + qx_i.astype(jnp.float32) \
        * qs.astype(jnp.float32) * xs[None, :].astype(jnp.float32)
    dist = aux[None, :].astype(jnp.float32) + coef * qx \
        + qb.astype(jnp.float32)

    part = sel_ref[u]
    base = part * s_cap + s * block_s
    idx = base + jax.lax.broadcasted_iota(jnp.int32, dist.shape, 1)
    d_sorted, i_sorted = bitonic_sort(dist, idx)
    m_d, m_i = merge_sorted_topk(run_d[...], run_i[...],
                                 d_sorted[:, :k_pad], i_sorted[:, :k_pad])
    run_d[...] = m_d
    run_i[...] = m_i

    @pl.when((u == n_sel - 1) & (s == n_sub - 1))
    def _write():
        out_d_ref[...] = run_d[...]
        out_i_ref[...] = run_i[...]


@functools.partial(
    jax.jit,
    static_argnames=("k_pad", "metric", "block_q", "block_s", "interpret"))
def scan_topk_indexed_q8_pallas(q_codes: Array, q_scales: Array,
                                data_codes: Array, data_scales: Array,
                                aux: Array, qc: Array, sel: Array,
                                qmask: Array, *,
                                k_pad: int, metric: str = "l2",
                                block_q: int = 128, block_s: int = 512,
                                interpret: bool = True,
                                ) -> Tuple[Array, Array]:
    """int8 indexed scan.  q_codes (B, d) int8 + q_scales (B, 1) f32;
    data_codes (P, S, d) int8 + data_scales (P, S) f32 (per-slot symmetric
    quantization); aux (P, S) = dequantized ||x||^2 + pad bias (L2) or pad
    bias (IP); qc (B, U) f32 = exact q . c_{sel[u]} for residual codes
    (zeros for plain codes).  Same return convention as
    ``scan_topk_indexed_pallas``."""
    assert _is_pow2(block_s) and _is_pow2(k_pad) and k_pad <= block_s
    B, d = q_codes.shape
    P, S, _ = data_codes.shape
    U = sel.shape[0]
    assert B % block_q == 0 and S % block_s == 0, (B, S, block_q, block_s)
    nq, ns = B // block_q, S // block_s
    coef = -2.0 if metric == "l2" else -1.0

    kernel = functools.partial(
        _scan_indexed_q8_kernel, k_pad=k_pad, coef=coef, n_sel=U, n_sub=ns,
        block_s=block_s, s_cap=S)
    grid_spec = pallas_compat.prefetch_scalar_grid_spec(
        num_scalar_prefetch=1,
        grid=(nq, U, ns),
        in_specs=[
            pl.BlockSpec((block_q, d), lambda i, u, s, sel_r: (i, 0)),
            pl.BlockSpec((block_q, 1), lambda i, u, s, sel_r: (i, 0)),
            pl.BlockSpec((1, block_s, d),
                         lambda i, u, s, sel_r: (sel_r[u], s, 0)),
            pl.BlockSpec((1, block_s),
                         lambda i, u, s, sel_r: (sel_r[u], s)),
            pl.BlockSpec((1, block_s),
                         lambda i, u, s, sel_r: (sel_r[u], s)),
            pl.BlockSpec((block_q, 1), lambda i, u, s, sel_r: (i, u)),
            pl.BlockSpec((block_q, 1), lambda i, u, s, sel_r: (i, u)),
        ],
        out_specs=[
            pl.BlockSpec((block_q, k_pad), lambda i, u, s, sel_r: (i, 0)),
            pl.BlockSpec((block_q, k_pad), lambda i, u, s, sel_r: (i, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, k_pad), jnp.float32),
            pltpu.VMEM((block_q, k_pad), jnp.int32),
        ],
    )
    out_d, out_i = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, k_pad), jnp.float32),
            jax.ShapeDtypeStruct((B, k_pad), jnp.int32),
        ],
        compiler_params=pallas_compat.compiler_params(
            dimension_semantics=(pallas_compat.PARALLEL,
                                 pallas_compat.ARBITRARY,
                                 pallas_compat.ARBITRARY)),
        interpret=interpret,
        name="quake_scan_topk_indexed_q8",
    )(sel, q_codes, q_scales, data_codes, data_scales, aux, qc, qmask)
    return out_d, out_i


def quantize_int8(x: Array, axis: int = -1) -> Tuple[Array, Array]:
    """Symmetric per-row int8 quantization: returns (codes, scales) with
    x ~= codes * scales[..., None]."""
    amax = jnp.max(jnp.abs(x), axis=axis, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    codes = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return codes, scale[..., 0]


def quantize_int8_residual(data: Array, centroids: Array
                           ) -> Tuple[Array, Array]:
    """IVF-style residual quantization: codes encode ``x - c_j`` (the
    residual against the partition centroid), whose dynamic range is the
    cluster radius rather than the embedding norm — substantially finer
    int8 resolution at identical storage.  data (P, S, d), centroids
    (P, d); returns (codes (P, S, d) int8, scales (P, S))."""
    resid = data - centroids[:, None, :].astype(data.dtype)
    return quantize_int8(resid)
